#!/usr/bin/env bash
# CI gate for the simde-rvv reproduction: release build, tests, lints.
# Run from the repository root.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Fault-tolerance gate: panic containment, retries, trap fidelity.
# Redundant with the full test run above, but kept as a named step so a
# regression in the recovery machinery is visible at a glance.
echo "== cargo test -q --test fault_injection --test store_bug =="
cargo test -q --test fault_injection --test store_bug

# Admission-verifier gate: every lowering the pipeline can produce —
# static rules plus all tuner candidate families (widen/lmul/
# force-baseline) — for the full suite × both modes × three vlens must
# pass the static verifier. Any rejection fails CI: the verifier's
# accept ⇒ no-trap contract only protects runs if healthy programs are
# actually accepted.
echo "== verify --static (suite x mode x vlen {128,256,512}) =="
cargo run --release --quiet -- verify --static --vlens 128,256,512

# Autotuner smoke: one kernel, candidate budget just wide enough to
# cover the widen AND lmul transform families — proves the search →
# database → report pipeline end to end in seconds, and that the lmul
# candidates are enumerated and scored.
echo "== tune --smoke (widen + lmul families) =="
cargo run --release --quiet -- tune --smoke --out /tmp/TUNED-smoke.json
grep -q '"lmul:2"' /tmp/TUNED-smoke.json
grep -q '"lmul:4"' /tmp/TUNED-smoke.json

echo "== cargo fmt -- --check =="
cargo fmt -- --check

# -D warnings also enforces the warn-level clippy::unwrap_used /
# clippy::expect_used gates scoped to the rvv and sim modules (their
# mod.rs inner attributes — rvv covers the new rvv::verify admission
# pass): execution-layer faults must be SimTraps, and the verifier
# itself must never panic on a malformed program.
echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "CI OK"
