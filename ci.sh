#!/usr/bin/env bash
# CI gate for the simde-rvv reproduction: release build, tests, lints.
# Run from the repository root.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

# Fault-tolerance gate: panic containment, retries, trap fidelity.
# Redundant with the full test run above, but kept as a named step so a
# regression in the recovery machinery is visible at a glance.
echo "== cargo test -q --test fault_injection --test store_bug =="
cargo test -q --test fault_injection --test store_bug

# Autotuner smoke: one kernel, candidate budget just wide enough to
# cover the widen AND lmul transform families — proves the search →
# database → report pipeline end to end in seconds, and that the lmul
# candidates are enumerated and scored.
echo "== tune --smoke (widen + lmul families) =="
cargo run --release --quiet -- tune --smoke --out /tmp/TUNED-smoke.json
grep -q '"lmul:2"' /tmp/TUNED-smoke.json
grep -q '"lmul:4"' /tmp/TUNED-smoke.json

echo "== cargo fmt -- --check =="
cargo fmt -- --check

# -D warnings also enforces the warn-level clippy::unwrap_used /
# clippy::expect_used gates scoped to the rvv and sim modules (their
# mod.rs inner attributes): execution-layer faults must be SimTraps.
echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "CI OK"
