#!/usr/bin/env bash
# CI gate for the simde-rvv reproduction: release build, tests, lints.
# Run from the repository root.
set -euo pipefail
cd "$(dirname "$0")/rust"

echo "== cargo build --release =="
cargo build --release

echo "== cargo test -q =="
cargo test -q

echo "== cargo clippy -- -D warnings =="
cargo clippy -- -D warnings

echo "CI OK"
