//! End-to-end migration driver — the full §4.2 workflow on real
//! workloads, the repository's E2E validation example.
//!
//! For each of the 10 XNNPACK kernels (or one chosen with
//! `--kernel <name>`):
//!   1. interpret the NEON program (golden reference),
//!   2. translate with original-SIMDe (baseline) and RVV-enhanced SIMDe,
//!   3. execute both on the Spike-like RVV simulator and check numerics,
//!   4. check the NEON golden against the JAX/XLA oracle (PJRT) if
//!      `artifacts/` exists,
//!   5. report the dynamic-instruction-count speedup (Figure 2).
//!
//! Run: make artifacts && cargo run --release --example migrate_xnnpack

use std::path::Path;
use std::time::Instant;

use anyhow::Result;

use simde_rvv::coordinator::verify_kernel;
use simde_rvv::kernels;
use simde_rvv::runtime::GoldenOracle;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::{Mode, Translator};

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let only = args
        .iter()
        .position(|a| a == "--kernel")
        .and_then(|i| args.get(i + 1))
        .cloned();

    let oracle = match GoldenOracle::load(Path::new("artifacts")) {
        Ok(o) => {
            println!("golden oracle loaded: {} ops on {}\n", o.ops().len(), o.platform());
            Some(o)
        }
        Err(e) => {
            println!("note: running without the XLA oracle ({e:#})\n");
            None
        }
    };

    let cfg = RvvConfig::new(128);
    let cases: Vec<_> = match &only {
        Some(k) => vec![kernels::by_name(k).expect("unknown kernel")],
        None => kernels::suite(),
    };

    println!(
        "{:<12} {:>12} {:>12} {:>9}  {:>9}  verified",
        "kernel", "baseline", "rvv-custom", "speedup", "wall"
    );
    let mut speedups = Vec::new();
    for case in &cases {
        let t0 = Instant::now();
        let (rb, _) = Translator::new(Mode::Baseline, cfg).translate(&case.prog)?;
        let (rc, _) = Translator::new(Mode::RvvCustom, cfg).translate(&case.prog)?;
        let (_, sb) = Simulator::new(&rb, cfg, &case.inputs)?.run()?;
        let (_, sc) = Simulator::new(&rc, cfg, &case.inputs)?.run()?;
        let outcome = verify_kernel(case, 128, oracle.as_ref())?;
        let speedup = sb.total() as f64 / sc.total() as f64;
        speedups.push(speedup);
        println!(
            "{:<12} {:>12} {:>12} {:>8.2}x  {:>8.1?}  {}",
            case.name,
            sb.total(),
            sc.total(),
            speedup,
            t0.elapsed(),
            if outcome.passed { "yes" } else { "NO" }
        );
        assert!(outcome.passed, "{} failed verification", case.name);
    }
    let (min, max) = speedups
        .iter()
        .fold((f64::INFINITY, 0.0f64), |(lo, hi), &s| (lo.min(s), hi.max(s)));
    println!("\nspeedup range: {min:.2}x – {max:.2}x   (paper Figure 2: 1.51x – 5.13x)");
    Ok(())
}
