//! Bit reverse — the paper's Listing 7 ("Binary Magic Numbers", Dr.
//! Dobb's 1983) conversion of `vrbitq_u8`.
//!
//! Shows the complex-algorithm conversion class: the custom RVV lowering
//! vectorises the three magic-number swap stages (15 RVV ops for 16
//! lanes), while baseline SIMDe scalarises the loop (~12 scalar
//! instructions *per lane*).
//!
//! Run: cargo run --release --example bit_reverse

use anyhow::Result;

use simde_rvv::ir::{AddrExpr, Arg, ProgramBuilder};
use simde_rvv::neon::elem::Elem;
use simde_rvv::neon::interp::{Buffer, Inputs, NeonInterp};
use simde_rvv::neon::ops::Family;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::{Mode, Translator};

fn main() -> Result<()> {
    let n = 1024usize;
    let mut b = ProgramBuilder::new("rbit_demo");
    let x_buf = b.input("X", Elem::U8, n);
    let y_buf = b.output("Y", Elem::U8, n);
    b.loop_(0, n as i64, 16, |b, i| {
        let x = b.vop(Family::Ld1, Elem::U8, true, vec![Arg::mem(x_buf, AddrExpr::s(i))]);
        let r = b.vop(Family::Rbit, Elem::U8, true, vec![Arg::V(x)]);
        b.vstore(Family::St1, Elem::U8, true, vec![Arg::mem(y_buf, AddrExpr::s(i)), Arg::V(r)]);
    });
    let prog = b.finish();

    let xs: Vec<u8> = (0..n).map(|i| (i * 37 + 11) as u8).collect();
    let mut inputs = Inputs::new();
    inputs.insert("X".into(), Buffer::from_u8s(&xs));

    let golden = NeonInterp::new(&prog, &inputs)?.run()?;
    let cfg = RvvConfig::new(128);

    println!("vrbitq_u8 over {n} bytes — Listing 7 conversion\n");
    let mut totals = Vec::new();
    for mode in [Mode::RvvCustom, Mode::Baseline] {
        let (rp, _) = Translator::new(mode, cfg).translate(&prog)?;
        let (out, stats) = Simulator::new(&rp, cfg, &inputs)?.run()?;
        assert_eq!(out["Y"].data, golden["Y"].data, "{mode:?} output mismatch");
        println!("{:<11} {}", mode.name(), stats.summary());
        totals.push(stats.total());
    }
    println!(
        "\nspeedup (baseline/custom): {:.2}x",
        totals[1] as f64 / totals[0] as f64
    );

    // spot check the magic
    let y = golden["Y"].data.clone();
    println!("\nexamples: 0x{:02x} -> 0x{:02x}, 0x{:02x} -> 0x{:02x}", xs[0], y[0], xs[1], y[1]);
    Ok(())
}
