//! A1 ablation — vector-length-agnostic scaling study.
//!
//! The paper's §3.2 makes the conversion vlen-aware (Table 2). This
//! example sweeps VLEN in {128, 256, 512}: NEON 128-bit types always
//! occupy the low 128 bits of the wider registers (LMUL=1 fixed-vlen
//! types), so the *custom* instruction count is vlen-invariant, while the
//! baseline's union grows with vlen (the Listing 4 memcpy hazard —
//! demonstrated at the end with the store bug injected).
//!
//! Run: cargo run --release --example vlen_sweep

use anyhow::Result;

use simde_rvv::coordinator;
use simde_rvv::kernels;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::types_map::union_size_bytes;
use simde_rvv::simde::{Mode, Translator};
use simde_rvv::neon::vreg::VecTy;
use simde_rvv::neon::elem::Elem;

fn main() -> Result<()> {
    let vlens = [128u32, 256, 512];
    println!("## VLA sweep: Figure-2 speedups by VLEN\n");
    print!("{:<12}", "kernel");
    for v in vlens {
        print!(" vlen={v:<6}");
    }
    println!();
    let tables: Vec<_> = vlens
        .iter()
        .map(|&v| coordinator::figure2(v, 4))
        .collect::<Result<Vec<_>>>()?;
    for (i, name) in kernels::NAMES.iter().enumerate() {
        print!("{name:<12}");
        for t in &tables {
            print!(" {:<10}", format!("{:.2}x", t[i].speedup));
        }
        println!();
    }

    println!("\n## union size growth (Listing 4 hazard precondition)\n");
    let q = VecTy::q(Elem::I32);
    for v in vlens {
        println!(
            "vlen={v}: sizeof(simde_int32x4 union) = {} bytes (NEON value: 16)",
            union_size_bytes(q, v, true)
        );
    }

    println!("\n## store-bug injection at vlen=256 (memcpy(sizeof(union)))\n");
    let case = kernels::vrelu::build(64);
    let cfg = RvvConfig::new(256);
    let tr = Translator::new(Mode::Baseline, cfg).with_union_store_bug(true);
    let (rp, _) = tr.translate(&case.prog)?;
    match Simulator::new(&rp, cfg, &case.inputs)?.run() {
        Err(e) => println!("store bug reproduced -> simulator fault: {e:#}"),
        Ok(_) => println!("store overran into adjacent elements (see tests/store_bug.rs)"),
    }
    Ok(())
}
