//! Quickstart — the paper's Listings 9 & 10 end-to-end.
//!
//! Builds the NEON vector-add program (`vld1q_s32` x2, `vaddq_s32`,
//! `vst1q_s32` over {0,1,2,3} + {4,5,6,7}), translates it with the
//! RVV-enhanced SIMDe engine, prints the Listing-10-style RVV instruction
//! stream, and executes it on the Spike-like simulator.
//!
//! Run: cargo run --release --example quickstart

use anyhow::Result;

use simde_rvv::ir::{AddrExpr, Arg, ProgramBuilder};
use simde_rvv::neon::elem::Elem;
use simde_rvv::neon::interp::{Buffer, Inputs, NeonInterp};
use simde_rvv::neon::ops::Family;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::{Mode, Translator};

fn main() -> Result<()> {
    // --- Listing 9: the NEON source -------------------------------------
    let mut b = ProgramBuilder::new("listing9");
    let a_buf = b.input("A", Elem::I32, 4);
    let b_buf = b.input("B", Elem::I32, 4);
    let o_buf = b.output("A_out", Elem::I32, 4);
    let va = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(a_buf, AddrExpr::k(0))]);
    let vb = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(b_buf, AddrExpr::k(0))]);
    let vc = b.vop(Family::Add, Elem::I32, true, vec![Arg::V(va), Arg::V(vb)]);
    b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o_buf, AddrExpr::k(0)), Arg::V(vc)]);
    let prog = b.finish();

    println!("// Listing 9 (NEON source):");
    println!("//   va = vld1q_s32(A); vb = vld1q_s32(B);");
    println!("//   va = vaddq_s32(va, vb); vst1q_s32(A, va);\n");

    let mut inputs = Inputs::new();
    inputs.insert("A".into(), Buffer::from_i32s(&[0, 1, 2, 3]));
    inputs.insert("B".into(), Buffer::from_i32s(&[4, 5, 6, 7]));

    // --- NEON golden ------------------------------------------------------
    let golden = NeonInterp::new(&prog, &inputs)?.run()?;
    println!("NEON golden result: {:?}\n", golden["A_out"].as_i32s());

    // --- translate to RVV (both modes) -----------------------------------
    let cfg = RvvConfig::new(128);
    for mode in [Mode::RvvCustom, Mode::Baseline] {
        let (rp, report) = Translator::new(mode, cfg).translate(&prog)?;
        println!("=== {} translation (Listing 10 analogue) ===", mode.name());
        print!("{}", rp.disasm());
        let (out, stats) = Simulator::new(&rp, cfg, &inputs)?.run()?;
        assert_eq!(out["A_out"].as_i32s(), golden["A_out"].as_i32s());
        println!("result: {:?}", out["A_out"].as_i32s());
        println!("dynamic instructions: {}", stats.summary());
        println!("conversion methods: {:?}\n", report.count_by_method());
    }

    println!("printf(\"%d\", A[0]) -> {}", golden["A_out"].as_i32s()[0]);
    Ok(())
}
