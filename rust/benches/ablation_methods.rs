//! A2 ablation bench: which conversion categories buy the speedup?
//! Starting from full-custom, force one category at a time back to the
//! baseline (generic) rules and report the per-kernel slowdown.

use simde_rvv::benchlib::header;
use simde_rvv::kernels;
use simde_rvv::neon::ops::Category;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::{Mode, Translator};

fn total(case: &kernels::KernelCase, force: Option<Category>) -> u64 {
    let cfg = RvvConfig::new(128);
    let mut tr = Translator::new(Mode::RvvCustom, cfg);
    if let Some(c) = force {
        tr = tr.with_forced_baseline(vec![c]);
    }
    let (rp, _) = tr.translate(&case.prog).unwrap();
    let (_, stats) = Simulator::new(&rp, cfg, &case.inputs).unwrap().run().unwrap();
    stats.total()
}

fn main() {
    header("A2 — per-category contribution (icount vs full-custom, >1 means the category's custom rules matter)");
    let cats = [
        Category::Memory,
        Category::Arith,
        Category::Compare,
        Category::Bitwise,
        Category::Convert,
        Category::FloatEst,
        Category::Permute,
    ];
    print!("| kernel | full |");
    for c in cats {
        print!(" -{c:?} |");
    }
    println!();
    print!("|---|---:|");
    for _ in cats {
        print!("---:|");
    }
    println!();
    for case in kernels::suite() {
        let full = total(&case, None);
        print!("| {} | {} |", case.name, full);
        for c in cats {
            let t = total(&case, Some(c));
            print!(" {:.2}x |", t as f64 / full as f64);
        }
        println!();
    }
}
