//! A1 ablation bench: Figure-2 speedups as VLEN scales 128 -> 512.
//! Custom-mode counts are vlen-invariant (fixed-vlen LMUL=1 types keep
//! NEON values in the low 128 bits); the ratio shifts only through the
//! baseline's union traffic.

use simde_rvv::benchlib::header;
use simde_rvv::coordinator;
use simde_rvv::kernels;

fn main() {
    header("A1 — vlen sweep");
    let vlens = [128u32, 256, 512];
    let tables: Vec<_> = vlens
        .iter()
        .map(|&v| coordinator::figure2(v, 4).expect("figure2"))
        .collect();
    print!("| kernel |");
    for v in vlens {
        print!(" vlen={v} |");
    }
    println!();
    print!("|---|");
    for _ in vlens {
        print!("---:|");
    }
    println!();
    for (i, name) in kernels::NAMES.iter().enumerate() {
        print!("| {name} |");
        for t in &tables {
            print!(" {:.2}x |", t[i].speedup);
        }
        println!();
        for t in &tables {
            assert!(t[i].speedup > 1.0);
        }
    }
}
