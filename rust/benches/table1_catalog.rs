//! Table 1 regeneration bench: the full-surface NEON catalog, counts by
//! return base type vs the paper, and generation throughput.

use simde_rvv::benchlib::{bench_auto, header};
use simde_rvv::neon::catalog;
use simde_rvv::report;
use std::time::Duration;

fn main() {
    header("Table 1 — NEON intrinsic counts by return base type");
    print!("{}", report::table1_markdown());

    let cat = catalog::generate();
    println!("\ncatalog size: {} intrinsics", cat.len());
    assert!(cat.len() > 2500);

    header("catalog generation throughput");
    let r = bench_auto("catalog::generate", Duration::from_millis(400), || {
        std::hint::black_box(catalog::generate().len());
    });
    println!("{}", r.line());
}
