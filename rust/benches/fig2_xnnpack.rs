//! Figure 2 regeneration bench: the 10 XNNPACK kernels through both SIMDe
//! modes at vlen=128, reporting dynamic instruction counts + speedups
//! (the paper's metric) and pipeline wall time.

use simde_rvv::benchlib::{bench_auto, header};
use simde_rvv::coordinator;
use simde_rvv::kernels;
use simde_rvv::report;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::{Mode, Translator};
use std::time::Duration;

fn main() {
    header("Figure 2 — XNNPACK suite, baseline vs RVV-enhanced SIMDe");
    let rows = coordinator::figure2(128, 4).expect("figure2");
    print!("{}", report::fig2_markdown(&rows, 128));

    // sanity: the Figure-2 claims hold
    for r in &rows {
        assert!(r.speedup > 1.0, "{} regressed", r.kernel);
    }

    header("pipeline wall time per kernel (translate + simulate, custom mode)");
    let cfg = RvvConfig::new(128);
    for case in kernels::suite() {
        let r = bench_auto(case.name, Duration::from_millis(400), || {
            let (rp, _) = Translator::new(Mode::RvvCustom, cfg).translate(&case.prog).unwrap();
            let (_, stats) = Simulator::new(&rp, cfg, &case.inputs).unwrap().run().unwrap();
            std::hint::black_box(stats.total());
        });
        println!("{}", r.line());
    }
}
