//! Table 2 regeneration bench: the NEON->RVV type mapping across vlen
//! bands and extension sets, plus mapping throughput.

use simde_rvv::benchlib::{bench_auto, header};
use simde_rvv::report;
use simde_rvv::simde::types_map::{map_neon_type, table2_rows};
use std::time::Duration;

fn main() {
    header("Table 2 — NEON types -> RVV fixed-vlen types");
    print!("{}", report::table2_markdown(true));
    println!();
    print!("{}", report::table2_markdown(false));

    header("type-map throughput (22 rows x 3 vlens x 2 ext-sets)");
    let rows = table2_rows();
    let r = bench_auto("types_map", Duration::from_millis(200), || {
        let mut n = 0;
        for &vt in &rows {
            for vlen in [32, 64, 128] {
                for zvfh in [false, true] {
                    if map_neon_type(vt, vlen, zvfh).is_ok() {
                        n += 1;
                    }
                }
            }
        }
        std::hint::black_box(n);
    });
    println!("{}", r.line());
}
