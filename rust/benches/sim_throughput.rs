//! Perf bench (L3 hot path): simulator + translator throughput.
//! The EXPERIMENTS.md §Perf target: >= 100 M simulated element-ops/s.

use simde_rvv::benchlib::{bench_auto, header};
use simde_rvv::kernels;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::{Mode, Translator};
use std::time::Duration;

fn main() {
    let cfg = RvvConfig::new(128);
    header("translator throughput");
    for case in [kernels::gemm::case(), kernels::vsigmoid::case()] {
        let r = bench_auto(&format!("translate/{}", case.name), Duration::from_millis(300), || {
            let (rp, _) = Translator::new(Mode::RvvCustom, cfg).translate(&case.prog).unwrap();
            std::hint::black_box(rp.static_ops());
        });
        println!("{}", r.line());
    }

    header("simulator throughput (custom-mode programs)");
    for case in kernels::suite() {
        let (rp, _) = Translator::new(Mode::RvvCustom, cfg).translate(&case.prog).unwrap();
        let mut insts = 0u64;
        let r = bench_auto(&format!("simulate/{}", case.name), Duration::from_millis(500), || {
            let (_, stats) = Simulator::new(&rp, cfg, &case.inputs).unwrap().run().unwrap();
            insts = stats.total();
            std::hint::black_box(insts);
        });
        let vec_elems = insts * 4; // ~4 lanes per vector instruction
        let mips = insts as f64 / r.median.as_secs_f64() / 1e6;
        let meps = vec_elems as f64 / r.median.as_secs_f64() / 1e6;
        println!("{}  [{mips:.1} M inst/s, ~{meps:.0} M elem-ops/s]", r.line());
    }
}
