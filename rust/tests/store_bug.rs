//! Failure injection — the paper's Listing 4 union-store bug.
//!
//! Under partial conversion, SIMDe's generic store does
//! `memcpy(ptr, &union, sizeof(union))`; once the RVV member makes the
//! union larger than the NEON value (vlen > 128), the store writes past
//! the intended 16 bytes. The paper's fix is the customized `vse32`
//! with the exact element count ("Ensure that we save the correct number
//! of elements into memory").

use simde_rvv::ir::{AddrExpr, Arg, ProgramBuilder};
use simde_rvv::neon::elem::Elem;
use simde_rvv::neon::interp::{Buffer, Inputs};
use simde_rvv::neon::ops::Family;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::{Mode, Translator};

/// Two adjacent 4-element stores into one 12-element output buffer (the
/// slack keeps the oversized store in-bounds so the *corruption* — not a
/// fault — is observable).
fn two_store_program() -> simde_rvv::ir::Program {
    let mut b = ProgramBuilder::new("adjacent_stores");
    let x = b.input("X", Elem::I32, 8);
    let o = b.output("O", Elem::I32, 12);
    let lo = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(x, AddrExpr::k(0))]);
    let hi = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(x, AddrExpr::k(4))]);
    // store the *high* half first, then the low half: a 32-byte buggy
    // store of the low half would overwrite the high half's result
    b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o, AddrExpr::k(4)), Arg::V(hi)]);
    b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o, AddrExpr::k(0)), Arg::V(lo)]);
    b.finish()
}

fn inputs() -> Inputs {
    let mut i = Inputs::new();
    i.insert("X".into(), Buffer::from_i32s(&[1, 2, 3, 4, 5, 6, 7, 8]));
    i
}

#[test]
fn buggy_store_corrupts_adjacent_memory_at_vlen_256() {
    let cfg = RvvConfig::new(256);
    let prog = two_store_program();

    // correct baseline: both halves intact
    let (rp, _) = Translator::new(Mode::Baseline, cfg).translate(&prog).unwrap();
    let (out, _) = Simulator::new(&rp, cfg, &inputs()).unwrap().run().unwrap();
    assert_eq!(out["O"].as_i32s()[..8], [1, 2, 3, 4, 5, 6, 7, 8]);

    // injected Listing-4 bug: memcpy(sizeof(union)) = 32 bytes
    let tr = Translator::new(Mode::Baseline, cfg).with_union_store_bug(true);
    let (rp, _) = tr.translate(&prog).unwrap();
    let (out, _) = Simulator::new(&rp, cfg, &inputs()).unwrap().run().unwrap();
    let got = out["O"].as_i32s();
    assert_eq!(got[..4], [1, 2, 3, 4], "low half must still be written");
    assert_ne!(
        got[4..],
        [5, 6, 7, 8],
        "the oversized store must clobber the adjacent elements"
    );
}

#[test]
fn buggy_store_is_harmless_at_vlen_128() {
    // union size == NEON size at vlen=128: the bug is latent
    let cfg = RvvConfig::new(128);
    let prog = two_store_program();
    let tr = Translator::new(Mode::Baseline, cfg).with_union_store_bug(true);
    let (rp, _) = tr.translate(&prog).unwrap();
    let (out, _) = Simulator::new(&rp, cfg, &inputs()).unwrap().run().unwrap();
    assert_eq!(out["O"].as_i32s()[..8], [1, 2, 3, 4, 5, 6, 7, 8]);
}

#[test]
fn custom_store_is_exact_at_any_vlen() {
    // the paper's fix: vse32 with the exact element count
    for vlen in [128, 256, 512] {
        let cfg = RvvConfig::new(vlen);
        let prog = two_store_program();
        let (rp, _) = Translator::new(Mode::RvvCustom, cfg).translate(&prog).unwrap();
        let (out, _) = Simulator::new(&rp, cfg, &inputs()).unwrap().run().unwrap();
        assert_eq!(out["O"].as_i32s()[..8], [1, 2, 3, 4, 5, 6, 7, 8], "vlen={vlen}");
    }
}

#[test]
fn buggy_store_at_buffer_end_faults() {
    // when the oversized store runs past the buffer, the simulator traps
    let cfg = RvvConfig::new(256);
    let mut b = ProgramBuilder::new("end_store");
    let x = b.input("X", Elem::I32, 4);
    let o = b.output("O", Elem::I32, 4); // exactly 16 bytes
    let v = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(x, AddrExpr::k(0))]);
    b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o, AddrExpr::k(0)), Arg::V(v)]);
    let prog = b.finish();
    let mut inputs = Inputs::new();
    inputs.insert("X".into(), Buffer::from_i32s(&[1, 2, 3, 4]));

    let tr = Translator::new(Mode::Baseline, cfg).with_union_store_bug(true);
    let (rp, _) = tr.translate(&prog).unwrap();
    let r = Simulator::new(&rp, cfg, &inputs).unwrap().run();
    assert!(r.is_err(), "32-byte store into a 16-byte buffer must fault");
}
