//! Failure injection — the paper's Listing 4 union-store bug.
//!
//! Under partial conversion, SIMDe's generic store does
//! `memcpy(ptr, &union, sizeof(union))`; once the RVV member makes the
//! union larger than the NEON value (vlen > 128), the store writes past
//! the intended 16 bytes. The paper's fix is the customized `vse32`
//! with the exact element count ("Ensure that we save the correct number
//! of elements into memory").

use simde_rvv::ir::{AddrExpr, Arg, BufDecl, BufKind, ProgramBuilder};
use simde_rvv::neon::elem::Elem;
use simde_rvv::neon::interp::{Buffer, Inputs};
use simde_rvv::neon::ops::Family;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::rvv::ops::{Dst, MemRef, RvvInst, RvvKind, Src};
use simde_rvv::rvv::program::{RStmt, RvvProgram};
use simde_rvv::rvv::vtype::{Lmul, Sew};
use simde_rvv::sim::{decode, Engine, SimTrap, Simulator, TrapKind};
use simde_rvv::simde::{Mode, Translator};

/// Two adjacent 4-element stores into one 12-element output buffer (the
/// slack keeps the oversized store in-bounds so the *corruption* — not a
/// fault — is observable).
fn two_store_program() -> simde_rvv::ir::Program {
    let mut b = ProgramBuilder::new("adjacent_stores");
    let x = b.input("X", Elem::I32, 8);
    let o = b.output("O", Elem::I32, 12);
    let lo = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(x, AddrExpr::k(0))]);
    let hi = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(x, AddrExpr::k(4))]);
    // store the *high* half first, then the low half: a 32-byte buggy
    // store of the low half would overwrite the high half's result
    b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o, AddrExpr::k(4)), Arg::V(hi)]);
    b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o, AddrExpr::k(0)), Arg::V(lo)]);
    b.finish()
}

fn inputs() -> Inputs {
    let mut i = Inputs::new();
    i.insert("X".into(), Buffer::from_i32s(&[1, 2, 3, 4, 5, 6, 7, 8]));
    i
}

#[test]
fn buggy_store_corrupts_adjacent_memory_at_vlen_256() {
    let cfg = RvvConfig::new(256);
    let prog = two_store_program();

    // correct baseline: both halves intact
    let (rp, _) = Translator::new(Mode::Baseline, cfg).translate(&prog).unwrap();
    let (out, _) = Simulator::new(&rp, cfg, &inputs()).unwrap().run().unwrap();
    assert_eq!(out["O"].as_i32s()[..8], [1, 2, 3, 4, 5, 6, 7, 8]);

    // injected Listing-4 bug: memcpy(sizeof(union)) = 32 bytes
    let tr = Translator::new(Mode::Baseline, cfg).with_union_store_bug(true);
    let (rp, _) = tr.translate(&prog).unwrap();
    let (out, _) = Simulator::new(&rp, cfg, &inputs()).unwrap().run().unwrap();
    let got = out["O"].as_i32s();
    assert_eq!(got[..4], [1, 2, 3, 4], "low half must still be written");
    assert_ne!(
        got[4..],
        [5, 6, 7, 8],
        "the oversized store must clobber the adjacent elements"
    );
}

#[test]
fn buggy_store_is_harmless_at_vlen_128() {
    // union size == NEON size at vlen=128: the bug is latent
    let cfg = RvvConfig::new(128);
    let prog = two_store_program();
    let tr = Translator::new(Mode::Baseline, cfg).with_union_store_bug(true);
    let (rp, _) = tr.translate(&prog).unwrap();
    let (out, _) = Simulator::new(&rp, cfg, &inputs()).unwrap().run().unwrap();
    assert_eq!(out["O"].as_i32s()[..8], [1, 2, 3, 4, 5, 6, 7, 8]);
}

#[test]
fn custom_store_is_exact_at_any_vlen() {
    // the paper's fix: vse32 with the exact element count
    for vlen in [128, 256, 512] {
        let cfg = RvvConfig::new(vlen);
        let prog = two_store_program();
        let (rp, _) = Translator::new(Mode::RvvCustom, cfg).translate(&prog).unwrap();
        let (out, _) = Simulator::new(&rp, cfg, &inputs()).unwrap().run().unwrap();
        assert_eq!(out["O"].as_i32s()[..8], [1, 2, 3, 4, 5, 6, 7, 8], "vlen={vlen}");
    }
}

#[test]
fn buggy_store_at_buffer_end_faults() {
    // when the oversized store runs past the buffer, the simulator traps
    let cfg = RvvConfig::new(256);
    let mut b = ProgramBuilder::new("end_store");
    let x = b.input("X", Elem::I32, 4);
    let o = b.output("O", Elem::I32, 4); // exactly 16 bytes
    let v = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(x, AddrExpr::k(0))]);
    b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o, AddrExpr::k(0)), Arg::V(v)]);
    let prog = b.finish();
    let mut inputs = Inputs::new();
    inputs.insert("X".into(), Buffer::from_i32s(&[1, 2, 3, 4]));

    let tr = Translator::new(Mode::Baseline, cfg).with_union_store_bug(true);
    let (rp, _) = tr.translate(&prog).unwrap();
    let err = Simulator::new(&rp, cfg, &inputs).unwrap().run().unwrap_err();

    // the fault is a structured trap carrying the execution context, not
    // a bare string: kind, kernel, engine and the offending instruction
    let t = err.downcast_ref::<SimTrap>().expect("SimTrap behind the anyhow error");
    assert!(
        matches!(t.kind, TrapKind::OutOfBounds { store: true, .. }),
        "expected an out-of-bounds store, got {:?}",
        t.kind
    );
    assert_eq!(t.kind.label(), "out-of-bounds-store");
    assert_eq!(t.engine, Some("interp"));
    assert!(
        t.kernel.as_deref().unwrap_or("").contains("end_store"),
        "kernel context: {:?}",
        t.kernel
    );
    assert!(t.pc.is_some(), "trap must carry a PC");
    let inst = t.inst.as_deref().unwrap_or("");
    assert!(inst.contains("vse"), "inst render: {inst}");
}

/// Hand-built straight-line program: `vle32` from X, then a `vse32` whose
/// base element index pushes the store 8 bytes past O's end.
fn oob_line_program() -> RvvProgram {
    RvvProgram {
        name: "oob_line".into(),
        bufs: vec![
            BufDecl { name: "X".into(), elem: Elem::I32, len: 4, kind: BufKind::Input },
            BufDecl { name: "O".into(), elem: Elem::I32, len: 4, kind: BufKind::Output },
        ],
        body: vec![
            RStmt::Op(RvvInst {
                kind: RvvKind::Vle,
                sew: Sew::E32,
                lmul: Lmul::M1,
                vl: 4,
                dst: Dst::V(0),
                srcs: vec![],
                mask: None,
                mem: Some(MemRef { buf: 0, index: AddrExpr::k(0), stride: 1 }),
            }),
            RStmt::Op(RvvInst {
                kind: RvvKind::Vse,
                sew: Sew::E32,
                lmul: Lmul::M1,
                vl: 4,
                dst: Dst::None,
                srcs: vec![Src::V(0)],
                mask: None,
                mem: Some(MemRef { buf: 1, index: AddrExpr::k(2), stride: 1 }),
            }),
        ],
        n_vregs: 1,
        n_mregs: 0,
        n_sregs: 0,
    }
}

#[test]
fn oob_store_trap_reports_pc_and_inst_on_both_engines() {
    let prog = oob_line_program();
    let cfg = RvvConfig::new(128);
    let mut inputs = Inputs::new();
    inputs.insert("X".into(), Buffer::from_i32s(&[1, 2, 3, 4]));

    // 16-byte store at byte 8 of a 16-byte buffer, from the second op
    let want =
        TrapKind::OutOfBounds { buf: 1, byte_off: 8, width: 16, len: 16, store: true };

    let err = Simulator::new(&prog, cfg, &inputs).unwrap().run().unwrap_err();
    let t = err.downcast_ref::<SimTrap>().expect("interp trap");
    assert_eq!(t.kind, want);
    assert_eq!(t.pc, Some(1), "second statement faults");
    assert_eq!(t.engine, Some("interp"));
    assert_eq!(t.kernel.as_deref(), Some("oob_line"));
    assert!(t.inst.as_deref().unwrap_or("").contains("vse32"), "inst: {:?}", t.inst);

    let dec = decode(&prog);
    let err = Engine::new(&prog, &dec, cfg, &inputs).unwrap().run().unwrap_err();
    let t = err.downcast_ref::<SimTrap>().expect("decoded trap");
    assert_eq!(t.kind, want);
    assert_eq!(t.pc, Some(1), "straight-line decoded stream maps 1:1");
    assert_eq!(t.engine, Some("decoded"));
    assert_eq!(t.kernel.as_deref(), Some("oob_line"));
    assert!(t.inst.as_deref().unwrap_or("").contains("vse32"), "inst: {:?}", t.inst);
}

#[test]
fn illegal_operand_program_traps_on_both_engines() {
    // vfadd at e8: no float element type of that width — an illegal
    // instruction, raised identically by both engines at pc 0
    let prog = RvvProgram {
        name: "e8_float".into(),
        bufs: vec![],
        body: vec![RStmt::Op(RvvInst {
            kind: RvvKind::Vfadd,
            sew: Sew::E8,
            lmul: Lmul::M1,
            vl: 4,
            dst: Dst::V(2),
            srcs: vec![Src::V(0), Src::V(1)],
            mask: None,
            mem: None,
        })],
        n_vregs: 3,
        n_mregs: 0,
        n_sregs: 0,
    };
    let cfg = RvvConfig::new(128);

    let err = Simulator::new(&prog, cfg, &Inputs::new()).unwrap().run().unwrap_err();
    let t = err.downcast_ref::<SimTrap>().expect("interp trap");
    assert!(
        matches!(t.kind, TrapKind::IllegalInstruction(_)),
        "expected illegal-instruction, got {:?}",
        t.kind
    );
    assert_eq!(t.pc, Some(0));
    assert_eq!(t.engine, Some("interp"));
    assert_eq!(t.kernel.as_deref(), Some("e8_float"));

    let dec = decode(&prog);
    let err = Engine::new(&prog, &dec, cfg, &Inputs::new()).unwrap().run().unwrap_err();
    let t = err.downcast_ref::<SimTrap>().expect("decoded trap");
    assert!(
        matches!(t.kind, TrapKind::IllegalInstruction(_)),
        "expected illegal-instruction, got {:?}",
        t.kind
    );
    assert_eq!(t.pc, Some(0));
    assert_eq!(t.engine, Some("decoded"));
}
