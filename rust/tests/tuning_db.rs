//! Round-trip and staleness guarantees for the persistent tuning
//! database (`TUNED.json`): what `tune` writes, `bench --tuned` must read
//! back exactly; a database from a different format version or for a
//! kernel whose shape changed must be refused rather than silently steer
//! lowering.

use std::collections::HashMap;

use simde_rvv::kernels;
use simde_rvv::simde::Mode;
use simde_rvv::tuner::db::{CandidateScore, TunedEntry, TuningDb, VERSION};
use simde_rvv::tuner::Candidate;

fn sample_db() -> TuningDb {
    let score = |id: &str, ok: bool, dyn_insts: u64, wall_ns: u64, error: &str| CandidateScore {
        id: id.into(),
        ok,
        dyn_insts,
        wall_ns,
        error: error.into(),
    };
    TuningDb {
        entries: vec![
            TunedEntry {
                kernel: "vrelu".into(),
                mode: Mode::RvvCustom,
                vlen: 512,
                fingerprint: 0xfedc_ba98_7654_3210, // above 2^53 on purpose
                engine: "decoded".into(),
                winner: "widen:4".into(),
                candidates: vec![
                    score("static", true, 36877, 120_000, ""),
                    score("widen:2", true, 18445, 70_000, ""),
                    score("widen:4", true, 9229, 40_000, ""),
                    score(
                        "widen:8",
                        false,
                        0,
                        0,
                        "widen:8: no loop admits widening by 8\nwith \"quotes\" and \\slashes\\",
                    ),
                ],
            },
            TunedEntry {
                kernel: "gemm".into(),
                mode: Mode::Baseline,
                vlen: 128,
                fingerprint: 1,
                engine: "interp".into(),
                winner: "static".into(),
                candidates: vec![score("static", true, 500, 9000, "")],
            },
        ],
    }
}

#[test]
fn json_round_trip_is_exact() {
    let db = sample_db();
    let text = db.to_json();
    let back = TuningDb::from_json(&text).expect("own output must parse");
    assert_eq!(back, db);
    // and a second trip is a fixed point
    assert_eq!(back.to_json(), text);
}

#[test]
fn file_round_trip() {
    let db = sample_db();
    let path = std::env::temp_dir().join(format!("tuned-db-test-{}.json", std::process::id()));
    db.save(&path).expect("save");
    let back = TuningDb::load(&path).expect("load");
    let _ = std::fs::remove_file(&path);
    assert_eq!(back, db);
}

#[test]
fn stale_version_is_rejected() {
    let text = sample_db()
        .to_json()
        .replacen(&format!("\"version\": {VERSION}"), "\"version\": 0", 1);
    let err = TuningDb::from_json(&text).expect_err("stale version must not parse");
    let msg = format!("{err:#}");
    assert!(msg.contains("version 0"), "error must name the bad version: {msg}");
    assert!(msg.contains("tune"), "error should point at re-tuning: {msg}");
}

#[test]
fn garbage_and_missing_fields_are_errors() {
    assert!(TuningDb::from_json("").is_err());
    assert!(TuningDb::from_json("not json").is_err());
    assert!(TuningDb::from_json("{\"entries\": []}").is_err(), "missing version");
    // entry without a kernel name
    let text = format!(
        "{{\"version\": {VERSION}, \"entries\": [{{\"mode\": \"baseline\", \"vlen\": 128}}]}}"
    );
    assert!(TuningDb::from_json(&text).is_err());
}

#[test]
fn winner_lookup_requires_exact_point_and_fingerprint() {
    let db = sample_db();
    let fp = 0xfedc_ba98_7654_3210u64;
    assert_eq!(db.winner("vrelu", Mode::RvvCustom, 512, fp), Some(Candidate::Widen(4)));
    assert_eq!(db.winner("gemm", Mode::Baseline, 128, 1), Some(Candidate::Static));
    // stale shape fingerprint: refuse, fall back to static rules
    assert_eq!(db.winner("vrelu", Mode::RvvCustom, 512, fp ^ 1), None);
    // wrong vlen / mode / kernel
    assert_eq!(db.winner("vrelu", Mode::RvvCustom, 256, fp), None);
    assert_eq!(db.winner("vrelu", Mode::Baseline, 512, fp), None);
    assert_eq!(db.winner("vsqrt", Mode::RvvCustom, 512, fp), None);
}

#[test]
fn fingerprints_are_stable_across_shape_but_not_content() {
    // two fresh instantiations of the same kernel must agree (the db is
    // only useful if fingerprints are deterministic), and different
    // kernels must not collide
    let mut by_kernel: HashMap<&str, u64> = HashMap::new();
    for name in kernels::NAMES {
        let a = kernels::by_name(name).expect("kernel exists").prog.fingerprint();
        let b = kernels::by_name(name).expect("kernel exists").prog.fingerprint();
        assert_eq!(a, b, "{name}: fingerprint not deterministic");
        for (other, fp) in &by_kernel {
            assert_ne!(a, *fp, "{name} collides with {other}");
        }
        by_kernel.insert(name, a);
    }
}

#[test]
fn candidate_ids_round_trip_through_parse() {
    for id in ["static", "widen:2", "widen:4", "widen:8", "force-baseline:memory",
        "force-baseline:float-est", "force-baseline:widen-narrow"]
    {
        let cand = Candidate::parse(id).unwrap_or_else(|| panic!("'{id}' must parse"));
        assert_eq!(cand.id(), id);
    }
    assert_eq!(Candidate::parse("widen:0"), None);
    assert_eq!(Candidate::parse("bogus"), None);
}
