//! Property test over the *entire conversion surface*: for every concrete
//! (family, elem, width) instantiation the registry covers, build a
//! one-intrinsic program with random inputs and check that both
//! translation modes reproduce the NEON reference semantics on the RVV
//! simulator — the per-intrinsic unit-test methodology of the paper's
//! §4.1 ("unit tests validate each instruction using multiple test
//! cases"), driven generatively instead of hand-written.

use simde_rvv::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use simde_rvv::neon::elem::Elem;
use simde_rvv::neon::interp::{Buffer, Inputs, NeonInterp};
use simde_rvv::neon::ops::{enumerate_implemented, ArgTy, Family, NeonOp};
use simde_rvv::neon::vreg::VecTy;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::types_map::map_neon_type;
use simde_rvv::simde::{Mode, Translator};
use simde_rvv::testutil::Rng;

/// A valid immediate for an op's Imm slot.
fn pick_imm(op: NeonOp, rng: &mut Rng) -> i64 {
    let bits = op.elem.bits() as i64;
    match op.family {
        Family::ShlN => rng.below(bits as u64 - 1) as i64, // 0..bits-1
        Family::ShrN => 1 + rng.below(bits as u64 - 1) as i64, // 1..bits-1
        Family::SliN | Family::SriN => 1 + rng.below(bits as u64 - 2) as i64,
        Family::ShrnN => {
            let nb = op.elem.narrowed().map(|e| e.bits()).unwrap_or(8) as u64;
            1 + rng.below(nb - 1) as i64
        }
        Family::Ext => rng.below(op.vt().lanes as u64) as i64,
        Family::DupLane | Family::MulLane | Family::MlaLane | Family::FmaLane => {
            // the lane source is a 64-bit (d) register
            let dl = 64 / op.elem.bits() as u64;
            rng.below(dl) as i64
        }
        Family::Ld1Lane | Family::St1Lane => rng.below(op.vt().lanes as u64) as i64,
        _ => rng.below(4) as i64,
    }
}

/// Random input buffer for a vector argument. Floats stay in a moderate
/// range (both semantic models canonicalise NaN identically, but exact
/// f16 rounding of extreme randoms is noise we don't need).
fn buffer_for(vt: VecTy, rng: &mut Rng) -> Buffer {
    if vt.elem.is_float() {
        let vals: Vec<f32> = (0..vt.lanes as usize).map(|_| rng.f32_in(-8.0, 8.0)).collect();
        match vt.elem {
            Elem::F32 => Buffer::from_f32s(&vals),
            _ => {
                // f16/f64 buffers: store raw lane patterns via conversions
                let mut b = Buffer::zeros(vt.elem, vt.lanes as usize);
                for (i, v) in vals.iter().enumerate() {
                    let raw = simde_rvv::neon::elem::from_f64(vt.elem, *v as f64);
                    b.write_elem(i, raw);
                }
                b
            }
        }
    } else {
        let mut b = Buffer::zeros(vt.elem, vt.lanes as usize);
        for i in 0..vt.lanes as usize {
            b.write_elem(i, rng.next_u64() & vt.elem.lane_mask());
        }
        b
    }
}

/// Build a one-op program plus inputs: load every vector arg, apply the
/// op, store the result.
fn synth(op: NeonOp, rng: &mut Rng) -> Option<(Program, Inputs)> {
    let sig = op.sig();
    let mut b = ProgramBuilder::new("conform");
    let mut inputs = Inputs::new();
    let mut args: Vec<Arg> = Vec::new();
    let mut vi = 0;

    // memory families handle their ptr arg specially
    for at in &sig.args {
        match at {
            ArgTy::V(vt) => {
                let name = format!("IN{vi}");
                let buf = b.input(&name, vt.elem, vt.lanes as usize);
                inputs.insert(name, buffer_for(*vt, rng));
                let r = b.vop(Family::Ld1, vt.elem, vt.is_q(), vec![Arg::mem(buf, AddrExpr::k(0))]);
                args.push(Arg::V(r));
                vi += 1;
            }
            ArgTy::Ptr(e) => {
                let name = format!("PTR{vi}");
                let lanes = (op.vt().bits() / e.bits()).max(1) as usize;
                let buf = b.input(&name, *e, lanes);
                inputs.insert(name, buffer_for(VecTy::of_bits(*e, op.vt().bits()), rng));
                args.push(Arg::mem(buf, AddrExpr::k(0)));
                vi += 1;
            }
            ArgTy::Imm => args.push(Arg::Imm(pick_imm(op, rng))),
            ArgTy::ScalarInt => {
                if op.elem.is_float() {
                    args.push(Arg::ImmF(rng.f32_in(-8.0, 8.0) as f64));
                } else {
                    args.push(Arg::Imm(rng.next_u64() as i64 & 0xff));
                }
            }
        }
    }

    match sig.ret {
        Some(rt) => {
            let out = b.output("OUT", rt.elem, rt.lanes as usize);
            let r = b.fresh_vreg();
            b.vop_into(r, op.family, op.elem, op.q, args);
            b.vstore(Family::St1, rt.elem, rt.is_q(), vec![Arg::mem(out, AddrExpr::k(0)), Arg::V(r)]);
        }
        None => {
            // stores: args[0] is the destination pointer; redirect it to an
            // output buffer
            let rt = op.vt();
            let out = b.output("OUT", rt.elem, rt.lanes as usize);
            let mut args = args;
            args[0] = Arg::mem(out, AddrExpr::k(0));
            // the stored vector comes from an input we already declared
            b.vstore(op.family, op.elem, op.q, args);
        }
    }
    Some((b.finish(), inputs))
}

/// Families whose float lowering legitimately differs in rounding —
/// fused vfmacc vs NEON's unfused vmla (and vice versa in baseline), and
/// two-op Newton steps vs NEON's single-rounding fused vrecps/vrsqrts.
/// Compared with a relative tolerance (abs floor 1.0).
fn float_tolerance(op: NeonOp, mode: Mode) -> f64 {
    if !op.elem.is_float() {
        return 0.0;
    }
    match op.family {
        Family::Mla | Family::Mls | Family::MlaLane => 1e-3,
        Family::Fma | Family::Fms | Family::FmaLane if mode == Mode::Baseline => 1e-3,
        Family::Recps | Family::Rsqrts => 1e-3,
        // the custom int-roundtrip rndn maps -0.0 to +0.0 (value-equal)
        Family::Rndn => 1e-9,
        _ => 0.0,
    }
}

/// f16 has too few mantissa bits for a meaningful fused-vs-unfused
/// tolerance under cancellation; those instantiations are covered by the
/// f32/f64 grid.
fn skip_fused_f16(op: NeonOp) -> bool {
    op.elem == Elem::F16
        && matches!(
            op.family,
            Family::Mla | Family::Mls | Family::MlaLane | Family::Fma | Family::Fms
                | Family::FmaLane | Family::Recps | Family::Rsqrts
        )
}

/// Lane values as f64 for tolerant float comparison.
fn lanes_f64(buf: &Buffer) -> Vec<f64> {
    (0..buf.len_elems())
        .map(|i| simde_rvv::neon::elem::to_f64(buf.elem, buf.read_elem(i)))
        .collect()
}

#[test]
fn every_conversion_matches_reference_semantics() {
    let cfg = RvvConfig::new(128);
    let mut rng = Rng::new(0xc0ffee);
    let mut tested = 0usize;
    let mut skipped = 0usize;

    for op in enumerate_implemented() {
        // the simulator needs mappable types (§3.2) for both modes' layouts
        let rt = op.sig().ret.unwrap_or_else(|| op.vt());
        if map_neon_type(rt, cfg.vlen, cfg.zvfh).is_err()
            || map_neon_type(op.vt(), cfg.vlen, cfg.zvfh).is_err()
        {
            skipped += 1;
            continue;
        }
        if skip_fused_f16(op) {
            skipped += 1;
            continue;
        }
        for trial in 0..2 {
            let Some((prog, inputs)) = synth(op, &mut rng) else {
                skipped += 1;
                continue;
            };
            // constrain Sshl shift operands to in-range values
            if op.family == Family::Sshl {
                continue; // separate targeted test below
            }
            let golden = match NeonInterp::new(&prog, &inputs).unwrap().run() {
                Ok(g) => g,
                Err(e) => panic!("{} golden failed: {e:#}", op.name()),
            };
            for mode in [Mode::RvvCustom, Mode::Baseline] {
                let (rp, _) = Translator::new(mode, cfg)
                    .translate(&prog)
                    .unwrap_or_else(|e| panic!("{} translate {mode:?}: {e:#}", op.name()));
                let (out, _) = Simulator::new(&rp, cfg, &inputs)
                    .unwrap()
                    .run()
                    .unwrap_or_else(|e| panic!("{} sim {mode:?}: {e:#}", op.name()));
                let (g, o) = (&golden["OUT"], &out["OUT"]);
                let tol = float_tolerance(op, mode);
                if tol > 0.0 && g.elem.is_float() {
                    let (gv, ov) = (lanes_f64(g), lanes_f64(o));
                    for (i, (x, y)) in gv.iter().zip(&ov).enumerate() {
                        let d = (x - y).abs() / x.abs().max(y.abs()).max(1.0);
                        assert!(
                            d <= tol,
                            "{} {mode:?} trial {trial} lane {i}: {x} vs {y} (rel {d})",
                            op.name()
                        );
                    }
                } else {
                    assert_eq!(
                        g.data,
                        o.data,
                        "{} {mode:?} trial {trial}: bit mismatch\n golden {:?}\n got    {:?}",
                        op.name(),
                        g.data,
                        o.data
                    );
                }
            }
            tested += 1;
        }
    }
    println!("conformance: {tested} op-trials checked, {skipped} skipped (unmappable types)");
    assert!(tested > 1000, "only {tested} trials ran");
}

#[test]
fn sshl_in_range_conforms() {
    // targeted: vshlq with shift amounts in [-(bits-1), bits-1]
    let cfg = RvvConfig::new(128);
    for e in [Elem::I8, Elem::I32, Elem::U16, Elem::U32] {
        let op = NeonOp::new(Family::Sshl, e, true);
        let vt = op.vt();
        let mut b = ProgramBuilder::new("sshl");
        let a_buf = b.input("A", e, vt.lanes as usize);
        let s_buf = b.input("S", e, vt.lanes as usize);
        let o_buf = b.output("OUT", e, vt.lanes as usize);
        let a = b.vop(Family::Ld1, e, true, vec![Arg::mem(a_buf, AddrExpr::k(0))]);
        let s = b.vop(Family::Ld1, e, true, vec![Arg::mem(s_buf, AddrExpr::k(0))]);
        let r = b.vop(Family::Sshl, e, true, vec![Arg::V(a), Arg::V(s)]);
        b.vstore(Family::St1, e, true, vec![Arg::mem(o_buf, AddrExpr::k(0)), Arg::V(r)]);
        let prog = b.finish();

        let mut rng = Rng::new(7 + e.bits() as u64);
        let mut inputs = Inputs::new();
        let mut a_in = Buffer::zeros(e, vt.lanes as usize);
        let mut s_in = Buffer::zeros(e, vt.lanes as usize);
        let bits = e.bits() as i64;
        for i in 0..vt.lanes as usize {
            a_in.write_elem(i, rng.next_u64() & e.lane_mask());
            let sh = (rng.below((2 * bits - 1) as u64) as i64) - (bits - 1);
            s_in.write_elem(i, simde_rvv::neon::elem::from_i64(e, sh));
        }
        inputs.insert("A".into(), a_in);
        inputs.insert("S".into(), s_in);

        let golden = NeonInterp::new(&prog, &inputs).unwrap().run().unwrap();
        for mode in [Mode::RvvCustom, Mode::Baseline] {
            let (rp, _) = Translator::new(mode, cfg).translate(&prog).unwrap();
            let (out, _) = Simulator::new(&rp, cfg, &inputs).unwrap().run().unwrap();
            assert_eq!(out["OUT"].data, golden["OUT"].data, "sshl {e:?} {mode:?}");
        }
    }
}
