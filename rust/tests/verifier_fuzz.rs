//! Seeded randomized differential fuzz for the admission verifier.
//!
//! Two properties, over a deterministic corpus (fixed xorshift64 seed,
//! no wall-clock or OS entropy):
//!
//! 1. **Accept ⇒ no trap**: every generated-valid program must be
//!    admitted by `rvv::verify` and then run trap-free on BOTH engines,
//!    with bit-identical output buffers and exactly equal `SimStats`.
//! 2. **Reject ⇒ matching trap**: every corrupted program must be
//!    rejected statically with the expected `VerifyErrorKind`, and when
//!    forced through execution anyway must raise the `TrapKind` the
//!    rejection predicts — the verifier is exactly as strict as the
//!    machine, never a different kind of strict.

use std::collections::HashMap;

use simde_rvv::ir::AddrExpr;
use simde_rvv::ir::{BufDecl, BufKind};
use simde_rvv::neon::elem::Elem;
use simde_rvv::neon::interp::{Buffer, Inputs};
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::rvv::ops::{Dst, MemRef, RvvInst, RvvKind, Src};
use simde_rvv::rvv::program::{RStmt, RvvProgram};
use simde_rvv::rvv::verify::{verify, VerifyErrorKind};
use simde_rvv::rvv::vtype::{Lmul, Sew};
use simde_rvv::sim::{decode, Engine, SimStats, SimTrap, Simulator, TrapKind};

const VLEN: u32 = 128;

/// xorshift64: tiny, deterministic, no external entropy.
struct Rng(u64);

impl Rng {
    fn next(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.0 = x;
        x
    }

    fn pick(&mut self, n: u64) -> u64 {
        self.next() % n
    }
}

/// One generated case: a valid program plus matching inputs.
struct Case {
    prog: RvvProgram,
    inputs: Inputs,
}

fn op(kind: RvvKind, dst: u32, a: u32, b: u32) -> RStmt {
    RStmt::Op(RvvInst {
        kind,
        sew: Sew::E32,
        lmul: Lmul::M1,
        vl: 4,
        dst: Dst::V(dst),
        srcs: vec![Src::V(a), Src::V(b)],
        mask: None,
        mem: None,
    })
}

fn mem_op(kind: RvvKind, dst: Dst, srcs: Vec<Src>, buf: u32) -> RStmt {
    RStmt::Op(RvvInst {
        kind,
        sew: Sew::E32,
        lmul: Lmul::M1,
        vl: 4,
        dst,
        srcs,
        mask: None,
        mem: Some(MemRef { buf, index: AddrExpr::s(0), stride: 1 }),
    })
}

/// A valid looped program: load A and B, a random chain of element-wise
/// i32 ops, store to O; addresses stay in-bounds by construction.
fn gen_case(rng: &mut Rng) -> Case {
    let len = [16usize, 32, 64][rng.pick(3) as usize];
    let arith = [RvvKind::Vadd, RvvKind::Vsub, RvvKind::Vmul, RvvKind::Vand, RvvKind::Vor, RvvKind::Vxor];
    let mut body = vec![mem_op(RvvKind::Vle, Dst::V(0), vec![], 0), mem_op(RvvKind::Vle, Dst::V(1), vec![], 1)];
    // 1..=3 chained ops, each reading the previous result
    let chain = 1 + rng.pick(3) as u32;
    for i in 0..chain {
        let kind = arith[rng.pick(arith.len() as u64) as usize];
        let prev = if i == 0 { 1 } else { 1 + i };
        body.push(op(kind, 2 + i, 0, prev));
    }
    body.push(mem_op(RvvKind::Vse, Dst::None, vec![Src::V(1 + chain)], 2));
    let prog = RvvProgram {
        name: format!("fuzz_{len}_{chain}"),
        bufs: vec![
            BufDecl { name: "A".into(), elem: Elem::I32, len, kind: BufKind::Input },
            BufDecl { name: "B".into(), elem: Elem::I32, len, kind: BufKind::Input },
            BufDecl { name: "O".into(), elem: Elem::I32, len, kind: BufKind::Output },
        ],
        body: vec![RStmt::Loop { ivar: 0, start: 0, end: len as i64, step: 4, body }],
        n_vregs: (2 + chain) as usize,
        n_mregs: 0,
        n_sregs: 1,
    };
    let mut inputs = Inputs::new();
    let vals = |rng: &mut Rng| (0..len).map(|_| rng.next() as i32).collect::<Vec<_>>();
    inputs.insert("A".into(), Buffer::from_i32s(&vals(rng)));
    inputs.insert("B".into(), Buffer::from_i32s(&vals(rng)));
    Case { prog, inputs }
}

fn run_interp(case: &Case) -> anyhow::Result<(HashMap<String, Buffer>, SimStats)> {
    Simulator::new(&case.prog, RvvConfig::new(VLEN), &case.inputs)?.run()
}

fn run_decoded(case: &Case) -> anyhow::Result<(HashMap<String, Buffer>, SimStats)> {
    let dec = decode(&case.prog);
    Engine::new(&case.prog, &dec, RvvConfig::new(VLEN), &case.inputs)?.run()
}

#[test]
fn accepted_programs_run_trap_free_and_bit_identical() {
    let mut rng = Rng(0x9e3779b97f4a7c15);
    for i in 0..64 {
        let case = gen_case(&mut rng);
        verify(&case.prog, VLEN)
            .unwrap_or_else(|e| panic!("case {i} ({}) rejected: {e}", case.prog.name));
        let (out_i, stats_i) = run_interp(&case)
            .unwrap_or_else(|e| panic!("case {i}: interp trapped on admitted program: {e:#}"));
        let (out_d, stats_d) = run_decoded(&case)
            .unwrap_or_else(|e| panic!("case {i}: decoded trapped on admitted program: {e:#}"));
        assert_eq!(stats_i, stats_d, "case {i}: stats diverge");
        assert_eq!(out_i.len(), out_d.len());
        for (name, buf) in &out_i {
            let other = &out_d[name];
            assert_eq!(buf.data, other.data, "case {i}: buffer '{name}' diverges bit-wise");
        }
    }
}

/// Force a rejected program through both engines and return the traps
/// (the whole point: the verifier's rejection must predict them).
fn forced_traps(case: &Case) -> Vec<SimTrap> {
    [run_interp(case), run_decoded(case)]
        .into_iter()
        .map(|r| {
            r.expect_err("rejected program must trap when forced through execution")
                .downcast::<SimTrap>()
                .expect("structured trap")
        })
        .collect()
}

fn assert_rejection(
    case: &Case,
    expected: VerifyErrorKind,
    trap_matches: impl Fn(&TrapKind) -> bool,
) {
    let err = verify(&case.prog, VLEN).expect_err("corrupted program must be rejected");
    assert_eq!(err.kind, expected, "{err}");
    for trap in forced_traps(case) {
        assert!(trap_matches(&trap.kind), "predicted {expected:?}, execution gave {:?}", trap.kind);
    }
}

#[test]
fn vl_corruption_rejects_and_traps_as_vsetvli() {
    let mut rng = Rng(0xdeadbeefcafef00d);
    for _ in 0..16 {
        let mut case = gen_case(&mut rng);
        // vl beyond VLMAX(e32, m1) on a random body op
        if let RStmt::Loop { body, .. } = &mut case.prog.body[0] {
            let i = rng.pick(body.len() as u64) as usize;
            if let RStmt::Op(inst) = &mut body[i] {
                inst.vl = 4 + 4 * (1 + rng.pick(8) as u32);
            }
        }
        assert_rejection(&case, VerifyErrorKind::VlExceedsVlmax, |k| {
            matches!(k, TrapKind::VsetvliViolation(_))
        });
    }
}

#[test]
fn misaligned_group_rejects_and_traps_as_bad_operand() {
    let mut rng = Rng(0x0123456789abcdef);
    for _ in 0..16 {
        let mut case = gen_case(&mut rng);
        case.prog.n_vregs += 8;
        // regroup the first arith op at m2 with an odd (misaligned) dst
        if let RStmt::Loop { body, .. } = &mut case.prog.body[0] {
            if let RStmt::Op(inst) = &mut body[2] {
                inst.lmul = Lmul::M2;
                inst.dst = Dst::V(3 + 2 * rng.pick(3) as u32);
                inst.srcs = vec![Src::V(0), Src::V(0)];
            }
        }
        assert_rejection(&case, VerifyErrorKind::MisalignedGroup, |k| {
            matches!(k, TrapKind::BadOperand(_))
        });
    }
}

#[test]
fn oob_address_rejects_and_traps_as_out_of_bounds() {
    let mut rng = Rng(0x5ca1ab1e0ddba11);
    for _ in 0..16 {
        let mut case = gen_case(&mut rng);
        let len = case.prog.bufs[2].len as i64;
        // push the store past the end of O for the final iterations
        if let RStmt::Loop { body, .. } = &mut case.prog.body[0] {
            let last = body.len() - 1;
            if let RStmt::Op(inst) = &mut body[last] {
                if let Some(mref) = &mut inst.mem {
                    mref.index = AddrExpr::s(0).addk(len + rng.pick(64) as i64);
                }
            }
        }
        assert_rejection(&case, VerifyErrorKind::OutOfBoundsAddress, |k| {
            matches!(k, TrapKind::OutOfBounds { store: true, .. })
        });
    }
}

#[test]
fn non_terminating_loop_rejects_and_fuel_traps() {
    let mut rng = Rng(0xfeedfacecafebeef);
    for _ in 0..8 {
        let mut case = gen_case(&mut rng);
        if let RStmt::Loop { step, .. } = &mut case.prog.body[0] {
            *step = -(rng.pick(2) as i64); // 0 or -1: the back-edge never advances
        }
        // static rejection names the shape; forced execution degrades to
        // fuel exhaustion (the default budget costs a diverging loop at
        // one trip) instead of hanging the thread, on both engines
        assert_rejection(&case, VerifyErrorKind::NonTerminatingLoop, |k| {
            matches!(k, TrapKind::FuelExhausted(_))
        });
    }
}
