//! Differential test: the pre-decoded lane-batched engine must be
//! observationally identical to the tree-walking interpreter —
//! bit-identical output buffers and exactly equal `SimStats` (the paper's
//! dynamic-instruction metric, vsetvli churn included) — across the
//! kernel suite × translation modes × vector lengths.

use simde_rvv::kernels;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::{decode, Engine, Simulator};
use simde_rvv::simde::{Mode, Translator};

#[test]
fn decoded_engine_matches_interpreter_bit_for_bit() {
    let mut combos = 0usize;
    for case in kernels::suite_small() {
        for mode in [Mode::Baseline, Mode::RvvCustom] {
            for vlen in [128u32, 256, 512] {
                let ctx = format!("{} mode={mode:?} vlen={vlen}", case.name);
                let cfg = RvvConfig::new(vlen);
                let (rp, _) = Translator::new(mode, cfg)
                    .translate(&case.prog)
                    .unwrap_or_else(|e| panic!("translate failed for {ctx}: {e:#}"));

                let (ref_out, ref_stats) = Simulator::new(&rp, cfg, &case.inputs)
                    .unwrap()
                    .run()
                    .unwrap_or_else(|e| panic!("interpreter failed for {ctx}: {e:#}"));

                let dec = decode(&rp);
                let (out, stats) = Engine::new(&rp, &dec, cfg, &case.inputs)
                    .unwrap()
                    .run()
                    .unwrap_or_else(|e| panic!("decoded engine failed for {ctx}: {e:#}"));

                assert_eq!(stats, ref_stats, "SimStats diverged for {ctx}");
                assert_eq!(out.len(), ref_out.len(), "output set diverged for {ctx}");
                for (name, ref_buf) in &ref_out {
                    let buf = out
                        .get(name)
                        .unwrap_or_else(|| panic!("missing output '{name}' for {ctx}"));
                    assert_eq!(buf.elem, ref_buf.elem, "elem type of '{name}' for {ctx}");
                    assert_eq!(
                        buf.data, ref_buf.data,
                        "output '{name}' not bit-identical for {ctx}"
                    );
                }
                combos += 1;
            }
        }
    }
    // 10 kernels x 2 modes x 3 vlens
    assert_eq!(combos, 60, "differential matrix lost coverage");
}

/// The suite kernels exercise the element-wise batched families; the
/// reduction family gets its own synthetic differential program so the
/// batched fold path in `exec_batched` is pinned engine-vs-interpreter
/// for every reduction kind, inside a loop (stats parity included).
#[test]
fn batched_reductions_match_interpreter_in_programs() {
    use std::collections::HashMap;

    use simde_rvv::ir::{AddrExpr, BufDecl, BufKind};
    use simde_rvv::neon::elem::Elem;
    use simde_rvv::neon::interp::Buffer;
    use simde_rvv::rvv::{Dst, MemRef, RStmt, RvvInst, RvvKind, RvvProgram, Sew, Src};

    let op = |kind: RvvKind, dst: Dst, srcs: Vec<Src>, mem: Option<MemRef>| {
        RStmt::Op(RvvInst { kind, sew: Sew::E32, vl: 4, dst, srcs, mask: None, mem })
    };
    let kinds = [
        (RvvKind::Vredsum, false),
        (RvvKind::Vredmax, false),
        (RvvKind::Vredmaxu, false),
        (RvvKind::Vredmin, false),
        (RvvKind::Vredminu, false),
        (RvvKind::Vfredusum, true),
        (RvvKind::Vfredmax, true),
        (RvvKind::Vfredmin, true),
    ];
    for (kind, float) in kinds {
        let elem = if float { Elem::F32 } else { Elem::I32 };
        let prog = RvvProgram {
            name: format!("red-{kind:?}"),
            bufs: vec![
                BufDecl { name: "x".into(), elem, len: 16, kind: BufKind::Input },
                BufDecl { name: "out".into(), elem, len: 4, kind: BufKind::Output },
            ],
            body: vec![
                op(
                    if float { RvvKind::VfmvVF } else { RvvKind::VmvVX },
                    Dst::V(1),
                    vec![if float { Src::ImmF(0.5) } else { Src::ImmI(5) }],
                    None,
                ),
                RStmt::Loop {
                    ivar: 0,
                    start: 0,
                    end: 16,
                    step: 4,
                    body: vec![
                        op(
                            RvvKind::Vle,
                            Dst::V(0),
                            vec![],
                            Some(MemRef { buf: 0, index: AddrExpr::s(0), stride: 1 }),
                        ),
                        op(kind, Dst::V(2), vec![Src::V(0), Src::V(1)], None),
                        // feed the partial back in as the next init
                        op(RvvKind::VmvVV, Dst::V(1), vec![Src::V(2)], None),
                    ],
                },
                op(
                    RvvKind::Vse,
                    Dst::None,
                    vec![Src::V(2)],
                    Some(MemRef { buf: 1, index: AddrExpr::k(0), stride: 1 }),
                ),
            ],
            n_vregs: 3,
            n_mregs: 1,
            n_sregs: 1,
        };
        let inputs: HashMap<String, Buffer> = [(
            "x".to_string(),
            if float {
                Buffer::from_f32s(&[
                    1.5, -2.25, 8.0, 0.125, 3.0, -7.5, 0.0, 2.5, -1.0, 4.75, 6.5, -0.5, 9.0,
                    -3.25, 1.0, 0.75,
                ])
            } else {
                Buffer::from_i32s(&[
                    -3, 7, -1, 2_147_418_113, 11, -9, 0, 5, 13, -2, 8, 1, -6, 4, 10, -12,
                ])
            },
        )]
        .into();
        let cfg = RvvConfig::new(128);
        let (ref_out, ref_stats) = Simulator::new(&prog, cfg, &inputs)
            .unwrap()
            .run()
            .unwrap_or_else(|e| panic!("interpreter failed for {kind:?}: {e:#}"));
        let dec = decode(&prog);
        let (out, stats) = Engine::new(&prog, &dec, cfg, &inputs)
            .unwrap()
            .run()
            .unwrap_or_else(|e| panic!("decoded engine failed for {kind:?}: {e:#}"));
        assert_eq!(stats, ref_stats, "SimStats diverged for {kind:?}");
        assert_eq!(
            out.get("out").unwrap().data,
            ref_out.get("out").unwrap().data,
            "reduction output not bit-identical for {kind:?}"
        );
    }
}

/// The cached `by_name` path (default shapes) must agree with a fresh
/// interpreter run too — this drives the coordinator's translation cache
/// end to end, across repeated hits.
#[test]
fn cached_jobs_match_interpreter_stats() {
    use simde_rvv::coordinator::{run_job_engine, EngineKind, Job};

    for kernel in ["vrelu", "gemm"] {
        for vlen in [128u32, 512] {
            let job = Job { kernel, mode: Mode::RvvCustom, vlen };
            let reference = run_job_engine(&job, EngineKind::Interp).unwrap();
            for round in 0..2 {
                let got = run_job_engine(&job, EngineKind::Decoded).unwrap();
                assert_eq!(
                    got.stats, reference.stats,
                    "{kernel} vlen={vlen} round={round} diverged from interpreter"
                );
            }
        }
    }
}
