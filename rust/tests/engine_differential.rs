//! Differential test: the pre-decoded lane-batched engine must be
//! observationally identical to the tree-walking interpreter —
//! bit-identical output buffers and exactly equal `SimStats` (the paper's
//! dynamic-instruction metric, vsetvli churn included) — across the
//! kernel suite × translation modes × vector lengths.

use simde_rvv::kernels;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::{decode, Engine, Simulator};
use simde_rvv::simde::{Mode, Translator};

#[test]
fn decoded_engine_matches_interpreter_bit_for_bit() {
    let mut combos = 0usize;
    for case in kernels::suite_small() {
        for mode in [Mode::Baseline, Mode::RvvCustom] {
            for vlen in [128u32, 256, 512] {
                let ctx = format!("{} mode={mode:?} vlen={vlen}", case.name);
                let cfg = RvvConfig::new(vlen);
                let (rp, _) = Translator::new(mode, cfg)
                    .translate(&case.prog)
                    .unwrap_or_else(|e| panic!("translate failed for {ctx}: {e:#}"));

                let (ref_out, ref_stats) = Simulator::new(&rp, cfg, &case.inputs)
                    .unwrap()
                    .run()
                    .unwrap_or_else(|e| panic!("interpreter failed for {ctx}: {e:#}"));

                let dec = decode(&rp);
                let (out, stats) = Engine::new(&rp, &dec, cfg, &case.inputs)
                    .unwrap()
                    .run()
                    .unwrap_or_else(|e| panic!("decoded engine failed for {ctx}: {e:#}"));

                assert_eq!(stats, ref_stats, "SimStats diverged for {ctx}");
                assert_eq!(out.len(), ref_out.len(), "output set diverged for {ctx}");
                for (name, ref_buf) in &ref_out {
                    let buf = out
                        .get(name)
                        .unwrap_or_else(|| panic!("missing output '{name}' for {ctx}"));
                    assert_eq!(buf.elem, ref_buf.elem, "elem type of '{name}' for {ctx}");
                    assert_eq!(
                        buf.data, ref_buf.data,
                        "output '{name}' not bit-identical for {ctx}"
                    );
                }
                combos += 1;
            }
        }
    }
    // 10 kernels x 2 modes x 3 vlens
    assert_eq!(combos, 60, "differential matrix lost coverage");
}

/// The cached `by_name` path (default shapes) must agree with a fresh
/// interpreter run too — this drives the coordinator's translation cache
/// end to end, across repeated hits.
#[test]
fn cached_jobs_match_interpreter_stats() {
    use simde_rvv::coordinator::{run_job_engine, EngineKind, Job};

    for kernel in ["vrelu", "gemm"] {
        for vlen in [128u32, 512] {
            let job = Job { kernel, mode: Mode::RvvCustom, vlen };
            let reference = run_job_engine(&job, EngineKind::Interp).unwrap();
            for round in 0..2 {
                let got = run_job_engine(&job, EngineKind::Decoded).unwrap();
                assert_eq!(
                    got.stats, reference.stats,
                    "{kernel} vlen={vlen} round={round} diverged from interpreter"
                );
            }
        }
    }
}
