//! Differential test: the pre-decoded lane-batched engine must be
//! observationally identical to the tree-walking interpreter —
//! bit-identical output buffers and exactly equal `SimStats` (the paper's
//! dynamic-instruction metric, vsetvli churn included) — across the
//! kernel suite × translation modes × vector lengths.

use simde_rvv::kernels;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::{decode, Engine, Simulator};
use simde_rvv::simde::{Mode, Translator};

#[test]
fn decoded_engine_matches_interpreter_bit_for_bit() {
    let mut combos = 0usize;
    for case in kernels::suite_small() {
        for mode in [Mode::Baseline, Mode::RvvCustom] {
            for vlen in [128u32, 256, 512] {
                let ctx = format!("{} mode={mode:?} vlen={vlen}", case.name);
                let cfg = RvvConfig::new(vlen);
                let (rp, _) = Translator::new(mode, cfg)
                    .translate(&case.prog)
                    .unwrap_or_else(|e| panic!("translate failed for {ctx}: {e:#}"));

                let (ref_out, ref_stats) = Simulator::new(&rp, cfg, &case.inputs)
                    .unwrap()
                    .run()
                    .unwrap_or_else(|e| panic!("interpreter failed for {ctx}: {e:#}"));

                let dec = decode(&rp);
                let (out, stats) = Engine::new(&rp, &dec, cfg, &case.inputs)
                    .unwrap()
                    .run()
                    .unwrap_or_else(|e| panic!("decoded engine failed for {ctx}: {e:#}"));

                assert_eq!(stats, ref_stats, "SimStats diverged for {ctx}");
                assert_eq!(out.len(), ref_out.len(), "output set diverged for {ctx}");
                for (name, ref_buf) in &ref_out {
                    let buf = out
                        .get(name)
                        .unwrap_or_else(|| panic!("missing output '{name}' for {ctx}"));
                    assert_eq!(buf.elem, ref_buf.elem, "elem type of '{name}' for {ctx}");
                    assert_eq!(
                        buf.data, ref_buf.data,
                        "output '{name}' not bit-identical for {ctx}"
                    );
                }
                combos += 1;
            }
        }
    }
    // 10 kernels x 2 modes x 3 vlens
    assert_eq!(combos, 60, "differential matrix lost coverage");
}

/// The suite kernels exercise the element-wise batched families; the
/// reduction family gets its own synthetic differential program so the
/// batched fold path in `exec_batched` is pinned engine-vs-interpreter
/// for every reduction kind, inside a loop (stats parity included).
#[test]
fn batched_reductions_match_interpreter_in_programs() {
    use std::collections::HashMap;

    use simde_rvv::ir::{AddrExpr, BufDecl, BufKind};
    use simde_rvv::neon::elem::Elem;
    use simde_rvv::neon::interp::Buffer;
    use simde_rvv::rvv::{Dst, Lmul, MemRef, RStmt, RvvInst, RvvKind, RvvProgram, Sew, Src};

    let op = |kind: RvvKind, dst: Dst, srcs: Vec<Src>, mem: Option<MemRef>| {
        RStmt::Op(RvvInst {
            kind,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 4,
            dst,
            srcs,
            mask: None,
            mem,
        })
    };
    let kinds = [
        (RvvKind::Vredsum, false),
        (RvvKind::Vredmax, false),
        (RvvKind::Vredmaxu, false),
        (RvvKind::Vredmin, false),
        (RvvKind::Vredminu, false),
        (RvvKind::Vfredusum, true),
        (RvvKind::Vfredmax, true),
        (RvvKind::Vfredmin, true),
    ];
    for (kind, float) in kinds {
        let elem = if float { Elem::F32 } else { Elem::I32 };
        let prog = RvvProgram {
            name: format!("red-{kind:?}"),
            bufs: vec![
                BufDecl { name: "x".into(), elem, len: 16, kind: BufKind::Input },
                BufDecl { name: "out".into(), elem, len: 4, kind: BufKind::Output },
            ],
            body: vec![
                op(
                    if float { RvvKind::VfmvVF } else { RvvKind::VmvVX },
                    Dst::V(1),
                    vec![if float { Src::ImmF(0.5) } else { Src::ImmI(5) }],
                    None,
                ),
                RStmt::Loop {
                    ivar: 0,
                    start: 0,
                    end: 16,
                    step: 4,
                    body: vec![
                        op(
                            RvvKind::Vle,
                            Dst::V(0),
                            vec![],
                            Some(MemRef { buf: 0, index: AddrExpr::s(0), stride: 1 }),
                        ),
                        op(kind, Dst::V(2), vec![Src::V(0), Src::V(1)], None),
                        // feed the partial back in as the next init
                        op(RvvKind::VmvVV, Dst::V(1), vec![Src::V(2)], None),
                    ],
                },
                op(
                    RvvKind::Vse,
                    Dst::None,
                    vec![Src::V(2)],
                    Some(MemRef { buf: 1, index: AddrExpr::k(0), stride: 1 }),
                ),
            ],
            n_vregs: 3,
            n_mregs: 1,
            n_sregs: 1,
        };
        let inputs: HashMap<String, Buffer> = [(
            "x".to_string(),
            if float {
                Buffer::from_f32s(&[
                    1.5, -2.25, 8.0, 0.125, 3.0, -7.5, 0.0, 2.5, -1.0, 4.75, 6.5, -0.5, 9.0,
                    -3.25, 1.0, 0.75,
                ])
            } else {
                Buffer::from_i32s(&[
                    -3, 7, -1, 2_147_418_113, 11, -9, 0, 5, 13, -2, 8, 1, -6, 4, 10, -12,
                ])
            },
        )]
        .into();
        let cfg = RvvConfig::new(128);
        let (ref_out, ref_stats) = Simulator::new(&prog, cfg, &inputs)
            .unwrap()
            .run()
            .unwrap_or_else(|e| panic!("interpreter failed for {kind:?}: {e:#}"));
        let dec = decode(&prog);
        let (out, stats) = Engine::new(&prog, &dec, cfg, &inputs)
            .unwrap()
            .run()
            .unwrap_or_else(|e| panic!("decoded engine failed for {kind:?}: {e:#}"));
        assert_eq!(stats, ref_stats, "SimStats diverged for {kind:?}");
        assert_eq!(
            out.get("out").unwrap().data,
            ref_out.get("out").unwrap().data,
            "reduction output not bit-identical for {kind:?}"
        );
    }
}

/// splat + axpy loop over 32 i32 elements at register grouping `F`:
/// every vector op carries `vl = 4·F` at `mF`, register ids are spread to
/// multiples of `F` (the alignment the tuner's remap guarantees), and the
/// trip count is divided by `F`. `F = 1` is the plain m1 reference.
fn grouped_axpy(factor: u32) -> simde_rvv::rvv::RvvProgram {
    use simde_rvv::ir::{AddrExpr, BufDecl, BufKind};
    use simde_rvv::neon::elem::Elem;
    use simde_rvv::rvv::{Dst, Lmul, MemRef, RStmt, RvvInst, RvvKind, RvvProgram, Sew, Src};

    let lmul = match factor {
        1 => Lmul::M1,
        2 => Lmul::M2,
        4 => Lmul::M4,
        _ => panic!("unsupported grouping {factor}"),
    };
    let vl = 4 * factor;
    let op = move |kind: RvvKind, dst: Dst, srcs: Vec<Src>, mem: Option<MemRef>| {
        RStmt::Op(RvvInst { kind, sew: Sew::E32, lmul, vl, dst, srcs, mask: None, mem })
    };
    RvvProgram {
        name: format!("axpy-m{factor}"),
        bufs: vec![
            BufDecl { name: "x".into(), elem: Elem::I32, len: 32, kind: BufKind::Input },
            BufDecl { name: "y".into(), elem: Elem::I32, len: 32, kind: BufKind::Output },
        ],
        body: vec![
            op(RvvKind::VmvVX, Dst::V(factor), vec![Src::ImmI(100)], None),
            RStmt::Loop {
                ivar: 0,
                start: 0,
                end: 32,
                step: i64::from(vl),
                body: vec![
                    op(
                        RvvKind::Vle,
                        Dst::V(0),
                        vec![],
                        Some(MemRef { buf: 0, index: AddrExpr::s(0), stride: 1 }),
                    ),
                    op(
                        RvvKind::Vadd,
                        Dst::V(2 * factor),
                        vec![Src::V(0), Src::V(factor)],
                        None,
                    ),
                    op(
                        RvvKind::Vse,
                        Dst::None,
                        vec![Src::V(2 * factor)],
                        Some(MemRef { buf: 1, index: AddrExpr::s(0), stride: 1 }),
                    ),
                ],
            },
        ],
        n_vregs: 3 * factor as usize,
        n_mregs: 1,
        n_sregs: 1,
    }
}

fn axpy_inputs() -> std::collections::HashMap<String, simde_rvv::neon::interp::Buffer> {
    let xs: Vec<i32> = (0..32).map(|i| i * 5 - 37).collect();
    [("x".to_string(), simde_rvv::neon::interp::Buffer::from_i32s(&xs))].into()
}

/// Register-grouped (m2/m4) programs must stay pinned three ways: the
/// decoded engine matches the interpreter exactly (stats included, so the
/// per-LMUL breakdown and the batched fast path are both checked), the
/// grouped output is bit-identical to the m1 reference, and grouping
/// strictly reduces the dynamic-instruction count.
#[test]
fn grouped_lmul_programs_match_interpreter_and_m1_bit_for_bit() {
    use simde_rvv::rvv::Lmul;

    let inputs = axpy_inputs();
    for vlen in [128u32, 256, 512] {
        let cfg = RvvConfig::new(vlen);
        let m1 = grouped_axpy(1);
        let (ref_out, ref_stats) = Simulator::new(&m1, cfg, &inputs)
            .unwrap()
            .run()
            .unwrap_or_else(|e| panic!("m1 reference failed at vlen {vlen}: {e:#}"));
        assert_eq!(ref_stats.by_lmul[Lmul::M2.index()], 0);
        assert_eq!(ref_stats.by_lmul[Lmul::M4.index()], 0);

        for (factor, lmul) in [(2u32, Lmul::M2), (4, Lmul::M4)] {
            let ctx = format!("m{factor} vlen={vlen}");
            let prog = grouped_axpy(factor);
            let (iout, istats) = Simulator::new(&prog, cfg, &inputs)
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("interpreter failed for {ctx}: {e:#}"));
            let dec = decode(&prog);
            let (dout, dstats) = Engine::new(&prog, &dec, cfg, &inputs)
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("decoded engine failed for {ctx}: {e:#}"));

            // engine parity, including the by_lmul breakdown
            assert_eq!(dstats, istats, "SimStats diverged for {ctx}");
            assert!(
                istats.by_lmul[lmul.index()] > 0,
                "grouped ops not counted under {lmul:?} for {ctx}: {istats:?}"
            );
            assert_eq!(
                dout.get("y").unwrap().data,
                iout.get("y").unwrap().data,
                "engines diverged for {ctx}"
            );
            // lmul-vs-m1 bit identity and the win that motivates grouping
            assert_eq!(
                iout.get("y").unwrap().data,
                ref_out.get("y").unwrap().data,
                "grouped output differs from m1 reference for {ctx}"
            );
            assert!(
                istats.total() < ref_stats.total(),
                "grouping did not reduce dyn insts for {ctx}: {} vs {}",
                istats.total(),
                ref_stats.total()
            );
        }
    }
}

/// A deliberately misaligned register-group base (`v1` as an m2 operand)
/// must trap as `BadOperand` on BOTH engines — never a panic, never a
/// silent wrong answer.
#[test]
fn misaligned_group_is_bad_operand_on_both_engines() {
    use simde_rvv::rvv::{Dst, RStmt, SimTrap, TrapKind};

    let mut prog = grouped_axpy(2);
    if let RStmt::Loop { body, .. } = &mut prog.body[1] {
        if let RStmt::Op(i) = &mut body[1] {
            i.dst = Dst::V(1); // odd base for an m2 group
        }
    }
    let inputs = axpy_inputs();
    let cfg = RvvConfig::new(128);

    let ierr = Simulator::new(&prog, cfg, &inputs).unwrap().run().unwrap_err();
    let itrap = ierr.downcast_ref::<SimTrap>().expect("interp trap must be structured");
    assert!(
        matches!(itrap.kind, TrapKind::BadOperand(_)),
        "expected BadOperand from interpreter: {itrap:?}"
    );
    assert_eq!(itrap.engine, Some("interp"));

    let dec = decode(&prog);
    let derr = Engine::new(&prog, &dec, cfg, &inputs).unwrap().run().unwrap_err();
    let dtrap = derr.downcast_ref::<SimTrap>().expect("decoded trap must be structured");
    assert!(
        matches!(dtrap.kind, TrapKind::BadOperand(_)),
        "expected BadOperand from decoded engine: {dtrap:?}"
    );
    assert_eq!(dtrap.engine, Some("decoded"));
}

/// The cached `by_name` path (default shapes) must agree with a fresh
/// interpreter run too — this drives the coordinator's translation cache
/// end to end, across repeated hits.
#[test]
fn cached_jobs_match_interpreter_stats() {
    use simde_rvv::coordinator::{run_job_engine, EngineKind, Job};

    for kernel in ["vrelu", "gemm"] {
        for vlen in [128u32, 512] {
            let job = Job { kernel, mode: Mode::RvvCustom, vlen };
            let reference = run_job_engine(&job, EngineKind::Interp).unwrap();
            for round in 0..2 {
                let got = run_job_engine(&job, EngineKind::Decoded).unwrap();
                assert_eq!(
                    got.stats, reference.stats,
                    "{kernel} vlen={vlen} round={round} diverged from interpreter"
                );
            }
        }
    }
}
