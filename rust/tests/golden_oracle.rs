//! Integration over the PJRT runtime: load the AOT artifacts and verify
//! the whole kernel suite against the JAX/XLA oracle (the three-layer
//! composition test). Skips with a notice when `make artifacts` has not
//! been run.

use std::path::Path;

use simde_rvv::coordinator::verify_kernel;
use simde_rvv::kernels;
use simde_rvv::runtime::GoldenOracle;

fn oracle() -> Option<GoldenOracle> {
    let dir = Path::new("artifacts");
    if !dir.join("manifest.txt").exists() {
        eprintln!("skipping golden-oracle tests: run `make artifacts` first");
        return None;
    }
    Some(GoldenOracle::load(dir).expect("loading artifacts"))
}

#[test]
fn oracle_covers_the_full_suite() {
    let Some(o) = oracle() else { return };
    let mut ops = o.ops();
    ops.sort();
    let mut want: Vec<&str> = kernels::NAMES.to_vec();
    want.sort();
    assert_eq!(ops, want);
    assert_eq!(o.platform(), "cpu");
}

#[test]
fn manifest_matches_kernel_buffers() {
    let Some(o) = oracle() else { return };
    for case in kernels::suite() {
        let entry = o.manifest(case.name).expect(case.name);
        let n_inputs = case
            .prog
            .bufs
            .iter()
            .filter(|b| b.kind == simde_rvv::ir::BufKind::Input)
            .count();
        let n_outputs = case
            .prog
            .bufs
            .iter()
            .filter(|b| b.kind == simde_rvv::ir::BufKind::Output)
            .count();
        assert_eq!(entry.inputs.len(), n_inputs, "{} inputs", case.name);
        assert_eq!(entry.outputs.len(), n_outputs, "{} outputs", case.name);
        // element counts line up with the rust buffers
        for ((_, dims), decl) in entry.inputs.iter().zip(
            case.prog.bufs.iter().filter(|b| b.kind == simde_rvv::ir::BufKind::Input),
        ) {
            let n: i64 = dims.iter().product();
            assert_eq!(n as usize, decl.len, "{} input {}", case.name, decl.name);
        }
    }
}

#[test]
fn full_suite_verifies_against_xla() {
    let Some(o) = oracle() else { return };
    for case in kernels::suite() {
        let outcome = verify_kernel(&case, 128, Some(&o)).expect(case.name);
        assert!(outcome.passed, "{} failed: {:?}", case.name, outcome);
        assert!(!outcome.vs_golden.is_empty(), "{} had no golden comparison", case.name);
    }
}
