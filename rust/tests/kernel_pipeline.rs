//! End-to-end pipeline integration: every XNNPACK kernel, interpreted
//! under NEON semantics (golden), translated under both SIMDe modes,
//! executed on the RVV simulator, outputs compared, and the Figure-2
//! speedup direction checked.

use simde_rvv::kernels;
use simde_rvv::neon::interp::NeonInterp;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::Simulator;
use simde_rvv::simde::{Mode, Translator};
use simde_rvv::testutil::max_abs_diff;

#[test]
fn all_kernels_both_modes_match_golden() {
    let cfg = RvvConfig::new(128);
    for case in kernels::suite_small() {
        let golden = NeonInterp::new(&case.prog, &case.inputs)
            .unwrap()
            .run()
            .unwrap_or_else(|e| panic!("{} golden: {e}", case.name));

        for mode in [Mode::RvvCustom, Mode::Baseline] {
            let tr = Translator::new(mode, cfg);
            let (rp, _) = tr
                .translate(&case.prog)
                .unwrap_or_else(|e| panic!("{} translate {mode:?}: {e}", case.name));
            let (out, _) = Simulator::new(&rp, cfg, &case.inputs)
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("{} sim {mode:?}: {e}", case.name));

            for (name, gold) in &golden {
                let got = &out[name];
                if gold.elem == simde_rvv::neon::elem::Elem::F32 {
                    let d = max_abs_diff(&got.as_f32s(), &gold.as_f32s());
                    assert!(
                        d <= case.sim_tol.max(1e-4),
                        "{} {mode:?} output {name}: diff {d} > {}",
                        case.name,
                        case.sim_tol
                    );
                } else {
                    assert_eq!(
                        got.data, gold.data,
                        "{} {mode:?} output {name}: integer mismatch",
                        case.name
                    );
                }
            }
        }
    }
}

#[test]
fn custom_mode_is_faster_on_every_kernel() {
    // Figure 2 direction: RVV-enhanced SIMDe beats baseline on all 10
    let cfg = RvvConfig::new(128);
    let mut lines = Vec::new();
    for case in kernels::suite_small() {
        let (rc, _) = Translator::new(Mode::RvvCustom, cfg).translate(&case.prog).unwrap();
        let (rb, _) = Translator::new(Mode::Baseline, cfg).translate(&case.prog).unwrap();
        let (_, sc) = Simulator::new(&rc, cfg, &case.inputs).unwrap().run().unwrap();
        let (_, sb) = Simulator::new(&rb, cfg, &case.inputs).unwrap().run().unwrap();
        let speedup = sb.total() as f64 / sc.total() as f64;
        lines.push(format!(
            "{:<12} baseline={:<9} custom={:<9} speedup={:.2}x",
            case.name,
            sb.total(),
            sc.total(),
            speedup
        ));
        assert!(
            speedup > 1.0,
            "{}: custom not faster ({} vs {})",
            case.name,
            sc.total(),
            sb.total()
        );
    }
    println!("{}", lines.join("\n"));
}
