//! Differential guarantees for the lowering autotuner:
//!
//! - running the search over the full Figure-2 suite × vlen {128, 256,
//!   512} never aborts — inapplicable or broken candidates score out;
//! - every tuned lowering replayed through the translator's tuning hook
//!   produces output buffers bit-identical to the static-rule lowering;
//! - at vlen 512 the search strictly improves the dynamic-instruction
//!   count for at least half the suite (the PR's acceptance bar);
//! - a candidate that traps at runtime degrades to a `FaultRecord`, not
//!   a search abort.

use std::collections::HashMap;
use std::sync::Arc;

use simde_rvv::coordinator::{self, CachedProgram, Job, RetryPolicy};
use simde_rvv::kernels;
use simde_rvv::neon::interp::Inputs;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::{decode, Engine};
use simde_rvv::simde::{Mode, Translator};
use simde_rvv::tuner::{self, TunerOptions};

#[test]
fn tuned_lowerings_are_bit_identical_and_improve_at_wide_vlen() {
    let opts = TunerOptions {
        vlens: vec![128, 256, 512],
        max_candidates: 4, // static + widen 2/4/8: the interesting axis
        ..TunerOptions::default()
    };
    let out = tuner::tune(&opts).expect("search must not abort");
    assert_eq!(out.db.entries.len(), kernels::NAMES.len() * 3, "one entry per point");

    for e in &out.db.entries {
        // provenance: every entry keeps the whole candidate set, every
        // scored-out candidate carries a reason
        assert!(!e.candidates.is_empty(), "no candidates recorded: {e:?}");
        assert_eq!(e.candidates[0].id, "static", "static must be scored first: {e:?}");
        for c in &e.candidates {
            assert!(c.ok || !c.error.is_empty(), "scored-out without a reason: {c:?}");
        }
        // the NEON shapes already fill a 128-bit machine: every widen
        // candidate must score out there and static must win
        if e.vlen == 128 {
            for c in e.candidates.iter().filter(|c| c.id.starts_with("widen:")) {
                assert!(!c.ok, "{}: widen cannot apply at vlen 128: {c:?}", e.kernel);
            }
            assert_eq!(e.winner, "static", "{}: unexpected winner at vlen 128", e.kernel);
        }
    }

    // acceptance bar: at vlen 512, at least half the kernels strictly
    // beat the static RvvCustom lowering on dynamic instructions
    let improved_512 =
        out.db.entries.iter().filter(|e| e.vlen == 512 && e.improved()).count();
    assert!(
        improved_512 >= kernels::NAMES.len() / 2,
        "only {improved_512}/{} kernels improved at vlen 512",
        kernels::NAMES.len()
    );

    // end-to-end differential: replay through the tuning hook and compare
    // output buffers bit for bit against the static lowering
    let db = Arc::new(out.db);
    for case in kernels::suite() {
        for vlen in [128u32, 256, 512] {
            let ctx = format!("{} vlen={vlen}", case.name);
            let cfg = RvvConfig::new(vlen);
            let (st, _) = Translator::new(Mode::RvvCustom, cfg)
                .translate(&case.prog)
                .unwrap_or_else(|e| panic!("static translate failed for {ctx}: {e:#}"));
            let (tu, _) = Translator::new(Mode::RvvCustom, cfg)
                .with_tuning(Arc::clone(&db))
                .translate(&case.prog)
                .unwrap_or_else(|e| panic!("tuned translate failed for {ctx}: {e:#}"));

            let sdec = decode(&st);
            let (sout, sstats) = Engine::new(&st, &sdec, cfg, &case.inputs)
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("static run failed for {ctx}: {e:#}"));
            let tdec = decode(&tu);
            let (tout, tstats) = Engine::new(&tu, &tdec, cfg, &case.inputs)
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("tuned run failed for {ctx}: {e:#}"));

            assert_eq!(sout.len(), tout.len(), "output set diverged for {ctx}");
            for (name, sbuf) in &sout {
                let tbuf = tout
                    .get(name)
                    .unwrap_or_else(|| panic!("missing tuned output '{name}' for {ctx}"));
                assert_eq!(tbuf.elem, sbuf.elem, "elem of '{name}' for {ctx}");
                assert_eq!(
                    tbuf.data, sbuf.data,
                    "tuned output '{name}' not bit-identical for {ctx}"
                );
            }
            // the tuned lowering may only ever cost fewer or equal
            // dynamic instructions — never more
            assert!(
                tstats.total() <= sstats.total(),
                "tuned lowering regressed {ctx}: {} > {}",
                tstats.total(),
                sstats.total()
            );
        }
    }
}

/// A candidate whose program traps at runtime must come back as a
/// structured `FaultRecord` (the tuner records it and keeps searching),
/// not a panic or process abort.
#[test]
fn trapping_candidate_degrades_to_fault_record() {
    use simde_rvv::ir::{AddrExpr, BufDecl, BufKind};
    use simde_rvv::neon::elem::Elem;
    use simde_rvv::rvv::{Dst, MemRef, RStmt, RvvInst, RvvKind, RvvProgram, Sew, Src};

    let op = |kind, dst, srcs, mem| {
        RStmt::Op(RvvInst { kind, sew: Sew::E32, vl: 4, dst, srcs, mask: None, mem })
    };
    let prog = RvvProgram {
        name: "oob-candidate".into(),
        bufs: vec![BufDecl { name: "out".into(), elem: Elem::I32, len: 4, kind: BufKind::Output }],
        body: vec![
            op(RvvKind::VmvVX, Dst::V(0), vec![Src::ImmI(7)], None),
            // stores way past the end of the 4-element buffer
            op(
                RvvKind::Vse,
                Dst::None,
                vec![Src::V(0)],
                Some(MemRef { buf: 0, index: AddrExpr::k(100), stride: 1 }),
            ),
        ],
        n_vregs: 1,
        n_mregs: 1,
        n_sregs: 1,
    };
    let prepared = CachedProgram { decoded: decode(&prog), rvv: prog };
    let job = Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 };
    let inputs: Inputs = HashMap::new();
    let fault =
        coordinator::run_prepared_with_recovery(3, &job, &prepared, &inputs, RetryPolicy::none())
            .expect_err("oob store must fault");
    assert_eq!(fault.index, 3, "candidate index must be preserved");
    assert_eq!(fault.job.kernel, "vrelu");
    assert!(fault.trap.is_some(), "expected a structured trap: {fault:?}");
    assert!(
        fault.error.contains("out-of-bounds-store"),
        "unhelpful fault error: {}",
        fault.error
    );
}
