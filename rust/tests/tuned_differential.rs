//! Differential guarantees for the lowering autotuner:
//!
//! - running the search over the full Figure-2 suite × vlen {128, 256,
//!   512} never aborts — inapplicable or broken candidates score out;
//! - every tuned lowering replayed through the translator's tuning hook
//!   produces output buffers bit-identical to the static-rule lowering;
//! - at vlen 512 the search strictly improves the dynamic-instruction
//!   count for at least half the suite (the PR's acceptance bar);
//! - a candidate that traps at runtime degrades to a `FaultRecord`, not
//!   a search abort.

use std::collections::HashMap;
use std::sync::Arc;

use simde_rvv::coordinator::{self, CachedProgram, Job, RetryPolicy};
use simde_rvv::kernels;
use simde_rvv::neon::interp::Inputs;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::sim::{decode, Engine};
use simde_rvv::simde::{Mode, Translator};
use simde_rvv::tuner::{self, TunerOptions};

#[test]
fn tuned_lowerings_are_bit_identical_and_improve_at_wide_vlen() {
    let opts = TunerOptions {
        vlens: vec![128, 256, 512],
        max_candidates: 4, // static + widen 2/4/8: the interesting axis
        ..TunerOptions::default()
    };
    let out = tuner::tune(&opts).expect("search must not abort");
    assert_eq!(out.db.entries.len(), kernels::NAMES.len() * 3, "one entry per point");

    for e in &out.db.entries {
        // provenance: every entry keeps the whole candidate set, every
        // scored-out candidate carries a reason
        assert!(!e.candidates.is_empty(), "no candidates recorded: {e:?}");
        assert_eq!(e.candidates[0].id, "static", "static must be scored first: {e:?}");
        for c in &e.candidates {
            assert!(c.ok || !c.error.is_empty(), "scored-out without a reason: {c:?}");
        }
        // the NEON shapes already fill a 128-bit machine: every widen
        // candidate must score out there and static must win
        if e.vlen == 128 {
            for c in e.candidates.iter().filter(|c| c.id.starts_with("widen:")) {
                assert!(!c.ok, "{}: widen cannot apply at vlen 128: {c:?}", e.kernel);
            }
            assert_eq!(e.winner, "static", "{}: unexpected winner at vlen 128", e.kernel);
        }
    }

    // acceptance bar: at vlen 512, at least half the kernels strictly
    // beat the static RvvCustom lowering on dynamic instructions
    let improved_512 =
        out.db.entries.iter().filter(|e| e.vlen == 512 && e.improved()).count();
    assert!(
        improved_512 >= kernels::NAMES.len() / 2,
        "only {improved_512}/{} kernels improved at vlen 512",
        kernels::NAMES.len()
    );

    // end-to-end differential: replay through the tuning hook and compare
    // output buffers bit for bit against the static lowering
    let db = Arc::new(out.db);
    for case in kernels::suite() {
        for vlen in [128u32, 256, 512] {
            let ctx = format!("{} vlen={vlen}", case.name);
            let cfg = RvvConfig::new(vlen);
            let (st, _) = Translator::new(Mode::RvvCustom, cfg)
                .translate(&case.prog)
                .unwrap_or_else(|e| panic!("static translate failed for {ctx}: {e:#}"));
            let (tu, _) = Translator::new(Mode::RvvCustom, cfg)
                .with_tuning(Arc::clone(&db))
                .translate(&case.prog)
                .unwrap_or_else(|e| panic!("tuned translate failed for {ctx}: {e:#}"));

            let sdec = decode(&st);
            let (sout, sstats) = Engine::new(&st, &sdec, cfg, &case.inputs)
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("static run failed for {ctx}: {e:#}"));
            let tdec = decode(&tu);
            let (tout, tstats) = Engine::new(&tu, &tdec, cfg, &case.inputs)
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("tuned run failed for {ctx}: {e:#}"));

            assert_eq!(sout.len(), tout.len(), "output set diverged for {ctx}");
            for (name, sbuf) in &sout {
                let tbuf = tout
                    .get(name)
                    .unwrap_or_else(|| panic!("missing tuned output '{name}' for {ctx}"));
                assert_eq!(tbuf.elem, sbuf.elem, "elem of '{name}' for {ctx}");
                assert_eq!(
                    tbuf.data, sbuf.data,
                    "tuned output '{name}' not bit-identical for {ctx}"
                );
            }
            // the tuned lowering may only ever cost fewer or equal
            // dynamic instructions — never more
            assert!(
                tstats.total() <= sstats.total(),
                "tuned lowering regressed {ctx}: {} > {}",
                tstats.total(),
                sstats.total()
            );
        }
    }
}

/// Every `lmul:{2,4}` candidate, lowered directly, must either refuse
/// with a reason or produce output buffers bit-identical to the static
/// m1 lowering — across the whole kernel suite × vlen {128, 256, 512}.
/// Legal regroupings must also strictly reduce dynamic instructions and
/// show up in the per-LMUL stats breakdown.
#[test]
fn lmul_candidates_are_bit_identical_across_the_suite() {
    use simde_rvv::rvv::Lmul;
    use simde_rvv::tuner::candidate::{self, Candidate};

    let mut legal = 0usize;
    for case in kernels::suite() {
        for vlen in [128u32, 256, 512] {
            let cfg = RvvConfig::new(vlen);
            let ctx = format!("{} vlen={vlen}", case.name);
            let (st, _) = Translator::new(Mode::RvvCustom, cfg)
                .translate(&case.prog)
                .unwrap_or_else(|e| panic!("static translate failed for {ctx}: {e:#}"));
            let sdec = decode(&st);
            let (sout, sstats) = Engine::new(&st, &sdec, cfg, &case.inputs)
                .unwrap()
                .run()
                .unwrap_or_else(|e| panic!("static run failed for {ctx}: {e:#}"));

            for f in [2u32, 4] {
                let cand = Candidate::Lmul(f);
                let lowered = candidate::lower_with(&case.prog, Mode::RvvCustom, cfg, &cand);
                let (gp, _) = match lowered {
                    Ok(x) => x,
                    Err(e) => {
                        // refusal is fine — but it must carry a reason
                        assert!(
                            !format!("{e:#}").is_empty(),
                            "empty refusal for lmul:{f} on {ctx}"
                        );
                        continue;
                    }
                };
                legal += 1;
                let gdec = decode(&gp);
                let (gout, gstats) = Engine::new(&gp, &gdec, cfg, &case.inputs)
                    .unwrap()
                    .run()
                    .unwrap_or_else(|e| panic!("lmul:{f} run failed for {ctx}: {e:#}"));
                assert_eq!(gout.len(), sout.len(), "output set diverged for lmul:{f} {ctx}");
                for (name, sbuf) in &sout {
                    let gbuf = gout.get(name).unwrap_or_else(|| {
                        panic!("missing output '{name}' for lmul:{f} {ctx}")
                    });
                    assert_eq!(
                        gbuf.data, sbuf.data,
                        "lmul:{f} output '{name}' not bit-identical for {ctx}"
                    );
                }
                let lm = if f == 2 { Lmul::M2 } else { Lmul::M4 };
                assert!(
                    gstats.by_lmul[lm.index()] > 0,
                    "grouped ops missing from by_lmul for lmul:{f} {ctx}: {gstats:?}"
                );
                assert!(
                    gstats.total() < sstats.total(),
                    "lmul:{f} did not reduce dyn insts for {ctx}: {} vs {}",
                    gstats.total(),
                    sstats.total()
                );
            }
        }
    }
    // vrelu alone must account for 6 legal points (2 factors × 3 vlens):
    // its static lowering is a single elementwise loop, exactly the shape
    // the grouping analysis admits at any vlen
    assert!(legal >= 6, "only {legal} legal lmul points across the suite");
}

/// The search itself must enumerate the lmul family (budget permitting),
/// keep full provenance for it, and still never abort anywhere on the
/// suite × vlen grid.
#[test]
fn search_with_lmul_family_never_aborts_and_keeps_provenance() {
    let opts = TunerOptions {
        vlens: vec![128, 256, 512],
        max_candidates: 6, // static + widen 2/4/8 + lmul 2/4
        ..TunerOptions::default()
    };
    let out = tuner::tune(&opts).expect("search must not abort");
    assert_eq!(out.db.entries.len(), kernels::NAMES.len() * 3, "one entry per point");
    for e in &out.db.entries {
        let lmuls: Vec<_> =
            e.candidates.iter().filter(|c| c.id.starts_with("lmul:")).collect();
        assert_eq!(lmuls.len(), 2, "{}: lmul family not enumerated: {e:?}", e.kernel);
        for c in lmuls {
            assert!(
                c.ok || !c.error.is_empty(),
                "{}: lmul scored out without a reason: {c:?}",
                e.kernel
            );
        }
        // a grouped winner is only ever recorded with a strict improvement
        if e.winner.starts_with("lmul:") {
            assert!(e.improved(), "{}: lmul winner without improvement: {e:?}", e.kernel);
        }
    }
    // the narrow machine is where the family earns its keep: widen cannot
    // apply at vlen 128, grouping can — at least vrelu must regroup there
    let narrow = out
        .db
        .entries
        .iter()
        .find(|e| e.kernel == "vrelu" && e.vlen == 128)
        .expect("vrelu@128 entry");
    assert!(
        narrow.winner.starts_with("lmul:"),
        "vrelu@128 should pick a grouped winner, got {}",
        narrow.winner
    );

    // and the grouped winner must replay bit-identically through the
    // translator's tuning hook, same as any other tuned lowering
    let db = Arc::new(out.db);
    let case = kernels::by_name("vrelu").unwrap();
    let cfg = RvvConfig::new(128);
    let (st, _) = Translator::new(Mode::RvvCustom, cfg).translate(&case.prog).unwrap();
    let (tu, _) =
        Translator::new(Mode::RvvCustom, cfg).with_tuning(db).translate(&case.prog).unwrap();
    let sdec = decode(&st);
    let (sout, sstats) = Engine::new(&st, &sdec, cfg, &case.inputs).unwrap().run().unwrap();
    let tdec = decode(&tu);
    let (tout, tstats) = Engine::new(&tu, &tdec, cfg, &case.inputs).unwrap().run().unwrap();
    for (name, sbuf) in &sout {
        assert_eq!(
            tout.get(name).map(|b| &b.data),
            Some(&sbuf.data),
            "replayed grouped lowering diverged on '{name}'"
        );
    }
    assert!(
        tstats.total() < sstats.total(),
        "replayed grouped lowering lost its improvement: {} vs {}",
        tstats.total(),
        sstats.total()
    );
}

/// A candidate whose program traps at runtime must come back as a
/// structured `FaultRecord` (the tuner records it and keeps searching),
/// not a panic or process abort.
#[test]
fn trapping_candidate_degrades_to_fault_record() {
    use simde_rvv::ir::{AddrExpr, BufDecl, BufKind};
    use simde_rvv::neon::elem::Elem;
    use simde_rvv::rvv::{Dst, Lmul, MemRef, RStmt, RvvInst, RvvKind, RvvProgram, Sew, Src};

    let op = |kind, dst, srcs, mem| {
        RStmt::Op(RvvInst {
            kind,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 4,
            dst,
            srcs,
            mask: None,
            mem,
        })
    };
    let prog = RvvProgram {
        name: "oob-candidate".into(),
        bufs: vec![BufDecl { name: "out".into(), elem: Elem::I32, len: 4, kind: BufKind::Output }],
        body: vec![
            op(RvvKind::VmvVX, Dst::V(0), vec![Src::ImmI(7)], None),
            // stores way past the end of the 4-element buffer
            op(
                RvvKind::Vse,
                Dst::None,
                vec![Src::V(0)],
                Some(MemRef { buf: 0, index: AddrExpr::k(100), stride: 1 }),
            ),
        ],
        n_vregs: 1,
        n_mregs: 1,
        n_sregs: 1,
    };
    let prepared = CachedProgram { decoded: decode(&prog), rvv: prog };
    let job = Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 };
    let inputs: Inputs = HashMap::new();
    let fault =
        coordinator::run_prepared_with_recovery(3, &job, &prepared, &inputs, RetryPolicy::none())
            .expect_err("oob store must fault");
    assert_eq!(fault.index, 3, "candidate index must be preserved");
    assert_eq!(fault.job.kernel, "vrelu");
    assert!(fault.trap.is_some(), "expected a structured trap: {fault:?}");
    assert!(
        fault.error.contains("out-of-bounds-store"),
        "unhelpful fault error: {}",
        fault.error
    );
}

/// A misaligned register group inside a candidate program must degrade to
/// a structured `BadOperand` fault record through the same recovery
/// primitive the tuner uses — never a panic.
#[test]
fn misaligned_group_candidate_degrades_to_fault_record() {
    use simde_rvv::ir::{BufDecl, BufKind};
    use simde_rvv::neon::elem::Elem;
    use simde_rvv::rvv::{Dst, Lmul, RStmt, RvvInst, RvvKind, RvvProgram, Sew, Src, TrapKind};

    let op = |kind, dst, srcs| {
        RStmt::Op(RvvInst {
            kind,
            sew: Sew::E32,
            lmul: Lmul::M2,
            vl: 8,
            dst,
            srcs,
            mask: None,
            mem: None,
        })
    };
    let prog = RvvProgram {
        name: "misaligned-group".into(),
        bufs: vec![BufDecl { name: "out".into(), elem: Elem::I32, len: 8, kind: BufKind::Output }],
        body: vec![
            op(RvvKind::VmvVX, Dst::V(0), vec![Src::ImmI(7)]),
            // v1 is an odd base for an m2 group: BadOperand, not a panic
            op(RvvKind::Vadd, Dst::V(1), vec![Src::V(0), Src::V(0)]),
        ],
        n_vregs: 4,
        n_mregs: 1,
        n_sregs: 1,
    };
    let prepared = CachedProgram { decoded: decode(&prog), rvv: prog };
    let job = Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 };
    let inputs: Inputs = HashMap::new();
    let fault =
        coordinator::run_prepared_with_recovery(5, &job, &prepared, &inputs, RetryPolicy::none())
            .expect_err("misaligned group must fault");
    let trap = fault.trap.as_ref().expect("structured trap expected");
    assert!(
        matches!(trap.kind, TrapKind::BadOperand(_)),
        "expected BadOperand, got {trap:?}"
    );
    assert!(fault.error.contains("bad-operand"), "unhelpful fault error: {}", fault.error);
}
