//! Fault-tolerance integration tests for the coordinator: a faulting or
//! panicking job must never abort the matrix. Deterministic faults are
//! driven by `FaultPlan` (fail job N on attempt M, panic in job K) and
//! verified across both engines and thread counts {1, 4}.
//!
//! Injected panics unwind through the per-attempt `catch_unwind`
//! backstop, so the default panic hook may print backtraces while these
//! tests run — that output is cosmetic.

use simde_rvv::coordinator::{
    figure2_report_opts, run_matrix_report, EngineKind, FaultPlan, Job, MatrixOptions,
    RetryPolicy,
};
use simde_rvv::kernels;
use simde_rvv::sim::TrapKind;
use simde_rvv::simde::Mode;

/// A small all-healthy job list over cheap kernels.
fn jobs(n: usize) -> Vec<Job> {
    (0..n)
        .map(|i| Job {
            kernel: if i % 2 == 0 { "vrelu" } else { "vsqrt" },
            mode: Mode::RvvCustom,
            vlen: 128,
        })
        .collect()
}

#[test]
fn panic_is_contained_on_both_engines_and_thread_counts() {
    for engine in [EngineKind::Interp, EngineKind::Decoded] {
        for threads in [1, 4] {
            let opts = MatrixOptions::new(threads)
                .engine(engine)
                .retry(RetryPolicy::none())
                .fault_plan(FaultPlan::new().panic_on(1, 1));
            let report = run_matrix_report(jobs(4), opts);

            assert_eq!(report.results.len(), 4);
            assert!(report.results[1].is_none(), "panicked job has no result");
            for i in [0, 2, 3] {
                assert!(
                    report.results[i].is_some(),
                    "engine={engine:?} threads={threads}: healthy job {i} must survive"
                );
            }
            assert_eq!(report.faults.len(), 1);
            let f = &report.faults[0];
            assert_eq!(f.index, 1);
            assert_eq!(f.attempts, 1);
            let trap = f.trap.as_ref().expect("panic becomes a structured trap");
            assert!(
                matches!(trap.kind, TrapKind::Panic(_)),
                "engine={engine:?} threads={threads}: {:?}",
                trap.kind
            );
            assert_eq!(trap.kind.label(), "panic");
        }
    }
}

#[test]
fn transient_fault_recovers_on_retry() {
    // job 0 traps on attempt 1 only; attempt 2 succeeds
    let opts = MatrixOptions::new(2)
        .retry(RetryPolicy { max_attempts: 2, interp_fallback: false })
        .fault_plan(FaultPlan::new().fail(0, 1));
    let report = run_matrix_report(jobs(3), opts);

    assert!(report.ok(), "faults: {:?}", report.faults);
    let r0 = report.results[0].as_ref().expect("retried job succeeds");
    assert_eq!(r0.attempts, 2);
    assert_eq!(r0.engine, EngineKind::Decoded);
    assert_eq!(report.results[1].as_ref().map(|r| r.attempts), Some(1));
}

#[test]
fn decoded_trap_falls_back_to_interp() {
    // every decoded attempt of job 0 traps; the interp fallback succeeds
    let opts = MatrixOptions::new(1)
        .retry(RetryPolicy { max_attempts: 2, interp_fallback: true })
        .fault_plan(FaultPlan::new().fail_engine(0, EngineKind::Decoded));
    let report = run_matrix_report(jobs(2), opts);

    assert!(report.ok(), "faults: {:?}", report.faults);
    let r0 = report.results[0].as_ref().expect("fallback result");
    assert_eq!(r0.engine, EngineKind::Interp, "degraded to the interpreter");
    assert_eq!(r0.attempts, 3, "2 decoded attempts + 1 interp fallback");
    // the fallback result is still the real simulation
    let healthy = report.results[1].as_ref().unwrap();
    assert!(r0.stats.total() > 0 && healthy.stats.total() > 0);
}

#[test]
fn exhausted_retries_degrade_to_fault_record() {
    // job 2 traps on every attempt and engine; everything else is healthy
    let opts = MatrixOptions::new(4)
        .retry(RetryPolicy { max_attempts: 2, interp_fallback: true })
        .fault_plan(FaultPlan::new().fail_always(2));
    let report = run_matrix_report(jobs(6), opts);

    assert_eq!(report.faults.len(), 1);
    let f = &report.faults[0];
    assert_eq!(f.index, 2);
    assert_eq!(f.attempts, 3, "2 decoded + 1 interp fallback, all injected");
    assert_eq!(f.engine, EngineKind::Interp, "last attempt was the fallback");
    let trap = f.trap.as_ref().expect("structured trap");
    assert!(matches!(trap.kind, TrapKind::Injected(_)), "{:?}", trap.kind);
    assert!(f.error.contains("injected") || f.error.contains("fault plan"), "{}", f.error);
    for (i, r) in report.results.iter().enumerate() {
        assert_eq!(r.is_some(), i != 2, "only job 2 may lack a result");
    }
}

#[test]
fn figure2_report_degrades_per_kernel_on_both_engines() {
    // fail both halves of the first kernel's pair (jobs 0 and 1 are
    // baseline+custom of kernels::NAMES[0]); every other kernel's row
    // must still be produced
    let first = kernels::NAMES[0];
    for engine in [EngineKind::Interp, EngineKind::Decoded] {
        let opts = MatrixOptions::new(4)
            .engine(engine)
            .retry(RetryPolicy::none())
            .fault_plan(FaultPlan::new().fail_always(0).fail_always(1));
        let fig = figure2_report_opts(128, opts);

        assert_eq!(fig.vlen, 128);
        assert_eq!(fig.failed, vec![first], "engine={engine:?}");
        assert_eq!(
            fig.rows.len(),
            kernels::NAMES.len() - 1,
            "engine={engine:?}: all healthy kernels keep their rows"
        );
        assert!(fig.rows.iter().all(|r| r.kernel != first));
        assert!(fig.rows.iter().all(|r| r.speedup > 0.0));
        assert_eq!(fig.faults.len(), 2, "one fault per failed half");
        assert!(fig.faults.iter().all(|f| f.job.kernel == first));
    }
}

#[test]
fn deterministic_trap_skips_straight_to_fallback() {
    use simde_rvv::coordinator::{run_prepared_with_recovery, CachedProgram};
    use simde_rvv::neon::interp::Inputs;
    use simde_rvv::rvv::ops::{Dst, RvvInst, RvvKind, Src};
    use simde_rvv::rvv::program::{RStmt, RvvProgram};
    use simde_rvv::rvv::vtype::{Lmul, Sew};
    use simde_rvv::sim::decode;

    // vl=1000 > VLMAX(e32, m1) at vlen 128: a VsetvliViolation is
    // deterministic — re-running the same engine on the same program
    // cannot succeed, so the ladder must spend exactly one decoded
    // attempt before the interp fallback instead of burning all three
    let prog = RvvProgram {
        name: "corrupt_vl".into(),
        bufs: vec![],
        body: vec![RStmt::Op(RvvInst {
            kind: RvvKind::VmvVX,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 1000,
            dst: Dst::V(0),
            srcs: vec![Src::ImmI(1)],
            mask: None,
            mem: None,
        })],
        n_vregs: 1,
        n_mregs: 0,
        n_sregs: 0,
    };
    let decoded = decode(&prog);
    let prepared = CachedProgram { rvv: prog, decoded };
    let job = Job { kernel: "corrupt_vl", mode: Mode::RvvCustom, vlen: 128 };
    let f = run_prepared_with_recovery(
        0,
        &job,
        &prepared,
        &Inputs::new(),
        RetryPolicy { max_attempts: 3, interp_fallback: true },
    )
    .expect_err("corrupt program must fault");
    assert_eq!(
        f.attempts, 2,
        "1 decoded attempt + 1 interp fallback; deterministic same-engine repeats skipped"
    );
    assert_eq!(f.engine, EngineKind::Interp, "last attempt was the fallback");
    let trap = f.trap.as_ref().expect("structured trap");
    assert!(matches!(trap.kind, TrapKind::VsetvliViolation(_)), "{:?}", trap.kind);
    assert!(trap.kind.is_deterministic());
}

#[test]
fn strict_matrix_surfaces_fault_after_running_everything() {
    // the legacy strict wrapper: first fault in job order becomes the
    // error, but workers are joined and the fault is downcastable
    let opts_err = run_matrix_report(
        jobs(4),
        MatrixOptions::new(2)
            .retry(RetryPolicy::none())
            .fault_plan(FaultPlan::new().panic_on(3, 1).fail_always(1)),
    );
    assert_eq!(opts_err.faults.len(), 2);
    assert_eq!(opts_err.faults[0].index, 1, "faults sorted by job index");
    assert_eq!(opts_err.faults[1].index, 3);
    let err = opts_err.into_results().unwrap_err();
    let f = err
        .downcast_ref::<simde_rvv::coordinator::FaultRecord>()
        .expect("first fault record");
    assert_eq!(f.index, 1);
}
