//! Fuel-bounded execution: both engines must stop a program at its
//! `ExecLimits` — dynamic-instruction budget or wall-clock deadline —
//! with a structured trap instead of hanging, and the coordinator must
//! degrade a runaway program to a `FaultRecord` while healthy matrix
//! runs (threads {1, 4}) stay unaffected.

use std::time::Duration;

use simde_rvv::coordinator::{
    run_matrix_report, run_prepared_with_recovery, CachedProgram, Job, MatrixOptions,
    RetryPolicy,
};
use simde_rvv::neon::interp::Inputs;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::rvv::ops::{Dst, RvvInst, RvvKind, Src};
use simde_rvv::rvv::program::{RStmt, RvvProgram};
use simde_rvv::rvv::vtype::{Lmul, Sew};
use simde_rvv::sim::{decode, Engine, ExecLimits, SimTrap, Simulator, TrapKind};
use simde_rvv::simde::Mode;

/// A buffer-free loop: `end`/`step` control the trip count, the body is
/// one legal vector op so the fuel meter sees vector work too.
fn counting_loop(end: i64, step: i64) -> RvvProgram {
    RvvProgram {
        name: "counting_loop".into(),
        bufs: vec![],
        body: vec![RStmt::Loop {
            ivar: 0,
            start: 0,
            end,
            step,
            body: vec![RStmt::Op(RvvInst {
                kind: RvvKind::VmvVX,
                sew: Sew::E32,
                lmul: Lmul::M1,
                vl: 4,
                dst: Dst::V(0),
                srcs: vec![Src::ImmI(1)],
                mask: None,
                mem: None,
            })],
        }],
        n_vregs: 1,
        n_mregs: 0,
        n_sregs: 1,
    }
}

/// Run `prog` under `limits` on both engines, returning each trap.
fn run_both(prog: &RvvProgram, limits: ExecLimits) -> Vec<(&'static str, SimTrap)> {
    let cfg = RvvConfig::new(128);
    let inputs = Inputs::new();
    let mut traps = Vec::new();

    let err = Simulator::with_limits(prog, cfg, &inputs, limits)
        .unwrap()
        .run()
        .expect_err("interp must hit the limit");
    traps.push(("interp", err.downcast::<SimTrap>().expect("structured trap")));

    let dec = decode(prog);
    let err = Engine::with_limits(prog, &dec, cfg, &inputs, limits)
        .unwrap()
        .run()
        .expect_err("decoded must hit the limit");
    traps.push(("decoded", err.downcast::<SimTrap>().expect("structured trap")));
    traps
}

#[test]
fn explicit_fuel_budget_traps_on_both_engines() {
    // a long but finite loop against a tiny budget
    let prog = counting_loop(1_000_000, 1);
    let limits = ExecLimits { max_dyn_insts: 32, wall_deadline: None };
    for (engine, trap) in run_both(&prog, limits) {
        assert!(
            matches!(trap.kind, TrapKind::FuelExhausted(_)),
            "{engine}: {:?}",
            trap.kind
        );
        assert_eq!(trap.kind.label(), "fuel-exhausted");
        assert!(trap.kind.is_deterministic(), "same fuel, same program, same outcome");
        assert_eq!(trap.engine, Some(engine));
    }
}

#[test]
fn zero_deadline_traps_on_both_engines() {
    let prog = counting_loop(16, 4);
    let limits = ExecLimits::unbounded().with_deadline(Duration::ZERO);
    for (engine, trap) in run_both(&prog, limits) {
        assert!(
            matches!(trap.kind, TrapKind::DeadlineExceeded(_)),
            "{engine}: {:?}",
            trap.kind
        );
        assert_eq!(trap.kind.label(), "deadline-exceeded");
        // a deadline depends on the host's clock, not the program: the
        // retry ladder is allowed to try again
        assert!(!trap.kind.is_deterministic());
    }
}

#[test]
fn default_budget_stops_a_runaway_back_edge() {
    // step 0 never advances the induction variable: without fuel this
    // loop runs forever. The default budget costs a non-terminating
    // back-edge at one trip, so the runaway exhausts it almost at once.
    let prog = counting_loop(16, 0);
    let limits = ExecLimits::for_program(&prog);
    assert!(limits.max_dyn_insts < u64::MAX);
    for (engine, trap) in run_both(&prog, limits) {
        assert!(
            matches!(trap.kind, TrapKind::FuelExhausted(_)),
            "{engine}: {:?}",
            trap.kind
        );
    }
}

#[test]
fn runaway_degrades_to_fault_record_through_the_coordinator() {
    let prog = counting_loop(16, 0);
    let decoded = decode(&prog);
    let prepared = CachedProgram { rvv: prog, decoded };
    let job = Job { kernel: "counting_loop", mode: Mode::RvvCustom, vlen: 128 };

    // no retries: one decoded attempt, one fault record
    let f = run_prepared_with_recovery(0, &job, &prepared, &Inputs::new(), RetryPolicy::none())
        .expect_err("runaway must fault");
    assert_eq!(f.attempts, 1);
    let trap = f.trap.as_ref().expect("structured trap");
    assert!(matches!(trap.kind, TrapKind::FuelExhausted(_)), "{:?}", trap.kind);

    // with the full ladder: fuel exhaustion is deterministic, so the
    // repeats on the same engine are skipped — one decoded attempt plus
    // the interp fallback (which exhausts identically)
    let f = run_prepared_with_recovery(
        0,
        &job,
        &prepared,
        &Inputs::new(),
        RetryPolicy { max_attempts: 3, interp_fallback: true },
    )
    .expect_err("runaway must fault on every engine");
    assert_eq!(f.attempts, 2, "1 decoded + 1 interp; deterministic repeats skipped");
    let trap = f.trap.as_ref().expect("structured trap");
    assert!(matches!(trap.kind, TrapKind::FuelExhausted(_)), "{:?}", trap.kind);
}

#[test]
fn healthy_matrix_runs_stay_under_the_default_budget() {
    // the default (shape-derived) limits must never fire on real suite
    // kernels, across worker counts
    for threads in [1, 4] {
        let jobs: Vec<Job> = ["vrelu", "vsqrt"]
            .into_iter()
            .flat_map(|k| {
                [Mode::Baseline, Mode::RvvCustom]
                    .map(|mode| Job { kernel: k, mode, vlen: 128 })
            })
            .collect();
        let report = run_matrix_report(jobs, MatrixOptions::new(threads));
        assert!(report.ok(), "threads={threads}: {:?}", report.faults);
        assert!(report.results.iter().all(|r| r.is_some()));
        let health = report.health();
        assert_eq!(health.passed, 4);
        assert_eq!(health.faulted, 0);
        assert!(health.fuel_spent > 0);
    }
}
