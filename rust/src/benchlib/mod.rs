//! Minimal benchmark harness (criterion is unavailable offline): warmup +
//! timed iterations with mean/median/min reporting, used by the
//! `harness = false` bench targets. The [`json`] submodule emits
//! machine-readable result files (e.g. `BENCH_6.json`) without serde.

pub mod json;

use std::time::{Duration, Instant};

/// Result of one benchmark.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub median: Duration,
    pub min: Duration,
}

impl BenchResult {
    pub fn line(&self) -> String {
        format!(
            "{:<32} iters={:<4} mean={:>10.3?} median={:>10.3?} min={:>10.3?}",
            self.name, self.iters, self.mean, self.median, self.min
        )
    }
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench(name: &str, warmup: usize, iters: usize, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        times.push(t.elapsed());
    }
    times.sort();
    let mean = times.iter().sum::<Duration>() / iters.max(1) as u32;
    BenchResult {
        name: name.to_string(),
        iters,
        mean,
        median: times[times.len() / 2],
        min: times[0],
    }
}

/// Auto-scale iteration count so each bench takes ~`budget`.
pub fn bench_auto(name: &str, budget: Duration, mut f: impl FnMut()) -> BenchResult {
    // one calibration run
    let t = Instant::now();
    f();
    let once = t.elapsed().max(Duration::from_micros(1));
    let iters = (budget.as_nanos() / once.as_nanos()).clamp(3, 1000) as usize;
    bench(name, 1, iters, f)
}

/// Print a standard bench header.
pub fn header(title: &str) {
    println!("=== {title} ===");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_reports_sane_times() {
        let r = bench("spin", 1, 5, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
        assert!(r.min <= r.median && r.median <= r.mean * 5);
    }

    #[test]
    fn auto_scales() {
        let r = bench_auto("fast", Duration::from_millis(5), || {
            std::hint::black_box(1 + 1);
        });
        assert!(r.iters >= 3);
    }
}
