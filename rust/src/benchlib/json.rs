//! Tiny JSON emission layer (serde is unavailable offline): just enough
//! to write flat machine-readable benchmark records like `BENCH_6.json`.
//!
//! Values are built with the [`Obj`] builder and composed with [`array`];
//! everything is a `String`, no intermediate tree.

/// Escape a string for inclusion in a JSON string literal.
pub fn escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Builder for one JSON object, field order preserved.
#[derive(Debug, Default)]
pub struct Obj {
    fields: Vec<(String, String)>,
}

impl Obj {
    pub fn new() -> Obj {
        Obj::default()
    }

    /// Add a raw, already-serialised JSON value (object, array, literal).
    pub fn raw(mut self, key: &str, value: impl Into<String>) -> Obj {
        self.fields.push((key.to_string(), value.into()));
        self
    }

    pub fn str(self, key: &str, value: &str) -> Obj {
        let v = format!("\"{}\"", escape(value));
        self.raw(key, v)
    }

    pub fn u64(self, key: &str, value: u64) -> Obj {
        self.raw(key, value.to_string())
    }

    /// Finite floats serialise as numbers; NaN/inf (not representable in
    /// JSON) as `null`.
    pub fn f64(self, key: &str, value: f64) -> Obj {
        let v = if value.is_finite() { format!("{value}") } else { "null".to_string() };
        self.raw(key, v)
    }

    pub fn bool(self, key: &str, value: bool) -> Obj {
        self.raw(key, if value { "true" } else { "false" })
    }

    /// Serialise to a single-line JSON object.
    pub fn finish(self) -> String {
        let mut out = String::from("{");
        for (i, (k, v)) in self.fields.iter().enumerate() {
            if i > 0 {
                out.push_str(", ");
            }
            out.push_str(&format!("\"{}\": {}", escape(k), v));
        }
        out.push('}');
        out
    }
}

/// Serialise pre-rendered JSON values as an array, one element per line
/// (diff-friendly for committed artifacts).
pub fn array<I>(items: I) -> String
where
    I: IntoIterator,
    I::Item: AsRef<str>,
{
    let items: Vec<String> = items.into_iter().map(|s| s.as_ref().to_string()).collect();
    if items.is_empty() {
        return "[]".to_string();
    }
    format!("[\n  {}\n]", items.join(",\n  "))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn builds_flat_records() {
        let row = Obj::new()
            .str("kernel", "gemm")
            .str("mode", "baseline")
            .u64("vlen", 512)
            .u64("wall_ns", 12345)
            .f64("speedup", 3.5)
            .bool("placeholder", false)
            .finish();
        assert_eq!(
            row,
            "{\"kernel\": \"gemm\", \"mode\": \"baseline\", \"vlen\": 512, \
             \"wall_ns\": 12345, \"speedup\": 3.5, \"placeholder\": false}"
        );
    }

    #[test]
    fn escapes_and_non_finite() {
        assert_eq!(escape("a\"b\\c\n"), "a\\\"b\\\\c\\n");
        let o = Obj::new().f64("x", f64::NAN).finish();
        assert_eq!(o, "{\"x\": null}");
        assert_eq!(array(Vec::<String>::new()), "[]");
        assert_eq!(array(["1", "2"]), "[\n  1,\n  2\n]");
    }
}
