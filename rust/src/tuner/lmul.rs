//! Register-grouping transform over translated [`RvvProgram`]s: the
//! tuner's `lmul:F` candidate family.
//!
//! Where [`super::widen`] packs `F` coalesced iterations into the spare
//! lanes of a single wide register (`vl·F` at `m1`), `regroup` keeps the
//! per-register occupancy fixed and moves the scaled `vl` onto an
//! `m2`/`m4` register *group*: each vector operand occupies `F`
//! consecutive architectural registers, `VLMAX` scales with the group,
//! and one grouped instruction retires the work of `F` originals. Because
//! per-register capacity does not grow, the transform applies even on
//! machines the widen transform must refuse — a VLEN=128 unit with
//! `vl=4×e32` loops has no spare lanes at `m1`, but `m2` grouping still
//! halves the dynamic instruction count.
//!
//! Legality is the shared analysis in [`super::legal`] with
//! `cap_factor = 1`: the coalesced `vl·F` always satisfies
//! `vl·F ≤ VLMAX(mF)` exactly when the original `vl ≤ VLMAX(m1)` did.
//!
//! Application, given a legal plan:
//!
//! 1. coalesced loops get `step·F`, and every body op gets `vl·F` and
//!    `lmul = mF` (nested legal loops included);
//! 2. the plan's pre-loop splats get the same `vl·F` / `lmul = mF`
//!    scaling so invariant lanes cover the whole group;
//! 3. every vector register id in the program is remapped `r → r·F` and
//!    `n_vregs` grows `×F`. The translator allocates ids densely, so
//!    without the remap `v1` would be a misaligned `m2` group base
//!    (`SimTrap::BadOperand`). After it, every named id is a multiple of
//!    `F` — aligned by construction — and the `F−1` registers above each
//!    base are named by no other operand, so groups cannot overlap
//!    values that non-grouped ops still use.
//!
//! Mask registers are untouched: mask capacity is `VLEN` bits per
//! register, which bounds `vl·F` for every legal vtype.

use crate::rvv::machine::RvvConfig;
use crate::rvv::{Lmul, RStmt, RvvProgram};
use super::legal;

/// Re-emit `prog`'s legal loops at register grouping `mF`. Returns `Err`
/// with a reason when `factor` is not a supported grouping or no loop
/// qualifies (the tuner records this as a scored-out candidate).
pub fn regroup(prog: &RvvProgram, vlen: u32, factor: u32) -> Result<RvvProgram, String> {
    let lmul = match factor {
        2 => Lmul::M2,
        4 => Lmul::M4,
        _ => return Err(format!("unsupported register grouping factor {factor} (want 2 or 4)")),
    };
    // cap_factor 1: the per-register footprint is unchanged, the group grows
    let plan = legal::analyze(prog, vlen, factor, 1)?;
    let mut out = prog.clone();
    for path in &plan.splats {
        if let Some(RStmt::Op(inst)) = legal::stmt_at_mut(&mut out.body, path) {
            inst.vl *= factor;
            inst.lmul = lmul;
        }
    }
    for path in &plan.loops {
        if let Some(RStmt::Loop { step, body, .. }) = legal::stmt_at_mut(&mut out.body, path) {
            *step *= i64::from(factor);
            scale_and_group(body, factor, lmul);
        }
    }
    remap_vregs(&mut out.body, factor);
    out.n_vregs *= factor as usize;
    Ok(out)
}

/// Convenience wrapper taking the machine config.
pub fn regroup_for(prog: &RvvProgram, cfg: RvvConfig, factor: u32) -> Result<RvvProgram, String> {
    regroup(prog, cfg.vlen, factor)
}

/// `vl·F` and `lmul = mF` on every vector op of a coalesced loop body.
fn scale_and_group(stmts: &mut [RStmt], factor: u32, lmul: Lmul) {
    for s in stmts {
        match s {
            RStmt::Op(inst) => {
                inst.vl *= factor;
                inst.lmul = lmul;
            }
            RStmt::Loop { body, .. } => scale_and_group(body, factor, lmul),
            _ => {}
        }
    }
}

/// Remap every vector register id `r → r·F` program-wide: op destinations,
/// vector sources, and the vreg references inside scalar-fallback blocks.
/// Mask and scalar registers keep their ids.
fn remap_vregs(stmts: &mut [RStmt], factor: u32) {
    use crate::rvv::{Dst, Src};
    for s in stmts {
        match s {
            RStmt::Op(inst) => {
                if let Dst::V(r) = &mut inst.dst {
                    *r *= factor;
                }
                for src in &mut inst.srcs {
                    if let Src::V(r) = src {
                        *r *= factor;
                    }
                }
            }
            RStmt::Loop { body, .. } => remap_vregs(body, factor),
            RStmt::Scalar(b) => {
                for a in &mut b.call.args {
                    if let crate::ir::Arg::V(r) = a {
                        *r *= factor;
                    }
                }
                if let Some(r) = &mut b.dst {
                    *r *= factor;
                }
            }
            RStmt::SSet { .. } => {}
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use std::collections::HashMap;

    use super::*;
    use crate::ir::{AddrExpr, BufDecl, BufKind};
    use crate::neon::elem::Elem;
    use crate::neon::interp::Buffer;
    use crate::rvv::{Dst, MemRef, RvvInst, RvvKind, Sew, Src};
    use crate::sim::Simulator;

    fn op(kind: RvvKind, vl: u32, dst: Dst, srcs: Vec<Src>, mem: Option<MemRef>) -> RStmt {
        RStmt::Op(RvvInst { kind, sew: Sew::E32, lmul: Lmul::M1, vl, dst, srcs, mask: None, mem })
    }

    /// splat v1; loop: load v0, v2 = v0 + v1, store v2.
    fn axpy_like(end: i64) -> RvvProgram {
        RvvProgram {
            name: "axpy-like".into(),
            bufs: vec![
                BufDecl { name: "x".into(), elem: Elem::I32, len: 32, kind: BufKind::Input },
                BufDecl { name: "y".into(), elem: Elem::I32, len: 32, kind: BufKind::Output },
            ],
            body: vec![
                op(RvvKind::VmvVX, 4, Dst::V(1), vec![Src::ImmI(100)], None),
                RStmt::Loop {
                    ivar: 0,
                    start: 0,
                    end,
                    step: 4,
                    body: vec![
                        op(
                            RvvKind::Vle,
                            4,
                            Dst::V(0),
                            vec![],
                            Some(MemRef { buf: 0, index: AddrExpr::s(0), stride: 1 }),
                        ),
                        op(RvvKind::Vadd, 4, Dst::V(2), vec![Src::V(0), Src::V(1)], None),
                        op(
                            RvvKind::Vse,
                            4,
                            Dst::None,
                            vec![Src::V(2)],
                            Some(MemRef { buf: 1, index: AddrExpr::s(0), stride: 1 }),
                        ),
                    ],
                },
            ],
            n_vregs: 3,
            n_mregs: 1,
            n_sregs: 1,
        }
    }

    fn inputs() -> HashMap<String, Buffer> {
        let xs: Vec<i32> = (0..32).map(|i| i * 3 - 11).collect();
        [("x".to_string(), Buffer::from_i32s(&xs))].into()
    }

    fn run(prog: &RvvProgram, vlen: u32) -> (Vec<u8>, u64) {
        let cfg = RvvConfig::new(vlen);
        let (out, stats) = Simulator::new(prog, cfg, &inputs()).unwrap().run().unwrap();
        (out.get("y").unwrap().data.clone(), stats.total())
    }

    #[test]
    fn regroups_and_stays_bit_identical_with_fewer_insts() {
        let prog = axpy_like(32);
        for factor in [2u32, 4] {
            let grouped = regroup(&prog, 128, factor).expect("regroupable");
            let (ref_data, ref_total) = run(&prog, 128);
            let (data, total) = run(&grouped, 128);
            assert_eq!(data, ref_data, "m{factor} grouping changed output bits");
            assert!(
                total < ref_total,
                "m{factor} grouping did not reduce dyn insts: {total} vs {ref_total}"
            );
        }
    }

    #[test]
    fn applies_where_widen_cannot() {
        // VLEN 128 has zero spare lanes for a vl=4 e32 loop: widen must
        // refuse, regroup must succeed — the whole point of the family
        let prog = axpy_like(32);
        assert!(super::super::widen::widen(&prog, 128, 2).is_err());
        assert!(regroup(&prog, 128, 2).is_ok());
    }

    #[test]
    fn regroup_sets_lmul_and_remaps_aligned_register_groups() {
        let grouped = regroup(&axpy_like(32), 128, 2).unwrap();
        assert_eq!(grouped.n_vregs, 6);
        match &grouped.body[0] {
            RStmt::Op(i) => {
                assert_eq!(i.vl, 8);
                assert_eq!(i.lmul, Lmul::M2);
                assert_eq!(i.dst, Dst::V(2), "splat reg not remapped");
            }
            s => panic!("unexpected stmt {s:?}"),
        }
        match &grouped.body[1] {
            RStmt::Loop { step, body, .. } => {
                assert_eq!(*step, 8);
                for s in body {
                    let RStmt::Op(i) = s else { panic!("unexpected stmt {s:?}") };
                    assert_eq!(i.vl, 8);
                    assert_eq!(i.lmul, Lmul::M2);
                    if let Dst::V(r) = i.dst {
                        assert_eq!(r % 2, 0, "v{r} is a misaligned m2 group base");
                    }
                    for src in &i.srcs {
                        if let Src::V(r) = src {
                            assert_eq!(r % 2, 0, "v{r} is a misaligned m2 source");
                        }
                    }
                }
            }
            s => panic!("unexpected stmt {s:?}"),
        }
    }

    #[test]
    fn rejects_bad_factors_and_illegal_loops() {
        assert!(regroup(&axpy_like(32), 128, 3).is_err(), "m3 is not a grouping");
        assert!(regroup(&axpy_like(32), 128, 8).is_err(), "m8 kept out of the family");
        // trip 8 not divisible by coalescing factor 4... (32/4 = 8, fine);
        // use trip 3 instead
        assert!(regroup(&axpy_like(12), 128, 4).is_err(), "trip divisibility must fail");
        // loop-carried dependence rejected like widen
        let mut carried = axpy_like(32);
        if let RStmt::Loop { body, .. } = &mut carried.body[1] {
            body.push(op(RvvKind::VmvVV, 4, Dst::V(1), vec![Src::V(2)], None));
        }
        assert!(regroup(&carried, 128, 2).is_err(), "loop-carried dependence must fail");
    }
}
