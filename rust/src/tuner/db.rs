//! The persistent tuning database (`TUNED.json`).
//!
//! Every search run produces one [`TunedEntry`] per (kernel, mode, vlen)
//! point, carrying full provenance: the whole candidate set with scores
//! (dynamic-instruction count plus wall-clock tiebreak), which candidate
//! won, which engine scored it, and the program's shape fingerprint at
//! tuning time. [`TuningDb::winner`] is the lookup the translator's
//! tuned-override hook uses; it refuses stale entries — a fingerprint or
//! format-version mismatch silently (and safely) falls back to the
//! static rule.
//!
//! Serialisation is hand-rolled on both sides (serde is unavailable
//! offline): emission through [`crate::benchlib::json`], parsing through
//! a minimal recursive-descent JSON reader below. Fingerprints are
//! stored as hex *strings* — they are full 64-bit digests and a JSON
//! number would round-trip through f64 and lose bits above 2^53.

use std::fs;
use std::path::Path;

use anyhow::{anyhow, bail, Context, Result};

use crate::benchlib::json::{array, Obj};
use crate::simde::Mode;
use crate::tuner::candidate::Candidate;

/// Format version; [`TuningDb::from_json`] rejects anything else.
pub const VERSION: u32 = 1;

/// Score record for one candidate lowering. `ok == false` means the
/// candidate was scored out — lowering refused, run faulted, or output
/// diverged from the static reference — with the reason in `error`.
#[derive(Debug, Clone, PartialEq)]
pub struct CandidateScore {
    pub id: String,
    pub ok: bool,
    pub dyn_insts: u64,
    pub wall_ns: u64,
    pub error: String,
}

/// One tuned point with full provenance.
#[derive(Debug, Clone, PartialEq)]
pub struct TunedEntry {
    pub kernel: String,
    pub mode: Mode,
    pub vlen: u32,
    /// [`crate::ir::Program::fingerprint`] of the kernel at tuning time.
    pub fingerprint: u64,
    /// Engine label that scored the winning run (normally "decoded").
    pub engine: String,
    /// [`Candidate::id`] of the selected lowering.
    pub winner: String,
    pub candidates: Vec<CandidateScore>,
}

impl TunedEntry {
    /// The static candidate's score, if it ran.
    pub fn static_score(&self) -> Option<&CandidateScore> {
        self.candidates.iter().find(|c| c.id == "static" && c.ok)
    }

    /// The winning candidate's score.
    pub fn winner_score(&self) -> Option<&CandidateScore> {
        self.candidates.iter().find(|c| c.id == self.winner && c.ok)
    }

    /// Did tuning strictly beat the static rule on dynamic instructions?
    pub fn improved(&self) -> bool {
        match (self.static_score(), self.winner_score()) {
            (Some(s), Some(w)) => self.winner != "static" && w.dyn_insts < s.dyn_insts,
            _ => false,
        }
    }
}

/// The database: a flat set of tuned entries plus a format version.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct TuningDb {
    pub entries: Vec<TunedEntry>,
}

impl TuningDb {
    pub fn new() -> TuningDb {
        TuningDb::default()
    }

    /// Look up the winning candidate for an exact (kernel, mode, vlen,
    /// fingerprint) point. A fingerprint mismatch — the kernel changed
    /// shape since tuning — returns `None` so callers fall back to the
    /// static rule.
    pub fn winner(&self, kernel: &str, mode: Mode, vlen: u32, fingerprint: u64) -> Option<Candidate> {
        self.entries
            .iter()
            .find(|e| {
                e.kernel == kernel
                    && e.mode == mode
                    && e.vlen == vlen
                    && e.fingerprint == fingerprint
            })
            .and_then(|e| Candidate::parse(&e.winner))
    }

    /// Serialise to pretty-enough JSON (one candidate per line).
    pub fn to_json(&self) -> String {
        let entries: Vec<String> = self
            .entries
            .iter()
            .map(|e| {
                let cands: Vec<String> = e
                    .candidates
                    .iter()
                    .map(|c| {
                        Obj::new()
                            .str("id", &c.id)
                            .bool("ok", c.ok)
                            .u64("dyn_insts", c.dyn_insts)
                            .u64("wall_ns", c.wall_ns)
                            .str("error", &c.error)
                            .finish()
                    })
                    .collect();
                Obj::new()
                    .str("kernel", &e.kernel)
                    .str("mode", e.mode.name())
                    .u64("vlen", u64::from(e.vlen))
                    .str("fingerprint", &format!("{:#018x}", e.fingerprint))
                    .str("engine", &e.engine)
                    .str("winner", &e.winner)
                    .raw("candidates", array(&cands))
                    .finish()
            })
            .collect();
        Obj::new()
            .u64("version", u64::from(VERSION))
            .raw("entries", array(&entries))
            .finish()
    }

    /// Parse a database, rejecting unknown format versions outright (a
    /// stale database must never silently steer lowering).
    pub fn from_json(text: &str) -> Result<TuningDb> {
        let root = parse_json(text)?;
        let version = root
            .get("version")
            .and_then(Json::as_u64)
            .context("tuning db: missing or non-numeric 'version'")?;
        if version != u64::from(VERSION) {
            bail!("tuning db: version {version} is not the supported version {VERSION} — re-run `tune`");
        }
        let mut db = TuningDb::new();
        for e in root.get("entries").and_then(Json::as_arr).unwrap_or(&[]) {
            let kernel = e
                .get("kernel")
                .and_then(Json::as_str)
                .context("tuning db: entry missing 'kernel'")?
                .to_string();
            let mode_name = e
                .get("mode")
                .and_then(Json::as_str)
                .context("tuning db: entry missing 'mode'")?;
            let mode = Mode::parse(mode_name)
                .ok_or_else(|| anyhow!("tuning db: unknown mode '{mode_name}'"))?;
            let vlen = e
                .get("vlen")
                .and_then(Json::as_u64)
                .context("tuning db: entry missing 'vlen'")? as u32;
            let fp_text = e
                .get("fingerprint")
                .and_then(Json::as_str)
                .context("tuning db: entry missing 'fingerprint'")?;
            let fingerprint = parse_hex_u64(fp_text)
                .with_context(|| format!("tuning db: bad fingerprint '{fp_text}'"))?;
            let engine =
                e.get("engine").and_then(Json::as_str).unwrap_or("decoded").to_string();
            let winner = e
                .get("winner")
                .and_then(Json::as_str)
                .context("tuning db: entry missing 'winner'")?
                .to_string();
            let mut candidates = Vec::new();
            for c in e.get("candidates").and_then(Json::as_arr).unwrap_or(&[]) {
                candidates.push(CandidateScore {
                    id: c
                        .get("id")
                        .and_then(Json::as_str)
                        .context("tuning db: candidate missing 'id'")?
                        .to_string(),
                    ok: c.get("ok").and_then(Json::as_bool).unwrap_or(false),
                    dyn_insts: c.get("dyn_insts").and_then(Json::as_u64).unwrap_or(0),
                    wall_ns: c.get("wall_ns").and_then(Json::as_u64).unwrap_or(0),
                    error: c.get("error").and_then(Json::as_str).unwrap_or("").to_string(),
                });
            }
            db.entries.push(TunedEntry {
                kernel,
                mode,
                vlen,
                fingerprint,
                engine,
                winner,
                candidates,
            });
        }
        Ok(db)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        fs::write(path, self.to_json() + "\n")
            .with_context(|| format!("writing tuning db to {}", path.display()))
    }

    pub fn load(path: &Path) -> Result<TuningDb> {
        let text = fs::read_to_string(path)
            .with_context(|| format!("reading tuning db from {}", path.display()))?;
        TuningDb::from_json(&text)
            .with_context(|| format!("parsing tuning db {}", path.display()))
    }
}

fn parse_hex_u64(s: &str) -> Result<u64> {
    let digits = s.strip_prefix("0x").unwrap_or(s);
    u64::from_str_radix(digits, 16).map_err(|e| anyhow!("{e}"))
}

// ---------------------------------------------------------------------
// Minimal JSON reader: objects, arrays, strings (with escapes), numbers
// (kept as raw text — precision is the caller's business), booleans,
// null. Just enough to read back what `to_json` writes, while tolerating
// hand-edited files.

/// Parsed JSON value. Numbers stay as raw literals so 64-bit integers
/// survive (no f64 round trip).
#[derive(Debug, Clone, PartialEq)]
enum Json {
    Null,
    Bool(bool),
    Num(String),
    Str(String),
    Arr(Vec<Json>),
    Obj(Vec<(String, Json)>),
}

impl Json {
    fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(fields) => fields.iter().find(|(k, _)| k == key).map(|(_, v)| v),
            _ => None,
        }
    }

    fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) => n.parse().ok(),
            _ => None,
        }
    }

    fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(items) => Some(items),
            _ => None,
        }
    }
}

struct Reader<'a> {
    bytes: &'a [u8],
    pos: usize,
}

fn parse_json(text: &str) -> Result<Json> {
    let mut r = Reader { bytes: text.as_bytes(), pos: 0 };
    let v = r.value()?;
    r.skip_ws();
    if r.pos != r.bytes.len() {
        bail!("json: trailing data at byte {}", r.pos);
    }
    Ok(v)
}

impl Reader<'_> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&mut self) -> Result<u8> {
        self.skip_ws();
        self.bytes
            .get(self.pos)
            .copied()
            .ok_or_else(|| anyhow!("json: unexpected end of input"))
    }

    fn eat(&mut self, b: u8) -> Result<()> {
        if self.peek()? != b {
            bail!("json: expected '{}' at byte {}", b as char, self.pos);
        }
        self.pos += 1;
        Ok(())
    }

    fn literal(&mut self, word: &str, value: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(value)
        } else {
            bail!("json: bad literal at byte {}", self.pos)
        }
    }

    fn value(&mut self) -> Result<Json> {
        match self.peek()? {
            b'{' => self.object(),
            b'[' => self.array(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.literal("true", Json::Bool(true)),
            b'f' => self.literal("false", Json::Bool(false)),
            b'n' => self.literal("null", Json::Null),
            b'-' | b'0'..=b'9' => self.number(),
            c => bail!("json: unexpected '{}' at byte {}", c as char, self.pos),
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut fields = Vec::new();
        if self.peek()? == b'}' {
            self.pos += 1;
            return Ok(Json::Obj(fields));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.eat(b':')?;
            fields.push((key, self.value()?));
            match self.peek()? {
                b',' => self.pos += 1,
                b'}' => {
                    self.pos += 1;
                    return Ok(Json::Obj(fields));
                }
                c => bail!("json: expected ',' or '}}', got '{}' at byte {}", c as char, self.pos),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut items = Vec::new();
        if self.peek()? == b']' {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            items.push(self.value()?);
            match self.peek()? {
                b',' => self.pos += 1,
                b']' => {
                    self.pos += 1;
                    return Ok(Json::Arr(items));
                }
                c => bail!("json: expected ',' or ']', got '{}' at byte {}", c as char, self.pos),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let Some(&b) = self.bytes.get(self.pos) else {
                bail!("json: unterminated string");
            };
            self.pos += 1;
            match b {
                b'"' => return Ok(out),
                b'\\' => {
                    let Some(&esc) = self.bytes.get(self.pos) else {
                        bail!("json: unterminated escape");
                    };
                    self.pos += 1;
                    match esc {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b'r' => out.push('\r'),
                        b't' => out.push('\t'),
                        b'b' => out.push('\u{0008}'),
                        b'f' => out.push('\u{000c}'),
                        b'u' => {
                            let hex = self
                                .bytes
                                .get(self.pos..self.pos + 4)
                                .ok_or_else(|| anyhow!("json: truncated \\u escape"))?;
                            let code = u32::from_str_radix(std::str::from_utf8(hex)?, 16)?;
                            self.pos += 4;
                            // surrogate pairs don't occur in our own output;
                            // map lone surrogates to the replacement char
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        c => bail!("json: bad escape '\\{}'", c as char),
                    }
                }
                _ => {
                    // collect the full UTF-8 sequence starting at b
                    let start = self.pos - 1;
                    while self.pos < self.bytes.len() && (self.bytes[self.pos] & 0xc0) == 0x80 {
                        self.pos += 1;
                    }
                    out.push_str(std::str::from_utf8(&self.bytes[start..self.pos])?);
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9')
        {
            self.pos += 1;
        }
        Ok(Json::Num(std::str::from_utf8(&self.bytes[start..self.pos])?.to_string()))
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    fn sample_db() -> TuningDb {
        TuningDb {
            entries: vec![TunedEntry {
                kernel: "vrelu".into(),
                mode: Mode::RvvCustom,
                vlen: 512,
                fingerprint: 0xdead_beef_cafe_f00d, // > 2^53: must survive JSON
                engine: "decoded".into(),
                winner: "widen:4".into(),
                candidates: vec![
                    CandidateScore {
                        id: "static".into(),
                        ok: true,
                        dyn_insts: 1000,
                        wall_ns: 5000,
                        error: String::new(),
                    },
                    CandidateScore {
                        id: "widen:4".into(),
                        ok: true,
                        dyn_insts: 400,
                        wall_ns: 2000,
                        error: String::new(),
                    },
                    CandidateScore {
                        id: "widen:8".into(),
                        ok: false,
                        dyn_insts: 0,
                        wall_ns: 0,
                        error: "widen:8: no loop admits widening by 8\n\"quoted\\path\"".into(),
                    },
                ],
            }],
        }
    }

    #[test]
    fn json_round_trips_exactly() {
        let db = sample_db();
        let text = db.to_json();
        let back = TuningDb::from_json(&text).unwrap();
        assert_eq!(back, db);
    }

    #[test]
    fn stale_version_is_rejected() {
        let text = sample_db().to_json().replacen("\"version\": 1", "\"version\": 99", 1);
        let err = TuningDb::from_json(&text).unwrap_err();
        assert!(format!("{err:#}").contains("version 99"), "unhelpful error: {err:#}");
    }

    #[test]
    fn winner_respects_fingerprint_and_point() {
        let db = sample_db();
        let hit = db.winner("vrelu", Mode::RvvCustom, 512, 0xdead_beef_cafe_f00d);
        assert_eq!(hit, Some(Candidate::Widen(4)));
        // stale shape, wrong vlen, wrong mode, unknown kernel: all None
        assert_eq!(db.winner("vrelu", Mode::RvvCustom, 512, 1), None);
        assert_eq!(db.winner("vrelu", Mode::RvvCustom, 256, 0xdead_beef_cafe_f00d), None);
        assert_eq!(db.winner("vrelu", Mode::Baseline, 512, 0xdead_beef_cafe_f00d), None);
        assert_eq!(db.winner("gemm", Mode::RvvCustom, 512, 0xdead_beef_cafe_f00d), None);
    }

    #[test]
    fn entry_improvement_accounting() {
        let e = &sample_db().entries[0];
        assert!(e.improved());
        assert_eq!(e.static_score().unwrap().dyn_insts, 1000);
        assert_eq!(e.winner_score().unwrap().dyn_insts, 400);
    }
}
