//! # Lowering autotuner
//!
//! Search-based selection of customized RVV conversions. The static
//! per-intrinsic rules in [`crate::simde`] pick one lowering per
//! (intrinsic, mode, vlen) point; this module treats that choice as the
//! *first* candidate in a search space rather than the final answer:
//!
//! 1. **Enumerate** ([`candidate`]) — for each kernel the static rule
//!    plus alternatives: loop-coalescing `widen:F` variants that fill
//!    wide vector units the fixed 128-bit NEON shapes leave idle
//!    ([`widen`]), register-grouping `lmul:F` variants that re-emit the
//!    same coalescing at `m2`/`m4` vtypes ([`lmul`]) — applicable even
//!    when the machine has no spare lanes — and
//!    `force-baseline:<category>` degradations that swap a
//!    combo/algorithmic sequence for the generic SIMDe path.
//! 2. **Score** — every lowered candidate first passes the admission
//!    verifier ([`crate::rvv::verify`]) as a cheap pre-filter: a program
//!    the verifier rejects would only trap at runtime, so it is scored
//!    out immediately without spending an execution. Survivors run
//!    through the pre-decoded engine via the coordinator's
//!    fault-tolerant primitive
//!    ([`crate::coordinator::run_prepared_with_recovery`]). Candidates
//!    are independent, so the runs fan out over a worker pool; winner
//!    selection stays deterministic because scoring walks the collected
//!    results in candidate-id order. A per-(kernel, candidate-family)
//!    circuit breaker ([`crate::coordinator::Breaker`]) watches the
//!    runs: after `breaker_threshold` consecutive faults in one family
//!    (`widen`, `lmul`, `force-baseline`), the remaining candidates of
//!    that family are skipped — the skip is recorded in the provenance
//!    rows and counted in [`TuneOutcome::skipped`]. The static rule is
//!    never breaker-skipped. The score is the paper's metric,
//!    [`crate::sim::SimStats::total`] dynamic instructions, with
//!    wall-clock as tiebreak. A candidate that fails to lower, traps,
//!    panics, or produces output bytes different from the static
//!    reference is *scored out* (recorded with `ok = false` and, for
//!    runtime faults, a [`crate::coordinator::FaultRecord`]) — never
//!    aborts the search.
//! 3. **Persist** ([`db`]) — winners plus full provenance (entire
//!    candidate set with scores, shape fingerprint, engine) go into a
//!    versioned `TUNED.json`. [`crate::simde::Translator::with_tuning`]
//!    consults it at translation time, so `bench --tuned` and
//!    `figure2_report` replay tuned lowerings exactly.
//!
//! Safety invariant: a tuned lowering is only ever selected if its
//! output buffers were bit-identical to the static lowering's during the
//! search, and the database lookup re-checks the program's shape
//! fingerprint so a changed kernel silently falls back to the static
//! rule.

pub mod candidate;
pub mod db;
pub mod legal;
pub mod lmul;
pub mod widen;

use std::collections::{HashMap, VecDeque};
use std::sync::{Mutex, MutexGuard};

use anyhow::{bail, Context, Result};

use crate::coordinator::{self, Breaker, CachedProgram, EngineKind, FaultRecord, Job, RetryPolicy};
use crate::kernels;
use crate::neon::interp::Buffer;
use crate::rvv::machine::RvvConfig;
use crate::sim::decode;
use crate::simde::Mode;
use db::{CandidateScore, TunedEntry, TuningDb};

pub use candidate::Candidate;

/// Search configuration.
#[derive(Debug, Clone)]
pub struct TunerOptions {
    /// Vector lengths to tune for.
    pub vlens: Vec<u32>,
    /// Kernels to tune; empty means the full Figure-2 suite.
    pub kernels: Vec<&'static str>,
    /// Translation modes to tune (baseline has an empty candidate space
    /// beyond `static`, so the default is custom only).
    pub modes: Vec<Mode>,
    /// Candidate budget per point; `static` is always kept.
    pub max_candidates: usize,
    /// Recovery ladder for candidate runs.
    pub retry: RetryPolicy,
    /// Worker threads for candidate runs within one tuning point.
    pub threads: usize,
    /// Consecutive faults in one (kernel, candidate-family) before the
    /// circuit breaker opens and the family's remaining candidates are
    /// skipped (min 1; the static rule is never skipped).
    pub breaker_threshold: u32,
}

impl Default for TunerOptions {
    fn default() -> TunerOptions {
        TunerOptions {
            vlens: vec![512],
            kernels: Vec::new(),
            modes: vec![Mode::RvvCustom],
            max_candidates: 16,
            retry: RetryPolicy::none(),
            threads: std::thread::available_parallelism().map_or(1, |n| n.get()),
            breaker_threshold: 3,
        }
    }
}

impl TunerOptions {
    /// Tiny smoke configuration for CI: one kernel, budget just large
    /// enough to cover the `widen` and `lmul` transform families.
    pub fn smoke(vlen: u32) -> TunerOptions {
        TunerOptions {
            vlens: vec![vlen],
            kernels: vec!["vrelu"],
            max_candidates: 6,
            ..TunerOptions::default()
        }
    }
}

/// Everything a search run produced.
#[derive(Debug)]
pub struct TuneOutcome {
    /// The tuning database (winners + provenance), ready to save.
    pub db: TuningDb,
    /// Faults from candidates that trapped or panicked mid-run (they are
    /// also scored out in the corresponding entry).
    pub faults: Vec<FaultRecord>,
    /// Entries whose winner strictly beat the static rule.
    pub improved: usize,
    /// Candidate runs skipped because their family's circuit breaker was
    /// open (each is also a scored-out provenance row in its entry).
    pub skipped: usize,
}

/// Run the search over the whole (vlen × kernel × mode) grid.
pub fn tune(opts: &TunerOptions) -> Result<TuneOutcome> {
    let _quiet = coordinator::quiet_panics();
    let kernel_names: Vec<&'static str> =
        if opts.kernels.is_empty() { kernels::NAMES.to_vec() } else { opts.kernels.clone() };
    let mut db = TuningDb::new();
    let mut faults = Vec::new();
    let mut skipped = 0usize;
    let breaker = Breaker::new(opts.breaker_threshold);
    for &vlen in &opts.vlens {
        for &kernel in &kernel_names {
            for &mode in &opts.modes {
                let entry = tune_point(kernel, mode, vlen, opts, &breaker, &mut faults, &mut skipped)
                    .with_context(
                        || format!("tuning {kernel} mode={} vlen={vlen}", mode.name()),
                    )?;
                db.entries.push(entry);
            }
        }
    }
    let improved = db.entries.iter().filter(|e| e.improved()).count();
    Ok(TuneOutcome { db, faults, improved, skipped })
}

fn outputs_identical(a: &HashMap<String, Buffer>, b: &HashMap<String, Buffer>) -> bool {
    a.len() == b.len()
        && a.iter().all(|(name, buf)| {
            b.get(name).is_some_and(|other| other.elem == buf.elem && other.data == buf.data)
        })
}

fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    match m.lock() {
        Ok(g) => g,
        Err(p) => p.into_inner(),
    }
}

/// What one candidate's lower + run produced, before scoring.
enum CandRun {
    /// The lowering refused to apply (e.g. no coalescible loop).
    Skip(String),
    /// Trap/panic survived the recovery ladder as a fault record.
    Fault(Box<FaultRecord>),
    /// A completed run with outputs and scoring signals.
    Done(Box<coordinator::PreparedOutcome>),
}

/// The breaker family of a candidate id: the transform prefix before the
/// first `:` (`widen:2` → `widen`), or the whole id (`static`).
fn family_of(id: &str) -> &str {
    id.split(':').next().unwrap_or(id)
}

/// Lower one candidate, pass it through the admission verifier, and run
/// it through the recovery ladder. Pure function of its arguments — safe
/// to fan out across worker threads.
fn run_candidate(
    ci: usize,
    cand: &candidate::Candidate,
    case: &kernels::KernelCase,
    mode: Mode,
    cfg: RvvConfig,
    job: &Job,
    retry: RetryPolicy,
) -> CandRun {
    match candidate::lower_with(&case.prog, mode, cfg, cand) {
        Ok((rvv, _report)) => {
            // admission pre-filter: a rejected program would only trap at
            // runtime, so score it out without spending an execution
            if let Err(e) = crate::rvv::verify::verify(&rvv, job.vlen) {
                return CandRun::Skip(format!("verify: {e}"));
            }
            let decoded = decode(&rvv);
            let prepared = CachedProgram { rvv, decoded };
            match coordinator::run_prepared_with_recovery(ci, job, &prepared, &case.inputs, retry) {
                Ok(out) => CandRun::Done(Box::new(out)),
                Err(fault) => CandRun::Fault(Box::new(fault)),
            }
        }
        Err(e) => CandRun::Skip(format!("{e:#}")),
    }
}

/// Tune one (kernel, mode, vlen) point: fan the candidate runs out over
/// a worker pool (they are independent), then score sequentially in
/// candidate-id order with the static lowering as the bit-identity
/// reference — index 0 is always `static`, so the reference is available
/// before any alternative is judged and the winner is deterministic.
fn tune_point(
    kernel: &'static str,
    mode: Mode,
    vlen: u32,
    opts: &TunerOptions,
    breaker: &Breaker,
    faults: &mut Vec<FaultRecord>,
    skipped: &mut usize,
) -> Result<TunedEntry> {
    let case = kernels::by_name(kernel).with_context(|| format!("unknown kernel '{kernel}'"))?;
    let fingerprint = case.prog.fingerprint();
    let cfg = RvvConfig::new(vlen);
    let cands = candidate::enumerate(&case.prog, mode, opts.max_candidates);
    let job = Job { kernel, mode, vlen };

    // phase 1: run all candidates over the worker pool, results into
    // per-candidate slots (same queue + slots shape as the coordinator's
    // run_matrix_report pool)
    let queue: Mutex<VecDeque<usize>> = Mutex::new((0..cands.len()).collect());
    let slots: Mutex<Vec<Option<CandRun>>> =
        Mutex::new((0..cands.len()).map(|_| None).collect());
    let workers = opts.threads.max(1).min(cands.len().max(1));
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                let next = lock_ignore_poison(&queue).pop_front();
                let Some(ci) = next else { return };
                let cand = &cands[ci];
                let id = cand.id();
                let fam = family_of(&id);
                // the static rule is the bit-identity reference and is
                // never breaker-skipped; alternatives of a family that
                // keeps faulting are
                if !cand.is_static() && breaker.is_open(kernel, fam) {
                    lock_ignore_poison(&slots)[ci] = Some(CandRun::Skip(format!(
                        "skipped: breaker open for ({kernel}, {fam}) after {} consecutive fault(s)",
                        breaker.threshold()
                    )));
                    continue;
                }
                let run = run_candidate(ci, cand, &case, mode, cfg, &job, opts.retry);
                if !cand.is_static() {
                    match &run {
                        CandRun::Fault(_) => breaker.record_fault(kernel, fam),
                        CandRun::Done(_) => breaker.record_ok(kernel, fam),
                        CandRun::Skip(_) => {}
                    }
                }
                lock_ignore_poison(&slots)[ci] = Some(run);
            });
        }
    });
    let mut slots = match slots.into_inner() {
        Ok(v) => v,
        Err(p) => p.into_inner(),
    };

    // phase 2: sequential scoring in candidate-id order
    let mut scores: Vec<CandidateScore> = Vec::new();
    let mut reference: Option<HashMap<String, Buffer>> = None;
    let mut best: Option<(u64, u64, String, EngineKind)> = None;

    for (ci, cand) in cands.iter().enumerate() {
        let id = cand.id();
        let run = slots[ci]
            .take()
            .unwrap_or_else(|| CandRun::Skip("no result: worker thread died".to_string()));
        match run {
            CandRun::Skip(e) => {
                if cand.is_static() {
                    bail!("static lowering failed — nothing to tune against: {e}");
                }
                // candidate does not apply here (no coalescible loop,
                // verifier rejection, or open breaker): scored out,
                // search continues
                if e.starts_with("skipped: breaker open") {
                    *skipped += 1;
                }
                scores.push(CandidateScore {
                    id,
                    ok: false,
                    dyn_insts: 0,
                    wall_ns: 0,
                    error: e,
                });
            }
            CandRun::Done(out) => {
                if let Some(reference) = &reference {
                    if !outputs_identical(reference, &out.outputs) {
                        scores.push(CandidateScore {
                            id,
                            ok: false,
                            dyn_insts: out.stats.total(),
                            wall_ns: out.wall.as_nanos() as u64,
                            error: "output buffers diverge from the static lowering".into(),
                        });
                        continue;
                    }
                }
                let dyn_insts = out.stats.total();
                let wall_ns = out.wall.as_nanos() as u64;
                let engine = out.engine;
                if cand.is_static() {
                    reference = Some(out.outputs);
                }
                let better =
                    best.as_ref().is_none_or(|(d, w, _, _)| (dyn_insts, wall_ns) < (*d, *w));
                if better {
                    best = Some((dyn_insts, wall_ns, id.clone(), engine));
                }
                scores.push(CandidateScore {
                    id,
                    ok: true,
                    dyn_insts,
                    wall_ns,
                    error: String::new(),
                });
            }
            CandRun::Fault(fault) => {
                if cand.is_static() {
                    let msg = fault.error.clone();
                    faults.push(*fault);
                    bail!("static lowering faulted ({msg}) — nothing to tune against");
                }
                // trap/panic inside a candidate: degrade to a fault record
                // plus a scored-out row, keep searching
                scores.push(CandidateScore {
                    id,
                    ok: false,
                    dyn_insts: 0,
                    wall_ns: 0,
                    error: fault.error.clone(),
                });
                faults.push(*fault);
            }
        }
    }

    let Some((_, _, winner, engine)) = best else {
        bail!("no candidate survived scoring for {kernel}");
    };
    Ok(TunedEntry {
        kernel: kernel.to_string(),
        mode,
        vlen,
        fingerprint,
        engine: engine.label().to_string(),
        winner,
        candidates: scores,
    })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn narrow_machine_keeps_the_static_rule() {
        // at VLEN 128 the NEON shapes already fill the machine: every
        // widen candidate must score out and static must win
        let opts = TunerOptions {
            vlens: vec![128],
            kernels: vec!["vrelu"],
            max_candidates: 4,
            ..TunerOptions::default()
        };
        let out = tune(&opts).unwrap();
        assert_eq!(out.db.entries.len(), 1);
        let e = &out.db.entries[0];
        assert_eq!(e.winner, "static");
        assert_eq!(out.improved, 0);
        let widens: Vec<_> = e.candidates.iter().filter(|c| c.id.starts_with("widen:")).collect();
        assert!(!widens.is_empty(), "widen candidates were not enumerated");
        for w in widens {
            assert!(!w.ok, "widen must score out at vlen 128: {w:?}");
            assert!(!w.error.is_empty(), "scored-out candidate needs a reason");
        }
    }

    #[test]
    fn wide_machine_widens_vrelu() {
        let opts = TunerOptions {
            vlens: vec![512],
            kernels: vec!["vrelu"],
            max_candidates: 4,
            ..TunerOptions::default()
        };
        let out = tune(&opts).unwrap();
        let e = &out.db.entries[0];
        assert!(e.winner.starts_with("widen:"), "expected a widen winner, got {}", e.winner);
        assert!(e.improved(), "winner must strictly beat static: {e:?}");
        assert_eq!(out.improved, 1);
        // healthy candidates never open the breaker
        assert_eq!(out.skipped, 0);
        // winner must be replayable through the db lookup
        let cand = out
            .db
            .winner("vrelu", Mode::RvvCustom, 512, e.fingerprint)
            .expect("winner must parse");
        assert!(!cand.is_static());
    }

    #[test]
    fn narrow_machine_regroups_vrelu() {
        // the same VLEN 128 point where widen scores out: with the full
        // candidate budget the lmul family applies (per-register capacity
        // is unchanged, the group grows) and must beat static
        let opts = TunerOptions {
            vlens: vec![128],
            kernels: vec!["vrelu"],
            ..TunerOptions::default()
        };
        let out = tune(&opts).unwrap();
        let e = &out.db.entries[0];
        assert!(e.winner.starts_with("lmul:"), "expected an lmul winner, got {}", e.winner);
        assert!(e.improved(), "grouping must strictly beat static: {e:?}");
        let lmuls: Vec<_> = e.candidates.iter().filter(|c| c.id.starts_with("lmul:")).collect();
        assert_eq!(lmuls.len(), 2, "both lmul:2 and lmul:4 must be enumerated");
        for c in lmuls {
            assert!(c.ok, "lmul candidates must be legal at vlen 128: {c:?}");
        }
    }

    #[test]
    fn single_thread_and_pool_agree() {
        // determinism satellite: the winner and full score table must not
        // depend on how the candidate runs were scheduled (vlen 128 keeps
        // the candidate dyn-inst scores distinct, so no wall-clock ties)
        let pooled = TunerOptions {
            vlens: vec![128],
            kernels: vec!["vrelu"],
            ..TunerOptions::default()
        };
        let serial = TunerOptions { threads: 1, ..pooled.clone() };
        let a = tune(&pooled).unwrap();
        let b = tune(&serial).unwrap();
        assert_eq!(a.db.entries.len(), b.db.entries.len());
        for (ea, eb) in a.db.entries.iter().zip(&b.db.entries) {
            assert_eq!(ea.winner, eb.winner);
            let ids_a: Vec<_> = ea.candidates.iter().map(|c| (&c.id, c.ok)).collect();
            let ids_b: Vec<_> = eb.candidates.iter().map(|c| (&c.id, c.ok)).collect();
            assert_eq!(ids_a, ids_b, "score tables diverge between schedules");
        }
    }
}
