//! # Lowering autotuner
//!
//! Search-based selection of customized RVV conversions. The static
//! per-intrinsic rules in [`crate::simde`] pick one lowering per
//! (intrinsic, mode, vlen) point; this module treats that choice as the
//! *first* candidate in a search space rather than the final answer:
//!
//! 1. **Enumerate** ([`candidate`]) — for each kernel the static rule
//!    plus alternatives: loop-coalescing `widen:F` variants that fill
//!    wide vector units the fixed 128-bit NEON shapes leave idle
//!    ([`widen`]), and `force-baseline:<category>` degradations that swap
//!    a combo/algorithmic sequence for the generic SIMDe path.
//! 2. **Score** — run every candidate through the pre-decoded engine via
//!    the coordinator's fault-tolerant primitive
//!    ([`crate::coordinator::run_prepared_with_recovery`]). The score is
//!    the paper's metric, [`crate::sim::SimStats::total`] dynamic
//!    instructions, with wall-clock as tiebreak. A candidate that fails
//!    to lower, traps, panics, or produces output bytes different from
//!    the static reference is *scored out* (recorded with `ok = false`
//!    and, for runtime faults, a [`crate::coordinator::FaultRecord`]) —
//!    never aborts the search.
//! 3. **Persist** ([`db`]) — winners plus full provenance (entire
//!    candidate set with scores, shape fingerprint, engine) go into a
//!    versioned `TUNED.json`. [`crate::simde::Translator::with_tuning`]
//!    consults it at translation time, so `bench --tuned` and
//!    `figure2_report` replay tuned lowerings exactly.
//!
//! Safety invariant: a tuned lowering is only ever selected if its
//! output buffers were bit-identical to the static lowering's during the
//! search, and the database lookup re-checks the program's shape
//! fingerprint so a changed kernel silently falls back to the static
//! rule.

pub mod candidate;
pub mod db;
pub mod widen;

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use crate::coordinator::{self, CachedProgram, EngineKind, FaultRecord, Job, RetryPolicy};
use crate::kernels;
use crate::neon::interp::Buffer;
use crate::rvv::machine::RvvConfig;
use crate::sim::decode;
use crate::simde::Mode;
use db::{CandidateScore, TunedEntry, TuningDb};

pub use candidate::Candidate;

/// Search configuration.
#[derive(Debug, Clone)]
pub struct TunerOptions {
    /// Vector lengths to tune for.
    pub vlens: Vec<u32>,
    /// Kernels to tune; empty means the full Figure-2 suite.
    pub kernels: Vec<&'static str>,
    /// Translation modes to tune (baseline has an empty candidate space
    /// beyond `static`, so the default is custom only).
    pub modes: Vec<Mode>,
    /// Candidate budget per point; `static` is always kept.
    pub max_candidates: usize,
    /// Recovery ladder for candidate runs.
    pub retry: RetryPolicy,
}

impl Default for TunerOptions {
    fn default() -> TunerOptions {
        TunerOptions {
            vlens: vec![512],
            kernels: Vec::new(),
            modes: vec![Mode::RvvCustom],
            max_candidates: 16,
            retry: RetryPolicy::none(),
        }
    }
}

impl TunerOptions {
    /// Tiny smoke configuration for CI: one kernel, minimal budget.
    pub fn smoke(vlen: u32) -> TunerOptions {
        TunerOptions {
            vlens: vec![vlen],
            kernels: vec!["vrelu"],
            max_candidates: 3,
            ..TunerOptions::default()
        }
    }
}

/// Everything a search run produced.
#[derive(Debug)]
pub struct TuneOutcome {
    /// The tuning database (winners + provenance), ready to save.
    pub db: TuningDb,
    /// Faults from candidates that trapped or panicked mid-run (they are
    /// also scored out in the corresponding entry).
    pub faults: Vec<FaultRecord>,
    /// Entries whose winner strictly beat the static rule.
    pub improved: usize,
}

/// Run the search over the whole (vlen × kernel × mode) grid.
pub fn tune(opts: &TunerOptions) -> Result<TuneOutcome> {
    let _quiet = coordinator::quiet_panics();
    let kernel_names: Vec<&'static str> =
        if opts.kernels.is_empty() { kernels::NAMES.to_vec() } else { opts.kernels.clone() };
    let mut db = TuningDb::new();
    let mut faults = Vec::new();
    for &vlen in &opts.vlens {
        for &kernel in &kernel_names {
            for &mode in &opts.modes {
                let entry = tune_point(kernel, mode, vlen, opts, &mut faults).with_context(
                    || format!("tuning {kernel} mode={} vlen={vlen}", mode.name()),
                )?;
                db.entries.push(entry);
            }
        }
    }
    let improved = db.entries.iter().filter(|e| e.improved()).count();
    Ok(TuneOutcome { db, faults, improved })
}

fn outputs_identical(a: &HashMap<String, Buffer>, b: &HashMap<String, Buffer>) -> bool {
    a.len() == b.len()
        && a.iter().all(|(name, buf)| {
            b.get(name).is_some_and(|other| other.elem == buf.elem && other.data == buf.data)
        })
}

/// Tune one (kernel, mode, vlen) point: run the static lowering first as
/// the bit-identity reference, then score each alternative against it.
fn tune_point(
    kernel: &'static str,
    mode: Mode,
    vlen: u32,
    opts: &TunerOptions,
    faults: &mut Vec<FaultRecord>,
) -> Result<TunedEntry> {
    let case = kernels::by_name(kernel).with_context(|| format!("unknown kernel '{kernel}'"))?;
    let fingerprint = case.prog.fingerprint();
    let cfg = RvvConfig::new(vlen);
    let cands = candidate::enumerate(&case.prog, mode, opts.max_candidates);
    let job = Job { kernel, mode, vlen };

    let mut scores: Vec<CandidateScore> = Vec::new();
    let mut reference: Option<HashMap<String, Buffer>> = None;
    let mut best: Option<(u64, u64, String, EngineKind)> = None;

    for (ci, cand) in cands.iter().enumerate() {
        let id = cand.id();
        let lowered = candidate::lower_with(&case.prog, mode, cfg, cand);
        let (rvv, _report) = match lowered {
            Ok(x) => x,
            Err(e) if cand.is_static() => {
                return Err(e.context("static lowering failed — nothing to tune against"));
            }
            Err(e) => {
                // candidate does not apply here (e.g. no widenable loop):
                // scored out, search continues
                scores.push(CandidateScore {
                    id,
                    ok: false,
                    dyn_insts: 0,
                    wall_ns: 0,
                    error: format!("{e:#}"),
                });
                continue;
            }
        };
        let decoded = decode(&rvv);
        let prepared = CachedProgram { rvv, decoded };
        match coordinator::run_prepared_with_recovery(ci, &job, &prepared, &case.inputs, opts.retry)
        {
            Ok(out) => {
                if let Some(reference) = &reference {
                    if !outputs_identical(reference, &out.outputs) {
                        scores.push(CandidateScore {
                            id,
                            ok: false,
                            dyn_insts: out.stats.total(),
                            wall_ns: out.wall.as_nanos() as u64,
                            error: "output buffers diverge from the static lowering".into(),
                        });
                        continue;
                    }
                }
                let dyn_insts = out.stats.total();
                let wall_ns = out.wall.as_nanos() as u64;
                if cand.is_static() {
                    reference = Some(out.outputs);
                }
                let better =
                    best.as_ref().is_none_or(|(d, w, _, _)| (dyn_insts, wall_ns) < (*d, *w));
                if better {
                    best = Some((dyn_insts, wall_ns, id.clone(), out.engine));
                }
                scores.push(CandidateScore {
                    id,
                    ok: true,
                    dyn_insts,
                    wall_ns,
                    error: String::new(),
                });
            }
            Err(fault) if cand.is_static() => {
                let msg = fault.error.clone();
                faults.push(fault);
                bail!("static lowering faulted ({msg}) — nothing to tune against");
            }
            Err(fault) => {
                // trap/panic inside a candidate: degrade to a fault record
                // plus a scored-out row, keep searching
                scores.push(CandidateScore {
                    id,
                    ok: false,
                    dyn_insts: 0,
                    wall_ns: 0,
                    error: fault.error.clone(),
                });
                faults.push(fault);
            }
        }
    }

    let Some((_, _, winner, engine)) = best else {
        bail!("no candidate survived scoring for {kernel}");
    };
    Ok(TunedEntry {
        kernel: kernel.to_string(),
        mode,
        vlen,
        fingerprint,
        engine: engine.label().to_string(),
        winner,
        candidates: scores,
    })
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn narrow_machine_keeps_the_static_rule() {
        // at VLEN 128 the NEON shapes already fill the machine: every
        // widen candidate must score out and static must win
        let opts = TunerOptions {
            vlens: vec![128],
            kernels: vec!["vrelu"],
            max_candidates: 4,
            ..TunerOptions::default()
        };
        let out = tune(&opts).unwrap();
        assert_eq!(out.db.entries.len(), 1);
        let e = &out.db.entries[0];
        assert_eq!(e.winner, "static");
        assert_eq!(out.improved, 0);
        let widens: Vec<_> = e.candidates.iter().filter(|c| c.id.starts_with("widen:")).collect();
        assert!(!widens.is_empty(), "widen candidates were not enumerated");
        for w in widens {
            assert!(!w.ok, "widen must score out at vlen 128: {w:?}");
            assert!(!w.error.is_empty(), "scored-out candidate needs a reason");
        }
    }

    #[test]
    fn wide_machine_widens_vrelu() {
        let opts = TunerOptions {
            vlens: vec![512],
            kernels: vec!["vrelu"],
            max_candidates: 4,
            ..TunerOptions::default()
        };
        let out = tune(&opts).unwrap();
        let e = &out.db.entries[0];
        assert!(e.winner.starts_with("widen:"), "expected a widen winner, got {}", e.winner);
        assert!(e.improved(), "winner must strictly beat static: {e:?}");
        assert_eq!(out.improved, 1);
        // winner must be replayable through the db lookup
        let cand = out
            .db
            .winner("vrelu", Mode::RvvCustom, 512, e.fingerprint)
            .expect("winner must parse");
        assert!(!cand.is_static());
    }
}
