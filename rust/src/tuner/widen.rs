//! Loop-coalescing widening transform over translated [`RvvProgram`]s.
//!
//! NEON kernels are written against fixed 128-bit q-registers, so the
//! translated RVV code processes 4×f32 (or 16×u8, …) per loop iteration
//! no matter how wide the target vector unit is. On a VLEN=512 machine
//! that wastes 3/4 of each vector instruction *and* pays the modelled
//! loop overhead (and `vsetvli` churn) four times more often than
//! necessary.
//!
//! `widen(prog, vlen, F)` coalesces `F` consecutive iterations of a loop
//! into one: the loop `step` is multiplied by `F` and every vector op in
//! the body has its `vl` multiplied by `F`, staying at `m1` — the extra
//! lanes land in the spare capacity of a single wide register, so the
//! transform requires `vl · F · SEW ≤ VLEN`. The legality analysis is
//! shared with the register-grouping transform in [`super::legal`] (see
//! its module docs for the full rule set); [`super::lmul`] is the
//! companion transform that scales the register group instead of the
//! per-register occupancy, which is why it also applies on machines with
//! no spare lanes.

use crate::rvv::machine::RvvConfig;
use crate::rvv::{RStmt, RvvProgram};
use super::legal;

/// Widen `prog` by `factor`: coalesce every legally-widenable loop.
/// Returns `Err` with a reason when no loop admits widening (the tuner
/// records this as a scored-out candidate).
pub fn widen(prog: &RvvProgram, vlen: u32, factor: u32) -> Result<RvvProgram, String> {
    // capacity grows with the factor: vl·F lanes must fit one m1 register
    let plan = legal::analyze(prog, vlen, factor, factor)?;
    let mut out = prog.clone();
    for path in &plan.splats {
        if let Some(RStmt::Op(inst)) = legal::stmt_at_mut(&mut out.body, path) {
            inst.vl *= factor;
        }
    }
    for path in &plan.loops {
        if let Some(RStmt::Loop { step, body, .. }) = legal::stmt_at_mut(&mut out.body, path) {
            *step *= i64::from(factor);
            legal::scale_vls(body, factor);
        }
    }
    Ok(out)
}

/// Convenience wrapper taking the machine config.
pub fn widen_for(prog: &RvvProgram, cfg: RvvConfig, factor: u32) -> Result<RvvProgram, String> {
    widen(prog, cfg.vlen, factor)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use std::collections::HashMap;

    use super::*;
    use crate::ir::{AddrExpr, BufDecl, BufKind};
    use crate::neon::elem::Elem;
    use crate::neon::interp::Buffer;
    use crate::rvv::{Dst, Lmul, MemRef, RvvInst, RvvKind, Sew, Src};
    use crate::sim::Simulator;

    fn op(kind: RvvKind, vl: u32, dst: Dst, srcs: Vec<Src>, mem: Option<MemRef>) -> RStmt {
        RStmt::Op(RvvInst { kind, sew: Sew::E32, lmul: Lmul::M1, vl, dst, srcs, mask: None, mem })
    }

    /// splat v1; loop: load v0, v2 = v0 + v1, store v2.
    fn axpy_like(end: i64) -> RvvProgram {
        RvvProgram {
            name: "axpy-like".into(),
            bufs: vec![
                BufDecl { name: "x".into(), elem: Elem::I32, len: 32, kind: BufKind::Input },
                BufDecl { name: "y".into(), elem: Elem::I32, len: 32, kind: BufKind::Output },
            ],
            body: vec![
                op(RvvKind::VmvVX, 4, Dst::V(1), vec![Src::ImmI(100)], None),
                RStmt::Loop {
                    ivar: 0,
                    start: 0,
                    end,
                    step: 4,
                    body: vec![
                        op(
                            RvvKind::Vle,
                            4,
                            Dst::V(0),
                            vec![],
                            Some(MemRef { buf: 0, index: AddrExpr::s(0), stride: 1 }),
                        ),
                        op(RvvKind::Vadd, 4, Dst::V(2), vec![Src::V(0), Src::V(1)], None),
                        op(
                            RvvKind::Vse,
                            4,
                            Dst::None,
                            vec![Src::V(2)],
                            Some(MemRef { buf: 1, index: AddrExpr::s(0), stride: 1 }),
                        ),
                    ],
                },
            ],
            n_vregs: 3,
            n_mregs: 1,
            n_sregs: 1,
        }
    }

    fn inputs() -> HashMap<String, Buffer> {
        let xs: Vec<i32> = (0..32).map(|i| i * 3 - 11).collect();
        [("x".to_string(), Buffer::from_i32s(&xs))].into()
    }

    fn run(prog: &RvvProgram, vlen: u32) -> (Vec<u8>, u64) {
        let cfg = RvvConfig::new(vlen);
        let (out, stats) = Simulator::new(prog, cfg, &inputs()).unwrap().run().unwrap();
        (out.get("y").unwrap().data.clone(), stats.total())
    }

    #[test]
    fn widens_and_stays_bit_identical_with_fewer_insts() {
        let prog = axpy_like(32);
        let wide = widen(&prog, 512, 4).expect("widenable");
        let (ref_data, ref_total) = run(&prog, 512);
        let (data, total) = run(&wide, 512);
        assert_eq!(data, ref_data, "widening changed output bits");
        assert!(total < ref_total, "widening did not reduce dyn insts: {total} vs {ref_total}");
    }

    #[test]
    fn widen_scales_step_splat_and_vls() {
        let wide = widen(&axpy_like(32), 512, 4).unwrap();
        match &wide.body[0] {
            RStmt::Op(i) => assert_eq!(i.vl, 16, "pre-loop splat vl not widened"),
            s => panic!("unexpected stmt {s:?}"),
        }
        match &wide.body[1] {
            RStmt::Loop { step, body, .. } => {
                assert_eq!(*step, 16);
                for s in body {
                    match s {
                        RStmt::Op(i) => assert_eq!(i.vl, 16),
                        s => panic!("unexpected stmt {s:?}"),
                    }
                }
            }
            s => panic!("unexpected stmt {s:?}"),
        }
    }

    #[test]
    fn rejects_when_capacity_trip_or_dependence_fails() {
        // vl 4 * factor 4 * 32 bits = 512 > 128
        assert!(widen(&axpy_like(32), 128, 4).is_err(), "capacity must fail at vlen 128");
        // trip 3 not divisible by 4
        assert!(widen(&axpy_like(12), 512, 4).is_err(), "trip divisibility must fail");

        // loop-carried: v1 updated from v2 each iteration
        let mut carried = axpy_like(32);
        if let RStmt::Loop { body, .. } = &mut carried.body[1] {
            body.push(op(RvvKind::VmvVV, 4, Dst::V(1), vec![Src::V(2)], None));
        }
        assert!(widen(&carried, 512, 4).is_err(), "loop-carried dependence must fail");

        // store stride != 1
        let mut strided = axpy_like(32);
        if let RStmt::Loop { body, .. } = &mut strided.body[1] {
            if let RStmt::Op(i) = &mut body[2] {
                i.mem = Some(MemRef { buf: 1, index: AddrExpr::s(0), stride: 2 });
            }
        }
        assert!(widen(&strided, 512, 4).is_err(), "strided store must fail");
    }

    #[test]
    fn rejects_body_writes_read_outside() {
        let mut leak = axpy_like(32);
        // read v2 (body-written) after the loop
        leak.body.push(op(RvvKind::VmvVV, 4, Dst::V(1), vec![Src::V(2)], None));
        assert!(widen(&leak, 512, 4).is_err(), "outside read of body write must fail");
    }
}
