//! Shared loop-coalescing legality analysis, used by both iteration
//! transforms in the tuner:
//!
//! - [`super::widen`] — multiply each body op's `vl` by `F` at `m1`
//!   (fills the spare lanes of one wide register);
//! - [`super::lmul`] — keep per-register occupancy and move the scaled
//!   `vl` onto an `m2`/`m4` register *group* instead.
//!
//! Both transforms coalesce `F` consecutive iterations of a loop into
//! one, so they share the same soundness argument and the same analysis;
//! the only difference is capacity: widening packs `vl·F` lanes into a
//! single register (`vl · F · SEW ≤ VLEN`), while regrouping grows the
//! register group with the lane count (`vl · F ≤ VLMAX(mF)`, which holds
//! exactly when the original `vl ≤ VLMAX(m1)` did). That difference is
//! the `cap_factor` parameter of [`analyze`].
//!
//! The analysis is deliberately conservative — the tuner treats a refusal
//! as "candidate scored out", never as an error, so it is always safe to
//! say no:
//!
//! - the trip count must be positive, exact (`(end-start) % step == 0`)
//!   and divisible by `F`;
//! - every statement in the body is an unmasked vector op from an
//!   element-wise whitelist (lane `i` depends only on lane `i` of its
//!   sources), or a unit-stride `Vle`/`Vse` whose address advances by
//!   exactly `vl` elements per iteration (`coeff(ivar) * step == vl`),
//!   or a nested constant-bound loop of such ops;
//! - each op's coalesced footprint fits the machine:
//!   `vl * cap_factor * sew.bits() <= VLEN`;
//! - no register written in the body is read before its first write in
//!   the body (no loop-carried dependence) or anywhere outside the loop;
//! - registers read but never written in the body (invariants) must be
//!   defined by a single, program-unique top-level splat (`VmvVX` /
//!   `VfmvVF`), which gets its `vl` scaled too;
//! - no buffer is both loaded and stored in the body, and each stored
//!   buffer has exactly one store op (so per-iteration store footprints
//!   partition and merging iterations cannot reorder overlapping writes).
//!
//! Under those rules the coalesced loop performs exactly the same lane
//! computations and exactly the same memory writes as `F` original
//! iterations, so outputs are bit-identical — the tuner's differential
//! check re-verifies this at runtime anyway.

use std::collections::{HashMap, HashSet};

use crate::rvv::{Dst, RStmt, RvvInst, RvvKind, RvvProgram, Sew, Src};
use crate::sim::AffineAddr;

/// A vector or mask register, for dependence tracking.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Reg {
    V(u32),
    M(u32),
}

/// What the environment knows about the last definition of a vreg:
/// `Some((path, vl, sew))` for a top-level splat, `None` otherwise.
type SplatInfo = Option<(Vec<usize>, u32, Sew)>;

/// The result of a successful analysis: which loops to coalesce and which
/// pre-loop splats must have their `vl` scaled along with them.
#[derive(Default)]
pub struct LoopPlan {
    /// Index paths (through nested `Loop` bodies) of loops to coalesce.
    pub loops: Vec<Vec<usize>>,
    /// Index paths of pre-loop splats whose `vl` must be scaled.
    pub splats: HashSet<Vec<usize>>,
}

/// Find every loop that legally admits coalescing `factor` iterations.
/// `cap_factor` is the per-register footprint growth (see module docs);
/// `Err` with a reason when no loop qualifies.
pub fn analyze(
    prog: &RvvProgram,
    vlen: u32,
    factor: u32,
    cap_factor: u32,
) -> Result<LoopPlan, String> {
    if factor < 2 {
        return Err(format!("factor {factor} must be >= 2"));
    }
    let (greads, gwrites) = global_counts(prog);
    let mut plan = LoopPlan::default();
    let cx = Analysis {
        factor: u64::from(factor),
        vlen: u64::from(vlen),
        cap_factor: u64::from(cap_factor),
        greads,
        gwrites,
    };
    let mut env: HashMap<u32, SplatInfo> = HashMap::new();
    scan(&prog.body, &mut Vec::new(), &cx, &mut env, &mut plan);
    if plan.loops.is_empty() {
        return Err(format!("no loop admits coalescing {factor} iterations"));
    }
    Ok(plan)
}

struct Analysis {
    factor: u64,
    vlen: u64,
    cap_factor: u64,
    greads: HashMap<Reg, usize>,
    gwrites: HashMap<Reg, usize>,
}

/// Registers read by an instruction: vector/mask sources, the mask
/// operand, and the accumulator (destination) of multiply-accumulate ops.
fn inst_reads(inst: &RvvInst, out: &mut Vec<Reg>) {
    for s in &inst.srcs {
        match s {
            Src::V(r) => out.push(Reg::V(*r)),
            Src::M(r) => out.push(Reg::M(*r)),
            _ => {}
        }
    }
    if let Some(m) = inst.mask {
        out.push(Reg::M(m));
    }
    if matches!(
        inst.kind,
        RvvKind::Vmacc
            | RvvKind::Vnmsac
            | RvvKind::Vwmacc
            | RvvKind::Vwmaccu
            | RvvKind::Vfmacc
            | RvvKind::Vfnmacc
            | RvvKind::Vfmsac
            | RvvKind::Vfnmsac
    ) {
        if let Dst::V(r) = inst.dst {
            out.push(Reg::V(r));
        }
    }
}

fn inst_write(inst: &RvvInst) -> Option<Reg> {
    match inst.dst {
        Dst::V(r) => Some(Reg::V(r)),
        Dst::M(r) => Some(Reg::M(r)),
        Dst::None => None,
    }
}

/// Count every register read and write in the whole program, including
/// scalar-fallback blocks (which read vreg args and may write a vreg).
fn global_counts(prog: &RvvProgram) -> (HashMap<Reg, usize>, HashMap<Reg, usize>) {
    let mut reads: HashMap<Reg, usize> = HashMap::new();
    let mut writes: HashMap<Reg, usize> = HashMap::new();
    fn walk(stmts: &[RStmt], reads: &mut HashMap<Reg, usize>, writes: &mut HashMap<Reg, usize>) {
        let mut scratch = Vec::new();
        for s in stmts {
            match s {
                RStmt::Op(inst) => {
                    scratch.clear();
                    inst_reads(inst, &mut scratch);
                    for r in &scratch {
                        *reads.entry(*r).or_insert(0) += 1;
                    }
                    if let Some(r) = inst_write(inst) {
                        *writes.entry(r).or_insert(0) += 1;
                    }
                }
                RStmt::Loop { body, .. } => walk(body, reads, writes),
                RStmt::Scalar(b) => {
                    for a in &b.call.args {
                        if let crate::ir::Arg::V(r) = a {
                            *reads.entry(Reg::V(*r)).or_insert(0) += 1;
                        }
                    }
                    if let Some(r) = b.dst {
                        *writes.entry(Reg::V(r)).or_insert(0) += 1;
                    }
                }
                RStmt::SSet { .. } => {}
            }
        }
    }
    walk(&prog.body, &mut reads, &mut writes);
    (reads, writes)
}

/// Program-order walk: maintain the splat environment, try each loop as
/// a coalescing candidate, and descend into rejected loops looking for
/// legal inner loops (e.g. a channel loop inside spatial loops).
fn scan(
    stmts: &[RStmt],
    path: &mut Vec<usize>,
    cx: &Analysis,
    env: &mut HashMap<u32, SplatInfo>,
    plan: &mut LoopPlan,
) {
    for (i, s) in stmts.iter().enumerate() {
        path.push(i);
        match s {
            RStmt::Op(inst) => {
                if let Dst::V(r) = inst.dst {
                    let splat = matches!(inst.kind, RvvKind::VmvVX | RvvKind::VfmvVF)
                        && path.len() == 1;
                    env.insert(r, splat.then(|| (path.clone(), inst.vl, inst.sew)));
                }
            }
            RStmt::Scalar(b) => {
                if let Some(r) = b.dst {
                    env.insert(r, None);
                }
            }
            RStmt::SSet { .. } => {}
            RStmt::Loop { ivar, start, end, step, body } => {
                match check_loop(*ivar, *start, *end, *step, body, cx, env) {
                    Some(splats) => {
                        plan.loops.push(path.clone());
                        plan.splats.extend(splats);
                    }
                    None => scan(body, path, cx, env, plan),
                }
                // after the loop, any reg its body defines is no longer a
                // known splat for later candidates
                let mut defs = Vec::new();
                collect_vreg_defs(body, &mut defs);
                for r in defs {
                    env.insert(r, None);
                }
            }
        }
        path.pop();
    }
}

fn collect_vreg_defs(stmts: &[RStmt], out: &mut Vec<u32>) {
    for s in stmts {
        match s {
            RStmt::Op(inst) => {
                if let Dst::V(r) = inst.dst {
                    out.push(r);
                }
            }
            RStmt::Loop { body, .. } => collect_vreg_defs(body, out),
            RStmt::Scalar(b) => out.extend(b.dst),
            RStmt::SSet { .. } => {}
        }
    }
}

/// Vector ops whose lane `i` depends only on lane `i` of each source —
/// safe to execute over `F*vl` lanes at once. Widening/narrowing ops,
/// reductions, permutes and strided memory ops are deliberately absent.
fn elementwise(kind: RvvKind) -> bool {
    use RvvKind::*;
    matches!(
        kind,
        Vadd | Vsub | Vrsub | Vmul | Vmulh | Vmulhu | Vmin | Vminu | Vmax | Vmaxu
            | Vsadd | Vsaddu | Vssub | Vssubu | Vand | Vor | Vxor | Vsll | Vsrl | Vsra
            | VmvVV | VmvVX | VfmvVF | Vmerge | Vfmerge
            | Vmseq | Vmsne | Vmsltu | Vmslt | Vmsleu | Vmsle | Vmsgtu | Vmsgt
            | Vmfeq | Vmfne | Vmflt | Vmfle | Vmfgt | Vmfge
            | Vmand | Vmor | Vmxor | Vmnand
            | Vfadd | Vfsub | Vfrsub | Vfmul | Vfdiv | Vfrdiv
            | Vfmacc | Vfnmacc | Vfmsac | Vfnmsac | Vmacc | Vnmsac
            | Vfmin | Vfmax | Vfsqrt | Vfrec7 | Vfrsqrt7
            | Vfsgnj | Vfsgnjn | Vfsgnjx
            | VfcvtXF | VfcvtRtzXF | VfcvtFX | VfcvtFXu | VfcvtRtzXuF
    )
}

/// Per-candidate mutable state threaded through the body walk.
struct BodyCheck<'a> {
    ivar: u32,
    step: i64,
    cx: &'a Analysis,
    env: &'a HashMap<u32, SplatInfo>,
    body_writes: HashSet<Reg>,
    written: HashSet<Reg>,
    body_reads: HashMap<Reg, usize>,
    loaded_bufs: HashSet<u32>,
    stored_bufs: HashSet<u32>,
    splats: HashSet<Vec<usize>>,
}

/// Check one loop for coalescing legality. `Some(splat paths)` when legal.
fn check_loop(
    ivar: u32,
    start: i64,
    end: i64,
    step: i64,
    body: &[RStmt],
    cx: &Analysis,
    env: &HashMap<u32, SplatInfo>,
) -> Option<HashSet<Vec<usize>>> {
    if step <= 0 || end <= start || (end - start) % step != 0 {
        return None;
    }
    let trip = (end - start) / step;
    if trip <= 0 || (trip as u64) % cx.factor != 0 {
        return None;
    }
    let mut body_writes = HashSet::new();
    if !precollect_writes(body, &mut body_writes) {
        return None;
    }
    let mut chk = BodyCheck {
        ivar,
        step,
        cx,
        env,
        body_writes,
        written: HashSet::new(),
        body_reads: HashMap::new(),
        loaded_bufs: HashSet::new(),
        stored_bufs: HashSet::new(),
        splats: HashSet::new(),
    };
    if !walk_body(body, &mut chk) {
        return None;
    }
    // no buffer may be both loaded and stored inside the body
    if chk.loaded_bufs.intersection(&chk.stored_bufs).next().is_some() {
        return None;
    }
    // nothing written in the body may be read anywhere outside it
    for r in &chk.body_writes {
        let total = chk.cx.greads.get(r).copied().unwrap_or(0);
        let inside = chk.body_reads.get(r).copied().unwrap_or(0);
        if total != inside {
            return None;
        }
    }
    Some(chk.splats)
}

/// Collect every register the body writes; `false` on a scalar
/// statement (SSet/Scalar), which disqualifies the loop outright.
fn precollect_writes(stmts: &[RStmt], out: &mut HashSet<Reg>) -> bool {
    for s in stmts {
        match s {
            RStmt::Op(inst) => {
                if let Some(r) = inst_write(inst) {
                    out.insert(r);
                }
            }
            RStmt::Loop { body, .. } => {
                if !precollect_writes(body, out) {
                    return false;
                }
            }
            RStmt::SSet { .. } | RStmt::Scalar(_) => return false,
        }
    }
    true
}

fn walk_body(stmts: &[RStmt], chk: &mut BodyCheck<'_>) -> bool {
    for s in stmts {
        match s {
            RStmt::SSet { .. } | RStmt::Scalar(_) => return false,
            RStmt::Loop { ivar, body, .. } => {
                // nested constant-bound loops are fine as long as they do
                // not rebind the candidate induction variable
                if *ivar == chk.ivar || !walk_body(body, chk) {
                    return false;
                }
            }
            RStmt::Op(inst) => {
                if !check_op(inst, chk) {
                    return false;
                }
            }
        }
    }
    true
}

fn check_op(inst: &RvvInst, chk: &mut BodyCheck<'_>) -> bool {
    if inst.mask.is_some() {
        return false;
    }
    // the coalesced per-register footprint must fit the machine
    if u64::from(inst.vl) * chk.cx.cap_factor * u64::from(inst.sew.bits()) > chk.cx.vlen {
        return false;
    }
    match inst.kind {
        RvvKind::Vle | RvvKind::Vse => {
            let Some(mref) = &inst.mem else { return false };
            if mref.stride != 1 {
                return false;
            }
            let addr = AffineAddr::compile(&mref.index, 1);
            let coeff = addr
                .terms
                .iter()
                .find(|(r, _)| *r == chk.ivar)
                .map(|(_, c)| *c)
                .unwrap_or(0);
            // the access must advance by exactly vl elements per iteration
            // so that F coalesced iterations cover one contiguous run
            if coeff * chk.step != i64::from(inst.vl) {
                return false;
            }
            if inst.kind == RvvKind::Vle {
                chk.loaded_bufs.insert(mref.buf);
            } else if !chk.stored_bufs.insert(mref.buf) {
                return false; // second store op to the same buffer
            }
        }
        k if elementwise(k) => {}
        _ => return false,
    }
    // the induction variable may not feed a vector op as a scalar operand
    // (its value differs between the coalesced iterations)
    if inst.srcs.iter().any(|s| matches!(s, Src::SReg(r) if *r == chk.ivar)) {
        return false;
    }
    let mut reads = Vec::new();
    inst_reads(inst, &mut reads);
    for r in &reads {
        *chk.body_reads.entry(*r).or_insert(0) += 1;
        if chk.written.contains(r) {
            continue;
        }
        if chk.body_writes.contains(r) {
            return false; // read before first body write: loop-carried
        }
        // loop-invariant read: only a program-unique top-level splat
        // qualifies (its vl gets scaled so every lane sees the value)
        match r {
            Reg::M(_) => return false,
            Reg::V(v) => match chk.env.get(v) {
                Some(Some((path, svl, ssew)))
                    if chk.cx.gwrites.get(r).copied().unwrap_or(0) == 1
                        && u64::from(*svl) * chk.cx.cap_factor * u64::from(ssew.bits())
                            <= chk.cx.vlen =>
                {
                    chk.splats.insert(path.clone());
                }
                _ => return false,
            },
        }
    }
    if let Some(r) = inst_write(inst) {
        chk.written.insert(r);
    }
    true
}

/// Navigate to the statement at an index path produced by [`analyze`].
pub fn stmt_at_mut<'a>(body: &'a mut [RStmt], path: &[usize]) -> Option<&'a mut RStmt> {
    let (first, rest) = path.split_first()?;
    let s = body.get_mut(*first)?;
    if rest.is_empty() {
        return Some(s);
    }
    match s {
        RStmt::Loop { body, .. } => stmt_at_mut(body, rest),
        _ => None,
    }
}

/// Multiply every vector op's `vl` in a statement subtree by `factor`.
pub fn scale_vls(stmts: &mut [RStmt], factor: u32) {
    for s in stmts {
        match s {
            RStmt::Op(inst) => inst.vl *= factor,
            RStmt::Loop { body, .. } => scale_vls(body, factor),
            _ => {}
        }
    }
}
