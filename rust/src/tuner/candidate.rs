//! The autotuner's candidate space: alternative ways to lower one IR
//! program at a given (mode, vlen) point.
//!
//! Each [`Candidate`] names a complete lowering strategy with a stable
//! string id (what the tuning database persists):
//!
//! - `static` — exactly what [`Translator::new`] would produce; always
//!   enumerated and always the baseline other candidates must beat.
//! - `widen:F` — the static lowering post-processed by
//!   [`crate::tuner::widen::widen`], coalescing `F` loop iterations into
//!   one when the target VLEN has spare lanes.
//! - `lmul:F` — the static lowering re-emitted at register grouping
//!   `m2`/`m4` by [`crate::tuner::lmul::regroup`]: same iteration
//!   coalescing, but the scaled `vl` lands on a register *group* instead
//!   of the spare lanes of one register, so it applies even when the
//!   NEON shapes already fill the machine.
//! - `force-baseline:<category>` — lower intrinsics of one category
//!   through the generic SIMDe path instead of the customized RVV rule
//!   (occasionally the "clever" combo sequence loses to the plain one).
//!
//! [`lower_with`] materialises a candidate into an [`RvvProgram`]; a
//! candidate that cannot apply (e.g. no widenable loop) returns `Err`,
//! which the search records as a scored-out candidate rather than a
//! failure.

use anyhow::{anyhow, Result};

use crate::ir::Program;
use crate::neon::ops::Category;
use crate::rvv::machine::RvvConfig;
use crate::rvv::RvvProgram;
use crate::simde::registry::program_categories;
use crate::simde::{Mode, TranslationReport, Translator};
use crate::tuner::{lmul, widen};

/// One point in the lowering search space.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Candidate {
    /// The unmodified static-rule lowering.
    Static,
    /// Loop-coalesce the static lowering by this factor.
    Widen(u32),
    /// Re-emit the static lowering's legal loops at register grouping
    /// `m2`/`m4` (factor 2 or 4), dividing the trip count.
    Lmul(u32),
    /// Degrade one intrinsic category to the generic SIMDe path.
    ForceBaseline(Category),
}

/// All twelve categories with their stable kebab-case database names.
const CATEGORY_NAMES: &[(Category, &str)] = &[
    (Category::Memory, "memory"),
    (Category::Arith, "arith"),
    (Category::Pairwise, "pairwise"),
    (Category::Saturating, "saturating"),
    (Category::WidenNarrow, "widen-narrow"),
    (Category::Compare, "compare"),
    (Category::Bitwise, "bitwise"),
    (Category::Shift, "shift"),
    (Category::Permute, "permute"),
    (Category::Convert, "convert"),
    (Category::FloatEst, "float-est"),
    (Category::BitManip, "bit-manip"),
];

fn category_name(cat: Category) -> &'static str {
    CATEGORY_NAMES
        .iter()
        .find(|(c, _)| *c == cat)
        .map(|(_, n)| *n)
        .unwrap_or("unknown")
}

fn category_parse(name: &str) -> Option<Category> {
    CATEGORY_NAMES.iter().find(|(_, n)| *n == name).map(|(c, _)| *c)
}

impl Candidate {
    /// Stable string id persisted in the tuning database.
    pub fn id(&self) -> String {
        match self {
            Candidate::Static => "static".to_string(),
            Candidate::Widen(f) => format!("widen:{f}"),
            Candidate::Lmul(f) => format!("lmul:{f}"),
            Candidate::ForceBaseline(cat) => format!("force-baseline:{}", category_name(*cat)),
        }
    }

    /// Inverse of [`Candidate::id`].
    pub fn parse(id: &str) -> Option<Candidate> {
        if id == "static" {
            return Some(Candidate::Static);
        }
        if let Some(f) = id.strip_prefix("widen:") {
            return f.parse::<u32>().ok().filter(|f| *f >= 2).map(Candidate::Widen);
        }
        if let Some(f) = id.strip_prefix("lmul:") {
            return f.parse::<u32>().ok().filter(|f| matches!(f, 2 | 4)).map(Candidate::Lmul);
        }
        if let Some(cat) = id.strip_prefix("force-baseline:") {
            return category_parse(cat).map(Candidate::ForceBaseline);
        }
        None
    }

    pub fn is_static(&self) -> bool {
        matches!(self, Candidate::Static)
    }
}

/// Enumerate the candidate set for one program under one mode, largest
/// expected win first. `Static` is always first and always kept; a
/// `max_candidates` budget truncates the tail.
pub fn enumerate(prog: &Program, mode: Mode, max_candidates: usize) -> Vec<Candidate> {
    let mut out = vec![Candidate::Static];
    if mode == Mode::RvvCustom {
        for f in [2u32, 4, 8] {
            out.push(Candidate::Widen(f));
        }
        for f in [2u32, 4] {
            out.push(Candidate::Lmul(f));
        }
        for cat in program_categories(prog) {
            out.push(Candidate::ForceBaseline(cat));
        }
    }
    out.truncate(max_candidates.max(1));
    out
}

/// Materialise one candidate lowering. Builds a plain translator
/// internally (never a tuning-aware one), so the tuned-override hook in
/// [`Translator::translate`] cannot recurse through here.
pub fn lower_with(
    prog: &Program,
    mode: Mode,
    cfg: RvvConfig,
    cand: &Candidate,
) -> Result<(RvvProgram, TranslationReport)> {
    match cand {
        Candidate::Static => Translator::new(mode, cfg).translate(prog),
        Candidate::ForceBaseline(cat) => {
            Translator::new(mode, cfg).with_forced_baseline(vec![*cat]).translate(prog)
        }
        Candidate::Widen(f) => {
            let (rp, report) = Translator::new(mode, cfg).translate(prog)?;
            let wide = widen::widen(&rp, cfg.vlen, *f)
                .map_err(|e| anyhow!("widen:{f}: {e}"))?;
            Ok((wide, report))
        }
        Candidate::Lmul(f) => {
            let (rp, report) = Translator::new(mode, cfg).translate(prog)?;
            let grouped = lmul::regroup(&rp, cfg.vlen, *f)
                .map_err(|e| anyhow!("lmul:{f}: {e}"))?;
            Ok((grouped, report))
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn id_parse_round_trips() {
        let mut cands = vec![
            Candidate::Static,
            Candidate::Widen(2),
            Candidate::Widen(8),
            Candidate::Lmul(2),
            Candidate::Lmul(4),
        ];
        for (cat, _) in CATEGORY_NAMES {
            cands.push(Candidate::ForceBaseline(*cat));
        }
        for c in cands {
            assert_eq!(Candidate::parse(&c.id()), Some(c.clone()), "round trip for {c:?}");
        }
        assert_eq!(Candidate::parse("widen:1"), None);
        assert_eq!(Candidate::parse("widen:x"), None);
        assert_eq!(Candidate::parse("lmul:1"), None);
        assert_eq!(Candidate::parse("lmul:8"), None);
        assert_eq!(Candidate::parse("lmul:x"), None);
        assert_eq!(Candidate::parse("force-baseline:nope"), None);
        assert_eq!(Candidate::parse(""), None);
    }

    #[test]
    fn enumerate_is_static_first_and_budgeted() {
        let case = crate::kernels::by_name("vrelu").unwrap();
        let all = enumerate(&case.prog, Mode::RvvCustom, 64);
        assert_eq!(all[0], Candidate::Static);
        assert!(all.contains(&Candidate::Widen(4)), "widen candidates missing: {all:?}");
        assert!(all.contains(&Candidate::Lmul(2)), "lmul candidates missing: {all:?}");
        assert!(all.contains(&Candidate::Lmul(4)), "lmul candidates missing: {all:?}");
        assert!(
            all.iter().any(|c| matches!(c, Candidate::ForceBaseline(_))),
            "force-baseline candidates missing: {all:?}"
        );
        let tight = enumerate(&case.prog, Mode::RvvCustom, 2);
        assert_eq!(tight.len(), 2);
        assert_eq!(tight[0], Candidate::Static);
        // baseline mode has nothing to vary
        assert_eq!(enumerate(&case.prog, Mode::Baseline, 64), vec![Candidate::Static]);
    }

    #[test]
    fn lower_with_static_matches_translator() {
        let case = crate::kernels::by_name("vrelu").unwrap();
        let cfg = RvvConfig::new(512);
        let (a, _) = Translator::new(Mode::RvvCustom, cfg).translate(&case.prog).unwrap();
        let (b, _) = lower_with(&case.prog, Mode::RvvCustom, cfg, &Candidate::Static).unwrap();
        assert_eq!(a.static_ops(), b.static_ops());
    }
}
