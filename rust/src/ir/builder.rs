//! Fluent builder for IR programs — the "C file" authoring surface the
//! kernel suite uses.

use super::program::{AddrExpr, Arg, BufDecl, BufKind, NeonCall, Program, Stmt};
use crate::neon::elem::Elem;
use crate::neon::ops::{Family, NeonOp};

pub struct ProgramBuilder {
    name: String,
    bufs: Vec<BufDecl>,
    frames: Vec<Vec<Stmt>>,
    next_vreg: u32,
    next_sreg: u32,
}

impl ProgramBuilder {
    pub fn new(name: &str) -> ProgramBuilder {
        ProgramBuilder {
            name: name.to_string(),
            bufs: Vec::new(),
            frames: vec![Vec::new()],
            next_vreg: 0,
            next_sreg: 0,
        }
    }

    // -- buffers -------------------------------------------------------------

    pub fn input(&mut self, name: &str, elem: Elem, len: usize) -> u32 {
        self.add_buf(name, elem, len, BufKind::Input)
    }

    pub fn output(&mut self, name: &str, elem: Elem, len: usize) -> u32 {
        self.add_buf(name, elem, len, BufKind::Output)
    }

    pub fn scratch(&mut self, name: &str, elem: Elem, len: usize) -> u32 {
        self.add_buf(name, elem, len, BufKind::Scratch)
    }

    fn add_buf(&mut self, name: &str, elem: Elem, len: usize, kind: BufKind) -> u32 {
        self.bufs.push(BufDecl { name: name.to_string(), elem, len, kind });
        (self.bufs.len() - 1) as u32
    }

    // -- registers -----------------------------------------------------------

    pub fn fresh_vreg(&mut self) -> u32 {
        let r = self.next_vreg;
        self.next_vreg += 1;
        r
    }

    pub fn fresh_sreg(&mut self) -> u32 {
        let r = self.next_sreg;
        self.next_sreg += 1;
        r
    }

    // -- statements ----------------------------------------------------------

    fn push(&mut self, s: Stmt) {
        self.frames.last_mut().unwrap().push(s);
    }

    /// Emit a vector-producing intrinsic, returning the destination vreg.
    pub fn vop(&mut self, family: Family, elem: Elem, q: bool, args: Vec<Arg>) -> u32 {
        let dst = self.fresh_vreg();
        self.vop_into(dst, family, elem, q, args);
        dst
    }

    /// Emit a vector-producing intrinsic into an existing vreg (loop-carried
    /// accumulators).
    pub fn vop_into(&mut self, dst: u32, family: Family, elem: Elem, q: bool, args: Vec<Arg>) {
        let op = NeonOp::new(family, elem, q);
        debug_assert!(op.is_valid(), "invalid op {}", op.name());
        debug_assert!(op.sig().ret.is_some(), "{} returns void", op.name());
        self.next_vreg = self.next_vreg.max(dst + 1);
        self.push(Stmt::VOp { dst, call: NeonCall { op, args } });
    }

    /// Emit a void intrinsic (store).
    pub fn vstore(&mut self, family: Family, elem: Elem, q: bool, args: Vec<Arg>) {
        let op = NeonOp::new(family, elem, q);
        debug_assert!(op.is_valid(), "invalid op {}", op.name());
        debug_assert!(op.sig().ret.is_none(), "{} returns a value", op.name());
        self.push(Stmt::VStore { call: NeonCall { op, args } });
    }

    /// Set a scalar register to an affine expression.
    pub fn sset(&mut self, dst: u32, expr: AddrExpr) {
        self.next_sreg = self.next_sreg.max(dst + 1);
        self.push(Stmt::SSet { dst, expr });
    }

    /// Structured counted loop; the closure receives the induction
    /// variable's scalar register.
    pub fn loop_(&mut self, start: i64, end: i64, step: i64, f: impl FnOnce(&mut Self, u32)) {
        assert!(step > 0 && end >= start, "bad loop bounds {start}..{end} step {step}");
        let ivar = self.fresh_sreg();
        self.frames.push(Vec::new());
        f(self, ivar);
        let body = self.frames.pop().unwrap();
        self.push(Stmt::Loop { ivar, start, end, step, body });
    }

    pub fn finish(mut self) -> Program {
        assert_eq!(self.frames.len(), 1, "unclosed loop frame");
        Program {
            name: self.name,
            bufs: self.bufs,
            body: self.frames.pop().unwrap(),
            n_vregs: self.next_vreg as usize,
            n_sregs: self.next_sreg as usize,
        }
    }
}
