//! IR data structures: buffers, statements, affine address expressions.

use crate::neon::elem::Elem;
use crate::neon::ops::NeonOp;

/// Buffer role in a program.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BufKind {
    Input,
    Output,
    /// Read-write scratch initialised to zero (e.g. accumulator spill).
    Scratch,
}

/// A named memory buffer of `len` elements of type `elem`.
#[derive(Debug, Clone)]
pub struct BufDecl {
    pub name: String,
    pub elem: Elem,
    pub len: usize,
    pub kind: BufKind,
}

/// Affine integer expression over loop variables / scalar registers.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AddrExpr {
    Const(i64),
    SReg(u32),
    Add(Box<AddrExpr>, Box<AddrExpr>),
    Mul(Box<AddrExpr>, i64),
}

impl AddrExpr {
    pub fn k(v: i64) -> AddrExpr {
        AddrExpr::Const(v)
    }

    pub fn s(r: u32) -> AddrExpr {
        AddrExpr::SReg(r)
    }

    pub fn add(self, rhs: AddrExpr) -> AddrExpr {
        AddrExpr::Add(Box::new(self), Box::new(rhs))
    }

    pub fn addk(self, k: i64) -> AddrExpr {
        self.add(AddrExpr::Const(k))
    }

    pub fn mul(self, k: i64) -> AddrExpr {
        AddrExpr::Mul(Box::new(self), k)
    }

    /// Evaluate given scalar register values.
    pub fn eval(&self, sregs: &[i64]) -> i64 {
        match self {
            AddrExpr::Const(v) => *v,
            AddrExpr::SReg(r) => sregs[*r as usize],
            AddrExpr::Add(a, b) => a.eval(sregs) + b.eval(sregs),
            AddrExpr::Mul(a, k) => a.eval(sregs) * k,
        }
    }

    /// Number of scalar ALU ops this expression costs when computed naively
    /// (used by the simulator's address-arithmetic accounting; compilers
    /// fold most of this into addressing modes, counted the same for both
    /// translation modes).
    pub fn op_count(&self) -> u64 {
        match self {
            AddrExpr::Const(_) | AddrExpr::SReg(_) => 0,
            AddrExpr::Add(a, b) => 1 + a.op_count() + b.op_count(),
            AddrExpr::Mul(a, _) => 1 + a.op_count(),
        }
    }
}

/// One NEON intrinsic invocation.
#[derive(Debug, Clone)]
pub struct NeonCall {
    pub op: NeonOp,
    pub args: Vec<Arg>,
}

/// Argument of an intrinsic call in the IR.
#[derive(Debug, Clone)]
pub enum Arg {
    /// Vector register.
    V(u32),
    /// Scalar register (for `vdup_n` of loop-derived ints).
    S(u32),
    /// Immediate (lane index, shift amount).
    Imm(i64),
    /// Float immediate (vdup_n of float constants).
    ImmF(f64),
    /// Memory operand: `&buf[index]` in *elements* of the buffer type.
    Mem { buf: u32, index: AddrExpr },
}

impl Arg {
    pub fn mem(buf: u32, index: AddrExpr) -> Arg {
        Arg::Mem { buf, index }
    }
}

/// Program statement.
#[derive(Debug, Clone)]
pub enum Stmt {
    /// `v<dst> = intrinsic(args)`.
    VOp { dst: u32, call: NeonCall },
    /// Void intrinsic (stores).
    VStore { call: NeonCall },
    /// `s<dst> = expr` (scalar/address computation).
    SSet { dst: u32, expr: AddrExpr },
    /// `for ivar in (start..end).step_by(step) { body }` — `ivar` is a
    /// scalar register holding the induction variable.
    Loop {
        ivar: u32,
        start: i64,
        end: i64,
        step: i64,
        body: Vec<Stmt>,
    },
}

/// A complete kernel program.
#[derive(Debug, Clone)]
pub struct Program {
    pub name: String,
    pub bufs: Vec<BufDecl>,
    pub body: Vec<Stmt>,
    pub n_vregs: usize,
    pub n_sregs: usize,
}

/// Static structure counts (for reports and tests).
#[derive(Debug, Default, Clone, Copy, PartialEq, Eq)]
pub struct StaticCounts {
    pub intrinsic_calls: usize,
    pub loops: usize,
    pub sset: usize,
}

impl Program {
    pub fn buf(&self, name: &str) -> Option<(u32, &BufDecl)> {
        self.bufs
            .iter()
            .enumerate()
            .find(|(_, b)| b.name == name)
            .map(|(i, b)| (i as u32, b))
    }

    pub fn count_static(&self) -> StaticCounts {
        fn walk(stmts: &[Stmt], c: &mut StaticCounts) {
            for s in stmts {
                match s {
                    Stmt::VOp { .. } | Stmt::VStore { .. } => c.intrinsic_calls += 1,
                    Stmt::SSet { .. } => c.sset += 1,
                    Stmt::Loop { body, .. } => {
                        c.loops += 1;
                        walk(body, c);
                    }
                }
            }
        }
        let mut c = StaticCounts::default();
        walk(&self.body, &mut c);
        c
    }

    /// Structural shape fingerprint: a stable 64-bit FNV-1a hash over the
    /// program's name, buffer declarations, register counts and the full
    /// statement tree (ops, operands, address expressions, loop bounds).
    ///
    /// Two programs share a fingerprint iff they are structurally
    /// identical, so `(kernel, mode, vlen, fingerprint)` is a sound
    /// translation-cache key even for custom-shaped sweeps, and a tuning
    /// database entry can detect that the kernel it was tuned for has
    /// since changed shape. Buffer *contents* are deliberately excluded —
    /// translation depends only on shape.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.str(&self.name);
        h.u64(self.bufs.len() as u64);
        for b in &self.bufs {
            h.str(&b.name);
            h.str(&format!("{:?}", b.elem));
            h.u64(b.len as u64);
            h.u64(match b.kind {
                BufKind::Input => 0,
                BufKind::Output => 1,
                BufKind::Scratch => 2,
            });
        }
        h.u64(self.n_vregs as u64);
        h.u64(self.n_sregs as u64);
        fn addr(h: &mut Fnv, e: &AddrExpr) {
            match e {
                AddrExpr::Const(v) => {
                    h.u64(0x10);
                    h.i64(*v);
                }
                AddrExpr::SReg(r) => {
                    h.u64(0x11);
                    h.u64(*r as u64);
                }
                AddrExpr::Add(a, b) => {
                    h.u64(0x12);
                    addr(h, a);
                    addr(h, b);
                }
                AddrExpr::Mul(a, k) => {
                    h.u64(0x13);
                    addr(h, a);
                    h.i64(*k);
                }
            }
        }
        fn call(h: &mut Fnv, c: &NeonCall) {
            h.str(c.op.name());
            h.u64(c.args.len() as u64);
            for a in &c.args {
                match a {
                    Arg::V(r) => {
                        h.u64(0x20);
                        h.u64(*r as u64);
                    }
                    Arg::S(r) => {
                        h.u64(0x21);
                        h.u64(*r as u64);
                    }
                    Arg::Imm(v) => {
                        h.u64(0x22);
                        h.i64(*v);
                    }
                    Arg::ImmF(v) => {
                        h.u64(0x23);
                        h.u64(v.to_bits());
                    }
                    Arg::Mem { buf, index } => {
                        h.u64(0x24);
                        h.u64(*buf as u64);
                        addr(h, index);
                    }
                }
            }
        }
        fn walk(h: &mut Fnv, stmts: &[Stmt]) {
            h.u64(stmts.len() as u64);
            for s in stmts {
                match s {
                    Stmt::VOp { dst, call: c } => {
                        h.u64(0x30);
                        h.u64(*dst as u64);
                        call(h, c);
                    }
                    Stmt::VStore { call: c } => {
                        h.u64(0x31);
                        call(h, c);
                    }
                    Stmt::SSet { dst, expr } => {
                        h.u64(0x32);
                        h.u64(*dst as u64);
                        addr(h, expr);
                    }
                    Stmt::Loop { ivar, start, end, step, body } => {
                        h.u64(0x33);
                        h.u64(*ivar as u64);
                        h.i64(*start);
                        h.i64(*end);
                        h.i64(*step);
                        walk(h, body);
                    }
                }
            }
        }
        walk(&mut h, &self.body);
        h.0
    }

    /// Every distinct NEON op used by the program (the "migration surface"
    /// a SIMDe port must cover).
    pub fn used_ops(&self) -> Vec<NeonOp> {
        fn walk(stmts: &[Stmt], out: &mut Vec<NeonOp>) {
            for s in stmts {
                match s {
                    Stmt::VOp { call, .. } | Stmt::VStore { call } => out.push(call.op),
                    Stmt::Loop { body, .. } => walk(body, out),
                    Stmt::SSet { .. } => {}
                }
            }
        }
        let mut out = Vec::new();
        walk(&self.body, &mut out);
        out.sort_by_key(|o| o.name());
        out.dedup();
        out
    }
}

/// Minimal FNV-1a 64-bit hasher (no std `Hasher` ceremony: the digest
/// must be stable across runs and platforms, which `DefaultHasher` does
/// not guarantee).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn byte(&mut self, b: u8) {
        self.0 ^= b as u64;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    fn u64(&mut self, v: u64) {
        for b in v.to_le_bytes() {
            self.byte(b);
        }
    }

    fn i64(&mut self, v: i64) {
        self.u64(v as u64);
    }

    fn str(&mut self, s: &str) {
        self.u64(s.len() as u64);
        for b in s.bytes() {
            self.byte(b);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn addr_expr_eval() {
        // i*16 + j*4 + 3
        let e = AddrExpr::s(0).mul(16).add(AddrExpr::s(1).mul(4)).addk(3);
        assert_eq!(e.eval(&[2, 1]), 39);
        assert_eq!(e.eval(&[0, 0]), 3);
        assert!(e.op_count() >= 3);
    }

    #[test]
    fn fingerprint_is_stable_and_shape_sensitive() {
        let mk = |len: usize| Program {
            name: "fp".to_string(),
            bufs: vec![BufDecl {
                name: "x".to_string(),
                elem: Elem::F32,
                len,
                kind: BufKind::Input,
            }],
            body: vec![Stmt::Loop { ivar: 0, start: 0, end: len as i64, step: 4, body: vec![] }],
            n_vregs: 2,
            n_sregs: 1,
        };
        let a = mk(16);
        // deterministic across calls
        assert_eq!(a.fingerprint(), a.fingerprint());
        // identical shape => identical digest
        assert_eq!(a.fingerprint(), mk(16).fingerprint());
        // different shape => different digest
        assert_ne!(a.fingerprint(), mk(32).fingerprint());
    }
}
