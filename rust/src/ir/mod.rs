//! Portable intrinsic-program IR.
//!
//! Kernels (the XNNPACK-like suite) are written once as programs over NEON
//! intrinsics with structured loops and affine addressing — the IR analogue
//! of a C source file that includes `<arm_neon.h>`. The same program is
//! (a) interpreted directly under NEON semantics (golden reference), and
//! (b) translated by the SIMDe engine into an RVV program and executed on
//! the Spike-like simulator.

mod builder;
mod program;

pub use builder::ProgramBuilder;
pub use program::{
    AddrExpr, Arg, BufDecl, BufKind, NeonCall, Program, Stmt,
};

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::elem::Elem;
    use crate::neon::ops::Family;

    #[test]
    fn build_vector_add_listing9() {
        // the paper's Listing 9: 4-wide s32 vector add
        let mut b = ProgramBuilder::new("vadd_listing9");
        let a_buf = b.input("A", Elem::I32, 4);
        let b_buf = b.input("B", Elem::I32, 4);
        let o_buf = b.output("O", Elem::I32, 4);
        let va = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(a_buf, AddrExpr::k(0))]);
        let vb = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(b_buf, AddrExpr::k(0))]);
        let vc = b.vop(Family::Add, Elem::I32, true, vec![Arg::V(va), Arg::V(vb)]);
        b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o_buf, AddrExpr::k(0)), Arg::V(vc)]);
        let p = b.finish();
        assert_eq!(p.bufs.len(), 3);
        assert_eq!(p.body.len(), 4);
        assert!(p.n_vregs >= 3);
    }

    #[test]
    fn loops_nest() {
        let mut b = ProgramBuilder::new("nested");
        let buf = b.output("O", Elem::F32, 64);
        let zero = b.vop(Family::DupN, Elem::F32, true, vec![Arg::Imm(0)]);
        b.loop_(0, 4, 1, |b, i| {
            b.loop_(0, 4, 1, |b, j| {
                let idx = AddrExpr::SReg(i).mul(16).add(AddrExpr::SReg(j).mul(4));
                b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(buf, idx), Arg::V(zero)]);
            });
        });
        let p = b.finish();
        assert_eq!(p.body.len(), 2); // DupN + outer loop
        let counts = p.count_static();
        assert_eq!(counts.loops, 2);
        assert_eq!(counts.intrinsic_calls, 2);
    }
}
