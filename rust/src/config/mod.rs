//! Configuration system: a minimal TOML-subset parser (no serde offline)
//! plus the typed settings the pipeline consumes. Files look like:
//!
//! ```toml
//! [sim]
//! vlen = 128
//! zvfh = true
//!
//! [run]
//! threads = 4
//! artifacts = "artifacts"
//! ```

use std::collections::BTreeMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::rvv::machine::RvvConfig;

/// A parsed config value.
#[derive(Debug, Clone, PartialEq)]
pub enum Value {
    Int(i64),
    Bool(bool),
    Str(String),
}

impl Value {
    pub fn as_int(&self) -> Option<i64> {
        match self {
            Value::Int(i) => Some(*i),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Value::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Value::Str(s) => Some(s),
            _ => None,
        }
    }
}

/// Parsed file: `section.key -> value`.
#[derive(Debug, Default, Clone)]
pub struct Config {
    pub values: BTreeMap<String, Value>,
}

impl Config {
    pub fn parse(text: &str) -> Result<Config> {
        let mut values = BTreeMap::new();
        let mut section = String::new();
        for (no, raw) in text.lines().enumerate() {
            let line = raw.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            if let Some(name) = line.strip_prefix('[') {
                let name = name.strip_suffix(']').with_context(|| format!("line {}: bad section", no + 1))?;
                section = name.trim().to_string();
                continue;
            }
            let (k, v) = line
                .split_once('=')
                .with_context(|| format!("line {}: expected key = value", no + 1))?;
            let key = if section.is_empty() {
                k.trim().to_string()
            } else {
                format!("{section}.{}", k.trim())
            };
            let v = v.trim();
            let val = if v == "true" {
                Value::Bool(true)
            } else if v == "false" {
                Value::Bool(false)
            } else if let Ok(i) = v.parse::<i64>() {
                Value::Int(i)
            } else {
                let s = v.trim_matches('"');
                if s.len() + 2 != v.len() && v.starts_with('"') {
                    bail!("line {}: unterminated string", no + 1);
                }
                Value::Str(s.to_string())
            };
            values.insert(key, val);
        }
        Ok(Config { values })
    }

    pub fn load(path: &Path) -> Result<Config> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path:?}"))?;
        Config::parse(&text)
    }

    pub fn get(&self, key: &str) -> Option<&Value> {
        self.values.get(key)
    }
}

/// Typed settings for the whole pipeline.
#[derive(Debug, Clone)]
pub struct Settings {
    pub vlen: u32,
    pub zvfh: bool,
    pub threads: usize,
    pub artifacts: String,
}

impl Default for Settings {
    fn default() -> Settings {
        Settings { vlen: 128, zvfh: true, threads: default_threads(), artifacts: "artifacts".into() }
    }
}

fn default_threads() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(4)
}

impl Settings {
    pub fn from_config(cfg: &Config) -> Result<Settings> {
        let mut s = Settings::default();
        if let Some(v) = cfg.get("sim.vlen") {
            let v = v.as_int().context("sim.vlen must be an integer")?;
            if !(32..=65536).contains(&v) || (v as u64).count_ones() != 1 {
                bail!("sim.vlen must be a power of two in [32, 65536], got {v}");
            }
            s.vlen = v as u32;
        }
        if let Some(v) = cfg.get("sim.zvfh") {
            s.zvfh = v.as_bool().context("sim.zvfh must be a bool")?;
        }
        if let Some(v) = cfg.get("run.threads") {
            s.threads = v.as_int().context("run.threads must be an integer")?.max(1) as usize;
        }
        if let Some(v) = cfg.get("run.artifacts") {
            s.artifacts = v.as_str().context("run.artifacts must be a string")?.to_string();
        }
        Ok(s)
    }

    pub fn rvv(&self) -> RvvConfig {
        RvvConfig { vlen: self.vlen, zvfh: self.zvfh }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_sections_and_types() {
        let c = Config::parse(
            "# comment\n[sim]\nvlen = 256\nzvfh = false\n\n[run]\nthreads = 8\nartifacts = \"art\"\n",
        )
        .unwrap();
        assert_eq!(c.get("sim.vlen"), Some(&Value::Int(256)));
        assert_eq!(c.get("sim.zvfh"), Some(&Value::Bool(false)));
        assert_eq!(c.get("run.artifacts"), Some(&Value::Str("art".into())));
        let s = Settings::from_config(&c).unwrap();
        assert_eq!(s.vlen, 256);
        assert!(!s.zvfh);
        assert_eq!(s.threads, 8);
    }

    #[test]
    fn rejects_bad_vlen() {
        let c = Config::parse("[sim]\nvlen = 100\n").unwrap();
        assert!(Settings::from_config(&c).is_err());
        let c = Config::parse("[sim]\nvlen = 7\n").unwrap();
        assert!(Settings::from_config(&c).is_err());
    }

    #[test]
    fn rejects_malformed_lines() {
        assert!(Config::parse("novalue\n").is_err());
        assert!(Config::parse("[unclosed\n").is_err());
    }

    #[test]
    fn defaults_are_sane() {
        let s = Settings::default();
        assert_eq!(s.vlen, 128);
        assert!(s.zvfh);
        assert!(s.threads >= 1);
    }
}
