//! L3 coordinator: the migration/benchmark pipeline. Runs the
//! (kernel x mode x vlen) job matrix across a worker-thread pool
//! (std::thread — the work is CPU-bound simulation, no async needed),
//! verifies translated outputs against the NEON interpretation and the
//! JAX/XLA golden oracle, and aggregates the Figure 2 rows.

mod verify;

pub use verify::{verify_kernel, VerifyOutcome};

use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::kernels::{self, KernelCase};
use crate::rvv::machine::RvvConfig;
use crate::sim::{SimStats, Simulator};
use crate::simde::{Mode, Translator};

/// One unit of work.
#[derive(Debug, Clone)]
pub struct Job {
    pub kernel: &'static str,
    pub mode: Mode,
    pub vlen: u32,
}

/// Result of one simulated job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job: Job,
    pub stats: SimStats,
    pub wall: Duration,
}

/// Run one job (translate + simulate).
pub fn run_job(job: &Job) -> Result<JobResult> {
    let case = kernels::by_name(job.kernel)
        .with_context(|| format!("unknown kernel '{}'", job.kernel))?;
    run_job_on(&case, job)
}

fn run_job_on(case: &KernelCase, job: &Job) -> Result<JobResult> {
    let cfg = RvvConfig::new(job.vlen);
    let t0 = Instant::now();
    let tr = Translator::new(job.mode, cfg);
    let (rp, _) = tr.translate(&case.prog)?;
    let (_, stats) = Simulator::new(&rp, cfg, &case.inputs)?.run()?;
    Ok(JobResult { job: job.clone(), stats, wall: t0.elapsed() })
}

/// Run a job list across `threads` workers; results in input order.
pub fn run_matrix(jobs: Vec<Job>, threads: usize) -> Result<Vec<JobResult>> {
    let n = jobs.len();
    let queue: Arc<Mutex<VecDeque<(usize, Job)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().collect()));
    let (tx, rx) = mpsc::channel::<(usize, Result<JobResult>)>();

    let workers: Vec<_> = (0..threads.max(1))
        .map(|_| {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            std::thread::spawn(move || loop {
                let next = queue.lock().unwrap().pop_front();
                match next {
                    Some((idx, job)) => {
                        let r = run_job(&job);
                        if tx.send((idx, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            })
        })
        .collect();
    drop(tx);

    let mut slots: Vec<Option<JobResult>> = (0..n).map(|_| None).collect();
    for (idx, r) in rx {
        slots[idx] = Some(r?);
    }
    for w in workers {
        w.join().expect("worker panicked");
    }
    Ok(slots.into_iter().map(|s| s.expect("missing result")).collect())
}

/// One Figure 2 row.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub kernel: &'static str,
    pub baseline: u64,
    pub custom: u64,
    pub speedup: f64,
}

/// Compute the Figure 2 table at a given vlen across the worker pool.
pub fn figure2(vlen: u32, threads: usize) -> Result<Vec<Fig2Row>> {
    let mut jobs = Vec::new();
    for name in kernels::NAMES {
        jobs.push(Job { kernel: name, mode: Mode::Baseline, vlen });
        jobs.push(Job { kernel: name, mode: Mode::RvvCustom, vlen });
    }
    let results = run_matrix(jobs, threads)?;
    let rows = results
        .chunks(2)
        .map(|pair| {
            let (b, c) = (&pair[0], &pair[1]);
            debug_assert_eq!(b.job.kernel, c.job.kernel);
            Fig2Row {
                kernel: b.job.kernel,
                baseline: b.stats.total(),
                custom: c.stats.total(),
                speedup: b.stats.total() as f64 / c.stats.total() as f64,
            }
        })
        .collect();
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_runs_in_parallel_and_preserves_order() {
        let jobs = vec![
            Job { kernel: "vrelu", mode: Mode::Baseline, vlen: 128 },
            Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 },
            Job { kernel: "maxpool", mode: Mode::RvvCustom, vlen: 128 },
        ];
        let results = run_matrix(jobs, 3).unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].job.kernel, "vrelu");
        assert_eq!(results[0].job.mode, Mode::Baseline);
        assert_eq!(results[2].job.kernel, "maxpool");
        assert!(results[0].stats.total() > results[1].stats.total());
    }

    #[test]
    fn unknown_kernel_is_an_error() {
        let jobs = vec![Job { kernel: "nope", mode: Mode::Baseline, vlen: 128 }];
        assert!(run_matrix(jobs, 1).is_err());
    }
}
