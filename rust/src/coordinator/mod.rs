//! L3 coordinator: the migration/benchmark pipeline. Runs the
//! (kernel x mode x vlen) job matrix across a worker-thread pool
//! (std::thread — the work is CPU-bound simulation, no async needed),
//! verifies translated outputs against the NEON interpretation and the
//! JAX/XLA golden oracle, and aggregates the Figure 2 rows.
//!
//! # Translation + decode cache
//!
//! Translating a kernel and decoding it for the pre-decoded engine is a
//! pure function of the program's shape, the mode and the vlen. The
//! coordinator memoises the `(RvvProgram, DecodedProgram)` pair in a
//! process-wide [`TranslationCache`] of `Arc`-shared [`CachedProgram`]s
//! keyed on `(kernel, mode, vlen, shape fingerprint)` — see
//! [`crate::ir::Program::fingerprint`]. The fingerprint makes the key
//! sound for *any* program shape, so custom-shaped sweeps (e.g.
//! `kernels::suite_small()`) and tuner candidate runs are cacheable, not
//! just the default `kernels::by_name` shapes: same-shape jobs share one
//! translation, while differently-shaped programs carrying the same
//! kernel name can never collide. Jobs running with a tuning database
//! ([`MatrixOptions::tuning`]) bypass the cache instead — a tuned RVV
//! stream differs from the static-rule stream under the same key.
//!
//! # Engines
//!
//! Jobs default to the pre-decoded lane-batched [`Engine`]; the
//! tree-walking [`Simulator`] remains available through
//! [`EngineKind::Interp`] as the differential-testing oracle and the
//! pre-PR baseline for `benches/sim_throughput.rs`. Both produce
//! bit-identical buffers and equal [`SimStats`] (see the `sim` module
//! docs).
//!
//! # Admission verification
//!
//! Every program entering execution through the coordinator passes the
//! static verifier ([`crate::rvv::verify`]) first: the translation cache
//! verifies a freshly translated program *before* decoding and caching
//! it, and the fresh-translate paths (interp jobs, tuned jobs) verify
//! inline. An illegal program — vl > VLMAX, misaligned register group,
//! out-of-range register, unprovable or out-of-bounds affine address,
//! non-terminating back-edge — is rejected at admission as a
//! [`SimTrap`]-convertible `VerifyError`, so it degrades through the
//! same ladder as a runtime trap instead of executing at all. The
//! verifier's accept ⇒ no-trap contract and its exclusions (masked
//! memory bounds, data-dependent lane indices) are documented on
//! [`crate::rvv::verify`]; the runtime trap layer and the fuel bounds
//! below cover exactly the excluded residue.
//!
//! # Fault tolerance
//!
//! A faulting or panicking job must never abort the matrix. The layers,
//! innermost out:
//!
//! 1. **Structured traps** — the simulators report faults as
//!    [`SimTrap`]s (see [`crate::rvv::trap`]) rather than panicking, so a
//!    bad program produces a typed error with kernel/engine/PC context.
//! 2. **Fuel bounds** — both engines run under [`crate::sim::ExecLimits`]
//!    (dynamic-instruction budget derived from the program's static
//!    shape, optional wall deadline), so even a fault class the verifier
//!    cannot see statically ends in a `FuelExhausted`/`DeadlineExceeded`
//!    trap, never a hung worker.
//! 3. **Panic backstop** — each job attempt runs under
//!    `std::panic::catch_unwind`; a residual panic (simulator bug, bad
//!    register index) becomes a [`TrapKind::Panic`] record instead of a
//!    dead worker. Matrix runs and tuner searches install a scoped
//!    [`quiet_panics`] guard around the backstop, so contained panics do
//!    not spam backtraces; the previous hook is restored when the
//!    outermost guard drops.
//! 4. **Retries + degradation** — a [`RetryPolicy`] re-runs failed
//!    attempts, optionally falling back from the decoded engine to the
//!    interpreter (identical semantics, independent code path).
//!    Deterministic traps (`TrapKind::is_deterministic`) skip the
//!    remaining same-engine attempts — re-running an identical
//!    deterministic simulation cannot change the outcome — and go
//!    straight to the cross-engine fallback; injected/panic/deadline
//!    faults keep full retry semantics. A job that exhausts its attempts
//!    degrades to a [`FaultRecord`] in the [`MatrixReport`]; healthy
//!    jobs are unaffected and workers keep draining the queue.
//! 5. **Circuit breaker** — an optional per-(kernel, family) [`Breaker`]
//!    opens after K consecutive faults; remaining jobs for that pair are
//!    skipped up front and recorded as [`SkipRecord`]s, so a
//!    systematically broken configuration stops burning retry budget.
//!    [`MatrixReport::health`] summarises the run (verified / passed /
//!    faulted / skipped, fuel spent).
//!
//! [`run_matrix_report`] is the fault-tolerant core. The legacy
//! [`run_matrix`]/[`run_matrix_engine`] wrappers keep their strict
//! `Result` contract (first fault, in job order, becomes the error) and
//! single-attempt policy. [`figure2_report`] degrades per kernel: rows
//! whose baseline+custom pair both succeeded are emitted, failed kernels
//! are listed alongside their `FaultRecord`s.
//!
//! Deterministic fault-injection tests drive all of this through
//! [`FaultPlan`] (fail job N on attempt M, panic in job K) — see
//! `tests/fault_injection.rs`.
//!
//! [`TrapKind::Panic`]: crate::rvv::trap::TrapKind

mod verify;

pub use verify::{verify_kernel, VerifyOutcome};

use std::collections::{HashMap, VecDeque};
use std::fmt;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, MutexGuard, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::kernels::{self, KernelCase};
use crate::neon::interp::{Buffer, Inputs};
use crate::rvv::machine::RvvConfig;
use crate::rvv::program::RvvProgram;
use crate::rvv::trap::SimTrap;
use crate::sim::{decode, DecodedProgram, Engine, SimStats, Simulator};
use crate::simde::{Mode, Translator};
use crate::tuner::db::TuningDb;

/// Lock a mutex, recovering the guard if a previous holder panicked.
///
/// All coordinator-shared state (translation cache, job queue) is written
/// in a panic-safe order — an entry is either absent or complete — so a
/// poisoned lock carries no torn data and refusing to run after one would
/// turn a single contained panic into a process-wide outage.
fn lock_ignore_poison<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

type PrevHook = Box<dyn Fn(&std::panic::PanicHookInfo<'_>) + Send + Sync + 'static>;

/// Refcounted process-global state behind [`quiet_panics`]: the panic
/// hook is process-wide, so nested/concurrent guards must share one
/// depth counter and only the outermost transition touches the hook.
#[derive(Default)]
struct QuietHookState {
    depth: usize,
    prev: Option<PrevHook>,
}

fn quiet_hook_state() -> &'static Mutex<QuietHookState> {
    static STATE: OnceLock<Mutex<QuietHookState>> = OnceLock::new();
    STATE.get_or_init(|| Mutex::new(QuietHookState::default()))
}

/// RAII guard from [`quiet_panics`]; dropping the outermost guard
/// restores the previous panic hook.
pub struct QuietPanicGuard(());

/// Silence the panic hook for the lifetime of the returned guard.
///
/// The per-attempt `catch_unwind` backstop contains panics, but the
/// default hook still prints a message + backtrace for each one — noise
/// that drowns real output during tuner searches (where a panicking
/// candidate is an *expected*, scored-out outcome) and fault-injection
/// tests. Guards nest and may overlap across threads: a shared refcount
/// ensures the hook is swapped once on the first guard and restored when
/// the last one drops. Panic *propagation* is untouched — only the
/// printing side effect is suppressed.
pub fn quiet_panics() -> QuietPanicGuard {
    let mut st = lock_ignore_poison(quiet_hook_state());
    if st.depth == 0 {
        st.prev = Some(std::panic::take_hook());
        std::panic::set_hook(Box::new(|_| {}));
    }
    st.depth += 1;
    QuietPanicGuard(())
}

impl Drop for QuietPanicGuard {
    fn drop(&mut self) {
        let mut st = lock_ignore_poison(quiet_hook_state());
        st.depth -= 1;
        if st.depth == 0 {
            if let Some(prev) = st.prev.take() {
                std::panic::set_hook(prev);
            }
        }
    }
}

/// One unit of work.
#[derive(Debug, Clone)]
pub struct Job {
    pub kernel: &'static str,
    pub mode: Mode,
    pub vlen: u32,
}

/// Which execution engine a job runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Tree-walking interpreter (`sim::Simulator`) — the reference.
    Interp,
    /// Pre-decoded lane-batched engine (`sim::Engine`) — the default.
    Decoded,
}

impl EngineKind {
    /// Short stable label, matching the engine tags on [`SimTrap`].
    pub fn label(self) -> &'static str {
        match self {
            EngineKind::Interp => "interp",
            EngineKind::Decoded => "decoded",
        }
    }
}

/// Result of one simulated job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job: Job,
    pub stats: SimStats,
    pub wall: Duration,
    /// Attempts taken to produce this result (1 = first try).
    pub attempts: u32,
    /// Engine that actually produced it — may differ from the requested
    /// engine after an interp fallback.
    pub engine: EngineKind,
}

/// A translated + decoded program, shared across jobs via `Arc`.
#[derive(Debug)]
pub struct CachedProgram {
    pub rvv: RvvProgram,
    pub decoded: DecodedProgram,
}

/// Process-wide memo of translation + decode results keyed on
/// (kernel, mode, vlen, shape fingerprint). The fingerprint
/// ([`crate::ir::Program::fingerprint`]) covers the program's full
/// structure, so the key is valid for any shape — default suite shapes
/// and custom-shaped sweeps alike.
#[derive(Default)]
pub struct TranslationCache {
    map: Mutex<HashMap<(&'static str, Mode, u32, u64), Arc<CachedProgram>>>,
}

impl TranslationCache {
    /// Fetch the decoded program for `job`, translating + verifying +
    /// decoding on first use. Verification is the mandatory admission
    /// stage: only verified programs are decoded and cached, so a cache
    /// hit is a proof the program was admitted once already.
    ///
    /// The lock is deliberately released between the miss check and the
    /// insert so translation runs unlocked; concurrent misses on the same
    /// key may therefore translate twice, and `entry().or_insert` makes
    /// the first insert win while the duplicate is dropped. This is a
    /// benign race: translation is a pure function of the key, so either
    /// artifact is interchangeable — the cost is one wasted translation,
    /// never a wrong result. Locks recover from poisoning (a worker that
    /// panicked while reading the map cannot have torn an entry).
    pub fn get_or_translate(&self, case: &KernelCase, job: &Job) -> Result<Arc<CachedProgram>> {
        let key = (job.kernel, job.mode, job.vlen, case.prog.fingerprint());
        if let Some(hit) = lock_ignore_poison(&self.map).get(&key) {
            return Ok(Arc::clone(hit));
        }
        let cfg = RvvConfig::new(job.vlen);
        let (rvv, _) = Translator::new(job.mode, cfg).translate(&case.prog)?;
        verify_admission(&rvv, job)?;
        let decoded = decode(&rvv);
        let entry = Arc::new(CachedProgram { rvv, decoded });
        let mut map = lock_ignore_poison(&self.map);
        Ok(Arc::clone(map.entry(key).or_insert(entry)))
    }

    /// Number of cached programs (for tests/benches).
    pub fn len(&self) -> usize {
        lock_ignore_poison(&self.map).len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The mandatory admission stage: statically verify a translated program
/// before it may execute. A rejection is surfaced as the [`SimTrap`] the
/// execution layer would have raised (tagged with the kernel name), so
/// the recovery ladder records it as a structured `FaultRecord` and the
/// retry classifier sees a deterministic fault.
fn verify_admission(rvv: &RvvProgram, job: &Job) -> Result<()> {
    crate::rvv::verify::verify(rvv, job.vlen)
        .map_err(|e| anyhow::Error::new(SimTrap::from(e).in_kernel(job.kernel)))
}

/// The shared process-wide cache used by `run_job` and the worker pool.
pub fn translation_cache() -> &'static TranslationCache {
    static CACHE: OnceLock<TranslationCache> = OnceLock::new();
    CACHE.get_or_init(TranslationCache::default)
}

/// Run one job on the default (pre-decoded) engine, via the shared cache.
pub fn run_job(job: &Job) -> Result<JobResult> {
    run_job_engine(job, EngineKind::Decoded)
}

/// Run one job on an explicit engine. `Interp` translates from scratch
/// every time (the pre-PR behaviour); `Decoded` goes through the shared
/// translation cache.
pub fn run_job_engine(job: &Job, engine: EngineKind) -> Result<JobResult> {
    run_job_engine_opts(job, engine, None)
}

/// [`run_job_engine`] with an optional tuning database. When a database
/// is supplied the translator consults it for a tuned lowering
/// (falling back to the static rules per entry), and the job bypasses
/// the shared translation cache: a tuned RVV stream differs from the
/// static-rule stream that an untuned job would cache under the same
/// (kernel, mode, vlen, fingerprint) key.
pub fn run_job_engine_opts(
    job: &Job,
    engine: EngineKind,
    tuning: Option<&Arc<TuningDb>>,
) -> Result<JobResult> {
    let case = kernels::by_name(job.kernel)
        .with_context(|| format!("unknown kernel '{}'", job.kernel))?;
    let cfg = RvvConfig::new(job.vlen);
    let translator = || {
        let tr = Translator::new(job.mode, cfg);
        match tuning {
            Some(db) => tr.with_tuning(Arc::clone(db)),
            None => tr,
        }
    };
    let t0 = Instant::now();
    let stats = match (engine, tuning) {
        (EngineKind::Interp, _) => {
            let (rp, _) = translator().translate(&case.prog)?;
            verify_admission(&rp, job)?;
            let (_, stats) = Simulator::new(&rp, cfg, &case.inputs)?.run()?;
            stats
        }
        (EngineKind::Decoded, Some(_)) => {
            let (rp, _) = translator().translate(&case.prog)?;
            verify_admission(&rp, job)?;
            let dec = decode(&rp);
            let (_, stats) = Engine::new(&rp, &dec, cfg, &case.inputs)?.run()?;
            stats
        }
        (EngineKind::Decoded, None) => {
            let cached = translation_cache().get_or_translate(&case, job)?;
            let (_, stats) = Engine::new(&cached.rvv, &cached.decoded, cfg, &case.inputs)?.run()?;
            stats
        }
    };
    Ok(JobResult { job: job.clone(), stats, wall: t0.elapsed(), attempts: 1, engine })
}

/// How failed job attempts are retried.
///
/// Attempts whose failure is a deterministic trap
/// (`TrapKind::is_deterministic`) do not re-run on the same engine —
/// the remaining same-engine slots are skipped and the ladder moves
/// straight to the cross-engine fallback. Injected/panic/deadline
/// faults keep the full schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RetryPolicy {
    /// Attempts on the requested engine before giving up (min 1).
    pub max_attempts: u32,
    /// After exhausting decoded-engine attempts, try once more on the
    /// tree-walking interpreter — an independent code path with identical
    /// semantics, so a decoded-engine bug degrades to a slower result
    /// instead of a fault. No effect when the requested engine is
    /// already `Interp`.
    pub interp_fallback: bool,
}

impl RetryPolicy {
    /// Single attempt, no fallback — the strict legacy behaviour.
    pub fn none() -> RetryPolicy {
        RetryPolicy { max_attempts: 1, interp_fallback: false }
    }
}

impl Default for RetryPolicy {
    fn default() -> RetryPolicy {
        RetryPolicy { max_attempts: 2, interp_fallback: true }
    }
}

/// What a [`FaultPlan`] entry injects.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum InjectKind {
    /// Return a `TrapKind::Injected` [`SimTrap`] from the attempt.
    Trap,
    /// `panic!` inside the attempt, exercising the unwind backstop.
    Panic,
}

/// One deterministic injected fault: matches a job index plus optional
/// attempt number and engine (None = match any).
#[derive(Debug, Clone)]
pub struct InjectedFault {
    pub job: usize,
    pub attempt: Option<u32>,
    pub engine: Option<EngineKind>,
    pub kind: InjectKind,
}

/// Test-only deterministic fault injection for the worker pool: "fail job
/// N on attempt M", "panic in job K". Injection happens inside the
/// per-attempt `catch_unwind`, before the job body runs, so the recovery
/// machinery is exercised exactly as it would be by a real fault.
///
/// Compiled unconditionally (it is plain data and the lookup is one
/// `Vec::iter().find`), but only tests construct one — production entry
/// points leave `MatrixOptions::fault_plan` empty.
#[derive(Debug, Clone, Default)]
pub struct FaultPlan {
    faults: Vec<InjectedFault>,
}

impl FaultPlan {
    pub fn new() -> FaultPlan {
        FaultPlan::default()
    }

    /// Inject a trap in job `job`, attempt `attempt` only (1-based).
    pub fn fail(mut self, job: usize, attempt: u32) -> FaultPlan {
        self.faults.push(InjectedFault {
            job,
            attempt: Some(attempt),
            engine: None,
            kind: InjectKind::Trap,
        });
        self
    }

    /// Inject a trap in every attempt of job `job`, on every engine.
    pub fn fail_always(mut self, job: usize) -> FaultPlan {
        self.faults.push(InjectedFault { job, attempt: None, engine: None, kind: InjectKind::Trap });
        self
    }

    /// Inject a trap in job `job` whenever it runs on `engine` — lets a
    /// test fail every decoded attempt while the interp fallback succeeds.
    pub fn fail_engine(mut self, job: usize, engine: EngineKind) -> FaultPlan {
        self.faults.push(InjectedFault {
            job,
            attempt: None,
            engine: Some(engine),
            kind: InjectKind::Trap,
        });
        self
    }

    /// Panic inside job `job`, attempt `attempt` (1-based).
    pub fn panic_on(mut self, job: usize, attempt: u32) -> FaultPlan {
        self.faults.push(InjectedFault {
            job,
            attempt: Some(attempt),
            engine: None,
            kind: InjectKind::Panic,
        });
        self
    }

    fn lookup(&self, job: usize, attempt: u32, engine: EngineKind) -> Option<InjectKind> {
        self.faults
            .iter()
            .find(|f| {
                f.job == job
                    && (f.attempt.is_none() || f.attempt == Some(attempt))
                    && (f.engine.is_none() || f.engine == Some(engine))
            })
            .map(|f| f.kind)
    }
}

/// Per-(kernel, family) consecutive-failure tracker: the circuit breaker.
///
/// After `threshold` consecutive faults for one (kernel, family) pair the
/// breaker *opens* and callers skip further attempts for that pair up
/// front (recorded as [`SkipRecord`]s / `Skipped` provenance) instead of
/// burning full retry ladders on a systematically broken configuration.
/// A success resets the pair's count. Under a parallel pool the count is
/// racy by design — two workers may both start before either records a
/// fault, so a breaker may open one or two jobs "late"; it never opens
/// early, and healthy pairs (no faults at all) are never affected.
#[derive(Debug)]
pub struct Breaker {
    threshold: u32,
    state: Mutex<HashMap<(String, String), u32>>,
}

impl Breaker {
    /// Breaker opening after `threshold` consecutive faults (min 1).
    pub fn new(threshold: u32) -> Breaker {
        Breaker { threshold: threshold.max(1), state: Mutex::new(HashMap::new()) }
    }

    pub fn threshold(&self) -> u32 {
        self.threshold
    }

    pub fn is_open(&self, kernel: &str, family: &str) -> bool {
        lock_ignore_poison(&self.state)
            .get(&(kernel.to_string(), family.to_string()))
            .is_some_and(|c| *c >= self.threshold)
    }

    pub fn record_ok(&self, kernel: &str, family: &str) {
        lock_ignore_poison(&self.state).remove(&(kernel.to_string(), family.to_string()));
    }

    pub fn record_fault(&self, kernel: &str, family: &str) {
        *lock_ignore_poison(&self.state)
            .entry((kernel.to_string(), family.to_string()))
            .or_insert(0) += 1;
    }
}

/// A job that was never attempted because its breaker was open.
#[derive(Debug, Clone)]
pub struct SkipRecord {
    /// Index into the submitted job list.
    pub index: usize,
    pub job: Job,
    pub reason: String,
}

impl fmt::Display for SkipRecord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "job #{} {} [{:?} vlen={}] skipped: {}",
            self.index, self.job.kernel, self.job.mode, self.job.vlen, self.reason,
        )
    }
}

/// Options for [`run_matrix_report`].
#[derive(Debug, Clone)]
pub struct MatrixOptions {
    pub threads: usize,
    pub engine: EngineKind,
    pub retry: RetryPolicy,
    pub fault_plan: Option<Arc<FaultPlan>>,
    /// Tuning database consulted during lowering; jobs bypass the
    /// translation cache when set (see [`run_job_engine_opts`]).
    pub tuning: Option<Arc<TuningDb>>,
    /// Circuit breaker shared across the run (and, if the caller wants,
    /// across runs). Family key is the job's mode. `None` = no breaker.
    pub breaker: Option<Arc<Breaker>>,
}

impl MatrixOptions {
    /// Decoded engine, default retry policy, no fault injection.
    pub fn new(threads: usize) -> MatrixOptions {
        MatrixOptions {
            threads,
            engine: EngineKind::Decoded,
            retry: RetryPolicy::default(),
            fault_plan: None,
            tuning: None,
            breaker: None,
        }
    }

    pub fn engine(mut self, engine: EngineKind) -> MatrixOptions {
        self.engine = engine;
        self
    }

    pub fn retry(mut self, retry: RetryPolicy) -> MatrixOptions {
        self.retry = retry;
        self
    }

    pub fn fault_plan(mut self, plan: FaultPlan) -> MatrixOptions {
        self.fault_plan = Some(Arc::new(plan));
        self
    }

    pub fn tuning(mut self, db: Arc<TuningDb>) -> MatrixOptions {
        self.tuning = Some(db);
        self
    }

    pub fn breaker(mut self, breaker: Arc<Breaker>) -> MatrixOptions {
        self.breaker = Some(breaker);
        self
    }
}

/// How one job failed after all recovery was exhausted.
#[derive(Debug, Clone)]
pub struct FaultRecord {
    /// Index into the submitted job list.
    pub index: usize,
    pub job: Job,
    /// Total attempts made (0 = the job never produced an outcome, e.g.
    /// its worker died outside the backstop).
    pub attempts: u32,
    /// Engine of the last attempt.
    pub engine: EngineKind,
    /// Rendered error chain of the last attempt.
    pub error: String,
    /// Structured trap, when the failure was (or unwound into) one.
    pub trap: Option<SimTrap>,
}

impl fmt::Display for FaultRecord {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "job #{} {} [{:?} vlen={}] failed on {} after {} attempt(s): {}",
            self.index,
            self.job.kernel,
            self.job.mode,
            self.job.vlen,
            self.engine.label(),
            self.attempts,
            self.error,
        )
    }
}

impl std::error::Error for FaultRecord {}

/// Outcome of a fault-tolerant matrix run: per-job results in input
/// order (`None` where the job faulted or was skipped) plus the fault
/// and skip records, sorted by job index.
#[derive(Debug)]
pub struct MatrixReport {
    pub results: Vec<Option<JobResult>>,
    pub faults: Vec<FaultRecord>,
    /// Jobs never attempted because their circuit breaker was open.
    pub skipped: Vec<SkipRecord>,
}

/// Health summary of one matrix run (see [`MatrixReport::health`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct MatrixHealth {
    /// Jobs admitted by the verifier and executed (= passed + faulted).
    pub verified: usize,
    pub passed: usize,
    pub faulted: usize,
    /// Jobs skipped by an open circuit breaker.
    pub skipped: usize,
    /// Total dynamic instructions (fuel) consumed by successful jobs.
    pub fuel_spent: u64,
}

impl MatrixReport {
    pub fn ok(&self) -> bool {
        self.faults.is_empty() && self.skipped.is_empty()
    }

    /// Aggregate verified/passed/faulted/skipped counts and the fuel
    /// spent by successful jobs.
    pub fn health(&self) -> MatrixHealth {
        let passed = self.results.iter().flatten().count();
        MatrixHealth {
            verified: passed + self.faults.len(),
            passed,
            faulted: self.faults.len(),
            skipped: self.skipped.len(),
            fuel_spent: self.results.iter().flatten().map(|r| r.stats.total()).sum(),
        }
    }

    /// Collapse to the strict contract: all results, or the first fault
    /// (in job order) as the error. The error is an `anyhow::Error`
    /// wrapping the [`FaultRecord`], so callers can still downcast.
    /// Breaker skips (only possible when the caller opted into a
    /// breaker) are an error too.
    pub fn into_results(self) -> Result<Vec<JobResult>> {
        if let Some(f) = self.faults.into_iter().next() {
            return Err(anyhow::Error::new(f));
        }
        if let Some(s) = self.skipped.first() {
            bail!("{s}");
        }
        let mut out = Vec::with_capacity(self.results.len());
        for (i, slot) in self.results.into_iter().enumerate() {
            match slot {
                Some(jr) => out.push(jr),
                None => bail!("missing result for job #{i} with no fault record"),
            }
        }
        Ok(out)
    }
}

fn panic_message(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "non-string panic payload".to_string()
    }
}

/// Run one job through the full recovery ladder: injection check, panic
/// backstop, retries on the requested engine, optional interp fallback.
// the Err carries the full fault context by design; it is built once per
// failed job, never on a hot path
#[allow(clippy::result_large_err)]
fn run_with_recovery(
    idx: usize,
    job: &Job,
    retry: RetryPolicy,
    primary: EngineKind,
    plan: Option<&FaultPlan>,
    tuning: Option<&Arc<TuningDb>>,
) -> Result<JobResult, FaultRecord> {
    let mut schedule = vec![primary; retry.max_attempts.max(1) as usize];
    if retry.interp_fallback && primary == EngineKind::Decoded {
        schedule.push(EngineKind::Interp);
    }
    let mut last: Option<(anyhow::Error, EngineKind)> = None;
    let mut executed: u32 = 0;
    let mut i = 0;
    while i < schedule.len() {
        let eng = schedule[i];
        executed += 1;
        let attempt = executed;
        let outcome = catch_unwind(AssertUnwindSafe(|| {
            if let Some(kind) = plan.and_then(|p| p.lookup(idx, attempt, eng)) {
                match kind {
                    InjectKind::Trap => {
                        return Err(SimTrap::injected(format!(
                            "fault plan: job #{idx} attempt {attempt}"
                        ))
                        .in_kernel(job.kernel)
                        .on_engine(eng.label())
                        .into());
                    }
                    InjectKind::Panic => {
                        panic!("fault plan: injected panic in job #{idx} attempt {attempt}")
                    }
                }
            }
            run_job_engine_opts(job, eng, tuning)
        }));
        match outcome {
            Ok(Ok(mut jr)) => {
                jr.attempts = attempt;
                jr.engine = eng;
                return Ok(jr);
            }
            Ok(Err(e)) => {
                // a deterministic trap re-runs identically: skip the
                // remaining same-engine attempts, go straight to the
                // cross-engine fallback (if any)
                let deterministic =
                    e.downcast_ref::<SimTrap>().is_some_and(|t| t.kind.is_deterministic());
                last = Some((e, eng));
                if deterministic {
                    while i + 1 < schedule.len() && schedule[i + 1] == eng {
                        i += 1;
                    }
                }
            }
            Err(payload) => {
                let trap = SimTrap::panicked(panic_message(payload))
                    .in_kernel(job.kernel)
                    .on_engine(eng.label());
                last = Some((anyhow::Error::new(trap), eng));
            }
        }
        i += 1;
    }
    let attempts = executed.max(1);
    let (error, engine) = match last {
        Some(l) => l,
        // unreachable: the schedule always has at least one attempt
        None => (anyhow::anyhow!("no attempt executed"), primary),
    };
    let trap = error.downcast_ref::<SimTrap>().cloned();
    Err(FaultRecord {
        index: idx,
        job: job.clone(),
        attempts,
        engine,
        error: format!("{error:#}"),
        trap,
    })
}

/// Result of one prepared-program run: output buffers (for bit-identity
/// checks) plus the scoring signals. Unlike [`JobResult`] this keeps the
/// outputs, which the tuner compares against the static-rule reference.
#[derive(Debug)]
pub struct PreparedOutcome {
    pub outputs: HashMap<String, Buffer>,
    pub stats: SimStats,
    pub wall: Duration,
    pub attempts: u32,
    pub engine: EngineKind,
}

/// Run an already translated + decoded program through the same recovery
/// ladder as the matrix jobs: per-attempt `catch_unwind` backstop,
/// retries on the decoded engine, optional interp fallback, degradation
/// to a [`FaultRecord`]. This is the tuner's execution primitive — a
/// candidate lowering is an arbitrary RVV program that may trap or
/// panic, and a broken candidate must score out of the search, not abort
/// it. `job` provides the fault-record context (kernel, mode, vlen);
/// `idx` is the caller's candidate index.
// the Err carries full fault context, built once per failed candidate
#[allow(clippy::result_large_err)]
pub fn run_prepared_with_recovery(
    idx: usize,
    job: &Job,
    prog: &CachedProgram,
    inputs: &Inputs,
    retry: RetryPolicy,
) -> Result<PreparedOutcome, FaultRecord> {
    let cfg = RvvConfig::new(job.vlen);
    let mut schedule = vec![EngineKind::Decoded; retry.max_attempts.max(1) as usize];
    if retry.interp_fallback {
        schedule.push(EngineKind::Interp);
    }
    let mut last: Option<(anyhow::Error, EngineKind)> = None;
    let mut executed: u32 = 0;
    let mut i = 0;
    while i < schedule.len() {
        let eng = schedule[i];
        executed += 1;
        let t0 = Instant::now();
        let outcome = catch_unwind(AssertUnwindSafe(|| match eng {
            EngineKind::Interp => Simulator::new(&prog.rvv, cfg, inputs)?.run(),
            EngineKind::Decoded => Engine::new(&prog.rvv, &prog.decoded, cfg, inputs)?.run(),
        }));
        match outcome {
            Ok(Ok((outputs, stats))) => {
                return Ok(PreparedOutcome {
                    outputs,
                    stats,
                    wall: t0.elapsed(),
                    attempts: executed,
                    engine: eng,
                });
            }
            Ok(Err(e)) => {
                // deterministic traps skip the remaining same-engine
                // attempts — identical simulation, identical outcome
                let deterministic =
                    e.downcast_ref::<SimTrap>().is_some_and(|t| t.kind.is_deterministic());
                last = Some((e, eng));
                if deterministic {
                    while i + 1 < schedule.len() && schedule[i + 1] == eng {
                        i += 1;
                    }
                }
            }
            Err(payload) => {
                let trap = SimTrap::panicked(panic_message(payload))
                    .in_kernel(job.kernel)
                    .on_engine(eng.label());
                last = Some((anyhow::Error::new(trap), eng));
            }
        }
        i += 1;
    }
    let attempts = executed.max(1);
    let (error, engine) = match last {
        Some(l) => l,
        // unreachable: the schedule always has at least one attempt
        None => (anyhow::anyhow!("no attempt executed"), EngineKind::Decoded),
    };
    let trap = error.downcast_ref::<SimTrap>().cloned();
    Err(FaultRecord {
        index: idx,
        job: job.clone(),
        attempts,
        engine,
        error: format!("{error:#}"),
        trap,
    })
}

/// Fault-tolerant matrix run: every job is attempted under the recovery
/// ladder, workers stay alive through failures and keep draining the
/// queue, and the report carries partial results plus fault records.
/// Never fails as a whole — degradation is per job.
pub fn run_matrix_report(jobs: Vec<Job>, opts: MatrixOptions) -> MatrixReport {
    enum Outcome {
        Done(JobResult),
        Fault(Box<FaultRecord>),
        Skipped(SkipRecord),
    }

    let _quiet = quiet_panics();
    let n = jobs.len();
    let job_table = jobs.clone();
    let queue: Arc<Mutex<VecDeque<(usize, Job)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().collect()));
    let (tx, rx) = mpsc::channel::<(usize, Outcome)>();

    let workers: Vec<_> = (0..opts.threads.max(1))
        .map(|_| {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            let plan = opts.fault_plan.clone();
            let tuning = opts.tuning.clone();
            let breaker = opts.breaker.clone();
            let (retry, engine) = (opts.retry, opts.engine);
            std::thread::spawn(move || loop {
                let next = lock_ignore_poison(&queue).pop_front();
                match next {
                    Some((idx, job)) => {
                        // family key for matrix jobs: the translation mode
                        let family = format!("{:?}", job.mode);
                        if let Some(b) = breaker.as_ref() {
                            if b.is_open(job.kernel, &family) {
                                let s = SkipRecord {
                                    index: idx,
                                    job: job.clone(),
                                    reason: format!(
                                        "breaker open for ({}, {family}) after {} consecutive fault(s)",
                                        job.kernel,
                                        b.threshold(),
                                    ),
                                };
                                if tx.send((idx, Outcome::Skipped(s))).is_err() {
                                    return;
                                }
                                continue;
                            }
                        }
                        let r = run_with_recovery(
                            idx,
                            &job,
                            retry,
                            engine,
                            plan.as_deref(),
                            tuning.as_ref(),
                        );
                        if let Some(b) = breaker.as_ref() {
                            match &r {
                                Ok(_) => b.record_ok(job.kernel, &family),
                                Err(_) => b.record_fault(job.kernel, &family),
                            }
                        }
                        let out = match r {
                            Ok(jr) => Outcome::Done(jr),
                            Err(f) => Outcome::Fault(Box::new(f)),
                        };
                        if tx.send((idx, out)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            })
        })
        .collect();
    drop(tx);

    let mut slots: Vec<Option<JobResult>> = (0..n).map(|_| None).collect();
    let mut faults: Vec<FaultRecord> = Vec::new();
    let mut skipped: Vec<SkipRecord> = Vec::new();
    for (idx, r) in rx {
        match r {
            Outcome::Done(jr) => slots[idx] = Some(jr),
            Outcome::Fault(f) => faults.push(*f),
            Outcome::Skipped(s) => skipped.push(s),
        }
    }
    for w in workers {
        // the per-attempt catch_unwind makes worker death near-impossible;
        // if one dies anyway, its hole is synthesised as a fault below
        let _ = w.join();
    }
    for (i, slot) in slots.iter().enumerate() {
        if slot.is_none()
            && !faults.iter().any(|f| f.index == i)
            && !skipped.iter().any(|s| s.index == i)
        {
            faults.push(FaultRecord {
                index: i,
                job: job_table[i].clone(),
                attempts: 0,
                engine: opts.engine,
                error: "no result: worker thread died or the job was never handed out".to_string(),
                trap: None,
            });
        }
    }
    faults.sort_by_key(|f| f.index);
    skipped.sort_by_key(|s| s.index);
    MatrixReport { results: slots, faults, skipped }
}

/// Run a job list across `threads` workers; results in input order.
pub fn run_matrix(jobs: Vec<Job>, threads: usize) -> Result<Vec<JobResult>> {
    run_matrix_engine(jobs, threads, EngineKind::Decoded)
}

/// `run_matrix` with an explicit engine choice: the strict single-attempt
/// contract. All jobs still run to completion with workers kept alive
/// (see [`run_matrix_report`]); afterwards the first fault, in job order,
/// becomes the error.
pub fn run_matrix_engine(
    jobs: Vec<Job>,
    threads: usize,
    engine: EngineKind,
) -> Result<Vec<JobResult>> {
    let opts = MatrixOptions::new(threads).engine(engine).retry(RetryPolicy::none());
    run_matrix_report(jobs, opts).into_results()
}

/// One Figure 2 row.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub kernel: &'static str,
    pub baseline: u64,
    pub custom: u64,
    pub speedup: f64,
}

/// Figure 2 with degradation: rows for every kernel whose baseline+custom
/// pair both succeeded, failed kernels listed with their fault records.
#[derive(Debug)]
pub struct Fig2Report {
    pub vlen: u32,
    pub rows: Vec<Fig2Row>,
    /// Kernels with no row because at least one half of the pair faulted.
    pub failed: Vec<&'static str>,
    pub faults: Vec<FaultRecord>,
}

/// The (kernel × mode) job list behind the Figure 2 table at one vlen.
pub fn figure2_jobs(vlen: u32) -> Vec<Job> {
    let mut jobs = Vec::new();
    for name in kernels::NAMES {
        jobs.push(Job { kernel: name, mode: Mode::Baseline, vlen });
        jobs.push(Job { kernel: name, mode: Mode::RvvCustom, vlen });
    }
    jobs
}

/// Compute the Figure 2 table at a given vlen across the worker pool.
/// Strict: any fault is an error (used by the sweeps and benches, which
/// want a hard failure rather than a partial table).
pub fn figure2(vlen: u32, threads: usize) -> Result<Vec<Fig2Row>> {
    figure2_with(vlen, threads, EngineKind::Decoded)
}

/// `figure2` with an explicit engine choice (used by the throughput bench
/// to compare engines on identical work).
pub fn figure2_with(vlen: u32, threads: usize, engine: EngineKind) -> Result<Vec<Fig2Row>> {
    let results = run_matrix_engine(figure2_jobs(vlen), threads, engine)?;
    let rows = results
        .chunks(2)
        .map(|pair| {
            let (b, c) = (&pair[0], &pair[1]);
            debug_assert_eq!(b.job.kernel, c.job.kernel);
            Fig2Row {
                kernel: b.job.kernel,
                baseline: b.stats.total(),
                custom: c.stats.total(),
                speedup: b.stats.total() as f64 / c.stats.total() as f64,
            }
        })
        .collect();
    Ok(rows)
}

/// Fault-tolerant Figure 2: partial rows plus fault annotations.
pub fn figure2_report(vlen: u32, threads: usize) -> Fig2Report {
    figure2_report_opts(vlen, MatrixOptions::new(threads))
}

/// [`figure2_report`] with explicit [`MatrixOptions`] (engine choice,
/// retry policy, fault injection for tests).
pub fn figure2_report_opts(vlen: u32, opts: MatrixOptions) -> Fig2Report {
    let jobs = figure2_jobs(vlen);
    let names: Vec<&'static str> = jobs.iter().step_by(2).map(|j| j.kernel).collect();
    let report = run_matrix_report(jobs, opts);
    let mut rows = Vec::new();
    let mut failed = Vec::new();
    for (i, pair) in report.results.chunks(2).enumerate() {
        match pair {
            [Some(b), Some(c)] => rows.push(Fig2Row {
                kernel: b.job.kernel,
                baseline: b.stats.total(),
                custom: c.stats.total(),
                speedup: b.stats.total() as f64 / c.stats.total() as f64,
            }),
            _ => failed.push(names[i]),
        }
    }
    Fig2Report { vlen, rows, failed, faults: report.faults }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_runs_in_parallel_and_preserves_order() {
        let jobs = vec![
            Job { kernel: "vrelu", mode: Mode::Baseline, vlen: 128 },
            Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 },
            Job { kernel: "maxpool", mode: Mode::RvvCustom, vlen: 128 },
        ];
        let results = run_matrix(jobs, 3).unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].job.kernel, "vrelu");
        assert_eq!(results[0].job.mode, Mode::Baseline);
        assert_eq!(results[2].job.kernel, "maxpool");
        assert!(results[0].stats.total() > results[1].stats.total());
        assert!(results.iter().all(|r| r.attempts == 1 && r.engine == EngineKind::Decoded));
    }

    #[test]
    fn unknown_kernel_is_an_error() {
        let jobs = vec![Job { kernel: "nope", mode: Mode::Baseline, vlen: 128 }];
        assert!(run_matrix(jobs, 1).is_err());
    }

    #[test]
    fn failed_job_still_joins_workers_and_reports_first_error() {
        // one bad job sandwiched between good ones, more jobs than threads
        // so workers outlive the failure
        let mut jobs = vec![
            Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 },
            Job { kernel: "nope", mode: Mode::Baseline, vlen: 128 },
        ];
        for _ in 0..6 {
            jobs.push(Job { kernel: "vsqrt", mode: Mode::RvvCustom, vlen: 128 });
        }
        let err = run_matrix(jobs, 2).unwrap_err();
        assert!(err.to_string().contains("nope"), "unexpected error: {err:#}");
        // the strict wrapper surfaces the fault as a downcastable record
        let f = err.downcast_ref::<FaultRecord>().expect("FaultRecord");
        assert_eq!(f.index, 1);
        assert_eq!(f.attempts, 1);
    }

    #[test]
    fn engines_agree_and_cache_fills() {
        let job = Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 };
        let a = run_job_engine(&job, EngineKind::Interp).unwrap();
        let b = run_job_engine(&job, EngineKind::Decoded).unwrap();
        assert_eq!(a.stats, b.stats);
        // second decoded run hits the cache and still agrees
        let c = run_job_engine(&job, EngineKind::Decoded).unwrap();
        assert_eq!(b.stats, c.stats);
        assert!(!translation_cache().is_empty());
    }

    #[test]
    fn breaker_counts_consecutive_faults_and_resets_on_success() {
        let b = Breaker::new(2);
        b.record_fault("k", "f");
        assert!(!b.is_open("k", "f"));
        b.record_fault("k", "f");
        assert!(b.is_open("k", "f"));
        assert!(!b.is_open("k", "other"));
        b.record_ok("k", "f");
        assert!(!b.is_open("k", "f"));
    }

    #[test]
    fn open_breaker_skips_remaining_jobs_and_health_reports_it() {
        // six copies of one (kernel, mode) pair, all injected to fault;
        // single worker for a deterministic order: threshold 2 means two
        // full fault ladders, then four up-front skips
        let jobs: Vec<Job> =
            (0..6).map(|_| Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 }).collect();
        let mut plan = FaultPlan::new();
        for i in 0..6 {
            plan = plan.fail_always(i);
        }
        let opts = MatrixOptions::new(1)
            .retry(RetryPolicy::none())
            .fault_plan(plan)
            .breaker(Arc::new(Breaker::new(2)));
        let report = run_matrix_report(jobs, opts);
        assert_eq!(report.faults.len(), 2);
        assert_eq!(report.skipped.len(), 4);
        assert!(report.skipped[0].reason.contains("breaker open"), "{}", report.skipped[0]);
        assert!(!report.ok());
        let h = report.health();
        assert_eq!(h.verified, 2);
        assert_eq!(h.passed, 0);
        assert_eq!(h.faulted, 2);
        assert_eq!(h.skipped, 4);
        assert_eq!(h.fuel_spent, 0);
    }

    #[test]
    fn healthy_run_reports_clean_health() {
        let jobs = vec![
            Job { kernel: "vrelu", mode: Mode::Baseline, vlen: 128 },
            Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 },
        ];
        let report =
            run_matrix_report(jobs, MatrixOptions::new(2).breaker(Arc::new(Breaker::new(3))));
        assert!(report.ok());
        let h = report.health();
        assert_eq!(h.passed, 2);
        assert_eq!(h.verified, 2);
        assert_eq!(h.faulted, 0);
        assert_eq!(h.skipped, 0);
        assert!(h.fuel_spent > 0);
    }

    #[test]
    fn fault_plan_lookup_matches_wildcards() {
        let plan = FaultPlan::new()
            .fail(0, 2)
            .fail_engine(1, EngineKind::Decoded)
            .fail_always(2)
            .panic_on(3, 1);
        assert_eq!(plan.lookup(0, 1, EngineKind::Decoded), None);
        assert_eq!(plan.lookup(0, 2, EngineKind::Interp), Some(InjectKind::Trap));
        assert_eq!(plan.lookup(1, 5, EngineKind::Decoded), Some(InjectKind::Trap));
        assert_eq!(plan.lookup(1, 5, EngineKind::Interp), None);
        assert_eq!(plan.lookup(2, 9, EngineKind::Interp), Some(InjectKind::Trap));
        assert_eq!(plan.lookup(3, 1, EngineKind::Decoded), Some(InjectKind::Panic));
        assert_eq!(plan.lookup(4, 1, EngineKind::Decoded), None);
    }
}
