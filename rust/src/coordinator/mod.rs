//! L3 coordinator: the migration/benchmark pipeline. Runs the
//! (kernel x mode x vlen) job matrix across a worker-thread pool
//! (std::thread — the work is CPU-bound simulation, no async needed),
//! verifies translated outputs against the NEON interpretation and the
//! JAX/XLA golden oracle, and aggregates the Figure 2 rows.
//!
//! # Translation + decode cache
//!
//! Translating a kernel and decoding it for the pre-decoded engine is a
//! pure function of `(kernel, mode, vlen)` for the suite's default shapes
//! (the only shapes reachable through [`kernels::by_name`]). The
//! coordinator therefore memoises the `(RvvProgram, DecodedProgram)` pair
//! in a process-wide [`TranslationCache`] of `Arc`-shared
//! [`CachedProgram`]s: `run_matrix`, `figure2`, and the vlen-sweep benches
//! translate each program once and every subsequent job — from any worker
//! thread — reuses the decoded artifact. Custom-shaped cases (e.g.
//! `kernels::suite_small()`) bypass the cache by construction, since the
//! cache key is the kernel *name* and their programs differ from the
//! default shapes.
//!
//! # Engines
//!
//! Jobs default to the pre-decoded lane-batched [`Engine`]; the
//! tree-walking [`Simulator`] remains available through
//! [`EngineKind::Interp`] as the differential-testing oracle and the
//! pre-PR baseline for `benches/sim_throughput.rs`. Both produce
//! bit-identical buffers and equal [`SimStats`] (see the `sim` module
//! docs).

mod verify;

pub use verify::{verify_kernel, VerifyOutcome};

use std::collections::{HashMap, VecDeque};
use std::sync::mpsc;
use std::sync::{Arc, Mutex, OnceLock};
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::kernels::{self, KernelCase};
use crate::rvv::machine::RvvConfig;
use crate::rvv::program::RvvProgram;
use crate::sim::{decode, DecodedProgram, Engine, SimStats, Simulator};
use crate::simde::{Mode, Translator};

/// One unit of work.
#[derive(Debug, Clone)]
pub struct Job {
    pub kernel: &'static str,
    pub mode: Mode,
    pub vlen: u32,
}

/// Which execution engine a job runs on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EngineKind {
    /// Tree-walking interpreter (`sim::Simulator`) — the reference.
    Interp,
    /// Pre-decoded lane-batched engine (`sim::Engine`) — the default.
    Decoded,
}

/// Result of one simulated job.
#[derive(Debug, Clone)]
pub struct JobResult {
    pub job: Job,
    pub stats: SimStats,
    pub wall: Duration,
}

/// A translated + decoded program, shared across jobs via `Arc`.
#[derive(Debug)]
pub struct CachedProgram {
    pub rvv: RvvProgram,
    pub decoded: DecodedProgram,
}

/// Process-wide memo of translation + decode results keyed on
/// (kernel, mode, vlen). Valid only for the suite's default shapes —
/// the `by_name` path — because the key carries no shape information.
#[derive(Default)]
pub struct TranslationCache {
    map: Mutex<HashMap<(&'static str, Mode, u32), Arc<CachedProgram>>>,
}

impl TranslationCache {
    /// Fetch the decoded program for `job`, translating + decoding on
    /// first use. Concurrent misses on the same key may translate twice;
    /// the first insert wins and the duplicate is dropped (translation is
    /// pure, so either result is interchangeable).
    pub fn get_or_translate(&self, case: &KernelCase, job: &Job) -> Result<Arc<CachedProgram>> {
        let key = (job.kernel, job.mode, job.vlen);
        if let Some(hit) = self.map.lock().unwrap().get(&key) {
            return Ok(Arc::clone(hit));
        }
        let cfg = RvvConfig::new(job.vlen);
        let (rvv, _) = Translator::new(job.mode, cfg).translate(&case.prog)?;
        let decoded = decode(&rvv);
        let entry = Arc::new(CachedProgram { rvv, decoded });
        let mut map = self.map.lock().unwrap();
        Ok(Arc::clone(map.entry(key).or_insert(entry)))
    }

    /// Number of cached programs (for tests/benches).
    pub fn len(&self) -> usize {
        self.map.lock().unwrap().len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// The shared process-wide cache used by `run_job` and the worker pool.
pub fn translation_cache() -> &'static TranslationCache {
    static CACHE: OnceLock<TranslationCache> = OnceLock::new();
    CACHE.get_or_init(TranslationCache::default)
}

/// Run one job on the default (pre-decoded) engine, via the shared cache.
pub fn run_job(job: &Job) -> Result<JobResult> {
    run_job_engine(job, EngineKind::Decoded)
}

/// Run one job on an explicit engine. `Interp` translates from scratch
/// every time (the pre-PR behaviour); `Decoded` goes through the shared
/// translation cache.
pub fn run_job_engine(job: &Job, engine: EngineKind) -> Result<JobResult> {
    let case = kernels::by_name(job.kernel)
        .with_context(|| format!("unknown kernel '{}'", job.kernel))?;
    let cfg = RvvConfig::new(job.vlen);
    let t0 = Instant::now();
    let stats = match engine {
        EngineKind::Interp => {
            let (rp, _) = Translator::new(job.mode, cfg).translate(&case.prog)?;
            let (_, stats) = Simulator::new(&rp, cfg, &case.inputs)?.run()?;
            stats
        }
        EngineKind::Decoded => {
            let cached = translation_cache().get_or_translate(&case, job)?;
            let (_, stats) = Engine::new(&cached.rvv, &cached.decoded, cfg, &case.inputs)?.run()?;
            stats
        }
    };
    Ok(JobResult { job: job.clone(), stats, wall: t0.elapsed() })
}

/// Run a job list across `threads` workers; results in input order.
pub fn run_matrix(jobs: Vec<Job>, threads: usize) -> Result<Vec<JobResult>> {
    run_matrix_engine(jobs, threads, EngineKind::Decoded)
}

/// `run_matrix` with an explicit engine choice.
///
/// On a failed job the queue is drained (no new work is handed out), the
/// remaining in-flight results are received, and every worker is joined
/// *before* the first error propagates — an early return here used to
/// leave detached workers still executing against a dropped receiver.
pub fn run_matrix_engine(
    jobs: Vec<Job>,
    threads: usize,
    engine: EngineKind,
) -> Result<Vec<JobResult>> {
    let n = jobs.len();
    let queue: Arc<Mutex<VecDeque<(usize, Job)>>> =
        Arc::new(Mutex::new(jobs.into_iter().enumerate().collect()));
    let (tx, rx) = mpsc::channel::<(usize, Result<JobResult>)>();

    let workers: Vec<_> = (0..threads.max(1))
        .map(|_| {
            let queue = Arc::clone(&queue);
            let tx = tx.clone();
            std::thread::spawn(move || loop {
                let next = queue.lock().unwrap().pop_front();
                match next {
                    Some((idx, job)) => {
                        let r = run_job_engine(&job, engine);
                        if tx.send((idx, r)).is_err() {
                            return;
                        }
                    }
                    None => return,
                }
            })
        })
        .collect();
    drop(tx);

    let mut slots: Vec<Option<JobResult>> = (0..n).map(|_| None).collect();
    let mut first_err: Option<anyhow::Error> = None;
    for (idx, r) in rx {
        match r {
            Ok(jr) => slots[idx] = Some(jr),
            Err(e) => {
                if first_err.is_none() {
                    // stop handing out work; keep draining so workers can
                    // finish their in-flight jobs and exit
                    queue.lock().unwrap().clear();
                    first_err = Some(e);
                }
            }
        }
    }
    for w in workers {
        w.join().expect("worker panicked");
    }
    if let Some(e) = first_err {
        return Err(e);
    }
    Ok(slots.into_iter().map(|s| s.expect("missing result")).collect())
}

/// One Figure 2 row.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub kernel: &'static str,
    pub baseline: u64,
    pub custom: u64,
    pub speedup: f64,
}

/// The (kernel × mode) job list behind the Figure 2 table at one vlen.
pub fn figure2_jobs(vlen: u32) -> Vec<Job> {
    let mut jobs = Vec::new();
    for name in kernels::NAMES {
        jobs.push(Job { kernel: name, mode: Mode::Baseline, vlen });
        jobs.push(Job { kernel: name, mode: Mode::RvvCustom, vlen });
    }
    jobs
}

/// Compute the Figure 2 table at a given vlen across the worker pool.
pub fn figure2(vlen: u32, threads: usize) -> Result<Vec<Fig2Row>> {
    figure2_with(vlen, threads, EngineKind::Decoded)
}

/// `figure2` with an explicit engine choice (used by the throughput bench
/// to compare engines on identical work).
pub fn figure2_with(vlen: u32, threads: usize, engine: EngineKind) -> Result<Vec<Fig2Row>> {
    let results = run_matrix_engine(figure2_jobs(vlen), threads, engine)?;
    let rows = results
        .chunks(2)
        .map(|pair| {
            let (b, c) = (&pair[0], &pair[1]);
            debug_assert_eq!(b.job.kernel, c.job.kernel);
            Fig2Row {
                kernel: b.job.kernel,
                baseline: b.stats.total(),
                custom: c.stats.total(),
                speedup: b.stats.total() as f64 / c.stats.total() as f64,
            }
        })
        .collect();
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matrix_runs_in_parallel_and_preserves_order() {
        let jobs = vec![
            Job { kernel: "vrelu", mode: Mode::Baseline, vlen: 128 },
            Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 },
            Job { kernel: "maxpool", mode: Mode::RvvCustom, vlen: 128 },
        ];
        let results = run_matrix(jobs, 3).unwrap();
        assert_eq!(results.len(), 3);
        assert_eq!(results[0].job.kernel, "vrelu");
        assert_eq!(results[0].job.mode, Mode::Baseline);
        assert_eq!(results[2].job.kernel, "maxpool");
        assert!(results[0].stats.total() > results[1].stats.total());
    }

    #[test]
    fn unknown_kernel_is_an_error() {
        let jobs = vec![Job { kernel: "nope", mode: Mode::Baseline, vlen: 128 }];
        assert!(run_matrix(jobs, 1).is_err());
    }

    #[test]
    fn failed_job_still_joins_workers_and_reports_first_error() {
        // one bad job sandwiched between good ones, more jobs than threads
        // so the queue-drain path is exercised
        let mut jobs = vec![
            Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 },
            Job { kernel: "nope", mode: Mode::Baseline, vlen: 128 },
        ];
        for _ in 0..6 {
            jobs.push(Job { kernel: "vsqrt", mode: Mode::RvvCustom, vlen: 128 });
        }
        let err = run_matrix(jobs, 2).unwrap_err();
        assert!(err.to_string().contains("nope"), "unexpected error: {err:#}");
    }

    #[test]
    fn engines_agree_and_cache_fills() {
        let job = Job { kernel: "vrelu", mode: Mode::RvvCustom, vlen: 128 };
        let a = run_job_engine(&job, EngineKind::Interp).unwrap();
        let b = run_job_engine(&job, EngineKind::Decoded).unwrap();
        assert_eq!(a.stats, b.stats);
        // second decoded run hits the cache and still agrees
        let c = run_job_engine(&job, EngineKind::Decoded).unwrap();
        assert_eq!(b.stats, c.stats);
        assert!(!translation_cache().is_empty());
    }
}
