//! Verification: translated-program outputs vs the NEON golden
//! interpretation, and (when artifacts are available) vs the JAX/XLA
//! oracle — the reproduction of the paper's §4.1 validation workflow.

use anyhow::{bail, Context, Result};

use crate::ir::BufKind;
use crate::kernels::KernelCase;
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, NeonInterp};
use crate::runtime::GoldenOracle;
use crate::rvv::machine::RvvConfig;
use crate::sim::Simulator;
use crate::simde::{Mode, Translator};
use crate::testutil::max_abs_diff;

/// Per-mode, per-output comparison outcome for one kernel.
#[derive(Debug, Clone)]
pub struct VerifyOutcome {
    pub kernel: &'static str,
    /// (mode, output name, max |diff| vs NEON interp) — integer outputs
    /// report 0.0 only on exact match.
    pub vs_neon: Vec<(Mode, String, f32)>,
    /// (output name, max |diff| of NEON interp vs XLA oracle), empty if no
    /// oracle was supplied.
    pub vs_golden: Vec<(String, f32)>,
    pub passed: bool,
}

/// Ordered output buffer names (declaration order, matching the golden
/// artifact's positional outputs).
fn output_names(case: &KernelCase) -> Vec<String> {
    case.prog
        .bufs
        .iter()
        .filter(|b| b.kind == BufKind::Output)
        .map(|b| b.name.clone())
        .collect()
}

/// Ordered input buffers (declaration order, matching the golden
/// artifact's positional inputs).
fn ordered_inputs<'a>(case: &'a KernelCase) -> Vec<&'a Buffer> {
    case.prog
        .bufs
        .iter()
        .filter(|b| b.kind == BufKind::Input)
        .map(|b| &case.inputs[&b.name])
        .collect()
}

fn diff_buffers(a: &Buffer, b: &Buffer) -> Result<f32> {
    if a.elem.is_float() {
        Ok(max_abs_diff(&a.as_f32s(), &b.as_f32s()))
    } else if a.data == b.data {
        Ok(0.0)
    } else {
        bail!("integer outputs differ")
    }
}

/// Verify one kernel under both translation modes, optionally against the
/// XLA oracle.
pub fn verify_kernel(
    case: &KernelCase,
    vlen: u32,
    oracle: Option<&GoldenOracle>,
) -> Result<VerifyOutcome> {
    let cfg = RvvConfig::new(vlen);
    let neon_out = NeonInterp::new(&case.prog, &case.inputs)?
        .run()
        .with_context(|| format!("{}: NEON interpretation", case.name))?;

    let mut vs_neon = Vec::new();
    let mut passed = true;
    for mode in [Mode::RvvCustom, Mode::Baseline] {
        let (rp, _) = Translator::new(mode, cfg).translate(&case.prog)?;
        let (out, _) = Simulator::new(&rp, cfg, &case.inputs)?.run()?;
        for name in output_names(case) {
            let d = diff_buffers(&out[&name], &neon_out[&name])
                .with_context(|| format!("{} {mode:?} output {name}", case.name))?;
            if d > case.sim_tol {
                passed = false;
            }
            vs_neon.push((mode, name, d));
        }
    }

    let mut vs_golden = Vec::new();
    if let Some(oracle) = oracle {
        let golden = oracle
            .run(case.name, &ordered_inputs(case))
            .with_context(|| format!("{}: golden oracle", case.name))?;
        for (name, gbuf) in output_names(case).into_iter().zip(golden) {
            let nbuf = &neon_out[&name];
            let d = if nbuf.elem == Elem::F32 {
                max_abs_diff(&nbuf.as_f32s(), &gbuf.as_f32s())
            } else if nbuf.data == gbuf.data {
                0.0
            } else {
                passed = false;
                f32::INFINITY
            };
            if d > case.golden_tol {
                passed = false;
            }
            vs_golden.push((name, d));
        }
    }

    Ok(VerifyOutcome { kernel: case.name, vs_neon, vs_golden, passed })
}
