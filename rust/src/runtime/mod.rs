//! JAX/XLA golden oracle: loads the AOT-compiled HLO-text artifacts
//! produced by `make artifacts` and executes them on the PJRT CPU client.
//!
//! This is the only place python-originated code runs — at build time it
//! was lowered to HLO; at run time the Rust binary is self-contained.
//! Pattern from /opt/xla-example/load_hlo (HLO *text* interchange; see
//! that README for why serialized protos are rejected).

use std::collections::HashMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use crate::neon::elem::Elem;
use crate::neon::interp::Buffer;

/// Parsed manifest row: op name + input/output shapes.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    pub inputs: Vec<(String, Vec<i64>)>,
    pub outputs: Vec<(String, Vec<i64>)>,
}

fn parse_shape(s: &str) -> Result<(String, Vec<i64>)> {
    // "f32[64,64]" or "uint32[16,16,16]"
    let (dtype, rest) = s.split_once('[').context("missing '[' in shape")?;
    let dims = rest
        .trim_end_matches(']')
        .split(',')
        .filter(|d| !d.is_empty())
        .map(|d| d.parse::<i64>().context("bad dim"))
        .collect::<Result<Vec<_>>>()?;
    Ok((dtype.to_string(), dims))
}

/// Parse `artifacts/manifest.txt`.
pub fn parse_manifest(path: &Path) -> Result<Vec<ManifestEntry>> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading {path:?} — run `make artifacts` first"))?;
    let mut out = Vec::new();
    for line in text.lines().filter(|l| !l.trim().is_empty()) {
        let parts: Vec<&str> = line.split(';').collect();
        if parts.len() != 5 {
            bail!("bad manifest line: {line}");
        }
        let inputs = parts[3].split('+').map(parse_shape).collect::<Result<Vec<_>>>()?;
        let outputs = parts[4].split('+').map(parse_shape).collect::<Result<Vec<_>>>()?;
        out.push(ManifestEntry { name: parts[0].to_string(), inputs, outputs });
    }
    Ok(out)
}

/// The oracle: one compiled executable per golden op.
pub struct GoldenOracle {
    client: xla::PjRtClient,
    exes: HashMap<String, xla::PjRtLoadedExecutable>,
    manifest: HashMap<String, ManifestEntry>,
    dir: PathBuf,
}

impl GoldenOracle {
    /// Load and compile every artifact listed in the manifest.
    pub fn load(dir: &Path) -> Result<GoldenOracle> {
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        let entries = parse_manifest(&dir.join("manifest.txt"))?;
        let mut exes = HashMap::new();
        let mut manifest = HashMap::new();
        for e in entries {
            let path = dir.join(format!("{}.hlo.txt", e.name));
            let proto = xla::HloModuleProto::from_text_file(
                path.to_str().context("non-utf8 path")?,
            )
            .with_context(|| format!("parsing {path:?}"))?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp).with_context(|| format!("compiling {}", e.name))?;
            exes.insert(e.name.clone(), exe);
            manifest.insert(e.name.clone(), e);
        }
        Ok(GoldenOracle { client, exes, manifest, dir: dir.to_path_buf() })
    }

    pub fn ops(&self) -> Vec<&str> {
        let mut v: Vec<&str> = self.exes.keys().map(|s| s.as_str()).collect();
        v.sort();
        v
    }

    pub fn manifest(&self, op: &str) -> Option<&ManifestEntry> {
        self.manifest.get(op)
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Execute a golden op on positional input buffers, returning output
    /// buffers (f32 or u32 per the manifest).
    pub fn run(&self, op: &str, inputs: &[&Buffer]) -> Result<Vec<Buffer>> {
        let exe = self.exes.get(op).with_context(|| format!("unknown golden op '{op}'"))?;
        let entry = &self.manifest[op];
        if inputs.len() != entry.inputs.len() {
            bail!("{op}: {} inputs given, manifest wants {}", inputs.len(), entry.inputs.len());
        }
        let mut literals = Vec::with_capacity(inputs.len());
        for (buf, (dtype, dims)) in inputs.iter().zip(&entry.inputs) {
            if dtype != "f32" {
                bail!("{op}: non-f32 input in manifest ({dtype})");
            }
            let want: i64 = dims.iter().product();
            if buf.len_elems() as i64 != want {
                bail!("{op}: input has {} elems, manifest wants {want}", buf.len_elems());
            }
            let lit = xla::Literal::vec1(&buf.as_f32s()).reshape(dims)?;
            literals.push(lit);
        }
        let result = exe.execute::<xla::Literal>(&literals)?[0][0]
            .to_literal_sync()?
            .to_tuple()?;
        if result.len() != entry.outputs.len() {
            bail!("{op}: got {} outputs, manifest wants {}", result.len(), entry.outputs.len());
        }
        let mut out = Vec::with_capacity(result.len());
        for (lit, (dtype, _)) in result.into_iter().zip(&entry.outputs) {
            match dtype.as_str() {
                "float32" | "f32" => {
                    out.push(Buffer::from_f32s(&lit.to_vec::<f32>()?));
                }
                "uint32" | "u32" => {
                    out.push(Buffer::from_u32s(&lit.to_vec::<u32>()?));
                }
                other => bail!("{op}: unsupported output dtype {other}"),
            }
        }
        Ok(out)
    }
}

impl std::fmt::Debug for GoldenOracle {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("GoldenOracle")
            .field("ops", &self.ops())
            .field("dir", &self.dir)
            .finish()
    }
}

/// Map a golden output dtype string to our buffer elem (for checks).
pub fn dtype_elem(dtype: &str) -> Option<Elem> {
    match dtype {
        "float32" | "f32" => Some(Elem::F32),
        "uint32" | "u32" => Some(Elem::U32),
        "int32" | "i32" => Some(Elem::I32),
        _ => None,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn manifest_shape_parser() {
        let (d, dims) = parse_shape("f32[64,64]").unwrap();
        assert_eq!(d, "f32");
        assert_eq!(dims, vec![64, 64]);
        let (d, dims) = parse_shape("uint32[16,16,16]").unwrap();
        assert_eq!(d, "uint32");
        assert_eq!(dims, vec![16, 16, 16]);
        assert!(parse_shape("garbage").is_err());
    }
}
