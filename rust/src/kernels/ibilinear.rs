//! IBILINEAR: 2x bilinear upsampling over a C-channel image (XNNPACK
//! ibilinear pattern: per output pixel, `top = tl + a*(tr-tl)`,
//! `bottom = bl + a*(br-bl)`, `out = top + b*(bottom-top)` — sub + fma
//! chains over channel q-registers).
//!
//! Output grid: out (2(H-1), 2(W-1)) with sample offsets a,b in
//! {0.25, 0.75} (align_corners=false style interior samples).

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::KernelCase;

pub const WEIGHTS: [f64; 2] = [0.25, 0.75];

/// `h` = square input side, `c` = channels (multiple of 4).
pub fn program(h: usize, c: usize) -> Program {
    assert_eq!(c % 4, 0);
    let oh = 2 * (h - 1);
    let f = Elem::F32;
    let mut b = ProgramBuilder::new("ibilinear");
    let i_buf = b.input("I", Elem::F32, h * h * c);
    let o_buf = b.output("O", Elem::F32, oh * oh * c);
    // hoisted weight broadcasts (two distinct sample offsets)
    let w_lo = b.vop(Family::DupN, f, true, vec![Arg::ImmF(WEIGHTS[0])]);
    let w_hi = b.vop(Family::DupN, f, true, vec![Arg::ImmF(WEIGHTS[1])]);
    let wreg = [w_lo, w_hi];

    b.loop_(0, (h - 1) as i64, 1, |b, sy| {
        b.loop_(0, (h - 1) as i64, 1, |b, sx| {
            b.loop_(0, c as i64, 4, |b, ci| {
                let corner = |dy: i64, dx: i64| {
                    AddrExpr::s(sy)
                        .addk(dy)
                        .mul((h * c) as i64)
                        .add(AddrExpr::s(sx).addk(dx).mul(c as i64))
                        .add(AddrExpr::s(ci))
                };
                let tl = b.vop(Family::Ld1, f, true, vec![Arg::mem(i_buf, corner(0, 0))]);
                let tr = b.vop(Family::Ld1, f, true, vec![Arg::mem(i_buf, corner(0, 1))]);
                let bl = b.vop(Family::Ld1, f, true, vec![Arg::mem(i_buf, corner(1, 0))]);
                let br = b.vop(Family::Ld1, f, true, vec![Arg::mem(i_buf, corner(1, 1))]);
                let dtop = b.vop(Family::Sub, f, true, vec![Arg::V(tr), Arg::V(tl)]);
                let dbot = b.vop(Family::Sub, f, true, vec![Arg::V(br), Arg::V(bl)]);
                for dy in 0..2usize {
                    for dx in 0..2usize {
                        let av = wreg[dx];
                        let bv = wreg[dy];
                        let top = b.vop(Family::Fma, f, true, vec![Arg::V(tl), Arg::V(dtop), Arg::V(av)]);
                        let bot = b.vop(Family::Fma, f, true, vec![Arg::V(bl), Arg::V(dbot), Arg::V(av)]);
                        let dv = b.vop(Family::Sub, f, true, vec![Arg::V(bot), Arg::V(top)]);
                        let out = b.vop(Family::Fma, f, true, vec![Arg::V(top), Arg::V(dv), Arg::V(bv)]);
                        let oidx = AddrExpr::s(sy)
                            .mul(2)
                            .addk(dy as i64)
                            .mul(oh as i64)
                            .add(AddrExpr::s(sx).mul(2).addk(dx as i64))
                            .mul(c as i64)
                            .add(AddrExpr::s(ci));
                        b.vstore(Family::St1, f, true, vec![Arg::mem(o_buf, oidx), Arg::V(out)]);
                    }
                }
            });
        });
    });
    b.finish()
}

pub fn inputs(h: usize, c: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("I".into(), Buffer::from_f32s(&rng.f32s(h * h * c, -2.0, 2.0)));
    i
}

pub fn build(h: usize, c: usize) -> KernelCase {
    KernelCase {
        name: "ibilinear",
        description: "2x bilinear upsampling (sub+fma interpolation chains)",
        prog: program(h, c),
        inputs: inputs(h, c, 0xb111),
        sim_tol: 1e-5,
        golden_tol: 1e-4,
    }
}

/// Figure 2 default: 17x17x4 -> 32x32x4.
pub fn case() -> KernelCase {
    build(17, 4)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;

    #[test]
    fn matches_scalar_reference() {
        let (h, c) = (5, 4);
        let case = build(h, c);
        let oh = 2 * (h - 1);
        let img = case.inputs["I"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();
        let got = out["O"].as_f32s();
        for sy in 0..h - 1 {
            for sx in 0..h - 1 {
                for ch in 0..c {
                    let at = |y: usize, x: usize| img[(y * h + x) * c + ch];
                    for (dy, wb) in WEIGHTS.iter().enumerate() {
                        for (dx, wa) in WEIGHTS.iter().enumerate() {
                            let (wa, wb) = (*wa as f32, *wb as f32);
                            let top = at(sy, sx) + wa * (at(sy, sx + 1) - at(sy, sx));
                            let bot = at(sy + 1, sx) + wa * (at(sy + 1, sx + 1) - at(sy + 1, sx));
                            let want = top + wb * (bot - top);
                            let o = ((2 * sy + dy) * oh + 2 * sx + dx) * c + ch;
                            assert!(
                                (got[o] - want).abs() < 1e-5,
                                "pixel ({},{}) ch {}: {} vs {}",
                                2 * sy + dy,
                                2 * sx + dx,
                                ch,
                                got[o],
                                want
                            );
                        }
                    }
                }
            }
        }
    }
}
