//! VSIGMOID: elementwise logistic function, XNNPACK rr2-p5 pattern:
//! `sigmoid(x) = e / (1 + e)` with `e = exp(-|x|)`, reciprocal by
//! `vrecpeq` Newton, and a final compare+bitselect to mirror the
//! positive half (`sigmoid(x) = 1 - sigmoid(-x)`).

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::expmath::{emit_exp_neg, emit_recip, ExpConsts};
use super::KernelCase;

pub fn program(n: usize) -> Program {
    assert_eq!(n % 4, 0);
    let f = Elem::F32;
    let mut b = ProgramBuilder::new("vsigmoid");
    let x_buf = b.input("X", Elem::F32, n);
    let y_buf = b.output("Y", Elem::F32, n);
    // hoisted loop invariants (clang hoists vdupq_n of constants)
    let k = ExpConsts::hoist(&mut b);
    let zero = b.vop(Family::DupN, f, true, vec![Arg::ImmF(0.0)]);
    b.loop_(0, n as i64, 4, |b, i| {
        let x = b.vop(Family::Ld1, f, true, vec![Arg::mem(x_buf, AddrExpr::s(i))]);
        let z = b.vop(Family::Abs, f, true, vec![Arg::V(x)]);
        let e = emit_exp_neg(b, &k, z); // exp(-|x|)
        // d = 1 + e ; s = e / d  (= sigmoid(-|x|))
        let one = k.one();
        let d = b.vop(Family::Add, f, true, vec![Arg::V(e), Arg::V(one)]);
        let rcp = emit_recip(b, d);
        let s_neg = b.vop(Family::Mul, f, true, vec![Arg::V(e), Arg::V(rcp)]);
        // y = x < 0 ? s_neg : 1 - s_neg
        let s_pos = b.vop(Family::Sub, f, true, vec![Arg::V(one), Arg::V(s_neg)]);
        let mneg = b.vop(Family::Clt, f, true, vec![Arg::V(x), Arg::V(zero)]);
        let y = b.vop(Family::Bsl, f, true, vec![Arg::V(mneg), Arg::V(s_neg), Arg::V(s_pos)]);
        b.vstore(Family::St1, f, true, vec![Arg::mem(y_buf, AddrExpr::s(i)), Arg::V(y)]);
    });
    b.finish()
}

pub fn inputs(n: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("X".into(), Buffer::from_f32s(&rng.f32s(n, -8.0, 8.0)));
    i
}

pub fn build(n: usize) -> KernelCase {
    KernelCase {
        name: "vsigmoid",
        description: "elementwise sigmoid (exp rr2-p5 + vrecpe Newton + bitselect)",
        prog: program(n),
        inputs: inputs(n, 0x516),
        sim_tol: 1e-5,
        golden_tol: 5e-3,
    }
}

/// Figure 2 default: n = 8192.
pub fn case() -> KernelCase {
    build(8192)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;
    use crate::testutil::max_abs_diff;

    #[test]
    fn matches_libm_sigmoid() {
        let case = build(256);
        let x = case.inputs["X"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();
        let want: Vec<f32> = x.iter().map(|v| 1.0 / (1.0 + (-v).exp())).collect();
        let d = max_abs_diff(&out["Y"].as_f32s(), &want);
        assert!(d < 1e-5, "sigmoid abs err {d}");
    }

    #[test]
    fn symmetry() {
        // sigmoid(x) + sigmoid(-x) == 1 by construction of the bitselect
        let mut inputs = Inputs::new();
        let xs: Vec<f32> = (0..64).map(|i| (i as f32 - 32.0) / 4.0).collect();
        inputs.insert("X".into(), Buffer::from_f32s(&xs));
        let p = program(64);
        let out = NeonInterp::new(&p, &inputs).unwrap().run().unwrap();
        let y = out["Y"].as_f32s();
        for i in 0..32 {
            let a = y[i];
            let b = y[63 - i + 1 - 1];
            if (xs[i] + xs[63 - i]).abs() < 1e-6 {
                assert!((a + b - 1.0).abs() < 1e-5);
            }
        }
    }
}
