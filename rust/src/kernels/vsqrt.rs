//! VSQRT: elementwise square root via `vrsqrteq_f32` estimate + two
//! `vrsqrtsq_f32` Newton steps + final multiply — exactly XNNPACK's
//! neon-rsqrt pattern (A32 NEON has no vector sqrt instruction).

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::KernelCase;

pub fn program(n: usize) -> Program {
    assert_eq!(n % 4, 0);
    let mut b = ProgramBuilder::new("vsqrt");
    let x_buf = b.input("X", Elem::F32, n);
    let y_buf = b.output("Y", Elem::F32, n);
    b.loop_(0, n as i64, 4, |b, i| {
        let x = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(x_buf, AddrExpr::s(i))]);
        // t ~= 1/sqrt(x)
        let mut t = b.vop(Family::Rsqrte, Elem::F32, true, vec![Arg::V(x)]);
        for _ in 0..2 {
            // t *= (3 - x*t*t) / 2
            let u = b.vop(Family::Mul, Elem::F32, true, vec![Arg::V(x), Arg::V(t)]);
            let s = b.vop(Family::Rsqrts, Elem::F32, true, vec![Arg::V(u), Arg::V(t)]);
            t = b.vop(Family::Mul, Elem::F32, true, vec![Arg::V(t), Arg::V(s)]);
        }
        // sqrt(x) = x * rsqrt(x)
        let y = b.vop(Family::Mul, Elem::F32, true, vec![Arg::V(x), Arg::V(t)]);
        b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(y_buf, AddrExpr::s(i)), Arg::V(y)]);
    });
    b.finish()
}

/// Inputs strictly positive (XNNPACK vsqrt assumes non-negative input; we
/// keep away from 0 so the rsqrt path needs no zero-select).
pub fn inputs(n: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("X".into(), Buffer::from_f32s(&rng.f32s(n, 0.01, 100.0)));
    i
}

pub fn build(n: usize) -> KernelCase {
    KernelCase {
        name: "vsqrt",
        description: "elementwise sqrt (vrsqrte + 2 Newton steps)",
        prog: program(n),
        inputs: inputs(n, 0x5a4d),
        sim_tol: 1e-5,
        golden_tol: 1e-3,
    }
}

/// Figure 2 default: n = 16384.
pub fn case() -> KernelCase {
    build(16384)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;
    use crate::testutil::max_rel_diff;

    #[test]
    fn converges_to_sqrt() {
        let case = build(256);
        let x = case.inputs["X"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();
        let want: Vec<f32> = x.iter().map(|v| v.sqrt()).collect();
        let rel = max_rel_diff(&out["Y"].as_f32s(), &want);
        assert!(rel < 1e-5, "rel err {rel}");
    }
}
