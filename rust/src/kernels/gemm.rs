//! GEMM: f32 matrix multiply `C[M,N] = A[M,K] x B[K,N]`, XNNPACK-style
//! microkernel — per (m, n-block) a q-register accumulator fed by
//! broadcast-A x row-of-B `vfmaq_f32` (NR = 4).

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::KernelCase;

pub fn program(m: usize, k: usize, n: usize) -> Program {
    assert_eq!(n % 4, 0, "N must be a multiple of NR=4");
    let mut b = ProgramBuilder::new("gemm");
    let a_buf = b.input("A", Elem::F32, m * k);
    let b_buf = b.input("B", Elem::F32, k * n);
    let c_buf = b.output("C", Elem::F32, m * n);

    b.loop_(0, m as i64, 1, |b, mi| {
        b.loop_(0, n as i64, 4, |b, ni| {
            let acc = b.vop(Family::DupN, Elem::F32, true, vec![Arg::ImmF(0.0)]);
            b.loop_(0, k as i64, 1, |b, ki| {
                // a = broadcast A[m*K + k]
                let a = b.vop(
                    Family::Ld1Dup,
                    Elem::F32,
                    true,
                    vec![Arg::mem(a_buf, AddrExpr::s(mi).mul(k as i64).add(AddrExpr::s(ki)))],
                );
                // bv = B[k*N + n .. +4]
                let bv = b.vop(
                    Family::Ld1,
                    Elem::F32,
                    true,
                    vec![Arg::mem(b_buf, AddrExpr::s(ki).mul(n as i64).add(AddrExpr::s(ni)))],
                );
                // acc += a * bv (fused)
                b.vop_into(acc, Family::Fma, Elem::F32, true, vec![Arg::V(acc), Arg::V(a), Arg::V(bv)]);
            });
            b.vstore(
                Family::St1,
                Elem::F32,
                true,
                vec![
                    Arg::mem(c_buf, AddrExpr::s(mi).mul(n as i64).add(AddrExpr::s(ni))),
                    Arg::V(acc),
                ],
            );
        });
    });
    b.finish()
}

pub fn inputs(m: usize, k: usize, n: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("A".into(), Buffer::from_f32s(&rng.f32s(m * k, -1.0, 1.0)));
    i.insert("B".into(), Buffer::from_f32s(&rng.f32s(k * n, -1.0, 1.0)));
    i
}

pub fn build(m: usize, k: usize, n: usize) -> KernelCase {
    KernelCase {
        name: "gemm",
        description: "f32 GEMM microkernel (vfmaq accumulators, NR=4)",
        prog: program(m, k, n),
        inputs: inputs(m, k, n, 0x9e3779b9),
        sim_tol: 1e-4,
        golden_tol: 1e-3,
    }
}

/// Figure 2 default: 64x64x64.
pub fn case() -> KernelCase {
    build(64, 64, 64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;

    /// Scalar reference.
    fn gemm_ref(m: usize, k: usize, n: usize, a: &[f32], b: &[f32]) -> Vec<f32> {
        let mut c = vec![0f32; m * n];
        for i in 0..m {
            for j in 0..n {
                let mut acc = 0f32;
                for p in 0..k {
                    acc = a[i * k + p].mul_add(b[p * n + j], acc);
                }
                c[i * n + j] = acc;
            }
        }
        c
    }

    #[test]
    fn matches_scalar_reference() {
        let (m, k, n) = (8, 12, 8);
        let case = build(m, k, n);
        let a = case.inputs["A"].as_f32s();
        let b = case.inputs["B"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();
        let want = gemm_ref(m, k, n, &a, &b);
        crate::testutil::assert_close(&out["C"].as_f32s(), &want, 1e-4, "gemm");
    }
}
