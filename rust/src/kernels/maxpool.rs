//! MAXPOOL: 2x2 stride-2 max pooling over HWC layout (`vmaxq_f32` tree).

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::KernelCase;

pub fn program(h: usize, c: usize) -> Program {
    assert_eq!(h % 2, 0);
    assert_eq!(c % 4, 0);
    let oh = h / 2;
    let mut b = ProgramBuilder::new("maxpool");
    let i_buf = b.input("I", Elem::F32, h * h * c);
    let o_buf = b.output("O", Elem::F32, oh * oh * c);

    b.loop_(0, oh as i64, 1, |b, oy| {
        b.loop_(0, oh as i64, 1, |b, ox| {
            b.loop_(0, c as i64, 4, |b, ci| {
                let at = |dy: i64, dx: i64| {
                    AddrExpr::s(oy)
                        .mul(2)
                        .addk(dy)
                        .mul((h * c) as i64)
                        .add(AddrExpr::s(ox).mul(2).addk(dx).mul(c as i64))
                        .add(AddrExpr::s(ci))
                };
                let v0 = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(i_buf, at(0, 0))]);
                let v1 = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(i_buf, at(0, 1))]);
                let v2 = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(i_buf, at(1, 0))]);
                let v3 = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(i_buf, at(1, 1))]);
                let m01 = b.vop(Family::Max, Elem::F32, true, vec![Arg::V(v0), Arg::V(v1)]);
                let m23 = b.vop(Family::Max, Elem::F32, true, vec![Arg::V(v2), Arg::V(v3)]);
                let m = b.vop(Family::Max, Elem::F32, true, vec![Arg::V(m01), Arg::V(m23)]);
                let oidx = AddrExpr::s(oy)
                    .mul(oh as i64)
                    .add(AddrExpr::s(ox))
                    .mul(c as i64)
                    .add(AddrExpr::s(ci));
                b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(o_buf, oidx), Arg::V(m)]);
            });
        });
    });
    b.finish()
}

pub fn inputs(h: usize, c: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("I".into(), Buffer::from_f32s(&rng.f32s(h * h * c, -4.0, 4.0)));
    i
}

pub fn build(h: usize, c: usize) -> KernelCase {
    KernelCase {
        name: "maxpool",
        description: "2x2 stride-2 max pooling (vmaxq tree)",
        prog: program(h, c),
        inputs: inputs(h, c, 0xfeed),
        sim_tol: 0.0,
        golden_tol: 0.0,
    }
}

/// Figure 2 default: 32x32x16 -> 16x16x16.
pub fn case() -> KernelCase {
    build(32, 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;

    #[test]
    fn matches_scalar_reference() {
        let (h, c) = (8, 8);
        let case = build(h, c);
        let oh = h / 2;
        let i = case.inputs["I"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();
        let mut want = vec![0f32; oh * oh * c];
        for oy in 0..oh {
            for ox in 0..oh {
                for ch in 0..c {
                    let v = [
                        i[(2 * oy * h + 2 * ox) * c + ch],
                        i[(2 * oy * h + 2 * ox + 1) * c + ch],
                        i[((2 * oy + 1) * h + 2 * ox) * c + ch],
                        i[((2 * oy + 1) * h + 2 * ox + 1) * c + ch],
                    ];
                    want[(oy * oh + ox) * c + ch] = v.iter().fold(f32::MIN, |a, &x| a.max(x));
                }
            }
        }
        crate::testutil::assert_close(&out["O"].as_f32s(), &want, 0.0, "maxpool");
    }
}
