//! CONVHWC: 3x3 direct convolution over HWC-layout input, Cout blocked by
//! NR=4 q-register accumulators (XNNPACK conv_hwc pattern: broadcast input
//! pixel x weight row `vfmaq`).

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::KernelCase;

const KH: usize = 3;
const KW: usize = 3;

/// `h` = input height/width (square), `cin`/`cout` channels; valid padding.
pub fn program(h: usize, cin: usize, cout: usize) -> Program {
    assert_eq!(cout % 4, 0);
    let oh = h - KH + 1;
    let mut b = ProgramBuilder::new("convhwc");
    let i_buf = b.input("I", Elem::F32, h * h * cin);
    let w_buf = b.input("W", Elem::F32, KH * KW * cin * cout);
    let bias_buf = b.input("BIAS", Elem::F32, cout);
    let o_buf = b.output("O", Elem::F32, oh * oh * cout);

    b.loop_(0, oh as i64, 1, |b, oy| {
        b.loop_(0, oh as i64, 1, |b, ox| {
            b.loop_(0, cout as i64, 4, |b, co| {
                let acc = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(bias_buf, AddrExpr::s(co))]);
                b.loop_(0, KH as i64, 1, |b, ky| {
                    b.loop_(0, KW as i64, 1, |b, kx| {
                        b.loop_(0, cin as i64, 1, |b, ci| {
                            // x = I[(oy+ky)*H*Cin + (ox+kx)*Cin + ci] broadcast
                            let idx = AddrExpr::s(oy)
                                .add(AddrExpr::s(ky))
                                .mul((h * cin) as i64)
                                .add(AddrExpr::s(ox).add(AddrExpr::s(kx)).mul(cin as i64))
                                .add(AddrExpr::s(ci));
                            let x = b.vop(Family::Ld1Dup, Elem::F32, true, vec![Arg::mem(i_buf, idx)]);
                            // w = W[((ky*KW+kx)*Cin + ci)*Cout + co .. +4]
                            let widx = AddrExpr::s(ky)
                                .mul(KW as i64)
                                .add(AddrExpr::s(kx))
                                .mul(cin as i64)
                                .add(AddrExpr::s(ci))
                                .mul(cout as i64)
                                .add(AddrExpr::s(co));
                            let w = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(w_buf, widx)]);
                            b.vop_into(acc, Family::Fma, Elem::F32, true, vec![Arg::V(acc), Arg::V(x), Arg::V(w)]);
                        });
                    });
                });
                let oidx = AddrExpr::s(oy)
                    .mul(oh as i64)
                    .add(AddrExpr::s(ox))
                    .mul(cout as i64)
                    .add(AddrExpr::s(co));
                b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(o_buf, oidx), Arg::V(acc)]);
            });
        });
    });
    b.finish()
}

pub fn inputs(h: usize, cin: usize, cout: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("I".into(), Buffer::from_f32s(&rng.f32s(h * h * cin, -1.0, 1.0)));
    i.insert("W".into(), Buffer::from_f32s(&rng.f32s(KH * KW * cin * cout, -0.5, 0.5)));
    i.insert("BIAS".into(), Buffer::from_f32s(&rng.f32s(cout, -0.1, 0.1)));
    i
}

pub fn build(h: usize, cin: usize, cout: usize) -> KernelCase {
    KernelCase {
        name: "convhwc",
        description: "3x3 HWC direct convolution, Cout-blocked vfmaq",
        prog: program(h, cin, cout),
        inputs: inputs(h, cin, cout, 0xc0ffee),
        sim_tol: 1e-4,
        golden_tol: 1e-3,
    }
}

/// Figure 2 default: 12x12x8 -> 10x10x16.
pub fn case() -> KernelCase {
    build(12, 8, 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;

    #[test]
    fn matches_scalar_reference() {
        let (h, cin, cout) = (6, 4, 8);
        let case = build(h, cin, cout);
        let oh = h - 2;
        let i = case.inputs["I"].as_f32s();
        let w = case.inputs["W"].as_f32s();
        let bias = case.inputs["BIAS"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();

        let mut want = vec![0f32; oh * oh * cout];
        for oy in 0..oh {
            for ox in 0..oh {
                for co in 0..cout {
                    let mut acc = bias[co];
                    for ky in 0..3 {
                        for kx in 0..3 {
                            for ci in 0..cin {
                                let x = i[((oy + ky) * h + ox + kx) * cin + ci];
                                let wv = w[((ky * 3 + kx) * cin + ci) * cout + co];
                                acc = x.mul_add(wv, acc);
                            }
                        }
                    }
                    want[(oy * oh + ox) * cout + co] = acc;
                }
            }
        }
        crate::testutil::assert_close(&out["O"].as_f32s(), &want, 1e-4, "convhwc");
    }
}
