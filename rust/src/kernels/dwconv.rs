//! DWCONV: 3x3 depthwise convolution, channel-blocked (XNNPACK dwconv
//! pattern: per-channel `vfmaq` of input x weight, no channel reduction).

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::KernelCase;

pub fn program(h: usize, c: usize) -> Program {
    assert_eq!(c % 4, 0);
    let oh = h - 2;
    let mut b = ProgramBuilder::new("dwconv");
    let i_buf = b.input("I", Elem::F32, h * h * c);
    let w_buf = b.input("W", Elem::F32, 9 * c);
    let bias_buf = b.input("BIAS", Elem::F32, c);
    let o_buf = b.output("O", Elem::F32, oh * oh * c);

    b.loop_(0, oh as i64, 1, |b, oy| {
        b.loop_(0, oh as i64, 1, |b, ox| {
            b.loop_(0, c as i64, 4, |b, ci| {
                let acc = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(bias_buf, AddrExpr::s(ci))]);
                b.loop_(0, 3, 1, |b, ky| {
                    b.loop_(0, 3, 1, |b, kx| {
                        let iidx = AddrExpr::s(oy)
                            .add(AddrExpr::s(ky))
                            .mul((h * c) as i64)
                            .add(AddrExpr::s(ox).add(AddrExpr::s(kx)).mul(c as i64))
                            .add(AddrExpr::s(ci));
                        let x = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(i_buf, iidx)]);
                        let widx = AddrExpr::s(ky)
                            .mul(3)
                            .add(AddrExpr::s(kx))
                            .mul(c as i64)
                            .add(AddrExpr::s(ci));
                        let w = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(w_buf, widx)]);
                        b.vop_into(acc, Family::Fma, Elem::F32, true, vec![Arg::V(acc), Arg::V(x), Arg::V(w)]);
                    });
                });
                let oidx = AddrExpr::s(oy)
                    .mul(oh as i64)
                    .add(AddrExpr::s(ox))
                    .mul(c as i64)
                    .add(AddrExpr::s(ci));
                b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(o_buf, oidx), Arg::V(acc)]);
            });
        });
    });
    b.finish()
}

pub fn inputs(h: usize, c: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("I".into(), Buffer::from_f32s(&rng.f32s(h * h * c, -1.0, 1.0)));
    i.insert("W".into(), Buffer::from_f32s(&rng.f32s(9 * c, -0.5, 0.5)));
    i.insert("BIAS".into(), Buffer::from_f32s(&rng.f32s(c, -0.1, 0.1)));
    i
}

pub fn build(h: usize, c: usize) -> KernelCase {
    KernelCase {
        name: "dwconv",
        description: "3x3 depthwise convolution, channel-blocked vfmaq",
        prog: program(h, c),
        inputs: inputs(h, c, 0xdeadbeef),
        sim_tol: 1e-4,
        golden_tol: 1e-3,
    }
}

/// Figure 2 default: 16x16x16.
pub fn case() -> KernelCase {
    build(16, 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;

    #[test]
    fn matches_scalar_reference() {
        let (h, c) = (6, 8);
        let case = build(h, c);
        let oh = h - 2;
        let i = case.inputs["I"].as_f32s();
        let w = case.inputs["W"].as_f32s();
        let bias = case.inputs["BIAS"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();

        let mut want = vec![0f32; oh * oh * c];
        for oy in 0..oh {
            for ox in 0..oh {
                for ch in 0..c {
                    let mut acc = bias[ch];
                    for ky in 0..3 {
                        for kx in 0..3 {
                            acc = i[((oy + ky) * h + ox + kx) * c + ch]
                                .mul_add(w[(ky * 3 + kx) * c + ch], acc);
                        }
                    }
                    want[(oy * oh + ox) * c + ch] = acc;
                }
            }
        }
        crate::testutil::assert_close(&out["O"].as_f32s(), &want, 1e-4, "dwconv");
    }
}
