//! ARGMAXPOOL: 2x2 max pooling that also returns the index of the max
//! (XNNPACK argmaxpool pattern: `vcgtq` compare + `vbslq` select for both
//! the running value and the running index).

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::KernelCase;

pub fn program(h: usize, c: usize) -> Program {
    assert_eq!(h % 2, 0);
    assert_eq!(c % 4, 0);
    let oh = h / 2;
    let mut b = ProgramBuilder::new("argmaxpool");
    let i_buf = b.input("I", Elem::F32, h * h * c);
    let ov_buf = b.output("OV", Elem::F32, oh * oh * c);
    let oi_buf = b.output("OI", Elem::U32, oh * oh * c);
    // hoisted index constants
    let zero_idx = b.vop(Family::DupN, Elem::U32, true, vec![Arg::Imm(0)]);
    let jvs: Vec<u32> = (1..4)
        .map(|j| b.vop(Family::DupN, Elem::U32, true, vec![Arg::Imm(j)]))
        .collect();

    b.loop_(0, oh as i64, 1, |b, oy| {
        b.loop_(0, oh as i64, 1, |b, ox| {
            b.loop_(0, c as i64, 4, |b, ci| {
                let at = |dy: i64, dx: i64| {
                    AddrExpr::s(oy)
                        .mul(2)
                        .addk(dy)
                        .mul((h * c) as i64)
                        .add(AddrExpr::s(ox).mul(2).addk(dx).mul(c as i64))
                        .add(AddrExpr::s(ci))
                };
                let best = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(i_buf, at(0, 0))]);
                let idx = b.fresh_vreg();
                b.vop_into(idx, Family::Orr, Elem::U32, true, vec![Arg::V(zero_idx), Arg::V(zero_idx)]);
                for (j, (dy, dx)) in [(0i64, 1i64), (1, 0), (1, 1)].iter().enumerate() {
                    let v = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(i_buf, at(*dy, *dx))]);
                    // c = v > best (u32 all-ones mask)
                    let cmp = b.vop(Family::Cgt, Elem::F32, true, vec![Arg::V(v), Arg::V(best)]);
                    b.vop_into(best, Family::Bsl, Elem::F32, true, vec![Arg::V(cmp), Arg::V(v), Arg::V(best)]);
                    b.vop_into(idx, Family::Bsl, Elem::U32, true, vec![Arg::V(cmp), Arg::V(jvs[j]), Arg::V(idx)]);
                }
                let oidx = AddrExpr::s(oy)
                    .mul(oh as i64)
                    .add(AddrExpr::s(ox))
                    .mul(c as i64)
                    .add(AddrExpr::s(ci));
                b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(ov_buf, oidx.clone()), Arg::V(best)]);
                b.vstore(Family::St1, Elem::U32, true, vec![Arg::mem(oi_buf, oidx), Arg::V(idx)]);
            });
        });
    });
    b.finish()
}

pub fn inputs(h: usize, c: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("I".into(), Buffer::from_f32s(&rng.f32s(h * h * c, -4.0, 4.0)));
    i
}

pub fn build(h: usize, c: usize) -> KernelCase {
    KernelCase {
        name: "argmaxpool",
        description: "2x2 argmax pooling (vcgtq + vbslq value/index tracking)",
        prog: program(h, c),
        inputs: inputs(h, c, 0xa59a),
        sim_tol: 0.0,
        golden_tol: 0.0,
    }
}

/// Figure 2 default: 32x32x16.
pub fn case() -> KernelCase {
    build(32, 16)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;

    #[test]
    fn matches_scalar_reference() {
        let (h, c) = (8, 8);
        let case = build(h, c);
        let oh = h / 2;
        let i = case.inputs["I"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();
        let vals = out["OV"].as_f32s();
        let idxs = out["OI"].as_u32s();
        for oy in 0..oh {
            for ox in 0..oh {
                for ch in 0..c {
                    let v = [
                        i[(2 * oy * h + 2 * ox) * c + ch],
                        i[(2 * oy * h + 2 * ox + 1) * c + ch],
                        i[((2 * oy + 1) * h + 2 * ox) * c + ch],
                        i[((2 * oy + 1) * h + 2 * ox + 1) * c + ch],
                    ];
                    let (mut bi, mut bv) = (0u32, v[0]);
                    for (j, &x) in v.iter().enumerate().skip(1) {
                        if x > bv {
                            bv = x;
                            bi = j as u32;
                        }
                    }
                    let o = (oy * oh + ox) * c + ch;
                    assert_eq!(vals[o], bv, "value at {o}");
                    assert_eq!(idxs[o], bi, "index at {o}");
                }
            }
        }
    }
}
