//! The paper's §4.2 benchmark workloads: the 10 XNNPACK neural-network
//! compute functions, written as NEON-intrinsic IR programs that
//! algorithmically mirror XNNPACK's NEON microkernels (fma accumulators,
//! rsqrt Newton iterations, exp-based sigmoid/tanh with `vcvtnq` + exponent
//! reconstruction, compare+bitselect argmax tracking, ...).

pub mod argmaxpool;
pub mod convhwc;
pub mod dwconv;
pub mod expmath;
pub mod gemm;
pub mod ibilinear;
pub mod maxpool;
pub mod vrelu;
pub mod vsigmoid;
pub mod vsqrt;
pub mod vtanh;

use crate::ir::Program;
use crate::neon::interp::Inputs;

/// One benchmark case: program + inputs + comparison tolerances.
pub struct KernelCase {
    pub name: &'static str,
    pub description: &'static str,
    pub prog: Program,
    pub inputs: Inputs,
    /// tolerance for RVV-translated vs NEON-interpreted outputs (fused vs
    /// unfused fma rounding in baseline mode)
    pub sim_tol: f32,
    /// tolerance vs the JAX/XLA golden oracle (polynomial approximations
    /// vs libm transcendentals)
    pub golden_tol: f32,
}

/// The Figure 2 suite at the default shapes (see DESIGN.md §6).
pub fn suite() -> Vec<KernelCase> {
    vec![
        gemm::case(),
        convhwc::case(),
        dwconv::case(),
        maxpool::case(),
        argmaxpool::case(),
        vrelu::case(),
        vsqrt::case(),
        vtanh::case(),
        vsigmoid::case(),
        ibilinear::case(),
    ]
}

/// Reduced shapes for fast integration tests.
pub fn suite_small() -> Vec<KernelCase> {
    vec![
        gemm::build(8, 8, 8),
        convhwc::build(6, 4, 8),
        dwconv::build(6, 8),
        maxpool::build(8, 8),
        argmaxpool::build(8, 8),
        vrelu::build(256),
        vsqrt::build(256),
        vtanh::build(256),
        vsigmoid::build(256),
        ibilinear::build(5, 4),
    ]
}

pub fn by_name(name: &str) -> Option<KernelCase> {
    suite().into_iter().find(|k| k.name == name)
}

/// All suite kernel names in Figure 2 order.
pub const NAMES: [&str; 10] = [
    "gemm",
    "convhwc",
    "dwconv",
    "maxpool",
    "argmaxpool",
    "vrelu",
    "vsqrt",
    "vtanh",
    "vsigmoid",
    "ibilinear",
];

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::{typecheck, NeonInterp};

    #[test]
    fn suite_has_ten_kernels_matching_fig2() {
        let s = suite();
        assert_eq!(s.len(), 10);
        for (k, want) in s.iter().zip(NAMES) {
            assert_eq!(k.name, want);
        }
    }

    #[test]
    fn all_programs_typecheck() {
        for k in suite().iter().chain(suite_small().iter()) {
            typecheck(&k.prog).unwrap_or_else(|e| panic!("{}: {e}", k.name));
        }
    }

    #[test]
    fn all_small_programs_interpret() {
        for k in suite_small() {
            let out = NeonInterp::new(&k.prog, &k.inputs)
                .unwrap_or_else(|e| panic!("{}: {e}", k.name))
                .run()
                .unwrap_or_else(|e| panic!("{}: {e}", k.name));
            assert!(!out.is_empty(), "{} produced no outputs", k.name);
        }
    }
}
