//! VTANH: elementwise hyperbolic tangent, XNNPACK expm1-style:
//! `tanh(|x|) = (1 - t) / (1 + t)` with `t = exp(-2|x|)`, sign restored by
//! a sign-bit `vbslq` (mask 0x80000000) — compare + select free.

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::expmath::{emit_exp_neg, emit_recip, ExpConsts};
use super::KernelCase;

pub fn program(n: usize) -> Program {
    assert_eq!(n % 4, 0);
    let f = Elem::F32;
    let mut b = ProgramBuilder::new("vtanh");
    let x_buf = b.input("X", Elem::F32, n);
    let y_buf = b.output("Y", Elem::F32, n);
    // hoisted constants (clang hoists vdupq_n of loop invariants)
    let sign_mask = b.vop(Family::DupN, Elem::U32, true, vec![Arg::Imm(0x8000_0000)]);
    let two = b.vop(Family::DupN, f, true, vec![Arg::ImmF(2.0)]);
    let k = ExpConsts::hoist(&mut b);
    b.loop_(0, n as i64, 4, |b, i| {
        let x = b.vop(Family::Ld1, f, true, vec![Arg::mem(x_buf, AddrExpr::s(i))]);
        let a = b.vop(Family::Abs, f, true, vec![Arg::V(x)]);
        let z = b.vop(Family::Mul, f, true, vec![Arg::V(a), Arg::V(two)]);
        let t = emit_exp_neg(b, &k, z); // exp(-2|x|) in (0, 1]
        // tanh(|x|) = (1 - t) / (1 + t)
        let one = k.one();
        let num = b.vop(Family::Sub, f, true, vec![Arg::V(one), Arg::V(t)]);
        let den = b.vop(Family::Add, f, true, vec![Arg::V(one), Arg::V(t)]);
        let rcp = emit_recip(b, den);
        let th = b.vop(Family::Mul, f, true, vec![Arg::V(num), Arg::V(rcp)]);
        // restore sign: take the sign bit from x, magnitude from th
        let y = b.vop(Family::Bsl, f, true, vec![Arg::V(sign_mask), Arg::V(x), Arg::V(th)]);
        b.vstore(Family::St1, f, true, vec![Arg::mem(y_buf, AddrExpr::s(i)), Arg::V(y)]);
    });
    b.finish()
}

pub fn inputs(n: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("X".into(), Buffer::from_f32s(&rng.f32s(n, -5.0, 5.0)));
    i
}

pub fn build(n: usize) -> KernelCase {
    KernelCase {
        name: "vtanh",
        description: "elementwise tanh (exp(-2|x|) + Newton reciprocal + sign bitselect)",
        prog: program(n),
        inputs: inputs(n, 0x7a17),
        sim_tol: 1e-5,
        golden_tol: 5e-3,
    }
}

/// Figure 2 default: n = 8192.
pub fn case() -> KernelCase {
    build(8192)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;
    use crate::testutil::max_abs_diff;

    #[test]
    fn matches_libm_tanh() {
        let case = build(256);
        let x = case.inputs["X"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();
        let want: Vec<f32> = x.iter().map(|v| v.tanh()).collect();
        let d = max_abs_diff(&out["Y"].as_f32s(), &want);
        assert!(d < 1e-5, "tanh abs err {d}");
    }

    #[test]
    fn odd_symmetry_and_sign() {
        let xs: Vec<f32> = vec![-3.0, -1.0, -0.25, 0.0, 0.25, 1.0, 3.0, 5.0];
        let mut inputs = Inputs::new();
        inputs.insert("X".into(), Buffer::from_f32s(&xs));
        let p = program(8);
        let out = NeonInterp::new(&p, &inputs).unwrap().run().unwrap();
        let y = out["Y"].as_f32s();
        assert!((y[0] + y[6]).abs() < 1e-6, "tanh odd symmetry");
        assert!(y[3].abs() < 1e-6);
        assert!(y[0] < 0.0 && y[7] > 0.0);
    }
}
