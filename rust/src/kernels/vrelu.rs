//! VRELU: elementwise `max(x, 0)` (XNNPACK vrelu: hoisted zero +
//! `vmaxq_f32` over a flat array).

use crate::ir::{AddrExpr, Arg, Program, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::interp::{Buffer, Inputs};
use crate::neon::ops::Family;
use crate::testutil::Rng;
use super::KernelCase;

pub fn program(n: usize) -> Program {
    assert_eq!(n % 4, 0);
    let mut b = ProgramBuilder::new("vrelu");
    let x_buf = b.input("X", Elem::F32, n);
    let y_buf = b.output("Y", Elem::F32, n);
    let zero = b.vop(Family::DupN, Elem::F32, true, vec![Arg::ImmF(0.0)]);
    b.loop_(0, n as i64, 4, |b, i| {
        let x = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(x_buf, AddrExpr::s(i))]);
        let y = b.vop(Family::Max, Elem::F32, true, vec![Arg::V(x), Arg::V(zero)]);
        b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(y_buf, AddrExpr::s(i)), Arg::V(y)]);
    });
    b.finish()
}

pub fn inputs(n: usize, seed: u64) -> Inputs {
    let mut rng = Rng::new(seed);
    let mut i = Inputs::new();
    i.insert("X".into(), Buffer::from_f32s(&rng.f32s(n, -4.0, 4.0)));
    i
}

pub fn build(n: usize) -> KernelCase {
    KernelCase {
        name: "vrelu",
        description: "elementwise ReLU (vmaxq with hoisted zero)",
        prog: program(n),
        inputs: inputs(n, 0x5e1f),
        sim_tol: 0.0,
        golden_tol: 0.0,
    }
}

/// Figure 2 default: n = 16384.
pub fn case() -> KernelCase {
    build(16384)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::interp::NeonInterp;

    #[test]
    fn matches_scalar_reference() {
        let case = build(64);
        let x = case.inputs["X"].as_f32s();
        let out = NeonInterp::new(&case.prog, &case.inputs).unwrap().run().unwrap();
        let want: Vec<f32> = x.iter().map(|v| v.max(0.0)).collect();
        crate::testutil::assert_close(&out["Y"].as_f32s(), &want, 0.0, "vrelu");
    }
}
