//! Shared NEON-intrinsic building blocks for the transcendental kernels:
//! XNNPACK-style `exp(-z)` (round-to-nearest `vcvtnq` + extended-precision
//! ln2 reduction + p5 Horner polynomial + exponent-bit reconstruction) and
//! reciprocal via `vrecpeq` + two Newton steps.
//!
//! These are the op mixes that make vtanh/vsigmoid hot in the paper's
//! Figure 2: `vcvtnq`/`vrndn` scalarise in baseline SIMDe while the
//! customized conversions keep them single RVV instructions.

use crate::ir::{Arg, ProgramBuilder};
use crate::neon::elem::Elem;
use crate::neon::ops::Family;

pub const LOG2E: f64 = std::f64::consts::LOG2_E;
pub const LN2_HI: f64 = 0.693145751953125; // high bits of ln2, exact in f32
pub const LN2_LO: f64 = 1.428606765330187045e-06;
const C2: f64 = 0.5;
const C3: f64 = 1.0 / 6.0;
const C4: f64 = 1.0 / 24.0;
const C5: f64 = 1.0 / 120.0;

/// Loop-invariant constant registers for the exp evaluation — hoisted
/// outside the element loop like clang does with `vdupq_n_f32` of
/// constants.
pub struct ExpConsts {
    mlog2e: u32,
    ln2hi: u32,
    ln2lo: u32,
    one: u32,
    c2: u32,
    c3: u32,
    c4: u32,
    c5: u32,
}

impl ExpConsts {
    pub fn hoist(b: &mut ProgramBuilder) -> ExpConsts {
        let f = Elem::F32;
        let mut dup = |v: f64| b.vop(Family::DupN, f, true, vec![Arg::ImmF(v)]);
        ExpConsts {
            mlog2e: dup(-LOG2E),
            ln2hi: dup(LN2_HI),
            ln2lo: dup(LN2_LO),
            one: dup(1.0),
            c2: dup(C2),
            c3: dup(C3),
            c4: dup(C4),
            c5: dup(C5),
        }
    }

    pub fn one(&self) -> u32 {
        self.one
    }
}

/// Emit `exp(-z)` for a register `z` holding values in [0, ~80).
/// Returns the register with the result.
pub fn emit_exp_neg(b: &mut ProgramBuilder, k: &ExpConsts, z: u32) -> u32 {
    let f = Elem::F32;
    // n = round_ne(-z * log2e)
    let t0 = b.vop(Family::Mul, f, true, vec![Arg::V(z), Arg::V(k.mlog2e)]);
    let n_i = b.vop(Family::CvtnFI, f, true, vec![Arg::V(t0)]);
    let n_f = b.vop(Family::CvtIF, Elem::I32, true, vec![Arg::V(n_i)]);
    // r = -z - n*ln2   (two-term ln2 for extra precision)
    let negz = b.vop(Family::Neg, f, true, vec![Arg::V(z)]);
    let r1 = b.vop(Family::Fms, f, true, vec![Arg::V(negz), Arg::V(n_f), Arg::V(k.ln2hi)]);
    let r = b.vop(Family::Fms, f, true, vec![Arg::V(r1), Arg::V(n_f), Arg::V(k.ln2lo)]);
    // p = e^r, Horner p5 (SSA: each fma writes a fresh register)
    let mut p = k.c5;
    for coeff in [k.c4, k.c3, k.c2, k.one, k.one] {
        p = b.vop(Family::Fma, f, true, vec![Arg::V(coeff), Arg::V(p), Arg::V(r)]);
    }
    // scale by 2^n: add n << 23 to the float's bits
    let bits = b.vop(Family::ShlN, Elem::I32, true, vec![Arg::V(n_i), Arg::Imm(23)]);
    let p_i = b.vop(Family::Reinterpret, Elem::I32, true, vec![Arg::V(p)]);
    let e_i = b.vop(Family::Add, Elem::I32, true, vec![Arg::V(p_i), Arg::V(bits)]);
    b.vop(Family::Reinterpret, Elem::F32, true, vec![Arg::V(e_i)])
}

/// Emit `1/d` via `vrecpeq_f32` + two `vrecpsq_f32` Newton steps.
pub fn emit_recip(b: &mut ProgramBuilder, d: u32) -> u32 {
    let f = Elem::F32;
    let mut rcp = b.vop(Family::Recpe, f, true, vec![Arg::V(d)]);
    for _ in 0..2 {
        let step = b.vop(Family::Recps, f, true, vec![Arg::V(d), Arg::V(rcp)]);
        rcp = b.vop(Family::Mul, f, true, vec![Arg::V(rcp), Arg::V(step)]);
    }
    rcp
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::AddrExpr;
    use crate::neon::interp::{Buffer, Inputs, NeonInterp};
    use crate::testutil::{max_rel_diff, Rng};

    #[test]
    fn exp_neg_accuracy() {
        let n = 64;
        let mut b = ProgramBuilder::new("exp_test");
        let x = b.input("X", Elem::F32, n);
        let y = b.output("Y", Elem::F32, n);
        let k = ExpConsts::hoist(&mut b);
        b.loop_(0, n as i64, 4, |b, i| {
            let z = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(x, AddrExpr::s(i))]);
            let e = emit_exp_neg(b, &k, z);
            b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(y, AddrExpr::s(i)), Arg::V(e)]);
        });
        let p = b.finish();

        let mut rng = Rng::new(3);
        let xs = rng.f32s(n, 0.0, 16.0);
        let mut inputs = Inputs::new();
        inputs.insert("X".into(), Buffer::from_f32s(&xs));
        let out = NeonInterp::new(&p, &inputs).unwrap().run().unwrap();
        let want: Vec<f32> = xs.iter().map(|v| (-v).exp()).collect();
        let rel = max_rel_diff(&out["Y"].as_f32s(), &want);
        assert!(rel < 1e-5, "exp rel err {rel}");
    }

    #[test]
    fn recip_accuracy() {
        let n = 64;
        let mut b = ProgramBuilder::new("recip_test");
        let x = b.input("X", Elem::F32, n);
        let y = b.output("Y", Elem::F32, n);
        b.loop_(0, n as i64, 4, |b, i| {
            let d = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(x, AddrExpr::s(i))]);
            let r = emit_recip(b, d);
            b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(y, AddrExpr::s(i)), Arg::V(r)]);
        });
        let p = b.finish();

        let mut rng = Rng::new(5);
        let xs = rng.f32s(n, 0.5, 10.0);
        let mut inputs = Inputs::new();
        inputs.insert("X".into(), Buffer::from_f32s(&xs));
        let out = NeonInterp::new(&p, &inputs).unwrap().run().unwrap();
        let want: Vec<f32> = xs.iter().map(|v| 1.0 / v).collect();
        let rel = max_rel_diff(&out["Y"].as_f32s(), &want);
        assert!(rel < 1e-6, "recip rel err {rel}");
    }
}
