//! Test utilities: a deterministic PRNG (no `rand` offline) and numeric
//! comparison helpers used by unit/property tests, the kernel suite's
//! input generators, and the verification pipeline.

/// xorshift64* PRNG — deterministic, seedable, no dependencies.
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
}

impl Rng {
    pub fn new(seed: u64) -> Rng {
        Rng { state: seed.max(1).wrapping_mul(0x9e37_79b9_7f4a_7c15) | 1 }
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.state = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Uniform in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        self.next_u64() % n.max(1)
    }

    /// Uniform f32 in [lo, hi).
    pub fn f32_in(&mut self, lo: f32, hi: f32) -> f32 {
        let u = (self.next_u64() >> 40) as f32 / (1u64 << 24) as f32;
        lo + u * (hi - lo)
    }

    /// Vector of uniform f32s.
    pub fn f32s(&mut self, n: usize, lo: f32, hi: f32) -> Vec<f32> {
        (0..n).map(|_| self.f32_in(lo, hi)).collect()
    }

    /// Vector of i32s in [lo, hi).
    pub fn i32s(&mut self, n: usize, lo: i32, hi: i32) -> Vec<i32> {
        (0..n)
            .map(|_| lo.wrapping_add((self.below((hi - lo) as u64)) as i32))
            .collect()
    }

    /// Raw lane values for an element type (full bit range).
    pub fn lanes(&mut self, n: usize, bits: u32) -> Vec<u64> {
        let mask = if bits == 64 { u64::MAX } else { (1u64 << bits) - 1 };
        (0..n).map(|_| self.next_u64() & mask).collect()
    }
}

/// Maximum absolute difference between two f32 slices.
pub fn max_abs_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len(), "length mismatch {} vs {}", a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            if x.is_nan() && y.is_nan() {
                0.0
            } else {
                (x - y).abs()
            }
        })
        .fold(0.0f32, f32::max)
}

/// Maximum relative difference (with absolute floor).
pub fn max_rel_diff(a: &[f32], b: &[f32]) -> f32 {
    assert_eq!(a.len(), b.len());
    a.iter()
        .zip(b)
        .map(|(x, y)| {
            if x.is_nan() && y.is_nan() {
                return 0.0;
            }
            let d = (x - y).abs();
            let m = x.abs().max(y.abs()).max(1e-6);
            d / m
        })
        .fold(0.0f32, f32::max)
}

/// Panic with a useful message if slices differ beyond `tol` (absolute).
pub fn assert_close(a: &[f32], b: &[f32], tol: f32, what: &str) {
    let d = max_abs_diff(a, b);
    assert!(
        d <= tol,
        "{what}: max abs diff {d} > tol {tol} (first few: {:?} vs {:?})",
        &a[..a.len().min(8)],
        &b[..b.len().min(8)]
    );
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn rng_ranges() {
        let mut r = Rng::new(7);
        for _ in 0..1000 {
            let v = r.f32_in(-2.0, 3.0);
            assert!((-2.0..3.0).contains(&v));
            let i = r.below(17);
            assert!(i < 17);
        }
    }

    #[test]
    fn diff_helpers() {
        assert_eq!(max_abs_diff(&[1.0, 2.0], &[1.0, 2.5]), 0.5);
        assert!(max_rel_diff(&[100.0], &[101.0]) < 0.011);
        assert_eq!(max_abs_diff(&[f32::NAN], &[f32::NAN]), 0.0);
    }
}
