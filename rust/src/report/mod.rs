//! Report emitters: regenerate the paper's Table 1, Table 2, and Figure 2
//! as markdown/CSV, plus the conversion-method histogram (§3.3).

use std::fmt::Write;

use crate::coordinator::{Fig2Report, Fig2Row};
use crate::neon::catalog;
use crate::neon::elem::BaseClass;
use crate::rvv::machine::RvvConfig;
use crate::simde::registry;
use crate::simde::types_map::{table2_cell, table2_rows};

/// Table 1: NEON intrinsic counts by return base type, ours vs the paper.
pub fn table1_markdown() -> String {
    let ours = catalog::counts_by_class();
    let paper = catalog::paper_table1();
    let mut s = String::new();
    let _ = writeln!(s, "## Table 1 — Categorization of NEON intrinsics by return base type\n");
    let _ = writeln!(s, "| Return base type | paper | ours (generated catalog) | delta |");
    let _ = writeln!(s, "|---|---:|---:|---:|");
    let mut total_p = 0usize;
    let mut total_o = 0usize;
    for (class, p) in &paper {
        let o = *ours.get(class).unwrap_or(&0);
        total_p += p;
        total_o += o;
        let delta = o as i64 - *p as i64;
        let _ = writeln!(s, "| {} | {} | {} | {:+} |", class.name(), p, o, delta);
    }
    let _ = writeln!(s, "| **total** | **{total_p}** | **{total_o}** | **{:+}** |", total_o as i64 - total_p as i64);
    s
}

/// Table 1 as CSV (class,paper,ours).
pub fn table1_csv() -> String {
    let ours = catalog::counts_by_class();
    let mut s = String::from("class,paper,ours\n");
    for (class, p) in catalog::paper_table1() {
        let o = *ours.get(&class).unwrap_or(&0);
        let _ = writeln!(s, "{},{},{}", class.name(), p, o);
    }
    s
}

/// Table 2: NEON type -> RVV type mapping by vlen band (paper layout).
pub fn table2_markdown(zvfh: bool) -> String {
    let mut s = String::new();
    let _ = writeln!(
        s,
        "## Table 2 — NEON types -> RVV fixed-vlen types (Zvfh {})\n",
        if zvfh { "enabled" } else { "disabled" }
    );
    let _ = writeln!(s, "| Neon | vlen<64 | 64<=vlen<128 | vlen>=128 |");
    let _ = writeln!(s, "|---|---|---|---|");
    for vt in table2_rows() {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} |",
            vt.name(),
            table2_cell(vt, 32, zvfh),
            table2_cell(vt, 64, zvfh),
            table2_cell(vt, 128, zvfh),
        );
    }
    s
}

/// Figure 2: per-kernel dynamic-instruction-count speedups.
pub fn fig2_markdown(rows: &[Fig2Row], vlen: u32) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "## Figure 2 — RVV-enhanced SIMDe speedup (vlen={vlen}, dynamic instruction count)\n");
    let _ = writeln!(s, "| kernel | baseline insts | rvv-custom insts | speedup |");
    let _ = writeln!(s, "|---|---:|---:|---:|");
    for r in rows {
        let _ = writeln!(s, "| {} | {} | {} | {:.2}x |", r.kernel, r.baseline, r.custom, r.speedup);
    }
    let min = rows.iter().map(|r| r.speedup).fold(f64::INFINITY, f64::min);
    let max = rows.iter().map(|r| r.speedup).fold(0.0, f64::max);
    let _ = writeln!(s, "\nrange: {min:.2}x – {max:.2}x (paper: 1.51x – 5.13x)");
    s
}

/// Figure 2 from a fault-tolerant run: the healthy rows, then an
/// annotation block for kernels that produced no row and the fault
/// records behind them.
pub fn fig2_markdown_report(rep: &Fig2Report) -> String {
    let mut s = fig2_markdown(&rep.rows, rep.vlen);
    if !rep.failed.is_empty() {
        let _ = writeln!(s, "\nfailed kernels (no row): {}", rep.failed.join(", "));
    }
    for f in &rep.faults {
        let _ = writeln!(s, "- fault: {f}");
    }
    s
}

pub fn fig2_csv(rows: &[Fig2Row]) -> String {
    let mut s = String::from("kernel,baseline,custom,speedup\n");
    for r in rows {
        let _ = writeln!(s, "{},{},{},{:.4}", r.kernel, r.baseline, r.custom, r.speedup);
    }
    s
}

/// Autotuner search summary: one row per tuned point, static score vs
/// winner score, plus the scored-out candidate tally.
pub fn tune_markdown(out: &crate::tuner::TuneOutcome) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "## Lowering autotuner — search results\n");
    let _ = writeln!(s, "| kernel | mode | vlen | static insts | winner | winner insts | delta |");
    let _ = writeln!(s, "|---|---|---:|---:|---|---:|---:|");
    for e in &out.db.entries {
        let stat = e.static_score().map_or(0, |c| c.dyn_insts);
        let win = e.winner_score().map_or(0, |c| c.dyn_insts);
        let delta = if stat > 0 {
            format!("{:+.1}%", (win as f64 - stat as f64) / stat as f64 * 100.0)
        } else {
            "-".to_string()
        };
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {} | {} | {} |",
            e.kernel,
            e.mode.name(),
            e.vlen,
            stat,
            e.winner,
            win,
            delta
        );
    }
    let scored_out: usize = out
        .db
        .entries
        .iter()
        .map(|e| e.candidates.iter().filter(|c| !c.ok).count())
        .sum();
    let _ = writeln!(
        s,
        "\n{} of {} points improved over the static rule; {} candidate(s) scored out; {} runtime fault(s)",
        out.improved,
        out.db.entries.len(),
        scored_out,
        out.faults.len()
    );
    if out.skipped > 0 {
        let _ = writeln!(
            s,
            "{} candidate run(s) skipped by an open circuit breaker (see the per-candidate provenance rows)",
            out.skipped
        );
    }
    s
}

/// Health summary of one fault-tolerant matrix run: admission/execution
/// counters plus the fuel (dynamic instructions) the successful runs
/// consumed.
pub fn health_markdown(h: &crate::coordinator::MatrixHealth) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### Run health\n");
    let _ = writeln!(s, "| verified | passed | faulted | skipped | fuel spent |");
    let _ = writeln!(s, "|---:|---:|---:|---:|---:|");
    let _ = writeln!(
        s,
        "| {} | {} | {} | {} | {} |",
        h.verified, h.passed, h.faulted, h.skipped, h.fuel_spent
    );
    s
}

/// §3.3 conversion-method histogram over the implemented surface.
pub fn methods_markdown(cfg: RvvConfig) -> String {
    let hist = registry::method_histogram(cfg);
    let total: usize = hist.values().sum();
    let mut s = String::new();
    let _ = writeln!(s, "## Conversion methods over the implemented surface (vlen={}, {} conversions)\n", cfg.vlen, total);
    let _ = writeln!(s, "| method | conversions |");
    let _ = writeln!(s, "|---|---:|");
    for (m, n) in hist {
        let _ = writeln!(s, "| {m} | {n} |");
    }
    let _ = writeln!(s, "\n(the paper reports 1520 customized conversions over the full 4344-intrinsic surface)");
    s
}

/// Sanity accessor used by benches.
pub fn table1_total() -> usize {
    catalog::generate().len()
}

/// Count for one class (bench assertions).
pub fn table1_class(class: BaseClass) -> usize {
    *catalog::counts_by_class().get(&class).unwrap_or(&0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_report_contains_all_classes() {
        let md = table1_markdown();
        for c in ["int", "uint", "float", "poly", "void", "bfloat"] {
            assert!(md.contains(&format!("| {c} |")), "missing {c}");
        }
        assert!(md.contains("4344") || md.contains("total"));
    }

    #[test]
    fn table2_report_matches_paper_cells() {
        let md = table2_markdown(true);
        assert!(md.contains("| int32x4_t | x | x | vint32m1_t |"));
        assert!(md.contains("| int8x8_t | x | vint8m1_t | vint8m1_t |"));
        let md = table2_markdown(false);
        assert!(md.contains("| float16x8_t | x | x | x |"));
    }

    #[test]
    fn fig2_report_annotates_faults() {
        use crate::coordinator::{EngineKind, FaultRecord, Job};
        use crate::simde::Mode;
        let rep = Fig2Report {
            vlen: 128,
            rows: vec![Fig2Row { kernel: "gemm", baseline: 200, custom: 100, speedup: 2.0 }],
            failed: vec!["vrelu"],
            faults: vec![FaultRecord {
                index: 2,
                job: Job { kernel: "vrelu", mode: Mode::Baseline, vlen: 128 },
                attempts: 3,
                engine: EngineKind::Decoded,
                error: "sim trap [injected] boom".into(),
                trap: None,
            }],
        };
        let md = fig2_markdown_report(&rep);
        assert!(md.contains("| gemm | 200 | 100 | 2.00x |"));
        assert!(md.contains("failed kernels (no row): vrelu"));
        assert!(md.contains("injected"));
    }

    #[test]
    fn tune_report_formats() {
        use crate::simde::Mode;
        use crate::tuner::db::{CandidateScore, TunedEntry, TuningDb};
        use crate::tuner::TuneOutcome;
        let score = |id: &str, ok: bool, dyn_insts: u64| CandidateScore {
            id: id.into(),
            ok,
            dyn_insts,
            wall_ns: 10,
            error: if ok { String::new() } else { "nope".into() },
        };
        let out = TuneOutcome {
            db: TuningDb {
                entries: vec![TunedEntry {
                    kernel: "vrelu".into(),
                    mode: Mode::RvvCustom,
                    vlen: 512,
                    fingerprint: 7,
                    engine: "decoded".into(),
                    winner: "widen:4".into(),
                    candidates: vec![
                        score("static", true, 1000),
                        score("widen:4", true, 400),
                        score("widen:8", false, 0),
                    ],
                }],
            },
            faults: vec![],
            improved: 1,
            skipped: 0,
        };
        let md = tune_markdown(&out);
        assert!(md.contains("| vrelu | rvv-custom | 512 | 1000 | widen:4 | 400 | -60.0% |"), "{md}");
        assert!(md.contains("1 of 1 points improved"), "{md}");
        assert!(md.contains("1 candidate(s) scored out"), "{md}");
        assert!(!md.contains("circuit breaker"), "no breaker line on a clean run: {md}");
        let skipped = TuneOutcome { skipped: 2, ..out };
        let md = tune_markdown(&skipped);
        assert!(md.contains("2 candidate run(s) skipped by an open circuit breaker"), "{md}");
    }

    #[test]
    fn health_report_formats() {
        use crate::coordinator::MatrixHealth;
        let h = MatrixHealth { verified: 6, passed: 4, faulted: 2, skipped: 3, fuel_spent: 1234 };
        let md = health_markdown(&h);
        assert!(md.contains("| 6 | 4 | 2 | 3 | 1234 |"), "{md}");
    }

    #[test]
    fn fig2_report_formats() {
        let rows = vec![Fig2Row { kernel: "gemm", baseline: 200, custom: 100, speedup: 2.0 }];
        let md = fig2_markdown(&rows, 128);
        assert!(md.contains("| gemm | 200 | 100 | 2.00x |"));
        let csv = fig2_csv(&rows);
        assert!(csv.contains("gemm,200,100,2.0000"));
    }
}
