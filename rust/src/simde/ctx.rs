//! Lowering context: register mapping, scratch allocation, and RVV
//! instruction emit helpers shared by all conversion rules.

use crate::ir::{AddrExpr, Arg, BufDecl};
use crate::neon::elem::Elem;
use crate::neon::ops::NeonOp;
use crate::rvv::machine::RvvConfig;
use crate::rvv::ops::{Dst, MemRef, RvvInst, RvvKind, Src};
use crate::rvv::program::RStmt;
use crate::rvv::vtype::{Lmul, Sew};

/// Context for lowering one IR program. NEON vregs map identity onto RVV
/// vregs; scratch vector/mask registers are allocated from a pool above
/// them and recycled per intrinsic (scratch values never live across
/// statements).
pub struct Ctx<'a> {
    pub cfg: RvvConfig,
    pub bufs: &'a [BufDecl],
    base_vregs: u32,
    scratch_next: u32,
    pub scratch_max: u32,
    mask_next: u32,
    pub mask_max: u32,
    pub out: Vec<RStmt>,
}

impl<'a> Ctx<'a> {
    pub fn new(cfg: RvvConfig, bufs: &'a [BufDecl], base_vregs: u32) -> Ctx<'a> {
        Ctx {
            cfg,
            bufs,
            base_vregs,
            scratch_next: 0,
            scratch_max: 0,
            mask_next: 0,
            mask_max: 0,
            out: Vec::new(),
        }
    }

    /// RVV vreg for a NEON vreg (identity mapping).
    pub fn v(&self, neon_reg: u32) -> u32 {
        neon_reg
    }

    /// Fresh scratch vector register (valid until `reset_scratch`).
    pub fn scratch(&mut self) -> u32 {
        let r = self.base_vregs + self.scratch_next;
        self.scratch_next += 1;
        self.scratch_max = self.scratch_max.max(self.scratch_next);
        r
    }

    /// Fresh scratch mask register.
    pub fn mask(&mut self) -> u32 {
        let m = self.mask_next;
        self.mask_next += 1;
        self.mask_max = self.mask_max.max(self.mask_next);
        m
    }

    /// Recycle scratch registers between intrinsic lowerings.
    pub fn reset_scratch(&mut self) {
        self.scratch_next = 0;
        self.mask_next = 0;
    }

    // -- operand helpers ------------------------------------------------------

    /// Vector source operand for an IR vector-register argument.
    pub fn vsrc(&self, a: &Arg) -> Src {
        match a {
            Arg::V(r) => Src::V(self.v(*r)),
            Arg::Imm(i) => Src::ImmI(*i),
            Arg::ImmF(f) => Src::ImmF(*f),
            Arg::S(r) => Src::SReg(*r),
            Arg::Mem { .. } => panic!("memory arg where vector expected"),
        }
    }

    pub fn memref(&self, a: &Arg) -> MemRef {
        match a {
            Arg::Mem { buf, index } => MemRef { buf: *buf, index: index.clone(), stride: 1 },
            _ => panic!("expected memory arg"),
        }
    }

    pub fn memref_strided(&self, a: &Arg, stride: i64) -> MemRef {
        let mut m = self.memref(a);
        m.stride = stride;
        m
    }

    // -- emit helpers -----------------------------------------------------------

    pub fn emit(&mut self, inst: RvvInst) {
        self.out.push(RStmt::Op(inst));
    }

    /// Generic op: `dst = kind(srcs)` at (sew, vl). The static translator
    /// models the paper's LMUL=1 fixed-size mapping; grouped (`m2`/`m4`)
    /// variants are introduced later by the tuner's `lmul:F` transform.
    pub fn op(&mut self, kind: RvvKind, sew: Sew, vl: u32, dst: Dst, srcs: Vec<Src>) {
        self.emit(RvvInst { kind, sew, lmul: Lmul::M1, vl, dst, srcs, mask: None, mem: None });
    }

    /// Masked op.
    pub fn op_masked(&mut self, kind: RvvKind, sew: Sew, vl: u32, dst: Dst, srcs: Vec<Src>, mask: u32) {
        self.emit(RvvInst { kind, sew, lmul: Lmul::M1, vl, dst, srcs, mask: Some(mask), mem: None });
    }

    /// Unit-stride load into `dst`.
    pub fn load(&mut self, sew: Sew, vl: u32, dst: u32, mem: MemRef) {
        self.emit(RvvInst {
            kind: if mem.stride == 1 { RvvKind::Vle } else { RvvKind::Vlse },
            sew,
            lmul: Lmul::M1,
            vl,
            dst: Dst::V(dst),
            srcs: vec![],
            mask: None,
            mem: Some(mem),
        });
    }

    /// Masked unit-stride load.
    pub fn load_masked(&mut self, sew: Sew, vl: u32, dst: u32, mem: MemRef, mask: u32) {
        self.emit(RvvInst {
            kind: if mem.stride == 1 { RvvKind::Vle } else { RvvKind::Vlse },
            sew,
            lmul: Lmul::M1,
            vl,
            dst: Dst::V(dst),
            srcs: vec![],
            mask: Some(mask),
            mem: Some(mem),
        });
    }

    /// Unit-stride store of `src`.
    pub fn store(&mut self, sew: Sew, vl: u32, src: u32, mem: MemRef) {
        self.emit(RvvInst {
            kind: if mem.stride == 1 { RvvKind::Vse } else { RvvKind::Vsse },
            sew,
            lmul: Lmul::M1,
            vl,
            dst: Dst::None,
            srcs: vec![Src::V(src)],
            mask: None,
            mem: Some(mem),
        });
    }

    /// `vmv.v.v dst, src` unless dst == src. Returns whether an op was
    /// emitted.
    pub fn mov_v(&mut self, sew: Sew, vl: u32, dst: u32, src: u32) -> bool {
        if dst == src {
            return false;
        }
        self.op(RvvKind::VmvVV, sew, vl, Dst::V(dst), vec![Src::V(src)]);
        true
    }

    /// Ensure the accumulator value of a fused op sits in `dst` (vfmacc
    /// accumulates into its destination register).
    pub fn ensure_acc_in_dst(&mut self, sew: Sew, vl: u32, dst: u32, acc: u32) {
        self.mov_v(sew, vl, dst, acc);
    }
}

/// SEW/vl of the *named* vector type of an op (the common case: all
/// operands share the suffix type).
pub fn op_sew_vl(op: NeonOp) -> (Sew, u32) {
    let vt = op.vt();
    (Sew::of_elem(vt.elem), vt.lanes as u32)
}

/// SEW/vl of the op's *return* type.
pub fn ret_sew_vl(op: NeonOp) -> (Sew, u32) {
    let vt = op.sig().ret.expect("op returns a vector");
    (Sew::of_elem(vt.elem), vt.lanes as u32)
}

/// Whether the element is a float type for RVV op selection.
pub fn is_float(e: Elem) -> bool {
    e.is_float()
}

/// Convenience: an `AddrExpr` constant.
pub fn k(v: i64) -> AddrExpr {
    AddrExpr::Const(v)
}
