//! Conversion methods and translation modes.
//!
//! The paper (§3.3) lists five conversion methods used by SIMDe; the two
//! translation modes select between them per intrinsic:
//!
//! - **Baseline** (original SIMDe): no RVV-specific conversions exist, so
//!   every intrinsic goes through the generic paths — vector attributes
//!   (method 3) where clang can lower the generic body, otherwise the
//!   auto-vectorization of the scalar implementation (method 4), which
//!   fails to vectorize lane-crossing / branchy / libm bodies and leaves a
//!   scalar loop.
//! - **RvvCustom** (this paper): customized RVV intrinsic sequences
//!   (methods 1/5), with vector attributes retained only where they are
//!   already optimal.

/// Translation mode.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Mode {
    /// Original SIMDe: generic union + clang vector attributes +
    /// auto-vectorization (the paper's comparison baseline).
    Baseline,
    /// RVV-enhanced SIMDe: customized RVV intrinsic conversions.
    RvvCustom,
}

impl Mode {
    pub fn name(self) -> &'static str {
        match self {
            Mode::Baseline => "baseline",
            Mode::RvvCustom => "rvv-custom",
        }
    }

    /// Inverse of [`Mode::name`], also accepting the CLI shorthand
    /// `custom`. Returns `None` for anything else.
    pub fn parse(s: &str) -> Option<Mode> {
        match s {
            "baseline" => Some(Mode::Baseline),
            "custom" | "rvv-custom" => Some(Mode::RvvCustom),
            _ => None,
        }
    }
}

/// How one intrinsic is converted under a given mode (reported per rule in
/// the registry; drives the A2 ablation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Method {
    /// Direct 1:1 RVV intrinsic (§3.3 method 1).
    CustomDirect,
    /// Combination of a few RVV intrinsics (§3.3 method 5, e.g. Listing 6).
    CustomCombo,
    /// Complex algorithmic conversion (e.g. Listing 7 bit reverse).
    CustomAlgorithmic,
    /// clang vector attributes lower the generic body well (§3.3 method 3).
    VectorAttr,
    /// Auto-vectorization of the scalar body succeeds (§3.3 method 4).
    ScalarAutovec,
    /// Generic scalar loop that does NOT vectorize (branchy / libm /
    /// lane-crossing) — the baseline's weak spot.
    ScalarLoop,
    /// Union memcpy path for loads/stores.
    MemUnion,
}

impl Method {
    pub fn name(self) -> &'static str {
        match self {
            Method::CustomDirect => "custom-direct",
            Method::CustomCombo => "custom-combo",
            Method::CustomAlgorithmic => "custom-algorithmic",
            Method::VectorAttr => "vector-attr",
            Method::ScalarAutovec => "scalar-autovec",
            Method::ScalarLoop => "scalar-loop",
            Method::MemUnion => "mem-union",
        }
    }

    pub fn is_custom(self) -> bool {
        matches!(
            self,
            Method::CustomDirect | Method::CustomCombo | Method::CustomAlgorithmic
        )
    }
}
