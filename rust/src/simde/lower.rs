//! Program-level translation: walk an IR program and lower every NEON
//! intrinsic through the conversion rules, producing an [`RvvProgram`] for
//! the simulator. This is the SIMDe "preprocessing stage" of the paper's
//! §4.2 workflow, as a compiler pass instead of C macro expansion.

use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::ir::{Program, Stmt};
use crate::neon::ops::Category;
use crate::rvv::machine::RvvConfig;
use crate::rvv::program::{RStmt, RvvProgram};
use crate::simde::ctx::Ctx;
use crate::simde::method::{Method, Mode};
use crate::simde::rules;
use crate::simde::types_map::{map_neon_type, Unmappable};
use crate::tuner::db::TuningDb;

/// The translation engine.
pub struct Translator {
    pub mode: Mode,
    pub cfg: RvvConfig,
    /// Inject the Listing-4 partial-conversion store bug (baseline only).
    pub union_store_bug: bool,
    /// A2 ablation: intrinsic categories forced through the baseline
    /// (generic) rules even in custom mode — measures each category's
    /// contribution to the speedup.
    pub force_baseline: Vec<Category>,
    /// Tuning database override: when set, [`Translator::translate`]
    /// consults it for a winning candidate lowering of
    /// (program, mode, vlen, fingerprint) and applies that instead of
    /// the static rules; entries that are missing, stale (fingerprint
    /// mismatch) or `static` fall through to the rules unchanged.
    pub tuning: Option<Arc<TuningDb>>,
}

/// Summary of one translation (for reports).
#[derive(Debug, Clone, Default)]
pub struct TranslationReport {
    /// (intrinsic name, method) per lowered call site.
    pub methods: Vec<(String, Method)>,
    /// Non-fatal provenance notes — e.g. a tuned lowering that no longer
    /// passes the admission verifier and was replaced by the static rule.
    pub warnings: Vec<String>,
}

impl TranslationReport {
    pub fn count_by_method(&self) -> std::collections::BTreeMap<&'static str, usize> {
        let mut m = std::collections::BTreeMap::new();
        for (_, meth) in &self.methods {
            *m.entry(meth.name()).or_insert(0) += 1;
        }
        m
    }
}

impl Translator {
    pub fn new(mode: Mode, cfg: RvvConfig) -> Translator {
        Translator {
            mode,
            cfg,
            union_store_bug: false,
            force_baseline: Vec::new(),
            tuning: None,
        }
    }

    pub fn with_union_store_bug(mut self, on: bool) -> Translator {
        self.union_store_bug = on;
        self
    }

    pub fn with_forced_baseline(mut self, cats: Vec<Category>) -> Translator {
        self.force_baseline = cats;
        self
    }

    /// Consult `db` for tuned lowerings (see the `tuning` field).
    pub fn with_tuning(mut self, db: Arc<TuningDb>) -> Translator {
        self.tuning = Some(db);
        self
    }

    fn mode_for(&self, call: &crate::ir::NeonCall) -> Mode {
        if self.mode == Mode::RvvCustom && self.force_baseline.contains(&call.op.category()) {
            Mode::Baseline
        } else {
            self.mode
        }
    }

    /// Check the paper's §3.2 type constraints: every vector type the
    /// program touches must be mappable under (vlen, zvfh) for the custom
    /// mode to use RVV registers. Returns the unmappable type names.
    pub fn unmappable_types(&self, prog: &Program) -> Vec<(String, Unmappable)> {
        let mut out = Vec::new();
        for op in prog.used_ops() {
            let vt = op.sig().ret.unwrap_or_else(|| op.vt());
            if let Err(why) = map_neon_type(vt, self.cfg.vlen, self.cfg.zvfh) {
                out.push((vt.name(), why));
            }
            let it = op.vt();
            if it != vt {
                if let Err(why) = map_neon_type(it, self.cfg.vlen, self.cfg.zvfh) {
                    out.push((it.name(), why));
                }
            }
        }
        out.sort();
        out.dedup();
        out
    }

    /// Translate a whole program.
    pub fn translate(&self, prog: &Program) -> Result<(RvvProgram, TranslationReport)> {
        // Tuned override: a non-static winner recorded for exactly this
        // (kernel, mode, vlen, shape) replaces the static-rule lowering.
        // `lower_with` re-enters translation through a plain Translator
        // (no tuning), so this cannot recurse. The replayed program is
        // re-verified at load time — the database is external input, so
        // a winner recorded by an older build (or a tampered file) must
        // not bypass admission; if it no longer verifies we fall back to
        // the static rules and record a warning in the report.
        let mut warnings: Vec<String> = Vec::new();
        if let Some(db) = &self.tuning {
            if let Some(cand) =
                db.winner(&prog.name, self.mode, self.cfg.vlen, prog.fingerprint())
            {
                if !cand.is_static() {
                    let (rvv, report) =
                        crate::tuner::candidate::lower_with(prog, self.mode, self.cfg, &cand)
                            .with_context(|| {
                                format!(
                                    "applying tuned lowering '{}' to '{}'",
                                    cand.id(),
                                    prog.name
                                )
                            })?;
                    match crate::rvv::verify::verify(&rvv, self.cfg.vlen) {
                        Ok(()) => return Ok((rvv, report)),
                        Err(e) => warnings.push(format!(
                            "tuned lowering '{}' rejected by verifier ({e}) — \
                             falling back to static rules",
                            cand.id()
                        )),
                    }
                }
            }
        }
        if self.mode == Mode::RvvCustom {
            let bad = self.unmappable_types(prog);
            if !bad.is_empty() {
                bail!(
                    "program '{}' uses NEON types unmappable at vlen={} zvfh={}: {:?} \
                     (paper §3.2: fall back to the generic SIMDe path)",
                    prog.name,
                    self.cfg.vlen,
                    self.cfg.zvfh,
                    bad
                );
            }
        }
        let mut report = TranslationReport { warnings, ..TranslationReport::default() };
        let mut ctx = Ctx::new(self.cfg, &prog.bufs, prog.n_vregs as u32);
        let body = self.lower_block(&prog.body, &mut ctx, &mut report)?;
        let n_vregs = prog.n_vregs + ctx.scratch_max as usize;
        let n_mregs = ctx.mask_max as usize;
        Ok((
            RvvProgram {
                name: format!("{}@{}", prog.name, self.mode.name()),
                bufs: prog.bufs.clone(),
                body,
                n_vregs,
                n_mregs,
                n_sregs: prog.n_sregs,
            },
            report,
        ))
    }

    fn lower_block(
        &self,
        stmts: &[Stmt],
        ctx: &mut Ctx,
        report: &mut TranslationReport,
    ) -> Result<Vec<RStmt>> {
        let mut out = Vec::new();
        for s in stmts {
            match s {
                Stmt::VOp { dst, call } => {
                    let method = rules::lower(self.mode_for(call), call, Some(*dst), ctx, self.union_store_bug)
                        .with_context(|| format!("lowering {}", call.op.name()))?;
                    report.methods.push((call.op.name(), method));
                    out.append(&mut ctx.out);
                }
                Stmt::VStore { call } => {
                    let method = rules::lower(self.mode_for(call), call, None, ctx, self.union_store_bug)
                        .with_context(|| format!("lowering {}", call.op.name()))?;
                    report.methods.push((call.op.name(), method));
                    out.append(&mut ctx.out);
                }
                Stmt::SSet { dst, expr } => {
                    out.push(RStmt::SSet { dst: *dst, expr: expr.clone() });
                }
                Stmt::Loop { ivar, start, end, step, body } => {
                    let inner = self.lower_block(body, ctx, report)?;
                    out.push(RStmt::Loop {
                        ivar: *ivar,
                        start: *start,
                        end: *end,
                        step: *step,
                        body: inner,
                    });
                }
            }
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::{AddrExpr, Arg, ProgramBuilder};
    use crate::neon::elem::Elem;
    use crate::neon::interp::{Buffer, Inputs, NeonInterp};
    use crate::neon::ops::Family;
    use crate::sim::Simulator;

    fn vadd_prog() -> Program {
        let mut b = ProgramBuilder::new("vadd");
        let a = b.input("A", Elem::I32, 4);
        let bb = b.input("B", Elem::I32, 4);
        let o = b.output("O", Elem::I32, 4);
        let va = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(a, AddrExpr::k(0))]);
        let vb = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(bb, AddrExpr::k(0))]);
        let vc = b.vop(Family::Add, Elem::I32, true, vec![Arg::V(va), Arg::V(vb)]);
        b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o, AddrExpr::k(0)), Arg::V(vc)]);
        b.finish()
    }

    fn inputs() -> Inputs {
        let mut i = Inputs::new();
        i.insert("A".into(), Buffer::from_i32s(&[0, 1, 2, 3]));
        i.insert("B".into(), Buffer::from_i32s(&[4, 5, 6, 7]));
        i
    }

    #[test]
    fn listing9_to_listing10_custom() {
        // the paper's running example end-to-end
        let p = vadd_prog();
        let tr = Translator::new(Mode::RvvCustom, RvvConfig::new(128));
        let (rp, report) = tr.translate(&p).unwrap();
        // vle32 + vle32 + vadd + vse32, like Listing 10
        assert_eq!(rp.static_ops(), 4);
        assert!(report.methods.iter().all(|(_, m)| m.is_custom()));
        assert!(report.warnings.is_empty());

        let (out, stats) = Simulator::new(&rp, RvvConfig::new(128), &inputs())
            .unwrap()
            .run()
            .unwrap();
        assert_eq!(out["O"].as_i32s(), vec![4, 6, 8, 10]);
        // 4 instructions + 1 vsetvli
        assert_eq!(stats.total(), 5);
    }

    #[test]
    fn baseline_matches_numerics_but_costs_more() {
        let p = vadd_prog();
        let custom = Translator::new(Mode::RvvCustom, RvvConfig::new(128));
        let base = Translator::new(Mode::Baseline, RvvConfig::new(128));
        let (rc, _) = custom.translate(&p).unwrap();
        let (rb, _) = base.translate(&p).unwrap();

        let (oc, sc) = Simulator::new(&rc, RvvConfig::new(128), &inputs()).unwrap().run().unwrap();
        let (ob, sb) = Simulator::new(&rb, RvvConfig::new(128), &inputs()).unwrap().run().unwrap();
        assert_eq!(oc["O"].as_i32s(), ob["O"].as_i32s());
        // the baseline's e8 memcpy traffic churns vsetvli
        assert!(sb.vsetvli > sc.vsetvli, "baseline {} vs custom {}", sb.vsetvli, sc.vsetvli);
        assert!(sb.total() > sc.total());
    }

    #[test]
    fn both_modes_match_neon_interpreter() {
        let p = vadd_prog();
        let golden = NeonInterp::new(&p, &inputs()).unwrap().run().unwrap();
        for mode in [Mode::RvvCustom, Mode::Baseline] {
            let tr = Translator::new(mode, RvvConfig::new(128));
            let (rp, _) = tr.translate(&p).unwrap();
            let (out, _) = Simulator::new(&rp, RvvConfig::new(128), &inputs()).unwrap().run().unwrap();
            assert_eq!(out["O"].as_i32s(), golden["O"].as_i32s(), "mode {mode:?}");
        }
    }

    #[test]
    fn zvfh_gates_f16_programs() {
        // an f16 program translates only when Zvfh is on (paper §3.2 rule 3)
        let mut b = ProgramBuilder::new("f16add");
        let x = b.input("X", Elem::F16, 8);
        let o = b.output("O", Elem::F16, 8);
        let v = b.vop(Family::Ld1, Elem::F16, true, vec![Arg::mem(x, AddrExpr::k(0))]);
        let r = b.vop(Family::Add, Elem::F16, true, vec![Arg::V(v), Arg::V(v)]);
        b.vstore(Family::St1, Elem::F16, true, vec![Arg::mem(o, AddrExpr::k(0)), Arg::V(r)]);
        let p = b.finish();

        let on = RvvConfig { vlen: 128, zvfh: true };
        let off = RvvConfig { vlen: 128, zvfh: false };
        assert!(Translator::new(Mode::RvvCustom, on).translate(&p).is_ok());
        let err = Translator::new(Mode::RvvCustom, off).translate(&p).unwrap_err();
        assert!(format!("{err:#}").contains("NeedsZvfh"), "{err:#}");
        // generic path still available
        assert!(Translator::new(Mode::Baseline, off).translate(&p).is_ok());
    }

    #[test]
    fn disasm_contains_listing10_mnemonics() {
        let p = vadd_prog();
        let (rp, _) = Translator::new(Mode::RvvCustom, RvvConfig::new(128)).translate(&p).unwrap();
        let asm = rp.disasm();
        assert!(asm.contains("vle32"), "{asm}");
        assert!(asm.contains("vadd.vv"), "{asm}");
        assert!(asm.contains("vse32"), "{asm}");
    }

    #[test]
    fn forced_baseline_categories_degrade_gracefully() {
        use crate::neon::ops::Category;
        let p = vadd_prog();
        let cfg = RvvConfig::new(128);
        let (full, _) = Translator::new(Mode::RvvCustom, cfg).translate(&p).unwrap();
        let (degraded, _) = Translator::new(Mode::RvvCustom, cfg)
            .with_forced_baseline(vec![Category::Memory])
            .translate(&p)
            .unwrap();
        let (of, sf) = Simulator::new(&full, cfg, &inputs()).unwrap().run().unwrap();
        let (od, sd) = Simulator::new(&degraded, cfg, &inputs()).unwrap().run().unwrap();
        assert_eq!(of["O"].as_i32s(), od["O"].as_i32s());
        assert!(sd.total() > sf.total(), "{} vs {}", sd.total(), sf.total());
    }

    #[test]
    fn custom_mode_rejects_small_vlen() {
        // paper §3.2 rule 2: q types need vlen >= 128
        let p = vadd_prog();
        let tr = Translator::new(Mode::RvvCustom, RvvConfig::new(64));
        assert!(tr.translate(&p).is_err());
        // baseline still works (generic path)
        let tr = Translator::new(Mode::Baseline, RvvConfig::new(64));
        assert!(tr.translate(&p).is_ok());
    }
}
