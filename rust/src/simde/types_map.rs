//! The paper's §3.2 type-conversion strategy (Table 2): mapping NEON
//! fixed-size vector types onto RVV LMUL=1 fixed-vlen types (LLVM D145088),
//! gated by the hardware `vlen` and the `Zvfh` extension.
//!
//! Rules reproduced from the paper:
//! 1. vlen < 64 — no substitution for NEON 64-bit types;
//! 2. vlen < 128 — no substitution for NEON 128-bit types;
//! 3. without Zvfh, f16 vectors cannot be substituted.
//!
//! When substitution fails the union's vector-attribute member is used
//! instead (the generic SIMDe path).
//!
//! Note: the paper's printed Table 2 contains obvious typesetting slips
//! (128-bit integer rows all read `vint8m1_t`); we implement the intended
//! mapping (`int16x8_t -> vint16m1_t`, etc.) and record the discrepancy in
//! EXPERIMENTS.md.

use crate::neon::elem::Elem;
use crate::neon::vreg::VecTy;
use crate::rvv::vtype::Lmul;

/// A fixed-vlen RVV intrinsic type (LMUL=1 per D145088).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RvvType {
    pub elem: Elem,
    pub lmul: Lmul,
}

impl RvvType {
    /// C type name, e.g. `vint32m1_t`, `vfloat16m1_t`.
    pub fn name(self) -> String {
        let base = match self.elem {
            Elem::I8 => "int8",
            Elem::I16 => "int16",
            Elem::I32 => "int32",
            Elem::I64 => "int64",
            Elem::U8 => "uint8",
            Elem::U16 => "uint16",
            Elem::U32 => "uint32",
            Elem::U64 => "uint64",
            Elem::F16 => "float16",
            Elem::F32 => "float32",
            Elem::F64 => "float64",
            // poly types map onto unsigned carriers
            Elem::P8 => "uint8",
            Elem::P16 => "uint16",
            Elem::P64 => "uint64",
            Elem::BF16 => "bfloat16",
        };
        let m = match self.lmul {
            Lmul::MF2 => "mf2",
            Lmul::M1 => "m1",
            Lmul::M2 => "m2",
            Lmul::M4 => "m4",
            Lmul::M8 => "m8",
        };
        format!("v{base}{m}_t")
    }
}

/// Why a NEON type could not be mapped (the paper's `x` cells).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Unmappable {
    /// vlen too small for the NEON register width.
    VlenTooSmall,
    /// f16 requires the Zvfh extension.
    NeedsZvfh,
    /// bf16 has no modelled RVV counterpart (would need Zvfbfmin).
    NoRvvType,
}

/// Map a NEON vector type to its RVV LMUL=1 type under a given `vlen` and
/// extension set — the paper's Table 2 as a function.
pub fn map_neon_type(vt: VecTy, vlen: u32, zvfh: bool) -> Result<RvvType, Unmappable> {
    if vt.elem == Elem::BF16 {
        return Err(Unmappable::NoRvvType);
    }
    if vt.elem == Elem::F16 && !zvfh {
        return Err(Unmappable::NeedsZvfh);
    }
    if vlen < vt.bits() {
        return Err(Unmappable::VlenTooSmall);
    }
    Ok(RvvType { elem: vt.elem, lmul: Lmul::M1 })
}

/// The row set of the paper's Table 2, in print order.
pub fn table2_rows() -> Vec<VecTy> {
    let d = [
        Elem::I8, Elem::I16, Elem::I32, Elem::I64,
        Elem::U8, Elem::U16, Elem::U32, Elem::U64,
        Elem::F16, Elem::F32, Elem::F64,
    ];
    let mut rows: Vec<VecTy> = d.iter().map(|&e| VecTy::d(e)).collect();
    rows.extend(d.iter().map(|&e| VecTy::q(e)));
    rows
}

/// Render one Table 2 cell: type name or `x`.
pub fn table2_cell(vt: VecTy, vlen: u32, zvfh: bool) -> String {
    match map_neon_type(vt, vlen, zvfh) {
        Ok(t) => t.name(),
        Err(_) => "x".to_string(),
    }
}

/// Size of the SIMDe generic union for a NEON type once the RVV member is
/// added (§3.2: "the size of the union increases" when vlen > NEON width) —
/// this is what makes the memcpy-store bug (Listing 4) observable.
pub fn union_size_bytes(vt: VecTy, vlen: u32, zvfh: bool) -> u32 {
    let neon = vt.bits() / 8;
    match map_neon_type(vt, vlen, zvfh) {
        Ok(_) => neon.max(vlen / 8),
        Err(_) => neon,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table2_vlen_128_matches_paper() {
        // vlen >= 128: every d and q integer/float row maps to m1
        assert_eq!(table2_cell(VecTy::d(Elem::I8), 128, true), "vint8m1_t");
        assert_eq!(table2_cell(VecTy::q(Elem::I16), 128, true), "vint16m1_t");
        assert_eq!(table2_cell(VecTy::q(Elem::U64), 128, true), "vuint64m1_t");
        assert_eq!(table2_cell(VecTy::q(Elem::F16), 128, true), "vfloat16m1_t");
        assert_eq!(table2_cell(VecTy::q(Elem::F64), 128, true), "vfloat64m1_t");
    }

    #[test]
    fn table2_vlen_64_only_d_types() {
        // 64 <= vlen < 128: d types map, q types don't
        assert_eq!(table2_cell(VecTy::d(Elem::I32), 64, true), "vint32m1_t");
        assert_eq!(table2_cell(VecTy::q(Elem::I32), 64, true), "x");
        assert_eq!(table2_cell(VecTy::d(Elem::F32), 64, true), "vfloat32m1_t");
    }

    #[test]
    fn table2_vlen_32_nothing() {
        for vt in table2_rows() {
            assert_eq!(table2_cell(vt, 32, true), "x");
        }
    }

    #[test]
    fn zvfh_gates_f16() {
        assert_eq!(table2_cell(VecTy::q(Elem::F16), 128, false), "x");
        assert_eq!(table2_cell(VecTy::d(Elem::F16), 128, false), "x");
        assert_eq!(table2_cell(VecTy::q(Elem::F16), 128, true), "vfloat16m1_t");
        // other types unaffected
        assert_eq!(table2_cell(VecTy::q(Elem::F32), 128, false), "vfloat32m1_t");
    }

    #[test]
    fn union_grows_with_vlen() {
        // the Listing-4 bug precondition: union bigger than the NEON value
        assert_eq!(union_size_bytes(VecTy::q(Elem::I32), 128, true), 16);
        assert_eq!(union_size_bytes(VecTy::q(Elem::I32), 256, true), 32);
        assert_eq!(union_size_bytes(VecTy::d(Elem::I32), 256, true), 32);
        // unmapped types keep the NEON size
        assert_eq!(union_size_bytes(VecTy::q(Elem::I32), 64, true), 16);
    }

    #[test]
    fn poly_maps_to_unsigned_carrier() {
        assert_eq!(
            map_neon_type(VecTy::q(Elem::P8), 128, true).unwrap().name(),
            "vuint8m1_t"
        );
    }

    #[test]
    fn row_count_matches_paper() {
        // Table 2 lists 22 rows (11 d + 11 q)
        assert_eq!(table2_rows().len(), 22);
    }
}
