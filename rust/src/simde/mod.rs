//! The paper's contribution: the SIMDe NEON->RVV translation engine.
//!
//! - [`types_map`] — §3.2 type conversion (Table 2): NEON fixed types onto
//!   fixed-vlen LMUL=1 RVV types, gated by vlen and Zvfh;
//! - [`rules`] — §3.3 function conversion: customized RVV sequences per
//!   intrinsic (Listings 4-7) vs the generic baseline paths;
//! - [`lower`] — program-level translation to [`crate::rvv::RvvProgram`];
//! - [`registry`] — coverage table over the whole implemented surface;
//! - [`costs`] — the calibrated baseline cost model.

pub mod costs;
pub mod ctx;
pub mod lower;
pub mod method;
pub mod registry;
pub mod rules;
pub mod types_map;

pub use lower::{TranslationReport, Translator};
pub use method::{Method, Mode};
