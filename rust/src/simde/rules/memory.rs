//! Memory-intrinsic conversions (`vld1*`/`vst1*`).
//!
//! Custom mode issues typed unit-stride RVV loads/stores with the exact
//! element count — the paper's Listing 4 fix ("Ensure that we save the
//! correct number of elements into memory").
//!
//! Baseline mode models SIMDe's generic path: `memcpy` through the private
//! union, which clang lowers to byte-granular vector memory ops (`vle8`/
//! `vse8` of the register width). Semantically identical on little-endian,
//! but the `e8` configuration churns `vsetvli` against the typed compute
//! around it. With the (optional) partial-conversion bug enabled, stores
//! copy `sizeof(union)` bytes — more than the NEON value — reproducing the
//! Listing 4 overrun.

use anyhow::{bail, Result};

use crate::ir::NeonCall;
use crate::neon::ops::Family;
use crate::rvv::ops::{Dst, RvvKind, Src};
use crate::rvv::vtype::Sew;
use crate::simde::ctx::{op_sew_vl, Ctx};
use crate::simde::method::Method;
use crate::simde::types_map::union_size_bytes;

pub fn custom(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let (sew, vl) = op_sew_vl(op);
    match op.family {
        Family::Ld1 => {
            ctx.load(sew, vl, dst.unwrap(), ctx.memref(&call.args[0]));
            Ok(Method::CustomDirect)
        }
        Family::Ld1Dup => {
            // stride-0 broadcast load
            ctx.load(sew, vl, dst.unwrap(), ctx.memref_strided(&call.args[0], 0));
            Ok(Method::CustomDirect)
        }
        Family::Ld1Lane => {
            // vid + vmseq -> lane mask; masked stride-0 load leaves the
            // other lanes undisturbed
            let d = dst.unwrap();
            let src = match call.args[1] {
                crate::ir::Arg::V(r) => ctx.v(r),
                _ => bail!("vld1_lane expects vector arg"),
            };
            let lane = match call.args[2] {
                crate::ir::Arg::Imm(i) => i,
                _ => bail!("vld1_lane expects imm lane"),
            };
            ctx.mov_v(sew, vl, d, src);
            let t = ctx.scratch();
            let mk = ctx.mask();
            ctx.op(RvvKind::Vid, sew, vl, Dst::V(t), vec![]);
            ctx.op(RvvKind::Vmseq, sew, vl, Dst::M(mk), vec![Src::V(t), Src::ImmI(lane)]);
            ctx.load_masked(sew, vl, d, ctx.memref_strided(&call.args[0], 0), mk);
            Ok(Method::CustomCombo)
        }
        Family::St1 => {
            let src = match call.args[1] {
                crate::ir::Arg::V(r) => ctx.v(r),
                _ => bail!("vst1 expects vector arg"),
            };
            ctx.store(sew, vl, src, ctx.memref(&call.args[0]));
            Ok(Method::CustomDirect)
        }
        Family::St1Lane => {
            let src = match call.args[1] {
                crate::ir::Arg::V(r) => ctx.v(r),
                _ => bail!("vst1_lane expects vector arg"),
            };
            let lane = match call.args[2] {
                crate::ir::Arg::Imm(i) => i,
                _ => bail!("vst1_lane expects imm lane"),
            };
            let t = ctx.scratch();
            ctx.op(RvvKind::Vslidedown, sew, 1, Dst::V(t), vec![Src::V(src), Src::ImmI(lane)]);
            ctx.store(sew, 1, t, ctx.memref(&call.args[0]));
            Ok(Method::CustomCombo)
        }
        f => bail!("memory::custom got family {f:?}"),
    }
}

pub fn baseline(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx, union_store_bug: bool) -> Result<Method> {
    let op = call.op;
    let (sew, vl) = op_sew_vl(op);
    let bytes = op.vt().bits() / 8;
    match op.family {
        Family::Ld1 => {
            // memcpy(&union, ptr, bytes) -> vle8 of the register width
            ctx.load(Sew::E8, bytes, dst.unwrap(), ctx.memref(&call.args[0]));
            Ok(Method::MemUnion)
        }
        Family::Ld1Dup => {
            // scalar load + generic dup, clang lowers to a broadcast; same
            // instruction shape as custom but in the e8/compute churn
            ctx.load(sew, vl, dst.unwrap(), ctx.memref_strided(&call.args[0], 0));
            Ok(Method::ScalarAutovec)
        }
        Family::Ld1Lane | Family::St1Lane => {
            // per-lane memcpy through the union -> scalar fallback
            super::scalar_fallback(call, dst, 2, 3, ctx);
            Ok(Method::ScalarLoop)
        }
        Family::St1 => {
            let src = match call.args[1] {
                crate::ir::Arg::V(r) => ctx.v(r),
                _ => bail!("vst1 expects vector arg"),
            };
            let store_bytes = if union_store_bug {
                // Listing 4 bug: memcpy(ptr, &union, sizeof(union))
                union_size_bytes(op.vt(), ctx.cfg.vlen, ctx.cfg.zvfh)
            } else {
                bytes
            };
            ctx.store(Sew::E8, store_bytes, src, ctx.memref(&call.args[0]));
            Ok(Method::MemUnion)
        }
        f => bail!("memory::baseline got family {f:?}"),
    }
}
