//! Widen/narrow and conversion rules. `vmovl`/`vmovn` map to single
//! `vsext`/`vzext`/`vnsrl`; saturating narrows clamp then narrow; the
//! round-to-nearest conversions (`vcvtnq`, the hot op in XNNPACK's
//! exp-based sigmoid/tanh) map to a single `vfcvt.x.f.v` — while the SIMDe
//! generic is a per-lane `roundevenf` libm loop the auto-vectorizer
//! rejects.

use anyhow::{bail, Result};

use crate::ir::NeonCall;
use crate::neon::ops::Family;
use crate::rvv::ops::{Dst, RvvKind, Src};
use crate::rvv::vtype::Sew;
use crate::simde::costs;
use crate::simde::ctx::{op_sew_vl, ret_sew_vl, Ctx};
use crate::simde::method::Method;

pub fn custom(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let e = op.elem;
    let d = dst.unwrap();
    match op.family {
        Family::Movl => {
            let (wsew, wvl) = ret_sew_vl(op);
            let a = ctx.vsrc(&call.args[0]);
            let kind = if e.is_unsigned() { RvvKind::Vzext2 } else { RvvKind::Vsext2 };
            ctx.op(kind, wsew, wvl, Dst::V(d), vec![a]);
            Ok(Method::CustomDirect)
        }
        Family::Movn => {
            let (nsew, nvl) = ret_sew_vl(op);
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::Vnsrl, nsew, nvl, Dst::V(d), vec![a, Src::ImmI(0)]);
            Ok(Method::CustomDirect)
        }
        Family::Qmovn => {
            // clamp at wide SEW then narrow
            let (nsew, nvl) = ret_sew_vl(op);
            let wsew = Sew::of_bits(nsew.bits() * 2);
            let a = ctx.vsrc(&call.args[0]);
            let t = ctx.scratch();
            if e.is_unsigned() {
                let hi = (1i64 << nsew.bits()) - 1;
                ctx.op(RvvKind::Vminu, wsew, nvl, Dst::V(t), vec![a, Src::ImmI(hi)]);
            } else {
                let hi = (1i64 << (nsew.bits() - 1)) - 1;
                let lo = -(1i64 << (nsew.bits() - 1));
                ctx.op(RvvKind::Vmin, wsew, nvl, Dst::V(t), vec![a, Src::ImmI(hi)]);
                ctx.op(RvvKind::Vmax, wsew, nvl, Dst::V(t), vec![Src::V(t), Src::ImmI(lo)]);
            }
            ctx.op(RvvKind::Vnsrl, nsew, nvl, Dst::V(d), vec![Src::V(t), Src::ImmI(0)]);
            Ok(Method::CustomCombo)
        }
        Family::Qmovun => {
            // signed wide -> unsigned narrow: clamp [0, 2^n - 1]
            let (nsew, nvl) = ret_sew_vl(op);
            let wsew = Sew::of_bits(nsew.bits() * 2);
            let a = ctx.vsrc(&call.args[0]);
            let t = ctx.scratch();
            let hi = (1i64 << nsew.bits()) - 1;
            ctx.op(RvvKind::Vmax, wsew, nvl, Dst::V(t), vec![a, Src::ImmI(0)]);
            ctx.op(RvvKind::Vmin, wsew, nvl, Dst::V(t), vec![Src::V(t), Src::ImmI(hi)]);
            ctx.op(RvvKind::Vnsrl, nsew, nvl, Dst::V(d), vec![Src::V(t), Src::ImmI(0)]);
            Ok(Method::CustomCombo)
        }
        Family::CvtIF => {
            let (sew, vl) = op_sew_vl(op);
            let a = ctx.vsrc(&call.args[0]);
            let kind = if e.is_unsigned() { RvvKind::VfcvtFXu } else { RvvKind::VfcvtFX };
            ctx.op(kind, sew, vl, Dst::V(d), vec![a]);
            Ok(Method::CustomDirect)
        }
        Family::CvtFI => {
            let (sew, vl) = op_sew_vl(op);
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::VfcvtRtzXF, sew, vl, Dst::V(d), vec![a]);
            Ok(Method::CustomDirect)
        }
        Family::CvtnFI => {
            let (sew, vl) = op_sew_vl(op);
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::VfcvtXF, sew, vl, Dst::V(d), vec![a]);
            Ok(Method::CustomDirect)
        }
        Family::Reinterpret => {
            // bit cast: register copy (clang emits nothing; we count the
            // conservative vmv both modes emit)
            let a = ctx.vsrc(&call.args[0]);
            let bytes = op.vt().bits() / 8;
            ctx.op(RvvKind::VmvVV, Sew::E8, bytes, Dst::V(d), vec![a]);
            Ok(Method::CustomDirect)
        }
        f => bail!("convert::custom got family {f:?}"),
    }
}

pub fn baseline(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    match op.family {
        // __builtin_convertvector lowers the same way
        Family::Movl | Family::Movn | Family::CvtIF | Family::CvtFI | Family::Reinterpret => {
            custom(call, dst, ctx)?;
            Ok(Method::VectorAttr)
        }
        // branchy clamp loops don't vectorize
        Family::Qmovn | Family::Qmovun => {
            super::scalar_fallback(call, dst, costs::QNARROW_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        // per-lane roundevenf libm call: scalarised
        Family::CvtnFI => {
            super::scalar_fallback(call, dst, costs::ROUNDEVEN_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        f => bail!("convert::baseline got family {f:?}"),
    }
}
