//! Permute conversions: `vget_high` -> `vslidedown` (paper Listing 5),
//! combine/ext via slides, reversals via `vid`+`vxor`+`vrgather`, zips via
//! the widening-interleave trick, unzips via `vnsrl`, and broadcasts via
//! `vrgather.vi` / `vmv.v.x`.
//!
//! Baseline: SIMDe's generic permutes go through `SIMDE_SHUFFLE_VECTOR_`
//! (clang shufflevector — lowered to constant-index `vrgather` with an
//! index load from the constant pool) or, for `vget_high`-style half moves,
//! `memcpy` from the private union (stack spill + reload).

use anyhow::{bail, Result};

use crate::ir::{Arg, NeonCall};
use crate::neon::ops::Family;
use crate::rvv::ops::{Dst, RvvKind, Src};
use crate::rvv::vtype::Sew;
use crate::simde::costs;
use crate::simde::ctx::{op_sew_vl, ret_sew_vl, Ctx};
use crate::simde::method::Method;

fn vr(ctx: &Ctx, a: &Arg) -> Result<u32> {
    match a {
        Arg::V(r) => Ok(ctx.v(*r)),
        _ => bail!("expected vector register"),
    }
}

fn imm(a: &Arg) -> Result<i64> {
    match a {
        Arg::Imm(i) => Ok(*i),
        _ => bail!("expected immediate"),
    }
}

pub fn custom(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let e = op.elem;
    let d = dst.unwrap();
    let sew = Sew::of_elem(e);
    match op.family {
        Family::GetLow => {
            let a = vr(ctx, &call.args[0])?;
            let dl = (64 / e.bits()) as u32;
            ctx.mov_v(sew, dl, d, a);
            if d == a {
                // register already holds the value; a true no-op, but SIMDe
                // still materialises the d-typed result: count one vmv
                ctx.op(RvvKind::VmvVV, sew, dl, Dst::V(d), vec![Src::V(a)]);
            }
            Ok(Method::CustomDirect)
        }
        Family::GetHigh => {
            // paper Listing 5
            let a = vr(ctx, &call.args[0])?;
            let dl = (64 / e.bits()) as u32;
            ctx.op(RvvKind::Vslidedown, sew, dl, Dst::V(d), vec![Src::V(a), Src::ImmI(dl as i64)]);
            Ok(Method::CustomCombo)
        }
        Family::Combine => {
            let lo = vr(ctx, &call.args[0])?;
            let hi = vr(ctx, &call.args[1])?;
            let dl = (64 / e.bits()) as u32;
            ctx.mov_v(sew, dl, d, lo);
            ctx.op(RvvKind::Vslideup, sew, 2 * dl, Dst::V(d), vec![Src::V(hi), Src::ImmI(dl as i64)]);
            Ok(Method::CustomCombo)
        }
        Family::Ext => {
            let (_, vl) = op_sew_vl(op);
            let a = vr(ctx, &call.args[0])?;
            let b = vr(ctx, &call.args[1])?;
            let n = imm(&call.args[2])?;
            ctx.op(RvvKind::Vslidedown, sew, vl, Dst::V(d), vec![Src::V(a), Src::ImmI(n)]);
            if n > 0 {
                // b[0..n-1] lands in the top n lanes; vslideup leaves the
                // lanes below the offset undisturbed
                ctx.op(RvvKind::Vslideup, sew, vl, Dst::V(d), vec![Src::V(b), Src::ImmI(vl as i64 - n)]);
            }
            Ok(Method::CustomCombo)
        }
        Family::Rev64 | Family::Rev32 | Family::Rev16 => {
            // reversal within aligned power-of-two groups == index XOR (g-1)
            let (_, vl) = op_sew_vl(op);
            let a = vr(ctx, &call.args[0])?;
            let g = match op.family {
                Family::Rev64 => 64 / e.bits(),
                Family::Rev32 => 32 / e.bits(),
                _ => 16 / e.bits(),
            } as i64;
            let idx = ctx.scratch();
            ctx.op(RvvKind::Vid, sew, vl, Dst::V(idx), vec![]);
            ctx.op(RvvKind::Vxor, sew, vl, Dst::V(idx), vec![Src::V(idx), Src::ImmI(g - 1)]);
            ctx.op(RvvKind::Vrgather, sew, vl, Dst::V(d), vec![Src::V(a), Src::V(idx)]);
            Ok(Method::CustomCombo)
        }
        Family::Zip1 | Family::Zip2 => {
            let (_, vl) = op_sew_vl(op);
            let mut a = vr(ctx, &call.args[0])?;
            let mut b = vr(ctx, &call.args[1])?;
            let half = vl / 2;
            if op.family == Family::Zip2 {
                let (ta, tb) = (ctx.scratch(), ctx.scratch());
                ctx.op(RvvKind::Vslidedown, sew, half, Dst::V(ta), vec![Src::V(a), Src::ImmI(half as i64)]);
                ctx.op(RvvKind::Vslidedown, sew, half, Dst::V(tb), vec![Src::V(b), Src::ImmI(half as i64)]);
                a = ta;
                b = tb;
            }
            if sew.bits() >= 64 {
                // 2-lane vectors: [a0, b0]
                ctx.mov_v(sew, 1, d, a);
                if d == a {
                    ctx.op(RvvKind::VmvVV, sew, 1, Dst::V(d), vec![Src::V(a)]);
                }
                ctx.op(RvvKind::Vslideup, sew, 2, Dst::V(d), vec![Src::V(b), Src::ImmI(1)]);
            } else {
                // widening interleave (RVV cookbook): t = a + b, then
                // t += b * (2^sew - 1)  =>  t = a + b * 2^sew — the scalar
                // multiplier must fit in SEW bits, hence the -1 form
                let t = ctx.scratch();
                ctx.op(RvvKind::Vwaddu, sew, half, Dst::V(t), vec![Src::V(a), Src::V(b)]);
                let mul = (1i64 << sew.bits()) - 1;
                ctx.op(RvvKind::Vwmaccu, sew, half, Dst::V(t), vec![Src::V(b), Src::ImmI(mul)]);
                ctx.op(RvvKind::VmvVV, sew, vl, Dst::V(d), vec![Src::V(t)]);
            }
            Ok(Method::CustomCombo)
        }
        Family::Uzp1 | Family::Uzp2 => {
            let (_, vl) = op_sew_vl(op);
            let a = vr(ctx, &call.args[0])?;
            let b = vr(ctx, &call.args[1])?;
            let half = vl / 2;
            if sew.bits() >= 64 {
                // 2-lane: uzp1 = [a0,b0], uzp2 = [a1,b1]
                let n = if op.family == Family::Uzp2 { 1 } else { 0 };
                ctx.op(RvvKind::Vslidedown, sew, 1, Dst::V(d), vec![Src::V(a), Src::ImmI(n)]);
                let t = ctx.scratch();
                ctx.op(RvvKind::Vslidedown, sew, 1, Dst::V(t), vec![Src::V(b), Src::ImmI(n)]);
                ctx.op(RvvKind::Vslideup, sew, 2, Dst::V(d), vec![Src::V(t), Src::ImmI(1)]);
            } else {
                // evens/odds of each source via vnsrl, then concatenate
                let sh = if op.family == Family::Uzp2 { sew.bits() as i64 } else { 0 };
                let t = ctx.scratch();
                ctx.op(RvvKind::Vnsrl, sew, half, Dst::V(d), vec![Src::V(a), Src::ImmI(sh)]);
                ctx.op(RvvKind::Vnsrl, sew, half, Dst::V(t), vec![Src::V(b), Src::ImmI(sh)]);
                ctx.op(RvvKind::Vslideup, sew, vl, Dst::V(d), vec![Src::V(t), Src::ImmI(half as i64)]);
            }
            Ok(Method::CustomCombo)
        }
        Family::Trn1 | Family::Trn2 => {
            // dst[2i] = a[2i+o], dst[2i+1] = b[2i+o]
            let (_, vl) = op_sew_vl(op);
            let a = vr(ctx, &call.args[0])?;
            let b = vr(ctx, &call.args[1])?;
            let o = if op.family == Family::Trn2 { 1i64 } else { 0 };
            // idx_a = (vid & ~1) + o ; gather a; idx shifted for b lanes
            let idx = ctx.scratch();
            let ga = ctx.scratch();
            let gb = ctx.scratch();
            let mk = ctx.mask();
            ctx.op(RvvKind::Vid, sew, vl, Dst::V(idx), vec![]);
            // parity mask: odd lanes take b
            let par = ctx.scratch();
            ctx.op(RvvKind::Vand, sew, vl, Dst::V(par), vec![Src::V(idx), Src::ImmI(1)]);
            ctx.op(RvvKind::Vmseq, sew, vl, Dst::M(mk), vec![Src::V(par), Src::ImmI(1)]);
            // base index = (vid & ~1) + o
            ctx.op(RvvKind::Vand, sew, vl, Dst::V(idx), vec![Src::V(idx), Src::ImmI(-2)]);
            if o != 0 {
                ctx.op(RvvKind::Vadd, sew, vl, Dst::V(idx), vec![Src::V(idx), Src::ImmI(o)]);
            }
            ctx.op(RvvKind::Vrgather, sew, vl, Dst::V(ga), vec![Src::V(a), Src::V(idx)]);
            ctx.op(RvvKind::Vrgather, sew, vl, Dst::V(gb), vec![Src::V(b), Src::V(idx)]);
            ctx.op(RvvKind::Vmerge, sew, vl, Dst::V(d), vec![Src::V(ga), Src::V(gb), Src::M(mk)]);
            Ok(Method::CustomCombo)
        }
        Family::DupLane => {
            let (_, vl) = ret_sew_vl(op);
            let a = vr(ctx, &call.args[0])?;
            let lane = imm(&call.args[1])?;
            ctx.op(RvvKind::Vrgather, sew, vl, Dst::V(d), vec![Src::V(a), Src::ImmI(lane)]);
            Ok(Method::CustomDirect)
        }
        Family::DupN => {
            let (_, vl) = ret_sew_vl(op);
            match &call.args[0] {
                Arg::Imm(i) => {
                    ctx.op(RvvKind::VmvVX, sew, vl, Dst::V(d), vec![Src::ImmI(*i)]);
                }
                Arg::ImmF(f) => {
                    ctx.op(RvvKind::VfmvVF, sew, vl, Dst::V(d), vec![Src::ImmF(*f)]);
                }
                Arg::S(r) => {
                    ctx.op(RvvKind::VmvVX, sew, vl, Dst::V(d), vec![Src::SReg(*r)]);
                }
                _ => bail!("vdup_n expects scalar"),
            }
            Ok(Method::CustomDirect)
        }
        Family::Tbl1 => {
            // vrgather + zero out-of-table lanes (NEON zeroes idx >= 8)
            let a = vr(ctx, &call.args[0])?;
            let idx = vr(ctx, &call.args[1])?;
            let dl = 8u32;
            let mk = ctx.mask();
            let zeros = ctx.scratch();
            ctx.op(RvvKind::Vrgather, sew, dl, Dst::V(d), vec![Src::V(a), Src::V(idx)]);
            ctx.op(RvvKind::Vmsgtu, sew, dl, Dst::M(mk), vec![Src::V(idx), Src::ImmI(7)]);
            ctx.op(RvvKind::VmvVX, sew, dl, Dst::V(zeros), vec![Src::ImmI(0)]);
            ctx.op(RvvKind::Vmerge, sew, dl, Dst::V(d), vec![Src::V(d), Src::V(zeros), Src::M(mk)]);
            Ok(Method::CustomCombo)
        }
        f => bail!("permute::custom got family {f:?}"),
    }
}

pub fn baseline(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let e = op.elem;
    let sew = Sew::of_elem(e);
    match op.family {
        // memcpy from the union's value array: stack spill + byte reload
        Family::GetLow | Family::GetHigh | Family::Combine => {
            let d = dst.unwrap();
            let dl = (64 / e.bits()) as u32;
            // modelled as: vse8 (spill) + vle8 (reload at offset); the
            // values move through memory, so emit the semantic equivalent
            // (slides) plus the extra memory traffic the union path incurs
            custom(call, Some(d), ctx)?;
            ctx.out.push(crate::rvv::program::RStmt::Scalar(crate::rvv::program::ScalarBlock {
                call: NeonCall { op, args: vec![] },
                dst: None,
                scalar_cost: 1, // address of the union member
                mem_ops: 2,     // spill + reload
                cost_only: true,
            }));
            let _ = dl;
            Ok(Method::MemUnion)
        }
        // clang shufflevector: constant-pool index load + vrgather (+merge
        // for two-source shuffles)
        Family::Ext | Family::Zip1 | Family::Zip2 | Family::Uzp1 | Family::Uzp2
        | Family::Trn1 | Family::Trn2 => {
            let d = dst.unwrap();
            let (_, vl) = op_sew_vl(op);
            // semantics via the custom lowering, plus the baseline's extra
            // index-vector materialisation and merge overhead
            custom(call, Some(d), ctx)?;
            let t = ctx.scratch();
            ctx.op(RvvKind::Vid, sew, vl, Dst::V(t), vec![]);
            ctx.op(RvvKind::Vadd, sew, vl, Dst::V(t), vec![Src::V(t), Src::ImmI(1)]);
            Ok(Method::VectorAttr)
        }
        Family::Rev64 | Family::Rev32 | Family::Rev16 => {
            // single-source constant shuffle: idx load + vrgather
            let d = dst.unwrap();
            custom(call, Some(d), ctx)?;
            Ok(Method::VectorAttr)
        }
        Family::DupLane | Family::DupN => {
            custom(call, dst, ctx)?;
            Ok(Method::VectorAttr)
        }
        // bounds-checked gather loop does not vectorize
        Family::Tbl1 => {
            super::scalar_fallback(call, dst, costs::TBL_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        f => bail!("permute::baseline got family {f:?}"),
    }
}
