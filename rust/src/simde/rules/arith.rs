//! Arithmetic conversions: direct 1:1 maps (`vadd` -> `vadd.vv`), fused
//! multiply-accumulate (`vfmaq` -> `vfmacc.vv`, the gemm hot op), widening
//! multiplies (`vmull` -> `vwmul.vv`), halving adds via widen+narrow, and
//! saturating ops (`vqadd` -> `vsadd.vv`) whose SIMDe generic is a branchy
//! scalar loop — one of the big baseline losses.

use anyhow::{bail, Result};

use crate::ir::{Arg, NeonCall};
use crate::neon::ops::Family;
use crate::rvv::ops::{Dst, RvvKind, Src};
use crate::rvv::vtype::Sew;
use crate::simde::costs;
use crate::simde::ctx::{op_sew_vl, Ctx};
use crate::simde::method::Method;

fn vr(ctx: &Ctx, a: &Arg) -> Result<u32> {
    match a {
        Arg::V(r) => Ok(ctx.v(*r)),
        _ => bail!("expected vector register"),
    }
}

/// Pick the signed/unsigned/float variant of a 3-way op family.
fn pick3(e: crate::neon::elem::Elem, s: RvvKind, u: RvvKind, f: RvvKind) -> RvvKind {
    if e.is_float() {
        f
    } else if e.is_unsigned() {
        u
    } else {
        s
    }
}

pub fn custom(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let e = op.elem;
    let (sew, vl) = op_sew_vl(op);
    let d = dst.unwrap();
    let fam = op.family;
    match fam {
        Family::Add | Family::Sub | Family::Mul | Family::Div | Family::Min | Family::Max => {
            let kind = match fam {
                Family::Add => pick3(e, RvvKind::Vadd, RvvKind::Vadd, RvvKind::Vfadd),
                Family::Sub => pick3(e, RvvKind::Vsub, RvvKind::Vsub, RvvKind::Vfsub),
                Family::Mul => pick3(e, RvvKind::Vmul, RvvKind::Vmul, RvvKind::Vfmul),
                Family::Div => RvvKind::Vfdiv,
                Family::Min => pick3(e, RvvKind::Vmin, RvvKind::Vminu, RvvKind::Vfmin),
                Family::Max => pick3(e, RvvKind::Vmax, RvvKind::Vmaxu, RvvKind::Vfmax),
                _ => unreachable!(),
            };
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            ctx.op(kind, sew, vl, Dst::V(d), vec![a, b]);
            Ok(Method::CustomDirect)
        }
        Family::Mla | Family::Mls | Family::Fma | Family::Fms => {
            // acc in dst register, then vmacc/vfmacc family
            let acc = vr(ctx, &call.args[0])?;
            let a = ctx.vsrc(&call.args[1]);
            let b = ctx.vsrc(&call.args[2]);
            ctx.ensure_acc_in_dst(sew, vl, d, acc);
            let kind = if e.is_float() {
                if matches!(fam, Family::Mla | Family::Fma) {
                    RvvKind::Vfmacc
                } else {
                    RvvKind::Vfnmsac
                }
            } else if matches!(fam, Family::Mla) {
                RvvKind::Vmacc
            } else {
                RvvKind::Vnmsac
            };
            ctx.op(kind, sew, vl, Dst::V(d), vec![a, b]);
            Ok(Method::CustomDirect)
        }
        Family::Abs => {
            let a = ctx.vsrc(&call.args[0]);
            if e.is_float() {
                ctx.op(RvvKind::Vfsgnjx, sew, vl, Dst::V(d), vec![a.clone(), a]);
            } else {
                let t = ctx.scratch();
                ctx.op(RvvKind::Vrsub, sew, vl, Dst::V(t), vec![a.clone(), Src::ImmI(0)]);
                ctx.op(RvvKind::Vmax, sew, vl, Dst::V(d), vec![a, Src::V(t)]);
            }
            Ok(Method::CustomCombo)
        }
        Family::Neg => {
            let a = ctx.vsrc(&call.args[0]);
            if e.is_float() {
                ctx.op(RvvKind::Vfsgnjn, sew, vl, Dst::V(d), vec![a.clone(), a]);
            } else {
                ctx.op(RvvKind::Vrsub, sew, vl, Dst::V(d), vec![a, Src::ImmI(0)]);
            }
            Ok(Method::CustomDirect)
        }
        Family::Hadd | Family::Rhadd => {
            // (a + b [+1]) >> 1 via widening add + narrowing shift
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            let t = ctx.scratch();
            let wadd = if e.is_unsigned() { RvvKind::Vwaddu } else { RvvKind::Vwadd };
            ctx.op(wadd, sew, vl, Dst::V(t), vec![a, b]);
            let wide = Sew::of_bits(sew.bits() * 2);
            if fam == Family::Rhadd {
                ctx.op(RvvKind::Vadd, wide, vl, Dst::V(t), vec![Src::V(t), Src::ImmI(1)]);
            }
            let nsr = if e.is_unsigned() { RvvKind::Vnsrl } else { RvvKind::Vnsra };
            ctx.op(nsr, sew, vl, Dst::V(d), vec![Src::V(t), Src::ImmI(1)]);
            Ok(Method::CustomCombo)
        }
        Family::Qadd | Family::Qsub => {
            let kind = match (fam, e.is_unsigned()) {
                (Family::Qadd, false) => RvvKind::Vsadd,
                (Family::Qadd, true) => RvvKind::Vsaddu,
                (Family::Qsub, false) => RvvKind::Vssub,
                (Family::Qsub, true) => RvvKind::Vssubu,
                _ => unreachable!(),
            };
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            ctx.op(kind, sew, vl, Dst::V(d), vec![a, b]);
            Ok(Method::CustomDirect)
        }
        Family::Abd => {
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            if e.is_float() {
                ctx.op(RvvKind::Vfsub, sew, vl, Dst::V(d), vec![a, b]);
                ctx.op(RvvKind::Vfsgnjx, sew, vl, Dst::V(d), vec![Src::V(d), Src::V(d)]);
            } else {
                // max(a,b) - min(a,b)
                let (mx, mn) = (ctx.scratch(), ctx.scratch());
                let (kmax, kmin) = if e.is_unsigned() {
                    (RvvKind::Vmaxu, RvvKind::Vminu)
                } else {
                    (RvvKind::Vmax, RvvKind::Vmin)
                };
                ctx.op(kmax, sew, vl, Dst::V(mx), vec![a.clone(), b.clone()]);
                ctx.op(kmin, sew, vl, Dst::V(mn), vec![a, b]);
                ctx.op(RvvKind::Vsub, sew, vl, Dst::V(d), vec![Src::V(mx), Src::V(mn)]);
            }
            Ok(Method::CustomCombo)
        }
        Family::MulLane | Family::MlaLane | Family::FmaLane => {
            // broadcast the lane with vrgather.vi, then mul / macc
            let (lane_vec_idx, lane_imm_idx, acc_idx) = match fam {
                Family::MulLane => (1, 2, None),
                _ => (2, 3, Some(0)),
            };
            let lv = vr(ctx, &call.args[lane_vec_idx])?;
            let lane = match call.args[lane_imm_idx] {
                Arg::Imm(i) => i,
                _ => bail!("lane must be imm"),
            };
            let t = ctx.scratch();
            ctx.op(RvvKind::Vrgather, sew, vl, Dst::V(t), vec![Src::V(lv), Src::ImmI(lane)]);
            match acc_idx {
                None => {
                    let a = ctx.vsrc(&call.args[0]);
                    let kind = pick3(e, RvvKind::Vmul, RvvKind::Vmul, RvvKind::Vfmul);
                    ctx.op(kind, sew, vl, Dst::V(d), vec![a, Src::V(t)]);
                }
                Some(ai) => {
                    let acc = vr(ctx, &call.args[ai])?;
                    let a = ctx.vsrc(&call.args[1]);
                    ctx.ensure_acc_in_dst(sew, vl, d, acc);
                    let kind = if e.is_float() { RvvKind::Vfmacc } else { RvvKind::Vmacc };
                    ctx.op(kind, sew, vl, Dst::V(d), vec![a, Src::V(t)]);
                }
            }
            Ok(Method::CustomCombo)
        }
        Family::Mull => {
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            let kind = if e.is_unsigned() { RvvKind::Vwmulu } else { RvvKind::Vwmul };
            // vl = number of source (d) lanes
            let dl = (64 / e.bits()) as u32;
            ctx.op(kind, sew, dl, Dst::V(d), vec![a, b]);
            Ok(Method::CustomDirect)
        }
        Family::Mlal => {
            let acc = vr(ctx, &call.args[0])?;
            let a = ctx.vsrc(&call.args[1]);
            let b = ctx.vsrc(&call.args[2]);
            let dl = (64 / e.bits()) as u32;
            let wide = Sew::of_bits(sew.bits() * 2);
            ctx.mov_v(wide, dl, d, acc);
            let kind = if e.is_unsigned() { RvvKind::Vwmaccu } else { RvvKind::Vwmacc };
            ctx.op(kind, sew, dl, Dst::V(d), vec![a, b]);
            Ok(Method::CustomDirect)
        }
        Family::Pmin | Family::Pmax | Family::Padd => {
            // concat a,b then even/odd split via vnsrl (sew <= 32)
            let a = vr(ctx, &call.args[0])?;
            let b = vr(ctx, &call.args[1])?;
            let cat = ctx.scratch();
            // both inputs are d vectors: place a at 0..dl, b at dl..2dl
            let dl = vl; // d-form lanes
            ctx.mov_v(sew, dl, cat, a);
            ctx.op(RvvKind::Vslideup, sew, 2 * dl, Dst::V(cat), vec![Src::V(b), Src::ImmI(dl as i64)]);
            if sew.bits() > 32 {
                bail!("pairwise on 64-bit lanes unsupported (NEON has no d-form s64 pairwise)");
            }
            let wide = Sew::of_bits(sew.bits() * 2);
            let (even, odd) = (ctx.scratch(), ctx.scratch());
            // view pairs as wide elements: evens = low halves, odds = high
            ctx.op(RvvKind::Vnsrl, sew, dl, Dst::V(even), vec![Src::V(cat), Src::ImmI(0)]);
            ctx.op(RvvKind::Vnsrl, sew, dl, Dst::V(odd), vec![Src::V(cat), Src::ImmI(sew.bits() as i64)]);
            let _ = wide;
            let kind = match fam {
                Family::Padd => pick3(e, RvvKind::Vadd, RvvKind::Vadd, RvvKind::Vfadd),
                Family::Pmin => pick3(e, RvvKind::Vmin, RvvKind::Vminu, RvvKind::Vfmin),
                Family::Pmax => pick3(e, RvvKind::Vmax, RvvKind::Vmaxu, RvvKind::Vfmax),
                _ => unreachable!(),
            };
            ctx.op(kind, sew, dl, Dst::V(d), vec![Src::V(even), Src::V(odd)]);
            Ok(Method::CustomCombo)
        }
        f => bail!("arith::custom got family {f:?}"),
    }
}

pub fn baseline(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let e = op.elem;
    let (sew, vl) = op_sew_vl(op);
    let fam = op.family;
    match fam {
        // clang vector attributes lower these to the same single op
        Family::Add | Family::Sub | Family::Mul | Family::Div => {
            custom(call, dst, ctx)?;
            Ok(Method::VectorAttr)
        }
        // int min/max vector attr (select) folds to vmin/vmax; float NaN
        // semantics force compare+merge
        Family::Min | Family::Max => {
            if e.is_float() {
                let d = dst.unwrap();
                let a = ctx.vsrc(&call.args[0]);
                let b = ctx.vsrc(&call.args[1]);
                let mk = ctx.mask();
                let cmp = if fam == Family::Min { RvvKind::Vmflt } else { RvvKind::Vmfgt };
                ctx.op(cmp, sew, vl, Dst::M(mk), vec![a.clone(), b.clone()]);
                ctx.op(RvvKind::Vmerge, sew, vl, Dst::V(d), vec![b, a, Src::M(mk)]);
                Ok(Method::VectorAttr)
            } else {
                custom(call, dst, ctx)?;
                Ok(Method::VectorAttr)
            }
        }
        // a + b*c as two ops (no fusion in the generic body)
        Family::Mla | Family::Mls | Family::Fma | Family::Fms => {
            let d = dst.unwrap();
            let acc = ctx.vsrc(&call.args[0]);
            let a = ctx.vsrc(&call.args[1]);
            let b = ctx.vsrc(&call.args[2]);
            let t = ctx.scratch();
            let (mul, addsub) = if e.is_float() {
                (
                    RvvKind::Vfmul,
                    if matches!(fam, Family::Mla | Family::Fma) { RvvKind::Vfadd } else { RvvKind::Vfsub },
                )
            } else {
                (
                    RvvKind::Vmul,
                    if fam == Family::Mla { RvvKind::Vadd } else { RvvKind::Vsub },
                )
            };
            ctx.op(mul, sew, vl, Dst::V(t), vec![a, b]);
            ctx.op(addsub, sew, vl, Dst::V(d), vec![acc, Src::V(t)]);
            Ok(Method::VectorAttr)
        }
        // generic abs/neg via sign tricks: 3 ops int, 2 float
        Family::Abs => {
            let d = dst.unwrap();
            let a = ctx.vsrc(&call.args[0]);
            if e.is_float() {
                // clang: load sign-mask constant + vand
                let t = ctx.scratch();
                let mask = !(1i64 << (sew.bits() - 1));
                ctx.op(RvvKind::VmvVX, sew, vl, Dst::V(t), vec![Src::ImmI(mask)]);
                ctx.op(RvvKind::Vand, sew, vl, Dst::V(d), vec![a, Src::V(t)]);
            } else {
                // m = a >> (bits-1); (a ^ m) - m
                let m = ctx.scratch();
                let x = ctx.scratch();
                ctx.op(RvvKind::Vsra, sew, vl, Dst::V(m), vec![a.clone(), Src::ImmI(sew.bits() as i64 - 1)]);
                ctx.op(RvvKind::Vxor, sew, vl, Dst::V(x), vec![a, Src::V(m)]);
                ctx.op(RvvKind::Vsub, sew, vl, Dst::V(d), vec![Src::V(x), Src::V(m)]);
            }
            Ok(Method::VectorAttr)
        }
        Family::Neg => {
            custom(call, dst, ctx)?;
            Ok(Method::VectorAttr)
        }
        // generic bit tricks: floor-avg (a&b)+((a^b)>>1), ceil-avg
        // (a|b)-((a^b)>>1) — 4 ops either way
        Family::Hadd | Family::Rhadd => {
            let d = dst.unwrap();
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            let (t1, t2) = (ctx.scratch(), ctx.scratch());
            let first = if fam == Family::Hadd { RvvKind::Vand } else { RvvKind::Vor };
            ctx.op(first, sew, vl, Dst::V(t1), vec![a.clone(), b.clone()]);
            ctx.op(RvvKind::Vxor, sew, vl, Dst::V(t2), vec![a, b]);
            let shr = if e.is_unsigned() { RvvKind::Vsrl } else { RvvKind::Vsra };
            ctx.op(shr, sew, vl, Dst::V(t2), vec![Src::V(t2), Src::ImmI(1)]);
            let last = if fam == Family::Hadd { RvvKind::Vadd } else { RvvKind::Vsub };
            ctx.op(last, sew, vl, Dst::V(d), vec![Src::V(t1), Src::V(t2)]);
            Ok(Method::VectorAttr)
        }
        // branchy scalar loop: does not auto-vectorize
        Family::Qadd | Family::Qsub => {
            super::scalar_fallback(call, dst, costs::SATURATING_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        Family::Abd => {
            let d = dst.unwrap();
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            if e.is_float() {
                // fabsf(a-b) vectorizes: sub + sign-mask and
                let t = ctx.scratch();
                ctx.op(RvvKind::Vfsub, sew, vl, Dst::V(d), vec![a, b]);
                let mask = !(1i64 << (sew.bits() - 1));
                ctx.op(RvvKind::VmvVX, sew, vl, Dst::V(t), vec![Src::ImmI(mask)]);
                ctx.op(RvvKind::Vand, sew, vl, Dst::V(d), vec![Src::V(d), Src::V(t)]);
                Ok(Method::ScalarAutovec)
            } else {
                custom(call, dst, ctx)?;
                Ok(Method::VectorAttr)
            }
        }
        // lane forms: splat-shuffle (1 op) + unfused mul/add chain
        Family::MulLane | Family::MlaLane | Family::FmaLane => {
            let d = dst.unwrap();
            let (lane_vec_idx, lane_imm_idx, acc_idx) = match fam {
                Family::MulLane => (1, 2, None),
                _ => (2, 3, Some(0usize)),
            };
            let lv = vr(ctx, &call.args[lane_vec_idx])?;
            let lane = match call.args[lane_imm_idx] {
                Arg::Imm(i) => i,
                _ => bail!("lane must be imm"),
            };
            let t = ctx.scratch();
            ctx.op(RvvKind::Vrgather, sew, vl, Dst::V(t), vec![Src::V(lv), Src::ImmI(lane)]);
            let mulk = pick3(e, RvvKind::Vmul, RvvKind::Vmul, RvvKind::Vfmul);
            match acc_idx {
                None => {
                    let a = ctx.vsrc(&call.args[0]);
                    ctx.op(mulk, sew, vl, Dst::V(d), vec![a, Src::V(t)]);
                }
                Some(ai) => {
                    let acc = ctx.vsrc(&call.args[ai]);
                    let a = ctx.vsrc(&call.args[1]);
                    let p = ctx.scratch();
                    ctx.op(mulk, sew, vl, Dst::V(p), vec![a, Src::V(t)]);
                    let addk = pick3(e, RvvKind::Vadd, RvvKind::Vadd, RvvKind::Vfadd);
                    ctx.op(addk, sew, vl, Dst::V(d), vec![acc, Src::V(p)]);
                }
            }
            Ok(Method::VectorAttr)
        }
        // widening: convertvector both sides + wide op
        Family::Mull | Family::Mlal => {
            let d = dst.unwrap();
            let wide = Sew::of_bits(sew.bits() * 2);
            let dl = (64 / e.bits()) as u32;
            let ext = if e.is_unsigned() { RvvKind::Vzext2 } else { RvvKind::Vsext2 };
            let (off, has_acc) = if fam == Family::Mlal { (1usize, true) } else { (0, false) };
            let (wa, wb) = (ctx.scratch(), ctx.scratch());
            let a = vr(ctx, &call.args[off])?;
            let b = vr(ctx, &call.args[off + 1])?;
            ctx.op(ext, wide, dl, Dst::V(wa), vec![Src::V(a)]);
            ctx.op(ext, wide, dl, Dst::V(wb), vec![Src::V(b)]);
            if has_acc {
                let acc = ctx.vsrc(&call.args[0]);
                let p = ctx.scratch();
                ctx.op(RvvKind::Vmul, wide, dl, Dst::V(p), vec![Src::V(wa), Src::V(wb)]);
                ctx.op(RvvKind::Vadd, wide, dl, Dst::V(d), vec![acc, Src::V(p)]);
            } else {
                ctx.op(RvvKind::Vmul, wide, dl, Dst::V(d), vec![Src::V(wa), Src::V(wb)]);
            }
            Ok(Method::VectorAttr)
        }
        // lane-crossing scalar loop
        Family::Pmin | Family::Pmax | Family::Padd => {
            super::scalar_fallback(call, dst, costs::PAIRWISE_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        f => bail!("arith::baseline got family {f:?}"),
    }
}
