//! Float-estimate and rounding rules: `vrecpe`/`vrsqrte` map to RVV's
//! `vfrec7.v`/`vfrsqrt7.v` estimates, Newton steps are 2–3 arithmetic ops,
//! `vsqrtq` is a single `vfsqrt.v`. The SIMDe generics for all the
//! estimate/sqrt/rounding ops are per-lane libm loops — the biggest
//! baseline loss (the paper's vsqrt benchmark).

use anyhow::{bail, Result};

use crate::ir::NeonCall;
use crate::neon::ops::Family;
use crate::rvv::ops::{Dst, RvvKind, Src};
use crate::simde::costs;
use crate::simde::ctx::{op_sew_vl, Ctx};
use crate::simde::method::Method;

pub fn custom(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let (sew, vl) = op_sew_vl(op);
    let d = dst.unwrap();
    match op.family {
        Family::Recpe => {
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::Vfrec7, sew, vl, Dst::V(d), vec![a]);
            Ok(Method::CustomDirect)
        }
        Family::Rsqrte => {
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::Vfrsqrt7, sew, vl, Dst::V(d), vec![a]);
            Ok(Method::CustomDirect)
        }
        Family::Recps => {
            // 2 - a*b
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            let t = ctx.scratch();
            ctx.op(RvvKind::Vfmul, sew, vl, Dst::V(t), vec![a, b]);
            ctx.op(RvvKind::Vfrsub, sew, vl, Dst::V(d), vec![Src::V(t), Src::ImmF(2.0)]);
            Ok(Method::CustomCombo)
        }
        Family::Rsqrts => {
            // (3 - a*b) / 2
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            let t = ctx.scratch();
            ctx.op(RvvKind::Vfmul, sew, vl, Dst::V(t), vec![a, b]);
            ctx.op(RvvKind::Vfrsub, sew, vl, Dst::V(t), vec![Src::V(t), Src::ImmF(3.0)]);
            ctx.op(RvvKind::Vfmul, sew, vl, Dst::V(d), vec![Src::V(t), Src::ImmF(0.5)]);
            Ok(Method::CustomCombo)
        }
        Family::Sqrt => {
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::Vfsqrt, sew, vl, Dst::V(d), vec![a]);
            Ok(Method::CustomDirect)
        }
        Family::Rndn => {
            // round-to-nearest-even via int round-trip (bounded domain,
            // exactly XNNPACK's vcvtnq+vcvtq pattern)
            let a = ctx.vsrc(&call.args[0]);
            let t = ctx.scratch();
            ctx.op(RvvKind::VfcvtXF, sew, vl, Dst::V(t), vec![a]);
            ctx.op(RvvKind::VfcvtFX, sew, vl, Dst::V(d), vec![Src::V(t)]);
            Ok(Method::CustomCombo)
        }
        f => bail!("floatest::custom got family {f:?}"),
    }
}

pub fn baseline(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    match op.family {
        // pure-arithmetic Newton steps vectorize fine
        Family::Recps | Family::Rsqrts => {
            custom(call, dst, ctx)?;
            Ok(Method::VectorAttr)
        }
        // per-lane libm loops: errno blocks vectorization
        Family::Sqrt => {
            super::scalar_fallback(call, dst, costs::SQRTF_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        Family::Rsqrte => {
            super::scalar_fallback(call, dst, costs::RSQRT_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        Family::Recpe => {
            super::scalar_fallback(call, dst, costs::RECIP_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        Family::Rndn => {
            super::scalar_fallback(call, dst, costs::ROUNDEVEN_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        f => bail!("floatest::baseline got family {f:?}"),
    }
}
