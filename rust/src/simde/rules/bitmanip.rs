//! Bit-manipulation rules. `vrbit` is the paper's Listing 7: the binary-
//! magic-numbers bit reverse vectorised with RVV bitwise ops. Base RVV 1.0
//! has no clz/popcount vector instructions (those arrive with Zvbb, after
//! the paper), so `vclz`/`vcnt` are SWAR sequences too.

use anyhow::{bail, Result};

use crate::ir::NeonCall;
use crate::neon::ops::Family;
use crate::rvv::ops::{Dst, RvvKind, Src};
use crate::rvv::vtype::Sew;
use crate::simde::costs;
use crate::simde::ctx::{op_sew_vl, Ctx};
use crate::simde::method::Method;

/// One magic-numbers swap stage: `v = ((v >> s) & m) | ((v & m) << s)`.
fn swap_stage(ctx: &mut Ctx, sew: Sew, vl: u32, v: u32, s: i64, m: i64) {
    let (t1, t2) = (ctx.scratch(), ctx.scratch());
    ctx.op(RvvKind::Vsrl, sew, vl, Dst::V(t1), vec![Src::V(v), Src::ImmI(s)]);
    ctx.op(RvvKind::Vand, sew, vl, Dst::V(t1), vec![Src::V(t1), Src::ImmI(m)]);
    ctx.op(RvvKind::Vand, sew, vl, Dst::V(t2), vec![Src::V(v), Src::ImmI(m)]);
    ctx.op(RvvKind::Vsll, sew, vl, Dst::V(t2), vec![Src::V(t2), Src::ImmI(s)]);
    ctx.op(RvvKind::Vor, sew, vl, Dst::V(v), vec![Src::V(t1), Src::V(t2)]);
}

/// SWAR popcount at `sew`, in place. Returns op count emitted.
fn emit_popcount(ctx: &mut Ctx, sew: Sew, vl: u32, v: u32) {
    let bits = sew.bits();
    let rep = |nib: u64| -> i64 {
        // repeat a byte pattern across the lane width
        let mut m = 0u64;
        for _ in 0..(bits / 8).max(1) {
            m = (m << 8) | nib;
        }
        m as i64
    };
    let m55 = rep(0x55);
    let m33 = rep(0x33);
    let m0f = rep(0x0f);
    let t = ctx.scratch();
    // v -= (v >> 1) & 0x55..
    ctx.op(RvvKind::Vsrl, sew, vl, Dst::V(t), vec![Src::V(v), Src::ImmI(1)]);
    ctx.op(RvvKind::Vand, sew, vl, Dst::V(t), vec![Src::V(t), Src::ImmI(m55)]);
    ctx.op(RvvKind::Vsub, sew, vl, Dst::V(v), vec![Src::V(v), Src::V(t)]);
    // v = (v & 0x33..) + ((v >> 2) & 0x33..)
    ctx.op(RvvKind::Vsrl, sew, vl, Dst::V(t), vec![Src::V(v), Src::ImmI(2)]);
    ctx.op(RvvKind::Vand, sew, vl, Dst::V(t), vec![Src::V(t), Src::ImmI(m33)]);
    ctx.op(RvvKind::Vand, sew, vl, Dst::V(v), vec![Src::V(v), Src::ImmI(m33)]);
    ctx.op(RvvKind::Vadd, sew, vl, Dst::V(v), vec![Src::V(v), Src::V(t)]);
    // v = (v + (v >> 4)) & 0x0f..
    ctx.op(RvvKind::Vsrl, sew, vl, Dst::V(t), vec![Src::V(v), Src::ImmI(4)]);
    ctx.op(RvvKind::Vadd, sew, vl, Dst::V(v), vec![Src::V(v), Src::V(t)]);
    ctx.op(RvvKind::Vand, sew, vl, Dst::V(v), vec![Src::V(v), Src::ImmI(m0f)]);
    if bits > 8 {
        // fold byte counts: (v * 0x0101..) >> (bits - 8)
        let ones = rep(0x01);
        ctx.op(RvvKind::Vmul, sew, vl, Dst::V(v), vec![Src::V(v), Src::ImmI(ones)]);
        ctx.op(RvvKind::Vsrl, sew, vl, Dst::V(v), vec![Src::V(v), Src::ImmI(bits as i64 - 8)]);
    }
}

pub fn custom(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let (sew, vl) = op_sew_vl(op);
    let d = dst.unwrap();
    match op.family {
        Family::Rbit => {
            // Listing 7 vectorised: three swap stages reverse each byte
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::VmvVV, sew, vl, Dst::V(d), vec![a]);
            swap_stage(ctx, sew, vl, d, 1, 0x55);
            swap_stage(ctx, sew, vl, d, 2, 0x33);
            swap_stage(ctx, sew, vl, d, 4, 0x0f);
            Ok(Method::CustomAlgorithmic)
        }
        Family::Cnt => {
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::VmvVV, sew, vl, Dst::V(d), vec![a]);
            emit_popcount(ctx, sew, vl, d);
            Ok(Method::CustomAlgorithmic)
        }
        Family::Clz => {
            // smear then popcount the inverse: clz = popcount(~smear(v))
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::VmvVV, sew, vl, Dst::V(d), vec![a]);
            let t = ctx.scratch();
            let mut k = 1i64;
            while k < sew.bits() as i64 {
                ctx.op(RvvKind::Vsrl, sew, vl, Dst::V(t), vec![Src::V(d), Src::ImmI(k)]);
                ctx.op(RvvKind::Vor, sew, vl, Dst::V(d), vec![Src::V(d), Src::V(t)]);
                k <<= 1;
            }
            ctx.op(RvvKind::Vxor, sew, vl, Dst::V(d), vec![Src::V(d), Src::ImmI(-1)]);
            emit_popcount(ctx, sew, vl, d);
            Ok(Method::CustomAlgorithmic)
        }
        f => bail!("bitmanip::custom got family {f:?}"),
    }
}

pub fn baseline(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let per_lane = match op.family {
        Family::Rbit => costs::RBIT_PER_LANE,
        Family::Clz => costs::CLZ_PER_LANE,
        Family::Cnt => costs::CNT_PER_LANE,
        f => bail!("bitmanip::baseline got family {f:?}"),
    };
    super::scalar_fallback(call, dst, per_lane, costs::SCALAR_MEM_PER_LANE, ctx);
    Ok(Method::ScalarLoop)
}
