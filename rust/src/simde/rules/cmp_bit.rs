//! Comparison and bitwise conversions. Comparisons use the paper's
//! Listing 6 pattern: `vmv` (zeros) + `vmseq`-family + `vmerge` with -1.

use anyhow::{bail, Result};

use crate::ir::NeonCall;
use crate::neon::ops::Family;
use crate::rvv::ops::{Dst, RvvKind, Src};
use crate::simde::ctx::{op_sew_vl, Ctx};
use crate::simde::method::Method;

pub fn custom(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let e = op.elem;
    let (sew, vl) = op_sew_vl(op);
    let d = dst.unwrap();
    let fam = op.family;
    match fam {
        Family::Ceq | Family::Cge | Family::Cgt | Family::Cle | Family::Clt | Family::Ceqz => {
            let a = ctx.vsrc(&call.args[0]);
            let b = if fam == Family::Ceqz {
                Src::ImmI(0)
            } else {
                ctx.vsrc(&call.args[1])
            };
            let kind = if e.is_float() {
                match fam {
                    Family::Ceq => RvvKind::Vmfeq,
                    Family::Cge => RvvKind::Vmfge,
                    Family::Cgt => RvvKind::Vmfgt,
                    Family::Cle => RvvKind::Vmfle,
                    Family::Clt => RvvKind::Vmflt,
                    Family::Ceqz => RvvKind::Vmfeq,
                    _ => unreachable!(),
                }
            } else if e.is_unsigned() {
                match fam {
                    Family::Ceq | Family::Ceqz => RvvKind::Vmseq,
                    Family::Cge => RvvKind::Vmsgtu, // a >= b  via swap: use vmsleu(b,a)
                    Family::Cgt => RvvKind::Vmsgtu,
                    Family::Cle => RvvKind::Vmsleu,
                    Family::Clt => RvvKind::Vmsltu,
                    _ => unreachable!(),
                }
            } else {
                match fam {
                    Family::Ceq | Family::Ceqz => RvvKind::Vmseq,
                    Family::Cge => RvvKind::Vmsgt,
                    Family::Cgt => RvvKind::Vmsgt,
                    Family::Cle => RvvKind::Vmsle,
                    Family::Clt => RvvKind::Vmslt,
                    _ => unreachable!(),
                }
            };
            // Cge on ints: a >= b  <=>  !(a < b); implement as vmsle(b, a)
            // by operand swap to stay 1 instruction
            let (x, y, kind) = if !e.is_float() && fam == Family::Cge {
                (
                    b,
                    a,
                    if e.is_unsigned() { RvvKind::Vmsleu } else { RvvKind::Vmsle },
                )
            } else {
                (a, b, kind)
            };
            // float Ceqz compares against 0.0
            let y = if fam == Family::Ceqz && e.is_float() { Src::ImmF(0.0) } else { y };
            let mk = ctx.mask();
            let zeros = ctx.scratch();
            // Listing 6: vmv (zeros) + compare -> mask + vmerge(-1)
            ctx.op(RvvKind::VmvVX, sew, vl, Dst::V(zeros), vec![Src::ImmI(0)]);
            ctx.op(kind, sew, vl, Dst::M(mk), vec![x, y]);
            ctx.op(RvvKind::Vmerge, sew, vl, Dst::V(d), vec![Src::V(zeros), Src::ImmI(-1), Src::M(mk)]);
            Ok(Method::CustomCombo)
        }
        Family::Tst => {
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            let t = ctx.scratch();
            let mk = ctx.mask();
            let zeros = ctx.scratch();
            ctx.op(RvvKind::Vand, sew, vl, Dst::V(t), vec![a, b]);
            ctx.op(RvvKind::VmvVX, sew, vl, Dst::V(zeros), vec![Src::ImmI(0)]);
            ctx.op(RvvKind::Vmsne, sew, vl, Dst::M(mk), vec![Src::V(t), Src::ImmI(0)]);
            ctx.op(RvvKind::Vmerge, sew, vl, Dst::V(d), vec![Src::V(zeros), Src::ImmI(-1), Src::M(mk)]);
            Ok(Method::CustomCombo)
        }
        Family::And | Family::Orr | Family::Eor => {
            let kind = match fam {
                Family::And => RvvKind::Vand,
                Family::Orr => RvvKind::Vor,
                _ => RvvKind::Vxor,
            };
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            ctx.op(kind, sew, vl, Dst::V(d), vec![a, b]);
            Ok(Method::CustomDirect)
        }
        Family::Bic | Family::Orn => {
            // a & ~b / a | ~b (no vandn without Zvkb)
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            let t = ctx.scratch();
            ctx.op(RvvKind::Vxor, sew, vl, Dst::V(t), vec![b, Src::ImmI(-1)]);
            let kind = if fam == Family::Bic { RvvKind::Vand } else { RvvKind::Vor };
            ctx.op(kind, sew, vl, Dst::V(d), vec![a, Src::V(t)]);
            Ok(Method::CustomCombo)
        }
        Family::Mvn => {
            let a = ctx.vsrc(&call.args[0]);
            ctx.op(RvvKind::Vxor, sew, vl, Dst::V(d), vec![a, Src::ImmI(-1)]);
            Ok(Method::CustomDirect)
        }
        Family::Bsl => {
            // ((a ^ b) & m) ^ b — 3 ops (vs the naive 4-op and/or chain)
            let m = ctx.vsrc(&call.args[0]);
            let a = ctx.vsrc(&call.args[1]);
            let b = ctx.vsrc(&call.args[2]);
            let t = ctx.scratch();
            ctx.op(RvvKind::Vxor, sew, vl, Dst::V(t), vec![a, b.clone()]);
            ctx.op(RvvKind::Vand, sew, vl, Dst::V(t), vec![Src::V(t), m]);
            ctx.op(RvvKind::Vxor, sew, vl, Dst::V(d), vec![Src::V(t), b]);
            Ok(Method::CustomCombo)
        }
        f => bail!("cmp_bit::custom got family {f:?}"),
    }
}

pub fn baseline(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let (sew, vl) = op_sew_vl(op);
    let fam = op.family;
    match fam {
        // vector-attribute comparisons lower to the same 3-op pattern
        Family::Ceq | Family::Cge | Family::Cgt | Family::Cle | Family::Clt
        | Family::Ceqz | Family::Tst => {
            custom(call, dst, ctx)?;
            Ok(Method::VectorAttr)
        }
        Family::And | Family::Orr | Family::Eor | Family::Mvn => {
            custom(call, dst, ctx)?;
            Ok(Method::VectorAttr)
        }
        Family::Bic | Family::Orn => {
            custom(call, dst, ctx)?;
            Ok(Method::VectorAttr)
        }
        // SIMDe generic bsl: (m & a) | (~m & b) — 4 ops
        Family::Bsl => {
            let d = dst.unwrap();
            let m = ctx.vsrc(&call.args[0]);
            let a = ctx.vsrc(&call.args[1]);
            let b = ctx.vsrc(&call.args[2]);
            let (t1, t2) = (ctx.scratch(), ctx.scratch());
            ctx.op(RvvKind::Vand, sew, vl, Dst::V(t1), vec![m.clone(), a]);
            ctx.op(RvvKind::Vxor, sew, vl, Dst::V(t2), vec![m, Src::ImmI(-1)]);
            ctx.op(RvvKind::Vand, sew, vl, Dst::V(t2), vec![Src::V(t2), b]);
            ctx.op(RvvKind::Vor, sew, vl, Dst::V(d), vec![Src::V(t1), Src::V(t2)]);
            Ok(Method::VectorAttr)
        }
        f => bail!("cmp_bit::baseline got family {f:?}"),
    }
}
