//! Shift conversions: immediate shifts map 1:1; shift-insert (`vsli`/
//! `vsri`) combine shift+mask ops; variable signed shifts (`vshl.s*`) need
//! a positive/negative split.

use anyhow::{bail, Result};

use crate::ir::NeonCall;
use crate::neon::ops::Family;
use crate::rvv::ops::{Dst, RvvKind, Src};
use crate::simde::costs;
use crate::simde::ctx::{op_sew_vl, ret_sew_vl, Ctx};
use crate::simde::method::Method;

pub fn custom(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    let e = op.elem;
    let (sew, vl) = op_sew_vl(op);
    let d = dst.unwrap();
    match op.family {
        Family::ShlN => {
            let a = ctx.vsrc(&call.args[0]);
            let n = ctx.vsrc(&call.args[1]);
            ctx.op(RvvKind::Vsll, sew, vl, Dst::V(d), vec![a, n]);
            Ok(Method::CustomDirect)
        }
        Family::ShrN => {
            let a = ctx.vsrc(&call.args[0]);
            let n = ctx.vsrc(&call.args[1]);
            let kind = if e.is_unsigned() { RvvKind::Vsrl } else { RvvKind::Vsra };
            ctx.op(kind, sew, vl, Dst::V(d), vec![a, n]);
            Ok(Method::CustomDirect)
        }
        Family::SliN => {
            // dst = (b << n) | (a & low_n_mask)
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            let n = match call.args[2] {
                crate::ir::Arg::Imm(i) => i,
                _ => bail!("vsli shift must be imm"),
            };
            let keep = if n == 0 { 0 } else { (1i64 << n) - 1 };
            let t = ctx.scratch();
            ctx.op(RvvKind::Vsll, sew, vl, Dst::V(t), vec![b, Src::ImmI(n)]);
            ctx.op(RvvKind::Vand, sew, vl, Dst::V(d), vec![a, Src::ImmI(keep)]);
            ctx.op(RvvKind::Vor, sew, vl, Dst::V(d), vec![Src::V(d), Src::V(t)]);
            Ok(Method::CustomCombo)
        }
        Family::SriN => {
            // dst = (b >>u n) | (a & high_n_mask)
            let a = ctx.vsrc(&call.args[0]);
            let b = ctx.vsrc(&call.args[1]);
            let n = match call.args[2] {
                crate::ir::Arg::Imm(i) => i,
                _ => bail!("vsri shift must be imm"),
            };
            let bits = sew.bits() as i64;
            let mask = e.lane_mask() as i64;
            let keep_hi = if n == 0 { 0 } else { mask & !(((mask as u64) >> n) as i64) };
            let _ = bits;
            let t = ctx.scratch();
            ctx.op(RvvKind::Vsrl, sew, vl, Dst::V(t), vec![b, Src::ImmI(n)]);
            ctx.op(RvvKind::Vand, sew, vl, Dst::V(d), vec![a, Src::ImmI(keep_hi)]);
            ctx.op(RvvKind::Vor, sew, vl, Dst::V(d), vec![Src::V(d), Src::V(t)]);
            Ok(Method::CustomCombo)
        }
        Family::Sshl => {
            // per-lane signed shift: split positive (left) / negative (right)
            let a = ctx.vsrc(&call.args[0]);
            let s = ctx.vsrc(&call.args[1]);
            let (sl, sneg, sr) = (ctx.scratch(), ctx.scratch(), ctx.scratch());
            let mk = ctx.mask();
            ctx.op(RvvKind::Vsll, sew, vl, Dst::V(sl), vec![a.clone(), s.clone()]);
            ctx.op(RvvKind::Vrsub, sew, vl, Dst::V(sneg), vec![s.clone(), Src::ImmI(0)]);
            let shr = if e.is_unsigned() { RvvKind::Vsrl } else { RvvKind::Vsra };
            ctx.op(shr, sew, vl, Dst::V(sr), vec![a, Src::V(sneg)]);
            ctx.op(RvvKind::Vmslt, sew, vl, Dst::M(mk), vec![s, Src::ImmI(0)]);
            ctx.op(RvvKind::Vmerge, sew, vl, Dst::V(d), vec![Src::V(sl), Src::V(sr), Src::M(mk)]);
            Ok(Method::CustomCombo)
        }
        Family::ShrnN => {
            let a = ctx.vsrc(&call.args[0]);
            let n = ctx.vsrc(&call.args[1]);
            let (nsew, nvl) = ret_sew_vl(op);
            let kind = if e.is_unsigned() { RvvKind::Vnsrl } else { RvvKind::Vnsra };
            ctx.op(kind, nsew, nvl, Dst::V(d), vec![a, n]);
            Ok(Method::CustomDirect)
        }
        f => bail!("shift::custom got family {f:?}"),
    }
}

pub fn baseline(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    let op = call.op;
    match op.family {
        // vector-attribute shifts lower identically
        Family::ShlN | Family::ShrN => {
            custom(call, dst, ctx)?;
            Ok(Method::VectorAttr)
        }
        // generic (b<<n)|(a&mask) is also vector-attribute expressible,
        // clang emits the same 3-op chain plus a spare mask materialise
        Family::SliN | Family::SriN => {
            custom(call, dst, ctx)?;
            // extra constant materialisation clang does not fold
            let (sew, vl) = op_sew_vl(op);
            let t = ctx.scratch();
            ctx.op(RvvKind::VmvVX, sew, vl, Dst::V(t), vec![Src::ImmI(0)]);
            Ok(Method::VectorAttr)
        }
        // branchy per-lane body (negative => right shift) doesn't vectorize
        Family::Sshl => {
            super::scalar_fallback(call, dst, costs::SSHL_PER_LANE, costs::SCALAR_MEM_PER_LANE, ctx);
            Ok(Method::ScalarLoop)
        }
        // convertvector truncate + shift
        Family::ShrnN => {
            custom(call, dst, ctx)?;
            let (sew, vl) = ret_sew_vl(op);
            let t = ctx.scratch();
            ctx.op(RvvKind::VmvVV, sew, vl, Dst::V(t), vec![Src::V(dst.unwrap())]);
            Ok(Method::VectorAttr)
        }
        f => bail!("shift::baseline got family {f:?}"),
    }
}
