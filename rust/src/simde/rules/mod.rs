//! Conversion-rule registry: per-category custom (RVV-enhanced) and
//! baseline (original SIMDe) lowerings of every implemented NEON intrinsic.

mod arith;
mod bitmanip;
mod cmp_bit;
mod convert;
mod floatest;
mod memory;
mod permute;
mod shift;

use anyhow::Result;

use crate::ir::NeonCall;
use crate::neon::ops::Category;
use crate::rvv::program::{RStmt, ScalarBlock};
use crate::simde::costs;
use crate::simde::ctx::Ctx;
use crate::simde::method::{Method, Mode};

/// Lower one intrinsic call under the given mode. Returns the conversion
/// method used (for reporting and the A2 ablation).
pub fn lower(
    mode: Mode,
    call: &NeonCall,
    dst: Option<u32>,
    ctx: &mut Ctx,
    union_store_bug: bool,
) -> Result<Method> {
    let method = lower_inner(mode, call, dst, ctx, union_store_bug)?;
    if mode == Mode::Baseline && matches!(method, Method::VectorAttr | Method::ScalarAutovec) {
        // SIMDe generic functions round-trip operands through the private
        // union (`to_private`/`from_private`); at -O3 clang removes most of
        // it but per-call residual stack traffic remains — the source of
        // the paper's ~1.5x floor on purely arithmetic kernels.
        ctx.out.push(RStmt::Scalar(ScalarBlock {
            call: NeonCall { op: call.op, args: vec![] },
            dst: None,
            scalar_cost: 1,
            mem_ops: 1,
            cost_only: true,
        }));
    }
    Ok(method)
}

fn lower_inner(
    mode: Mode,
    call: &NeonCall,
    dst: Option<u32>,
    ctx: &mut Ctx,
    union_store_bug: bool,
) -> Result<Method> {
    ctx.reset_scratch();
    let cat = call.op.category();
    match (mode, cat) {
        (Mode::RvvCustom, Category::Memory) => memory::custom(call, dst, ctx),
        (Mode::Baseline, Category::Memory) => memory::baseline(call, dst, ctx, union_store_bug),
        (Mode::RvvCustom, Category::Arith | Category::Pairwise | Category::Saturating) => {
            if matches!(
                call.op.family,
                crate::neon::ops::Family::Qmovn | crate::neon::ops::Family::Qmovun
            ) {
                convert::custom(call, dst, ctx)
            } else {
                arith::custom(call, dst, ctx)
            }
        }
        (Mode::Baseline, Category::Arith | Category::Pairwise | Category::Saturating) => {
            // saturating narrows live in the convert rules
            if matches!(
                call.op.family,
                crate::neon::ops::Family::Qmovn | crate::neon::ops::Family::Qmovun
            ) {
                convert::baseline(call, dst, ctx)
            } else {
                arith::baseline(call, dst, ctx)
            }
        }
        (Mode::RvvCustom, Category::Compare | Category::Bitwise) => cmp_bit::custom(call, dst, ctx),
        (Mode::Baseline, Category::Compare | Category::Bitwise) => cmp_bit::baseline(call, dst, ctx),
        (Mode::RvvCustom, Category::Shift) => shift::custom(call, dst, ctx),
        (Mode::Baseline, Category::Shift) => shift::baseline(call, dst, ctx),
        (Mode::RvvCustom, Category::Permute) => permute::custom(call, dst, ctx),
        (Mode::Baseline, Category::Permute) => permute::baseline(call, dst, ctx),
        (Mode::RvvCustom, Category::Convert | Category::WidenNarrow) => {
            match call.op.family {
                // widening multiplies live in the arith rules, narrowing
                // shifts in the shift rules
                crate::neon::ops::Family::Mull | crate::neon::ops::Family::Mlal => {
                    arith::custom(call, dst, ctx)
                }
                crate::neon::ops::Family::ShrnN => shift::custom(call, dst, ctx),
                _ => convert::custom(call, dst, ctx),
            }
        }
        (Mode::Baseline, Category::Convert | Category::WidenNarrow) => {
            match call.op.family {
                crate::neon::ops::Family::Mull | crate::neon::ops::Family::Mlal => {
                    arith::baseline(call, dst, ctx)
                }
                crate::neon::ops::Family::ShrnN => shift::baseline(call, dst, ctx),
                _ => convert::baseline(call, dst, ctx),
            }
        }
        (Mode::RvvCustom, Category::FloatEst) => floatest::custom(call, dst, ctx),
        (Mode::Baseline, Category::FloatEst) => floatest::baseline(call, dst, ctx),
        (Mode::RvvCustom, Category::BitManip) => bitmanip::custom(call, dst, ctx),
        (Mode::Baseline, Category::BitManip) => bitmanip::baseline(call, dst, ctx),
    }
}

/// Resolve the saturating-narrow overlap for custom mode too.
pub fn lower_custom_qmov(call: &NeonCall, dst: Option<u32>, ctx: &mut Ctx) -> Result<Method> {
    convert::custom(call, dst, ctx)
}

/// Emit a SIMDe generic scalar-loop fallback (baseline only): reference
/// semantics + calibrated cost.
pub(crate) fn scalar_fallback(
    call: &NeonCall,
    dst: Option<u32>,
    per_lane: u64,
    mem_per_lane: u64,
    ctx: &mut Ctx,
) {
    let lanes = call
        .op
        .sig()
        .ret
        .map(|r| r.lanes as u64)
        .unwrap_or_else(|| call.op.vt().lanes as u64);
    ctx.out.push(RStmt::Scalar(ScalarBlock {
        call: call.clone(),
        dst,
        scalar_cost: costs::SCALAR_SPILL_OVERHEAD + lanes * per_lane,
        mem_ops: lanes * mem_per_lane,
        cost_only: false,
    }));
}
