//! Conversion-coverage registry: enumerates every implemented concrete
//! conversion (the analogue of the paper's "conversions for a total of
//! 1520 Intrinsics") by dry-lowering each instantiation and recording the
//! method used.

use std::collections::BTreeMap;

use crate::ir::{Arg, BufDecl, BufKind, NeonCall};
use crate::neon::elem::Elem;
use crate::neon::ops::{enumerate_implemented, ArgTy, NeonOp};
use crate::rvv::machine::RvvConfig;
use crate::simde::ctx::Ctx;
use crate::simde::method::{Method, Mode};
use crate::simde::rules;

/// One registry entry: a concrete intrinsic and how each mode converts it.
#[derive(Debug, Clone)]
pub struct Conversion {
    pub op: NeonOp,
    pub custom_method: Method,
    pub baseline_method: Method,
    /// static RVV ops emitted by the custom lowering
    pub custom_ops: usize,
}

/// Build a synthetic call matching the op's signature (for dry lowering).
fn synth_call(op: NeonOp) -> NeonCall {
    let sig = op.sig();
    let mut next_v = 0u32;
    let args = sig
        .args
        .iter()
        .map(|a| match a {
            ArgTy::V(_) => {
                let r = next_v;
                next_v += 1;
                Arg::V(r)
            }
            ArgTy::Ptr(_) => Arg::Mem { buf: 0, index: crate::ir::AddrExpr::Const(0) },
            ArgTy::Imm => Arg::Imm(1),
            ArgTy::ScalarInt => {
                if op.elem.is_float() {
                    Arg::ImmF(1.0)
                } else {
                    Arg::Imm(1)
                }
            }
        })
        .collect();
    NeonCall { op, args }
}

/// Dry-lower every implemented instantiation under both modes.
pub fn conversion_table(cfg: RvvConfig) -> Vec<Conversion> {
    let bufs = vec![BufDecl { name: "synthetic".into(), elem: Elem::I8, len: 1024, kind: BufKind::Input }];
    let mut out = Vec::new();
    for op in enumerate_implemented() {
        // skip instantiations whose types the config cannot map (§3.2) —
        // both the named (input) type and the return type must map
        let rt = op.sig().ret.unwrap_or_else(|| op.vt());
        if crate::simde::types_map::map_neon_type(rt, cfg.vlen, cfg.zvfh).is_err()
            || crate::simde::types_map::map_neon_type(op.vt(), cfg.vlen, cfg.zvfh).is_err()
        {
            continue;
        }
        let call = synth_call(op);
        let dst = if op.sig().ret.is_some() { Some(100) } else { None };

        let mut cctx = Ctx::new(cfg, &bufs, 128);
        let custom_method = match rules::lower(Mode::RvvCustom, &call, dst, &mut cctx, false) {
            Ok(m) => m,
            Err(_) => continue,
        };
        let custom_ops = cctx.out.len();

        let mut bctx = Ctx::new(cfg, &bufs, 128);
        let baseline_method = match rules::lower(Mode::Baseline, &call, dst, &mut bctx, false) {
            Ok(m) => m,
            Err(_) => continue,
        };

        out.push(Conversion { op, custom_method, baseline_method, custom_ops });
    }
    out
}

/// Every distinct intrinsic category a program touches, in a stable
/// order. The tuner uses this to enumerate `force-baseline:<category>`
/// candidates — one per category the program can actually be degraded
/// on — instead of trying all twelve blindly.
pub fn program_categories(prog: &crate::ir::Program) -> Vec<crate::neon::ops::Category> {
    let mut cats: Vec<crate::neon::ops::Category> =
        prog.used_ops().iter().map(|op| op.category()).collect();
    // Category has no Ord; its Debug render is stable and unique per
    // variant, so sort on that for a deterministic candidate order
    cats.sort_by_key(|c| format!("{c:?}"));
    cats.dedup();
    cats
}

/// Counts by (custom) conversion method — the §3.3 methods breakdown.
pub fn method_histogram(cfg: RvvConfig) -> BTreeMap<&'static str, usize> {
    let mut m = BTreeMap::new();
    for c in conversion_table(cfg) {
        *m.entry(c.custom_method.name()).or_insert(0) += 1;
    }
    m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::ops::Family;

    #[test]
    fn substantial_conversion_coverage() {
        // the paper implements 1520 conversions; our grid instantiates the
        // implemented families into several hundred concrete conversions
        let table = conversion_table(RvvConfig::new(128));
        assert!(table.len() > 500, "only {} conversions", table.len());
    }

    #[test]
    fn every_custom_lowering_emits_ops() {
        for c in conversion_table(RvvConfig::new(128)) {
            assert!(
                c.custom_ops > 0 || c.op.family == Family::GetLow,
                "{} emitted no ops",
                c.op.name()
            );
        }
    }

    #[test]
    fn custom_methods_dominate() {
        // paper: "we predominantly use customized RVV Intrinsics
        // implementations for the conversions"
        let table = conversion_table(RvvConfig::new(128));
        let custom = table.iter().filter(|c| c.custom_method.is_custom()).count();
        assert!(custom * 10 >= table.len() * 9, "{custom}/{} custom", table.len());
    }

    #[test]
    fn baseline_uses_generic_methods_only() {
        for c in conversion_table(RvvConfig::new(128)) {
            assert!(
                !c.baseline_method.is_custom(),
                "{} baseline used a custom method",
                c.op.name()
            );
        }
    }

    #[test]
    fn zvfh_gates_f16_conversions() {
        let with = conversion_table(RvvConfig { vlen: 128, zvfh: true });
        let without = conversion_table(RvvConfig { vlen: 128, zvfh: false });
        let f16_with = with.iter().filter(|c| c.op.elem == Elem::F16).count();
        let f16_without = without.iter().filter(|c| c.op.elem == Elem::F16).count();
        assert!(f16_with > 0);
        assert_eq!(f16_without, 0);
    }
}
