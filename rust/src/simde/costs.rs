//! Calibrated instruction-cost constants for the baseline's scalar
//! fallbacks (SIMDe generic loops that clang's auto-vectorizer rejects).
//!
//! Costs approximate what clang -O3 emits on rv64gc for SIMDe's generic
//! per-lane loops: each lane does `load operand(s); compute; store result`
//! through the private union on the stack. Libm-call bodies (sqrtf,
//! roundevenf, ...) additionally pay the call + the scalar routine.
//! These constants only affect the *baseline* mode, i.e. they calibrate the
//! denominator of the Figure 2 speedups; EXPERIMENTS.md §Calibration
//! discusses sensitivity.

/// Per-lane scalar ALU cost of a branchy saturating add/sub body.
pub const SATURATING_PER_LANE: u64 = 5;

/// Per-lane cost of a saturating-narrow body (clamp + truncate).
pub const QNARROW_PER_LANE: u64 = 6;

/// Per-lane cost of a libm sqrt (call overhead + fsqrt + errno guard).
pub const SQRTF_PER_LANE: u64 = 10;

/// Per-lane cost of 1/sqrtf (sqrt + divide).
pub const RSQRT_PER_LANE: u64 = 12;

/// Per-lane cost of 1/x reciprocal.
pub const RECIP_PER_LANE: u64 = 6;

/// Per-lane cost of roundevenf/lrintf-style libm rounding.
pub const ROUNDEVEN_PER_LANE: u64 = 8;

/// Per-lane cost of the binary-magic-numbers scalar bit reverse
/// (3 swap stages x ~4 ops, Listing 7).
pub const RBIT_PER_LANE: u64 = 12;

/// Per-lane cost of a scalarised count-leading-zeros.
pub const CLZ_PER_LANE: u64 = 8;

/// Per-lane cost of a scalarised popcount.
pub const CNT_PER_LANE: u64 = 6;

/// Per-lane cost of a table-lookup body (bounds check + indexed load).
pub const TBL_PER_LANE: u64 = 5;

/// Per-lane cost of a pairwise-op body (lane-crossing indexing).
pub const PAIRWISE_PER_LANE: u64 = 4;

/// Per-lane cost of a variable-shift body (sign test + two shifts).
pub const SSHL_PER_LANE: u64 = 6;

/// Per-lane memory traffic of a generic scalar loop: operand load(s) +
/// result store through the union.
pub const SCALAR_MEM_PER_LANE: u64 = 2;

/// Fixed overhead of entering a scalar fallback: spilling live vector
/// operands to the union on the stack and reloading the result.
pub const SCALAR_SPILL_OVERHEAD: u64 = 3;
