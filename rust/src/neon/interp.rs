//! NEON interpreter: executes an IR program directly under NEON semantics.
//! This is the golden reference every translated RVV program is checked
//! against (the role SIMDe's native-ARM path plays in the paper's
//! validation workflow).

use std::collections::HashMap;

use anyhow::{bail, Context, Result};

use super::elem::Elem;
use super::ops::{ArgTy, Family};
use super::semantics::{eval_pure, Value};
use super::vreg::{VReg, VecTy};
use crate::ir::{Arg, BufDecl, BufKind, NeonCall, Program, Stmt};
#[cfg(test)]
use crate::ir::AddrExpr;

/// Raw byte memory for one buffer.
#[derive(Debug, Clone)]
pub struct Buffer {
    pub elem: Elem,
    pub data: Vec<u8>,
}

impl Buffer {
    pub fn zeros(elem: Elem, len: usize) -> Buffer {
        Buffer { elem, data: vec![0; len * elem.bytes() as usize] }
    }

    pub fn from_f32s(vals: &[f32]) -> Buffer {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_bits().to_le_bytes());
        }
        Buffer { elem: Elem::F32, data }
    }

    pub fn from_i32s(vals: &[i32]) -> Buffer {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Buffer { elem: Elem::I32, data }
    }

    pub fn from_u8s(vals: &[u8]) -> Buffer {
        Buffer { elem: Elem::U8, data: vals.to_vec() }
    }

    pub fn from_u32s(vals: &[u32]) -> Buffer {
        let mut data = Vec::with_capacity(vals.len() * 4);
        for v in vals {
            data.extend_from_slice(&v.to_le_bytes());
        }
        Buffer { elem: Elem::U32, data }
    }

    pub fn len_elems(&self) -> usize {
        self.data.len() / self.elem.bytes() as usize
    }

    pub fn read_elem(&self, idx: usize) -> u64 {
        let w = self.elem.bytes() as usize;
        let off = idx * w;
        let mut buf = [0u8; 8];
        buf[..w].copy_from_slice(&self.data[off..off + w]);
        u64::from_le_bytes(buf)
    }

    pub fn write_elem(&mut self, idx: usize, raw: u64) {
        let w = self.elem.bytes() as usize;
        let off = idx * w;
        self.data[off..off + w].copy_from_slice(&raw.to_le_bytes()[..w]);
    }

    pub fn as_f32s(&self) -> Vec<f32> {
        assert_eq!(self.elem, Elem::F32);
        (0..self.len_elems())
            .map(|i| f32::from_bits(self.read_elem(i) as u32))
            .collect()
    }

    pub fn as_i32s(&self) -> Vec<i32> {
        (0..self.len_elems()).map(|i| self.read_elem(i) as i32).collect()
    }

    pub fn as_u32s(&self) -> Vec<u32> {
        (0..self.len_elems()).map(|i| self.read_elem(i) as u32).collect()
    }
}

/// Named input set for a program run.
pub type Inputs = HashMap<String, Buffer>;

/// Execution statistics from a NEON interpretation.
#[derive(Debug, Default, Clone, Copy)]
pub struct NeonStats {
    /// Dynamic count of NEON intrinsic invocations.
    pub intrinsic_execs: u64,
    /// Dynamic count of scalar (address) assignments.
    pub scalar_execs: u64,
    /// Dynamic loop iterations.
    pub loop_iters: u64,
}

/// Interpreter state over one program.
pub struct NeonInterp<'p> {
    prog: &'p Program,
    bufs: Vec<Buffer>,
    vregs: Vec<Option<VReg>>,
    sregs: Vec<i64>,
    pub stats: NeonStats,
}

impl<'p> NeonInterp<'p> {
    pub fn new(prog: &'p Program, inputs: &Inputs) -> Result<NeonInterp<'p>> {
        let mut bufs = Vec::with_capacity(prog.bufs.len());
        for decl in &prog.bufs {
            bufs.push(materialise(decl, inputs)?);
        }
        Ok(NeonInterp {
            prog,
            bufs,
            vregs: vec![None; prog.n_vregs],
            sregs: vec![0; prog.n_sregs],
            stats: NeonStats::default(),
        })
    }

    /// Run to completion; returns output buffers by name.
    pub fn run(mut self) -> Result<HashMap<String, Buffer>> {
        let body = &self.prog.body;
        self.exec_block(body)?;
        let mut out = HashMap::new();
        for (decl, buf) in self.prog.bufs.iter().zip(self.bufs) {
            if decl.kind == BufKind::Output {
                out.insert(decl.name.clone(), buf);
            }
        }
        Ok(out)
    }

    fn exec_block(&mut self, stmts: &'p [Stmt]) -> Result<()> {
        for s in stmts {
            match s {
                Stmt::VOp { dst, call } => {
                    let v = self.exec_call(call)?.expect("VOp must produce a value");
                    self.vregs[*dst as usize] = Some(v);
                    self.stats.intrinsic_execs += 1;
                }
                Stmt::VStore { call } => {
                    let r = self.exec_call(call)?;
                    debug_assert!(r.is_none());
                    self.stats.intrinsic_execs += 1;
                }
                Stmt::SSet { dst, expr } => {
                    self.sregs[*dst as usize] = expr.eval(&self.sregs);
                    self.stats.scalar_execs += 1;
                }
                Stmt::Loop { ivar, start, end, step, body } => {
                    let mut i = *start;
                    while i < *end {
                        self.sregs[*ivar as usize] = i;
                        self.stats.loop_iters += 1;
                        self.exec_block(body)?;
                        i += step;
                    }
                }
            }
        }
        Ok(())
    }

    fn vreg(&self, r: u32) -> Result<VReg> {
        self.vregs[r as usize]
            .clone()
            .with_context(|| format!("read of undefined vreg v{r}"))
    }

    /// Execute one intrinsic call: memory families here, pure families via
    /// [`eval_pure`].
    fn exec_call(&mut self, call: &NeonCall) -> Result<Option<VReg>> {
        let op = call.op;
        match op.family {
            Family::Ld1 => {
                let (buf, idx) = self.resolve_mem(&call.args[0])?;
                let vt = op.vt();
                let v = self.load_vec(buf, idx, vt)?;
                Ok(Some(v))
            }
            Family::Ld1Dup => {
                let (buf, idx) = self.resolve_mem(&call.args[0])?;
                let raw = self.checked_read(buf, idx, 1)?[0];
                Ok(Some(VReg::splat_raw(op.vt(), raw)))
            }
            Family::Ld1Lane => {
                let (buf, idx) = self.resolve_mem(&call.args[0])?;
                let mut v = self.vreg(arg_v(&call.args[1])?)?;
                let lane = arg_imm(&call.args[2])? as usize;
                let raw = self.checked_read(buf, idx, 1)?[0];
                v.set_lane(lane, raw);
                Ok(Some(v))
            }
            Family::St1 => {
                let (buf, idx) = self.resolve_mem(&call.args[0])?;
                let v = self.vreg(arg_v(&call.args[1])?)?;
                self.store_vec(buf, idx, &v)?;
                Ok(None)
            }
            Family::St1Lane => {
                let (buf, idx) = self.resolve_mem(&call.args[0])?;
                let v = self.vreg(arg_v(&call.args[1])?)?;
                let lane = arg_imm(&call.args[2])? as usize;
                self.checked_write(buf, idx, &[v.lane(lane)])?;
                Ok(None)
            }
            _ => {
                // pure op: materialise arguments and evaluate
                let mut vals = Vec::with_capacity(call.args.len());
                for a in &call.args {
                    vals.push(match a {
                        Arg::V(r) => Value::V(self.vreg(*r)?),
                        Arg::S(r) => Value::Imm(self.sregs[*r as usize]),
                        Arg::Imm(i) => Value::Imm(*i),
                        Arg::ImmF(f) => Value::F(*f),
                        Arg::Mem { .. } => bail!("{} takes no memory operand", op.name()),
                    });
                }
                Ok(Some(eval_pure(op, &vals)))
            }
        }
    }

    fn resolve_mem(&self, a: &Arg) -> Result<(usize, usize)> {
        match a {
            Arg::Mem { buf, index } => {
                let idx = index.eval(&self.sregs);
                if idx < 0 {
                    bail!("negative buffer index {idx}");
                }
                Ok((*buf as usize, idx as usize))
            }
            _ => bail!("expected memory operand"),
        }
    }

    fn checked_read(&self, buf: usize, idx: usize, n: usize) -> Result<Vec<u64>> {
        let b = &self.bufs[buf];
        if idx + n > b.len_elems() {
            bail!(
                "OOB read of {}[{}..{}] (len {})",
                self.prog.bufs[buf].name,
                idx,
                idx + n,
                b.len_elems()
            );
        }
        Ok((idx..idx + n).map(|i| b.read_elem(i)).collect())
    }

    fn checked_write(&mut self, buf: usize, idx: usize, vals: &[u64]) -> Result<()> {
        let b = &mut self.bufs[buf];
        if idx + vals.len() > b.len_elems() {
            bail!(
                "OOB write of {}[{}..{}] (len {})",
                self.prog.bufs[buf].name,
                idx,
                idx + vals.len(),
                b.len_elems()
            );
        }
        for (i, &v) in vals.iter().enumerate() {
            b.write_elem(idx + i, v);
        }
        Ok(())
    }

    fn load_vec(&self, buf: usize, idx: usize, vt: VecTy) -> Result<VReg> {
        let raws = self.checked_read(buf, idx, vt.lanes as usize)?;
        Ok(VReg::from_raw(vt, raws))
    }

    fn store_vec(&mut self, buf: usize, idx: usize, v: &VReg) -> Result<()> {
        self.checked_write(buf, idx, &v.lanes.clone())
    }
}

fn materialise(decl: &BufDecl, inputs: &Inputs) -> Result<Buffer> {
    match decl.kind {
        BufKind::Input => {
            let b = inputs
                .get(&decl.name)
                .with_context(|| format!("missing input buffer '{}'", decl.name))?;
            if b.elem != decl.elem || b.len_elems() != decl.len {
                bail!(
                    "input '{}' mismatch: want {:?}x{}, got {:?}x{}",
                    decl.name,
                    decl.elem,
                    decl.len,
                    b.elem,
                    b.len_elems()
                );
            }
            Ok(b.clone())
        }
        BufKind::Output | BufKind::Scratch => Ok(Buffer::zeros(decl.elem, decl.len)),
    }
}

fn arg_v(a: &Arg) -> Result<u32> {
    match a {
        Arg::V(r) => Ok(*r),
        _ => bail!("expected vector register argument"),
    }
}

fn arg_imm(a: &Arg) -> Result<i64> {
    match a {
        Arg::Imm(i) => Ok(*i),
        _ => bail!("expected immediate argument"),
    }
}

/// Validate that every intrinsic call in a program matches its signature —
/// the IR-level analogue of C type checking against `<arm_neon.h>`.
pub fn typecheck(prog: &Program) -> Result<()> {
    fn check_block(prog: &Program, stmts: &[Stmt]) -> Result<()> {
        for s in stmts {
            match s {
                Stmt::VOp { call, .. } | Stmt::VStore { call } => {
                    let sig = call.op.sig();
                    if sig.args.len() != call.args.len() {
                        bail!(
                            "{}: arity mismatch ({} args, want {})",
                            call.op.name(),
                            call.args.len(),
                            sig.args.len()
                        );
                    }
                    for (at, a) in sig.args.iter().zip(&call.args) {
                        let ok = matches!(
                            (at, a),
                            (ArgTy::V(_), Arg::V(_))
                                | (ArgTy::Ptr(_), Arg::Mem { .. })
                                | (ArgTy::Imm, Arg::Imm(_))
                                | (ArgTy::ScalarInt, Arg::Imm(_))
                                | (ArgTy::ScalarInt, Arg::ImmF(_))
                                | (ArgTy::ScalarInt, Arg::S(_))
                        );
                        if !ok {
                            bail!("{}: argument kind mismatch ({at:?} vs {a:?})", call.op.name());
                        }
                        if let (ArgTy::Ptr(e), Arg::Mem { buf, .. }) = (at, a) {
                            let decl = &prog.bufs[*buf as usize];
                            if decl.elem.bits() != e.bits() {
                                bail!(
                                    "{}: pointer elem width mismatch (buf '{}' is {:?}, op wants {:?})",
                                    call.op.name(),
                                    decl.name,
                                    decl.elem,
                                    e
                                );
                            }
                        }
                    }
                }
                Stmt::Loop { body, .. } => check_block(prog, body)?,
                Stmt::SSet { .. } => {}
            }
        }
        Ok(())
    }
    check_block(prog, &prog.body)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ir::ProgramBuilder;
    use crate::neon::ops::Family;

    fn vadd_program() -> Program {
        let mut b = ProgramBuilder::new("vadd");
        let a = b.input("A", Elem::I32, 4);
        let bb = b.input("B", Elem::I32, 4);
        let o = b.output("O", Elem::I32, 4);
        let va = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(a, AddrExpr::k(0))]);
        let vb = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(bb, AddrExpr::k(0))]);
        let vc = b.vop(Family::Add, Elem::I32, true, vec![Arg::V(va), Arg::V(vb)]);
        b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o, AddrExpr::k(0)), Arg::V(vc)]);
        b.finish()
    }

    #[test]
    fn listing9_vector_add() {
        // the paper's Listing 9 example: {0,1,2,3} + {4,5,6,7}
        let p = vadd_program();
        typecheck(&p).unwrap();
        let mut inputs = Inputs::new();
        inputs.insert("A".into(), Buffer::from_i32s(&[0, 1, 2, 3]));
        inputs.insert("B".into(), Buffer::from_i32s(&[4, 5, 6, 7]));
        let out = NeonInterp::new(&p, &inputs).unwrap().run().unwrap();
        assert_eq!(out["O"].as_i32s(), vec![4, 6, 8, 10]);
    }

    #[test]
    fn looped_relu() {
        let n = 32usize;
        let mut b = ProgramBuilder::new("relu");
        let x = b.input("X", Elem::F32, n);
        let y = b.output("Y", Elem::F32, n);
        let zero = b.vop(Family::DupN, Elem::F32, true, vec![Arg::Imm(0)]);
        b.loop_(0, n as i64, 4, |b, i| {
            let v = b.vop(Family::Ld1, Elem::F32, true, vec![Arg::mem(x, AddrExpr::s(i))]);
            let r = b.vop(Family::Max, Elem::F32, true, vec![Arg::V(v), Arg::V(zero)]);
            b.vstore(Family::St1, Elem::F32, true, vec![Arg::mem(y, AddrExpr::s(i)), Arg::V(r)]);
        });
        let p = b.finish();
        typecheck(&p).unwrap();

        let xs: Vec<f32> = (0..n).map(|i| i as f32 - 16.0).collect();
        let mut inputs = Inputs::new();
        inputs.insert("X".into(), Buffer::from_f32s(&xs));
        let interp = NeonInterp::new(&p, &inputs).unwrap();
        let out = interp.run().unwrap();
        let ys = out["Y"].as_f32s();
        for (x, y) in xs.iter().zip(&ys) {
            assert_eq!(*y, x.max(0.0));
        }
    }

    #[test]
    fn oob_read_is_an_error() {
        let mut b = ProgramBuilder::new("oob");
        let a = b.input("A", Elem::I32, 3); // too small for a q load
        let _ = b.vop(Family::Ld1, Elem::I32, true, vec![Arg::mem(a, AddrExpr::k(0))]);
        let p = b.finish();
        let mut inputs = Inputs::new();
        inputs.insert("A".into(), Buffer::from_i32s(&[1, 2, 3]));
        let r = NeonInterp::new(&p, &inputs).unwrap().run();
        assert!(r.is_err());
    }

    #[test]
    fn undefined_vreg_is_an_error() {
        let mut b = ProgramBuilder::new("undef");
        let o = b.output("O", Elem::I32, 4);
        let dangling = b.fresh_vreg();
        b.vstore(Family::St1, Elem::I32, true, vec![Arg::mem(o, AddrExpr::k(0)), Arg::V(dangling)]);
        let p = b.finish();
        let r = NeonInterp::new(&p, &Inputs::new()).unwrap().run();
        assert!(r.is_err());
    }

    #[test]
    fn stats_count_dynamic_execs() {
        let p = vadd_program();
        let mut inputs = Inputs::new();
        inputs.insert("A".into(), Buffer::from_i32s(&[0; 4]));
        inputs.insert("B".into(), Buffer::from_i32s(&[0; 4]));
        let interp = NeonInterp::new(&p, &inputs).unwrap();
        let stats_holder = {
            let mut i = interp;
            i.exec_block(&p.body).unwrap();
            i.stats
        };
        assert_eq!(stats_holder.intrinsic_execs, 4);
    }
}
