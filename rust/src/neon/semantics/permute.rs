//! Permute family semantics: half extraction (`vget_high`, paper Listing 5),
//! combine, window extract, reversals, zips/unzips/transposes, broadcasts,
//! and byte table lookup.

use super::Value;
use crate::neon::elem::{self, Elem};
use crate::neon::ops::{Family, NeonOp};
use crate::neon::vreg::{VReg, VecTy};

pub fn eval(op: NeonOp, args: &[Value]) -> VReg {
    let ret = op.sig().ret.expect("permute ops return a vector");
    match op.family {
        Family::GetLow => {
            let a = args[0].v();
            VReg::from_raw(ret, a.lanes[..ret.lanes as usize].to_vec())
        }
        Family::GetHigh => {
            // paper Listing 5: RVV equivalent is vslidedown by N/2
            let a = args[0].v();
            let half = a.lanes.len() / 2;
            VReg::from_raw(ret, a.lanes[half..].to_vec())
        }
        Family::Combine => {
            let (lo, hi) = (args[0].v(), args[1].v());
            let lanes = lo.lanes.iter().chain(&hi.lanes).copied().collect();
            VReg::from_raw(ret, lanes)
        }
        Family::Ext => {
            // result = concat(a, b)[n .. n+lanes]
            let (a, b) = (args[0].v(), args[1].v());
            let n = args[2].imm() as usize;
            let cat: Vec<u64> = a.lanes.iter().chain(&b.lanes).copied().collect();
            VReg::from_raw(ret, cat[n..n + ret.lanes as usize].to_vec())
        }
        Family::Rev64 | Family::Rev32 | Family::Rev16 => {
            let group_bits = match op.family {
                Family::Rev64 => 64,
                Family::Rev32 => 32,
                _ => 16,
            };
            let a = args[0].v();
            let per = (group_bits / op.elem.bits()) as usize;
            let mut lanes = a.lanes.clone();
            for chunk in lanes.chunks_mut(per) {
                chunk.reverse();
            }
            VReg::from_raw(ret, lanes)
        }
        Family::Zip1 | Family::Zip2 => {
            let (a, b) = (args[0].v(), args[1].v());
            let half = a.lanes.len() / 2;
            let off = if op.family == Family::Zip2 { half } else { 0 };
            let mut lanes = Vec::with_capacity(a.lanes.len());
            for i in 0..half {
                lanes.push(a.lanes[off + i]);
                lanes.push(b.lanes[off + i]);
            }
            VReg::from_raw(ret, lanes)
        }
        Family::Uzp1 | Family::Uzp2 => {
            let (a, b) = (args[0].v(), args[1].v());
            let off = if op.family == Family::Uzp2 { 1 } else { 0 };
            let lanes = a
                .lanes
                .iter()
                .chain(&b.lanes)
                .copied()
                .skip(off)
                .step_by(2)
                .collect();
            VReg::from_raw(ret, lanes)
        }
        Family::Trn1 | Family::Trn2 => {
            let (a, b) = (args[0].v(), args[1].v());
            let off = if op.family == Family::Trn2 { 1 } else { 0 };
            let mut lanes = Vec::with_capacity(a.lanes.len());
            for i in (0..a.lanes.len()).step_by(2) {
                lanes.push(a.lanes[i + off]);
                lanes.push(b.lanes[i + off]);
            }
            VReg::from_raw(ret, lanes)
        }
        Family::DupLane => {
            let a = args[0].v();
            let lane = args[1].imm() as usize;
            VReg::splat_raw(ret, a.lane(lane))
        }
        Family::DupN => {
            let raw = if op.elem.is_float() {
                elem::from_f64(op.elem, args[0].fimm())
            } else {
                elem::from_i64(op.elem, args[0].imm())
            };
            VReg::splat_raw(ret, raw)
        }
        Family::Tbl1 => {
            // byte table lookup: out[i] = idx[i] < 8 ? table[idx[i]] : 0
            let (table, idx) = (args[0].v(), args[1].v());
            let lanes = idx
                .lanes
                .iter()
                .map(|&i| {
                    let i = elem::to_u64(Elem::U8, i) as usize;
                    if i < table.lanes.len() {
                        table.lanes[i]
                    } else {
                        0
                    }
                })
                .collect();
            VReg::from_raw(VecTy::d(Elem::U8), lanes)
        }
        f => panic!("permute::eval got family {f:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn q32(v: &[i64]) -> Value {
        Value::V(VReg::from_i64s(VecTy::q(Elem::I32), v))
    }

    fn d32(v: &[i64]) -> Value {
        Value::V(VReg::from_i64s(VecTy::d(Elem::I32), v))
    }

    #[test]
    fn vget_high_s32_listing5() {
        let op = NeonOp::new(Family::GetHigh, Elem::I32, false);
        let r = eval(op, &[q32(&[1, 2, 3, 4])]);
        assert_eq!(r.ty, VecTy::d(Elem::I32));
        assert_eq!(r.as_i64s(), vec![3, 4]);
    }

    #[test]
    fn vget_low_and_combine_roundtrip() {
        let lo = eval(NeonOp::new(Family::GetLow, Elem::I32, false), &[q32(&[1, 2, 3, 4])]);
        let hi = eval(NeonOp::new(Family::GetHigh, Elem::I32, false), &[q32(&[1, 2, 3, 4])]);
        let back = eval(
            NeonOp::new(Family::Combine, Elem::I32, false),
            &[Value::V(lo), Value::V(hi)],
        );
        assert_eq!(back.as_i64s(), vec![1, 2, 3, 4]);
    }

    #[test]
    fn vextq_s32_window() {
        let op = NeonOp::new(Family::Ext, Elem::I32, true);
        let r = eval(op, &[q32(&[1, 2, 3, 4]), q32(&[5, 6, 7, 8]), Value::Imm(3)]);
        assert_eq!(r.as_i64s(), vec![4, 5, 6, 7]);
    }

    #[test]
    fn vrev64q_s32() {
        let op = NeonOp::new(Family::Rev64, Elem::I32, true);
        let r = eval(op, &[q32(&[1, 2, 3, 4])]);
        assert_eq!(r.as_i64s(), vec![2, 1, 4, 3]);
    }

    #[test]
    fn vzip1q_vzip2q() {
        let z1 = eval(NeonOp::new(Family::Zip1, Elem::I32, true), &[q32(&[1, 2, 3, 4]), q32(&[5, 6, 7, 8])]);
        assert_eq!(z1.as_i64s(), vec![1, 5, 2, 6]);
        let z2 = eval(NeonOp::new(Family::Zip2, Elem::I32, true), &[q32(&[1, 2, 3, 4]), q32(&[5, 6, 7, 8])]);
        assert_eq!(z2.as_i64s(), vec![3, 7, 4, 8]);
    }

    #[test]
    fn vuzp_vtrn() {
        let u1 = eval(NeonOp::new(Family::Uzp1, Elem::I32, true), &[q32(&[1, 2, 3, 4]), q32(&[5, 6, 7, 8])]);
        assert_eq!(u1.as_i64s(), vec![1, 3, 5, 7]);
        let u2 = eval(NeonOp::new(Family::Uzp2, Elem::I32, true), &[q32(&[1, 2, 3, 4]), q32(&[5, 6, 7, 8])]);
        assert_eq!(u2.as_i64s(), vec![2, 4, 6, 8]);
        let t1 = eval(NeonOp::new(Family::Trn1, Elem::I32, true), &[q32(&[1, 2, 3, 4]), q32(&[5, 6, 7, 8])]);
        assert_eq!(t1.as_i64s(), vec![1, 5, 3, 7]);
        let t2 = eval(NeonOp::new(Family::Trn2, Elem::I32, true), &[q32(&[1, 2, 3, 4]), q32(&[5, 6, 7, 8])]);
        assert_eq!(t2.as_i64s(), vec![2, 6, 4, 8]);
    }

    #[test]
    fn vdupq_lane_s32() {
        let op = NeonOp::new(Family::DupLane, Elem::I32, true);
        let r = eval(op, &[d32(&[7, 9]), Value::Imm(1)]);
        assert_eq!(r.as_i64s(), vec![9, 9, 9, 9]);
    }

    #[test]
    fn vdupq_n_s32() {
        let op = NeonOp::new(Family::DupN, Elem::I32, true);
        let r = eval(op, &[Value::Imm(-3)]);
        assert_eq!(r.as_i64s(), vec![-3; 4]);
    }

    #[test]
    fn vtbl1_u8_out_of_range_is_zero() {
        let op = NeonOp::new(Family::Tbl1, Elem::U8, false);
        let table = Value::V(VReg::from_i64s(VecTy::d(Elem::U8), &[10, 11, 12, 13, 14, 15, 16, 17]));
        let idx = Value::V(VReg::from_i64s(VecTy::d(Elem::U8), &[0, 7, 3, 200, 1, 8, 2, 5]));
        let r = eval(op, &[table, idx]);
        assert_eq!(r.as_u64s(), vec![10, 17, 13, 0, 11, 0, 12, 15]);
    }
}
