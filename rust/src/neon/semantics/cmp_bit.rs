//! Comparison (all-ones/all-zeros masks) and bitwise family semantics.
//!
//! NEON comparisons produce unsigned vectors whose lanes are all-ones where
//! the predicate holds — the paper's Listing 6 shows the RVV equivalent
//! (`vmv` + `vmseq` + `vmerge`).

use super::{map1, map2, map3, ones, Value};
use crate::neon::elem::{self};
use crate::neon::ops::{Family, NeonOp};
use crate::neon::vreg::VReg;

pub fn eval(op: NeonOp, args: &[Value]) -> VReg {
    let e = op.elem;
    let ret = op.sig().ret.expect("cmp/bit ops return a vector");
    match op.family {
        Family::Ceq => cmp(op, args, |o| o == std::cmp::Ordering::Equal),
        Family::Cge => cmp(op, args, |o| o != std::cmp::Ordering::Less),
        Family::Cgt => cmp(op, args, |o| o == std::cmp::Ordering::Greater),
        Family::Cle => cmp(op, args, |o| o != std::cmp::Ordering::Greater),
        Family::Clt => cmp(op, args, |o| o == std::cmp::Ordering::Less),
        Family::Ceqz => {
            let a = args[0].v();
            let zero = VReg::zero(a.ty);
            cmp(op, &[args[0].clone(), Value::V(zero)], |o| o == std::cmp::Ordering::Equal)
        }
        Family::Tst => {
            let m = ones(e);
            map2(ret, args[0].v(), args[1].v(), move |x, y| {
                if x & y != 0 {
                    m
                } else {
                    0
                }
            })
        }
        Family::And => map2(ret, args[0].v(), args[1].v(), |x, y| x & y),
        Family::Orr => map2(ret, args[0].v(), args[1].v(), |x, y| x | y),
        Family::Eor => map2(ret, args[0].v(), args[1].v(), |x, y| x ^ y),
        Family::Bic => map2(ret, args[0].v(), args[1].v(), |x, y| x & !y),
        Family::Orn => map2(ret, args[0].v(), args[1].v(), |x, y| x | !y),
        Family::Mvn => map1(ret, args[0].v(), |x| !x),
        Family::Bsl => {
            // (mask & a) | (~mask & b), bitwise
            map3(ret, args[0].v(), args[1].v(), args[2].v(), |m, a, b| {
                (m & a) | (!m & b)
            })
        }
        f => panic!("cmp_bit::eval got family {f:?}"),
    }
}

fn cmp(op: NeonOp, args: &[Value], pred: impl Fn(std::cmp::Ordering) -> bool) -> VReg {
    let e = op.elem;
    let ret = op.sig().ret.unwrap();
    let m = ones(ret.elem);
    map2(ret, args[0].v(), args[1].v(), move |x, y| {
        let ord = if e.is_float() {
            let (fx, fy) = (elem::to_f64(e, x), elem::to_f64(e, y));
            match fx.partial_cmp(&fy) {
                Some(o) => o,
                None => return 0, // NaN compares false on every predicate
            }
        } else if e.is_signed() {
            elem::to_i64(e, x).cmp(&elem::to_i64(e, y))
        } else {
            elem::to_u64(e, x).cmp(&elem::to_u64(e, y))
        };
        if pred(ord) {
            m
        } else {
            0
        }
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::elem::Elem;
    use crate::neon::vreg::VecTy;

    fn q32(v: &[i64]) -> Value {
        Value::V(VReg::from_i64s(VecTy::q(Elem::I32), v))
    }

    #[test]
    fn vceqq_s32_all_ones_pattern() {
        // paper Listing 6 semantics
        let op = NeonOp::new(Family::Ceq, Elem::I32, true);
        let r = eval(op, &[q32(&[1, 2, 3, 4]), q32(&[1, 0, 3, 0])]);
        assert_eq!(r.ty, VecTy::q(Elem::U32));
        assert_eq!(r.as_u64s(), vec![0xffff_ffff, 0, 0xffff_ffff, 0]);
    }

    #[test]
    fn vcltq_f32_nan_is_false() {
        let op = NeonOp::new(Family::Clt, Elem::F32, true);
        let a = Value::V(VReg::from_f32s(VecTy::q(Elem::F32), &[1.0, f32::NAN, -1.0, 0.0]));
        let b = Value::V(VReg::from_f32s(VecTy::q(Elem::F32), &[2.0, 2.0, 2.0, f32::NAN]));
        let r = eval(op, &[a, b]);
        assert_eq!(r.as_u64s(), vec![0xffff_ffff, 0, 0xffff_ffff, 0]);
    }

    #[test]
    fn vcgeq_u32_unsigned_order() {
        let op = NeonOp::new(Family::Cge, Elem::U32, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::U32), &[0xffff_ffff, 1, 5, 0]));
        let b = Value::V(VReg::from_i64s(VecTy::q(Elem::U32), &[1, 0xffff_ffff, 5, 0]));
        let r = eval(op, &[a, b]);
        assert_eq!(r.as_u64s(), vec![0xffff_ffff, 0, 0xffff_ffff, 0xffff_ffff]);
    }

    #[test]
    fn vbslq_bit_granularity() {
        let op = NeonOp::new(Family::Bsl, Elem::U32, true);
        let m = Value::V(VReg::from_i64s(VecTy::q(Elem::U32), &[0x0f0f_0f0f, 0, 0xffff_ffff, 0xff00_ff00]));
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::U32), &[0xaaaa_aaaa; 4]));
        let b = Value::V(VReg::from_i64s(VecTy::q(Elem::U32), &[0x5555_5555; 4]));
        let r = eval(op, &[m, a, b]);
        assert_eq!(
            r.as_u64s(),
            vec![0x5a5a_5a5a, 0x5555_5555, 0xaaaa_aaaa, 0xaa55_aa55]
        );
    }

    #[test]
    fn vtstq_s32() {
        let op = NeonOp::new(Family::Tst, Elem::I32, true);
        let r = eval(op, &[q32(&[1, 2, 4, 0]), q32(&[1, 1, 6, 7])]);
        assert_eq!(r.as_u64s(), vec![0xffff_ffff, 0, 0xffff_ffff, 0]);
    }

    #[test]
    fn vmvnq_u8() {
        let op = NeonOp::new(Family::Mvn, Elem::U8, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::U8), &[0x0f; 16]));
        let r = eval(op, &[a]);
        assert!(r.as_u64s().iter().all(|&x| x == 0xf0));
    }

    #[test]
    fn vceqzq_s32() {
        let op = NeonOp::new(Family::Ceqz, Elem::I32, true);
        let r = eval(op, &[q32(&[0, 5, 0, -1])]);
        assert_eq!(r.as_u64s(), vec![0xffff_ffff, 0, 0xffff_ffff, 0]);
    }
}
