//! Bit-manipulation family semantics: `vrbit` (the paper's Listing 7
//! binary-magic-numbers example), count-leading-zeros, and popcount.

use super::{map1, Value};
use crate::neon::ops::{Family, NeonOp};
use crate::neon::vreg::VReg;

/// Reverse the low `bits` bits of `x` via the Dr. Dobb's 1983
/// binary-magic-numbers swaps — the exact algorithm the paper's customized
/// RVV conversion vectorises (Listing 7).
pub fn bit_reverse(x: u64, bits: u32) -> u64 {
    let mut v = x;
    // swap odd and even bits
    v = ((v >> 1) & 0x5555_5555_5555_5555) | ((v & 0x5555_5555_5555_5555) << 1);
    // swap consecutive pairs
    v = ((v >> 2) & 0x3333_3333_3333_3333) | ((v & 0x3333_3333_3333_3333) << 2);
    // swap nibbles
    v = ((v >> 4) & 0x0f0f_0f0f_0f0f_0f0f) | ((v & 0x0f0f_0f0f_0f0f_0f0f) << 4);
    if bits > 8 {
        v = ((v >> 8) & 0x00ff_00ff_00ff_00ff) | ((v & 0x00ff_00ff_00ff_00ff) << 8);
    }
    if bits > 16 {
        v = ((v >> 16) & 0x0000_ffff_0000_ffff) | ((v & 0x0000_ffff_0000_ffff) << 16);
    }
    if bits > 32 {
        v = (v >> 32) | (v << 32);
    }
    v & if bits == 64 { u64::MAX } else { (1 << bits) - 1 }
}

pub fn eval(op: NeonOp, args: &[Value]) -> VReg {
    let e = op.elem;
    let ret = op.sig().ret.expect("bitmanip ops return a vector");
    let bits = e.bits();
    match op.family {
        Family::Rbit => map1(ret, args[0].v(), move |x| bit_reverse(x, bits)),
        Family::Clz => map1(ret, args[0].v(), move |x| {
            let masked = x & e.lane_mask();
            (masked << (64 - bits)).leading_zeros().min(bits) as u64
        }),
        Family::Cnt => map1(ret, args[0].v(), move |x| {
            (x & e.lane_mask()).count_ones() as u64
        }),
        f => panic!("bitmanip::eval got family {f:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::elem::Elem;
    use crate::neon::vreg::VecTy;

    #[test]
    fn bit_reverse_u8() {
        assert_eq!(bit_reverse(0b0000_0001, 8), 0b1000_0000);
        assert_eq!(bit_reverse(0b1010_0000, 8), 0b0000_0101);
        assert_eq!(bit_reverse(0xff, 8), 0xff);
        assert_eq!(bit_reverse(0, 8), 0);
    }

    #[test]
    fn bit_reverse_involution() {
        for bits in [8u32, 16, 32] {
            for x in [0u64, 1, 0xa5, 0x1234, 0xdead_beef] {
                let x = x & if bits == 64 { u64::MAX } else { (1 << bits) - 1 };
                assert_eq!(bit_reverse(bit_reverse(x, bits), bits), x, "bits={bits} x={x:#x}");
            }
        }
    }

    #[test]
    fn vrbitq_u8() {
        let op = NeonOp::new(Family::Rbit, Elem::U8, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::U8), &[
            0x01, 0x80, 0xa5, 0x3c, 0, 0xff, 0x0f, 0xf0, 1, 2, 3, 4, 5, 6, 7, 8,
        ]));
        let r = eval(op, &[a]);
        assert_eq!(r.as_u64s()[..8], [0x80, 0x01, 0xa5, 0x3c, 0, 0xff, 0xf0, 0x0f]);
    }

    #[test]
    fn vclzq_s32() {
        let op = NeonOp::new(Family::Clz, Elem::I32, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[1, 0, -1, 0x0000_8000]));
        let r = eval(op, &[a]);
        assert_eq!(r.as_i64s(), vec![31, 32, 0, 16]);
    }

    #[test]
    fn vcntq_u8() {
        let op = NeonOp::new(Family::Cnt, Elem::U8, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::U8), &[0xff, 0, 0x0f, 0xa5, 1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12]));
        let r = eval(op, &[a]);
        assert_eq!(r.as_u64s()[..4], [8, 0, 4, 4]);
    }
}
