//! Float estimate / rounding family semantics: `vrecpe`/`vrecps`,
//! `vrsqrte`/`vrsqrts` (XNNPACK's Newton-iteration sqrt path), exact sqrt,
//! and round-to-nearest.
//!
//! Estimate precision note: real NEON gives an 8-bit mantissa estimate and
//! RVV's `vfrec7`/`vfrsqrt7` give 7 bits, via different lookup tables. To
//! keep the NEON-interpreted golden outputs bit-comparable with translated
//! RVV runs, both semantic models use the same deterministic estimate
//! (mantissa truncated to 8 fraction bits); Newton steps are exact ops so
//! kernels converge to full precision the same way on both paths (see
//! DESIGN.md §2).

use super::{fop1, fop2, map1, map2, Value};
use crate::neon::elem::Elem;
use crate::neon::ops::{Family, NeonOp};
use crate::neon::vreg::VReg;

/// Shared 8-fraction-bit reciprocal estimate.
pub fn recip_estimate(x: f64) -> f64 {
    if x == 0.0 {
        return f64::INFINITY.copysign(x);
    }
    if x.is_infinite() {
        return 0.0f64.copysign(x);
    }
    truncate_mantissa(1.0 / x)
}

/// Shared 8-fraction-bit reciprocal square-root estimate.
pub fn rsqrt_estimate(x: f64) -> f64 {
    if x < 0.0 {
        return f64::NAN;
    }
    if x == 0.0 {
        return f64::INFINITY;
    }
    truncate_mantissa(1.0 / x.sqrt())
}

fn truncate_mantissa(v: f64) -> f64 {
    // keep 8 fraction bits of the f64 mantissa (52 -> 8)
    let bits = v.to_bits();
    f64::from_bits(bits & !((1u64 << 44) - 1))
}

pub fn eval(op: NeonOp, args: &[Value]) -> VReg {
    let e = op.elem;
    assert!(matches!(e, Elem::F16 | Elem::F32 | Elem::F64));
    let ret = op.sig().ret.expect("float-est ops return a vector");
    match op.family {
        Family::Recpe => map1(ret, args[0].v(), fop1(e, recip_estimate)),
        Family::Recps => {
            // Newton step for reciprocal: 2 - a*b (result feeds b*step)
            map2(ret, args[0].v(), args[1].v(), fop2(e, |a, b| 2.0 - a * b))
        }
        Family::Rsqrte => map1(ret, args[0].v(), fop1(e, rsqrt_estimate)),
        Family::Rsqrts => {
            // Newton step for rsqrt: (3 - a*b) / 2
            map2(ret, args[0].v(), args[1].v(), fop2(e, |a, b| (3.0 - a * b) / 2.0))
        }
        Family::Sqrt => map1(ret, args[0].v(), fop1(e, f64::sqrt)),
        Family::Rndn => map1(ret, args[0].v(), fop1(e, |x| {
            // round half to even
            let r = x.round();
            if (x - x.trunc()).abs() == 0.5 {
                if (x.floor() as i64) % 2 == 0 {
                    x.floor()
                } else {
                    x.ceil()
                }
            } else {
                r
            }
        })),
        f => panic!("floatest::eval got family {f:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::vreg::VecTy;

    fn qf(v: &[f32]) -> Value {
        Value::V(VReg::from_f32s(VecTy::q(Elem::F32), v))
    }

    #[test]
    fn vsqrtq_f32() {
        let op = NeonOp::new(Family::Sqrt, Elem::F32, true);
        let r = eval(op, &[qf(&[4.0, 9.0, 2.0, 0.0])]);
        let v = r.as_f64s();
        assert_eq!(v[0], 2.0);
        assert_eq!(v[1], 3.0);
        assert!((v[2] - 2f64.sqrt()).abs() < 1e-6);
        assert_eq!(v[3], 0.0);
    }

    #[test]
    fn rsqrte_newton_converges() {
        // two Newton iterations reach < 1e-6 relative error (XNNPACK pattern)
        for x in [0.5f64, 1.0, 2.0, 100.0, 12345.678] {
            let mut y = rsqrt_estimate(x);
            for _ in 0..2 {
                let step = (3.0 - x * y * y) / 2.0;
                y *= step;
            }
            let exact = 1.0 / x.sqrt();
            assert!(((y - exact) / exact).abs() < 1e-6, "x={x} y={y}");
        }
    }

    #[test]
    fn recpe_newton_converges() {
        for x in [0.5f64, 3.0, 7.7, 1e4] {
            let mut y = recip_estimate(x);
            for _ in 0..2 {
                y *= 2.0 - x * y;
            }
            assert!(((y - 1.0 / x) * x).abs() < 1e-6, "x={x}");
        }
    }

    #[test]
    fn estimate_initial_accuracy() {
        // estimates are within 2^-8 relative error
        for x in [1.0f64, 1.5, 2.0, 3.75, 1000.0] {
            let r = recip_estimate(x);
            assert!(((r - 1.0 / x) * x).abs() < 1.0 / 256.0 + 1e-12, "x={x}");
            let s = rsqrt_estimate(x);
            assert!(((s - 1.0 / x.sqrt()) * x.sqrt()).abs() < 1.0 / 256.0 + 1e-12);
        }
    }

    #[test]
    fn vrndnq_f32_ties_to_even() {
        let op = NeonOp::new(Family::Rndn, Elem::F32, true);
        let r = eval(op, &[qf(&[0.5, 1.5, -2.5, 3.3])]);
        assert_eq!(r.as_f64s(), vec![0.0, 2.0, -2.0, 3.0]);
    }

    #[test]
    fn recpe_edge_cases() {
        assert_eq!(recip_estimate(0.0), f64::INFINITY);
        assert_eq!(recip_estimate(f64::INFINITY), 0.0);
        assert!(rsqrt_estimate(-1.0).is_nan());
        assert_eq!(rsqrt_estimate(0.0), f64::INFINITY);
    }
}
