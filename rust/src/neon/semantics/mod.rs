//! Executable semantics for every implemented NEON intrinsic family.
//!
//! [`eval_pure`] evaluates non-memory intrinsics over concrete vector
//! values; memory families (`ld1*`/`st1*`) are handled by the interpreter,
//! which resolves addresses first. These semantics are the *golden
//! reference* for the whole pipeline: translated RVV programs must
//! reproduce them.

mod arith;
mod bitmanip;
mod cmp_bit;
mod convert;
pub mod floatest;
mod permute;
mod shift;

use super::ops::{Family, NeonOp};
use super::vreg::VReg;

/// A concrete argument to a pure intrinsic evaluation.
#[derive(Debug, Clone)]
pub enum Value {
    V(VReg),
    Imm(i64),
    /// Float immediate (for float `vdup_n`).
    F(f64),
}

impl Value {
    pub fn v(&self) -> &VReg {
        match self {
            Value::V(v) => v,
            other => panic!("expected vector, got {other:?}"),
        }
    }

    pub fn imm(&self) -> i64 {
        match self {
            Value::Imm(i) => *i,
            _ => panic!("expected imm"),
        }
    }

    pub fn fimm(&self) -> f64 {
        match self {
            Value::F(f) => *f,
            Value::Imm(i) => *i as f64,
            Value::V(_) => panic!("expected float imm, got vector"),
        }
    }
}

/// Evaluate a pure (non-memory) NEON intrinsic.
pub fn eval_pure(op: NeonOp, args: &[Value]) -> VReg {
    use Family::*;
    match op.family {
        Add | Sub | Mul | Mla | Mls | Fma | Fms | Div | Abs | Neg | Min
        | Max | Hadd | Rhadd | Qadd | Qsub | Abd | MulLane | MlaLane
        | FmaLane | Mull | Mlal | Pmin | Pmax | Padd => arith::eval(op, args),
        Ceq | Cge | Cgt | Cle | Clt | Ceqz | Tst | And | Orr | Eor | Bic
        | Orn | Mvn | Bsl => cmp_bit::eval(op, args),
        ShlN | ShrN | SliN | SriN | Sshl | ShrnN => shift::eval(op, args),
        GetLow | GetHigh | Combine | Ext | Rev64 | Rev32 | Rev16 | Zip1
        | Zip2 | Uzp1 | Uzp2 | Trn1 | Trn2 | DupLane | DupN | Tbl1 => {
            permute::eval(op, args)
        }
        Movl | Movn | Qmovn | Qmovun | CvtIF | CvtFI | CvtnFI | Reinterpret => {
            convert::eval(op, args)
        }
        Recpe | Recps | Rsqrte | Rsqrts | Sqrt | Rndn => floatest::eval(op, args),
        Rbit | Clz | Cnt => bitmanip::eval(op, args),
        Ld1 | Ld1Dup | Ld1Lane | St1 | St1Lane => {
            panic!("memory intrinsic {} must be handled by the interpreter", op.name())
        }
    }
}

// -- shared lane helpers ----------------------------------------------------

use super::elem::{self, Elem};
use super::vreg::VecTy;

/// Elementwise unary over raw lanes.
pub(crate) fn map1(ret: VecTy, a: &VReg, f: impl Fn(u64) -> u64) -> VReg {
    VReg::from_raw(ret, a.lanes.iter().map(|&x| f(x)).collect())
}

/// Elementwise binary over raw lanes.
pub(crate) fn map2(ret: VecTy, a: &VReg, b: &VReg, f: impl Fn(u64, u64) -> u64) -> VReg {
    VReg::from_raw(
        ret,
        a.lanes.iter().zip(&b.lanes).map(|(&x, &y)| f(x, y)).collect(),
    )
}

/// Elementwise ternary over raw lanes.
pub(crate) fn map3(
    ret: VecTy,
    a: &VReg,
    b: &VReg,
    c: &VReg,
    f: impl Fn(u64, u64, u64) -> u64,
) -> VReg {
    VReg::from_raw(
        ret,
        a.lanes
            .iter()
            .zip(&b.lanes)
            .zip(&c.lanes)
            .map(|((&x, &y), &z)| f(x, y, z))
            .collect(),
    )
}

/// Float unary on elem `e`.
pub(crate) fn fop1(e: Elem, f: impl Fn(f64) -> f64) -> impl Fn(u64) -> u64 {
    move |x| elem::from_f64(e, f(elem::to_f64(e, x)))
}

/// Float binary on elem `e`.
pub(crate) fn fop2(e: Elem, f: impl Fn(f64, f64) -> f64) -> impl Fn(u64, u64) -> u64 {
    move |x, y| elem::from_f64(e, f(elem::to_f64(e, x), elem::to_f64(e, y)))
}

/// Signed-integer binary on elem `e` (wrapping into lane width).
pub(crate) fn iop2(e: Elem, f: impl Fn(i64, i64) -> i64) -> impl Fn(u64, u64) -> u64 {
    move |x, y| elem::from_i64(e, f(elem::to_i64(e, x), elem::to_i64(e, y)))
}

/// Unsigned-integer binary on elem `e`.
pub(crate) fn uop2(e: Elem, f: impl Fn(u64, u64) -> u64) -> impl Fn(u64, u64) -> u64 {
    move |x, y| f(elem::to_u64(e, x), elem::to_u64(e, y)) & e.lane_mask()
}

/// All-ones lane pattern for comparison results.
pub(crate) fn ones(e: Elem) -> u64 {
    e.lane_mask()
}
