//! Shift family semantics: immediate shifts, shift-and-insert (`vsli`/
//! `vsri`, used by XNNPACK's exp reconstruction), vector shifts, and
//! narrowing shifts.

use super::{map1, map2, Value};
use crate::neon::elem::{self};
use crate::neon::ops::{Family, NeonOp};
use crate::neon::vreg::VReg;

pub fn eval(op: NeonOp, args: &[Value]) -> VReg {
    let e = op.elem;
    let ret = op.sig().ret.expect("shift ops return a vector");
    let bits = e.bits();
    match op.family {
        Family::ShlN => {
            let n = args[1].imm() as u32;
            assert!(n < bits, "vshl_n shift {n} out of range for {bits}-bit lanes");
            map1(ret, args[0].v(), move |x| x << n)
        }
        Family::ShrN => {
            let n = args[1].imm() as u32;
            assert!(n >= 1 && n <= bits, "vshr_n shift {n} out of range");
            if e.is_signed() {
                map1(ret, args[0].v(), move |x| {
                    elem::from_i64(e, elem::to_i64(e, x) >> n.min(63))
                })
            } else {
                map1(ret, args[0].v(), move |x| {
                    if n >= bits {
                        0
                    } else {
                        elem::to_u64(e, x) >> n
                    }
                })
            }
        }
        Family::SliN => {
            // vsli: (b << n) inserted into a keeping a's low n bits
            let n = args[2].imm() as u32;
            let keep = if n == 0 { 0 } else { (1u64 << n) - 1 };
            map2(ret, args[0].v(), args[1].v(), move |a, b| {
                ((b << n) & !keep) | (a & keep)
            })
        }
        Family::SriN => {
            // vsri: (b >> n) inserted into a keeping a's high n bits
            let n = args[2].imm() as u32;
            let keep_hi = if n == 0 {
                0
            } else {
                let m = elem::Elem::lane_mask(e);
                m & !(m >> n)
            };
            map2(ret, args[0].v(), args[1].v(), move |a, b| {
                ((elem::to_u64(e, b) >> n) & !keep_hi) | (a & keep_hi)
            })
        }
        Family::Sshl => {
            // shift by signed per-lane amount: positive left, negative right
            map2(ret, args[0].v(), args[1].v(), move |x, s| {
                let sh = elem::to_i64(e.as_signed(), s);
                if sh >= 0 {
                    let sh = (sh as u32).min(63);
                    if sh >= bits {
                        0
                    } else {
                        x << sh
                    }
                } else {
                    let sh = ((-sh) as u32).min(63);
                    if e.is_signed() {
                        elem::from_i64(e, elem::to_i64(e, x) >> sh.min(bits - 1))
                    } else if sh >= bits {
                        0
                    } else {
                        elem::to_u64(e, x) >> sh
                    }
                }
            })
        }
        Family::ShrnN => {
            // narrowing shift right: q source, d result, truncate to half width
            let n = args[1].imm() as u32;
            let src = args[0].v();
            let narrow = ret.elem;
            let lanes = src
                .lanes
                .iter()
                .map(|&x| {
                    let shifted = if e.is_signed() {
                        (elem::to_i64(e, x) >> n) as u64
                    } else {
                        elem::to_u64(e, x) >> n
                    };
                    shifted & narrow.lane_mask()
                })
                .collect();
            VReg::from_raw(ret, lanes)
        }
        f => panic!("shift::eval got family {f:?}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::elem::Elem;
    use crate::neon::vreg::VecTy;

    #[test]
    fn vshlq_n_s32() {
        let op = NeonOp::new(Family::ShlN, Elem::I32, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[1, -1, 3, 1 << 30]));
        let r = eval(op, &[a, Value::Imm(2)]);
        assert_eq!(r.as_i64s(), vec![4, -4, 12, 0]);
    }

    #[test]
    fn vshrq_n_signed_vs_unsigned() {
        let s = NeonOp::new(Family::ShrN, Elem::I32, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[-8, 8, -1, 0]));
        let r = eval(s, &[a, Value::Imm(2)]);
        assert_eq!(r.as_i64s(), vec![-2, 2, -1, 0]);

        let u = NeonOp::new(Family::ShrN, Elem::U32, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::U32), &[0xffff_fff8, 8, 1, 0]));
        let r = eval(u, &[a, Value::Imm(2)]);
        assert_eq!(r.as_u64s(), vec![0x3fff_fffe, 2, 0, 0]);
    }

    #[test]
    fn vsliq_n_inserts() {
        // used by XNNPACK exp: insert exponent bits
        let op = NeonOp::new(Family::SliN, Elem::I32, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[0b11, 0b01, 0, 0b10]));
        let b = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[1, 2, 3, 4]));
        let r = eval(op, &[a, b, Value::Imm(2)]);
        assert_eq!(r.as_i64s(), vec![0b111, 0b1001, 0b1100, 0b10010]);
    }

    #[test]
    fn vsriq_n_keeps_high() {
        let op = NeonOp::new(Family::SriN, Elem::U8, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::U8), &[0x80; 16]));
        let b = Value::V(VReg::from_i64s(VecTy::q(Elem::U8), &[0xff; 16]));
        let r = eval(op, &[a, b, Value::Imm(1)]);
        // keep a's top bit (0x80), insert 0xff>>1 = 0x7f into low 7
        assert!(r.as_u64s().iter().all(|&x| x == 0xff));
    }

    #[test]
    fn vshlq_s32_vector_negative_is_right() {
        let op = NeonOp::new(Family::Sshl, Elem::I32, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[16, 16, -16, 1]));
        let s = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[1, -2, -2, 40]));
        let r = eval(op, &[a, s]);
        assert_eq!(r.as_i64s(), vec![32, 4, -4, 0]);
    }

    #[test]
    fn vshrn_n_s32() {
        let op = NeonOp::new(Family::ShrnN, Elem::I32, false);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[0x12345678, -256, 0xffff, 1]));
        let r = eval(op, &[a, Value::Imm(8)]);
        assert_eq!(r.ty, VecTy::d(Elem::I16));
        // 0x123456 truncated to 16 bits = 0x3456
        assert_eq!(r.as_i64s(), vec![0x3456, -1, 0xff, 0]);
    }
}
