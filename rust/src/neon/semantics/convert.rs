//! Widen/narrow and conversion family semantics: `vmovl`/`vmovn`, saturating
//! narrows, int<->float conversions (truncating and round-to-nearest), and
//! `vreinterpret` bit casts.

use super::Value;
use crate::neon::elem::{self};
use crate::neon::ops::{Family, NeonOp};
use crate::neon::vreg::VReg;

pub fn eval(op: NeonOp, args: &[Value]) -> VReg {
    let e = op.elem;
    let ret = op.sig().ret.expect("convert ops return a vector");
    match op.family {
        Family::Movl => {
            let a = args[0].v();
            let lanes = a
                .lanes
                .iter()
                .map(|&x| {
                    if e.is_signed() {
                        elem::from_i64(ret.elem, elem::to_i64(e, x))
                    } else {
                        elem::to_u64(e, x)
                    }
                })
                .collect();
            VReg::from_raw(ret, lanes)
        }
        Family::Movn => {
            let a = args[0].v();
            let lanes = a.lanes.iter().map(|&x| x & ret.elem.lane_mask()).collect();
            VReg::from_raw(ret, lanes)
        }
        Family::Qmovn => {
            let a = args[0].v();
            let lanes = a
                .lanes
                .iter()
                .map(|&x| {
                    let v = if e.is_signed() {
                        elem::to_i64(e, x) as i128
                    } else {
                        elem::to_u64(e, x) as i128
                    };
                    elem::saturate(ret.elem, v)
                })
                .collect();
            VReg::from_raw(ret, lanes)
        }
        Family::Qmovun => {
            // signed wide -> unsigned narrow with saturation
            let a = args[0].v();
            let lanes = a
                .lanes
                .iter()
                .map(|&x| elem::saturate(ret.elem, elem::to_i64(e, x) as i128))
                .collect();
            VReg::from_raw(ret, lanes)
        }
        Family::CvtIF => {
            let a = args[0].v();
            let fe = ret.elem;
            let lanes = a
                .lanes
                .iter()
                .map(|&x| {
                    let v = if e.is_signed() {
                        elem::to_i64(e, x) as f64
                    } else {
                        elem::to_u64(e, x) as f64
                    };
                    elem::from_f64(fe, v)
                })
                .collect();
            VReg::from_raw(ret, lanes)
        }
        Family::CvtFI => cvt_float_int(op, args, RoundMode::TowardZero),
        Family::CvtnFI => cvt_float_int(op, args, RoundMode::NearestEven),
        Family::Reinterpret => {
            // the IR supplies a source vector; reinterpret to the named type
            args[0].v().reinterpret(ret)
        }
        f => panic!("convert::eval got family {f:?}"),
    }
}

enum RoundMode {
    TowardZero,
    NearestEven,
}

fn cvt_float_int(op: NeonOp, args: &[Value], mode: RoundMode) -> VReg {
    let e = op.elem;
    let ret = op.sig().ret.unwrap();
    let a = args[0].v();
    let bits = ret.elem.bits();
    let (lo, hi) = (-(2f64.powi(bits as i32 - 1)), 2f64.powi(bits as i32 - 1) - 1.0);
    let lanes = a
        .lanes
        .iter()
        .map(|&x| {
            let f = elem::to_f64(e, x);
            let r = match mode {
                RoundMode::TowardZero => f.trunc(),
                RoundMode::NearestEven => round_ties_even(f),
            };
            // NEON saturates out-of-range conversions; NaN -> 0
            let r = if r.is_nan() { 0.0 } else { r.clamp(lo, hi) };
            elem::from_i64(ret.elem, r as i64)
        })
        .collect();
    VReg::from_raw(ret, lanes)
}

fn round_ties_even(f: f64) -> f64 {
    let r = f.round(); // rounds half away from zero
    if (f - f.trunc()).abs() == 0.5 {
        // tie: pick the even neighbour
        let down = f.floor();
        let up = f.ceil();
        if (down as i64) % 2 == 0 {
            down
        } else {
            up
        }
    } else {
        r
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::elem::Elem;
    use crate::neon::vreg::VecTy;

    #[test]
    fn vmovl_s8_sign_extends() {
        let op = NeonOp::new(Family::Movl, Elem::I8, false);
        let a = Value::V(VReg::from_i64s(VecTy::d(Elem::I8), &[-1, 127, -128, 0, 1, 2, 3, 4]));
        let r = eval(op, &[a]);
        assert_eq!(r.ty, VecTy::q(Elem::I16));
        assert_eq!(r.as_i64s(), vec![-1, 127, -128, 0, 1, 2, 3, 4]);
    }

    #[test]
    fn vmovn_s16_truncates() {
        let op = NeonOp::new(Family::Movn, Elem::I16, false);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I16), &[0x1ff, -1, 300, 0, 1, 2, 3, 4]));
        let r = eval(op, &[a]);
        assert_eq!(r.ty, VecTy::d(Elem::I8));
        assert_eq!(r.as_i64s(), vec![-1, -1, 44, 0, 1, 2, 3, 4]);
    }

    #[test]
    fn vqmovn_s16_saturates() {
        let op = NeonOp::new(Family::Qmovn, Elem::I16, false);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I16), &[300, -300, 100, 0, 1, 2, 3, 4]));
        let r = eval(op, &[a]);
        assert_eq!(r.as_i64s(), vec![127, -128, 100, 0, 1, 2, 3, 4]);
    }

    #[test]
    fn vqmovun_s16_clamps_negative() {
        let op = NeonOp::new(Family::Qmovun, Elem::I16, false);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I16), &[-5, 300, 100, 0, 1, 2, 3, 4]));
        let r = eval(op, &[a]);
        assert_eq!(r.ty, VecTy::d(Elem::U8));
        assert_eq!(r.as_u64s(), vec![0, 255, 100, 0, 1, 2, 3, 4]);
    }

    #[test]
    fn vcvtq_f32_s32() {
        let op = NeonOp::new(Family::CvtIF, Elem::I32, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[-2, 0, 7, 100]));
        let r = eval(op, &[a]);
        assert_eq!(r.ty, VecTy::q(Elem::F32));
        assert_eq!(r.as_f64s(), vec![-2.0, 0.0, 7.0, 100.0]);
    }

    #[test]
    fn vcvtq_s32_f32_truncates_and_saturates() {
        let op = NeonOp::new(Family::CvtFI, Elem::F32, true);
        let a = Value::V(VReg::from_f32s(VecTy::q(Elem::F32), &[-2.9, 2.9, 3e10, -3e10]));
        let r = eval(op, &[a]);
        assert_eq!(r.as_i64s(), vec![-2, 2, i32::MAX as i64, i32::MIN as i64]);
    }

    #[test]
    fn vcvtnq_s32_f32_rne() {
        let op = NeonOp::new(Family::CvtnFI, Elem::F32, true);
        let a = Value::V(VReg::from_f32s(VecTy::q(Elem::F32), &[0.5, 1.5, 2.5, -0.5]));
        let r = eval(op, &[a]);
        assert_eq!(r.as_i64s(), vec![0, 2, 2, 0]);
    }

    #[test]
    fn reinterpret_s32_u8() {
        let op = NeonOp::new(Family::Reinterpret, Elem::U8, true);
        let a = Value::V(VReg::from_i64s(VecTy::q(Elem::I32), &[0x01020304, 0, 0, 0]));
        let r = eval(op, &[a]);
        assert_eq!(r.as_u64s()[..4], [4, 3, 2, 1]);
    }
}
