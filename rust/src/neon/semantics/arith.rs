//! Arithmetic family semantics: add/sub/mul, multiply-accumulate (fused and
//! unfused), halving/saturating adds, absolute difference, by-lane forms,
//! widening multiplies, and pairwise ops.

use super::{fop2, iop2, map1, map2, map3, uop2, Value};
use crate::neon::elem::{self, Elem};
use crate::neon::ops::{Family, NeonOp};
use crate::neon::vreg::{VReg, VecTy};

pub fn eval(op: NeonOp, args: &[Value]) -> VReg {
    let e = op.elem;
    let ret = op.sig().ret.expect("arith ops return a vector");
    match op.family {
        Family::Add => binary(ret, e, args, |a, b| a.wrapping_add(b), |a, b| a + b),
        Family::Sub => binary(ret, e, args, |a, b| a.wrapping_sub(b), |a, b| a - b),
        Family::Mul => binary(ret, e, args, |a, b| a.wrapping_mul(b), |a, b| a * b),
        Family::Div => {
            assert!(e.is_float(), "vdiv is float-only");
            map2(ret, args[0].v(), args[1].v(), fop2(e, |a, b| a / b))
        }
        Family::Mla => mla(ret, e, args, false, false),
        Family::Mls => mla(ret, e, args, true, false),
        Family::Fma => mla(ret, e, args, false, true),
        Family::Fms => mla(ret, e, args, true, true),
        Family::Abs => {
            if e.is_float() {
                map1(ret, args[0].v(), super::fop1(e, f64::abs))
            } else {
                map1(ret, args[0].v(), move |x| {
                    elem::from_i64(e, elem::to_i64(e, x).wrapping_abs())
                })
            }
        }
        Family::Neg => {
            if e.is_float() {
                map1(ret, args[0].v(), super::fop1(e, |a| -a))
            } else {
                map1(ret, args[0].v(), move |x| {
                    elem::from_i64(e, elem::to_i64(e, x).wrapping_neg())
                })
            }
        }
        Family::Min => minmax(ret, e, args, true),
        Family::Max => minmax(ret, e, args, false),
        Family::Pmin => pairwise(ret, e, args, PairKind::Min),
        Family::Pmax => pairwise(ret, e, args, PairKind::Max),
        Family::Padd => pairwise(ret, e, args, PairKind::Add),
        Family::Hadd => {
            // (a + b) >> 1 computed without intermediate overflow
            if e.is_signed() {
                map2(ret, args[0].v(), args[1].v(), iop2(e, |a, b| (a + b) >> 1))
            } else {
                map2(ret, args[0].v(), args[1].v(), uop2(e, |a, b| (a + b) >> 1))
            }
        }
        Family::Rhadd => {
            if e.is_signed() {
                map2(ret, args[0].v(), args[1].v(), iop2(e, |a, b| (a + b + 1) >> 1))
            } else {
                map2(ret, args[0].v(), args[1].v(), uop2(e, |a, b| (a + b + 1) >> 1))
            }
        }
        Family::Qadd => saturating(ret, e, args, false),
        Family::Qsub => saturating(ret, e, args, true),
        Family::Abd => {
            if e.is_float() {
                map2(ret, args[0].v(), args[1].v(), fop2(e, |a, b| (a - b).abs()))
            } else if e.is_signed() {
                map2(ret, args[0].v(), args[1].v(), iop2(e, |a, b| (a - b).abs()))
            } else {
                map2(ret, args[0].v(), args[1].v(), uop2(e, |a, b| a.abs_diff(b)))
            }
        }
        Family::MulLane => {
            let lane = args[2].imm() as usize;
            let b = args[1].v().lane(lane);
            let bv = VReg::splat_raw(args[0].v().ty, b);
            eval(NeonOp::new(Family::Mul, e, op.q), &[args[0].clone(), Value::V(bv)])
        }
        Family::MlaLane => {
            let lane = args[3].imm() as usize;
            let c = args[2].v().lane(lane);
            let cv = VReg::splat_raw(args[1].v().ty, c);
            mla(ret, e, &[args[0].clone(), args[1].clone(), Value::V(cv)], false, false)
        }
        Family::FmaLane => {
            let lane = args[3].imm() as usize;
            let c = args[2].v().lane(lane);
            let cv = VReg::splat_raw(args[1].v().ty, c);
            mla(ret, e, &[args[0].clone(), args[1].clone(), Value::V(cv)], false, true)
        }
        Family::Mull => {
            let (a, b) = (args[0].v(), args[1].v());
            let wide = ret.elem;
            let lanes = a
                .lanes
                .iter()
                .zip(&b.lanes)
                .map(|(&x, &y)| {
                    if e.is_signed() {
                        elem::from_i64(wide, elem::to_i64(e, x).wrapping_mul(elem::to_i64(e, y)))
                    } else {
                        (elem::to_u64(e, x).wrapping_mul(elem::to_u64(e, y))) & wide.lane_mask()
                    }
                })
                .collect();
            VReg::from_raw(ret, lanes)
        }
        Family::Mlal => {
            let (acc, a, b) = (args[0].v(), args[1].v(), args[2].v());
            let wide = ret.elem;
            let lanes = acc
                .lanes
                .iter()
                .zip(a.lanes.iter().zip(&b.lanes))
                .map(|(&s, (&x, &y))| {
                    if e.is_signed() {
                        let p = elem::to_i64(e, x).wrapping_mul(elem::to_i64(e, y));
                        elem::from_i64(wide, elem::to_i64(wide, s).wrapping_add(p))
                    } else {
                        let p = elem::to_u64(e, x).wrapping_mul(elem::to_u64(e, y));
                        (elem::to_u64(wide, s).wrapping_add(p)) & wide.lane_mask()
                    }
                })
                .collect();
            VReg::from_raw(ret, lanes)
        }
        f => panic!("arith::eval got non-arith family {f:?}"),
    }
}

fn binary(
    ret: VecTy,
    e: Elem,
    args: &[Value],
    fi: impl Fn(i64, i64) -> i64,
    ff: impl Fn(f64, f64) -> f64,
) -> VReg {
    let (a, b) = (args[0].v(), args[1].v());
    if e.is_float() {
        map2(ret, a, b, fop2(e, ff))
    } else {
        map2(ret, a, b, iop2(e, fi))
    }
}

/// `acc ± a*b`; `fused` selects single-rounding FMA (vfma) vs separate
/// multiply-then-add (vmla).
fn mla(ret: VecTy, e: Elem, args: &[Value], sub: bool, fused: bool) -> VReg {
    let (acc, a, b) = (args[0].v(), args[1].v(), args[2].v());
    if e.is_float() {
        map3(ret, acc, a, b, move |s, x, y| {
            let (s, x, y) = (elem::to_f64(e, s), elem::to_f64(e, x), elem::to_f64(e, y));
            let x = if sub { -x } else { x };
            let r = if fused {
                // emulate single rounding at lane precision
                match e {
                    Elem::F32 => {
                        ((x as f32).mul_add(y as f32, s as f32)) as f64
                    }
                    _ => x.mul_add(y, s),
                }
            } else {
                // two roundings at lane precision
                match e {
                    Elem::F32 => ((x as f32 * y as f32) + s as f32) as f64,
                    Elem::F16 | Elem::BF16 => {
                        // round the product through the half type
                        let p = elem::to_f64(e, elem::from_f64(e, x * y));
                        p + s
                    }
                    _ => x * y + s,
                }
            };
            elem::from_f64(e, r)
        })
    } else {
        map3(ret, acc, a, b, move |s, x, y| {
            let p = elem::to_i64(e, x).wrapping_mul(elem::to_i64(e, y));
            let p = if sub { -p } else { p };
            elem::from_i64(e, elem::to_i64(e, s).wrapping_add(p))
        })
    }
}

fn minmax(ret: VecTy, e: Elem, args: &[Value], is_min: bool) -> VReg {
    let (a, b) = (args[0].v(), args[1].v());
    if e.is_float() {
        map2(ret, a, b, fop2(e, move |x, y| {
            // NEON fmin/fmax propagate NaN
            if x.is_nan() || y.is_nan() {
                f64::NAN
            } else if is_min {
                x.min(y)
            } else {
                x.max(y)
            }
        }))
    } else if e.is_signed() {
        map2(ret, a, b, iop2(e, move |x, y| if is_min { x.min(y) } else { x.max(y) }))
    } else {
        map2(ret, a, b, uop2(e, move |x, y| if is_min { x.min(y) } else { x.max(y) }))
    }
}

enum PairKind {
    Min,
    Max,
    Add,
}

/// D-form pairwise ops: result lane i comes from input pair (2i, 2i+1) of
/// the concatenation [a, b].
fn pairwise(ret: VecTy, e: Elem, args: &[Value], kind: PairKind) -> VReg {
    let (a, b) = (args[0].v(), args[1].v());
    let cat: Vec<u64> = a.lanes.iter().chain(&b.lanes).copied().collect();
    let lanes = (0..ret.lanes as usize)
        .map(|i| {
            let (x, y) = (cat[2 * i], cat[2 * i + 1]);
            match kind {
                PairKind::Add => {
                    if e.is_float() {
                        elem::from_f64(e, elem::to_f64(e, x) + elem::to_f64(e, y))
                    } else {
                        elem::from_i64(e, elem::to_i64(e, x).wrapping_add(elem::to_i64(e, y)))
                    }
                }
                PairKind::Min | PairKind::Max => {
                    let is_min = matches!(kind, PairKind::Min);
                    if e.is_float() {
                        let (fx, fy) = (elem::to_f64(e, x), elem::to_f64(e, y));
                        elem::from_f64(e, if is_min { fx.min(fy) } else { fx.max(fy) })
                    } else if e.is_signed() {
                        let (ix, iy) = (elem::to_i64(e, x), elem::to_i64(e, y));
                        elem::from_i64(e, if is_min { ix.min(iy) } else { ix.max(iy) })
                    } else {
                        let (ux, uy) = (elem::to_u64(e, x), elem::to_u64(e, y));
                        if is_min {
                            ux.min(uy)
                        } else {
                            ux.max(uy)
                        }
                    }
                }
            }
        })
        .collect();
    VReg::from_raw(ret, lanes)
}

fn saturating(ret: VecTy, e: Elem, args: &[Value], sub: bool) -> VReg {
    let (a, b) = (args[0].v(), args[1].v());
    map2(ret, a, b, move |x, y| {
        let (xi, yi) = if e.is_signed() {
            (elem::to_i64(e, x) as i128, elem::to_i64(e, y) as i128)
        } else {
            (elem::to_u64(e, x) as i128, elem::to_u64(e, y) as i128)
        };
        let r = if sub { xi - yi } else { xi + yi };
        elem::saturate(e, r)
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::neon::vreg::VecTy;

    fn q32(v: &[i64]) -> Value {
        Value::V(VReg::from_i64s(VecTy::q(Elem::I32), v))
    }

    fn qf(v: &[f32]) -> Value {
        Value::V(VReg::from_f32s(VecTy::q(Elem::F32), v))
    }

    #[test]
    fn vaddq_s32() {
        let op = NeonOp::new(Family::Add, Elem::I32, true);
        let r = eval(op, &[q32(&[1, 2, 3, 4]), q32(&[10, 20, 30, i32::MAX as i64])]);
        assert_eq!(r.as_i64s(), vec![11, 22, 33, (i32::MAX as i64 + 4) as i32 as i64]);
    }

    #[test]
    fn vfmaq_f32_is_fused() {
        let op = NeonOp::new(Family::Fma, Elem::F32, true);
        let acc = qf(&[1.0, 0.0, 0.0, 0.0]);
        let a = qf(&[1.0 + 1e-7, 2.0, 3.0, 4.0]);
        let b = qf(&[1.0 + 1e-7, 2.0, 3.0, 4.0]);
        let r = eval(op, &[acc, a, b]);
        let exact = (1.0f32 + 1e-7).mul_add(1.0 + 1e-7, 1.0);
        assert_eq!(r.as_f64s()[0] as f32, exact);
    }

    #[test]
    fn vqaddq_s8_saturates() {
        let op = NeonOp::new(Family::Qadd, Elem::I8, true);
        let a = VReg::from_i64s(VecTy::q(Elem::I8), &[100; 16]);
        let b = VReg::from_i64s(VecTy::q(Elem::I8), &[100; 16]);
        let r = eval(op, &[Value::V(a), Value::V(b)]);
        assert!(r.as_i64s().iter().all(|&x| x == 127));
    }

    #[test]
    fn vqsubq_u8_floors_at_zero() {
        let op = NeonOp::new(Family::Qsub, Elem::U8, true);
        let a = VReg::from_i64s(VecTy::q(Elem::U8), &[5; 16]);
        let b = VReg::from_i64s(VecTy::q(Elem::U8), &[9; 16]);
        let r = eval(op, &[Value::V(a), Value::V(b)]);
        assert!(r.as_u64s().iter().all(|&x| x == 0));
    }

    #[test]
    fn vpadd_s32() {
        let op = NeonOp::new(Family::Padd, Elem::I32, false);
        let a = VReg::from_i64s(VecTy::d(Elem::I32), &[1, 2]);
        let b = VReg::from_i64s(VecTy::d(Elem::I32), &[30, 40]);
        let r = eval(op, &[Value::V(a), Value::V(b)]);
        assert_eq!(r.as_i64s(), vec![3, 70]);
    }

    #[test]
    fn vmull_s16_widens() {
        let op = NeonOp::new(Family::Mull, Elem::I16, false);
        let a = VReg::from_i64s(VecTy::d(Elem::I16), &[-300, 2, 3, 4]);
        let b = VReg::from_i64s(VecTy::d(Elem::I16), &[300, 2, 3, 4]);
        let r = eval(op, &[Value::V(a), Value::V(b)]);
        assert_eq!(r.ty, VecTy::q(Elem::I32));
        assert_eq!(r.as_i64s(), vec![-90000, 4, 9, 16]);
    }

    #[test]
    fn vfmaq_lane_broadcasts() {
        let op = NeonOp::new(Family::FmaLane, Elem::F32, true);
        let acc = qf(&[0.0; 4]);
        let a = qf(&[1.0, 2.0, 3.0, 4.0]);
        let lane_src = Value::V(VReg::from_f32s(VecTy::d(Elem::F32), &[10.0, 20.0]));
        let r = eval(op, &[acc, a, lane_src, Value::Imm(1)]);
        assert_eq!(r.as_f64s(), vec![20.0, 40.0, 60.0, 80.0]);
    }

    #[test]
    fn vhaddq_no_overflow() {
        let op = NeonOp::new(Family::Hadd, Elem::I32, true);
        let a = q32(&[i32::MAX as i64; 4]);
        let b = q32(&[i32::MAX as i64; 4]);
        let r = eval(op, &[a, b]);
        assert_eq!(r.as_i64s(), vec![i32::MAX as i64; 4]);
    }

    #[test]
    fn vabdq_u8() {
        let op = NeonOp::new(Family::Abd, Elem::U8, true);
        let a = VReg::from_i64s(VecTy::q(Elem::U8), &[10; 16]);
        let b = VReg::from_i64s(VecTy::q(Elem::U8), &[250; 16]);
        let r = eval(op, &[Value::V(a), Value::V(b)]);
        assert!(r.as_u64s().iter().all(|&x| x == 240));
    }
}
