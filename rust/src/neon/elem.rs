//! Element (lane) types shared by the NEON and RVV semantic models.
//!
//! Lane values are stored as raw bit patterns (`u64`, low bits significant)
//! and interpreted through [`Elem`]: signed/unsigned integers of 8..64 bits,
//! IEEE binary16/32/64, bfloat16, and the NEON polynomial types (`p8`/`p16`/
//! `p64`, carry-less multiply domain — bit patterns only).

/// Lane element type. Mirrors the NEON base-type vocabulary of the paper's
/// Table 1 (`int`, `uint`, `float`, `poly`, `bfloat`).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Elem {
    I8,
    I16,
    I32,
    I64,
    U8,
    U16,
    U32,
    U64,
    F16,
    F32,
    F64,
    P8,
    P16,
    P64,
    BF16,
}

/// Return-base-type class used by the paper's Table 1 categorisation.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum BaseClass {
    Int,
    Uint,
    Float,
    Poly,
    Void,
    Bfloat,
}

impl BaseClass {
    pub fn name(self) -> &'static str {
        match self {
            BaseClass::Int => "int",
            BaseClass::Uint => "uint",
            BaseClass::Float => "float",
            BaseClass::Poly => "poly",
            BaseClass::Void => "void",
            BaseClass::Bfloat => "bfloat",
        }
    }
}

impl Elem {
    pub const ALL: [Elem; 15] = [
        Elem::I8,
        Elem::I16,
        Elem::I32,
        Elem::I64,
        Elem::U8,
        Elem::U16,
        Elem::U32,
        Elem::U64,
        Elem::F16,
        Elem::F32,
        Elem::F64,
        Elem::P8,
        Elem::P16,
        Elem::P64,
        Elem::BF16,
    ];

    /// Lane width in bits.
    pub fn bits(self) -> u32 {
        match self {
            Elem::I8 | Elem::U8 | Elem::P8 => 8,
            Elem::I16 | Elem::U16 | Elem::F16 | Elem::P16 | Elem::BF16 => 16,
            Elem::I32 | Elem::U32 | Elem::F32 => 32,
            Elem::I64 | Elem::U64 | Elem::F64 | Elem::P64 => 64,
        }
    }

    pub fn bytes(self) -> u32 {
        self.bits() / 8
    }

    pub fn is_float(self) -> bool {
        matches!(self, Elem::F16 | Elem::F32 | Elem::F64 | Elem::BF16)
    }

    pub fn is_signed(self) -> bool {
        matches!(self, Elem::I8 | Elem::I16 | Elem::I32 | Elem::I64)
    }

    pub fn is_unsigned(self) -> bool {
        matches!(self, Elem::U8 | Elem::U16 | Elem::U32 | Elem::U64)
    }

    pub fn is_poly(self) -> bool {
        matches!(self, Elem::P8 | Elem::P16 | Elem::P64)
    }

    /// NEON type-suffix, e.g. `s32` in `vaddq_s32`.
    pub fn suffix(self) -> &'static str {
        match self {
            Elem::I8 => "s8",
            Elem::I16 => "s16",
            Elem::I32 => "s32",
            Elem::I64 => "s64",
            Elem::U8 => "u8",
            Elem::U16 => "u16",
            Elem::U32 => "u32",
            Elem::U64 => "u64",
            Elem::F16 => "f16",
            Elem::F32 => "f32",
            Elem::F64 => "f64",
            Elem::P8 => "p8",
            Elem::P16 => "p16",
            Elem::P64 => "p64",
            Elem::BF16 => "bf16",
        }
    }

    /// NEON C type name, e.g. `int32` in `int32x4_t`.
    pub fn ctype(self) -> &'static str {
        match self {
            Elem::I8 => "int8",
            Elem::I16 => "int16",
            Elem::I32 => "int32",
            Elem::I64 => "int64",
            Elem::U8 => "uint8",
            Elem::U16 => "uint16",
            Elem::U32 => "uint32",
            Elem::U64 => "uint64",
            Elem::F16 => "float16",
            Elem::F32 => "float32",
            Elem::F64 => "float64",
            Elem::P8 => "poly8",
            Elem::P16 => "poly16",
            Elem::P64 => "poly64",
            Elem::BF16 => "bfloat16",
        }
    }

    /// Table 1 categorisation class.
    pub fn base_class(self) -> BaseClass {
        match self {
            Elem::I8 | Elem::I16 | Elem::I32 | Elem::I64 => BaseClass::Int,
            Elem::U8 | Elem::U16 | Elem::U32 | Elem::U64 => BaseClass::Uint,
            Elem::F16 | Elem::F32 | Elem::F64 => BaseClass::Float,
            Elem::P8 | Elem::P16 | Elem::P64 => BaseClass::Poly,
            Elem::BF16 => BaseClass::Bfloat,
        }
    }

    /// The unsigned integer element of the same width.
    pub fn as_unsigned(self) -> Elem {
        match self.bits() {
            8 => Elem::U8,
            16 => Elem::U16,
            32 => Elem::U32,
            _ => Elem::U64,
        }
    }

    /// The signed integer element of the same width.
    pub fn as_signed(self) -> Elem {
        match self.bits() {
            8 => Elem::I8,
            16 => Elem::I16,
            32 => Elem::I32,
            _ => Elem::I64,
        }
    }

    /// Widened element (for `vmovl`/`vmull`): same signedness, double width.
    pub fn widened(self) -> Option<Elem> {
        Some(match self {
            Elem::I8 => Elem::I16,
            Elem::I16 => Elem::I32,
            Elem::I32 => Elem::I64,
            Elem::U8 => Elem::U16,
            Elem::U16 => Elem::U32,
            Elem::U32 => Elem::U64,
            Elem::F16 => Elem::F32,
            Elem::F32 => Elem::F64,
            _ => return None,
        })
    }

    /// Narrowed element (for `vmovn`): same signedness, half width.
    pub fn narrowed(self) -> Option<Elem> {
        Some(match self {
            Elem::I16 => Elem::I8,
            Elem::I32 => Elem::I16,
            Elem::I64 => Elem::I32,
            Elem::U16 => Elem::U8,
            Elem::U32 => Elem::U16,
            Elem::U64 => Elem::U32,
            Elem::F32 => Elem::F16,
            Elem::F64 => Elem::F32,
            _ => return None,
        })
    }

    /// Mask of the significant low bits of a raw lane value.
    pub fn lane_mask(self) -> u64 {
        match self.bits() {
            64 => u64::MAX,
            b => (1u64 << b) - 1,
        }
    }
}

// ---------------------------------------------------------------------------
// Typed lane interpretation over raw bits.
// ---------------------------------------------------------------------------

/// Sign-extend the low `bits` of `raw` to i64.
pub fn sext(raw: u64, bits: u32) -> i64 {
    let sh = 64 - bits;
    ((raw << sh) as i64) >> sh
}

/// Interpret raw bits as a signed integer lane value.
pub fn to_i64(e: Elem, raw: u64) -> i64 {
    debug_assert!(!e.is_float());
    if e.is_signed() {
        sext(raw & e.lane_mask(), e.bits())
    } else {
        (raw & e.lane_mask()) as i64
    }
}

/// Interpret raw bits as an unsigned integer lane value.
pub fn to_u64(e: Elem, raw: u64) -> u64 {
    raw & e.lane_mask()
}

/// Interpret raw bits as a float lane value (f16/bf16 promoted to f64 via f32).
pub fn to_f64(e: Elem, raw: u64) -> f64 {
    match e {
        Elem::F16 => f16_to_f32((raw & 0xffff) as u16) as f64,
        Elem::BF16 => bf16_to_f32((raw & 0xffff) as u16) as f64,
        Elem::F32 => f32::from_bits(raw as u32) as f64,
        Elem::F64 => f64::from_bits(raw),
        _ => panic!("to_f64 on non-float elem {e:?}"),
    }
}

/// Encode a float value into the raw bits of a float lane.
pub fn from_f64(e: Elem, v: f64) -> u64 {
    match e {
        Elem::F16 => f32_to_f16(v as f32) as u64,
        Elem::BF16 => f32_to_bf16(v as f32) as u64,
        Elem::F32 => (v as f32).to_bits() as u64,
        Elem::F64 => v.to_bits(),
        _ => panic!("from_f64 on non-float elem {e:?}"),
    }
}

/// Encode an integer value into raw lane bits (two's complement truncation).
pub fn from_i64(e: Elem, v: i64) -> u64 {
    (v as u64) & e.lane_mask()
}

/// Saturate `v` into the representable range of integer elem `e`.
pub fn saturate(e: Elem, v: i128) -> u64 {
    let bits = e.bits();
    if e.is_signed() {
        let max = (1i128 << (bits - 1)) - 1;
        let min = -(1i128 << (bits - 1));
        from_i64(e, v.clamp(min, max) as i64)
    } else {
        let max = (1i128 << bits) - 1;
        (v.clamp(0, max) as u64) & e.lane_mask()
    }
}

// ---------------------------------------------------------------------------
// Software binary16 / bfloat16.
// ---------------------------------------------------------------------------

/// IEEE binary16 -> binary32 (bit-exact, handles subnormals/inf/nan).
pub fn f16_to_f32(h: u16) -> f32 {
    let sign = ((h >> 15) & 1) as u32;
    let exp = ((h >> 10) & 0x1f) as u32;
    let frac = (h & 0x3ff) as u32;
    let bits = if exp == 0 {
        if frac == 0 {
            sign << 31
        } else {
            // subnormal: normalise
            let mut e = 127 - 15 + 1;
            let mut f = frac;
            while f & 0x400 == 0 {
                f <<= 1;
                e -= 1;
            }
            (sign << 31) | ((e as u32) << 23) | ((f & 0x3ff) << 13)
        }
    } else if exp == 0x1f {
        (sign << 31) | (0xff << 23) | (frac << 13)
    } else {
        (sign << 31) | ((exp + 127 - 15) << 23) | (frac << 13)
    };
    f32::from_bits(bits)
}

/// binary32 -> IEEE binary16 with round-to-nearest-even.
pub fn f32_to_f16(f: f32) -> u16 {
    let bits = f.to_bits();
    let sign = ((bits >> 16) & 0x8000) as u16;
    let exp = ((bits >> 23) & 0xff) as i32;
    let frac = bits & 0x7f_ffff;
    if exp == 0xff {
        // inf / nan
        return sign | 0x7c00 | if frac != 0 { 0x200 } else { 0 };
    }
    let e = exp - 127 + 15;
    if e >= 0x1f {
        return sign | 0x7c00; // overflow -> inf
    }
    if e <= 0 {
        if e < -10 {
            return sign; // underflow -> zero
        }
        // subnormal result
        let m = frac | 0x80_0000;
        let shift = (14 - e) as u32;
        let half = m >> shift;
        let rem = m & ((1 << shift) - 1);
        let round = (rem > (1 << (shift - 1)))
            || (rem == (1 << (shift - 1)) && (half & 1) == 1);
        return sign | (half as u16 + round as u16);
    }
    let half = ((e as u32) << 10) | (frac >> 13);
    let rem = frac & 0x1fff;
    let round = (rem > 0x1000) || (rem == 0x1000 && (half & 1) == 1);
    sign | (half as u16 + round as u16)
}

/// bfloat16 -> binary32 (truncation inverse: hi 16 bits).
pub fn bf16_to_f32(h: u16) -> f32 {
    f32::from_bits((h as u32) << 16)
}

/// binary32 -> bfloat16 with round-to-nearest-even.
pub fn f32_to_bf16(f: f32) -> u16 {
    let bits = f.to_bits();
    if f.is_nan() {
        return ((bits >> 16) as u16) | 0x40; // quiet the nan
    }
    let round_bit = 0x8000u32;
    let lsb = (bits >> 16) & 1;
    let rounded = bits.wrapping_add(0x7fff + lsb);
    // overflow of the low half carries into the exponent, which is correct RNE
    let _ = round_bit;
    (rounded >> 16) as u16
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths() {
        assert_eq!(Elem::I8.bits(), 8);
        assert_eq!(Elem::F16.bits(), 16);
        assert_eq!(Elem::P64.bits(), 64);
        for e in Elem::ALL {
            assert_eq!(e.bytes() * 8, e.bits());
        }
    }

    #[test]
    fn classes() {
        assert_eq!(Elem::I32.base_class(), BaseClass::Int);
        assert_eq!(Elem::U8.base_class(), BaseClass::Uint);
        assert_eq!(Elem::F32.base_class(), BaseClass::Float);
        assert_eq!(Elem::P8.base_class(), BaseClass::Poly);
        assert_eq!(Elem::BF16.base_class(), BaseClass::Bfloat);
    }

    #[test]
    fn sign_extension() {
        assert_eq!(to_i64(Elem::I8, 0xff), -1);
        assert_eq!(to_i64(Elem::I8, 0x7f), 127);
        assert_eq!(to_i64(Elem::I16, 0x8000), -32768);
        assert_eq!(to_i64(Elem::U8, 0xff), 255);
    }

    #[test]
    fn saturation() {
        assert_eq!(to_i64(Elem::I8, saturate(Elem::I8, 300)), 127);
        assert_eq!(to_i64(Elem::I8, saturate(Elem::I8, -300)), -128);
        assert_eq!(to_u64(Elem::U8, saturate(Elem::U8, 300)), 255);
        assert_eq!(to_u64(Elem::U8, saturate(Elem::U8, -4)), 0);
        assert_eq!(to_i64(Elem::I16, saturate(Elem::I16, 12)), 12);
    }

    #[test]
    fn f16_roundtrip() {
        // (1e-5 is subnormal in binary16 — covered by f16_subnormals below)
        for v in [0.0f32, 1.0, -1.0, 0.5, 65504.0, -2.25, 3.140625] {
            let h = f32_to_f16(v);
            let back = f16_to_f32(h);
            let rel = if v == 0.0 {
                (back - v).abs()
            } else {
                ((back - v) / v).abs()
            };
            assert!(rel < 1e-3, "v={v} back={back}");
        }
        assert!(f16_to_f32(f32_to_f16(f32::NAN)).is_nan());
        assert_eq!(f16_to_f32(f32_to_f16(f32::INFINITY)), f32::INFINITY);
        // overflow saturates to inf
        assert_eq!(f16_to_f32(f32_to_f16(1e30)), f32::INFINITY);
    }

    #[test]
    fn f16_subnormals() {
        // smallest positive binary16 subnormal = 2^-24
        let tiny = 2.0f32.powi(-24);
        assert_eq!(f16_to_f32(f32_to_f16(tiny)), tiny);
        let h = f32_to_f16(2.0f32.powi(-25) * 1.5);
        assert!(f16_to_f32(h) > 0.0);
    }

    #[test]
    fn bf16_roundtrip() {
        for v in [0.0f32, 1.0, -3.5, 1234.0, 1e30, -1e-20] {
            let b = f32_to_bf16(v);
            let back = bf16_to_f32(b);
            let rel = if v == 0.0 {
                (back - v).abs()
            } else {
                ((back - v) / v).abs()
            };
            assert!(rel < 1e-2, "v={v} back={back}");
        }
        assert!(bf16_to_f32(f32_to_bf16(f32::NAN)).is_nan());
    }

    #[test]
    fn widen_narrow() {
        assert_eq!(Elem::I8.widened(), Some(Elem::I16));
        assert_eq!(Elem::U32.widened(), Some(Elem::U64));
        assert_eq!(Elem::I64.widened(), None);
        assert_eq!(Elem::I16.narrowed(), Some(Elem::I8));
        assert_eq!(Elem::F64.narrowed(), Some(Elem::F32));
        assert_eq!(Elem::I8.narrowed(), None);
    }
}
