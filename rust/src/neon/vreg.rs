//! NEON vector register values: fixed 64-bit (`D`) or 128-bit (`Q`) vectors
//! of typed lanes, stored as raw bit patterns.

use super::elem::{self, Elem};

/// A NEON vector *type*: element type + lane count. Total width must be 64
/// or 128 bits (the paper's §3.2: "Neon Intrinsics types have lengths of 64
/// bits and 128 bits").
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VecTy {
    pub elem: Elem,
    pub lanes: u8,
}

impl VecTy {
    pub fn new(elem: Elem, lanes: u8) -> VecTy {
        let t = VecTy { elem, lanes };
        debug_assert!(t.bits() == 64 || t.bits() == 128, "bad NEON vector {t:?}");
        t
    }

    /// 64-bit ("doubleword") vector of `elem`.
    pub fn d(elem: Elem) -> VecTy {
        VecTy::new(elem, (64 / elem.bits()) as u8)
    }

    /// 128-bit ("quadword") vector of `elem`.
    pub fn q(elem: Elem) -> VecTy {
        VecTy::new(elem, (128 / elem.bits()) as u8)
    }

    /// `elem` vector of the given register width in bits.
    pub fn of_bits(elem: Elem, bits: u32) -> VecTy {
        match bits {
            64 => VecTy::d(elem),
            128 => VecTy::q(elem),
            _ => panic!("NEON vectors are 64 or 128 bits, got {bits}"),
        }
    }

    pub fn bits(self) -> u32 {
        self.elem.bits() * self.lanes as u32
    }

    pub fn is_q(self) -> bool {
        self.bits() == 128
    }

    /// NEON C type name, e.g. `int32x4_t`.
    pub fn name(self) -> String {
        format!("{}x{}_t", self.elem.ctype(), self.lanes)
    }
}

/// A NEON vector *value*: lanes as raw bits (low `elem.bits()` significant).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VReg {
    pub ty: VecTy,
    pub lanes: Vec<u64>,
}

impl VReg {
    pub fn zero(ty: VecTy) -> VReg {
        VReg { ty, lanes: vec![0; ty.lanes as usize] }
    }

    pub fn from_raw(ty: VecTy, lanes: Vec<u64>) -> VReg {
        assert_eq!(lanes.len(), ty.lanes as usize);
        let mask = ty.elem.lane_mask();
        VReg { ty, lanes: lanes.into_iter().map(|l| l & mask).collect() }
    }

    pub fn splat_raw(ty: VecTy, raw: u64) -> VReg {
        VReg::from_raw(ty, vec![raw; ty.lanes as usize])
    }

    pub fn from_f32s(ty: VecTy, vals: &[f32]) -> VReg {
        assert_eq!(ty.elem, Elem::F32);
        VReg::from_raw(ty, vals.iter().map(|v| v.to_bits() as u64).collect())
    }

    pub fn from_i64s(ty: VecTy, vals: &[i64]) -> VReg {
        VReg::from_raw(ty, vals.iter().map(|&v| elem::from_i64(ty.elem, v)).collect())
    }

    pub fn lane(&self, i: usize) -> u64 {
        self.lanes[i]
    }

    pub fn set_lane(&mut self, i: usize, raw: u64) {
        self.lanes[i] = raw & self.ty.elem.lane_mask();
    }

    pub fn as_f64s(&self) -> Vec<f64> {
        self.lanes.iter().map(|&l| elem::to_f64(self.ty.elem, l)).collect()
    }

    pub fn as_i64s(&self) -> Vec<i64> {
        self.lanes.iter().map(|&l| elem::to_i64(self.ty.elem, l)).collect()
    }

    pub fn as_u64s(&self) -> Vec<u64> {
        self.lanes.iter().map(|&l| elem::to_u64(self.ty.elem, l)).collect()
    }

    /// Serialise to little-endian bytes (the in-memory layout of st1).
    pub fn to_bytes(&self) -> Vec<u8> {
        let w = self.ty.elem.bytes() as usize;
        let mut out = Vec::with_capacity(self.ty.bits() as usize / 8);
        for &l in &self.lanes {
            out.extend_from_slice(&l.to_le_bytes()[..w]);
        }
        out
    }

    /// Deserialise from little-endian bytes (the in-memory layout of ld1).
    pub fn from_bytes(ty: VecTy, bytes: &[u8]) -> VReg {
        let w = ty.elem.bytes() as usize;
        assert_eq!(bytes.len(), ty.lanes as usize * w);
        let lanes = bytes
            .chunks_exact(w)
            .map(|c| {
                let mut buf = [0u8; 8];
                buf[..w].copy_from_slice(c);
                u64::from_le_bytes(buf)
            })
            .collect();
        VReg { ty, lanes }
    }

    /// Reinterpret the same 64/128 bits as a different lane layout
    /// (`vreinterpret`).
    pub fn reinterpret(&self, to: VecTy) -> VReg {
        assert_eq!(self.ty.bits(), to.bits(), "reinterpret width mismatch");
        VReg::from_bytes(to, &self.to_bytes())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vecty_names() {
        assert_eq!(VecTy::q(Elem::I32).name(), "int32x4_t");
        assert_eq!(VecTy::d(Elem::I32).name(), "int32x2_t");
        assert_eq!(VecTy::q(Elem::U8).name(), "uint8x16_t");
        assert_eq!(VecTy::q(Elem::F16).name(), "float16x8_t");
        assert_eq!(VecTy::d(Elem::P64).name(), "poly64x1_t");
    }

    #[test]
    fn lane_roundtrip() {
        let v = VReg::from_i64s(VecTy::q(Elem::I32), &[1, -2, 3, -4]);
        assert_eq!(v.as_i64s(), vec![1, -2, 3, -4]);
        let b = v.to_bytes();
        assert_eq!(b.len(), 16);
        assert_eq!(VReg::from_bytes(VecTy::q(Elem::I32), &b), v);
    }

    #[test]
    fn reinterpret_preserves_bits() {
        let v = VReg::from_i64s(VecTy::q(Elem::I32), &[0x01020304, 0, -1, 7]);
        let u8v = v.reinterpret(VecTy::q(Elem::U8));
        assert_eq!(u8v.as_u64s()[..4], [4, 3, 2, 1]);
        let back = u8v.reinterpret(VecTy::q(Elem::I32));
        assert_eq!(back, v);
    }

    #[test]
    #[should_panic]
    fn reinterpret_width_mismatch_panics() {
        let v = VReg::zero(VecTy::d(Elem::I8));
        let _ = v.reinterpret(VecTy::q(Elem::I8));
    }
}
