//! ARM NEON semantic model: element/vector types, the intrinsic family
//! grid, executable lane semantics, the golden-reference interpreter, and
//! the full-surface catalog behind the paper's Table 1.

pub mod catalog;
pub mod elem;
pub mod interp;
pub mod ops;
pub mod semantics;
pub mod vreg;
