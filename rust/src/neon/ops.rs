//! NEON intrinsic *families* and their instantiation grid.
//!
//! A concrete NEON intrinsic (e.g. `vaddq_s32`) is a [`NeonOp`]: a
//! [`Family`] (`Add`) instantiated at an element type (`s32`) and a register
//! width (`q` = 128-bit). Families carry their signature schema so the
//! interpreter, the translation engine, and the catalog generator all agree
//! on argument/return types.

use super::elem::Elem;
use super::vreg::VecTy;

/// Intrinsic family. Names follow the ACLE `v<base>{q}_<type>` convention.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    // -- memory ------------------------------------------------------------
    /// `vld1{q}_T(ptr)` — contiguous load.
    Ld1,
    /// `vld1{q}_dup_T(ptr)` — load one element, broadcast.
    Ld1Dup,
    /// `vld1{q}_lane_T(ptr, v, lane)` — load one element into a lane.
    Ld1Lane,
    /// `vst1{q}_T(ptr, v)` — contiguous store.
    St1,
    /// `vst1{q}_lane_T(ptr, v, lane)` — store one lane.
    St1Lane,

    // -- arithmetic ---------------------------------------------------------
    Add,
    Sub,
    Mul,
    /// `vmla{q}` — `a + b*c`, not fused.
    Mla,
    /// `vmls{q}` — `a - b*c`, not fused.
    Mls,
    /// `vfma{q}` — fused multiply-add (float only).
    Fma,
    /// `vfms{q}` — fused multiply-subtract (float only).
    Fms,
    /// `vdiv{q}` — float divide (A64).
    Div,
    Abs,
    Neg,
    Min,
    Max,
    /// pairwise min/max/add over concatenated inputs (D-form binary).
    Pmin,
    Pmax,
    Padd,
    /// halving add `(a+b)>>1` without overflow.
    Hadd,
    /// rounding halving add `(a+b+1)>>1`.
    Rhadd,
    /// saturating add/sub.
    Qadd,
    Qsub,
    /// absolute difference `|a-b|`.
    Abd,

    // -- by-lane forms (gemm microkernels) -----------------------------------
    /// `vmul{q}_lane_T(a, b, lane)`.
    MulLane,
    /// `vmla{q}_lane_T(acc, a, b, lane)`.
    MlaLane,
    /// `vfma{q}_lane_T(acc, a, b, lane)` (float, fused).
    FmaLane,

    // -- widening multiplies --------------------------------------------------
    /// `vmull_T(d, d) -> q` widening multiply.
    Mull,
    /// `vmlal_T(qacc, d, d) -> q` widening multiply-accumulate.
    Mlal,

    // -- comparisons (result: all-ones / all-zeros unsigned lanes) -----------
    Ceq,
    Cge,
    Cgt,
    Cle,
    Clt,
    /// `vceqz{q}` — compare equal to zero.
    Ceqz,
    /// `vtst{q}` — `(a & b) != 0`.
    Tst,

    // -- bitwise -------------------------------------------------------------
    And,
    Orr,
    Eor,
    /// `vbic{q}` — `a & ~b`.
    Bic,
    /// `vorn{q}` — `a | ~b`.
    Orn,
    Mvn,
    /// `vbsl{q}(mask, a, b)` — bit select.
    Bsl,

    // -- shifts ---------------------------------------------------------------
    /// `vshl{q}_n` — left shift by immediate.
    ShlN,
    /// `vshr{q}_n` — right shift by immediate (arithmetic for signed).
    ShrN,
    /// `vsli{q}_n` — shift left and insert.
    SliN,
    /// `vsri{q}_n` — shift right and insert.
    SriN,
    /// `vshl{q}` — shift by signed vector (negative = right).
    Sshl,
    /// `vshrn_n` — narrowing right shift (q source, d result).
    ShrnN,

    // -- permutes --------------------------------------------------------------
    /// `vget_low_T(q) -> d`.
    GetLow,
    /// `vget_high_T(q) -> d` (paper Listing 5).
    GetHigh,
    /// `vcombine_T(d, d) -> q`.
    Combine,
    /// `vext{q}_T(a, b, n)` — extract window.
    Ext,
    Rev64,
    Rev32,
    Rev16,
    Zip1,
    Zip2,
    Uzp1,
    Uzp2,
    Trn1,
    Trn2,
    /// `vdup{q}_lane_T(d, lane)` — broadcast a lane of a D vector.
    DupLane,
    /// `vdup{q}_n_T(scalar)` — broadcast an (integer-typed IR) scalar/imm.
    DupN,
    /// `vtbl1_u8(table, idx)` — byte table lookup (D form).
    Tbl1,

    // -- widen / narrow -----------------------------------------------------
    /// `vmovl_T(d) -> q` widen.
    Movl,
    /// `vmovn_T(q) -> d` narrow (truncate).
    Movn,
    /// saturating narrow.
    Qmovn,
    /// saturating narrow signed->unsigned.
    Qmovun,

    // -- conversions -----------------------------------------------------------
    /// `vcvt{q}_f32_s32` etc. — int -> float (elem = source int type).
    CvtIF,
    /// `vcvt{q}_s32_f32` etc. — float -> int, truncate toward zero.
    CvtFI,
    /// `vcvtn{q}_s32_f32` — float -> int, round to nearest even (A64).
    CvtnFI,
    /// `vreinterpret{q}` — bit cast (elem = destination type; src in args).
    Reinterpret,

    // -- float estimates / rounding -----------------------------------------
    /// `vrecpe{q}` — reciprocal estimate.
    Recpe,
    /// `vrecps{q}` — reciprocal Newton step `2 - a*b`.
    Recps,
    /// `vrsqrte{q}` — reciprocal sqrt estimate.
    Rsqrte,
    /// `vrsqrts{q}` — rsqrt Newton step `(3 - a*b)/2`.
    Rsqrts,
    /// `vsqrt{q}` — exact sqrt (A64).
    Sqrt,
    /// `vrndn{q}` — round to nearest even.
    Rndn,

    // -- misc bit ops (paper Listing 7) ---------------------------------------
    /// `vrbit{q}` — reverse bits within each byte... NEON semantics:
    /// reverses the bits of each 8-bit element (defined on 8-bit types).
    Rbit,
    /// count leading zeros per lane.
    Clz,
    /// popcount per lane (8-bit types).
    Cnt,
}

/// Argument type schema for one concrete intrinsic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ArgTy {
    /// Vector argument of the given type.
    V(VecTy),
    /// Pointer to elements of the given type (loads/stores).
    Ptr(Elem),
    /// Integer immediate (lane index, shift amount, ext offset).
    Imm,
    /// Integer scalar from an IR scalar register (vdupq_n of loop-derived
    /// values); float `_n_` forms are expressed via `Ld1Dup` instead.
    ScalarInt,
}

/// Full signature of a concrete intrinsic.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Sig {
    pub args: Vec<ArgTy>,
    pub ret: Option<VecTy>,
}

/// A concrete NEON intrinsic: family × element type × register width.
///
/// `elem`/`q` describe the *name suffix*: e.g. `vmovn_s16` has
/// `elem = I16` (the source type) and the signature derives the rest.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct NeonOp {
    pub family: Family,
    pub elem: Elem,
    pub q: bool,
}

impl NeonOp {
    pub fn new(family: Family, elem: Elem, q: bool) -> NeonOp {
        NeonOp { family, elem, q }
    }

    /// Register width in bits of the *named* type.
    pub fn bits(self) -> u32 {
        if self.q {
            128
        } else {
            64
        }
    }

    /// The vector type named by the suffix (e.g. `int32x4_t` for `..q_s32`).
    pub fn vt(self) -> VecTy {
        VecTy::of_bits(self.elem, self.bits())
    }

    /// ACLE-style rendered name, e.g. `vaddq_s32`, `vget_high_s32`,
    /// `vcvtq_f32_s32`.
    pub fn name(self) -> String {
        let q = if self.q { "q" } else { "" };
        let s = self.elem.suffix();
        match self.family {
            Family::Ld1 => format!("vld1{q}_{s}"),
            Family::Ld1Dup => format!("vld1{q}_dup_{s}"),
            Family::Ld1Lane => format!("vld1{q}_lane_{s}"),
            Family::St1 => format!("vst1{q}_{s}"),
            Family::St1Lane => format!("vst1{q}_lane_{s}"),
            Family::Add => format!("vadd{q}_{s}"),
            Family::Sub => format!("vsub{q}_{s}"),
            Family::Mul => format!("vmul{q}_{s}"),
            Family::Mla => format!("vmla{q}_{s}"),
            Family::Mls => format!("vmls{q}_{s}"),
            Family::Fma => format!("vfma{q}_{s}"),
            Family::Fms => format!("vfms{q}_{s}"),
            Family::Div => format!("vdiv{q}_{s}"),
            Family::Abs => format!("vabs{q}_{s}"),
            Family::Neg => format!("vneg{q}_{s}"),
            Family::Min => format!("vmin{q}_{s}"),
            Family::Max => format!("vmax{q}_{s}"),
            Family::Pmin => format!("vpmin_{s}"),
            Family::Pmax => format!("vpmax_{s}"),
            Family::Padd => format!("vpadd_{s}"),
            Family::Hadd => format!("vhadd{q}_{s}"),
            Family::Rhadd => format!("vrhadd{q}_{s}"),
            Family::Qadd => format!("vqadd{q}_{s}"),
            Family::Qsub => format!("vqsub{q}_{s}"),
            Family::Abd => format!("vabd{q}_{s}"),
            Family::MulLane => format!("vmul{q}_lane_{s}"),
            Family::MlaLane => format!("vmla{q}_lane_{s}"),
            Family::FmaLane => format!("vfma{q}_lane_{s}"),
            Family::Mull => format!("vmull_{s}"),
            Family::Mlal => format!("vmlal_{s}"),
            Family::Ceq => format!("vceq{q}_{s}"),
            Family::Cge => format!("vcge{q}_{s}"),
            Family::Cgt => format!("vcgt{q}_{s}"),
            Family::Cle => format!("vcle{q}_{s}"),
            Family::Clt => format!("vclt{q}_{s}"),
            Family::Ceqz => format!("vceqz{q}_{s}"),
            Family::Tst => format!("vtst{q}_{s}"),
            Family::And => format!("vand{q}_{s}"),
            Family::Orr => format!("vorr{q}_{s}"),
            Family::Eor => format!("veor{q}_{s}"),
            Family::Bic => format!("vbic{q}_{s}"),
            Family::Orn => format!("vorn{q}_{s}"),
            Family::Mvn => format!("vmvn{q}_{s}"),
            Family::Bsl => format!("vbsl{q}_{s}"),
            Family::ShlN => format!("vshl{q}_n_{s}"),
            Family::ShrN => format!("vshr{q}_n_{s}"),
            Family::SliN => format!("vsli{q}_n_{s}"),
            Family::SriN => format!("vsri{q}_n_{s}"),
            Family::Sshl => format!("vshl{q}_{s}"),
            Family::ShrnN => format!("vshrn_n_{s}"),
            Family::GetLow => format!("vget_low_{s}"),
            Family::GetHigh => format!("vget_high_{s}"),
            Family::Combine => format!("vcombine_{s}"),
            Family::Ext => format!("vext{q}_{s}"),
            Family::Rev64 => format!("vrev64{q}_{s}"),
            Family::Rev32 => format!("vrev32{q}_{s}"),
            Family::Rev16 => format!("vrev16{q}_{s}"),
            Family::Zip1 => format!("vzip1{q}_{s}"),
            Family::Zip2 => format!("vzip2{q}_{s}"),
            Family::Uzp1 => format!("vuzp1{q}_{s}"),
            Family::Uzp2 => format!("vuzp2{q}_{s}"),
            Family::Trn1 => format!("vtrn1{q}_{s}"),
            Family::Trn2 => format!("vtrn2{q}_{s}"),
            Family::DupLane => format!("vdup{q}_lane_{s}"),
            Family::DupN => format!("vdup{q}_n_{s}"),
            Family::Tbl1 => format!("vtbl1_{s}"),
            Family::Movl => format!("vmovl_{s}"),
            Family::Movn => format!("vmovn_{s}"),
            Family::Qmovn => format!("vqmovn_{s}"),
            Family::Qmovun => format!("vqmovun_{s}"),
            Family::CvtIF => {
                let fs = self.float_of_same_width().suffix();
                format!("vcvt{q}_{fs}_{s}")
            }
            Family::CvtFI => {
                let is = self.int_of_same_width().suffix();
                format!("vcvt{q}_{is}_{s}")
            }
            Family::CvtnFI => {
                let is = self.int_of_same_width().suffix();
                format!("vcvtn{q}_{is}_{s}")
            }
            Family::Reinterpret => format!("vreinterpret{q}_{s}"),
            Family::Recpe => format!("vrecpe{q}_{s}"),
            Family::Recps => format!("vrecps{q}_{s}"),
            Family::Rsqrte => format!("vrsqrte{q}_{s}"),
            Family::Rsqrts => format!("vrsqrts{q}_{s}"),
            Family::Sqrt => format!("vsqrt{q}_{s}"),
            Family::Rndn => format!("vrndn{q}_{s}"),
            Family::Rbit => format!("vrbit{q}_{s}"),
            Family::Clz => format!("vclz{q}_{s}"),
            Family::Cnt => format!("vcnt{q}_{s}"),
        }
    }

    /// For `CvtIF` (elem = int source): the float elem of the same width.
    pub fn float_of_same_width(self) -> Elem {
        match self.elem.bits() {
            16 => Elem::F16,
            32 => Elem::F32,
            64 => Elem::F64,
            b => panic!("no float of width {b}"),
        }
    }

    /// For `CvtFI`/`CvtnFI` (elem = float source): signed int of same width.
    pub fn int_of_same_width(self) -> Elem {
        self.elem.as_signed()
    }

    /// Signature of this concrete intrinsic. Panics if the instantiation is
    /// invalid (checked by [`NeonOp::is_valid`]).
    pub fn sig(self) -> Sig {
        use ArgTy::*;
        let vt = self.vt();
        let d = VecTy::d(self.elem);
        let v2 = |n| vec![V(vt); n];
        let bin = Sig { args: v2(2), ret: Some(vt) };
        let un = Sig { args: v2(1), ret: Some(vt) };
        let cmp_ret = VecTy::of_bits(self.elem.as_unsigned(), self.bits());
        match self.family {
            Family::Ld1 | Family::Ld1Dup => {
                Sig { args: vec![Ptr(self.elem)], ret: Some(vt) }
            }
            Family::Ld1Lane => {
                Sig { args: vec![Ptr(self.elem), V(vt), Imm], ret: Some(vt) }
            }
            Family::St1 => Sig { args: vec![Ptr(self.elem), V(vt)], ret: None },
            Family::St1Lane => {
                Sig { args: vec![Ptr(self.elem), V(vt), Imm], ret: None }
            }
            Family::Add
            | Family::Sub
            | Family::Mul
            | Family::Div
            | Family::Min
            | Family::Max
            | Family::Hadd
            | Family::Rhadd
            | Family::Qadd
            | Family::Qsub
            | Family::Abd
            | Family::And
            | Family::Orr
            | Family::Eor
            | Family::Bic
            | Family::Orn
            | Family::Sshl
            | Family::Recps
            | Family::Rsqrts
            | Family::Pmin
            | Family::Pmax
            | Family::Padd => bin,
            Family::Mla | Family::Mls | Family::Fma | Family::Fms => {
                Sig { args: v2(3), ret: Some(vt) }
            }
            Family::Abs
            | Family::Neg
            | Family::Mvn
            | Family::Rev64
            | Family::Rev32
            | Family::Rev16
            | Family::Recpe
            | Family::Rsqrte
            | Family::Sqrt
            | Family::Rndn
            | Family::Rbit
            | Family::Clz
            | Family::Cnt => un,
            Family::MulLane => {
                Sig { args: vec![V(vt), V(d), Imm], ret: Some(vt) }
            }
            Family::MlaLane | Family::FmaLane => {
                Sig { args: vec![V(vt), V(vt), V(d), Imm], ret: Some(vt) }
            }
            Family::Mull => {
                let wide = VecTy::q(self.elem.widened().unwrap());
                Sig { args: vec![V(d), V(d)], ret: Some(wide) }
            }
            Family::Mlal => {
                let wide = VecTy::q(self.elem.widened().unwrap());
                Sig { args: vec![V(wide), V(d), V(d)], ret: Some(wide) }
            }
            Family::Ceq | Family::Cge | Family::Cgt | Family::Cle
            | Family::Clt | Family::Tst => {
                Sig { args: v2(2), ret: Some(cmp_ret) }
            }
            Family::Ceqz => Sig { args: v2(1), ret: Some(cmp_ret) },
            Family::Bsl => {
                // mask is unsigned of same layout
                Sig { args: vec![V(cmp_ret), V(vt), V(vt)], ret: Some(vt) }
            }
            Family::ShlN | Family::ShrN | Family::SliN | Family::SriN => {
                let mut args = v2(1);
                if matches!(self.family, Family::SliN | Family::SriN) {
                    args = v2(2);
                }
                args.push(Imm);
                Sig { args, ret: Some(vt) }
            }
            Family::ShrnN => {
                let src = VecTy::q(self.elem);
                let narrow = VecTy::d(self.elem.narrowed().unwrap());
                Sig { args: vec![V(src), Imm], ret: Some(narrow) }
            }
            Family::GetLow | Family::GetHigh => {
                Sig { args: vec![V(VecTy::q(self.elem))], ret: Some(d) }
            }
            Family::Combine => {
                Sig { args: vec![V(d), V(d)], ret: Some(VecTy::q(self.elem)) }
            }
            Family::Ext => Sig { args: vec![V(vt), V(vt), Imm], ret: Some(vt) },
            Family::Zip1 | Family::Zip2 | Family::Uzp1 | Family::Uzp2
            | Family::Trn1 | Family::Trn2 => bin,
            Family::DupLane => Sig { args: vec![V(d), Imm], ret: Some(vt) },
            Family::DupN => Sig { args: vec![ScalarInt], ret: Some(vt) },
            Family::Tbl1 => {
                let du8 = VecTy::d(Elem::U8);
                Sig { args: vec![V(du8), V(du8)], ret: Some(du8) }
            }
            Family::Movl => {
                let wide = VecTy::q(self.elem.widened().unwrap());
                Sig { args: vec![V(d)], ret: Some(wide) }
            }
            Family::Movn | Family::Qmovn => {
                let src = VecTy::q(self.elem);
                let narrow = VecTy::d(self.elem.narrowed().unwrap());
                Sig { args: vec![V(src)], ret: Some(narrow) }
            }
            Family::Qmovun => {
                let src = VecTy::q(self.elem);
                let narrow = VecTy::d(self.elem.narrowed().unwrap().as_unsigned());
                Sig { args: vec![V(src)], ret: Some(narrow) }
            }
            Family::CvtIF => {
                let f = VecTy::of_bits(self.float_of_same_width(), self.bits());
                Sig { args: vec![V(vt)], ret: Some(f) }
            }
            Family::CvtFI | Family::CvtnFI => {
                let to = if self.elem.is_float() {
                    self.int_of_same_width()
                } else {
                    panic!("CvtFI elem must be float")
                };
                Sig { args: vec![V(vt)], ret: Some(VecTy::of_bits(to, self.bits())) }
            }
            Family::Reinterpret => {
                // source type supplied by the IR; nominal arg is same width
                Sig { args: vec![V(vt)], ret: Some(vt) }
            }
        }
    }

    /// Whether (family, elem, q) is a meaningful NEON intrinsic.
    pub fn is_valid(self) -> bool {
        let e = self.elem;
        match self.family {
            Family::Fma | Family::Fms | Family::Div | Family::Sqrt
            | Family::Rndn | Family::Recpe | Family::Recps | Family::Rsqrte
            | Family::Rsqrts | Family::FmaLane => {
                matches!(e, Elem::F16 | Elem::F32 | Elem::F64)
            }
            Family::CvtFI | Family::CvtnFI => matches!(e, Elem::F32 | Elem::F64 | Elem::F16),
            Family::CvtIF => {
                matches!(e, Elem::I16 | Elem::I32 | Elem::I64 | Elem::U16 | Elem::U32 | Elem::U64)
            }
            Family::Mla | Family::Mls | Family::Mul => {
                !e.is_poly() && e != Elem::BF16 && !matches!(e, Elem::I64 | Elem::U64)
                    || matches!(e, Elem::F64)
            }
            Family::MulLane | Family::MlaLane => {
                matches!(e, Elem::I16 | Elem::I32 | Elem::U16 | Elem::U32 | Elem::F32 | Elem::F16)
            }
            Family::Mull | Family::Mlal => {
                matches!(e, Elem::I8 | Elem::I16 | Elem::I32 | Elem::U8 | Elem::U16 | Elem::U32)
            }
            Family::Movl => matches!(
                e,
                Elem::I8 | Elem::I16 | Elem::I32 | Elem::U8 | Elem::U16 | Elem::U32
            ),
            Family::Movn | Family::Qmovn => matches!(
                e,
                Elem::I16 | Elem::I32 | Elem::I64 | Elem::U16 | Elem::U32 | Elem::U64
            ),
            Family::Qmovun => matches!(e, Elem::I16 | Elem::I32 | Elem::I64),
            Family::Hadd | Family::Rhadd => {
                matches!(e, Elem::I8 | Elem::I16 | Elem::I32 | Elem::U8 | Elem::U16 | Elem::U32)
            }
            Family::Qadd | Family::Qsub => !e.is_float() && !e.is_poly() && e != Elem::BF16,
            Family::Abd => {
                matches!(e, Elem::I8 | Elem::I16 | Elem::I32 | Elem::U8 | Elem::U16 | Elem::U32 | Elem::F32 | Elem::F16)
            }
            Family::Abs | Family::Neg => e.is_signed() || e.is_float(),
            Family::Min | Family::Max => {
                !e.is_poly() && e != Elem::BF16 && !matches!(e, Elem::I64 | Elem::U64)
                    || matches!(e, Elem::F64)
            }
            // D-form pairwise: a 64-bit register must hold at least one
            // *pair*, so 64-bit elements are invalid
            Family::Pmin | Family::Pmax | Family::Padd => {
                !e.is_poly() && e != Elem::BF16 && e.bits() < 64 && !self.q
            }
            Family::And | Family::Orr | Family::Eor | Family::Bic
            | Family::Orn | Family::Mvn | Family::Tst => !e.is_float() && e != Elem::BF16 && !matches!(e, Elem::P16 | Elem::P64),
            Family::Ceq | Family::Cge | Family::Cgt | Family::Cle
            | Family::Clt | Family::Ceqz => !e.is_poly() && e != Elem::BF16,
            Family::Bsl => e != Elem::BF16,
            Family::ShlN | Family::ShrN | Family::Sshl => !e.is_float() && !e.is_poly() && e != Elem::BF16,
            Family::SliN | Family::SriN => !e.is_float() && e != Elem::BF16 && !matches!(e, Elem::P16 | Elem::P64),
            Family::ShrnN => {
                matches!(e, Elem::I16 | Elem::I32 | Elem::I64 | Elem::U16 | Elem::U32 | Elem::U64)
            }
            Family::Rev64 => e.bits() < 64,
            Family::Rev32 => e.bits() < 32,
            Family::Rev16 => e.bits() < 16,
            Family::Rbit | Family::Cnt => matches!(e, Elem::I8 | Elem::U8 | Elem::P8),
            Family::Clz => {
                matches!(e, Elem::I8 | Elem::I16 | Elem::I32 | Elem::U8 | Elem::U16 | Elem::U32)
            }
            Family::Tbl1 => matches!(e, Elem::U8) && !self.q,
            // interleaves need at least one pair per register
            Family::Zip1 | Family::Zip2 | Family::Uzp1 | Family::Uzp2
            | Family::Trn1 | Family::Trn2 => {
                e != Elem::BF16 && !e.is_poly() && (self.q || e.bits() < 64)
            }
            Family::GetLow | Family::GetHigh | Family::Combine => e != Elem::BF16,
            Family::Ld1Lane | Family::St1Lane | Family::DupLane => e != Elem::BF16,
            _ => true,
        }
    }

    /// Broad category, used by rule tables and the cost model.
    pub fn category(self) -> Category {
        use Family::*;
        match self.family {
            Ld1 | Ld1Dup | Ld1Lane | St1 | St1Lane => Category::Memory,
            Add | Sub | Mul | Mla | Mls | Fma | Fms | Div | Abs | Neg | Min
            | Max | Hadd | Rhadd | Abd | MulLane | MlaLane | FmaLane => {
                Category::Arith
            }
            Pmin | Pmax | Padd => Category::Pairwise,
            Qadd | Qsub | Qmovn | Qmovun => Category::Saturating,
            Mull | Mlal | Movl | Movn | ShrnN => Category::WidenNarrow,
            Ceq | Cge | Cgt | Cle | Clt | Ceqz | Tst => Category::Compare,
            And | Orr | Eor | Bic | Orn | Mvn | Bsl => Category::Bitwise,
            ShlN | ShrN | SliN | SriN | Sshl => Category::Shift,
            GetLow | GetHigh | Combine | Ext | Rev64 | Rev32 | Rev16 | Zip1
            | Zip2 | Uzp1 | Uzp2 | Trn1 | Trn2 | DupLane | DupN | Tbl1 => {
                Category::Permute
            }
            CvtIF | CvtFI | CvtnFI | Reinterpret => Category::Convert,
            Recpe | Recps | Rsqrte | Rsqrts | Sqrt | Rndn => Category::FloatEst,
            Rbit | Clz | Cnt => Category::BitManip,
        }
    }
}

/// Conversion-relevant intrinsic category (drives rule tables and the
/// baseline cost model, §3.3 of the paper).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Category {
    Memory,
    Arith,
    Pairwise,
    Saturating,
    WidenNarrow,
    Compare,
    Bitwise,
    Shift,
    Permute,
    Convert,
    FloatEst,
    BitManip,
}

/// All families, for grid enumeration.
pub const ALL_FAMILIES: [Family; 83] = [
    Family::Ld1,
    Family::Ld1Dup,
    Family::Ld1Lane,
    Family::St1,
    Family::St1Lane,
    Family::Add,
    Family::Sub,
    Family::Mul,
    Family::Mla,
    Family::Mls,
    Family::Fma,
    Family::Fms,
    Family::Div,
    Family::Abs,
    Family::Neg,
    Family::Min,
    Family::Max,
    Family::Pmin,
    Family::Pmax,
    Family::Padd,
    Family::Hadd,
    Family::Rhadd,
    Family::Qadd,
    Family::Qsub,
    Family::Abd,
    Family::MulLane,
    Family::MlaLane,
    Family::FmaLane,
    Family::Mull,
    Family::Mlal,
    Family::Ceq,
    Family::Cge,
    Family::Cgt,
    Family::Cle,
    Family::Clt,
    Family::Ceqz,
    Family::Tst,
    Family::And,
    Family::Orr,
    Family::Eor,
    Family::Bic,
    Family::Orn,
    Family::Mvn,
    Family::Bsl,
    Family::ShlN,
    Family::ShrN,
    Family::SliN,
    Family::SriN,
    Family::Sshl,
    Family::ShrnN,
    Family::GetLow,
    Family::GetHigh,
    Family::Combine,
    Family::Ext,
    Family::Rev64,
    Family::Rev32,
    Family::Rev16,
    Family::Zip1,
    Family::Zip2,
    Family::Uzp1,
    Family::Uzp2,
    Family::Trn1,
    Family::Trn2,
    Family::DupLane,
    Family::DupN,
    Family::Tbl1,
    Family::Movl,
    Family::Movn,
    Family::Qmovn,
    Family::Qmovun,
    Family::CvtIF,
    Family::CvtFI,
    Family::CvtnFI,
    Family::Reinterpret,
    Family::Recpe,
    Family::Recps,
    Family::Rsqrte,
    Family::Rsqrts,
    Family::Sqrt,
    Family::Rndn,
    Family::Rbit,
    Family::Clz,
    Family::Cnt,
];

/// The integer/float element grid commonly instantiated by NEON.
pub const COMMON_ELEMS: [Elem; 11] = [
    Elem::I8,
    Elem::I16,
    Elem::I32,
    Elem::I64,
    Elem::U8,
    Elem::U16,
    Elem::U32,
    Elem::U64,
    Elem::F16,
    Elem::F32,
    Elem::F64,
];

/// Enumerate every valid concrete instantiation of the implemented families.
pub fn enumerate_implemented() -> Vec<NeonOp> {
    let mut out = Vec::new();
    for &f in ALL_FAMILIES.iter() {
        for &e in COMMON_ELEMS.iter().chain([Elem::P8].iter()) {
            for q in [false, true] {
                let op = NeonOp::new(f, e, q);
                if op.is_valid() {
                    // D-only families ignore q=true duplicates
                    if matches!(
                        f,
                        Family::Pmin
                            | Family::Pmax
                            | Family::Padd
                            | Family::Tbl1
                            | Family::Mull
                            | Family::Mlal
                            | Family::Movl
                            | Family::Movn
                            | Family::Qmovn
                            | Family::Qmovun
                            | Family::ShrnN
                            | Family::GetLow
                            | Family::GetHigh
                            | Family::Combine
                    ) && q
                    {
                        continue;
                    }
                    out.push(op);
                }
            }
        }
    }
    out.sort_by_key(|o| o.name());
    out.dedup_by_key(|o| o.name());
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn names_match_acle() {
        assert_eq!(NeonOp::new(Family::Add, Elem::I32, true).name(), "vaddq_s32");
        assert_eq!(NeonOp::new(Family::Add, Elem::I32, false).name(), "vadd_s32");
        assert_eq!(NeonOp::new(Family::GetHigh, Elem::I32, false).name(), "vget_high_s32");
        assert_eq!(NeonOp::new(Family::Ld1, Elem::F32, true).name(), "vld1q_f32");
        assert_eq!(NeonOp::new(Family::St1, Elem::I32, true).name(), "vst1q_s32");
        assert_eq!(NeonOp::new(Family::Ceq, Elem::I32, true).name(), "vceqq_s32");
        assert_eq!(NeonOp::new(Family::CvtIF, Elem::I32, true).name(), "vcvtq_f32_s32");
        assert_eq!(NeonOp::new(Family::CvtFI, Elem::F32, true).name(), "vcvtq_s32_f32");
        assert_eq!(NeonOp::new(Family::Rbit, Elem::U8, true).name(), "vrbitq_u8");
        assert_eq!(NeonOp::new(Family::Fma, Elem::F32, true).name(), "vfmaq_f32");
    }

    #[test]
    fn signatures() {
        let add = NeonOp::new(Family::Add, Elem::I32, true).sig();
        assert_eq!(add.ret, Some(VecTy::q(Elem::I32)));
        assert_eq!(add.args.len(), 2);

        let gh = NeonOp::new(Family::GetHigh, Elem::I32, false).sig();
        assert_eq!(gh.ret, Some(VecTy::d(Elem::I32)));
        assert_eq!(gh.args, vec![ArgTy::V(VecTy::q(Elem::I32))]);

        let ceq = NeonOp::new(Family::Ceq, Elem::I32, true).sig();
        assert_eq!(ceq.ret, Some(VecTy::q(Elem::U32)));

        let mull = NeonOp::new(Family::Mull, Elem::I16, false).sig();
        assert_eq!(mull.ret, Some(VecTy::q(Elem::I32)));

        let st = NeonOp::new(Family::St1, Elem::F32, true).sig();
        assert_eq!(st.ret, None);
    }

    #[test]
    fn validity() {
        assert!(NeonOp::new(Family::Fma, Elem::F32, true).is_valid());
        assert!(!NeonOp::new(Family::Fma, Elem::I32, true).is_valid());
        assert!(!NeonOp::new(Family::Rbit, Elem::I32, true).is_valid());
        assert!(NeonOp::new(Family::Rbit, Elem::U8, true).is_valid());
        assert!(!NeonOp::new(Family::Rev16, Elem::I16, true).is_valid());
        assert!(NeonOp::new(Family::Rev16, Elem::I8, true).is_valid());
    }

    #[test]
    fn enumeration_is_substantial() {
        let ops = enumerate_implemented();
        // the paper implements 1520 conversions; our implemented surface is a
        // large subset instantiated over the common grid
        assert!(ops.len() > 700, "got {}", ops.len());
        // all enumerated ops have coherent signatures
        for op in &ops {
            let _ = op.sig();
            let _ = op.name();
        }
    }
}
