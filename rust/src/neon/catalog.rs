//! Full-surface NEON intrinsic catalog for reproducing the paper's Table 1
//! ("Categorization of Neon Intrinsics with types": 4344 intrinsics split
//! by return base type).
//!
//! The catalog is generated from a data-driven specification of the ACLE
//! surface — op bases × register forms × element grids × variant suffixes —
//! rather than a hand-typed list of 4344 names. The paper's counts come
//! from ARM's official ACLE list; ours come from this generator, so
//! EXPERIMENTS.md reports both with per-class deltas.

use std::collections::BTreeMap;

use super::elem::{BaseClass, Elem};

/// One catalogued intrinsic name with its return base class.
#[derive(Debug, Clone)]
pub struct CatalogEntry {
    pub name: String,
    pub ret: BaseClass,
}

// Element grids ---------------------------------------------------------------

const INTS: [Elem; 4] = [Elem::I8, Elem::I16, Elem::I32, Elem::I64];
const UINTS: [Elem; 4] = [Elem::U8, Elem::U16, Elem::U32, Elem::U64];
const FLOATS: [Elem; 3] = [Elem::F16, Elem::F32, Elem::F64];
const POLYS: [Elem; 3] = [Elem::P8, Elem::P16, Elem::P64];
const NARROW_INTS: [Elem; 3] = [Elem::I8, Elem::I16, Elem::I32];
const NARROW_UINTS: [Elem; 3] = [Elem::U8, Elem::U16, Elem::U32];
const WIDE_INTS: [Elem; 3] = [Elem::I16, Elem::I32, Elem::I64];
const WIDE_UINTS: [Elem; 3] = [Elem::U16, Elem::U32, Elem::U64];

/// Which element grid an op spec instantiates over.
#[derive(Debug, Clone, Copy)]
enum Grid {
    /// signed + unsigned + float
    Iuf,
    /// signed + unsigned
    Iu,
    /// signed + unsigned, 8/16/32 only (widening sources)
    IuNarrow,
    /// signed + unsigned, 16/32/64 only (narrowing sources)
    IuWide,
    /// floats only
    F,
    /// f32/f64 only (A64 float ops)
    F3264,
    /// signed only
    I,
    /// everything incl. poly
    All,
    /// poly only
    P,
    /// 8-bit only (s8/u8/p8)
    Byte,
}

fn grid_elems(g: Grid) -> Vec<Elem> {
    match g {
        Grid::Iuf => INTS.iter().chain(&UINTS).chain(&FLOATS).copied().collect(),
        Grid::Iu => INTS.iter().chain(&UINTS).copied().collect(),
        Grid::IuNarrow => NARROW_INTS.iter().chain(&NARROW_UINTS).copied().collect(),
        Grid::IuWide => WIDE_INTS.iter().chain(&WIDE_UINTS).copied().collect(),
        Grid::F => FLOATS.to_vec(),
        Grid::F3264 => vec![Elem::F32, Elem::F64],
        Grid::I => INTS.to_vec(),
        Grid::All => INTS
            .iter()
            .chain(&UINTS)
            .chain(&FLOATS)
            .chain(&POLYS)
            .copied()
            .collect(),
        Grid::P => POLYS.to_vec(),
        Grid::Byte => vec![Elem::I8, Elem::U8, Elem::P8],
    }
}

/// How the return base class derives from the element.
#[derive(Debug, Clone, Copy)]
enum Ret {
    /// same class as the element
    Same,
    /// unsigned of same width (comparisons, tst)
    Uint,
    /// widened same-class (vmovl, vmull: poly widens to poly)
    SameWide,
    /// float (conversions to float)
    Float,
    /// signed int (float->int conversions, vcvt_s*)
    Int,
}

fn ret_class(r: Ret, e: Elem) -> BaseClass {
    match r {
        Ret::Same | Ret::SameWide => e.base_class(),
        Ret::Uint => BaseClass::Uint,
        Ret::Float => BaseClass::Float,
        Ret::Int => BaseClass::Int,
    }
}

/// Register/variant forms an op base instantiates.
#[derive(Debug, Clone, Copy)]
enum Form {
    /// `v<base>_<t>` (64-bit)
    D,
    /// `v<base>q_<t>` (128-bit)
    Q,
    /// `v<base>_n_<t>`
    DN,
    /// `v<base>q_n_<t>`
    QN,
    /// `v<base>_lane_<t>`
    DLane,
    /// `v<base>q_lane_<t>`
    QLane,
    /// `v<base>_laneq_<t>`
    DLaneq,
    /// `v<base>q_laneq_<t>`
    QLaneq,
    /// `v<base>_high_<t>` (A64 high-half form)
    High,
}

fn form_name(base: &str, f: Form, e: Elem) -> String {
    let s = e.suffix();
    match f {
        Form::D => format!("v{base}_{s}"),
        Form::Q => format!("v{base}q_{s}"),
        Form::DN => format!("v{base}_n_{s}"),
        Form::QN => format!("v{base}q_n_{s}"),
        Form::DLane => format!("v{base}_lane_{s}"),
        Form::QLane => format!("v{base}q_lane_{s}"),
        Form::DLaneq => format!("v{base}_laneq_{s}"),
        Form::QLaneq => format!("v{base}q_laneq_{s}"),
        Form::High => format!("v{base}_high_{s}"),
    }
}

const DQ: &[Form] = &[Form::D, Form::Q];
const DQN: &[Form] = &[Form::D, Form::Q, Form::DN, Form::QN];
const ALL_LANES: &[Form] = &[
    Form::D,
    Form::Q,
    Form::DLane,
    Form::QLane,
    Form::DLaneq,
    Form::QLaneq,
];
const ARITH_FULL: &[Form] = &[
    Form::D,
    Form::Q,
    Form::DN,
    Form::QN,
    Form::DLane,
    Form::QLane,
    Form::DLaneq,
    Form::QLaneq,
];
const DHIGH: &[Form] = &[Form::D, Form::High];
const DONLY: &[Form] = &[Form::D];

struct Spec {
    base: &'static str,
    grid: Grid,
    forms: &'static [Form],
    ret: Ret,
}

const fn sp(base: &'static str, grid: Grid, forms: &'static [Form], ret: Ret) -> Spec {
    Spec { base, grid, forms, ret }
}

/// The ACLE surface specification. Comments give the op group.
fn specs() -> Vec<Spec> {
    vec![
        // basic arithmetic
        sp("add", Grid::All, DQ, Ret::Same),
        sp("sub", Grid::Iuf, DQ, Ret::Same),
        sp("mul", Grid::Iuf, ARITH_FULL, Ret::Same),
        sp("mul", Grid::P, DQ, Ret::Same),
        sp("div", Grid::F3264, DQ, Ret::Same),
        sp("mla", Grid::Iuf, ARITH_FULL, Ret::Same),
        sp("mls", Grid::Iuf, ARITH_FULL, Ret::Same),
        sp("fma", Grid::F, ALL_LANES, Ret::Same),
        sp("fms", Grid::F, ALL_LANES, Ret::Same),
        sp("abs", Grid::I, DQ, Ret::Same),
        sp("abs", Grid::F, DQ, Ret::Same),
        sp("qabs", Grid::I, DQ, Ret::Same),
        sp("neg", Grid::I, DQ, Ret::Same),
        sp("neg", Grid::F, DQ, Ret::Same),
        sp("qneg", Grid::I, DQ, Ret::Same),
        sp("min", Grid::Iuf, DQ, Ret::Same),
        sp("max", Grid::Iuf, DQ, Ret::Same),
        sp("minnm", Grid::F, DQ, Ret::Same),
        sp("maxnm", Grid::F, DQ, Ret::Same),
        sp("abd", Grid::Iuf, DQ, Ret::Same),
        sp("aba", Grid::IuNarrow, DQ, Ret::Same),
        // halving / saturating
        sp("hadd", Grid::IuNarrow, DQ, Ret::Same),
        sp("rhadd", Grid::IuNarrow, DQ, Ret::Same),
        sp("hsub", Grid::IuNarrow, DQ, Ret::Same),
        sp("qadd", Grid::Iu, DQ, Ret::Same),
        sp("qsub", Grid::Iu, DQ, Ret::Same),
        sp("uqadd", Grid::I, DQ, Ret::Same),
        sp("sqadd", Grid::Iu, DQ, Ret::Uint),
        // pairwise
        sp("padd", Grid::Iuf, DONLY, Ret::Same),
        sp("paddq", Grid::Iuf, DONLY, Ret::Same), // vpaddq (A64), D slot reused
        sp("pmin", Grid::Iuf, DONLY, Ret::Same),
        sp("pmax", Grid::Iuf, DONLY, Ret::Same),
        sp("pminq", Grid::Iuf, DONLY, Ret::Same),
        sp("pmaxq", Grid::Iuf, DONLY, Ret::Same),
        sp("pminnm", Grid::F3264, DQ, Ret::Same),
        sp("pmaxnm", Grid::F3264, DQ, Ret::Same),
        sp("paddl", Grid::IuNarrow, DQ, Ret::SameWide),
        sp("padal", Grid::IuNarrow, DQ, Ret::SameWide),
        // widening/narrowing arith
        sp("addl", Grid::IuNarrow, DHIGH, Ret::SameWide),
        sp("addw", Grid::IuNarrow, DHIGH, Ret::SameWide),
        sp("subl", Grid::IuNarrow, DHIGH, Ret::SameWide),
        sp("subw", Grid::IuNarrow, DHIGH, Ret::SameWide),
        sp("addhn", Grid::IuWide, DHIGH, Ret::Same),
        sp("raddhn", Grid::IuWide, DHIGH, Ret::Same),
        sp("subhn", Grid::IuWide, DHIGH, Ret::Same),
        sp("rsubhn", Grid::IuWide, DHIGH, Ret::Same),
        sp("mull", Grid::IuNarrow, &[Form::D, Form::High, Form::DN, Form::DLane, Form::DLaneq], Ret::SameWide),
        sp("mull", Grid::P, DHIGH, Ret::SameWide),
        sp("mlal", Grid::IuNarrow, &[Form::D, Form::High, Form::DN, Form::DLane, Form::DLaneq], Ret::SameWide),
        sp("mlsl", Grid::IuNarrow, &[Form::D, Form::High, Form::DN, Form::DLane, Form::DLaneq], Ret::SameWide),
        // saturating doubling multiplies
        sp("qdmulh", Grid::I, ARITH_FULL, Ret::Same),
        sp("qrdmulh", Grid::I, ARITH_FULL, Ret::Same),
        sp("qrdmlah", Grid::I, ALL_LANES, Ret::Same),
        sp("qrdmlsh", Grid::I, ALL_LANES, Ret::Same),
        sp("qdmull", Grid::I, &[Form::D, Form::High, Form::DN, Form::DLane, Form::DLaneq], Ret::SameWide),
        sp("qdmlal", Grid::I, &[Form::D, Form::High, Form::DN, Form::DLane, Form::DLaneq], Ret::SameWide),
        sp("qdmlsl", Grid::I, &[Form::D, Form::High, Form::DN, Form::DLane, Form::DLaneq], Ret::SameWide),
        // comparisons -> uint masks
        sp("ceq", Grid::All, DQ, Ret::Uint),
        sp("ceqz", Grid::Iuf, DQ, Ret::Uint),
        sp("cge", Grid::Iuf, DQ, Ret::Uint),
        sp("cgez", Grid::I, DQ, Ret::Uint),
        sp("cgt", Grid::Iuf, DQ, Ret::Uint),
        sp("cgtz", Grid::I, DQ, Ret::Uint),
        sp("cle", Grid::Iuf, DQ, Ret::Uint),
        sp("clez", Grid::I, DQ, Ret::Uint),
        sp("clt", Grid::Iuf, DQ, Ret::Uint),
        sp("cltz", Grid::I, DQ, Ret::Uint),
        sp("cage", Grid::F, DQ, Ret::Uint),
        sp("cagt", Grid::F, DQ, Ret::Uint),
        sp("cale", Grid::F, DQ, Ret::Uint),
        sp("calt", Grid::F, DQ, Ret::Uint),
        sp("tst", Grid::Iu, DQ, Ret::Uint),
        sp("tst", Grid::Byte, DONLY, Ret::Uint),
        // bitwise
        sp("and", Grid::Iu, DQ, Ret::Same),
        sp("orr", Grid::Iu, DQ, Ret::Same),
        sp("eor", Grid::Iu, DQ, Ret::Same),
        sp("bic", Grid::Iu, DQ, Ret::Same),
        sp("orn", Grid::Iu, DQ, Ret::Same),
        sp("mvn", Grid::Iu, DQ, Ret::Same),
        sp("mvn", Grid::Byte, DQ, Ret::Same),
        sp("bsl", Grid::All, DQ, Ret::Same),
        // shifts
        sp("shl", Grid::Iu, DQN, Ret::Same),
        sp("qshl", Grid::Iu, DQN, Ret::Same),
        sp("qshlu", Grid::I, &[Form::DN, Form::QN], Ret::Uint),
        sp("rshl", Grid::Iu, DQ, Ret::Same),
        sp("qrshl", Grid::Iu, DQ, Ret::Same),
        sp("shr", Grid::Iu, &[Form::DN, Form::QN], Ret::Same),
        sp("rshr", Grid::Iu, &[Form::DN, Form::QN], Ret::Same),
        sp("sra", Grid::Iu, &[Form::DN, Form::QN], Ret::Same),
        sp("rsra", Grid::Iu, &[Form::DN, Form::QN], Ret::Same),
        sp("sli", Grid::Iu, &[Form::DN, Form::QN], Ret::Same),
        sp("sli", Grid::P, &[Form::DN, Form::QN], Ret::Same),
        sp("sri", Grid::Iu, &[Form::DN, Form::QN], Ret::Same),
        sp("sri", Grid::P, &[Form::DN, Form::QN], Ret::Same),
        sp("shll", Grid::IuNarrow, &[Form::DN], Ret::SameWide),
        sp("shrn", Grid::IuWide, &[Form::DN, Form::High], Ret::Same),
        sp("rshrn", Grid::IuWide, &[Form::DN, Form::High], Ret::Same),
        sp("qshrn", Grid::IuWide, &[Form::DN, Form::High], Ret::Same),
        sp("qrshrn", Grid::IuWide, &[Form::DN, Form::High], Ret::Same),
        sp("qshrun", Grid::IuWide, &[Form::DN, Form::High], Ret::Uint),
        sp("qrshrun", Grid::IuWide, &[Form::DN, Form::High], Ret::Uint),
        // permutes
        sp("get_low", Grid::All, DONLY, Ret::Same),
        sp("get_high", Grid::All, DONLY, Ret::Same),
        sp("combine", Grid::All, DONLY, Ret::Same),
        sp("ext", Grid::All, DQ, Ret::Same),
        sp("rev64", Grid::IuNarrow, DQ, Ret::Same),
        sp("rev64", Grid::Byte, DQ, Ret::Same),
        sp("rev32", Grid::Byte, DQ, Ret::Same),
        sp("rev16", Grid::Byte, DQ, Ret::Same),
        sp("zip1", Grid::Iuf, DQ, Ret::Same),
        sp("zip2", Grid::Iuf, DQ, Ret::Same),
        sp("uzp1", Grid::Iuf, DQ, Ret::Same),
        sp("uzp2", Grid::Iuf, DQ, Ret::Same),
        sp("trn1", Grid::Iuf, DQ, Ret::Same),
        sp("trn2", Grid::Iuf, DQ, Ret::Same),
        sp("zip", Grid::IuNarrow, DONLY, Ret::Same),
        sp("uzp", Grid::IuNarrow, DONLY, Ret::Same),
        sp("trn", Grid::IuNarrow, DONLY, Ret::Same),
        sp("dup", Grid::All, &[Form::DN, Form::QN, Form::DLane, Form::QLane, Form::DLaneq, Form::QLaneq], Ret::Same),
        sp("mov", Grid::All, &[Form::DN, Form::QN], Ret::Same),
        sp("create", Grid::All, DONLY, Ret::Same),
        sp("get", Grid::All, &[Form::DLane, Form::QLane], Ret::Same),
        sp("set", Grid::All, &[Form::DLane, Form::QLane], Ret::Same),
        // table lookups
        sp("tbl1", Grid::Byte, DONLY, Ret::Same),
        sp("tbl2", Grid::Byte, DONLY, Ret::Same),
        sp("tbl3", Grid::Byte, DONLY, Ret::Same),
        sp("tbl4", Grid::Byte, DONLY, Ret::Same),
        sp("tbx1", Grid::Byte, DONLY, Ret::Same),
        sp("tbx2", Grid::Byte, DONLY, Ret::Same),
        sp("tbx3", Grid::Byte, DONLY, Ret::Same),
        sp("tbx4", Grid::Byte, DONLY, Ret::Same),
        sp("qtbl1", Grid::Byte, DQ, Ret::Same),
        sp("qtbl2", Grid::Byte, DQ, Ret::Same),
        sp("qtbl3", Grid::Byte, DQ, Ret::Same),
        sp("qtbl4", Grid::Byte, DQ, Ret::Same),
        sp("qtbx1", Grid::Byte, DQ, Ret::Same),
        sp("qtbx2", Grid::Byte, DQ, Ret::Same),
        sp("qtbx3", Grid::Byte, DQ, Ret::Same),
        sp("qtbx4", Grid::Byte, DQ, Ret::Same),
        // widen/narrow moves
        sp("movl", Grid::IuNarrow, DHIGH, Ret::SameWide),
        sp("movn", Grid::IuWide, DHIGH, Ret::Same),
        sp("qmovn", Grid::IuWide, DHIGH, Ret::Same),
        sp("qmovun", Grid::IuWide, DHIGH, Ret::Uint),
        // conversions
        sp("cvt_f32", Grid::Iu, DQN, Ret::Float),
        sp("cvt_s32", Grid::F, DQN, Ret::Int),
        sp("cvt_u32", Grid::F, DQN, Ret::Uint),
        sp("cvta_s32", Grid::F, DQ, Ret::Int),
        sp("cvta_u32", Grid::F, DQ, Ret::Uint),
        sp("cvtm_s32", Grid::F, DQ, Ret::Int),
        sp("cvtm_u32", Grid::F, DQ, Ret::Uint),
        sp("cvtn_s32", Grid::F, DQ, Ret::Int),
        sp("cvtn_u32", Grid::F, DQ, Ret::Uint),
        sp("cvtp_s32", Grid::F, DQ, Ret::Int),
        sp("cvtp_u32", Grid::F, DQ, Ret::Uint),
        // float rounding / estimates
        sp("rnd", Grid::F, DQ, Ret::Same),
        sp("rnda", Grid::F, DQ, Ret::Same),
        sp("rndi", Grid::F, DQ, Ret::Same),
        sp("rndm", Grid::F, DQ, Ret::Same),
        sp("rndn", Grid::F, DQ, Ret::Same),
        sp("rndp", Grid::F, DQ, Ret::Same),
        sp("rndx", Grid::F, DQ, Ret::Same),
        sp("sqrt", Grid::F, DQ, Ret::Same),
        sp("recpe", Grid::F, DQ, Ret::Same),
        sp("recps", Grid::F, DQ, Ret::Same),
        sp("rsqrte", Grid::F, DQ, Ret::Same),
        sp("rsqrts", Grid::F, DQ, Ret::Same),
        // bit manipulation
        sp("rbit", Grid::Byte, DQ, Ret::Same),
        sp("cls", Grid::IuNarrow, DQ, Ret::Int),
        sp("clz", Grid::IuNarrow, DQ, Ret::Same),
        sp("cnt", Grid::Byte, DQ, Ret::Same),
        // reductions (A64)
        sp("addv", Grid::Iuf, DQ, Ret::Same),
        sp("addlv", Grid::IuNarrow, DQ, Ret::SameWide),
        sp("maxv", Grid::Iuf, DQ, Ret::Same),
        sp("minv", Grid::Iuf, DQ, Ret::Same),
        sp("maxnmv", Grid::F, DQ, Ret::Same),
        sp("minnmv", Grid::F, DQ, Ret::Same),
        // dot products (Armv8.2)
        sp("dot", Grid::Byte, &[Form::D, Form::Q, Form::DLane, Form::QLane, Form::DLaneq, Form::QLaneq], Ret::Same),
        // A64 element-copy and extended-multiply families
        sp("copy_lane", Grid::All, DONLY, Ret::Same),
        sp("copyq_lane", Grid::All, DONLY, Ret::Same),
        sp("copy_laneq", Grid::All, DONLY, Ret::Same),
        sp("copyq_laneq", Grid::All, DONLY, Ret::Same),
        sp("mulx", Grid::F, ALL_LANES, Ret::Same),
        sp("recpx", Grid::F, DQ, Ret::Same),
    ]
}

/// ACLE scalar-form intrinsics (the `b`/`h`/`s`/`d`-suffixed per-lane
/// operations, e.g. `vqaddb_s8`, `vaddh_f16`, `vrshld_s64`): a large part
/// of the official 4344 count the paper's Table 1 tallies.
fn scalar_form_entries() -> Vec<CatalogEntry> {
    let mut out = Vec::new();
    let widths: [(&str, Elem, Elem); 4] = [
        ("b", Elem::I8, Elem::U8),
        ("h", Elem::I16, Elem::U16),
        ("s", Elem::I32, Elem::U32),
        ("d", Elem::I64, Elem::U64),
    ];
    // integer scalar saturating/shift/narrow ops
    let int_bases = [
        "qadd", "qsub", "qshl", "qrshl", "qshlu", "qabs", "qneg", "qdmulh",
        "qrdmulh", "qmovn", "qmovun", "uqadd", "sqadd",
    ];
    for base in int_bases {
        for (suf, se, ue) in widths {
            out.push(CatalogEntry {
                name: format!("v{base}{suf}_{}", se.suffix()),
                ret: se.base_class(),
            });
            if !matches!(base, "qmovun" | "qshlu" | "qabs" | "qneg") {
                out.push(CatalogEntry {
                    name: format!("v{base}{suf}_{}", ue.suffix()),
                    ret: ue.base_class(),
                });
            }
        }
    }
    // d-form plain shifts/adds (A64 scalar)
    for base in ["shl", "rshl", "sra", "rsra", "shl_n", "add", "sub", "tst", "sli_n", "sri_n"] {
        out.push(CatalogEntry { name: format!("v{base}d_s64"), ret: BaseClass::Int });
        out.push(CatalogEntry { name: format!("v{base}d_u64"), ret: BaseClass::Uint });
    }
    // f16 scalar `h` forms (Armv8.2 fp16 scalar arithmetic)
    let h_bases = [
        "abs", "add", "sub", "mul", "mulx", "div", "fma", "fms", "neg",
        "recpe", "recps", "recpx", "rsqrte", "rsqrts", "sqrt", "rnd", "rnda",
        "rndi", "rndm", "rndn", "rndp", "rndx", "maxnm", "minnm", "cvth_f16_s16",
        "cvth_f16_u16", "ceq", "cge", "cgt", "cle", "clt", "ceqz", "cgez",
        "cgtz", "clez", "cltz", "cage", "cagt", "cale", "calt",
    ];
    for base in h_bases {
        let ret = if base.starts_with('c') && !base.starts_with("cvt") {
            BaseClass::Uint
        } else {
            BaseClass::Float
        };
        out.push(CatalogEntry { name: format!("v{base}h_f16"), ret });
    }
    // f32/f64 scalar forms
    for base in ["mulx", "recpe", "recps", "recpx", "rsqrte", "rsqrts", "abd", "cvtn_s32", "cvtn_u32", "cvta_s32", "cvta_u32", "cvtm_s32", "cvtp_s32", "rndn_32", "cage", "cagt"] {
        for (suf, e) in [("s", Elem::F32), ("d", Elem::F64)] {
            let ret = if base.starts_with("cvtn_s") || base.starts_with("cvta_s")
                || base.starts_with("cvtm") || base.starts_with("cvtp")
            {
                BaseClass::Int
            } else if base.starts_with("cvt") || base.starts_with("cage") || base.starts_with("cagt") {
                BaseClass::Uint
            } else {
                BaseClass::Float
            };
            out.push(CatalogEntry { name: format!("v{base}{suf}_{}", e.suffix()), ret });
        }
    }
    // crypto (uint8x16 domain)
    for (base, ret) in [
        ("aeseq_u8", BaseClass::Uint), ("aesdq_u8", BaseClass::Uint),
        ("aesmcq_u8", BaseClass::Uint), ("aesimcq_u8", BaseClass::Uint),
        ("sha1cq_u32", BaseClass::Uint), ("sha1pq_u32", BaseClass::Uint),
        ("sha1mq_u32", BaseClass::Uint), ("sha1su0q_u32", BaseClass::Uint),
        ("sha1su1q_u32", BaseClass::Uint), ("sha1h_u32", BaseClass::Uint),
        ("sha256hq_u32", BaseClass::Uint), ("sha256h2q_u32", BaseClass::Uint),
        ("sha256su0q_u32", BaseClass::Uint), ("sha256su1q_u32", BaseClass::Uint),
    ] {
        out.push(CatalogEntry { name: format!("v{base}"), ret });
    }
    // scalar lane extract/insert across the full grid
    for e in [
        Elem::I8, Elem::I16, Elem::I32, Elem::I64, Elem::U8, Elem::U16,
        Elem::U32, Elem::U64, Elem::F16, Elem::F32, Elem::F64, Elem::P8,
        Elem::P16, Elem::P64,
    ] {
        for q in ["", "q"] {
            out.push(CatalogEntry {
                name: format!("vget{q}_lane_{}", e.suffix()),
                ret: e.base_class(),
            });
            out.push(CatalogEntry {
                name: format!("vset{q}_lane_{}", e.suffix()),
                ret: e.base_class(),
            });
        }
    }
    // scalar reductions (vaddv h-suffixed results already counted in grid;
    // these are the A64 `v` scalar-result duplicates with across-lane
    // suffixes)
    for base in ["paddd_s64", "paddd_u64", "addvq_s64", "addvq_u64"] {
        let ret = if base.contains("_u") { BaseClass::Uint } else { BaseClass::Int };
        out.push(CatalogEntry { name: format!("v{base}"), ret });
    }
    out
}

/// Hand-listed intrinsics whose names do not follow the
/// base×form×elem grid: bfloat16 (Armv8.6), u32 estimate forms, poly64
/// crypto multiplies, and scalar `h`-suffix helpers.
fn raw_entries() -> Vec<CatalogEntry> {
    use BaseClass::*;
    let mut out = Vec::new();
    let mut push = |names: &[&str], ret: BaseClass| {
        for n in names {
            out.push(CatalogEntry { name: n.to_string(), ret });
        }
    };
    // u32 reciprocal estimate forms
    push(&["vrecpe_u32", "vrecpeq_u32", "vrsqrte_u32", "vrsqrteq_u32"], Uint);
    // poly64 widening multiply (crypto)
    push(&["vmull_p64", "vmull_high_p64"], Poly);
    // bfloat16 compute (~Armv8.6 surface)
    push(
        &[
            "vbfdot_f32", "vbfdotq_f32", "vbfdot_lane_f32", "vbfdotq_lane_f32",
            "vbfdot_laneq_f32", "vbfdotq_laneq_f32", "vbfmmlaq_f32",
            "vbfmlalbq_f32", "vbfmlalbq_lane_f32", "vbfmlalbq_laneq_f32",
            "vbfmlaltq_f32", "vbfmlaltq_lane_f32", "vbfmlaltq_laneq_f32",
            "vcvtah_f32_bf16",
        ],
        Float,
    );
    push(
        &[
            "vcvt_bf16_f32", "vcvtq_low_bf16_f32", "vcvtq_high_bf16_f32",
            "vcvth_bf16_f32", "vdup_n_bf16", "vdupq_n_bf16", "vdup_lane_bf16",
            "vdupq_lane_bf16", "vdup_laneq_bf16", "vdupq_laneq_bf16",
            "vduph_lane_bf16", "vduph_laneq_bf16", "vget_lane_bf16",
            "vgetq_lane_bf16", "vset_lane_bf16", "vsetq_lane_bf16",
            "vcreate_bf16", "vcombine_bf16", "vget_low_bf16", "vget_high_bf16",
            "vld1_bf16", "vld1q_bf16", "vld1_dup_bf16", "vld1q_dup_bf16",
            "vld1_lane_bf16", "vld1q_lane_bf16", "vld1_bf16_x2",
            "vld1q_bf16_x2", "vld1_bf16_x3", "vld1q_bf16_x3", "vld1_bf16_x4",
            "vld1q_bf16_x4", "vld2_bf16", "vld2q_bf16", "vld2_dup_bf16",
            "vld2q_dup_bf16", "vld2_lane_bf16", "vld2q_lane_bf16",
            "vld3_bf16", "vld3q_bf16", "vld3_dup_bf16", "vld3q_dup_bf16",
            "vld3_lane_bf16", "vld3q_lane_bf16", "vld4_bf16", "vld4q_bf16",
            "vld4_dup_bf16", "vld4q_dup_bf16", "vld4_lane_bf16",
            "vld4q_lane_bf16",
        ],
        Bfloat,
    );
    push(
        &[
            "vst1_bf16", "vst1q_bf16", "vst1_lane_bf16", "vst1q_lane_bf16",
            "vst1_bf16_x2", "vst1q_bf16_x2", "vst1_bf16_x3", "vst1q_bf16_x3",
            "vst1_bf16_x4", "vst1q_bf16_x4", "vst2_bf16", "vst2q_bf16",
            "vst2_lane_bf16", "vst2q_lane_bf16", "vst3_bf16", "vst3q_bf16",
            "vst3_lane_bf16", "vst3q_lane_bf16", "vst4_bf16", "vst4q_bf16",
            "vst4_lane_bf16", "vst4q_lane_bf16",
        ],
        Void,
    );
    out
}

/// Generate the full catalog.
pub fn generate() -> Vec<CatalogEntry> {
    let mut out = raw_entries();
    out.extend(scalar_form_entries());
    for s in specs() {
        for e in grid_elems(s.grid) {
            for &f in s.forms {
                let name = form_name(s.base, f, e);
                // bf16 pseudo-grid specs already carry their element in the
                // base name; skip re-suffixing artefacts by keeping as-is.
                let ret = ret_class(s.ret, e);
                out.push(CatalogEntry { name, ret });
            }
        }
    }
    // memory ops: vld1..vld4 / vst1..vst4 with dup/lane/x-struct variants
    let mem_elems: Vec<Elem> = INTS
        .iter()
        .chain(&UINTS)
        .chain(&FLOATS)
        .chain(&POLYS)
        .copied()
        .collect();
    for n in 1..=4u32 {
        for &e in &mem_elems {
            for q in ["", "q"] {
                let s = e.suffix();
                out.push(CatalogEntry { name: format!("vld{n}{q}_{s}"), ret: e.base_class() });
                out.push(CatalogEntry { name: format!("vld{n}{q}_dup_{s}"), ret: e.base_class() });
                out.push(CatalogEntry { name: format!("vld{n}{q}_lane_{s}"), ret: e.base_class() });
                out.push(CatalogEntry { name: format!("vst{n}{q}_{s}"), ret: BaseClass::Void });
                out.push(CatalogEntry { name: format!("vst{n}{q}_lane_{s}"), ret: BaseClass::Void });
            }
        }
    }
    // vld1x2/x3/x4 and vst1x2/x3/x4 struct-of-arrays forms
    for x in 2..=4u32 {
        for &e in &mem_elems {
            for q in ["", "q"] {
                let s = e.suffix();
                out.push(CatalogEntry { name: format!("vld1{q}_{s}_x{x}"), ret: e.base_class() });
                out.push(CatalogEntry { name: format!("vst1{q}_{s}_x{x}"), ret: BaseClass::Void });
            }
        }
    }
    // reinterprets: dst x src over the full grid (excluding identity)
    let re_elems: Vec<Elem> = INTS
        .iter()
        .chain(&UINTS)
        .chain(&FLOATS)
        .chain(&POLYS)
        .chain([Elem::BF16].iter())
        .copied()
        .collect();
    for q in ["", "q"] {
        for &dst in &re_elems {
            for &src in &re_elems {
                if dst == src {
                    continue;
                }
                out.push(CatalogEntry {
                    name: format!("vreinterpret{q}_{}_{}", dst.suffix(), src.suffix()),
                    ret: dst.base_class(),
                });
            }
        }
    }
    out.sort_by(|a, b| a.name.cmp(&b.name));
    out.dedup_by(|a, b| a.name == b.name);
    out
}

/// Table 1: counts by return base class.
pub fn counts_by_class() -> BTreeMap<BaseClass, usize> {
    let mut m = BTreeMap::new();
    for e in generate() {
        *m.entry(e.ret).or_insert(0) += 1;
    }
    m
}

/// The paper's Table 1 reference values.
pub fn paper_table1() -> Vec<(BaseClass, usize)> {
    vec![
        (BaseClass::Int, 1279),
        (BaseClass::Uint, 1448),
        (BaseClass::Float, 834),
        (BaseClass::Poly, 371),
        (BaseClass::Void, 331),
        (BaseClass::Bfloat, 81),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn catalog_is_deduplicated_and_large() {
        let cat = generate();
        assert!(cat.len() > 2500, "catalog too small: {}", cat.len());
        let mut names: Vec<&str> = cat.iter().map(|e| e.name.as_str()).collect();
        let n = names.len();
        names.dedup();
        assert_eq!(names.len(), n, "duplicate names in catalog");
    }

    #[test]
    fn class_ordering_matches_paper() {
        // paper Table 1: uint > int > float > poly > void > bfloat
        let c = counts_by_class();
        let get = |b: BaseClass| *c.get(&b).unwrap_or(&0);
        assert!(get(BaseClass::Uint) > get(BaseClass::Int));
        assert!(get(BaseClass::Int) > get(BaseClass::Float));
        assert!(get(BaseClass::Float) > get(BaseClass::Poly));
        assert!(get(BaseClass::Poly) > get(BaseClass::Bfloat));
        assert!(get(BaseClass::Void) > get(BaseClass::Bfloat));
    }

    #[test]
    fn known_names_present() {
        let cat = generate();
        for want in [
            "vaddq_s32",
            "vget_high_s32",
            "vceqq_s32",
            "vrbitq_u8",
            "vst1q_s32",
            "vld1q_f32",
            "vreinterpretq_u8_s32",
            "vfmaq_lane_f32",
        ] {
            assert!(cat.iter().any(|e| e.name == want), "missing {want}");
        }
    }

    #[test]
    fn comparisons_return_uint() {
        let cat = generate();
        let e = cat.iter().find(|e| e.name == "vceqq_s32").unwrap();
        assert_eq!(e.ret, BaseClass::Uint);
        let e = cat.iter().find(|e| e.name == "vst1q_s32").unwrap();
        assert_eq!(e.ret, BaseClass::Void);
    }
}
