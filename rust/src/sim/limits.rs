//! Fuel-bounded execution.
//!
//! [`ExecLimits`] caps one program execution in two dimensions: a
//! **dynamic-instruction budget** (`max_dyn_insts`, checked against
//! `SimStats::total()`) and an optional **wall-clock deadline**. Both
//! engines check the limits at loop iterations only — straight-line code
//! is statically bounded, so a program cannot exceed its budget by more
//! than one loop body.
//!
//! The default budget ([`ExecLimits::for_program`]) is derived from the
//! program's *static shape*: statically known trip counts × estimated
//! body cost, times a safety factor, plus slack. The estimate is an
//! upper bound of the real dynamic cost for any well-formed program
//! (every statement is costed at or above what the engines record), so
//! healthy jobs never trip the default — only a runaway back-edge (which
//! the estimator deliberately counts as a *single* trip) or a grossly
//! mis-translated program runs out of fuel. Exhaustion raises
//! `TrapKind::FuelExhausted` / `TrapKind::DeadlineExceeded`, which the
//! coordinator degrades to a `FaultRecord` like any other trap — the
//! worker thread survives.

use std::time::Duration;

use crate::rvv::program::{RStmt, RvvProgram};

use super::stats::LOOP_OVERHEAD;

/// Execution bounds for one job attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ExecLimits {
    /// Trap with `FuelExhausted` once `SimStats::total()` reaches this.
    pub max_dyn_insts: u64,
    /// Trap with `DeadlineExceeded` once this much wall-clock time has
    /// passed since the engine was constructed. `None` = no deadline.
    pub wall_deadline: Option<Duration>,
}

impl ExecLimits {
    /// No bounds at all (differential oracles, benches).
    pub fn unbounded() -> ExecLimits {
        ExecLimits { max_dyn_insts: u64::MAX, wall_deadline: None }
    }

    /// Derive a budget from the program's static shape: 4× the estimated
    /// dynamic cost plus fixed slack, no wall deadline. A loop whose
    /// back-edge cannot terminate is costed at one trip, so an actual
    /// runaway exhausts this budget almost immediately.
    pub fn for_program(prog: &RvvProgram) -> ExecLimits {
        let est = est_block(&prog.body);
        ExecLimits {
            max_dyn_insts: est.saturating_mul(4).saturating_add(1024),
            wall_deadline: None,
        }
    }

    pub fn with_deadline(mut self, d: Duration) -> ExecLimits {
        self.wall_deadline = Some(d);
        self
    }
}

impl Default for ExecLimits {
    fn default() -> ExecLimits {
        ExecLimits::unbounded()
    }
}

/// Static upper bound of the dynamic instructions a block records.
fn est_block(stmts: &[RStmt]) -> u64 {
    let mut total: u64 = 0;
    for s in stmts {
        let cost = match s {
            // one op plus at most one vsetvli
            RStmt::Op(_) => 2,
            RStmt::SSet { .. } => 1,
            RStmt::Scalar(b) => b.scalar_cost.saturating_add(b.mem_ops),
            RStmt::Loop { start, end, step, body, .. } => {
                let trips: u64 = if start >= end {
                    0
                } else if *step <= 0 {
                    // cannot terminate — the verifier rejects this shape;
                    // cost one trip so actual execution exhausts the fuel
                    1
                } else {
                    let t = (*end as i128 - *start as i128 + *step as i128 - 1) / *step as i128;
                    u64::try_from(t).unwrap_or(u64::MAX)
                };
                est_block(body).saturating_add(LOOP_OVERHEAD).saturating_mul(trips)
            }
        };
        total = total.saturating_add(cost);
    }
    total
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn budget_scales_with_trip_count() {
        let body = vec![RStmt::Loop { ivar: 0, start: 0, end: 100, step: 1, body: vec![] }];
        let p = RvvProgram { name: "l".into(), bufs: vec![], body, n_vregs: 0, n_mregs: 0, n_sregs: 1 };
        let lim = ExecLimits::for_program(&p);
        // 100 trips × LOOP_OVERHEAD × 4 + slack
        assert_eq!(lim.max_dyn_insts, 100 * LOOP_OVERHEAD * 4 + 1024);
        assert!(lim.wall_deadline.is_none());
    }

    #[test]
    fn runaway_back_edge_is_costed_one_trip() {
        let body = vec![RStmt::Loop { ivar: 0, start: 0, end: 100, step: 0, body: vec![] }];
        let p = RvvProgram { name: "r".into(), bufs: vec![], body, n_vregs: 0, n_mregs: 0, n_sregs: 1 };
        let lim = ExecLimits::for_program(&p);
        assert_eq!(lim.max_dyn_insts, LOOP_OVERHEAD * 4 + 1024);
    }

    #[test]
    fn unbounded_is_default() {
        assert_eq!(ExecLimits::default(), ExecLimits::unbounded());
    }
}
