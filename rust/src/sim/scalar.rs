//! SIMDe generic-path scalar-fallback execution, shared verbatim between
//! the tree-walking [`crate::sim::Simulator`] and the pre-decoded
//! [`crate::sim::Engine`] so the two paths cannot drift numerically or in
//! cost accounting.
//!
//! Faults (bad operands, out-of-bounds accesses) propagate as structured
//! [`SimTrap`]s, tagged with the scalar op's name as the "instruction".

use crate::ir::{Arg, BufDecl};
use crate::neon::ops::Family;
use crate::neon::semantics::{eval_pure, Value};
use crate::neon::vreg::{VReg, VecTy};
use crate::rvv::machine::RvvMachine;
use crate::rvv::program::ScalarBlock;
use crate::rvv::trap::SimTrap;
use crate::rvv::vtype::{Lmul, Sew};
use super::stats::SimStats;

/// Execute a SIMDe generic-path scalar fallback: numerics via the
/// reference NEON semantics over the values in the RVV registers, cost
/// from the calibrated model (see [`ScalarBlock`]).
pub(crate) fn exec_scalar_block(
    m: &mut RvvMachine,
    bufs: &[BufDecl],
    stats: &mut SimStats,
    b: &ScalarBlock,
) -> Result<(), SimTrap> {
    scalar_block_inner(m, bufs, stats, b)
        .map_err(|t| t.with_inst(format!("scalar {}", b.call.op.name())))
}

fn scalar_block_inner(
    m: &mut RvvMachine,
    bufs: &[BufDecl],
    stats: &mut SimStats,
    b: &ScalarBlock,
) -> Result<(), SimTrap> {
    let op = b.call.op;
    stats.scalar_ops += b.scalar_cost;
    stats.scalar_mem += b.mem_ops;
    // note: scalar code does not alter vtype — no vsetvli churn here;
    // the churn comes from the baseline's e8 memcpy traffic
    if b.cost_only {
        return Ok(());
    }

    match op.family {
        Family::Ld1 | Family::Ld1Dup => {
            let (buf, idx) = resolve_mem(m, &b.call.args[0])?;
            let vt = op.vt();
            let dst = b.dst.ok_or_else(|| SimTrap::bad_operand("scalar load without dst"))?;
            let decl = &bufs[buf as usize];
            let sew = Sew::of_bits(decl.elem.bits());
            for lane in 0..vt.lanes as u32 {
                let off = if op.family == Family::Ld1Dup {
                    idx * decl.elem.bytes() as i64
                } else {
                    (idx + lane as i64) * decl.elem.bytes() as i64
                };
                let raw = m.load_at(buf, off, sew)?;
                m.write_lane(dst, Sew::of_bits(vt.elem.bits()), Lmul::M1, lane, raw)?;
            }
            Ok(())
        }
        Family::St1 => {
            let (buf, idx) = resolve_mem(m, &b.call.args[0])?;
            let src = match b.call.args[1] {
                Arg::V(r) => r,
                _ => return Err(SimTrap::bad_operand("st1 src must be a vreg")),
            };
            let vt = op.vt();
            let decl = &bufs[buf as usize];
            let sew = Sew::of_bits(decl.elem.bits());
            for lane in 0..vt.lanes as u32 {
                let raw = m.read_lane(src, Sew::of_bits(vt.elem.bits()), Lmul::M1, lane)?;
                m.store_at(buf, (idx + lane as i64) * decl.elem.bytes() as i64, sew, raw)?;
            }
            Ok(())
        }
        Family::Ld1Lane => {
            let (buf, idx) = resolve_mem(m, &b.call.args[0])?;
            let src = match b.call.args[1] {
                Arg::V(r) => r,
                _ => return Err(SimTrap::bad_operand("ld1_lane src must be a vreg")),
            };
            let lane = match b.call.args[2] {
                Arg::Imm(i) => i as u32,
                _ => return Err(SimTrap::bad_operand("ld1_lane lane must be imm")),
            };
            let vt = op.vt();
            let dst = b.dst.ok_or_else(|| SimTrap::bad_operand("ld1_lane without dst"))?;
            let sew = Sew::of_bits(vt.elem.bits());
            // copy the source vector, then overwrite one lane
            for l in 0..vt.lanes as u32 {
                let raw = m.read_lane(src, sew, Lmul::M1, l)?;
                m.write_lane(dst, sew, Lmul::M1, l, raw)?;
            }
            let decl = &bufs[buf as usize];
            let raw =
                m.load_at(buf, idx * decl.elem.bytes() as i64, Sew::of_bits(decl.elem.bits()))?;
            m.write_lane(dst, sew, Lmul::M1, lane, raw)?;
            Ok(())
        }
        Family::St1Lane => {
            let (buf, idx) = resolve_mem(m, &b.call.args[0])?;
            let src = match b.call.args[1] {
                Arg::V(r) => r,
                _ => return Err(SimTrap::bad_operand("st1_lane src must be a vreg")),
            };
            let lane = match b.call.args[2] {
                Arg::Imm(i) => i as u32,
                _ => return Err(SimTrap::bad_operand("st1_lane lane must be imm")),
            };
            let vt = op.vt();
            let sew = Sew::of_bits(vt.elem.bits());
            let raw = m.read_lane(src, sew, Lmul::M1, lane)?;
            let decl = &bufs[buf as usize];
            m.store_at(buf, idx * decl.elem.bytes() as i64, Sew::of_bits(decl.elem.bits()), raw)?;
            Ok(())
        }
        _ => {
            // pure op via reference semantics
            let sig = op.sig();
            let mut vals = Vec::with_capacity(b.call.args.len());
            for (at, a) in sig.args.iter().zip(&b.call.args) {
                vals.push(match (at, a) {
                    (crate::neon::ops::ArgTy::V(vt), Arg::V(r)) => Value::V(read_neon(m, *r, *vt)?),
                    (_, Arg::Imm(i)) => Value::Imm(*i),
                    (_, Arg::S(r)) => Value::Imm(m.sregs[*r as usize]),
                    _ => {
                        return Err(SimTrap::bad_operand(format!(
                            "scalar block: bad arg for {}",
                            op.name()
                        )))
                    }
                });
            }
            let r = eval_pure(op, &vals);
            let dst = b.dst.ok_or_else(|| SimTrap::bad_operand("scalar op without dst"))?;
            write_neon(m, dst, &r)?;
            Ok(())
        }
    }
}

/// Read the low lanes of an RVV vreg as a NEON vector value. Scalar
/// fallbacks model the fixed 128-bit NEON types, so these always address
/// single (`m1`) registers.
fn read_neon(m: &RvvMachine, reg: u32, vt: VecTy) -> Result<VReg, SimTrap> {
    let sew = Sew::of_bits(vt.elem.bits());
    let lanes = (0..vt.lanes as u32)
        .map(|i| m.read_lane(reg, sew, Lmul::M1, i))
        .collect::<Result<Vec<u64>, SimTrap>>()?;
    Ok(VReg::from_raw(vt, lanes))
}

/// Write a NEON vector value into the low lanes of an RVV vreg.
fn write_neon(m: &mut RvvMachine, reg: u32, v: &VReg) -> Result<(), SimTrap> {
    let sew = Sew::of_bits(v.ty.elem.bits());
    for (i, &raw) in v.lanes.iter().enumerate() {
        m.write_lane(reg, sew, Lmul::M1, i as u32, raw)?;
    }
    Ok(())
}

fn resolve_mem(m: &RvvMachine, a: &Arg) -> Result<(u32, i64), SimTrap> {
    match a {
        Arg::Mem { buf, index } => Ok((*buf, index.eval(&m.sregs))),
        _ => Err(SimTrap::bad_operand("expected memory operand")),
    }
}
