//! Pre-decoded execution engine: runs a [`DecodedProgram`] with a flat
//! program-counter loop and lane-batched instruction semantics.
//!
//! This is the fast path behind every harness entry point (`run_job`,
//! `run_matrix`, `figure2`, the vlen-sweep benches). It is observationally
//! identical to the tree-walking [`crate::sim::Simulator`]:
//!
//! - output buffers are **bit-identical** — batched element-wise kernels
//!   in [`crate::rvv::exec::exec_batched`] compute the same formulas as
//!   the per-lane interpreter, and everything else falls back to the
//!   interpreter's own `exec`;
//! - [`SimStats`] are **exactly equal** — vsetvli churn is decided by the
//!   same runtime comparison wherever the decode pass could not prove the
//!   configuration statically, and loop/scalar accounting mirrors the
//!   interpreter statement-for-statement.
//!
//! The differential test (`tests/engine_differential.rs`) enforces both
//! properties across the kernel suite × modes × vlens.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::ir::BufKind;
use crate::neon::interp::{Buffer, Inputs};
use crate::rvv::exec::{exec_batched, ExecScratch};
use crate::rvv::machine::{RvvConfig, RvvMachine};
use crate::rvv::program::RvvProgram;
use crate::rvv::trap::SimTrap;
use crate::rvv::vtype::{Lmul, Sew};
use super::decode::{DecodedOp, DecodedProgram};
use super::limits::ExecLimits;
use super::scalar::exec_scalar_block;
use super::stats::{SimStats, LOOP_OVERHEAD};

/// One execution of a [`DecodedProgram`]. The decoded program is borrowed,
/// not owned: decode once per (kernel, mode, vlen), run many times.
pub struct Engine<'p> {
    prog: &'p RvvProgram,
    dec: &'p DecodedProgram,
    m: RvvMachine,
    /// current (sew, lmul, vl) configuration, None = unconfigured
    vcfg: Option<(Sew, Lmul, u32)>,
    /// loop trip counters, one slot per static loop (kept out of `sregs`
    /// so body writes to the induction register cannot alter trip counts,
    /// matching the interpreter's local loop variable)
    slots: Vec<i64>,
    scratch: ExecScratch,
    /// fuel / deadline bounds, checked at loop entries and back-edges
    limits: ExecLimits,
    started: std::time::Instant,
    pub stats: SimStats,
}

impl<'p> Engine<'p> {
    /// Build with the default fuel budget derived from the program's
    /// static shape ([`ExecLimits::for_program`]).
    pub fn new(
        prog: &'p RvvProgram,
        dec: &'p DecodedProgram,
        cfg: RvvConfig,
        inputs: &Inputs,
    ) -> Result<Engine<'p>> {
        Engine::with_limits(prog, dec, cfg, inputs, ExecLimits::for_program(prog))
    }

    pub fn with_limits(
        prog: &'p RvvProgram,
        dec: &'p DecodedProgram,
        cfg: RvvConfig,
        inputs: &Inputs,
        limits: ExecLimits,
    ) -> Result<Engine<'p>> {
        let mut bufs = Vec::with_capacity(prog.bufs.len());
        for decl in &prog.bufs {
            let b = match decl.kind {
                BufKind::Input => inputs
                    .get(&decl.name)
                    .with_context(|| format!("missing input '{}'", decl.name))?
                    .clone(),
                _ => Buffer::zeros(decl.elem, decl.len),
            };
            bufs.push(b);
        }
        let m = RvvMachine::new(cfg, prog.n_vregs, prog.n_mregs, prog.n_sregs, bufs);
        Ok(Engine {
            prog,
            dec,
            m,
            vcfg: None,
            slots: vec![0; dec.n_loop_slots],
            scratch: ExecScratch::default(),
            limits,
            started: std::time::Instant::now(),
            stats: SimStats::default(),
        })
    }

    /// Fuel / deadline check, run once per loop iteration (straight-line
    /// code is statically bounded, so per-op checks would only add cost).
    fn check_limits(&self) -> Result<()> {
        if self.stats.total() >= self.limits.max_dyn_insts {
            return Err(SimTrap::fuel_exhausted(format!(
                "dynamic-instruction budget of {} exhausted",
                self.limits.max_dyn_insts
            ))
            .in_kernel(&self.prog.name)
            .on_engine("decoded")
            .into());
        }
        if let Some(d) = self.limits.wall_deadline {
            if self.started.elapsed() >= d {
                return Err(SimTrap::deadline_exceeded(format!(
                    "wall-clock deadline of {d:?} passed"
                ))
                .in_kernel(&self.prog.name)
                .on_engine("decoded")
                .into());
            }
        }
        Ok(())
    }

    /// Run to completion, returning output buffers by name.
    pub fn run(mut self) -> Result<(HashMap<String, Buffer>, SimStats)> {
        self.exec_ops()?;
        let mut out = HashMap::new();
        for (decl, buf) in self.prog.bufs.iter().zip(self.m.bufs) {
            if decl.kind == BufKind::Output {
                out.insert(decl.name.clone(), buf);
            }
        }
        Ok((out, self.stats))
    }

    fn exec_ops(&mut self) -> Result<()> {
        let dec = self.dec;
        let mut pc = 0usize;
        while pc < dec.ops.len() {
            match &dec.ops[pc] {
                DecodedOp::Inst { idx, check_cfg } => {
                    let di = &dec.insts[*idx as usize];
                    if *check_cfg {
                        if self.vcfg != Some(di.want) {
                            self.stats.vsetvli += 1;
                            self.vcfg = Some(di.want);
                        }
                    } else {
                        // decode proved the predecessor left this config
                        debug_assert_eq!(self.vcfg, Some(di.want));
                    }
                    let mem_off = di.mem.as_ref().map(|a| a.eval(&self.m.sregs));
                    exec_batched(&mut self.m, &di.inst, mem_off, &mut self.scratch).map_err(
                        |t| {
                            t.at_pc(pc)
                                .with_inst(di.inst.asm())
                                .in_kernel(&self.prog.name)
                                .on_engine("decoded")
                        },
                    )?;
                    self.stats.record_vector(di.kind_idx, di.mnemonic, di.is_mem, di.inst.lmul);
                    pc += 1;
                }
                DecodedOp::SSet { dst, addr } => {
                    let v = addr.eval(&self.m.sregs);
                    self.m.sregs[*dst as usize] = v;
                    self.stats.scalar_ops += 1;
                    pc += 1;
                }
                DecodedOp::LoopStart { slot, ivar, start, end, exit } => {
                    self.slots[*slot as usize] = *start;
                    if *start < *end {
                        self.check_limits()?;
                        self.m.sregs[*ivar as usize] = *start;
                        self.stats.scalar_ops += LOOP_OVERHEAD;
                        pc += 1;
                    } else {
                        pc = *exit as usize;
                    }
                }
                DecodedOp::LoopBack { slot, ivar, step, end, back } => {
                    let v = self.slots[*slot as usize] + *step;
                    self.slots[*slot as usize] = v;
                    if v < *end {
                        self.check_limits()?;
                        self.m.sregs[*ivar as usize] = v;
                        self.stats.scalar_ops += LOOP_OVERHEAD;
                        pc = *back as usize;
                    } else {
                        pc += 1;
                    }
                }
                DecodedOp::Scalar { idx } => {
                    let b = &dec.scalars[*idx as usize];
                    exec_scalar_block(&mut self.m, &self.prog.bufs, &mut self.stats, b)
                        .map_err(|t| {
                            t.at_pc(pc).in_kernel(&self.prog.name).on_engine("decoded")
                        })?;
                    pc += 1;
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::ir::{AddrExpr, BufDecl};
    use crate::neon::elem::Elem;
    use crate::rvv::ops::{Dst, MemRef, RvvInst, RvvKind, Src};
    use crate::rvv::program::RStmt;
    use crate::sim::decode::decode;
    use crate::sim::Simulator;

    /// A looped saxpy-style program exercising loads, stores, arithmetic,
    /// address expressions and loop control.
    fn looped_program() -> RvvProgram {
        let vle = |dst, buf| {
            RStmt::Op(RvvInst {
                kind: RvvKind::Vle,
                sew: Sew::E32,
                lmul: Lmul::M1,
                vl: 4,
                dst: Dst::V(dst),
                srcs: vec![],
                mask: None,
                mem: Some(MemRef { buf, index: AddrExpr::s(0), stride: 1 }),
            })
        };
        RvvProgram {
            name: "loop_add".into(),
            bufs: vec![
                BufDecl { name: "A".into(), elem: Elem::I32, len: 16, kind: BufKind::Input },
                BufDecl { name: "B".into(), elem: Elem::I32, len: 16, kind: BufKind::Input },
                BufDecl { name: "O".into(), elem: Elem::I32, len: 16, kind: BufKind::Output },
            ],
            body: vec![RStmt::Loop {
                ivar: 0,
                start: 0,
                end: 16,
                step: 4,
                body: vec![
                    vle(0, 0),
                    vle(1, 1),
                    RStmt::Op(RvvInst {
                        kind: RvvKind::Vmacc,
                        sew: Sew::E32,
                        lmul: Lmul::M1,
                        vl: 4,
                        dst: Dst::V(1),
                        srcs: vec![Src::V(0), Src::V(0)],
                        mask: None,
                        mem: None,
                    }),
                    RStmt::Op(RvvInst {
                        kind: RvvKind::Vse,
                        sew: Sew::E32,
                        lmul: Lmul::M1,
                        vl: 4,
                        dst: Dst::None,
                        srcs: vec![Src::V(1)],
                        mask: None,
                        mem: Some(MemRef { buf: 2, index: AddrExpr::s(0), stride: 1 }),
                    }),
                ],
            }],
            n_vregs: 2,
            n_mregs: 0,
            n_sregs: 1,
        }
    }

    #[test]
    fn engine_matches_interpreter_on_looped_program() {
        let p = looped_program();
        let mut inputs = Inputs::new();
        inputs.insert("A".into(), Buffer::from_i32s(&(0..16).collect::<Vec<_>>()));
        inputs.insert("B".into(), Buffer::from_i32s(&(100..116).collect::<Vec<_>>()));
        let cfg = RvvConfig::new(128);

        let (ref_out, ref_stats) =
            Simulator::new(&p, cfg, &inputs).unwrap().run().unwrap();
        let dec = decode(&p);
        let (out, stats) = Engine::new(&p, &dec, cfg, &inputs).unwrap().run().unwrap();

        assert_eq!(out["O"].as_i32s(), ref_out["O"].as_i32s());
        assert_eq!(stats, ref_stats);
        // sanity: b[i] + a[i]*a[i]
        assert_eq!(out["O"].as_i32s()[5], 105 + 25);
    }

    #[test]
    fn zero_trip_loop_skips_body() {
        let mut p = looped_program();
        if let RStmt::Loop { end, .. } = &mut p.body[0] {
            *end = 0;
        }
        let mut inputs = Inputs::new();
        inputs.insert("A".into(), Buffer::from_i32s(&[0; 16]));
        inputs.insert("B".into(), Buffer::from_i32s(&[0; 16]));
        let cfg = RvvConfig::new(128);
        let dec = decode(&p);
        let (out, stats) = Engine::new(&p, &dec, cfg, &inputs).unwrap().run().unwrap();
        assert_eq!(stats.total(), 0);
        assert_eq!(out["O"].as_i32s(), vec![0; 16]);
    }
}
