//! Dynamic instruction-count statistics — the paper's performance metric
//! ("Since Spike is a functional model rather than a cycle-accurate
//! simulator, we employed dynamic instruction count", §4.2).

use std::collections::BTreeMap;

use crate::rvv::vtype::Lmul;

/// Modelled loop overhead per iteration (induction increment + branch),
/// identical for both translation modes.
pub const LOOP_OVERHEAD: u64 = 2;

/// Upper bound on RvvKind discriminants (fieldless enum).
const MAX_KINDS: usize = 128;

/// Dynamic instruction counts from one simulated run.
///
/// The per-opcode histogram is a flat array indexed by the opcode
/// discriminant — a BTreeMap entry per *dynamic* instruction was the
/// simulator's top hot spot (see EXPERIMENTS.md §Perf P1).
///
/// `PartialEq`/`Eq` compare every counter including the per-opcode
/// histogram — the differential test uses this to assert the decoded
/// engine reproduces the interpreter's metric exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimStats {
    /// RVV vector-arithmetic/permute/mask instructions.
    pub vector_ops: u64,
    /// RVV vector loads + stores.
    pub vector_mem: u64,
    /// `vsetvli` instructions (inserted on vtype/vl change).
    pub vsetvli: u64,
    /// Scalar ALU instructions (address arithmetic, loop overhead,
    /// scalar-fallback compute).
    pub scalar_ops: u64,
    /// Scalar loads/stores (scalar-fallback element traffic).
    pub scalar_mem: u64,
    /// Dynamic vector instructions by register grouping, indexed by
    /// [`Lmul::index`] — shows how much of a tuned kernel actually ran
    /// grouped (`m2`/`m4`) vs at the translator's static `m1`.
    pub by_lmul: [u64; Lmul::COUNT],
    counts: Box<[u64; MAX_KINDS]>,
    names: Box<[Option<&'static str>; MAX_KINDS]>,
}

impl Default for SimStats {
    fn default() -> SimStats {
        SimStats {
            vector_ops: 0,
            vector_mem: 0,
            vsetvli: 0,
            scalar_ops: 0,
            scalar_mem: 0,
            by_lmul: [0; Lmul::COUNT],
            counts: Box::new([0; MAX_KINDS]),
            names: Box::new([None; MAX_KINDS]),
        }
    }
}

impl SimStats {
    /// Total dynamic instruction count (the Figure 2 metric).
    pub fn total(&self) -> u64 {
        self.vector_ops + self.vector_mem + self.vsetvli + self.scalar_ops + self.scalar_mem
    }

    #[inline]
    pub fn record_vector(&mut self, kind_idx: usize, mnemonic: &'static str, is_mem: bool, lmul: Lmul) {
        if is_mem {
            self.vector_mem += 1;
        } else {
            self.vector_ops += 1;
        }
        self.by_lmul[lmul.index()] += 1;
        debug_assert!(kind_idx < MAX_KINDS);
        self.counts[kind_idx] += 1;
        if self.names[kind_idx].is_none() {
            self.names[kind_idx] = Some(mnemonic);
        }
    }

    /// Per-mnemonic histogram of vector instructions.
    pub fn histogram(&self) -> BTreeMap<&'static str, u64> {
        let mut m = BTreeMap::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                if let Some(n) = self.names[i] {
                    *m.entry(n).or_insert(0) += c;
                }
            }
        }
        m
    }

    pub fn merge(&mut self, o: &SimStats) {
        self.vector_ops += o.vector_ops;
        self.vector_mem += o.vector_mem;
        self.vsetvli += o.vsetvli;
        self.scalar_ops += o.scalar_ops;
        self.scalar_mem += o.scalar_mem;
        for i in 0..Lmul::COUNT {
            self.by_lmul[i] += o.by_lmul[i];
        }
        for i in 0..MAX_KINDS {
            self.counts[i] += o.counts[i];
            if self.names[i].is_none() {
                self.names[i] = o.names[i];
            }
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        let mut s = format!(
            "total={} (vec={} vmem={} vsetvli={} scalar={} smem={})",
            self.total(),
            self.vector_ops,
            self.vector_mem,
            self.vsetvli,
            self.scalar_ops,
            self.scalar_mem
        );
        // grouped execution is the exception worth surfacing; all-m1 runs
        // keep the line unchanged from previous PRs
        let grouped: Vec<String> = [Lmul::MF2, Lmul::M2, Lmul::M4, Lmul::M8]
            .into_iter()
            .filter(|l| self.by_lmul[l.index()] > 0)
            .map(|l| format!("{}={}", l.asm(), self.by_lmul[l.index()]))
            .collect();
        if !grouped.is_empty() {
            s.push_str(&format!(" lmul[{}]", grouped.join(" ")));
        }
        s
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_add_up() {
        let mut s = SimStats::default();
        s.record_vector(4, "vadd", false, Lmul::M1);
        s.record_vector(0, "vle", true, Lmul::M1);
        s.vsetvli += 1;
        s.scalar_ops += 3;
        s.scalar_mem += 2;
        assert_eq!(s.total(), 8);
        assert_eq!(s.histogram()["vadd"], 1);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = SimStats::default();
        a.record_vector(4, "vadd", false, Lmul::M1);
        let mut b = SimStats::default();
        b.record_vector(4, "vadd", false, Lmul::M1);
        b.record_vector(1, "vse", true, Lmul::M2);
        a.merge(&b);
        assert_eq!(a.vector_ops, 2);
        assert_eq!(a.vector_mem, 1);
        assert_eq!(a.histogram()["vadd"], 2);
        assert_eq!(a.by_lmul[Lmul::M1.index()], 2);
        assert_eq!(a.by_lmul[Lmul::M2.index()], 1);
    }

    #[test]
    fn grouped_counts_surface_in_summary() {
        let mut s = SimStats::default();
        s.record_vector(4, "vadd", false, Lmul::M1);
        assert!(!s.summary().contains("lmul["));
        s.record_vector(4, "vadd", false, Lmul::M2);
        s.record_vector(4, "vadd", false, Lmul::M4);
        assert!(s.summary().contains("lmul[m2=1 m4=1]"), "{}", s.summary());
    }
}
