//! Dynamic instruction-count statistics — the paper's performance metric
//! ("Since Spike is a functional model rather than a cycle-accurate
//! simulator, we employed dynamic instruction count", §4.2).

use std::collections::BTreeMap;

/// Modelled loop overhead per iteration (induction increment + branch),
/// identical for both translation modes.
pub const LOOP_OVERHEAD: u64 = 2;

/// Upper bound on RvvKind discriminants (fieldless enum).
const MAX_KINDS: usize = 128;

/// Dynamic instruction counts from one simulated run.
///
/// The per-opcode histogram is a flat array indexed by the opcode
/// discriminant — a BTreeMap entry per *dynamic* instruction was the
/// simulator's top hot spot (see EXPERIMENTS.md §Perf P1).
///
/// `PartialEq`/`Eq` compare every counter including the per-opcode
/// histogram — the differential test uses this to assert the decoded
/// engine reproduces the interpreter's metric exactly.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimStats {
    /// RVV vector-arithmetic/permute/mask instructions.
    pub vector_ops: u64,
    /// RVV vector loads + stores.
    pub vector_mem: u64,
    /// `vsetvli` instructions (inserted on vtype/vl change).
    pub vsetvli: u64,
    /// Scalar ALU instructions (address arithmetic, loop overhead,
    /// scalar-fallback compute).
    pub scalar_ops: u64,
    /// Scalar loads/stores (scalar-fallback element traffic).
    pub scalar_mem: u64,
    counts: Box<[u64; MAX_KINDS]>,
    names: Box<[Option<&'static str>; MAX_KINDS]>,
}

impl Default for SimStats {
    fn default() -> SimStats {
        SimStats {
            vector_ops: 0,
            vector_mem: 0,
            vsetvli: 0,
            scalar_ops: 0,
            scalar_mem: 0,
            counts: Box::new([0; MAX_KINDS]),
            names: Box::new([None; MAX_KINDS]),
        }
    }
}

impl SimStats {
    /// Total dynamic instruction count (the Figure 2 metric).
    pub fn total(&self) -> u64 {
        self.vector_ops + self.vector_mem + self.vsetvli + self.scalar_ops + self.scalar_mem
    }

    #[inline]
    pub fn record_vector(&mut self, kind_idx: usize, mnemonic: &'static str, is_mem: bool) {
        if is_mem {
            self.vector_mem += 1;
        } else {
            self.vector_ops += 1;
        }
        debug_assert!(kind_idx < MAX_KINDS);
        self.counts[kind_idx] += 1;
        if self.names[kind_idx].is_none() {
            self.names[kind_idx] = Some(mnemonic);
        }
    }

    /// Per-mnemonic histogram of vector instructions.
    pub fn histogram(&self) -> BTreeMap<&'static str, u64> {
        let mut m = BTreeMap::new();
        for (i, &c) in self.counts.iter().enumerate() {
            if c > 0 {
                if let Some(n) = self.names[i] {
                    *m.entry(n).or_insert(0) += c;
                }
            }
        }
        m
    }

    pub fn merge(&mut self, o: &SimStats) {
        self.vector_ops += o.vector_ops;
        self.vector_mem += o.vector_mem;
        self.vsetvli += o.vsetvli;
        self.scalar_ops += o.scalar_ops;
        self.scalar_mem += o.scalar_mem;
        for i in 0..MAX_KINDS {
            self.counts[i] += o.counts[i];
            if self.names[i].is_none() {
                self.names[i] = o.names[i];
            }
        }
    }

    /// One-line summary for logs.
    pub fn summary(&self) -> String {
        format!(
            "total={} (vec={} vmem={} vsetvli={} scalar={} smem={})",
            self.total(),
            self.vector_ops,
            self.vector_mem,
            self.vsetvli,
            self.scalar_ops,
            self.scalar_mem
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn totals_add_up() {
        let mut s = SimStats::default();
        s.record_vector(4, "vadd", false);
        s.record_vector(0, "vle", true);
        s.vsetvli += 1;
        s.scalar_ops += 3;
        s.scalar_mem += 2;
        assert_eq!(s.total(), 8);
        assert_eq!(s.histogram()["vadd"], 1);
    }

    #[test]
    fn merge_accumulates() {
        let mut a = SimStats::default();
        a.record_vector(4, "vadd", false);
        let mut b = SimStats::default();
        b.record_vector(4, "vadd", false);
        b.record_vector(1, "vse", true);
        a.merge(&b);
        assert_eq!(a.vector_ops, 2);
        assert_eq!(a.vector_mem, 1);
        assert_eq!(a.histogram()["vadd"], 2);
    }
}
