//! Decode pass of the pre-decoded execution engine: flattens an
//! [`RvvProgram`]'s statement tree into a linear [`DecodedOp`] stream that
//! the [`crate::sim::Engine`] executes with a program counter.
//!
//! What is precomputed, once per (kernel, mode, vlen):
//!
//! - **control flow** — `Loop` statements become `LoopStart`/`LoopBack`
//!   ops with PC targets (back-edges), replacing the interpreter's
//!   recursive `exec_block` walk;
//! - **addresses** — every `AddrExpr` (memory-operand indices and `SSet`
//!   right-hand sides) is compiled to an affine [`AffineAddr`]
//!   `base + Σ coef·sreg` form, with the buffer's element-byte scale
//!   folded into the coefficients for memory operands;
//! - **vsetvli decisions** — each instruction's `(sew, lmul, vl)` demand is
//!   analysed statically: inside a straight-line run whose predecessor
//!   already established the same configuration, the runtime
//!   `vsetvli` check is elided entirely (`check_cfg = false`). At control
//!   -flow joins (loop-body entry, loop exit) the static state is
//!   invalidated so the runtime check — identical to the interpreter's —
//!   decides, keeping the paper's vsetvli-churn metric exact;
//! - **stats metadata** — opcode discriminant, mnemonic and
//!   load/store-ness are captured per decoded instruction so the hot loop
//!   does no per-op classification.
//!
//! Decoding is semantics-preserving by construction: the engine's
//! differential test checks bit-identical output buffers and equal
//! [`crate::sim::SimStats`] against the tree-walking interpreter.

use crate::ir::AddrExpr;
use crate::rvv::ops::RvvInst;
use crate::rvv::program::{RStmt, RvvProgram, ScalarBlock};
use crate::rvv::vtype::{Lmul, Sew};

/// An affine integer expression `base + Σ coef·sreg`, precompiled from an
/// [`AddrExpr`] tree. Evaluation is a flat multiply-accumulate loop
/// instead of a recursive tree walk.
#[derive(Debug, Clone)]
pub struct AffineAddr {
    pub base: i64,
    /// (scalar register, coefficient) terms; deduplicated, zero terms
    /// dropped.
    pub terms: Vec<(u32, i64)>,
}

impl AffineAddr {
    /// Compile `expr * scale` into affine form. Distributing the scale
    /// over the tree is exact in wrapping arithmetic, so the result
    /// matches `expr.eval(sregs) * scale` for every `sregs`.
    pub fn compile(expr: &AddrExpr, scale: i64) -> AffineAddr {
        let mut a = AffineAddr { base: 0, terms: Vec::new() };
        a.absorb(expr, scale);
        a.terms.sort_by_key(|t| t.0);
        a.terms.dedup_by(|cur, prev| {
            if cur.0 == prev.0 {
                prev.1 += cur.1;
                true
            } else {
                false
            }
        });
        a.terms.retain(|t| t.1 != 0);
        a
    }

    fn absorb(&mut self, expr: &AddrExpr, scale: i64) {
        match expr {
            AddrExpr::Const(v) => self.base += v * scale,
            AddrExpr::SReg(r) => self.terms.push((*r, scale)),
            AddrExpr::Add(a, b) => {
                self.absorb(a, scale);
                self.absorb(b, scale);
            }
            AddrExpr::Mul(a, k) => self.absorb(a, scale * k),
        }
    }

    #[inline]
    pub fn eval(&self, sregs: &[i64]) -> i64 {
        let mut v = self.base;
        for &(r, c) in &self.terms {
            v += sregs[r as usize] * c;
        }
        v
    }
}

/// One pre-decoded RVV instruction with its execution metadata.
#[derive(Debug, Clone)]
pub struct DecodedInst {
    pub inst: RvvInst,
    /// Precompiled memory-operand byte offset (element-byte scale folded
    /// in), for loads/stores.
    pub mem: Option<AffineAddr>,
    /// The `(sew, lmul, vl)` configuration this instruction demands.
    /// Grouped (`m2`/`m4`) instructions decode like any other — the lane
    /// batch simply spans the whole register group, so tuned `lmul:F`
    /// kernels stay on the batched fast path.
    pub want: (Sew, Lmul, u32),
    /// Opcode discriminant + mnemonic + memory-op flag for stats
    /// recording without per-op classification.
    pub kind_idx: usize,
    pub mnemonic: &'static str,
    pub is_mem: bool,
}

/// One op of the linear decoded stream.
#[derive(Debug, Clone)]
pub enum DecodedOp {
    /// Execute `insts[idx]`. `check_cfg = false` means the decode pass
    /// proved the current configuration already matches (no vsetvli
    /// possible); `true` means compare at runtime and count a vsetvli on
    /// change, exactly like the interpreter.
    Inst { idx: u32, check_cfg: bool },
    /// `sregs[dst] = addr(sregs)` — one scalar instruction.
    SSet { dst: u32, addr: AffineAddr },
    /// Loop header: initialise trip counter `slot` to `start`; if
    /// `start >= end` jump to `exit`, else publish the induction variable
    /// and fall through into the body.
    LoopStart { slot: u32, ivar: u32, start: i64, end: i64, exit: u32 },
    /// Loop latch: step trip counter `slot`; while `< end`, publish the
    /// induction variable and jump back to `back` (the body head), else
    /// fall through out of the loop.
    LoopBack { slot: u32, ivar: u32, step: i64, end: i64, back: u32 },
    /// SIMDe generic-path scalar fallback `scalars[idx]` (baseline mode).
    Scalar { idx: u32 },
}

/// A fully decoded program: the reusable artifact cached per
/// (kernel, mode, vlen) by the coordinator's translation cache.
#[derive(Debug, Clone)]
pub struct DecodedProgram {
    pub ops: Vec<DecodedOp>,
    pub insts: Vec<DecodedInst>,
    pub scalars: Vec<ScalarBlock>,
    /// Number of loop trip-counter slots the engine must allocate.
    pub n_loop_slots: usize,
}

impl DecodedProgram {
    /// Number of ops in the linear stream (for reports/tests).
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }
}

/// Flatten `prog` into a linear decoded stream. Pure function of the
/// program — decode once, execute any number of times.
pub fn decode(prog: &RvvProgram) -> DecodedProgram {
    let mut d = Decoder {
        prog,
        out: DecodedProgram {
            ops: Vec::new(),
            insts: Vec::new(),
            scalars: Vec::new(),
            n_loop_slots: 0,
        },
        cur: None,
    };
    d.walk(&prog.body);
    d.out
}

struct Decoder<'p> {
    prog: &'p RvvProgram,
    out: DecodedProgram,
    /// Statically-known `(sew, lmul, vl)` configuration at the current
    /// decode point; `None` at control-flow joins.
    cur: Option<(Sew, Lmul, u32)>,
}

impl Decoder<'_> {
    fn walk(&mut self, stmts: &[RStmt]) {
        for s in stmts {
            match s {
                RStmt::Op(inst) => {
                    let want = (inst.sew, inst.lmul, inst.vl);
                    let check_cfg = self.cur != Some(want);
                    self.cur = Some(want);
                    let mem = inst.mem.as_ref().map(|mref| {
                        let scale = self.prog.bufs[mref.buf as usize].elem.bytes() as i64;
                        AffineAddr::compile(&mref.index, scale)
                    });
                    let idx = self.out.insts.len() as u32;
                    self.out.insts.push(DecodedInst {
                        inst: inst.clone(),
                        mem,
                        want,
                        kind_idx: inst.kind as usize,
                        mnemonic: inst.kind.mnemonic(),
                        is_mem: inst.kind.is_load() || inst.kind.is_store(),
                    });
                    self.out.ops.push(DecodedOp::Inst { idx, check_cfg });
                }
                RStmt::SSet { dst, expr } => {
                    self.out.ops.push(DecodedOp::SSet {
                        dst: *dst,
                        addr: AffineAddr::compile(expr, 1),
                    });
                }
                RStmt::Loop { ivar, start, end, step, body } => {
                    let slot = self.out.n_loop_slots as u32;
                    self.out.n_loop_slots += 1;
                    let head = self.out.ops.len();
                    // placeholder; exit patched once the body is decoded
                    self.out.ops.push(DecodedOp::LoopStart {
                        slot,
                        ivar: *ivar,
                        start: *start,
                        end: *end,
                        exit: u32::MAX,
                    });
                    // body entry is a join (fallthrough + back-edge)
                    self.cur = None;
                    self.walk(body);
                    let latch = self.out.ops.len();
                    self.out.ops.push(DecodedOp::LoopBack {
                        slot,
                        ivar: *ivar,
                        step: *step,
                        end: *end,
                        back: (head + 1) as u32,
                    });
                    if let DecodedOp::LoopStart { exit, .. } = &mut self.out.ops[head] {
                        *exit = (latch + 1) as u32;
                    }
                    // loop exit is a join (zero-trip jump + latch exit)
                    self.cur = None;
                }
                RStmt::Scalar(b) => {
                    // scalar fallbacks never touch vtype — `cur` unchanged
                    let idx = self.out.scalars.len() as u32;
                    self.out.scalars.push(b.clone());
                    self.out.ops.push(DecodedOp::Scalar { idx });
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::ir::{BufDecl, BufKind};
    use crate::neon::elem::Elem;
    use crate::rvv::ops::{Dst, MemRef, RvvInst, RvvKind, Src};

    #[test]
    fn affine_matches_tree_eval() {
        // i*16 + j*4 + 3, scaled by 4 bytes
        let e = AddrExpr::s(0).mul(16).add(AddrExpr::s(1).mul(4)).addk(3);
        let a = AffineAddr::compile(&e, 4);
        for sregs in [[0i64, 0], [2, 1], [7, 3], [-1, 5]] {
            assert_eq!(a.eval(&sregs), e.eval(&sregs) * 4);
        }
        // duplicate sregs fold into one term
        let e2 = AddrExpr::s(0).add(AddrExpr::s(0).mul(3));
        let a2 = AffineAddr::compile(&e2, 1);
        assert_eq!(a2.terms, vec![(0, 4)]);
        // cancelled terms are dropped
        let e3 = AddrExpr::s(1).add(AddrExpr::s(1).mul(-1));
        let a3 = AffineAddr::compile(&e3, 1);
        assert!(a3.terms.is_empty());
    }

    fn op(sew: Sew, vl: u32) -> RStmt {
        RStmt::Op(RvvInst {
            kind: RvvKind::VmvVX,
            sew,
            lmul: Lmul::M1,
            vl,
            dst: Dst::V(0),
            srcs: vec![Src::ImmI(1)],
            mask: None,
            mem: None,
        })
    }

    #[test]
    fn vsetvli_checks_elided_in_straight_line_runs() {
        let p = RvvProgram {
            name: "t".into(),
            bufs: vec![],
            body: vec![op(Sew::E32, 4), op(Sew::E32, 4), op(Sew::E8, 16), op(Sew::E8, 16)],
            n_vregs: 1,
            n_mregs: 0,
            n_sregs: 0,
        };
        let d = decode(&p);
        let checks: Vec<bool> = d
            .ops
            .iter()
            .map(|o| match o {
                DecodedOp::Inst { check_cfg, .. } => *check_cfg,
                _ => panic!("expected insts"),
            })
            .collect();
        assert_eq!(checks, vec![true, false, true, false]);
    }

    #[test]
    fn loop_body_and_exit_are_joins() {
        let p = RvvProgram {
            name: "t".into(),
            bufs: vec![],
            body: vec![
                op(Sew::E32, 4),
                RStmt::Loop { ivar: 0, start: 0, end: 2, step: 1, body: vec![op(Sew::E32, 4)] },
                op(Sew::E32, 4),
            ],
            n_vregs: 1,
            n_mregs: 0,
            n_sregs: 1,
        };
        let d = decode(&p);
        assert_eq!(d.n_loop_slots, 1);
        assert_eq!(d.ops.len(), 5); // op, LoopStart, op, LoopBack, op
        // same config everywhere, but the body op and the post-loop op sit
        // at joins so they must keep the runtime check
        let check = |i: usize| match &d.ops[i] {
            DecodedOp::Inst { check_cfg, .. } => *check_cfg,
            o => panic!("op {i}: expected inst, got {o:?}"),
        };
        assert!(check(0));
        assert!(check(2), "loop-body head keeps the runtime vsetvli check");
        assert!(check(4), "loop exit keeps the runtime vsetvli check");
        // branch targets line up
        match (&d.ops[1], &d.ops[3]) {
            (
                DecodedOp::LoopStart { exit, .. },
                DecodedOp::LoopBack { back, .. },
            ) => {
                assert_eq!(*exit, 4);
                assert_eq!(*back, 2);
            }
            other => panic!("unexpected shape {other:?}"),
        }
    }

    #[test]
    fn mem_offsets_are_byte_scaled() {
        let p = RvvProgram {
            name: "t".into(),
            bufs: vec![BufDecl { name: "A".into(), elem: Elem::I32, len: 16, kind: BufKind::Input }],
            body: vec![RStmt::Op(RvvInst {
                kind: RvvKind::Vle,
                sew: Sew::E32,
                lmul: Lmul::M1,
                vl: 4,
                dst: Dst::V(0),
                srcs: vec![],
                mask: None,
                mem: Some(MemRef { buf: 0, index: AddrExpr::s(0).addk(2), stride: 1 }),
            })],
            n_vregs: 1,
            n_mregs: 0,
            n_sregs: 1,
        };
        let d = decode(&p);
        let mem = d.insts[0].mem.as_ref().unwrap();
        // element index (s0 + 2) * 4 bytes
        assert_eq!(mem.eval(&[3]), 20);
        assert_eq!(mem.base, 8);
        assert_eq!(mem.terms, vec![(0, 4)]);
    }
}
