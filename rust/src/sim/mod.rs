//! Spike-like functional simulator: executes translated RVV programs
//! and reports the dynamic instruction counts behind Figure 2.

pub mod cpu;
pub mod stats;

pub use cpu::Simulator;
pub use stats::SimStats;
