//! Spike-like functional simulator: executes translated RVV programs
//! and reports the dynamic instruction counts behind Figure 2.
//!
//! # Execution engines
//!
//! Two observationally-identical engines execute an
//! [`crate::rvv::program::RvvProgram`]:
//!
//! - [`Simulator`] (`cpu.rs`) — the reference **tree-walking
//!   interpreter**: recursive statement walk, per-lane register access,
//!   address-expression trees evaluated on every use. Simple and obviously
//!   faithful to the paper's semantics; kept as the differential-testing
//!   oracle.
//! - [`Engine`] (`engine.rs`) — the **pre-decoded engine** used by the
//!   harness. [`decode`] (`decode.rs`) flattens the program once per
//!   (kernel, mode, vlen) into a linear [`DecodedProgram`]: loops become
//!   PC-based back-edges, `AddrExpr` trees become affine
//!   `base + Σ coef·sreg` forms with byte scaling folded in, and vsetvli
//!   checks are elided where the configuration is statically known. The
//!   engine then executes with a flat PC loop and **lane-batched**
//!   instruction semantics ([`crate::rvv::exec::exec_batched`]):
//!   element-wise families gather operands into typed scratch slices,
//!   compute in a tight loop, and scatter once — instead of per-lane
//!   8-byte `read_lane`/`write_lane` round-trips per operand.
//!
//! The contract between them is exact: bit-identical output buffers and
//! equal [`SimStats`] (vsetvli churn included), enforced by
//! `tests/engine_differential.rs`. Decoded programs are cached and shared
//! across jobs by the coordinator's translation cache
//! (see [`crate::coordinator`]).
//!
//! Scalar-fallback blocks (SIMDe generic paths) execute through one shared
//! implementation (`scalar.rs`) in both engines, so numerics and cost
//! accounting cannot drift.
//!
//! # Trap model
//!
//! Execution faults do not panic: both engines propagate structured
//! [`SimTrap`]s (see [`crate::rvv::trap`]) and enrich them with kernel
//! name, engine kind (`"interp"` / `"decoded"`), a PC, and the offending
//! instruction's debug render. The PC means different things per engine —
//! for [`Engine`] it is the static index into the decoded op stream, for
//! [`Simulator`] the dynamic index of the executed statement — but for
//! straight-line programs the two coincide. Recover a trap from an
//! `anyhow::Error` with `err.downcast_ref::<SimTrap>()`; the coordinator
//! does exactly this to build `FaultRecord`s
//! (see [`crate::coordinator`]).
//!
//! # Fuel
//!
//! Every execution is bounded by an [`ExecLimits`] (`limits.rs`): a
//! dynamic-instruction budget derived from the program's static shape by
//! default, plus an optional wall-clock deadline. Both engines check the
//! bounds at loop iterations; exhaustion raises
//! `TrapKind::FuelExhausted`/`DeadlineExceeded`, so a runaway back-edge
//! degrades to a `FaultRecord` instead of hanging a worker thread.
//! Construct with `Simulator::with_limits` / `Engine::with_limits` to
//! override the default budget.

#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod cpu;
pub mod decode;
pub mod engine;
pub mod limits;
pub(crate) mod scalar;
pub mod stats;

pub use cpu::Simulator;
pub use decode::{decode, AffineAddr, DecodedOp, DecodedProgram};
pub use engine::Engine;
pub use limits::ExecLimits;
pub use stats::SimStats;
pub use crate::rvv::trap::{SimTrap, TrapKind};
