//! The Spike-like functional simulator: executes a translated
//! [`RvvProgram`] on an [`RvvMachine`], producing output buffers and the
//! dynamic instruction count (the paper's §4 metric).
//!
//! `vsetvli` insertion follows compiler behaviour: one `vsetvli` is counted
//! whenever the (SEW, vl) configuration demanded by an instruction differs
//! from the current one — this is how baseline SIMDe's constant churn
//! between `e8` memcpy traffic and typed compute shows up as overhead.

use std::collections::HashMap;

use anyhow::{Context, Result};

use crate::ir::BufKind;
use crate::neon::interp::{Buffer, Inputs};
use crate::rvv::exec::exec;
use crate::rvv::machine::{RvvConfig, RvvMachine};
use crate::rvv::program::{RStmt, RvvProgram};
use crate::rvv::trap::SimTrap;
use crate::rvv::vtype::{Lmul, Sew};
use super::limits::ExecLimits;
use super::scalar::exec_scalar_block;
use super::stats::{SimStats, LOOP_OVERHEAD};

/// Simulator over one program execution.
pub struct Simulator<'p> {
    prog: &'p RvvProgram,
    m: RvvMachine,
    /// current (sew, lmul, vl) configuration, None = unconfigured
    vcfg: Option<(Sew, Lmul, u32)>,
    /// dynamic index of the executed statement (vector ops and scalar
    /// blocks) — attached to traps as their `pc`
    op_index: usize,
    /// fuel / deadline bounds, checked at loop iterations
    limits: ExecLimits,
    started: std::time::Instant,
    pub stats: SimStats,
}

impl<'p> Simulator<'p> {
    /// Build with the default fuel budget derived from the program's
    /// static shape ([`ExecLimits::for_program`]).
    pub fn new(prog: &'p RvvProgram, cfg: RvvConfig, inputs: &Inputs) -> Result<Simulator<'p>> {
        Simulator::with_limits(prog, cfg, inputs, ExecLimits::for_program(prog))
    }

    pub fn with_limits(
        prog: &'p RvvProgram,
        cfg: RvvConfig,
        inputs: &Inputs,
        limits: ExecLimits,
    ) -> Result<Simulator<'p>> {
        let mut bufs = Vec::with_capacity(prog.bufs.len());
        for decl in &prog.bufs {
            let b = match decl.kind {
                BufKind::Input => inputs
                    .get(&decl.name)
                    .with_context(|| format!("missing input '{}'", decl.name))?
                    .clone(),
                _ => Buffer::zeros(decl.elem, decl.len),
            };
            bufs.push(b);
        }
        let m = RvvMachine::new(cfg, prog.n_vregs, prog.n_mregs, prog.n_sregs, bufs);
        Ok(Simulator {
            prog,
            m,
            vcfg: None,
            op_index: 0,
            limits,
            started: std::time::Instant::now(),
            stats: SimStats::default(),
        })
    }

    /// Fuel / deadline check, run once per loop iteration (straight-line
    /// code is statically bounded, so per-op checks would only add cost).
    fn check_limits(&self) -> Result<()> {
        if self.stats.total() >= self.limits.max_dyn_insts {
            return Err(SimTrap::fuel_exhausted(format!(
                "dynamic-instruction budget of {} exhausted",
                self.limits.max_dyn_insts
            ))
            .in_kernel(&self.prog.name)
            .on_engine("interp")
            .into());
        }
        if let Some(d) = self.limits.wall_deadline {
            if self.started.elapsed() >= d {
                return Err(SimTrap::deadline_exceeded(format!(
                    "wall-clock deadline of {d:?} passed"
                ))
                .in_kernel(&self.prog.name)
                .on_engine("interp")
                .into());
            }
        }
        Ok(())
    }

    /// Run to completion, returning output buffers by name.
    pub fn run(mut self) -> Result<(HashMap<String, Buffer>, SimStats)> {
        self.exec_block(&self.prog.body)?;
        let mut out = HashMap::new();
        for (decl, buf) in self.prog.bufs.iter().zip(self.m.bufs) {
            if decl.kind == BufKind::Output {
                out.insert(decl.name.clone(), buf);
            }
        }
        Ok((out, self.stats))
    }

    fn exec_block(&mut self, stmts: &'p [RStmt]) -> Result<()> {
        for s in stmts {
            match s {
                RStmt::Op(inst) => {
                    // vsetvli on configuration change
                    let want = (inst.sew, inst.lmul, inst.vl);
                    if self.vcfg != Some(want) {
                        self.stats.vsetvli += 1;
                        self.vcfg = Some(want);
                    }
                    let mem_off = match &inst.mem {
                        Some(mref) => {
                            let elem_idx = mref.index.eval(&self.m.sregs);
                            let decl = &self.prog.bufs[mref.buf as usize];
                            Some(elem_idx * decl.elem.bytes() as i64)
                        }
                        None => None,
                    };
                    let pc = self.op_index;
                    self.op_index += 1;
                    exec(&mut self.m, inst, mem_off).map_err(|t| {
                        t.at_pc(pc)
                            .with_inst(inst.asm())
                            .in_kernel(&self.prog.name)
                            .on_engine("interp")
                    })?;
                    self.stats.record_vector(
                        inst.kind as usize,
                        inst.kind.mnemonic(),
                        inst.kind.is_load() || inst.kind.is_store(),
                        inst.lmul,
                    );
                }
                RStmt::SSet { dst, expr } => {
                    self.m.sregs[*dst as usize] = expr.eval(&self.m.sregs);
                    self.stats.scalar_ops += 1;
                }
                RStmt::Loop { ivar, start, end, step, body } => {
                    let mut i = *start;
                    while i < *end {
                        self.check_limits()?;
                        self.m.sregs[*ivar as usize] = i;
                        self.stats.scalar_ops += LOOP_OVERHEAD;
                        self.exec_block(body)?;
                        i += step;
                    }
                }
                RStmt::Scalar(b) => {
                    let pc = self.op_index;
                    self.op_index += 1;
                    exec_scalar_block(&mut self.m, &self.prog.bufs, &mut self.stats, b)
                        .map_err(|t| t.at_pc(pc).in_kernel(&self.prog.name).on_engine("interp"))?
                }
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::ir::AddrExpr;
    use crate::neon::elem::Elem;
    use crate::ir::BufDecl;
    use crate::rvv::ops::{Dst, MemRef, RvvInst, RvvKind, Src};

    fn listing10_program() -> RvvProgram {
        // vsetivli; vle32; vle32; vadd; vse32 — the paper's Listing 10
        let mem = |buf| Some(MemRef { buf, index: AddrExpr::k(0), stride: 1 });
        RvvProgram {
            name: "listing10".into(),
            bufs: vec![
                BufDecl { name: "A".into(), elem: Elem::I32, len: 4, kind: BufKind::Input },
                BufDecl { name: "B".into(), elem: Elem::I32, len: 4, kind: BufKind::Input },
                BufDecl { name: "O".into(), elem: Elem::I32, len: 4, kind: BufKind::Output },
            ],
            body: vec![
                RStmt::Op(RvvInst { kind: RvvKind::Vle, sew: Sew::E32, lmul: Lmul::M1, vl: 4, dst: Dst::V(0), srcs: vec![], mask: None, mem: mem(0) }),
                RStmt::Op(RvvInst { kind: RvvKind::Vle, sew: Sew::E32, lmul: Lmul::M1, vl: 4, dst: Dst::V(1), srcs: vec![], mask: None, mem: mem(1) }),
                RStmt::Op(RvvInst { kind: RvvKind::Vadd, sew: Sew::E32, lmul: Lmul::M1, vl: 4, dst: Dst::V(2), srcs: vec![Src::V(0), Src::V(1)], mask: None, mem: None }),
                RStmt::Op(RvvInst { kind: RvvKind::Vse, sew: Sew::E32, lmul: Lmul::M1, vl: 4, dst: Dst::None, srcs: vec![Src::V(2)], mask: None, mem: mem(2) }),
            ],
            n_vregs: 3,
            n_mregs: 0,
            n_sregs: 0,
        }
    }

    #[test]
    fn listing10_counts_and_results() {
        let p = listing10_program();
        let mut inputs = Inputs::new();
        inputs.insert("A".into(), Buffer::from_i32s(&[0, 1, 2, 3]));
        inputs.insert("B".into(), Buffer::from_i32s(&[4, 5, 6, 7]));
        let sim = Simulator::new(&p, RvvConfig::new(128), &inputs).unwrap();
        let (out, stats) = sim.run().unwrap();
        assert_eq!(out["O"].as_i32s(), vec![4, 6, 8, 10]);
        // one vsetvli (all ops share e32/vl=4), 3 mem ops, 1 arith
        assert_eq!(stats.vsetvli, 1);
        assert_eq!(stats.vector_mem, 3);
        assert_eq!(stats.vector_ops, 1);
        assert_eq!(stats.total(), 5);
    }

    #[test]
    fn vsetvli_churn_counted() {
        // alternating sew forces a vsetvli before every op
        let mut body = Vec::new();
        for i in 0..4 {
            let sew = if i % 2 == 0 { Sew::E8 } else { Sew::E32 };
            body.push(RStmt::Op(RvvInst {
                kind: RvvKind::VmvVX,
                sew,
                lmul: Lmul::M1,
                vl: 4,
                dst: Dst::V(0),
                srcs: vec![Src::ImmI(1)],
                mask: None,
                mem: None,
            }));
        }
        let p = RvvProgram { name: "churn".into(), bufs: vec![], body, n_vregs: 1, n_mregs: 0, n_sregs: 0 };
        let sim = Simulator::new(&p, RvvConfig::new(128), &Inputs::new()).unwrap();
        let (_, stats) = sim.run().unwrap();
        assert_eq!(stats.vsetvli, 4);
    }

    #[test]
    fn loop_overhead_counted() {
        let p = RvvProgram {
            name: "loop".into(),
            bufs: vec![],
            body: vec![RStmt::Loop { ivar: 0, start: 0, end: 10, step: 1, body: vec![] }],
            n_vregs: 0,
            n_mregs: 0,
            n_sregs: 1,
        };
        let sim = Simulator::new(&p, RvvConfig::new(128), &Inputs::new()).unwrap();
        let (_, stats) = sim.run().unwrap();
        assert_eq!(stats.scalar_ops, 10 * LOOP_OVERHEAD);
    }
}
