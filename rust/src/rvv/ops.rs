//! The RVV instruction set modelled by the simulator: opcode kinds,
//! operands, and assembly rendering (used by the quickstart example to
//! print the Listing-10-style instruction stream).

use crate::ir::AddrExpr;
use super::vtype::{Lmul, Sew, VType};

/// RVV opcode kind. Grouped per riscv-v-spec chapters.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum RvvKind {
    // loads/stores (unit-stride)
    Vle,
    Vse,
    /// strided load/store (rs2 = byte stride)
    Vlse,
    Vsse,
    // integer arithmetic
    Vadd,
    Vsub,
    Vrsub,
    Vmul,
    Vmulh,
    Vmulhu,
    Vwmul,
    Vwmulu,
    Vwadd,
    Vwaddu,
    /// integer multiply-add: vd += vs1 * vs2
    Vmacc,
    /// integer multiply-sub: vd -= vs1 * vs2
    Vnmsac,
    /// widening multiply-accumulate: vd(2*sew) += vs1 * vs2
    Vwmacc,
    Vwmaccu,
    Vminu,
    Vmin,
    Vmaxu,
    Vmax,
    // saturating
    Vsadd,
    Vsaddu,
    Vssub,
    Vssubu,
    // bitwise / shifts
    Vand,
    Vor,
    Vxor,
    Vsll,
    Vsrl,
    Vsra,
    Vnsrl,
    Vnsra,
    // moves / merges
    VmvVV,
    VmvVX,
    VfmvVF,
    Vmerge,
    Vfmerge,
    // integer compares -> mask
    Vmseq,
    Vmsne,
    Vmsltu,
    Vmslt,
    Vmsleu,
    Vmsle,
    Vmsgtu,
    Vmsgt,
    // float compares -> mask
    Vmfeq,
    Vmfne,
    Vmflt,
    Vmfle,
    Vmfgt,
    Vmfge,
    // float arithmetic
    Vfadd,
    Vfsub,
    Vfrsub,
    Vfmul,
    Vfdiv,
    Vfrdiv,
    Vfmacc,
    Vfnmacc,
    Vfmsac,
    Vfnmsac,
    Vfmin,
    Vfmax,
    Vfsqrt,
    /// 7-bit reciprocal estimate (modelled with the shared 8-bit estimate,
    /// see `neon::semantics::floatest`)
    Vfrec7,
    Vfrsqrt7,
    Vfsgnj,
    Vfsgnjn,
    Vfsgnjx,
    // conversions
    /// float -> signed int, round-to-nearest-even
    VfcvtXF,
    /// float -> signed int, truncate
    VfcvtRtzXF,
    /// signed int -> float
    VfcvtFX,
    /// unsigned int -> float
    VfcvtFXu,
    /// float -> unsigned int rtz
    VfcvtRtzXuF,
    /// widening float->float (f16->f32, f32->f64)
    VfwcvtFF,
    /// narrowing float->float
    VfncvtFF,
    // widening/narrowing integer converts
    Vzext2,
    Vsext2,
    // permutation
    Vslideup,
    Vslidedown,
    Vslide1down,
    Vrgather,
    Vid,
    Vcompress,
    // mask ops
    Vmand,
    Vmor,
    Vmxor,
    Vmnand,
    // reductions (scalar result in lane 0 of dst)
    Vredsum,
    Vredmax,
    Vredmaxu,
    Vredmin,
    Vredminu,
    Vfredusum,
    Vfredmax,
    Vfredmin,
}

impl RvvKind {
    /// Assembly mnemonic (without operand-form suffix).
    pub fn mnemonic(self) -> &'static str {
        use RvvKind::*;
        match self {
            Vle => "vle",
            Vse => "vse",
            Vlse => "vlse",
            Vsse => "vsse",
            Vadd => "vadd",
            Vsub => "vsub",
            Vrsub => "vrsub",
            Vmul => "vmul",
            Vmulh => "vmulh",
            Vmulhu => "vmulhu",
            Vwmul => "vwmul",
            Vwmulu => "vwmulu",
            Vwadd => "vwadd",
            Vwaddu => "vwaddu",
            Vmacc => "vmacc",
            Vnmsac => "vnmsac",
            Vwmacc => "vwmacc",
            Vwmaccu => "vwmaccu",
            Vminu => "vminu",
            Vmin => "vmin",
            Vmaxu => "vmaxu",
            Vmax => "vmax",
            Vsadd => "vsadd",
            Vsaddu => "vsaddu",
            Vssub => "vssub",
            Vssubu => "vssubu",
            Vand => "vand",
            Vor => "vor",
            Vxor => "vxor",
            Vsll => "vsll",
            Vsrl => "vsrl",
            Vsra => "vsra",
            Vnsrl => "vnsrl",
            Vnsra => "vnsra",
            VmvVV => "vmv.v.v",
            VmvVX => "vmv.v.x",
            VfmvVF => "vfmv.v.f",
            Vmerge => "vmerge",
            Vfmerge => "vfmerge",
            Vmseq => "vmseq",
            Vmsne => "vmsne",
            Vmsltu => "vmsltu",
            Vmslt => "vmslt",
            Vmsleu => "vmsleu",
            Vmsle => "vmsle",
            Vmsgtu => "vmsgtu",
            Vmsgt => "vmsgt",
            Vmfeq => "vmfeq",
            Vmfne => "vmfne",
            Vmflt => "vmflt",
            Vmfle => "vmfle",
            Vmfgt => "vmfgt",
            Vmfge => "vmfge",
            Vfadd => "vfadd",
            Vfsub => "vfsub",
            Vfrsub => "vfrsub",
            Vfmul => "vfmul",
            Vfdiv => "vfdiv",
            Vfrdiv => "vfrdiv",
            Vfmacc => "vfmacc",
            Vfnmacc => "vfnmacc",
            Vfmsac => "vfmsac",
            Vfnmsac => "vfnmsac",
            Vfmin => "vfmin",
            Vfmax => "vfmax",
            Vfsqrt => "vfsqrt.v",
            Vfrec7 => "vfrec7.v",
            Vfrsqrt7 => "vfrsqrt7.v",
            Vfsgnj => "vfsgnj",
            Vfsgnjn => "vfsgnjn",
            Vfsgnjx => "vfsgnjx",
            VfcvtXF => "vfcvt.x.f.v",
            VfcvtRtzXF => "vfcvt.rtz.x.f.v",
            VfcvtFX => "vfcvt.f.x.v",
            VfcvtFXu => "vfcvt.f.xu.v",
            VfcvtRtzXuF => "vfcvt.rtz.xu.f.v",
            VfwcvtFF => "vfwcvt.f.f.v",
            VfncvtFF => "vfncvt.f.f.w",
            Vzext2 => "vzext.vf2",
            Vsext2 => "vsext.vf2",
            Vslideup => "vslideup",
            Vslidedown => "vslidedown",
            Vslide1down => "vslide1down.vx",
            Vrgather => "vrgather",
            Vid => "vid.v",
            Vcompress => "vcompress.vm",
            Vmand => "vmand.mm",
            Vmor => "vmor.mm",
            Vmxor => "vmxor.mm",
            Vmnand => "vmnand.mm",
            Vredsum => "vredsum.vs",
            Vredmax => "vredmax.vs",
            Vredmaxu => "vredmaxu.vs",
            Vredmin => "vredmin.vs",
            Vredminu => "vredminu.vs",
            Vfredusum => "vfredusum.vs",
            Vfredmax => "vfredmax.vs",
            Vfredmin => "vfredmin.vs",
        }
    }

    pub fn is_load(self) -> bool {
        matches!(self, RvvKind::Vle | RvvKind::Vlse)
    }

    pub fn is_store(self) -> bool {
        matches!(self, RvvKind::Vse | RvvKind::Vsse)
    }

    /// Whether the destination is a mask register.
    pub fn writes_mask(self) -> bool {
        use RvvKind::*;
        matches!(
            self,
            Vmseq | Vmsne | Vmsltu | Vmslt | Vmsleu | Vmsle | Vmsgtu | Vmsgt
                | Vmfeq | Vmfne | Vmflt | Vmfle | Vmfgt | Vmfge | Vmand | Vmor
                | Vmxor | Vmnand
        )
    }
}

/// Source operand of an RVV instruction.
#[derive(Debug, Clone)]
pub enum Src {
    /// Vector register.
    V(u32),
    /// Mask register (for vmerge / masked ops / mask-mask ops).
    M(u32),
    /// Integer scalar immediate (`.vx`/`.vi` forms with a constant).
    ImmI(i64),
    /// Float scalar immediate (`.vf` form with a constant in `fa`).
    ImmF(f64),
    /// Integer scalar from an IR scalar register (loop-derived `.vx`).
    SReg(u32),
}

/// Destination operand.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Dst {
    V(u32),
    M(u32),
    None,
}

/// Memory reference for loads/stores: buffer id + element index expression
/// (+ optional element stride for vlse/vsse).
#[derive(Debug, Clone)]
pub struct MemRef {
    pub buf: u32,
    pub index: AddrExpr,
    /// element (not byte) stride for strided ops; 1 = unit stride
    pub stride: i64,
}

/// One RVV instruction instance.
#[derive(Debug, Clone)]
pub struct RvvInst {
    pub kind: RvvKind,
    pub sew: Sew,
    /// register grouping this instruction executes under; the static
    /// translator always emits `m1`, the tuner's `lmul:F` transform
    /// rewrites bodies to `m2`/`m4`
    pub lmul: Lmul,
    /// number of elements processed (AVL == vl; our lowerings pin vl)
    pub vl: u32,
    pub dst: Dst,
    pub srcs: Vec<Src>,
    /// `vm` mask (v0.t) — executes only where mask bit set, else dst lane
    /// is undisturbed
    pub mask: Option<u32>,
    pub mem: Option<MemRef>,
}

impl RvvInst {
    /// The `vtype` this instruction requires to be in effect.
    pub fn vtype(&self) -> VType {
        VType { sew: self.sew, lmul: self.lmul }
    }

    /// Assembly-like rendering for traces and the quickstart example, e.g.
    /// `vadd.vv v2, v0, v1` or `vle32.v v0, (A+0)`.
    pub fn asm(&self) -> String {
        let mn = self.kind.mnemonic();
        let dst = match self.dst {
            Dst::V(r) => format!("v{r}"),
            Dst::M(r) => format!("vm{r}"),
            Dst::None => String::new(),
        };
        if self.kind.is_load() || self.kind.is_store() {
            let v = match (self.dst, self.srcs.first()) {
                (Dst::V(r), _) => format!("v{r}"),
                (Dst::None, Some(Src::V(r))) => format!("v{r}"),
                _ => "v?".into(),
            };
            // render malformed mem ops (no MemRef) instead of panicking:
            // asm() runs inside trap/error paths and must stay total
            return match self.mem.as_ref() {
                Some(mem) => {
                    format!("{mn}{}.v {v}, (buf{}+{:?})", self.sew.bits(), mem.buf, mem.index)
                }
                None => format!("{mn}{}.v {v}, (?)", self.sew.bits()),
            };
        }
        let mut parts = Vec::new();
        if !dst.is_empty() {
            parts.push(dst);
        }
        let mut suffix = String::new();
        for s in &self.srcs {
            match s {
                Src::V(r) => {
                    parts.push(format!("v{r}"));
                    suffix.push('v');
                }
                Src::M(m) => {
                    parts.push(format!("vm{m}"));
                    suffix.push('m');
                }
                Src::ImmI(i) => {
                    parts.push(format!("{i}"));
                    suffix.push(if (-16..16).contains(i) { 'i' } else { 'x' });
                }
                Src::ImmF(f) => {
                    parts.push(format!("{f}"));
                    suffix.push('f');
                }
                Src::SReg(r) => {
                    parts.push(format!("s{r}"));
                    suffix.push('x');
                }
            }
        }
        let mn = if mn.contains('.') {
            mn.to_string()
        } else {
            format!("{mn}.{suffix}")
        };
        let mask = if self.mask.is_some() { ", v0.t" } else { "" };
        format!("{mn} {}{mask}", parts.join(", "))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn asm_rendering() {
        let add = RvvInst {
            kind: RvvKind::Vadd,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 4,
            dst: Dst::V(2),
            srcs: vec![Src::V(0), Src::V(1)],
            mask: None,
            mem: None,
        };
        assert_eq!(add.asm(), "vadd.vv v2, v0, v1");

        let merge = RvvInst {
            kind: RvvKind::Vmerge,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 4,
            dst: Dst::V(3),
            srcs: vec![Src::V(1), Src::ImmI(-1), Src::M(0)],
            mask: None,
            mem: None,
        };
        assert_eq!(merge.asm(), "vmerge.vim v3, v1, -1, vm0");
    }

    #[test]
    fn mask_writers() {
        assert!(RvvKind::Vmseq.writes_mask());
        assert!(RvvKind::Vmfeq.writes_mask());
        assert!(!RvvKind::Vadd.writes_mask());
        assert!(!RvvKind::Vmerge.writes_mask());
    }
}
