//! RISC-V Vector extension (RVV 1.0) semantic model: the vector-length-
//! agnostic target ISA of the migration. Configurable VLEN, `vtype`
//! (SEW/LMUL) and `vl` semantics per the riscv-v-spec, an executable op
//! set, and the RVV program representation the SIMDe engine lowers into.
//!
//! Execution-layer faults never panic: every detectable fault is a
//! structured [`trap::SimTrap`] propagated as `Result<_, SimTrap>` so the
//! coordinator can record, retry, and degrade instead of losing a worker.
#![warn(clippy::unwrap_used, clippy::expect_used)]

pub mod exec;
pub mod machine;
pub mod ops;
pub mod program;
pub mod trap;
pub mod verify;
pub mod vtype;

pub use machine::RvvMachine;
pub use ops::{Dst, MemRef, RvvInst, RvvKind, Src};
pub use program::{RStmt, RvvProgram, ScalarBlock};
pub use trap::{SimTrap, TrapKind};
pub use verify::{verify, VerifyError, VerifyErrorKind};
pub use vtype::{Lmul, Sew, VType};
