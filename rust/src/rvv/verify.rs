//! Admission-control verification of RVV programs.
//!
//! [`verify`] is a static pass over an [`RvvProgram`] that proves, without
//! executing a single instruction, that the program is well-formed for a
//! given VLEN. It runs before a program is cached, scored by the tuner, or
//! replayed from the tuning database — an illegal program is rejected at
//! admission instead of trapping (or hanging) mid-job.
//!
//! # The accept ⇒ no-trap contract
//!
//! A program the verifier accepts must execute on both engines without
//! raising a *structural* [`SimTrap`] and without running forever. The
//! checks mirror the execution layer's own fault conditions exactly:
//!
//! - `vl ≤ VLMAX(SEW, LMUL)` at every instruction (the runtime
//!   `vsetvli-violation` check, proved statically);
//! - register-group alignment and range: an `mF` operand names a base
//!   register `≡ 0 (mod F)` and its `F` consecutive registers fit the
//!   register file (the runtime `bad-operand` check in
//!   `RvvMachine::check_group`);
//! - mask and scalar register indices in range (the machine would
//!   otherwise index-panic, leaning on the coordinator's unwind backstop);
//! - widening/narrowing ops are not grouped (`unsupported-op` at
//!   execution), and float ops do not run at `e8`;
//! - scalar registers are defined (by an `SSet` or an enclosing loop's
//!   induction variable, including loop-carried definitions) before any
//!   use in an address expression or `.vx` operand, and vector/mask
//!   registers are written before they are read;
//! - every *unmasked* memory access is provably in-bounds: address
//!   expressions are affine in scalar registers, loop bounds are static,
//!   so interval arithmetic over the full trip range bounds each access
//!   byte-exactly against the buffer's length;
//! - every loop with `start < end` has `step > 0` — an affine back-edge
//!   that cannot terminate is rejected as [`VerifyErrorKind::NonTerminatingLoop`]
//!   instead of exhausting a fuel budget at run time.
//!
//! # Exclusions
//!
//! Three fault classes are deliberately left to the runtime layers
//! (structured traps + fuel, see `sim` and `rvv::trap`):
//!
//! - **masked memory bounds** — a masked load/store only touches lanes
//!   whose mask bit is set, which is data-dependent; the verifier checks
//!   the address expression's registers but not the byte range;
//! - **data-dependent lane indices** — `vrgather`/`vcompress` read lane
//!   positions from register *contents*;
//! - **scalar-fallback numerics** — `ScalarBlock`s are checked for
//!   register/buffer ranges and affine memory bounds of their load/store
//!   families, but the reference NEON semantics inside are trusted.
//!
//! Rejections convert into [`SimTrap`]s (`From<VerifyError>`), so callers
//! reuse the PR 7 degradation ladder: a rejected program becomes a
//! `FaultRecord`, never a dead worker.

use std::fmt;

use crate::ir::{AddrExpr, Arg};
use crate::neon::ops::Family;

use super::exec::mixed_eew;
use super::ops::{Dst, RvvInst, RvvKind, Src};
use super::program::{RStmt, RvvProgram, ScalarBlock};
use super::trap::SimTrap;

/// What class of illegality the verifier found.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum VerifyErrorKind {
    /// `vl` exceeds `VLMAX(SEW, LMUL)` at the given VLEN.
    VlExceedsVlmax,
    /// A grouped (`m2`/`m4`/`m8`) operand whose base register is not a
    /// multiple of the group size.
    MisalignedGroup,
    /// A vector/mask/scalar register index outside the program's
    /// declared register file.
    RegisterOutOfRange,
    /// A scalar/vector/mask register read before any definition reaches
    /// the use (loop-carried definitions count).
    UseBeforeDef,
    /// An unmasked memory access not provably inside its buffer across
    /// the full loop trip range.
    OutOfBoundsAddress,
    /// An affine back-edge that cannot terminate (`start < end` with
    /// `step ≤ 0`).
    NonTerminatingLoop,
    /// A memory operand naming a buffer the program does not declare.
    BadBuffer,
    /// An op the execution layer rejects structurally on this shape
    /// (grouped widening/narrowing, scalar fallback at tiny VLEN).
    UnsupportedOp,
    /// Operand list/kind does not match what the opcode requires.
    Malformed,
}

impl VerifyErrorKind {
    /// Short stable label for logs, reports and tests.
    pub fn label(self) -> &'static str {
        match self {
            VerifyErrorKind::VlExceedsVlmax => "vl-exceeds-vlmax",
            VerifyErrorKind::MisalignedGroup => "misaligned-group",
            VerifyErrorKind::RegisterOutOfRange => "register-out-of-range",
            VerifyErrorKind::UseBeforeDef => "use-before-def",
            VerifyErrorKind::OutOfBoundsAddress => "out-of-bounds-address",
            VerifyErrorKind::NonTerminatingLoop => "non-terminating-loop",
            VerifyErrorKind::BadBuffer => "bad-buffer",
            VerifyErrorKind::UnsupportedOp => "unsupported-op",
            VerifyErrorKind::Malformed => "malformed",
        }
    }
}

/// A structured admission rejection: the illegality class plus a rendered
/// description of the offending statement.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct VerifyError {
    pub kind: VerifyErrorKind,
    pub detail: String,
}

impl VerifyError {
    fn new(kind: VerifyErrorKind, detail: impl Into<String>) -> VerifyError {
        VerifyError { kind, detail: detail.into() }
    }
}

impl fmt::Display for VerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "verify rejected [{}] {}", self.kind.label(), self.detail)
    }
}

impl std::error::Error for VerifyError {}

/// Convert a rejection into the trap the execution layer would have
/// raised, so the coordinator's degradation ladder (retry classification,
/// `FaultRecord`) treats admission rejections like runtime faults.
impl From<VerifyError> for SimTrap {
    fn from(e: VerifyError) -> SimTrap {
        let msg = e.to_string();
        match e.kind {
            VerifyErrorKind::VlExceedsVlmax => SimTrap::vsetvli(msg),
            VerifyErrorKind::UnsupportedOp => SimTrap::unsupported(msg),
            // a non-terminating loop would only surface at run time as
            // exhausted fuel — report it under the same kind
            VerifyErrorKind::NonTerminatingLoop => SimTrap::fuel_exhausted(msg),
            VerifyErrorKind::MisalignedGroup
            | VerifyErrorKind::RegisterOutOfRange
            | VerifyErrorKind::UseBeforeDef
            | VerifyErrorKind::OutOfBoundsAddress
            | VerifyErrorKind::BadBuffer
            | VerifyErrorKind::Malformed => SimTrap::bad_operand(msg),
        }
    }
}

/// Verify `prog` for execution at `vlen`. Returns the first rejection in
/// program order, or `Ok(())` when the program is admitted.
pub fn verify(prog: &RvvProgram, vlen: u32) -> Result<(), VerifyError> {
    let c = Checker { prog, vlen };
    let mut env = Env {
        sregs: vec![SVal::Undef; prog.n_sregs],
        vdef: vec![false; prog.n_vregs],
        mdef: vec![false; prog.n_mregs],
    };
    c.check_block(&prog.body, &mut env)
}

/// Abstract value of one scalar register.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum SVal {
    /// Never written; the machine zero-initialises, so a read yields 0 —
    /// flagged as `UseBeforeDef` at checked uses.
    Undef,
    /// Known inclusive interval.
    Range(i64, i64),
    /// Written, value not statically bounded.
    Any,
}

impl SVal {
    fn join(self, other: SVal) -> SVal {
        match (self, other) {
            (SVal::Undef, SVal::Undef) => SVal::Undef,
            // one side may read the zero-initialised value
            (SVal::Undef, SVal::Range(a, b)) | (SVal::Range(a, b), SVal::Undef) => {
                SVal::Range(a.min(0), b.max(0))
            }
            (SVal::Range(a, b), SVal::Range(c, d)) => SVal::Range(a.min(c), b.max(d)),
            _ => SVal::Any,
        }
    }
}

/// Abstract machine state threaded through the walk: scalar-register
/// intervals plus defined bits for vector and mask registers.
#[derive(Debug, Clone, PartialEq, Eq)]
struct Env {
    sregs: Vec<SVal>,
    vdef: Vec<bool>,
    mdef: Vec<bool>,
}

impl Env {
    fn join(&self, other: &Env) -> Env {
        Env {
            sregs: self
                .sregs
                .iter()
                .zip(&other.sregs)
                .map(|(a, b)| a.join(*b))
                .collect(),
            vdef: self.vdef.iter().zip(&other.vdef).map(|(a, b)| *a || *b).collect(),
            mdef: self.mdef.iter().zip(&other.mdef).map(|(a, b)| *a || *b).collect(),
        }
    }
}

/// Static trip range of a loop: `Terminates(first, last)` with trip ≥ 1,
/// `Empty` for zero-trip, `Diverges` for a back-edge that never exits.
enum Trip {
    Terminates(i64, i64),
    Empty,
    Diverges,
}

fn trip_range(start: i64, end: i64, step: i64) -> Trip {
    if start >= end {
        return Trip::Empty;
    }
    if step <= 0 {
        return Trip::Diverges;
    }
    let (s, e, st) = (start as i128, end as i128, step as i128);
    let trip = (e - s + st - 1) / st;
    let last = s + (trip - 1) * st;
    Trip::Terminates(start, last as i64)
}

struct Checker<'p> {
    prog: &'p RvvProgram,
    vlen: u32,
}

impl Checker<'_> {
    // ---- interval evaluation over affine address expressions ----

    /// Checked evaluation: errors on out-of-range or undefined scalar
    /// registers; `Ok(None)` means "written but unbounded".
    fn eval_strict(&self, e: &AddrExpr, env: &Env) -> Result<Option<(i64, i64)>, VerifyError> {
        match e {
            AddrExpr::Const(v) => Ok(Some((*v, *v))),
            AddrExpr::SReg(r) => {
                let i = *r as usize;
                if i >= env.sregs.len() {
                    return Err(VerifyError::new(
                        VerifyErrorKind::RegisterOutOfRange,
                        format!("address uses s{r} but the program declares {} sregs", env.sregs.len()),
                    ));
                }
                match env.sregs[i] {
                    SVal::Undef => Err(VerifyError::new(
                        VerifyErrorKind::UseBeforeDef,
                        format!("s{r} read before any definition reaches the use"),
                    )),
                    SVal::Range(a, b) => Ok(Some((a, b))),
                    SVal::Any => Ok(None),
                }
            }
            AddrExpr::Add(a, b) => {
                let (x, y) = (self.eval_strict(a, env)?, self.eval_strict(b, env)?);
                Ok(match (x, y) {
                    (Some((al, ah)), Some((bl, bh))) => {
                        Some((al.saturating_add(bl), ah.saturating_add(bh)))
                    }
                    _ => None,
                })
            }
            AddrExpr::Mul(a, k) => Ok(mul_interval(self.eval_strict(a, env)?, *k)),
        }
    }

    /// Lenient evaluation for the transfer pass: undefined registers read
    /// the machine's zero-initialised value, range errors degrade to
    /// "unbounded" (the checked pass reports them).
    fn eval_lenient(&self, e: &AddrExpr, env: &Env) -> Option<(i64, i64)> {
        match e {
            AddrExpr::Const(v) => Some((*v, *v)),
            AddrExpr::SReg(r) => match env.sregs.get(*r as usize) {
                Some(SVal::Undef) => Some((0, 0)),
                Some(SVal::Range(a, b)) => Some((*a, *b)),
                _ => None,
            },
            AddrExpr::Add(a, b) => match (self.eval_lenient(a, env), self.eval_lenient(b, env)) {
                (Some((al, ah)), Some((bl, bh))) => {
                    Some((al.saturating_add(bl), ah.saturating_add(bh)))
                }
                _ => None,
            },
            AddrExpr::Mul(a, k) => mul_interval(self.eval_lenient(a, env), *k),
        }
    }

    // ---- effect transfer (no checks) ----

    /// One pass of the abstract transfer function: update register
    /// definitions and scalar intervals without raising errors (the
    /// checked pass walks the same statements afterwards).
    fn transfer(&self, stmts: &[RStmt], env: &mut Env) {
        for s in stmts {
            match s {
                RStmt::Op(inst) => {
                    let group = inst.lmul.group() as usize;
                    match inst.dst {
                        Dst::V(r) => mark_range(&mut env.vdef, r as usize, group),
                        Dst::M(r) => mark_range(&mut env.mdef, r as usize, 1),
                        Dst::None => {}
                    }
                }
                RStmt::SSet { dst, expr } => {
                    let v = self.eval_lenient(expr, env);
                    if let Some(slot) = env.sregs.get_mut(*dst as usize) {
                        *slot = v.map_or(SVal::Any, |(a, b)| SVal::Range(a, b));
                    }
                }
                RStmt::Loop { ivar, start, end, step, body } => match trip_range(*start, *end, *step) {
                    Trip::Empty => {}
                    Trip::Terminates(first, last) => {
                        self.loop_fix(body, env, *ivar as usize, SVal::Range(first, last));
                    }
                    Trip::Diverges => {
                        // checked pass rejects; approximate for state flow
                        self.loop_fix(body, env, *ivar as usize, SVal::Any);
                    }
                },
                RStmt::Scalar(b) => {
                    if !b.cost_only {
                        if let Some(d) = b.dst {
                            mark_range(&mut env.vdef, d as usize, 1);
                        }
                    }
                }
            }
        }
    }

    /// Join-until-stable fixpoint over a loop body: the environment that
    /// is valid at the top of *every* iteration (loop-carried scalar
    /// ranges widened to `Any` if four join rounds do not stabilise).
    fn loop_fix(&self, body: &[RStmt], env: &mut Env, ivar: usize, ivar_val: SVal) {
        let set_ivar = |e: &mut Env| {
            if let Some(slot) = e.sregs.get_mut(ivar) {
                *slot = ivar_val;
            }
        };
        set_ivar(env);
        for _ in 0..4 {
            let mut post = env.clone();
            self.transfer(body, &mut post);
            set_ivar(&mut post);
            let joined = env.join(&post);
            if joined == *env {
                return;
            }
            *env = joined;
        }
        // did not stabilise (e.g. `s = s + k` accumulation): widen every
        // scalar register the body writes, keep the definition bits
        let mut writes = Vec::new();
        collect_sreg_writes(body, &mut writes);
        for r in writes {
            if let Some(slot) = env.sregs.get_mut(r) {
                if *slot != SVal::Undef {
                    *slot = SVal::Any;
                }
            }
        }
        let mut post = env.clone();
        self.transfer(body, &mut post);
        *env = env.join(&post);
        set_ivar(env);
    }

    // ---- checked walk ----

    fn check_block(&self, stmts: &[RStmt], env: &mut Env) -> Result<(), VerifyError> {
        for s in stmts {
            match s {
                RStmt::Op(inst) => self.check_inst(inst, env)?,
                RStmt::SSet { dst, expr } => {
                    let d = *dst as usize;
                    if d >= env.sregs.len() {
                        return Err(VerifyError::new(
                            VerifyErrorKind::RegisterOutOfRange,
                            format!("SSet writes s{dst} but the program declares {} sregs", env.sregs.len()),
                        ));
                    }
                    let v = self.eval_strict(expr, env)?;
                    env.sregs[d] = v.map_or(SVal::Any, |(a, b)| SVal::Range(a, b));
                }
                RStmt::Loop { ivar, start, end, step, body } => {
                    self.check_loop(*ivar, *start, *end, *step, body, env)?;
                }
                RStmt::Scalar(b) => self.check_scalar(b, env)?,
            }
        }
        Ok(())
    }

    fn check_loop(
        &self,
        ivar: u32,
        start: i64,
        end: i64,
        step: i64,
        body: &[RStmt],
        env: &mut Env,
    ) -> Result<(), VerifyError> {
        let iv = ivar as usize;
        if iv >= env.sregs.len() {
            return Err(VerifyError::new(
                VerifyErrorKind::RegisterOutOfRange,
                format!("loop induction variable s{ivar} exceeds {} sregs", env.sregs.len()),
            ));
        }
        match trip_range(start, end, step) {
            Trip::Diverges => Err(VerifyError::new(
                VerifyErrorKind::NonTerminatingLoop,
                format!("loop s{ivar} = {start}..{end} step {step} cannot terminate"),
            )),
            Trip::Empty => {
                // body never executes, but decode still resolves its
                // buffer ids — keep that panic-free
                self.check_buf_ids(body)
            }
            Trip::Terminates(first, last) => {
                let mut stable = env.clone();
                self.loop_fix(body, &mut stable, iv, SVal::Range(first, last));
                let mut body_env = stable.clone();
                self.check_block(body, &mut body_env)?;
                *env = stable;
                Ok(())
            }
        }
    }

    /// Structural buffer-id validity for statically unreachable code
    /// (zero-trip loop bodies): `sim::decode` indexes `prog.bufs` for
    /// every memory op it flattens, reachable or not.
    fn check_buf_ids(&self, stmts: &[RStmt]) -> Result<(), VerifyError> {
        for s in stmts {
            match s {
                RStmt::Op(inst) => {
                    if let Some(mref) = &inst.mem {
                        self.check_buf(mref.buf, &inst.asm())?;
                    }
                }
                RStmt::Loop { body, .. } => self.check_buf_ids(body)?,
                _ => {}
            }
        }
        Ok(())
    }

    fn check_buf(&self, buf: u32, ctx: &str) -> Result<(), VerifyError> {
        if buf as usize >= self.prog.bufs.len() {
            return Err(VerifyError::new(
                VerifyErrorKind::BadBuffer,
                format!("`{ctx}` names buf{buf} but the program declares {} buffers", self.prog.bufs.len()),
            ));
        }
        Ok(())
    }

    fn check_vreg_use(
        &self,
        r: u32,
        group: usize,
        env: &Env,
        ctx: &RvvInst,
        is_use: bool,
    ) -> Result<(), VerifyError> {
        if group > 1 && r as usize % group != 0 {
            return Err(VerifyError::new(
                VerifyErrorKind::MisalignedGroup,
                format!("`{}`: v{r} is not {group}-aligned for {}", ctx.asm(), ctx.lmul.asm()),
            ));
        }
        if r as usize + group > env.vdef.len() {
            return Err(VerifyError::new(
                VerifyErrorKind::RegisterOutOfRange,
                format!(
                    "`{}`: register group v{r}..v{} exceeds register file of {}",
                    ctx.asm(),
                    r as usize + group - 1,
                    env.vdef.len()
                ),
            ));
        }
        if is_use && !env.vdef[r as usize..r as usize + group].iter().all(|d| *d) {
            return Err(VerifyError::new(
                VerifyErrorKind::UseBeforeDef,
                format!("`{}`: v{r} read before any definition reaches the use", ctx.asm()),
            ));
        }
        Ok(())
    }

    fn check_mreg(&self, r: u32, env: &Env, ctx: &RvvInst, is_use: bool) -> Result<(), VerifyError> {
        if r as usize >= env.mdef.len() {
            return Err(VerifyError::new(
                VerifyErrorKind::RegisterOutOfRange,
                format!("`{}`: vm{r} exceeds {} mask registers", ctx.asm(), env.mdef.len()),
            ));
        }
        if is_use && !env.mdef[r as usize] {
            return Err(VerifyError::new(
                VerifyErrorKind::UseBeforeDef,
                format!("`{}`: vm{r} read before any definition reaches the use", ctx.asm()),
            ));
        }
        Ok(())
    }

    fn check_inst(&self, inst: &RvvInst, env: &mut Env) -> Result<(), VerifyError> {
        let k = inst.kind;
        let group = inst.lmul.group() as usize;

        // vl legality — the static mirror of the runtime vsetvli check
        let vlmax = inst.vtype().vlmax(self.vlen);
        if inst.vl > vlmax {
            return Err(VerifyError::new(
                VerifyErrorKind::VlExceedsVlmax,
                format!(
                    "`{}`: vl {} exceeds VLMAX {vlmax} for vtype `{}` at VLEN {}",
                    inst.asm(),
                    inst.vl,
                    inst.vtype().asm(),
                    self.vlen
                ),
            ));
        }

        // structurally unsupported shapes the execution layer traps on
        if group > 1 && mixed_eew(k) {
            return Err(VerifyError::new(
                VerifyErrorKind::UnsupportedOp,
                format!("`{}`: widening/narrowing op at grouped LMUL {}", inst.asm(), inst.lmul.asm()),
            ));
        }
        if is_float_kind(k) && inst.sew == super::vtype::Sew::E8 {
            return Err(VerifyError::new(
                VerifyErrorKind::Malformed,
                format!("`{}`: no e8 float type", inst.asm()),
            ));
        }
        if is_widening_kind(k) && inst.sew == super::vtype::Sew::E64 {
            return Err(VerifyError::new(
                VerifyErrorKind::Malformed,
                format!("`{}`: no widened SEW above e64", inst.asm()),
            ));
        }
        if matches!(k, RvvKind::Vnsrl | RvvKind::Vnsra | RvvKind::VfncvtFF)
            && inst.sew == super::vtype::Sew::E64
        {
            // narrowing reads the source at 2×SEW — e64 sources have no
            // e128 wide side in this model
            return Err(VerifyError::new(
                VerifyErrorKind::Malformed,
                format!("`{}`: no widened source SEW above e64", inst.asm()),
            ));
        }

        // operand uses
        for s in &inst.srcs {
            match s {
                Src::V(r) => self.check_vreg_use(*r, group, env, inst, true)?,
                Src::M(r) => self.check_mreg(*r, env, inst, true)?,
                Src::SReg(r) => {
                    // reuse the strict evaluator's range/def checks
                    self.eval_strict(&AddrExpr::SReg(*r), env)?;
                }
                Src::ImmI(_) | Src::ImmF(_) => {}
            }
        }
        if let Some(mk) = inst.mask {
            self.check_mreg(mk, env, inst, true)?;
        }

        // operand shapes the execution layer traps on
        if k.writes_mask() && !matches!(inst.dst, Dst::M(_)) {
            return Err(VerifyError::new(
                VerifyErrorKind::Malformed,
                format!("`{}`: mask-writing op without mask destination", inst.asm()),
            ));
        }

        // memory
        if k.is_load() || k.is_store() {
            let Some(mref) = &inst.mem else {
                return Err(VerifyError::new(
                    VerifyErrorKind::Malformed,
                    format!("`{}`: memory op without MemRef", inst.asm()),
                ));
            };
            self.check_buf(mref.buf, &inst.asm())?;
            if k.is_load() && !matches!(inst.dst, Dst::V(_)) {
                return Err(VerifyError::new(
                    VerifyErrorKind::Malformed,
                    format!("`{}`: load without vreg destination", inst.asm()),
                ));
            }
            if k.is_store() && !matches!(inst.srcs.first(), Some(Src::V(_))) {
                return Err(VerifyError::new(
                    VerifyErrorKind::Malformed,
                    format!("`{}`: store without vreg source", inst.asm()),
                ));
            }
            if inst.mask.is_none() && inst.vl > 0 {
                // unmasked: every lane is touched, so the full affine
                // range must be in-bounds (masked bounds are a documented
                // exclusion — data-dependent)
                let idx = self.eval_strict(&mref.index, env)?;
                let Some((ilo, ihi)) = idx else {
                    return Err(VerifyError::new(
                        VerifyErrorKind::OutOfBoundsAddress,
                        format!("`{}`: address not provably in bounds (unbounded affine term)", inst.asm()),
                    ));
                };
                let decl = &self.prog.bufs[mref.buf as usize];
                let eb = decl.elem.bytes() as i128;
                let sewb = inst.sew.bytes() as i128;
                let len_bytes = decl.len as i128 * eb;
                let (base_lo, base_hi) = (ilo as i128 * eb, ihi as i128 * eb);
                let (lo, hi) = if mref.stride == 1 {
                    (base_lo, base_hi + inst.vl as i128 * sewb)
                } else {
                    let sb = mref.stride as i128 * sewb;
                    let span = (inst.vl as i128 - 1) * sb;
                    if sb >= 0 {
                        (base_lo, base_hi + span + sewb)
                    } else {
                        (base_lo + span, base_hi + sewb)
                    }
                };
                if lo < 0 || hi > len_bytes {
                    return Err(VerifyError::new(
                        VerifyErrorKind::OutOfBoundsAddress,
                        format!(
                            "`{}`: bytes [{lo}, {hi}) of buf{} ({len_bytes} bytes) across the full trip range",
                            inst.asm(),
                            mref.buf
                        ),
                    ));
                }
            } else {
                // masked / vl=0: still validate the address expression's
                // scalar registers so evaluation cannot panic
                let _ = self.eval_strict(&mref.index, env)?;
            }
        }

        // definitions last (an instruction cannot feed itself)
        match inst.dst {
            Dst::V(r) => {
                self.check_vreg_use(r, group, env, inst, false)?;
                mark_range(&mut env.vdef, r as usize, group);
            }
            Dst::M(r) => {
                self.check_mreg(r, env, inst, false)?;
                mark_range(&mut env.mdef, r as usize, 1);
            }
            Dst::None => {}
        }
        Ok(())
    }

    fn check_scalar(&self, b: &ScalarBlock, env: &mut Env) -> Result<(), VerifyError> {
        if b.cost_only {
            return Ok(());
        }
        let op = b.call.op;
        let name = op.name();
        // the scalar fallback stages fixed 128-bit NEON values in single
        // (m1) registers, whose storage is 2×VLEN bits
        if self.vlen < 64 {
            return Err(VerifyError::new(
                VerifyErrorKind::UnsupportedOp,
                format!("scalar fallback `{name}` needs VLEN >= 64 for 128-bit NEON staging"),
            ));
        }
        for a in &b.call.args {
            match a {
                Arg::V(r) => {
                    if *r as usize >= env.vdef.len() {
                        return Err(VerifyError::new(
                            VerifyErrorKind::RegisterOutOfRange,
                            format!("scalar `{name}`: v{r} exceeds register file of {}", env.vdef.len()),
                        ));
                    }
                    if !env.vdef[*r as usize] {
                        return Err(VerifyError::new(
                            VerifyErrorKind::UseBeforeDef,
                            format!("scalar `{name}`: v{r} read before any definition"),
                        ));
                    }
                }
                Arg::S(r) => {
                    self.eval_strict(&AddrExpr::SReg(*r), env)?;
                }
                Arg::Mem { buf, index } => {
                    self.check_buf(*buf, &format!("scalar {name}"))?;
                    let _ = self.eval_strict(index, env)?;
                }
                Arg::Imm(_) | Arg::ImmF(_) => {}
            }
        }
        // affine bounds for the memory families (mirrors sim::scalar)
        if matches!(op.family, Family::Ld1 | Family::St1 | Family::Ld1Dup | Family::Ld1Lane | Family::St1Lane)
        {
            let Some(Arg::Mem { buf, index }) = b.call.args.first() else {
                return Err(VerifyError::new(
                    VerifyErrorKind::Malformed,
                    format!("scalar `{name}`: memory family without memory operand"),
                ));
            };
            let Some((ilo, ihi)) = self.eval_strict(index, env)? else {
                return Err(VerifyError::new(
                    VerifyErrorKind::OutOfBoundsAddress,
                    format!("scalar `{name}`: address not provably in bounds (unbounded affine term)"),
                ));
            };
            let decl = &self.prog.bufs[*buf as usize];
            let eb = decl.elem.bytes() as i128;
            let len_bytes = decl.len as i128 * eb;
            let lanes = if matches!(op.family, Family::Ld1 | Family::St1) {
                op.vt().lanes as i128
            } else {
                1
            };
            let lo = ilo as i128 * eb;
            let hi = (ihi as i128 + lanes - 1) * eb + eb;
            if lo < 0 || hi > len_bytes {
                return Err(VerifyError::new(
                    VerifyErrorKind::OutOfBoundsAddress,
                    format!(
                        "scalar `{name}`: bytes [{lo}, {hi}) of buf{buf} ({len_bytes} bytes) across the full trip range"
                    ),
                ));
            }
        }
        if let Some(d) = b.dst {
            if d as usize >= env.vdef.len() {
                return Err(VerifyError::new(
                    VerifyErrorKind::RegisterOutOfRange,
                    format!("scalar `{name}`: dst v{d} exceeds register file of {}", env.vdef.len()),
                ));
            }
            env.vdef[d as usize] = true;
        }
        Ok(())
    }
}

fn mark_range(bits: &mut [bool], base: usize, n: usize) {
    for b in bits.iter_mut().skip(base).take(n) {
        *b = true;
    }
}

fn mul_interval(v: Option<(i64, i64)>, k: i64) -> Option<(i64, i64)> {
    match v {
        Some((lo, hi)) => {
            let (a, b) = (lo.saturating_mul(k), hi.saturating_mul(k));
            Some((a.min(b), a.max(b)))
        }
        None if k == 0 => Some((0, 0)),
        None => None,
    }
}

fn collect_sreg_writes(stmts: &[RStmt], out: &mut Vec<usize>) {
    for s in stmts {
        match s {
            RStmt::SSet { dst, .. } => out.push(*dst as usize),
            RStmt::Loop { ivar, body, .. } => {
                out.push(*ivar as usize);
                collect_sreg_writes(body, out);
            }
            _ => {}
        }
    }
}

/// Kinds whose execution goes through `float_elem` (no e8 form exists).
fn is_float_kind(k: RvvKind) -> bool {
    use RvvKind::*;
    matches!(
        k,
        VfmvVF | Vfmerge | Vmfeq | Vmfne | Vmflt | Vmfle | Vmfgt | Vmfge | Vfadd | Vfsub
            | Vfrsub | Vfmul | Vfdiv | Vfrdiv | Vfmacc | Vfnmacc | Vfmsac | Vfnmsac | Vfmin
            | Vfmax | Vfsqrt | Vfrec7 | Vfrsqrt7 | Vfsgnj | Vfsgnjn | Vfsgnjx | VfcvtXF
            | VfcvtRtzXF | VfcvtFX | VfcvtFXu | VfcvtRtzXuF | VfwcvtFF | VfncvtFF | Vfredusum
            | Vfredmax | Vfredmin
    )
}

/// Kinds whose destination (or accumulator) lives at 2×SEW.
fn is_widening_kind(k: RvvKind) -> bool {
    use RvvKind::*;
    matches!(k, Vwmul | Vwmulu | Vwadd | Vwaddu | Vwmacc | Vwmaccu | VfwcvtFF | Vzext2 | Vsext2)
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::ir::{BufDecl, BufKind};
    use crate::neon::elem::Elem;
    use crate::rvv::ops::MemRef;
    use crate::rvv::vtype::{Lmul, Sew};

    fn buf(name: &str, len: usize, kind: BufKind) -> BufDecl {
        BufDecl { name: name.into(), elem: Elem::I32, len, kind }
    }

    fn vle(dst: u32, b: u32, vl: u32) -> RStmt {
        RStmt::Op(RvvInst {
            kind: RvvKind::Vle,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl,
            dst: Dst::V(dst),
            srcs: vec![],
            mask: None,
            mem: Some(MemRef { buf: b, index: AddrExpr::s(0), stride: 1 }),
        })
    }

    fn vse(src: u32, b: u32, vl: u32) -> RStmt {
        RStmt::Op(RvvInst {
            kind: RvvKind::Vse,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl,
            dst: Dst::None,
            srcs: vec![Src::V(src)],
            mask: None,
            mem: Some(MemRef { buf: b, index: AddrExpr::s(0), stride: 1 }),
        })
    }

    fn vadd(dst: u32, a: u32, b: u32, vl: u32) -> RStmt {
        RStmt::Op(RvvInst {
            kind: RvvKind::Vadd,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl,
            dst: Dst::V(dst),
            srcs: vec![Src::V(a), Src::V(b)],
            mask: None,
            mem: None,
        })
    }

    /// 16-element looped add: the canonical legal program.
    fn legal_program() -> RvvProgram {
        RvvProgram {
            name: "legal".into(),
            bufs: vec![
                buf("A", 16, BufKind::Input),
                buf("B", 16, BufKind::Input),
                buf("O", 16, BufKind::Output),
            ],
            body: vec![RStmt::Loop {
                ivar: 0,
                start: 0,
                end: 16,
                step: 4,
                body: vec![vle(0, 0, 4), vle(1, 1, 4), vadd(2, 0, 1, 4), vse(2, 2, 4)],
            }],
            n_vregs: 3,
            n_mregs: 0,
            n_sregs: 1,
        }
    }

    #[test]
    fn legal_program_is_admitted() {
        verify(&legal_program(), 128).unwrap();
        verify(&legal_program(), 512).unwrap();
    }

    #[test]
    fn vl_above_vlmax_is_rejected() {
        let mut p = legal_program();
        if let RStmt::Loop { body, .. } = &mut p.body[0] {
            if let RStmt::Op(i) = &mut body[2] {
                i.vl = 8; // VLMAX(e32, m1, 128) = 4
            }
        }
        let e = verify(&p, 128).unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::VlExceedsVlmax, "{e}");
        // same program is legal on a wider machine
        verify(&p, 256).unwrap();
    }

    #[test]
    fn misaligned_group_base_is_rejected() {
        let mut p = legal_program();
        p.n_vregs = 8;
        if let RStmt::Loop { body, .. } = &mut p.body[0] {
            body[2] = RStmt::Op(RvvInst {
                kind: RvvKind::Vadd,
                sew: Sew::E32,
                lmul: Lmul::M2,
                vl: 4,
                dst: Dst::V(3), // not 2-aligned
                srcs: vec![Src::V(0), Src::V(0)],
                mask: None,
                mem: None,
            });
        }
        let e = verify(&p, 128).unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::MisalignedGroup, "{e}");
    }

    #[test]
    fn register_out_of_range_is_rejected() {
        let mut p = legal_program();
        if let RStmt::Loop { body, .. } = &mut p.body[0] {
            if let RStmt::Op(i) = &mut body[2] {
                i.dst = Dst::V(40);
            }
        }
        let e = verify(&p, 128).unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::RegisterOutOfRange, "{e}");
    }

    #[test]
    fn oob_affine_address_is_rejected_across_trip_range() {
        let mut p = legal_program();
        if let RStmt::Loop { end, .. } = &mut p.body[0] {
            // last iteration reads A[16..20) of a 16-element buffer
            *end = 20;
        }
        let e = verify(&p, 128).unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::OutOfBoundsAddress, "{e}");
        assert!(e.detail.contains("buf0"), "{e}");
    }

    #[test]
    fn negative_address_is_rejected() {
        let mut p = legal_program();
        if let RStmt::Loop { start, .. } = &mut p.body[0] {
            *start = -4;
        }
        let e = verify(&p, 128).unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::OutOfBoundsAddress, "{e}");
    }

    #[test]
    fn infinite_back_edge_is_rejected() {
        for step in [0, -1] {
            let mut p = legal_program();
            if let RStmt::Loop { step: s, .. } = &mut p.body[0] {
                *s = step;
            }
            let e = verify(&p, 128).unwrap_err();
            assert_eq!(e.kind, VerifyErrorKind::NonTerminatingLoop, "step {step}: {e}");
        }
    }

    #[test]
    fn zero_trip_loop_body_is_not_bounds_checked() {
        let mut p = legal_program();
        if let RStmt::Loop { start, end, .. } = &mut p.body[0] {
            // body would be wildly out of bounds if it ran — it never does
            *start = 100;
            *end = 0;
        }
        verify(&p, 128).unwrap();
    }

    #[test]
    fn bad_buffer_id_is_rejected_even_in_dead_code() {
        let mut p = legal_program();
        if let RStmt::Loop { start, end, body, .. } = &mut p.body[0] {
            *start = 1;
            *end = 0;
            if let RStmt::Op(i) = &mut body[0] {
                i.mem.as_mut().unwrap().buf = 9;
            }
        }
        let e = verify(&p, 128).unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::BadBuffer, "{e}");
    }

    #[test]
    fn use_before_def_is_rejected() {
        let mut p = legal_program();
        if let RStmt::Loop { body, .. } = &mut p.body[0] {
            // v7 is never written anywhere
            if let RStmt::Op(i) = &mut body[2] {
                i.srcs = vec![Src::V(0), Src::V(7)];
            }
        }
        p.n_vregs = 8;
        let e = verify(&p, 128).unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::UseBeforeDef, "{e}");
    }

    #[test]
    fn loop_carried_defs_are_visible() {
        // body reads v0 before (re)loading it — defined by iteration n-1
        // and, before iteration 0, by a pre-loop load
        let mut p = legal_program();
        let pre = vle(0, 0, 4);
        if let RStmt::Loop { body, .. } = &mut p.body[0] {
            body.rotate_left(1); // vle(1), vadd, vse, vle(0)
        }
        p.body.insert(0, pre);
        // the pre-loop load reads s0, so define it first (an undefined
        // sreg address is itself a rejection)
        p.body.insert(0, RStmt::SSet { dst: 0, expr: AddrExpr::k(0) });
        verify(&p, 128).unwrap();
    }

    #[test]
    fn undefined_sreg_address_is_rejected() {
        let mut p = legal_program();
        p.n_sregs = 2;
        if let RStmt::Loop { body, .. } = &mut p.body[0] {
            if let RStmt::Op(i) = &mut body[0] {
                i.mem.as_mut().unwrap().index = AddrExpr::s(1); // never SSet
            }
        }
        let e = verify(&p, 128).unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::UseBeforeDef, "{e}");
    }

    #[test]
    fn sset_defined_addresses_are_bounded() {
        let mut p = legal_program();
        p.n_sregs = 2;
        if let RStmt::Loop { body, .. } = &mut p.body[0] {
            body.insert(0, RStmt::SSet { dst: 1, expr: AddrExpr::s(0).mul(1).addk(0) });
            if let RStmt::Op(i) = &mut body[1] {
                i.mem.as_mut().unwrap().index = AddrExpr::s(1);
            }
        }
        verify(&p, 128).unwrap();
    }

    #[test]
    fn grouped_widening_op_is_rejected() {
        let mut p = legal_program();
        p.n_vregs = 8;
        if let RStmt::Loop { body, .. } = &mut p.body[0] {
            body[2] = RStmt::Op(RvvInst {
                kind: RvvKind::Vwmul,
                sew: Sew::E16,
                lmul: Lmul::M2,
                vl: 4,
                dst: Dst::V(4),
                srcs: vec![Src::V(0), Src::V(2)],
                mask: None,
                mem: None,
            });
        }
        let e = verify(&p, 128).unwrap_err();
        assert_eq!(e.kind, VerifyErrorKind::UnsupportedOp, "{e}");
    }

    #[test]
    fn masked_memory_bounds_are_excluded() {
        // a masked load past the end is admitted (data-dependent bounds
        // are a documented exclusion) as long as the mask is defined
        let mut p = legal_program();
        p.n_mregs = 1;
        if let RStmt::Loop { body, .. } = &mut p.body[0] {
            body.insert(
                2,
                RStmt::Op(RvvInst {
                    kind: RvvKind::Vmseq,
                    sew: Sew::E32,
                    lmul: Lmul::M1,
                    vl: 4,
                    dst: Dst::M(0),
                    srcs: vec![Src::V(0), Src::V(1)],
                    mask: None,
                    mem: None,
                }),
            );
            // mask the store (the compare at body[2] defines vm0 first)
            // and point it far past the end of the buffer
            if let RStmt::Op(i) = &mut body[4] {
                i.mask = Some(0);
                i.mem.as_mut().unwrap().index = AddrExpr::s(0).addk(1000);
            }
        }
        verify(&p, 128).unwrap();
    }

    #[test]
    fn error_converts_to_matching_trap() {
        let e = VerifyError::new(VerifyErrorKind::VlExceedsVlmax, "x");
        let t: SimTrap = e.into();
        assert_eq!(t.kind.label(), "vsetvli-violation");
        let e = VerifyError::new(VerifyErrorKind::NonTerminatingLoop, "x");
        let t: SimTrap = e.into();
        assert_eq!(t.kind.label(), "fuel-exhausted");
        let e = VerifyError::new(VerifyErrorKind::OutOfBoundsAddress, "x");
        let t: SimTrap = e.into();
        assert_eq!(t.kind.label(), "bad-operand");
    }
}
