//! RVV program representation — what the SIMDe translation engine lowers
//! IR programs into, and what the Spike-like simulator executes.

use crate::ir::{AddrExpr, BufDecl, NeonCall};
use super::ops::RvvInst;

/// A scalar fallback block: SIMDe's private-union per-lane loop for an
/// intrinsic with no custom RVV conversion and no auto-vectorizable body
/// (§3.3 method 4 failing, leaving scalar code).
///
/// Numerically it executes the reference NEON semantics (the scalar C loop
/// computes the same math); its *cost* is modelled explicitly:
/// `spill_ops + lanes * per_lane_cost + reload_ops` scalar instructions,
/// calibrated against what clang -O3 emits for SIMDe's generic loops (see
/// `simde::costs`).
#[derive(Debug, Clone)]
pub struct ScalarBlock {
    /// The NEON call to execute with reference semantics. Vector-register
    /// ids refer to the *RVV* virtual registers holding the NEON values in
    /// their low 64/128 bits.
    pub call: NeonCall,
    /// Destination RVV vreg (None for stores).
    pub dst: Option<u32>,
    /// Modelled dynamic scalar-instruction cost of the whole block.
    pub scalar_cost: u64,
    /// Modelled loads/stores within the block (subset of `scalar_cost`
    /// accounting, reported separately).
    pub mem_ops: u64,
    /// Pure cost annotation: the values were already computed by preceding
    /// ops; only count, do not execute.
    pub cost_only: bool,
}

/// RVV program statement.
#[derive(Debug, Clone)]
pub enum RStmt {
    /// One RVV instruction (one dynamic instruction when executed; the
    /// simulator inserts+counts `vsetvli` on vtype/vl change).
    Op(RvvInst),
    /// Scalar ALU statement (address arithmetic) — counted as one scalar
    /// instruction.
    SSet { dst: u32, expr: AddrExpr },
    /// Counted loop (adds modelled loop-overhead instructions per
    /// iteration).
    Loop {
        ivar: u32,
        start: i64,
        end: i64,
        step: i64,
        body: Vec<RStmt>,
    },
    /// SIMDe generic-path scalar fallback (baseline mode only).
    Scalar(ScalarBlock),
}

/// A complete translated program.
#[derive(Debug, Clone)]
pub struct RvvProgram {
    pub name: String,
    /// Buffer declarations, shared layout with the source IR program.
    pub bufs: Vec<BufDecl>,
    pub body: Vec<RStmt>,
    pub n_vregs: usize,
    pub n_mregs: usize,
    pub n_sregs: usize,
}

impl RvvProgram {
    /// Static count of RVV instructions (not dynamic; loops uncounted).
    pub fn static_ops(&self) -> usize {
        fn walk(stmts: &[RStmt]) -> usize {
            stmts
                .iter()
                .map(|s| match s {
                    RStmt::Op(_) => 1,
                    RStmt::Loop { body, .. } => walk(body),
                    _ => 0,
                })
                .sum()
        }
        walk(&self.body)
    }

    /// Flat listing of the instruction stream (loops annotated), for the
    /// quickstart example's Listing-10-style dump.
    pub fn disasm(&self) -> String {
        fn walk(stmts: &[RStmt], indent: usize, out: &mut String) {
            let pad = "  ".repeat(indent);
            for s in stmts {
                match s {
                    RStmt::Op(i) => {
                        out.push_str(&format!("{pad}{}\n", i.asm()));
                    }
                    RStmt::SSet { dst, expr } => {
                        out.push_str(&format!("{pad}s{dst} = {expr:?}\n"));
                    }
                    RStmt::Loop { ivar, start, end, step, body } => {
                        out.push_str(&format!(
                            "{pad}loop s{ivar} = {start}..{end} step {step}:\n"
                        ));
                        walk(body, indent + 1, out);
                    }
                    RStmt::Scalar(b) => {
                        out.push_str(&format!(
                            "{pad}scalar_loop {} (cost {} scalar insts)\n",
                            b.call.op.name(),
                            b.scalar_cost
                        ));
                    }
                }
            }
        }
        let mut out = String::new();
        walk(&self.body, 0, &mut out);
        out
    }
}
