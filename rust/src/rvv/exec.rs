//! Execution semantics for each [`RvvKind`] per the riscv-v-spec 1.0.
//!
//! Masked-off and tail lanes are left undisturbed (a legal ta/ma
//! implementation), which preserves the NEON values that live in the low
//! 64/128 bits of each virtual register after translation.
//!
//! Every detectable fault — illegal instruction, out-of-bounds memory,
//! operand-kind mismatch, unsupported opcode — propagates as a structured
//! [`SimTrap`] instead of panicking, so a malformed program costs one job,
//! not a worker thread. The happy path is byte-identical to the previous
//! panicking implementation.

use crate::neon::elem::{self, Elem};
use crate::neon::semantics::floatest;
use super::machine::RvvMachine;
use super::ops::{Dst, RvvInst, RvvKind, Src};
use super::trap::SimTrap;
use super::vtype::{Lmul, Sew};

/// Raise a [`SimTrap`] from the enclosing `Result<_, SimTrap>` function.
macro_rules! trap {
    ($ctor:ident, $($arg:tt)*) => {
        return Err(SimTrap::$ctor(format!($($arg)*)))
    };
}

fn float_elem(sew: Sew) -> Result<Elem, SimTrap> {
    match sew {
        Sew::E16 => Ok(Elem::F16),
        Sew::E32 => Ok(Elem::F32),
        Sew::E64 => Ok(Elem::F64),
        Sew::E8 => Err(SimTrap::illegal("no e8 float type")),
    }
}

fn int_elem(sew: Sew, signed: bool) -> Elem {
    let e = match sew {
        Sew::E8 => Elem::I8,
        Sew::E16 => Elem::I16,
        Sew::E32 => Elem::I32,
        Sew::E64 => Elem::I64,
    };
    if signed {
        e
    } else {
        e.as_unsigned()
    }
}

/// Double-width SEW for widening ops; traps when none exists (e64 source).
fn widened(sew: Sew) -> Result<Sew, SimTrap> {
    Sew::try_of_bits(sew.bits() * 2)
        .ok_or_else(|| SimTrap::illegal(format!("no widened SEW above {}", sew.asm())))
}

/// Half-width SEW for narrowing ops; traps when none exists (e8 source).
fn narrowed(sew: Sew) -> Result<Sew, SimTrap> {
    Sew::try_of_bits(sew.bits() / 2)
        .ok_or_else(|| SimTrap::illegal(format!("no narrowed SEW below {}", sew.asm())))
}

/// Resolve a scalar-capable source operand to a raw lane value at `sew`.
fn scalar_val(m: &RvvMachine, s: &Src, sew: Sew, float: bool) -> Result<u64, SimTrap> {
    Ok(match s {
        Src::ImmI(i) => elem::from_i64(int_elem(sew, true), *i),
        Src::ImmF(f) => elem::from_f64(float_elem(sew)?, *f),
        Src::SReg(r) => {
            let v = m.sregs[*r as usize];
            if float {
                elem::from_f64(float_elem(sew)?, v as f64)
            } else {
                elem::from_i64(int_elem(sew, true), v)
            }
        }
        other => trap!(bad_operand, "operand {other:?} is not scalar"),
    })
}

/// Per-lane value of a source operand (vector lane or broadcast scalar).
fn src_lane(
    m: &RvvMachine,
    s: &Src,
    sew: Sew,
    lmul: Lmul,
    lane: u32,
    float: bool,
) -> Result<u64, SimTrap> {
    match s {
        Src::V(r) => m.read_lane(*r, sew, lmul, lane),
        _ => scalar_val(m, s, sew, float),
    }
}

/// `vsetvli` legality: `vl` must not exceed `VLMAX = VLEN/SEW · LMUL` for
/// the instruction's vtype. Before PR 9 this was implicitly assumed at
/// `m1`; now it is an explicit structural fault.
fn check_vl_legal(m: &RvvMachine, inst: &RvvInst) -> Result<(), SimTrap> {
    let vt = inst.vtype();
    let vlmax = vt.vlmax(m.cfg.vlen);
    if inst.vl > vlmax {
        return Err(SimTrap::vsetvli(format!(
            "vl {} exceeds VLMAX {vlmax} for vtype `{}` at VLEN {}",
            inst.vl,
            vt.asm(),
            m.cfg.vlen
        )));
    }
    Ok(())
}

/// Widening/narrowing kinds access lanes at an EEW other than `inst.sew`;
/// their grouped (EMUL-scaled) forms are not modelled — the legality
/// analysis never emits them, so a grouped instance is a structural
/// unsupported-op fault rather than silently wrong lane mapping.
pub(crate) fn mixed_eew(k: RvvKind) -> bool {
    use RvvKind::*;
    matches!(
        k,
        Vwmul | Vwmulu | Vwadd | Vwaddu | Vwmacc | Vwmaccu | VfwcvtFF | VfncvtFF | Vnsrl
            | Vnsra | Vzext2 | Vsext2
    )
}

/// Execute one RVV instruction. `mem_byte_off` must be pre-resolved for
/// loads/stores (the simulator evaluates the `MemRef` address expression).
pub fn exec(m: &mut RvvMachine, inst: &RvvInst, mem_byte_off: Option<i64>) -> Result<(), SimTrap> {
    use RvvKind::*;
    let sew = inst.sew;
    let vl = inst.vl;
    let k = inst.kind;
    let lmul = inst.lmul;
    let group = lmul.group();
    check_vl_legal(m, inst)?;
    if group > 1 && mixed_eew(k) {
        trap!(unsupported, "widening/narrowing op {k:?} at grouped LMUL {}", lmul.asm());
    }

    // loads/stores
    if k.is_load() || k.is_store() {
        let Some(base) = mem_byte_off else {
            trap!(bad_operand, "memory op {k:?} without resolved address");
        };
        let Some(mref) = inst.mem.as_ref() else {
            trap!(bad_operand, "memory op {k:?} without MemRef");
        };
        // P2 fast path: unit-stride unmasked ops are a single bulk copy
        if inst.mask.is_none() && mref.stride == 1 {
            let n = (vl * sew.bytes()) as usize;
            match (k, inst.dst, inst.srcs.first()) {
                (Vle, Dst::V(dst), _) => return m.load_bulk(mref.buf, base, n, dst, lmul),
                (Vse, Dst::None, Some(Src::V(src))) => {
                    return m.store_bulk(mref.buf, base, n, *src, lmul)
                }
                _ => {}
            }
        }
        let stride = mref.stride * sew.bytes() as i64;
        match k {
            Vle | Vlse => {
                let Dst::V(dst) = inst.dst else {
                    trap!(bad_operand, "load {k:?} without vreg dst");
                };
                for i in 0..vl {
                    if let Some(mk) = inst.mask {
                        if !m.mask_bit(mk, i) {
                            continue;
                        }
                    }
                    let v = m.load_at(mref.buf, base + i as i64 * stride, sew)?;
                    m.write_lane(dst, sew, lmul, i, v)?;
                }
            }
            Vse | Vsse => {
                let Some(Src::V(src)) = inst.srcs.first() else {
                    trap!(bad_operand, "store {k:?} without vreg src");
                };
                for i in 0..vl {
                    if let Some(mk) = inst.mask {
                        if !m.mask_bit(mk, i) {
                            continue;
                        }
                    }
                    let v = m.read_lane(*src, sew, lmul, i)?;
                    m.store_at(mref.buf, base + i as i64 * stride, sew, v)?;
                }
            }
            _ => trap!(unsupported, "unexpected memory kind {k:?}"),
        }
        return Ok(());
    }

    // mask-register logical ops
    if matches!(k, Vmand | Vmor | Vmxor | Vmnand) {
        let Dst::M(dst) = inst.dst else {
            trap!(bad_operand, "mask op {k:?} without mask dst");
        };
        let (Some(Src::M(a)), Some(Src::M(b))) = (inst.srcs.first(), inst.srcs.get(1)) else {
            trap!(bad_operand, "mask op {k:?} needs two mask srcs");
        };
        for i in 0..vl {
            let (x, y) = (m.mask_bit(*a, i), m.mask_bit(*b, i));
            let r = match k {
                Vmand => x && y,
                Vmor => x || y,
                Vmxor => x ^ y,
                Vmnand => !(x && y),
                _ => trap!(unsupported, "unexpected mask-logical kind {k:?}"),
            };
            m.write_mask_bit(dst, i, r);
        }
        return Ok(());
    }

    // compares -> mask destination
    if k.writes_mask() {
        let Dst::M(dst) = inst.dst else {
            trap!(bad_operand, "compare {k:?} without mask dst");
        };
        let (Some(a), Some(b)) = (inst.srcs.first(), inst.srcs.get(1)) else {
            trap!(bad_operand, "compare {k:?} needs two srcs");
        };
        let float = matches!(k, Vmfeq | Vmfne | Vmflt | Vmfle | Vmfgt | Vmfge);
        for i in 0..vl {
            if let Some(mk) = inst.mask {
                if !m.mask_bit(mk, i) {
                    continue;
                }
            }
            let x = src_lane(m, a, sew, lmul, i, float)?;
            let y = src_lane(m, b, sew, lmul, i, float)?;
            let r = if float {
                let fe = float_elem(sew)?;
                let (fx, fy) = (elem::to_f64(fe, x), elem::to_f64(fe, y));
                match k {
                    Vmfeq => fx == fy,
                    Vmfne => fx != fy,
                    Vmflt => fx < fy,
                    Vmfle => fx <= fy,
                    Vmfgt => fx > fy,
                    Vmfge => fx >= fy,
                    _ => trap!(unsupported, "unexpected float compare {k:?}"),
                }
            } else {
                let se = int_elem(sew, true);
                let ue = int_elem(sew, false);
                let (sx, sy) = (elem::to_i64(se, x), elem::to_i64(se, y));
                let (ux, uy) = (elem::to_u64(ue, x), elem::to_u64(ue, y));
                match k {
                    Vmseq => x & se.lane_mask() == y & se.lane_mask(),
                    Vmsne => x & se.lane_mask() != y & se.lane_mask(),
                    Vmslt => sx < sy,
                    Vmsle => sx <= sy,
                    Vmsgt => sx > sy,
                    Vmsltu => ux < uy,
                    Vmsleu => ux <= uy,
                    Vmsgtu => ux > uy,
                    _ => trap!(unsupported, "unexpected int compare {k:?}"),
                }
            };
            m.write_mask_bit(dst, i, r);
        }
        return Ok(());
    }

    // reductions: dst[0] = fold(init = srcs[1][0], over srcs[0][0..vl])
    if matches!(k, Vredsum | Vredmax | Vredmaxu | Vredmin | Vredminu | Vfredusum | Vfredmax | Vfredmin) {
        let Dst::V(dst) = inst.dst else {
            trap!(bad_operand, "reduction {k:?} without vreg dst");
        };
        let (Some(&Src::V(vs2)), Some(&Src::V(vs1))) = (inst.srcs.first(), inst.srcs.get(1))
        else {
            trap!(bad_operand, "reduction {k:?} needs two vreg srcs");
        };
        // reduction scalar operands (vs1 init, vd result) are single
        // registers regardless of the vector operand's grouping
        let init = m.read_lane(vs1, sew, Lmul::M1, 0)?;
        if matches!(k, Vfredusum | Vfredmax | Vfredmin) {
            let e = float_elem(sew)?;
            let mut acc = elem::to_f64(e, init);
            for i in 0..vl {
                if let Some(mk) = inst.mask {
                    if !m.mask_bit(mk, i) {
                        continue;
                    }
                }
                let fx = elem::to_f64(e, m.read_lane(vs2, sew, lmul, i)?);
                acc = match k {
                    Vfredusum => acc + fx,
                    Vfredmax => acc.max(fx),
                    Vfredmin => acc.min(fx),
                    _ => trap!(unsupported, "unexpected float reduction {k:?}"),
                };
            }
            m.write_lane(dst, sew, Lmul::M1, 0, elem::from_f64(e, acc))?;
        } else {
            let mut acc_i = elem::to_i64(int_elem(sew, true), init);
            let mut acc_u = elem::to_u64(int_elem(sew, false), init);
            for i in 0..vl {
                if let Some(mk) = inst.mask {
                    if !m.mask_bit(mk, i) {
                        continue;
                    }
                }
                let x = m.read_lane(vs2, sew, lmul, i)?;
                let sx = elem::to_i64(int_elem(sew, true), x);
                let ux = elem::to_u64(int_elem(sew, false), x);
                match k {
                    Vredsum => acc_i = acc_i.wrapping_add(sx),
                    Vredmax => acc_i = acc_i.max(sx),
                    Vredmin => acc_i = acc_i.min(sx),
                    Vredmaxu => acc_u = acc_u.max(ux),
                    Vredminu => acc_u = acc_u.min(ux),
                    _ => trap!(unsupported, "unexpected int reduction {k:?}"),
                }
            }
            let out = if matches!(k, Vredmaxu | Vredminu) {
                acc_u
            } else {
                elem::from_i64(int_elem(sew, true), acc_i)
            };
            m.write_lane(dst, sew, Lmul::M1, 0, out)?;
        }
        return Ok(());
    }

    // permutation ops with cross-lane reads: snapshot sources first
    if matches!(k, Vslideup | Vslidedown | Vslide1down | Vrgather | Vcompress | Vid) {
        let Dst::V(dst) = inst.dst else {
            trap!(bad_operand, "permute {k:?} without vreg dst");
        };
        // VLMAX scales with the register group: an m2 slide reaches across
        // both member registers
        let vlmax = m.cfg.vlen / sew.bits() * group;
        match k {
            Vid => {
                for i in 0..vl {
                    m.write_lane(dst, sew, lmul, i, i as u64)?;
                }
            }
            Vslideup => {
                let Some(&Src::V(src)) = inst.srcs.first() else {
                    trap!(bad_operand, "vslideup needs vreg src");
                };
                let off = match inst.srcs.get(1) {
                    Some(Src::ImmI(i)) => *i as u32,
                    Some(Src::SReg(r)) => m.sregs[*r as usize] as u32,
                    _ => trap!(bad_operand, "vslideup offset operand"),
                };
                let snap = m.read_lanes(src, sew, lmul, vlmax.min(vl + off))?;
                for i in off..vl {
                    m.write_lane(dst, sew, lmul, i, snap[(i - off) as usize])?;
                }
            }
            Vslidedown => {
                let Some(&Src::V(src)) = inst.srcs.first() else {
                    trap!(bad_operand, "vslidedown needs vreg src");
                };
                let off = match inst.srcs.get(1) {
                    Some(Src::ImmI(i)) => *i as u32,
                    Some(Src::SReg(r)) => m.sregs[*r as usize] as u32,
                    _ => trap!(bad_operand, "vslidedown offset operand"),
                };
                let snap = m.read_lanes(src, sew, lmul, vlmax)?;
                for i in 0..vl {
                    let j = i + off;
                    let v = if j < vlmax { snap[j as usize] } else { 0 };
                    m.write_lane(dst, sew, lmul, i, v)?;
                }
            }
            Vslide1down => {
                let Some(&Src::V(src)) = inst.srcs.first() else {
                    trap!(bad_operand, "vslide1down needs vreg src");
                };
                let Some(s1) = inst.srcs.get(1) else {
                    trap!(bad_operand, "vslide1down scalar operand");
                };
                let x = scalar_val(m, s1, sew, false)?;
                let snap = m.read_lanes(src, sew, lmul, vl)?;
                for i in 0..vl.saturating_sub(1) {
                    m.write_lane(dst, sew, lmul, i, snap[(i + 1) as usize])?;
                }
                if vl > 0 {
                    m.write_lane(dst, sew, lmul, vl - 1, x)?;
                }
            }
            Vrgather => {
                let Some(&Src::V(src)) = inst.srcs.first() else {
                    trap!(bad_operand, "vrgather needs vreg src");
                };
                let snap = m.read_lanes(src, sew, lmul, vlmax)?;
                for i in 0..vl {
                    let idx = match inst.srcs.get(1) {
                        Some(Src::V(ir)) => m.read_lane(*ir, sew, lmul, i)?,
                        Some(s) => scalar_val(m, s, sew, false)?,
                        None => trap!(bad_operand, "vrgather index operand"),
                    };
                    let v = if (idx as u32) < vlmax { snap[idx as usize] } else { 0 };
                    m.write_lane(dst, sew, lmul, i, v)?;
                }
            }
            Vcompress => {
                let (Some(&Src::V(src)), Some(&Src::M(mk))) =
                    (inst.srcs.first(), inst.srcs.get(1))
                else {
                    trap!(bad_operand, "vcompress needs vreg + mask srcs");
                };
                let snap = m.read_lanes(src, sew, lmul, vl)?;
                let mut j = 0;
                for i in 0..vl {
                    if m.mask_bit(mk, i) {
                        m.write_lane(dst, sew, lmul, j, snap[i as usize])?;
                        j += 1;
                    }
                }
            }
            _ => trap!(unsupported, "unexpected permute kind {k:?}"),
        }
        return Ok(());
    }

    // everything else: elementwise
    let Dst::V(dst) = inst.dst else {
        trap!(bad_operand, "{k:?} without vreg dst");
    };

    // P4 fast path: vmv.v.v is a bulk register copy (vl*sew bytes);
    // single registers only — grouped moves go through the lane path
    if k == VmvVV && inst.mask.is_none() && group == 1 {
        if let Some(&Src::V(src)) = inst.srcs.first() {
            let n = (vl * sew.bytes()) as usize;
            if src != dst {
                let (a, b) = (src.min(dst) as usize, src.max(dst) as usize);
                // split_at_mut to copy between two registers
                let regs = m.regs_pair_mut(a, b);
                if src < dst {
                    regs.1[..n].copy_from_slice(&regs.0[..n]);
                } else {
                    regs.0[..n].copy_from_slice(&regs.1[..n]);
                }
            }
            return Ok(());
        }
    }

    // P3 fast path: unmasked e32 float vv-ops compute directly in f32
    // (skips the per-lane Elem dispatch + f64 round trip). Single
    // registers only: the helpers address lanes flat within one register.
    if inst.mask.is_none() && sew == Sew::E32 && group == 1 {
        if let Some(done) = exec_f32_fast(m, inst, dst)? {
            if done {
                return Ok(());
            }
        }
        // P4: direct-u32 integer ops (exp reconstruction mix)
        if exec_i32_fast(m, inst, dst)? {
            return Ok(());
        }
    }

    for i in 0..vl {
        if let Some(mk) = inst.mask {
            if !m.mask_bit(mk, i) && !matches!(k, Vmerge | Vfmerge) {
                continue;
            }
        }
        let out = exec_lane(m, inst, i)?;
        let dsew = dst_sew(k, sew)?;
        m.write_lane(dst, dsew, lmul, i, out)?;
    }
    Ok(())
}

/// Destination EEW for widening ops. Convention: for the vw* arithmetic
/// ops `inst.sew` is the *source* SEW (dest doubles); for vzext/vsext the
/// `inst.sew` is already the *destination* SEW (source halves).
fn dst_sew(k: RvvKind, sew: Sew) -> Result<Sew, SimTrap> {
    use RvvKind::*;
    match k {
        Vwmul | Vwmulu | Vwadd | Vwaddu | Vwmacc | Vwmaccu | VfwcvtFF => widened(sew),
        _ => Ok(sew),
    }
}

fn exec_lane(m: &RvvMachine, inst: &RvvInst, i: u32) -> Result<u64, SimTrap> {
    use RvvKind::*;
    let sew = inst.sew;
    let k = inst.kind;
    let lmul = inst.lmul;
    let fe = || float_elem(sew);
    let se = int_elem(sew, true);
    let ue = int_elem(sew, false);
    let a = inst
        .srcs
        .first()
        .map(|s| src_lane(m, s, sew, lmul, i, is_float_op(k)))
        .transpose()?;
    let b = inst
        .srcs
        .get(1)
        .map(|s| src_lane(m, s, sew, lmul, i, is_float_op(k)))
        .transpose()?;

    // operand-or-trap: replaces the old `a.unwrap()` sites
    macro_rules! opa {
        () => {
            match a {
                Some(v) => v,
                None => trap!(bad_operand, "{k:?} missing operand 0"),
            }
        };
    }
    macro_rules! opb {
        () => {
            match b {
                Some(v) => v,
                None => trap!(bad_operand, "{k:?} missing operand 1"),
            }
        };
    }

    Ok(match k {
        Vadd => elem::from_i64(se, elem::to_i64(se, opa!()).wrapping_add(elem::to_i64(se, opb!()))),
        Vsub => elem::from_i64(se, elem::to_i64(se, opa!()).wrapping_sub(elem::to_i64(se, opb!()))),
        Vrsub => elem::from_i64(se, elem::to_i64(se, opb!()).wrapping_sub(elem::to_i64(se, opa!()))),
        Vmul => elem::from_i64(se, elem::to_i64(se, opa!()).wrapping_mul(elem::to_i64(se, opb!()))),
        Vmulh => {
            let p = (elem::to_i64(se, opa!()) as i128) * (elem::to_i64(se, opb!()) as i128);
            elem::from_i64(se, (p >> sew.bits()) as i64)
        }
        Vmulhu => {
            let p = (elem::to_u64(ue, opa!()) as u128) * (elem::to_u64(ue, opb!()) as u128);
            ((p >> sew.bits()) as u64) & ue.lane_mask()
        }
        Vwmul => {
            let wide = int_elem(dst_sew(k, sew)?, true);
            elem::from_i64(wide, elem::to_i64(se, opa!()).wrapping_mul(elem::to_i64(se, opb!())))
        }
        Vwmulu => {
            let wide = int_elem(dst_sew(k, sew)?, false);
            (elem::to_u64(ue, opa!()).wrapping_mul(elem::to_u64(ue, opb!()))) & wide.lane_mask()
        }
        Vwadd => {
            let wide = int_elem(dst_sew(k, sew)?, true);
            elem::from_i64(wide, elem::to_i64(se, opa!()) + elem::to_i64(se, opb!()))
        }
        Vwaddu => elem::to_u64(ue, opa!()) + elem::to_u64(ue, opb!()),
        Vmacc | Vnmsac => {
            let Dst::V(dr) = inst.dst else { trap!(bad_operand, "{k:?} needs vreg dst") };
            let acc = elem::to_i64(se, m.read_lane(dr, sew, lmul, i)?);
            let p = elem::to_i64(se, opa!()).wrapping_mul(elem::to_i64(se, opb!()));
            let r = if k == Vmacc { acc.wrapping_add(p) } else { acc.wrapping_sub(p) };
            elem::from_i64(se, r)
        }
        Vwmacc => {
            let wide = int_elem(dst_sew(k, sew)?, true);
            let Dst::V(dr) = inst.dst else { trap!(bad_operand, "vwmacc needs vreg dst") };
            let acc = elem::to_i64(wide, m.read_lane(dr, dst_sew(k, sew)?, lmul, i)?);
            let p = elem::to_i64(se, opa!()).wrapping_mul(elem::to_i64(se, opb!()));
            elem::from_i64(wide, acc.wrapping_add(p))
        }
        Vwmaccu => {
            let wide = int_elem(dst_sew(k, sew)?, false);
            let Dst::V(dr) = inst.dst else { trap!(bad_operand, "vwmaccu needs vreg dst") };
            let acc = elem::to_u64(wide, m.read_lane(dr, dst_sew(k, sew)?, lmul, i)?);
            let p = elem::to_u64(ue, opa!()).wrapping_mul(elem::to_u64(ue, opb!()));
            (acc.wrapping_add(p)) & wide.lane_mask()
        }
        Vmin => elem::from_i64(se, elem::to_i64(se, opa!()).min(elem::to_i64(se, opb!()))),
        Vmax => elem::from_i64(se, elem::to_i64(se, opa!()).max(elem::to_i64(se, opb!()))),
        Vminu => elem::to_u64(ue, opa!()).min(elem::to_u64(ue, opb!())),
        Vmaxu => elem::to_u64(ue, opa!()).max(elem::to_u64(ue, opb!())),
        Vsadd => elem::saturate(se, elem::to_i64(se, opa!()) as i128 + elem::to_i64(se, opb!()) as i128),
        Vssub => elem::saturate(se, elem::to_i64(se, opa!()) as i128 - elem::to_i64(se, opb!()) as i128),
        Vsaddu => elem::saturate(ue, elem::to_u64(ue, opa!()) as i128 + elem::to_u64(ue, opb!()) as i128),
        Vssubu => elem::saturate(ue, elem::to_u64(ue, opa!()) as i128 - elem::to_u64(ue, opb!()) as i128),
        Vand => opa!() & opb!(),
        Vor => opa!() | opb!(),
        Vxor => opa!() ^ opb!(),
        Vsll => {
            let sh = (opb!() & (sew.bits() as u64 - 1)) as u32;
            (opa!() << sh) & ue.lane_mask()
        }
        Vsrl => {
            let sh = (opb!() & (sew.bits() as u64 - 1)) as u32;
            elem::to_u64(ue, opa!()) >> sh
        }
        Vsra => {
            let sh = (opb!() & (sew.bits() as u64 - 1)) as u32;
            elem::from_i64(se, elem::to_i64(se, opa!()) >> sh)
        }
        Vnsrl => {
            // source EEW = 2*sew
            let wsew = widened(sew)?;
            let wide = int_elem(wsew, false);
            let Some(&Src::V(src)) = inst.srcs.first() else {
                trap!(bad_operand, "vnsrl needs vreg src");
            };
            let x = m.read_lane(src, wsew, lmul, i)?;
            let sh = match inst.srcs.get(1) {
                Some(Src::ImmI(n)) => *n as u32,
                Some(s) => scalar_val(m, s, sew, false)? as u32,
                None => trap!(bad_operand, "vnsrl shift operand"),
            };
            (elem::to_u64(wide, x) >> sh) & ue.lane_mask()
        }
        Vnsra => {
            let wsew = widened(sew)?;
            let wide = int_elem(wsew, true);
            let Some(&Src::V(src)) = inst.srcs.first() else {
                trap!(bad_operand, "vnsra needs vreg src");
            };
            let x = m.read_lane(src, wsew, lmul, i)?;
            let sh = match inst.srcs.get(1) {
                Some(Src::ImmI(n)) => *n as u32,
                Some(s) => scalar_val(m, s, sew, false)? as u32,
                None => trap!(bad_operand, "vnsra shift operand"),
            };
            ((elem::to_i64(wide, x) >> sh) as u64) & ue.lane_mask()
        }
        VmvVV => opa!(),
        VmvVX | VfmvVF => {
            let Some(s0) = inst.srcs.first() else {
                trap!(bad_operand, "{k:?} missing scalar src");
            };
            scalar_val(m, s0, sew, k == VfmvVF)?
        }
        Vmerge | Vfmerge => {
            // srcs: [false_src(vector), true_src(vector|scalar), mask]
            let Some(&Src::M(mk)) = inst.srcs.get(2) else {
                trap!(bad_operand, "vmerge needs mask src");
            };
            if m.mask_bit(mk, i) {
                opb!()
            } else {
                opa!()
            }
        }
        Vzext2 => {
            let half = narrowed(sew)?;
            let Some(&Src::V(src)) = inst.srcs.first() else {
                trap!(bad_operand, "vzext needs vreg src");
            };
            elem::to_u64(int_elem(half, false), m.read_lane(src, half, lmul, i)?)
        }
        Vsext2 => {
            let half = narrowed(sew)?;
            let Some(&Src::V(src)) = inst.srcs.first() else {
                trap!(bad_operand, "vsext needs vreg src");
            };
            elem::from_i64(se, elem::to_i64(int_elem(half, true), m.read_lane(src, half, lmul, i)?))
        }
        Vfadd => fbin(fe()?, opa!(), opb!(), |x, y| x + y),
        Vfsub => fbin(fe()?, opa!(), opb!(), |x, y| x - y),
        Vfrsub => fbin(fe()?, opa!(), opb!(), |x, y| y - x),
        Vfmul => fbin(fe()?, opa!(), opb!(), |x, y| x * y),
        Vfdiv => fbin(fe()?, opa!(), opb!(), |x, y| x / y),
        Vfrdiv => fbin(fe()?, opa!(), opb!(), |x, y| y / x),
        Vfmacc | Vfnmacc | Vfmsac | Vfnmsac => {
            // vd = ±(vs1 * vs2) ± vd ; srcs = [multiplier_a, multiplier_b],
            // accumulator is the destination register
            let Dst::V(dr) = inst.dst else { trap!(bad_operand, "fma {k:?} needs vreg dst") };
            let acc = m.read_lane(dr, sew, lmul, i)?;
            let e = fe()?;
            let (x, y, s) = (elem::to_f64(e, opa!()), elem::to_f64(e, opb!()), elem::to_f64(e, acc));
            let r = match (k, e) {
                // single-rounding fused at lane precision
                (Vfmacc, Elem::F32) => ((x as f32).mul_add(y as f32, s as f32)) as f64,
                (Vfmacc, _) => x.mul_add(y, s),
                (Vfnmacc, Elem::F32) => ((-(x as f32)).mul_add(y as f32, -(s as f32))) as f64,
                (Vfnmacc, _) => (-x).mul_add(y, -s),
                (Vfmsac, Elem::F32) => ((x as f32).mul_add(y as f32, -(s as f32))) as f64,
                (Vfmsac, _) => x.mul_add(y, -s),
                (Vfnmsac, Elem::F32) => ((-(x as f32)).mul_add(y as f32, s as f32)) as f64,
                (Vfnmsac, _) => (-x).mul_add(y, s),
                _ => trap!(unsupported, "unexpected fma kind {k:?}"),
            };
            elem::from_f64(e, r)
        }
        Vfmin => fbin(fe()?, opa!(), opb!(), |x, y| {
            if x.is_nan() || y.is_nan() { f64::NAN } else { x.min(y) }
        }),
        Vfmax => fbin(fe()?, opa!(), opb!(), |x, y| {
            if x.is_nan() || y.is_nan() { f64::NAN } else { x.max(y) }
        }),
        Vfsqrt => funary(fe()?, opa!(), f64::sqrt),
        Vfrec7 => funary(fe()?, opa!(), floatest::recip_estimate),
        Vfrsqrt7 => funary(fe()?, opa!(), floatest::rsqrt_estimate),
        Vfsgnj => fsgn(fe()?, opa!(), opb!(), |_, sb| sb),
        Vfsgnjn => fsgn(fe()?, opa!(), opb!(), |_, sb| !sb),
        Vfsgnjx => fsgn(fe()?, opa!(), opb!(), |sa, sb| sa ^ sb),
        VfcvtXF => {
            let f = elem::to_f64(fe()?, opa!());
            let r = round_ties_even(f);
            saturate_f2i(r, sew, true)
        }
        VfcvtRtzXF => saturate_f2i(elem::to_f64(fe()?, opa!()).trunc(), sew, true),
        VfcvtRtzXuF => saturate_f2i(elem::to_f64(fe()?, opa!()).trunc(), sew, false),
        VfcvtFX => elem::from_f64(fe()?, elem::to_i64(se, opa!()) as f64),
        VfcvtFXu => elem::from_f64(fe()?, elem::to_u64(ue, opa!()) as f64),
        VfwcvtFF => {
            // src EEW = sew, dst = 2*sew
            let Some(&Src::V(src)) = inst.srcs.first() else {
                trap!(bad_operand, "vfwcvt needs vreg src");
            };
            let x = m.read_lane(src, sew, lmul, i)?;
            elem::from_f64(float_elem(dst_sew(k, sew)?)?, elem::to_f64(float_elem(sew)?, x))
        }
        VfncvtFF => {
            // src EEW = 2*sew, dst = sew
            let wide = widened(sew)?;
            let Some(&Src::V(src)) = inst.srcs.first() else {
                trap!(bad_operand, "vfncvt needs vreg src");
            };
            let x = m.read_lane(src, wide, lmul, i)?;
            elem::from_f64(fe()?, elem::to_f64(float_elem(wide)?, x))
        }
        _ => trap!(unsupported, "exec_lane: unhandled kind {k:?}"),
    })
}

/// P4: direct-u32 execution for unmasked e32 integer vv/vx ops.
/// Returns true when handled.
fn exec_i32_fast(m: &mut RvvMachine, inst: &RvvInst, dst: u32) -> Result<bool, SimTrap> {
    use RvvKind::*;
    if !matches!(inst.kind, Vadd | Vsub | Vand | Vor | Vxor | Vsll | Vsrl | Vsra | VmvVX) {
        return Ok(false);
    }
    #[inline(always)]
    fn g(m: &RvvMachine, s: &Src, i: u32) -> Option<u32> {
        match s {
            // a bad register index falls back to the generic path, which
            // raises the structured trap
            Src::V(r) => m.read_lane(*r, Sew::E32, Lmul::M1, i).ok().map(|v| v as u32),
            Src::ImmI(v) => Some(*v as u32),
            _ => None,
        }
    }
    // reject operand kinds the fast path doesn't cover
    if inst.srcs.is_empty() || inst.srcs.iter().any(|s| !matches!(s, Src::V(_) | Src::ImmI(_))) {
        return Ok(false);
    }
    for i in 0..inst.vl {
        let a = match g(m, &inst.srcs[0], i) {
            Some(v) => v,
            None => return Ok(false),
        };
        let r = if inst.kind == VmvVX {
            a
        } else {
            let b = match inst.srcs.get(1).and_then(|s| g(m, s, i)) {
                Some(v) => v,
                None => return Ok(false),
            };
            match inst.kind {
                Vadd => a.wrapping_add(b),
                Vsub => a.wrapping_sub(b),
                Vand => a & b,
                Vor => a | b,
                Vxor => a ^ b,
                Vsll => a << (b & 31),
                Vsrl => a >> (b & 31),
                Vsra => ((a as i32) >> (b & 31)) as u32,
                k => trap!(unsupported, "unexpected i32 fast-path kind {k:?}"),
            }
        };
        m.write_lane(dst, Sew::E32, Lmul::M1, i, r as u64)?;
    }
    Ok(true)
}

/// P3: direct-f32 execution for the hot float ops at SEW=e32.
/// Returns Some(true) when handled.
fn exec_f32_fast(m: &mut RvvMachine, inst: &RvvInst, dst: u32) -> Result<Option<bool>, SimTrap> {
    use RvvKind::*;
    #[inline(always)]
    fn f(m: &RvvMachine, s: &Src, i: u32) -> Option<f32> {
        match s {
            // a bad register index falls back to the generic path, which
            // raises the structured trap
            Src::V(r) => {
                m.read_lane(*r, Sew::E32, Lmul::M1, i).ok().map(|v| f32::from_bits(v as u32))
            }
            Src::ImmF(v) => Some(*v as f32),
            Src::ImmI(v) => Some(f32::from_bits(*v as u32)),
            Src::SReg(_) | Src::M(_) => None, // not handled here
        }
    }
    let handled = matches!(
        inst.kind,
        Vfadd | Vfsub | Vfrsub | Vfmul | Vfdiv | Vfmacc | Vfnmsac | Vfmin | Vfmax
    );
    if !handled
        || inst.srcs.is_empty()
        || inst.srcs.iter().any(|s| matches!(s, Src::SReg(_) | Src::M(_)))
    {
        return Ok(None);
    }
    for i in 0..inst.vl {
        let Some(a) = f(m, &inst.srcs[0], i) else {
            return Ok(None);
        };
        let b = match inst.srcs.get(1) {
            Some(s) => match f(m, s, i) {
                Some(v) => v,
                None => return Ok(None),
            },
            None => 0.0,
        };
        let r = match inst.kind {
            Vfadd => a + b,
            Vfsub => a - b,
            Vfrsub => b - a,
            Vfmul => a * b,
            Vfdiv => a / b,
            Vfmacc => {
                let acc = f32::from_bits(m.read_lane(dst, Sew::E32, Lmul::M1, i)? as u32);
                a.mul_add(b, acc)
            }
            Vfnmsac => {
                let acc = f32::from_bits(m.read_lane(dst, Sew::E32, Lmul::M1, i)? as u32);
                (-a).mul_add(b, acc)
            }
            Vfmin => {
                if a.is_nan() || b.is_nan() { f32::NAN } else { a.min(b) }
            }
            Vfmax => {
                if a.is_nan() || b.is_nan() { f32::NAN } else { a.max(b) }
            }
            k => trap!(unsupported, "unexpected f32 fast-path kind {k:?}"),
        };
        m.write_lane(dst, Sew::E32, Lmul::M1, i, r.to_bits() as u64)?;
    }
    Ok(Some(true))
}

// ---------------------------------------------------------------------------
// Lane-batched execution (the decoded engine's semantics layer).
// ---------------------------------------------------------------------------

/// Reusable operand buffers for [`exec_batched`]: owned by the decoded
/// engine so gathers allocate once per simulation, not per instruction.
#[derive(Debug, Default)]
pub struct ExecScratch {
    a: Vec<u64>,
    b: Vec<u64>,
    c: Vec<u64>,
}

/// Gather one source operand into `out` as `vl` raw lane values
/// (vector lanes bulk-copied, scalars broadcast). Returns false for mask
/// sources, which the batched paths don't model.
fn gather(
    m: &RvvMachine,
    s: &Src,
    sew: Sew,
    lmul: Lmul,
    vl: u32,
    float: bool,
    out: &mut Vec<u64>,
) -> Result<bool, SimTrap> {
    match s {
        Src::V(r) => {
            m.read_lanes_into(*r, sew, lmul, vl, out)?;
            Ok(true)
        }
        Src::M(_) => Ok(false),
        s => {
            let v = scalar_val(m, s, sew, float)?;
            out.clear();
            out.resize(vl as usize, v);
            Ok(true)
        }
    }
}

/// Lane-batched instruction execution, the decoded engine's entry point.
///
/// Element-wise families (integer ALU, e32 float, sign-injection, merges,
/// compares) run as one bulk gather per operand + one tight compute loop
/// + one bulk scatter over the contiguous vreg bytes, instead of the
/// interpreter's per-lane `read_lane`/`write_lane` round-trips (8-byte
/// copy + operand `match` per element per operand). Unmasked reductions
/// bulk-gather the source vector once and fold it with the interpreter's
/// exact accumulator semantics. Everything else — memory ops (already
/// bulk for unit-stride), masked ops, permutes, widening/narrowing —
/// falls back to [`exec`].
///
/// Results are bit-identical to [`exec`] for every instruction (the
/// engine-vs-interpreter differential test enforces this across the whole
/// kernel suite): each batched formula is the generic per-lane formula,
/// and the e32 float paths compute directly in `f32`, which is exact
/// versus the generic `f64` round-trip because double rounding through
/// binary64 is innocuous for binary32 +,-,*,/,sqrt and the fused-multiply
/// forms are evaluated at lane precision in both paths.
pub fn exec_batched(
    m: &mut RvvMachine,
    inst: &RvvInst,
    mem_byte_off: Option<i64>,
    scratch: &mut ExecScratch,
) -> Result<(), SimTrap> {
    use RvvKind::*;
    let k = inst.kind;
    let sew = inst.sew;
    let vl = inst.vl;
    let lmul = inst.lmul;
    check_vl_legal(m, inst)?;

    if inst.mask.is_some() {
        return exec(m, inst, mem_byte_off);
    }

    let cmp_int = matches!(k, Vmseq | Vmsne | Vmslt | Vmsle | Vmsgt | Vmsltu | Vmsleu | Vmsgtu);
    let cmp_f = matches!(k, Vmfeq | Vmfne | Vmflt | Vmfle | Vmfgt | Vmfge);
    if cmp_int || cmp_f {
        let Dst::M(dst) = inst.dst else {
            trap!(bad_operand, "compare {k:?} without mask dst");
        };
        let (Some(s0), Some(s1)) = (inst.srcs.first(), inst.srcs.get(1)) else {
            trap!(bad_operand, "compare {k:?} needs two srcs");
        };
        let (a, b) = (&mut scratch.a, &mut scratch.b);
        if !gather(m, s0, sew, lmul, vl, cmp_f, a)? || !gather(m, s1, sew, lmul, vl, cmp_f, b)? {
            return exec(m, inst, mem_byte_off);
        }
        macro_rules! cmp2 {
            ($f:expr) => {{
                for (i, (&x, &y)) in a.iter().zip(b.iter()).enumerate() {
                    m.write_mask_bit(dst, i as u32, $f(x, y));
                }
            }};
        }
        if cmp_f {
            let fe = float_elem(sew)?;
            match k {
                Vmfeq => cmp2!(|x, y| elem::to_f64(fe, x) == elem::to_f64(fe, y)),
                Vmfne => cmp2!(|x, y| elem::to_f64(fe, x) != elem::to_f64(fe, y)),
                Vmflt => cmp2!(|x, y| elem::to_f64(fe, x) < elem::to_f64(fe, y)),
                Vmfle => cmp2!(|x, y| elem::to_f64(fe, x) <= elem::to_f64(fe, y)),
                Vmfgt => cmp2!(|x, y| elem::to_f64(fe, x) > elem::to_f64(fe, y)),
                Vmfge => cmp2!(|x, y| elem::to_f64(fe, x) >= elem::to_f64(fe, y)),
                _ => trap!(unsupported, "unexpected float compare {k:?}"),
            }
        } else {
            let se = int_elem(sew, true);
            let ue = int_elem(sew, false);
            match k {
                Vmseq => cmp2!(|x: u64, y: u64| x & se.lane_mask() == y & se.lane_mask()),
                Vmsne => cmp2!(|x: u64, y: u64| x & se.lane_mask() != y & se.lane_mask()),
                Vmslt => cmp2!(|x, y| elem::to_i64(se, x) < elem::to_i64(se, y)),
                Vmsle => cmp2!(|x, y| elem::to_i64(se, x) <= elem::to_i64(se, y)),
                Vmsgt => cmp2!(|x, y| elem::to_i64(se, x) > elem::to_i64(se, y)),
                Vmsltu => cmp2!(|x, y| elem::to_u64(ue, x) < elem::to_u64(ue, y)),
                Vmsleu => cmp2!(|x, y| elem::to_u64(ue, x) <= elem::to_u64(ue, y)),
                Vmsgtu => cmp2!(|x, y| elem::to_u64(ue, x) > elem::to_u64(ue, y)),
                _ => trap!(unsupported, "unexpected int compare {k:?}"),
            }
        }
        return Ok(());
    }

    // reductions: one bulk gather of the source vector + a scalar fold,
    // replicating the interpreter's accumulator semantics exactly (f64
    // accumulator for float kinds, dual signed/unsigned accumulators for
    // int kinds), then a single lane-0 write. Masked reductions took the
    // per-lane fallback above.
    if matches!(k, Vredsum | Vredmax | Vredmaxu | Vredmin | Vredminu | Vfredusum | Vfredmax | Vfredmin) {
        let Dst::V(dst) = inst.dst else {
            trap!(bad_operand, "reduction {k:?} without vreg dst");
        };
        let (Some(&Src::V(vs2)), Some(&Src::V(vs1))) = (inst.srcs.first(), inst.srcs.get(1))
        else {
            trap!(bad_operand, "reduction {k:?} needs two vreg srcs");
        };
        m.read_lanes_into(vs2, sew, lmul, vl, &mut scratch.a)?;
        // reduction scalar operands are single registers (see `exec`)
        let init = m.read_lane(vs1, sew, Lmul::M1, 0)?;
        if matches!(k, Vfredusum | Vfredmax | Vfredmin) {
            let e = float_elem(sew)?;
            let mut acc = elem::to_f64(e, init);
            for &x in scratch.a.iter() {
                let fx = elem::to_f64(e, x);
                acc = match k {
                    Vfredusum => acc + fx,
                    Vfredmax => acc.max(fx),
                    Vfredmin => acc.min(fx),
                    _ => trap!(unsupported, "unexpected float reduction {k:?}"),
                };
            }
            m.write_lane(dst, sew, Lmul::M1, 0, elem::from_f64(e, acc))?;
        } else {
            let (se, ue) = (int_elem(sew, true), int_elem(sew, false));
            let mut acc_i = elem::to_i64(se, init);
            let mut acc_u = elem::to_u64(ue, init);
            for &x in scratch.a.iter() {
                let sx = elem::to_i64(se, x);
                let ux = elem::to_u64(ue, x);
                match k {
                    Vredsum => acc_i = acc_i.wrapping_add(sx),
                    Vredmax => acc_i = acc_i.max(sx),
                    Vredmin => acc_i = acc_i.min(sx),
                    Vredmaxu => acc_u = acc_u.max(ux),
                    Vredminu => acc_u = acc_u.min(ux),
                    _ => trap!(unsupported, "unexpected int reduction {k:?}"),
                }
            }
            let out = if matches!(k, Vredmaxu | Vredminu) {
                acc_u
            } else {
                elem::from_i64(se, acc_i)
            };
            m.write_lane(dst, sew, Lmul::M1, 0, out)?;
        }
        return Ok(());
    }

    let int_bin = matches!(
        k,
        Vadd | Vsub | Vrsub | Vmul | Vmulh | Vmulhu | Vmin | Vmax | Vminu | Vmaxu | Vsadd
            | Vssub | Vsaddu | Vssubu | Vand | Vor | Vxor | Vsll | Vsrl | Vsra
    );
    let int_macc = matches!(k, Vmacc | Vnmsac);
    let f32_bin = sew == Sew::E32
        && matches!(k, Vfadd | Vfsub | Vfrsub | Vfmul | Vfdiv | Vfrdiv | Vfmin | Vfmax);
    let f32_fma = sew == Sew::E32 && matches!(k, Vfmacc | Vfnmacc | Vfmsac | Vfnmsac);
    let f32_unary = sew == Sew::E32 && k == Vfsqrt;
    let sgnj = matches!(k, Vfsgnj | Vfsgnjn | Vfsgnjx);
    let merge = matches!(k, Vmerge | Vfmerge);
    let bcast = matches!(k, VmvVX | VfmvVF);

    if !(int_bin || int_macc || f32_bin || f32_fma || f32_unary || sgnj || merge || bcast) {
        return exec(m, inst, mem_byte_off);
    }

    let Dst::V(dst) = inst.dst else {
        trap!(bad_operand, "{k:?} without vreg dst");
    };
    let float = is_float_op(k);
    let (a, b) = (&mut scratch.a, &mut scratch.b);

    if bcast {
        let Some(s0) = inst.srcs.first() else {
            trap!(bad_operand, "{k:?} missing scalar src");
        };
        let v = scalar_val(m, s0, sew, k == VfmvVF)?;
        a.clear();
        a.resize(vl as usize, v);
        m.write_lanes_from(dst, sew, lmul, a)?;
        return Ok(());
    }

    let Some(s0) = inst.srcs.first() else {
        trap!(bad_operand, "{k:?} missing operand 0");
    };
    if !gather(m, s0, sew, lmul, vl, float, a)? {
        return exec(m, inst, mem_byte_off);
    }
    let binary = !f32_unary;
    if binary {
        let Some(s1) = inst.srcs.get(1) else {
            trap!(bad_operand, "{k:?} missing operand 1");
        };
        if !gather(m, s1, sew, lmul, vl, float, b)? {
            return exec(m, inst, mem_byte_off);
        }
    }

    // compute in place over `a` (or over the gathered accumulator `c`)
    macro_rules! zip2 {
        ($f:expr) => {{
            for (x, &y) in a.iter_mut().zip(b.iter()) {
                *x = $f(*x, y);
            }
        }};
    }
    macro_rules! fzip2 {
        ($f:expr) => {
            zip2!(|x: u64, y: u64| {
                let (fx, fy) = (f32::from_bits(x as u32), f32::from_bits(y as u32));
                let r: f32 = $f(fx, fy);
                r.to_bits() as u64
            })
        };
    }

    if merge {
        // srcs: [false_src, true_src, mask] — lane-select by mask bit
        let Some(&Src::M(mk)) = inst.srcs.get(2) else {
            trap!(bad_operand, "vmerge needs mask src");
        };
        let c = &mut scratch.c;
        c.clear();
        c.extend(m.mask_bits(mk, vl).iter().map(|&t| t as u64));
        for ((x, &y), &t) in a.iter_mut().zip(b.iter()).zip(c.iter()) {
            if t != 0 {
                *x = y;
            }
        }
        m.write_lanes_from(dst, sew, lmul, a)?;
        return Ok(());
    }

    if int_macc || f32_fma {
        // accumulator is the destination register
        let c = &mut scratch.c;
        m.read_lanes_into(dst, sew, lmul, vl, c)?;
        if int_macc {
            let se = int_elem(sew, true);
            for ((s, &x), &y) in c.iter_mut().zip(a.iter()).zip(b.iter()) {
                let acc = elem::to_i64(se, *s);
                let p = elem::to_i64(se, x).wrapping_mul(elem::to_i64(se, y));
                let r = if k == Vmacc { acc.wrapping_add(p) } else { acc.wrapping_sub(p) };
                *s = elem::from_i64(se, r);
            }
        } else {
            for ((s, &x), &y) in c.iter_mut().zip(a.iter()).zip(b.iter()) {
                let (fx, fy, fs) = (
                    f32::from_bits(x as u32),
                    f32::from_bits(y as u32),
                    f32::from_bits(*s as u32),
                );
                let r = match k {
                    Vfmacc => fx.mul_add(fy, fs),
                    Vfnmacc => (-fx).mul_add(fy, -fs),
                    Vfmsac => fx.mul_add(fy, -fs),
                    Vfnmsac => (-fx).mul_add(fy, fs),
                    _ => trap!(unsupported, "unexpected fma kind {k:?}"),
                };
                *s = r.to_bits() as u64;
            }
        }
        m.write_lanes_from(dst, sew, lmul, c)?;
        return Ok(());
    }

    if int_bin {
        let se = int_elem(sew, true);
        let ue = int_elem(sew, false);
        let shmask = sew.bits() as u64 - 1;
        match k {
            Vadd => zip2!(|x, y| elem::from_i64(se, elem::to_i64(se, x).wrapping_add(elem::to_i64(se, y)))),
            Vsub => zip2!(|x, y| elem::from_i64(se, elem::to_i64(se, x).wrapping_sub(elem::to_i64(se, y)))),
            Vrsub => zip2!(|x, y| elem::from_i64(se, elem::to_i64(se, y).wrapping_sub(elem::to_i64(se, x)))),
            Vmul => zip2!(|x, y| elem::from_i64(se, elem::to_i64(se, x).wrapping_mul(elem::to_i64(se, y)))),
            Vmulh => zip2!(|x, y| {
                let p = (elem::to_i64(se, x) as i128) * (elem::to_i64(se, y) as i128);
                elem::from_i64(se, (p >> sew.bits()) as i64)
            }),
            Vmulhu => zip2!(|x, y| {
                let p = (elem::to_u64(ue, x) as u128) * (elem::to_u64(ue, y) as u128);
                ((p >> sew.bits()) as u64) & ue.lane_mask()
            }),
            Vmin => zip2!(|x, y| elem::from_i64(se, elem::to_i64(se, x).min(elem::to_i64(se, y)))),
            Vmax => zip2!(|x, y| elem::from_i64(se, elem::to_i64(se, x).max(elem::to_i64(se, y)))),
            Vminu => zip2!(|x, y| elem::to_u64(ue, x).min(elem::to_u64(ue, y))),
            Vmaxu => zip2!(|x, y| elem::to_u64(ue, x).max(elem::to_u64(ue, y))),
            Vsadd => zip2!(|x, y| elem::saturate(se, elem::to_i64(se, x) as i128 + elem::to_i64(se, y) as i128)),
            Vssub => zip2!(|x, y| elem::saturate(se, elem::to_i64(se, x) as i128 - elem::to_i64(se, y) as i128)),
            Vsaddu => zip2!(|x, y| elem::saturate(ue, elem::to_u64(ue, x) as i128 + elem::to_u64(ue, y) as i128)),
            Vssubu => zip2!(|x, y| elem::saturate(ue, elem::to_u64(ue, x) as i128 - elem::to_u64(ue, y) as i128)),
            Vand => zip2!(|x: u64, y: u64| x & y),
            Vor => zip2!(|x: u64, y: u64| x | y),
            Vxor => zip2!(|x: u64, y: u64| x ^ y),
            Vsll => zip2!(|x: u64, y: u64| (x << ((y & shmask) as u32)) & ue.lane_mask()),
            Vsrl => zip2!(|x, y: u64| elem::to_u64(ue, x) >> ((y & shmask) as u32)),
            Vsra => zip2!(|x, y: u64| elem::from_i64(se, elem::to_i64(se, x) >> ((y & shmask) as u32))),
            _ => trap!(unsupported, "unexpected int-bin kind {k:?}"),
        }
        m.write_lanes_from(dst, sew, lmul, a)?;
        return Ok(());
    }

    if sgnj {
        let fe = float_elem(sew)?;
        match k {
            Vfsgnj => zip2!(|x, y| fsgn(fe, x, y, |_, sb| sb)),
            Vfsgnjn => zip2!(|x, y| fsgn(fe, x, y, |_, sb| !sb)),
            Vfsgnjx => zip2!(|x, y| fsgn(fe, x, y, |sa, sb| sa ^ sb)),
            _ => trap!(unsupported, "unexpected sign-injection kind {k:?}"),
        }
        m.write_lanes_from(dst, sew, lmul, a)?;
        return Ok(());
    }

    if f32_unary {
        for x in a.iter_mut() {
            *x = f32::from_bits(*x as u32).sqrt().to_bits() as u64;
        }
        m.write_lanes_from(dst, sew, lmul, a)?;
        return Ok(());
    }

    debug_assert!(f32_bin);
    match k {
        Vfadd => fzip2!(|x: f32, y: f32| x + y),
        Vfsub => fzip2!(|x: f32, y: f32| x - y),
        Vfrsub => fzip2!(|x: f32, y: f32| y - x),
        Vfmul => fzip2!(|x: f32, y: f32| x * y),
        Vfdiv => fzip2!(|x: f32, y: f32| x / y),
        Vfrdiv => fzip2!(|x: f32, y: f32| y / x),
        Vfmin => fzip2!(|x: f32, y: f32| if x.is_nan() || y.is_nan() { f32::NAN } else { x.min(y) }),
        Vfmax => fzip2!(|x: f32, y: f32| if x.is_nan() || y.is_nan() { f32::NAN } else { x.max(y) }),
        _ => trap!(unsupported, "unexpected f32-bin kind {k:?}"),
    }
    m.write_lanes_from(dst, sew, lmul, a)?;
    Ok(())
}

fn is_float_op(k: RvvKind) -> bool {
    use RvvKind::*;
    matches!(
        k,
        Vfadd | Vfsub | Vfrsub | Vfmul | Vfdiv | Vfrdiv | Vfmacc | Vfnmacc
            | Vfmsac | Vfnmsac | Vfmin | Vfmax | Vfsqrt | Vfrec7 | Vfrsqrt7
            | Vfsgnj | Vfsgnjn | Vfsgnjx | VfmvVF | Vfmerge | Vmfeq | Vmfne
            | Vmflt | Vmfle | Vmfgt | Vmfge
    )
}

fn fbin(e: Elem, a: u64, b: u64, f: impl Fn(f64, f64) -> f64) -> u64 {
    elem::from_f64(e, f(elem::to_f64(e, a), elem::to_f64(e, b)))
}

fn funary(e: Elem, a: u64, f: impl Fn(f64) -> f64) -> u64 {
    elem::from_f64(e, f(elem::to_f64(e, a)))
}

fn fsgn(e: Elem, a: u64, b: u64, pick: impl Fn(bool, bool) -> bool) -> u64 {
    let sign_bit = 1u64 << (e.bits() - 1);
    let (sa, sb) = (a & sign_bit != 0, b & sign_bit != 0);
    let s = pick(sa, sb);
    (a & !sign_bit) | if s { sign_bit } else { 0 }
}

fn saturate_f2i(r: f64, sew: Sew, signed: bool) -> u64 {
    let bits = sew.bits();
    if r.is_nan() {
        return 0;
    }
    if signed {
        let (lo, hi) = (-(2f64.powi(bits as i32 - 1)), 2f64.powi(bits as i32 - 1) - 1.0);
        elem::from_i64(int_elem(sew, true), r.clamp(lo, hi) as i64)
    } else {
        let hi = 2f64.powi(bits as i32) - 1.0;
        (r.clamp(0.0, hi) as u64) & int_elem(sew, false).lane_mask()
    }
}

fn round_ties_even(f: f64) -> f64 {
    if (f - f.trunc()).abs() == 0.5 {
        if (f.floor() as i64) % 2 == 0 {
            f.floor()
        } else {
            f.ceil()
        }
    } else {
        f.round()
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::ir::AddrExpr;
    use crate::neon::interp::Buffer;
    use crate::rvv::machine::RvvConfig;
    use crate::rvv::ops::MemRef;
    use crate::rvv::trap::TrapKind;

    fn mk_machine() -> RvvMachine {
        RvvMachine::new(RvvConfig::new(128), 8, 4, 4, vec![Buffer::from_i32s(&[1, 2, 3, 4, 5, 6, 7, 8])])
    }

    fn vinst(kind: RvvKind, dst: Dst, srcs: Vec<Src>) -> RvvInst {
        RvvInst { kind, sew: Sew::E32, lmul: Lmul::M1, vl: 4, dst, srcs, mask: None, mem: None }
    }

    fn load(m: &mut RvvMachine, dst: u32, byte_off: i64) {
        let inst = RvvInst {
            kind: RvvKind::Vle,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 4,
            dst: Dst::V(dst),
            srcs: vec![],
            mask: None,
            mem: Some(MemRef { buf: 0, index: AddrExpr::k(0), stride: 1 }),
        };
        exec(m, &inst, Some(byte_off)).unwrap();
    }

    #[test]
    fn vle_vadd_vse_roundtrip() {
        // the Listing 10 instruction sequence
        let mut m = mk_machine();
        load(&mut m, 0, 0);
        load(&mut m, 1, 16);
        exec(&mut m, &vinst(RvvKind::Vadd, Dst::V(2), vec![Src::V(0), Src::V(1)]), None).unwrap();
        let st = RvvInst {
            kind: RvvKind::Vse,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 4,
            dst: Dst::None,
            srcs: vec![Src::V(2)],
            mask: None,
            mem: Some(MemRef { buf: 0, index: AddrExpr::k(0), stride: 1 }),
        };
        exec(&mut m, &st, Some(0)).unwrap();
        assert_eq!(m.bufs[0].as_i32s(), vec![6, 8, 10, 12, 5, 6, 7, 8]);
    }

    #[test]
    fn vmseq_vmerge_ceq_pattern() {
        // paper Listing 6: vmv + vmseq + vmerge implements vceqq
        let mut m = mk_machine();
        load(&mut m, 0, 0); // [1,2,3,4]
        exec(&mut m, &vinst(RvvKind::VmvVX, Dst::V(1), vec![Src::ImmI(3)]), None).unwrap();
        exec(&mut m, &vinst(RvvKind::VmvVX, Dst::V(2), vec![Src::ImmI(0)]), None).unwrap();
        exec(&mut m, &vinst(RvvKind::Vmseq, Dst::M(0), vec![Src::V(0), Src::V(1)]), None).unwrap();
        exec(&mut m, &vinst(RvvKind::Vmerge, Dst::V(3), vec![Src::V(2), Src::ImmI(-1), Src::M(0)]), None).unwrap();
        let out: Vec<u64> = m.read_lanes(3, Sew::E32, Lmul::M1, 4).unwrap();
        assert_eq!(out, vec![0, 0, 0xffff_ffff, 0]);
    }

    #[test]
    fn vslidedown_get_high_pattern() {
        // paper Listing 5: vget_high via vslidedown
        let mut m = mk_machine();
        load(&mut m, 0, 0); // [1,2,3,4]
        exec(&mut m, &vinst(RvvKind::Vslidedown, Dst::V(1), vec![Src::V(0), Src::ImmI(2)]), None).unwrap();
        assert_eq!(m.read_lanes(1, Sew::E32, Lmul::M1, 2).unwrap(), vec![3, 4]);
    }

    #[test]
    fn vfmacc_accumulates_into_dst() {
        let mut m = mk_machine();
        for (lane, v) in [2.0f32, 3.0, 4.0, 5.0].iter().enumerate() {
            m.write_lane(0, Sew::E32, Lmul::M1, lane as u32, v.to_bits() as u64).unwrap();
            m.write_lane(1, Sew::E32, Lmul::M1, lane as u32, 10f32.to_bits() as u64).unwrap();
            m.write_lane(2, Sew::E32, Lmul::M1, lane as u32, 1f32.to_bits() as u64).unwrap();
        }
        exec(&mut m, &vinst(RvvKind::Vfmacc, Dst::V(2), vec![Src::V(0), Src::V(1)]), None).unwrap();
        let out: Vec<f32> = (0..4)
            .map(|i| f32::from_bits(m.read_lane(2, Sew::E32, Lmul::M1, i).unwrap() as u32))
            .collect();
        assert_eq!(out, vec![21.0, 31.0, 41.0, 51.0]);
    }

    #[test]
    fn masked_op_leaves_lanes_undisturbed() {
        let mut m = mk_machine();
        load(&mut m, 0, 0);
        exec(&mut m, &vinst(RvvKind::VmvVX, Dst::V(1), vec![Src::ImmI(100)]), None).unwrap();
        m.write_mask_bit(0, 0, true);
        m.write_mask_bit(0, 2, true);
        let mut add = vinst(RvvKind::Vadd, Dst::V(1), vec![Src::V(0), Src::ImmI(1)]);
        add.mask = Some(0);
        exec(&mut m, &add, None).unwrap();
        assert_eq!(m.read_lanes(1, Sew::E32, Lmul::M1, 4).unwrap(), vec![2, 100, 4, 100]);
    }

    #[test]
    fn vid_and_vrgather_reverse() {
        let mut m = mk_machine();
        load(&mut m, 0, 0);
        exec(&mut m, &vinst(RvvKind::Vid, Dst::V(1), vec![]), None).unwrap();
        // idx = 3 - vid
        exec(&mut m, &vinst(RvvKind::Vrsub, Dst::V(2), vec![Src::V(1), Src::ImmI(3)]), None).unwrap();
        exec(&mut m, &vinst(RvvKind::Vrgather, Dst::V(3), vec![Src::V(0), Src::V(2)]), None).unwrap();
        assert_eq!(m.read_lanes(3, Sew::E32, Lmul::M1, 4).unwrap(), vec![4, 3, 2, 1]);
    }

    #[test]
    fn vwmul_widens() {
        let mut m = mk_machine();
        let mut inst = vinst(RvvKind::Vwmul, Dst::V(1), vec![Src::V(0), Src::V(0)]);
        inst.sew = Sew::E16;
        inst.vl = 4;
        for (i, v) in [-300i64, 2, 3, 4].iter().enumerate() {
            m.write_lane(0, Sew::E16, Lmul::M1, i as u32, (*v as u64) & 0xffff).unwrap();
        }
        exec(&mut m, &inst, None).unwrap();
        let out: Vec<i64> = (0..4)
            .map(|i| elem::to_i64(Elem::I32, m.read_lane(1, Sew::E32, Lmul::M1, i).unwrap()))
            .collect();
        assert_eq!(out, vec![90000, 4, 9, 16]);
    }

    #[test]
    fn vfrsqrt7_matches_shared_estimate() {
        let mut m = mk_machine();
        m.write_lane(0, Sew::E32, Lmul::M1, 0, 4f32.to_bits() as u64).unwrap();
        exec(&mut m, &vinst(RvvKind::Vfrsqrt7, Dst::V(1), vec![Src::V(0)]), None).unwrap();
        let got = f32::from_bits(m.read_lane(1, Sew::E32, Lmul::M1, 0).unwrap() as u32);
        assert!((got as f64 - 0.5).abs() < 1.0 / 256.0);
    }

    #[test]
    fn vredsum_folds() {
        let mut m = mk_machine();
        load(&mut m, 0, 0); // [1,2,3,4]
        exec(&mut m, &vinst(RvvKind::VmvVX, Dst::V(1), vec![Src::ImmI(10)]), None).unwrap();
        exec(&mut m, &vinst(RvvKind::Vredsum, Dst::V(2), vec![Src::V(0), Src::V(1)]), None).unwrap();
        assert_eq!(m.read_lane(2, Sew::E32, Lmul::M1, 0).unwrap(), 20);
    }

    #[test]
    fn batched_reductions_match_interpreter() {
        use RvvKind::*;
        // signed negatives + a large unsigned value so the signed and
        // unsigned folds genuinely diverge per kind
        let ints: [i64; 4] = [-3, 7, -1, 0x7fff_0001];
        for k in [Vredsum, Vredmax, Vredmaxu, Vredmin, Vredminu] {
            let mut m1 = mk_machine();
            let mut m2 = mk_machine();
            for m in [&mut m1, &mut m2] {
                for (i, v) in ints.iter().enumerate() {
                    m.write_lane(0, Sew::E32, Lmul::M1, i as u32, (*v as u64) & 0xffff_ffff)
                        .unwrap();
                }
                exec(m, &vinst(VmvVX, Dst::V(1), vec![Src::ImmI(5)]), None).unwrap();
            }
            let inst = vinst(k, Dst::V(2), vec![Src::V(0), Src::V(1)]);
            exec(&mut m1, &inst, None).unwrap();
            let mut scratch = ExecScratch::default();
            exec_batched(&mut m2, &inst, None, &mut scratch).unwrap();
            assert_eq!(
                m1.read_lane(2, Sew::E32, Lmul::M1, 0).unwrap(),
                m2.read_lane(2, Sew::E32, Lmul::M1, 0).unwrap(),
                "batched {k:?} diverged from interpreter"
            );
        }
        let floats: [f32; 4] = [1.5, -2.25, 8.0, 0.125];
        for k in [Vfredusum, Vfredmax, Vfredmin] {
            let mut m1 = mk_machine();
            let mut m2 = mk_machine();
            for m in [&mut m1, &mut m2] {
                for (i, v) in floats.iter().enumerate() {
                    m.write_lane(0, Sew::E32, Lmul::M1, i as u32, v.to_bits() as u64).unwrap();
                }
                exec(m, &vinst(VfmvVF, Dst::V(1), vec![Src::ImmF(0.5)]), None).unwrap();
            }
            let inst = vinst(k, Dst::V(2), vec![Src::V(0), Src::V(1)]);
            exec(&mut m1, &inst, None).unwrap();
            let mut scratch = ExecScratch::default();
            exec_batched(&mut m2, &inst, None, &mut scratch).unwrap();
            assert_eq!(
                m1.read_lane(2, Sew::E32, Lmul::M1, 0).unwrap(),
                m2.read_lane(2, Sew::E32, Lmul::M1, 0).unwrap(),
                "batched {k:?} diverged from interpreter"
            );
        }
    }

    #[test]
    fn vlse_stride_zero_broadcasts() {
        // the custom vld1q_dup lowering: stride-0 strided load
        let mut m = mk_machine();
        let inst = RvvInst {
            kind: RvvKind::Vlse,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 4,
            dst: Dst::V(0),
            srcs: vec![],
            mask: None,
            mem: Some(MemRef { buf: 0, index: AddrExpr::k(0), stride: 0 }),
        };
        exec(&mut m, &inst, Some(8)).unwrap(); // element 2 (= 3)
        assert_eq!(m.read_lanes(0, Sew::E32, Lmul::M1, 4).unwrap(), vec![3, 3, 3, 3]);
    }

    #[test]
    fn vsse_strided_store() {
        let mut m = mk_machine();
        for i in 0..2 {
            m.write_lane(0, Sew::E32, Lmul::M1, i, 99 + i as u64).unwrap();
        }
        let inst = RvvInst {
            kind: RvvKind::Vsse,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 2,
            dst: Dst::None,
            srcs: vec![Src::V(0)],
            mask: None,
            mem: Some(MemRef { buf: 0, index: AddrExpr::k(0), stride: 2 }),
        };
        exec(&mut m, &inst, Some(0)).unwrap();
        assert_eq!(m.bufs[0].as_i32s(), vec![99, 2, 100, 4, 5, 6, 7, 8]);
    }

    #[test]
    fn bulk_fast_path_matches_slow_path_semantics() {
        // masked load forces the per-lane path; unmasked takes the bulk
        // path — same bytes either way
        let mut m1 = mk_machine();
        let mut m2 = mk_machine();
        let fast = RvvInst {
            kind: RvvKind::Vle,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 4,
            dst: Dst::V(0),
            srcs: vec![],
            mask: None,
            mem: Some(MemRef { buf: 0, index: AddrExpr::k(0), stride: 1 }),
        };
        exec(&mut m1, &fast, Some(4)).unwrap();
        let mut slow = fast.clone();
        slow.mask = Some(0);
        for i in 0..4 {
            m2.write_mask_bit(0, i, true);
        }
        exec(&mut m2, &slow, Some(4)).unwrap();
        assert_eq!(
            m1.read_lanes(0, Sew::E32, Lmul::M1, 4).unwrap(),
            m2.read_lanes(0, Sew::E32, Lmul::M1, 4).unwrap()
        );
    }

    #[test]
    fn vnsrl_narrows() {
        let mut m = mk_machine();
        m.write_lane(0, Sew::E32, Lmul::M1, 0, 0x0001_0002).unwrap();
        m.write_lane(0, Sew::E32, Lmul::M1, 1, 0xffff_0000).unwrap();
        let mut inst = vinst(RvvKind::Vnsrl, Dst::V(1), vec![Src::V(0), Src::ImmI(16)]);
        inst.sew = Sew::E16;
        inst.vl = 2;
        exec(&mut m, &inst, None).unwrap();
        assert_eq!(m.read_lane(1, Sew::E16, Lmul::M1, 0).unwrap(), 1);
        assert_eq!(m.read_lane(1, Sew::E16, Lmul::M1, 1).unwrap(), 0xffff);
    }

    #[test]
    fn oob_store_traps_with_structured_kind() {
        let mut m = mk_machine();
        let st = RvvInst {
            kind: RvvKind::Vse,
            sew: Sew::E32,
            lmul: Lmul::M1,
            vl: 4,
            dst: Dst::None,
            srcs: vec![Src::V(0)],
            mask: None,
            mem: Some(MemRef { buf: 0, index: AddrExpr::k(0), stride: 1 }),
        };
        // buffer is 32 bytes; a 16-byte store at byte 20 runs past the end
        let t = exec(&mut m, &st, Some(20)).unwrap_err();
        match t.kind {
            TrapKind::OutOfBounds { buf, byte_off, width, len, store } => {
                assert_eq!((buf, byte_off, width, len, store), (0, 20, 16, 32, true));
            }
            other => panic!("expected OOB trap, got {other:?}"),
        }
    }

    #[test]
    fn e8_float_op_is_illegal_instruction() {
        let mut m = mk_machine();
        let mut inst = vinst(RvvKind::Vfadd, Dst::V(1), vec![Src::V(0), Src::V(0)]);
        inst.sew = Sew::E8;
        let t = exec(&mut m, &inst, None).unwrap_err();
        assert!(matches!(t.kind, TrapKind::IllegalInstruction(_)), "{t}");
    }

    #[test]
    fn grouped_add_matches_per_register_m1() {
        // VLEN=128, e32, m2: one grouped vadd over 8 lanes must equal two
        // m1 vadds over the member registers — on both execution paths
        let vals_a: Vec<u64> = (0..8).map(|i| 10 + i).collect();
        let vals_b: Vec<u64> = (0..8).map(|i| 100 * (i + 1)).collect();
        let mut grouped = RvvMachine::new(RvvConfig::new(128), 8, 0, 0, vec![]);
        let mut batched = RvvMachine::new(RvvConfig::new(128), 8, 0, 0, vec![]);
        for m in [&mut grouped, &mut batched] {
            m.write_lanes_from(0, Sew::E32, Lmul::M2, &vals_a).unwrap();
            m.write_lanes_from(2, Sew::E32, Lmul::M2, &vals_b).unwrap();
        }
        let mut inst = vinst(RvvKind::Vadd, Dst::V(4), vec![Src::V(0), Src::V(2)]);
        inst.lmul = Lmul::M2;
        inst.vl = 8;
        exec(&mut grouped, &inst, None).unwrap();
        let mut scratch = ExecScratch::default();
        exec_batched(&mut batched, &inst, None, &mut scratch).unwrap();
        let want: Vec<u64> = vals_a.iter().zip(&vals_b).map(|(a, b)| a + b).collect();
        assert_eq!(grouped.read_lanes(4, Sew::E32, Lmul::M2, 8).unwrap(), want);
        assert_eq!(batched.read_lanes(4, Sew::E32, Lmul::M2, 8).unwrap(), want);
        // and the group halves are plain m1 registers
        assert_eq!(grouped.read_lanes(4, Sew::E32, Lmul::M1, 4).unwrap(), want[..4]);
        assert_eq!(grouped.read_lanes(5, Sew::E32, Lmul::M1, 4).unwrap(), want[4..]);
    }

    #[test]
    fn vl_beyond_vlmax_is_vsetvli_trap() {
        // VLEN=128, e32, m1: VLMAX is 4, vl=8 is a configuration breach
        let mut m = mk_machine();
        let mut inst = vinst(RvvKind::Vadd, Dst::V(2), vec![Src::V(0), Src::V(1)]);
        inst.vl = 8;
        let t = exec(&mut m, &inst, None).unwrap_err();
        assert!(matches!(t.kind, TrapKind::VsetvliViolation(_)), "{t}");
        let mut scratch = ExecScratch::default();
        let t = exec_batched(&mut m, &inst, None, &mut scratch).unwrap_err();
        assert!(matches!(t.kind, TrapKind::VsetvliViolation(_)), "{t}");
        // the same vl is legal at m2
        inst.lmul = Lmul::M2;
        inst.dst = Dst::V(2);
        inst.srcs = vec![Src::V(0), Src::ImmI(1)];
        exec(&mut m, &inst, None).unwrap();
    }

    #[test]
    fn misaligned_group_is_bad_operand_trap() {
        let mut m = mk_machine();
        let mut inst = vinst(RvvKind::Vadd, Dst::V(1), vec![Src::V(0), Src::ImmI(1)]);
        inst.lmul = Lmul::M2;
        inst.vl = 8;
        // v1 dst is not 2-aligned; v0 src is fine
        let t = exec(&mut m, &inst, None).unwrap_err();
        assert!(matches!(t.kind, TrapKind::BadOperand(_)), "{t}");
        let mut scratch = ExecScratch::default();
        let t = exec_batched(&mut m, &inst, None, &mut scratch).unwrap_err();
        assert!(matches!(t.kind, TrapKind::BadOperand(_)), "{t}");
    }

    #[test]
    fn grouped_widening_op_is_unsupported() {
        let mut m = mk_machine();
        let mut inst = vinst(RvvKind::Vwmul, Dst::V(2), vec![Src::V(0), Src::V(0)]);
        inst.sew = Sew::E16;
        inst.lmul = Lmul::M2;
        inst.vl = 8;
        let t = exec(&mut m, &inst, None).unwrap_err();
        assert!(matches!(t.kind, TrapKind::UnsupportedOp(_)), "{t}");
    }

    #[test]
    fn missing_operand_is_bad_operand_trap() {
        let mut m = mk_machine();
        // vadd with a single src: operand 1 is missing
        let t = exec(&mut m, &vinst(RvvKind::Vadd, Dst::V(1), vec![Src::V(0)]), None).unwrap_err();
        assert!(matches!(t.kind, TrapKind::BadOperand(_)), "{t}");
        // compare with a mask dst missing -> bad operand, not panic
        let t2 = exec(&mut m, &vinst(RvvKind::Vmseq, Dst::V(1), vec![Src::V(0), Src::V(0)]), None)
            .unwrap_err();
        assert!(matches!(t2.kind, TrapKind::BadOperand(_)), "{t2}");
    }
}
