//! The RVV virtual machine state: a VLEN-parameterised vector register
//! file, mask registers, scalar registers, and byte-addressed buffers.
//!
//! Register files are *virtual* (sized by the program, like post-regalloc
//! SSA): the simulator counts instructions, it does not model register
//! pressure — matching the paper's functional-simulation methodology.

use crate::neon::interp::Buffer;
use super::trap::SimTrap;
use super::vtype::Sew;

/// Machine configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RvvConfig {
    /// Vector register length in bits (the paper's `vlen`, compile-time
    /// fixed via `__riscv_v_fixed_vlen`).
    pub vlen: u32,
    /// Zvfh extension (f16 vectors) enabled — gates Table 2 f16 rows.
    pub zvfh: bool,
}

impl Default for RvvConfig {
    fn default() -> Self {
        RvvConfig { vlen: 128, zvfh: true }
    }
}

impl RvvConfig {
    pub fn new(vlen: u32) -> RvvConfig {
        match RvvConfig::try_new(vlen) {
            Ok(c) => c,
            Err(t) => panic!("{t}"),
        }
    }

    /// Fallible constructor: a bad VLEN is a [`SimTrap`] (vsetvli
    /// violation), not a panic — the coordinator uses this so malformed
    /// job parameters become `FaultRecord`s.
    pub fn try_new(vlen: u32) -> Result<RvvConfig, SimTrap> {
        if !(vlen.is_power_of_two() && (32..=65536).contains(&vlen)) {
            return Err(SimTrap::vsetvli(format!(
                "bad VLEN {vlen}: must be a power of two in 32..=65536"
            )));
        }
        Ok(RvvConfig { vlen, zvfh: true })
    }

    pub fn vlen_bytes(self) -> usize {
        self.vlen as usize / 8
    }
}

/// Machine state.
pub struct RvvMachine {
    pub cfg: RvvConfig,
    /// vector registers: raw little-endian bytes, VLEN/8 each
    vregs: Vec<Vec<u8>>,
    /// mask registers: one bool per element position (up to VLEN at e8/m8)
    masks: Vec<Vec<bool>>,
    /// scalar registers
    pub sregs: Vec<i64>,
    /// memory buffers (layout shared with the source IR program)
    pub bufs: Vec<Buffer>,
}

impl RvvMachine {
    pub fn new(cfg: RvvConfig, n_vregs: usize, n_mregs: usize, n_sregs: usize, bufs: Vec<Buffer>) -> RvvMachine {
        RvvMachine {
            cfg,
            // 2x VLEN storage per virtual register: widening ops (vwadd,
            // vwmul, vzext) write LMUL=2 results, i.e. a register *pair* —
            // modelled as one double-width virtual register (instruction
            // counts are unaffected)
            vregs: vec![vec![0; cfg.vlen_bytes() * 2]; n_vregs],
            masks: vec![vec![false; cfg.vlen as usize]; n_mregs],
            sregs: vec![0; n_sregs],
            bufs,
        }
    }

    // -- vector lane access ---------------------------------------------------

    pub fn read_lane(&self, reg: u32, sew: Sew, lane: u32) -> u64 {
        let w = sew.bytes() as usize;
        let off = lane as usize * w;
        let data = &self.vregs[reg as usize];
        debug_assert!(off + w <= data.len(), "lane {lane} at {sew:?} exceeds VLEN");
        let mut buf = [0u8; 8];
        buf[..w].copy_from_slice(&data[off..off + w]);
        u64::from_le_bytes(buf)
    }

    pub fn write_lane(&mut self, reg: u32, sew: Sew, lane: u32, raw: u64) {
        let w = sew.bytes() as usize;
        let off = lane as usize * w;
        let data = &mut self.vregs[reg as usize];
        debug_assert!(off + w <= data.len(), "lane {lane} at {sew:?} exceeds VLEN");
        data[off..off + w].copy_from_slice(&raw.to_le_bytes()[..w]);
    }

    /// Read `vl` lanes.
    pub fn read_lanes(&self, reg: u32, sew: Sew, vl: u32) -> Vec<u64> {
        (0..vl).map(|i| self.read_lane(reg, sew, i)).collect()
    }

    /// Batched lane read: copy `vl` lanes of `reg` at `sew` into `out`
    /// (cleared first) as zero-extended raw values. One pass over the
    /// contiguous register bytes instead of `vl` `read_lane` round-trips —
    /// the gather half of the lane-batched execution engine.
    pub fn read_lanes_into(&self, reg: u32, sew: Sew, vl: u32, out: &mut Vec<u64>) {
        let data = &self.vregs[reg as usize];
        let n = vl as usize;
        debug_assert!(n * sew.bytes() as usize <= data.len(), "vl {vl} at {sew:?} exceeds VLEN");
        out.clear();
        match sew {
            Sew::E8 => out.extend(data[..n].iter().map(|&b| b as u64)),
            Sew::E16 => out.extend(
                data.chunks_exact(2).take(n).map(|c| u16::from_le_bytes([c[0], c[1]]) as u64),
            ),
            Sew::E32 => out.extend(
                data.chunks_exact(4)
                    .take(n)
                    .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u64),
            ),
            Sew::E64 => out.extend(
                data.chunks_exact(8)
                    .take(n)
                    .map(|c| u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])),
            ),
        }
    }

    /// Batched lane write: scatter `vals` into the low lanes of `reg` at
    /// `sew` (lane `i` = `vals[i]`, truncated to the lane width). The
    /// scatter half of the lane-batched execution engine.
    pub fn write_lanes_from(&mut self, reg: u32, sew: Sew, vals: &[u64]) {
        let data = &mut self.vregs[reg as usize];
        debug_assert!(vals.len() * sew.bytes() as usize <= data.len());
        match sew {
            Sew::E8 => {
                for (c, &v) in data.iter_mut().zip(vals) {
                    *c = v as u8;
                }
            }
            Sew::E16 => {
                for (c, &v) in data.chunks_exact_mut(2).zip(vals) {
                    c.copy_from_slice(&(v as u16).to_le_bytes());
                }
            }
            Sew::E32 => {
                for (c, &v) in data.chunks_exact_mut(4).zip(vals) {
                    c.copy_from_slice(&(v as u32).to_le_bytes());
                }
            }
            Sew::E64 => {
                for (c, &v) in data.chunks_exact_mut(8).zip(vals) {
                    c.copy_from_slice(&v.to_le_bytes());
                }
            }
        }
    }

    /// The first `vl` bits of a mask register as a bool slice.
    pub fn mask_bits(&self, reg: u32, vl: u32) -> &[bool] {
        &self.masks[reg as usize][..vl as usize]
    }

    /// Raw bytes of a vreg (for reinterpret-style moves).
    pub fn reg_bytes(&self, reg: u32) -> &[u8] {
        &self.vregs[reg as usize]
    }

    pub fn reg_bytes_mut(&mut self, reg: u32) -> &mut Vec<u8> {
        &mut self.vregs[reg as usize]
    }

    /// Mutable access to two distinct registers (a < b) for bulk copies.
    pub fn regs_pair_mut(&mut self, a: usize, b: usize) -> (&mut [u8], &mut [u8]) {
        debug_assert!(a < b);
        let (lo, hi) = self.vregs.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    }

    // -- mask access -----------------------------------------------------------

    pub fn read_mask(&self, reg: u32, vl: u32) -> Vec<bool> {
        self.masks[reg as usize][..vl as usize].to_vec()
    }

    pub fn mask_bit(&self, reg: u32, lane: u32) -> bool {
        self.masks[reg as usize][lane as usize]
    }

    pub fn write_mask_bit(&mut self, reg: u32, lane: u32, v: bool) {
        self.masks[reg as usize][lane as usize] = v;
    }

    // -- memory -----------------------------------------------------------------

    /// Load `sew.bytes()` at a *byte* offset — RVV memory is untyped; the
    /// simulator converts the IR's element indices to byte addresses.
    /// Negative and past-the-end offsets trap as [`SimTrap`] out-of-bounds.
    pub fn load_at(&self, buf: u32, byte_off: i64, sew: Sew) -> Result<u64, SimTrap> {
        let w = sew.bytes() as usize;
        let b = self
            .bufs
            .get(buf as usize)
            .ok_or_else(|| SimTrap::oob(buf, byte_off, w, 0, false))?;
        if byte_off < 0 {
            return Err(SimTrap::oob(buf, byte_off, w, b.data.len(), false));
        }
        let off = byte_off as usize;
        if off + w > b.data.len() {
            return Err(SimTrap::oob(buf, byte_off, w, b.data.len(), false));
        }
        let mut raw = [0u8; 8];
        raw[..w].copy_from_slice(&b.data[off..off + w]);
        Ok(u64::from_le_bytes(raw))
    }

    /// Bulk load: copy `n` bytes from buffer memory into the low bytes of
    /// a register (unit-stride unmasked vle fast path — P2).
    pub fn load_bulk(&mut self, buf: u32, byte_off: i64, n: usize, reg: u32) -> Result<(), SimTrap> {
        let b = self
            .bufs
            .get(buf as usize)
            .ok_or_else(|| SimTrap::oob(buf, byte_off, n, 0, false))?;
        if byte_off < 0 {
            return Err(SimTrap::oob(buf, byte_off, n, b.data.len(), false));
        }
        let off = byte_off as usize;
        if off + n > b.data.len() {
            return Err(SimTrap::oob(buf, byte_off, n, b.data.len(), false));
        }
        self.vregs[reg as usize][..n].copy_from_slice(&b.data[off..off + n]);
        Ok(())
    }

    /// Bulk store: copy the low `n` bytes of a register into buffer memory
    /// (unit-stride unmasked vse fast path — P2).
    pub fn store_bulk(&mut self, buf: u32, byte_off: i64, n: usize, reg: u32) -> Result<(), SimTrap> {
        // split borrows: registers and buffers are separate fields
        let reg_data = &self.vregs[reg as usize][..n] as *const [u8];
        let b = self
            .bufs
            .get_mut(buf as usize)
            .ok_or_else(|| SimTrap::oob(buf, byte_off, n, 0, true))?;
        if byte_off < 0 {
            return Err(SimTrap::oob(buf, byte_off, n, b.data.len(), true));
        }
        let off = byte_off as usize;
        if off + n > b.data.len() {
            return Err(SimTrap::oob(buf, byte_off, n, b.data.len(), true));
        }
        // SAFETY: vregs and bufs are disjoint fields; no aliasing
        b.data[off..off + n].copy_from_slice(unsafe { &*reg_data });
        Ok(())
    }

    /// Store `sew.bytes()` at a *byte* offset.
    pub fn store_at(&mut self, buf: u32, byte_off: i64, sew: Sew, val: u64) -> Result<(), SimTrap> {
        let w = sew.bytes() as usize;
        let b = self
            .bufs
            .get_mut(buf as usize)
            .ok_or_else(|| SimTrap::oob(buf, byte_off, w, 0, true))?;
        if byte_off < 0 {
            return Err(SimTrap::oob(buf, byte_off, w, b.data.len(), true));
        }
        let off = byte_off as usize;
        if off + w > b.data.len() {
            return Err(SimTrap::oob(buf, byte_off, w, b.data.len(), true));
        }
        b.data[off..off + w].copy_from_slice(&val.to_le_bytes()[..w]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::neon::elem::Elem;

    #[test]
    fn lane_rw_by_sew() {
        let cfg = RvvConfig::new(128);
        let mut m = RvvMachine::new(cfg, 2, 1, 0, vec![]);
        m.write_lane(0, Sew::E32, 0, 0xdead_beef);
        m.write_lane(0, Sew::E32, 3, 7);
        assert_eq!(m.read_lane(0, Sew::E32, 0), 0xdead_beef);
        assert_eq!(m.read_lane(0, Sew::E32, 3), 7);
        // byte view overlaps
        assert_eq!(m.read_lane(0, Sew::E8, 0), 0xef);
        assert_eq!(m.read_lane(0, Sew::E8, 3), 0xde);
    }

    #[test]
    fn byte_addressed_memory() {
        // an i32 buffer accessed at e32 and e8
        let cfg = RvvConfig::new(128);
        let buf = Buffer::from_i32s(&[1, -1, 3, 4]);
        let mut m = RvvMachine::new(cfg, 1, 0, 0, vec![buf]);
        assert_eq!(m.load_at(0, 4, Sew::E32).unwrap(), 0xffff_ffff);
        assert_eq!(m.load_at(0, 4, Sew::E8).unwrap(), 0xff);
        m.store_at(0, 8, Sew::E32, 42).unwrap();
        assert_eq!(m.bufs[0].as_i32s(), vec![1, -1, 42, 4]);
        assert!(m.load_at(0, 16, Sew::E32).is_err());
        assert!(m.load_at(0, -1, Sew::E8).is_err());
    }

    #[test]
    fn batched_lane_access_matches_scalar() {
        let cfg = RvvConfig::new(128);
        let mut m = RvvMachine::new(cfg, 2, 0, 0, vec![]);
        for sew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
            let vl = 128 / sew.bits();
            let vals: Vec<u64> =
                (0..vl as u64).map(|i| (i.wrapping_mul(0x9e37_79b9_7f4a_7c15)) & sew_mask(sew)).collect();
            m.write_lanes_from(0, sew, &vals);
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(m.read_lane(0, sew, i as u32), v, "{sew:?} lane {i}");
            }
            let mut got = Vec::new();
            m.read_lanes_into(0, sew, vl, &mut got);
            assert_eq!(got, vals, "{sew:?} batched read");
        }
    }

    fn sew_mask(sew: Sew) -> u64 {
        match sew.bits() {
            64 => u64::MAX,
            b => (1u64 << b) - 1,
        }
    }

    #[test]
    fn vlen_scales_register_file() {
        // 2x VLEN storage for LMUL=2 widening results
        let m = RvvMachine::new(RvvConfig::new(512), 1, 0, 0, vec![]);
        assert_eq!(m.reg_bytes(0).len(), 128);
        let _ = Elem::F32; // silence unused import in some cfgs
    }
}
