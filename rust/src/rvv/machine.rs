//! The RVV virtual machine state: a VLEN-parameterised vector register
//! file, mask registers, scalar registers, and byte-addressed buffers.
//!
//! Register files are *virtual* (sized by the program, like post-regalloc
//! SSA): the simulator counts instructions, it does not model register
//! pressure — matching the paper's functional-simulation methodology.

use crate::neon::interp::Buffer;
use super::trap::SimTrap;
use super::vtype::{Lmul, Sew};

/// Machine configuration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RvvConfig {
    /// Vector register length in bits (the paper's `vlen`, compile-time
    /// fixed via `__riscv_v_fixed_vlen`).
    pub vlen: u32,
    /// Zvfh extension (f16 vectors) enabled — gates Table 2 f16 rows.
    pub zvfh: bool,
}

impl Default for RvvConfig {
    fn default() -> Self {
        RvvConfig { vlen: 128, zvfh: true }
    }
}

impl RvvConfig {
    pub fn new(vlen: u32) -> RvvConfig {
        match RvvConfig::try_new(vlen) {
            Ok(c) => c,
            Err(t) => panic!("{t}"),
        }
    }

    /// Fallible constructor: a bad VLEN is a [`SimTrap`] (vsetvli
    /// violation), not a panic — the coordinator uses this so malformed
    /// job parameters become `FaultRecord`s.
    pub fn try_new(vlen: u32) -> Result<RvvConfig, SimTrap> {
        if !(vlen.is_power_of_two() && (32..=65536).contains(&vlen)) {
            return Err(SimTrap::vsetvli(format!(
                "bad VLEN {vlen}: must be a power of two in 32..=65536"
            )));
        }
        Ok(RvvConfig { vlen, zvfh: true })
    }

    pub fn vlen_bytes(self) -> usize {
        self.vlen as usize / 8
    }
}

/// Machine state.
pub struct RvvMachine {
    pub cfg: RvvConfig,
    /// vector registers: raw little-endian bytes, VLEN/8 each
    vregs: Vec<Vec<u8>>,
    /// mask registers: one bool per element position (up to VLEN at e8/m8)
    masks: Vec<Vec<bool>>,
    /// scalar registers
    pub sregs: Vec<i64>,
    /// memory buffers (layout shared with the source IR program)
    pub bufs: Vec<Buffer>,
}

impl RvvMachine {
    pub fn new(cfg: RvvConfig, n_vregs: usize, n_mregs: usize, n_sregs: usize, bufs: Vec<Buffer>) -> RvvMachine {
        RvvMachine {
            cfg,
            // 2x VLEN storage per virtual register: widening ops (vwadd,
            // vwmul, vzext) write LMUL=2 results, i.e. a register *pair* —
            // modelled as one double-width virtual register (instruction
            // counts are unaffected)
            vregs: vec![vec![0; cfg.vlen_bytes() * 2]; n_vregs],
            masks: vec![vec![false; cfg.vlen as usize]; n_mregs],
            sregs: vec![0; n_sregs],
            bufs,
        }
    }

    // -- vector lane access ---------------------------------------------------
    //
    // Since PR 9 every lane accessor takes the instruction's LMUL. At `m1`
    // (and fractional LMUL) a lane lives inside a single architectural
    // register, with the 2x-VLEN widening area reachable exactly as before.
    // At `m2`/`m4`/`m8` the operand is a *register group*: `group()`
    // consecutive registers, `VLEN/SEW` lanes each, base register aligned
    // to the group size. Bad indices are structural `SimTrap::BadOperand`
    // faults (not panics): the recovery ladder turns them into
    // `FaultRecord`s.

    /// Validate a group operand: alignment and register-file bounds.
    /// Returns the group size in registers.
    fn check_group(&self, reg: u32, lmul: Lmul) -> Result<u32, SimTrap> {
        let group = lmul.group();
        if group > 1 && reg % group != 0 {
            return Err(SimTrap::bad_operand(format!(
                "misaligned register group: v{reg} is not {}-aligned for {}",
                group,
                lmul.asm()
            )));
        }
        if reg as usize + group as usize > self.vregs.len() {
            return Err(SimTrap::bad_operand(format!(
                "register group v{reg}..v{} exceeds register file of {}",
                reg + group - 1,
                self.vregs.len()
            )));
        }
        Ok(group)
    }

    /// Map (`reg`, `lane`) under `lmul` to (member register, byte offset).
    fn lane_loc(&self, reg: u32, sew: Sew, lmul: Lmul, lane: u32) -> Result<(usize, usize), SimTrap> {
        let group = self.check_group(reg, lmul)?;
        let w = sew.bytes() as usize;
        if group == 1 {
            // single register: lanes may extend into the 2x widening area
            let off = lane as usize * w;
            if off + w > self.vregs[reg as usize].len() {
                return Err(SimTrap::bad_operand(format!(
                    "lane {lane} at {} exceeds v{reg} storage",
                    sew.asm()
                )));
            }
            return Ok((reg as usize, off));
        }
        let per_reg = self.cfg.vlen / sew.bits();
        let member = lane / per_reg;
        if member >= group {
            return Err(SimTrap::bad_operand(format!(
                "lane {lane} at {} exceeds {} group v{reg}..v{}",
                sew.asm(),
                lmul.asm(),
                reg + group - 1
            )));
        }
        Ok(((reg + member) as usize, (lane % per_reg) as usize * w))
    }

    pub fn read_lane(&self, reg: u32, sew: Sew, lmul: Lmul, lane: u32) -> Result<u64, SimTrap> {
        let (member, off) = self.lane_loc(reg, sew, lmul, lane)?;
        let w = sew.bytes() as usize;
        let data = &self.vregs[member];
        let mut buf = [0u8; 8];
        buf[..w].copy_from_slice(&data[off..off + w]);
        Ok(u64::from_le_bytes(buf))
    }

    pub fn write_lane(
        &mut self,
        reg: u32,
        sew: Sew,
        lmul: Lmul,
        lane: u32,
        raw: u64,
    ) -> Result<(), SimTrap> {
        let (member, off) = self.lane_loc(reg, sew, lmul, lane)?;
        let w = sew.bytes() as usize;
        self.vregs[member][off..off + w].copy_from_slice(&raw.to_le_bytes()[..w]);
        Ok(())
    }

    /// Read `vl` lanes.
    pub fn read_lanes(&self, reg: u32, sew: Sew, lmul: Lmul, vl: u32) -> Result<Vec<u64>, SimTrap> {
        let mut out = Vec::with_capacity(vl as usize);
        self.read_lanes_into(reg, sew, lmul, vl, &mut out)?;
        Ok(out)
    }

    /// Batched lane read: copy `vl` lanes of the group at `reg` into `out`
    /// (cleared first) as zero-extended raw values. One pass per member
    /// register over contiguous bytes instead of `vl` `read_lane`
    /// round-trips — the gather half of the lane-batched execution engine.
    pub fn read_lanes_into(
        &self,
        reg: u32,
        sew: Sew,
        lmul: Lmul,
        vl: u32,
        out: &mut Vec<u64>,
    ) -> Result<(), SimTrap> {
        let group = self.check_group(reg, lmul)?;
        out.clear();
        let per_reg = if group == 1 {
            // whole single-register storage, widening area included
            (self.vregs[reg as usize].len() / sew.bytes() as usize) as u32
        } else {
            self.cfg.vlen / sew.bits()
        };
        if vl > per_reg * group {
            return Err(SimTrap::bad_operand(format!(
                "vl {vl} at {} exceeds {} group at v{reg}",
                sew.asm(),
                lmul.asm()
            )));
        }
        let mut remaining = vl;
        for member in 0..group {
            if remaining == 0 {
                break;
            }
            let n = remaining.min(per_reg) as usize;
            let data = &self.vregs[(reg + member) as usize];
            match sew {
                Sew::E8 => out.extend(data[..n].iter().map(|&b| b as u64)),
                Sew::E16 => out.extend(
                    data.chunks_exact(2).take(n).map(|c| u16::from_le_bytes([c[0], c[1]]) as u64),
                ),
                Sew::E32 => out.extend(
                    data.chunks_exact(4)
                        .take(n)
                        .map(|c| u32::from_le_bytes([c[0], c[1], c[2], c[3]]) as u64),
                ),
                Sew::E64 => out.extend(data.chunks_exact(8).take(n).map(|c| {
                    u64::from_le_bytes([c[0], c[1], c[2], c[3], c[4], c[5], c[6], c[7]])
                })),
            }
            remaining -= n as u32;
        }
        Ok(())
    }

    /// Batched lane write: scatter `vals` into the low lanes of the group
    /// at `reg` (lane `i` = `vals[i]`, truncated to the lane width). The
    /// scatter half of the lane-batched execution engine.
    pub fn write_lanes_from(
        &mut self,
        reg: u32,
        sew: Sew,
        lmul: Lmul,
        vals: &[u64],
    ) -> Result<(), SimTrap> {
        let group = self.check_group(reg, lmul)?;
        let per_reg = if group == 1 {
            (self.vregs[reg as usize].len() / sew.bytes() as usize) as u32
        } else {
            self.cfg.vlen / sew.bits()
        };
        if vals.len() > (per_reg * group) as usize {
            return Err(SimTrap::bad_operand(format!(
                "vl {} at {} exceeds {} group at v{reg}",
                vals.len(),
                sew.asm(),
                lmul.asm()
            )));
        }
        for (member, chunk) in vals.chunks(per_reg.max(1) as usize).enumerate() {
            let data = &mut self.vregs[reg as usize + member];
            match sew {
                Sew::E8 => {
                    for (c, &v) in data.iter_mut().zip(chunk) {
                        *c = v as u8;
                    }
                }
                Sew::E16 => {
                    for (c, &v) in data.chunks_exact_mut(2).zip(chunk) {
                        c.copy_from_slice(&(v as u16).to_le_bytes());
                    }
                }
                Sew::E32 => {
                    for (c, &v) in data.chunks_exact_mut(4).zip(chunk) {
                        c.copy_from_slice(&(v as u32).to_le_bytes());
                    }
                }
                Sew::E64 => {
                    for (c, &v) in data.chunks_exact_mut(8).zip(chunk) {
                        c.copy_from_slice(&v.to_le_bytes());
                    }
                }
            }
        }
        Ok(())
    }

    /// The first `vl` bits of a mask register as a bool slice.
    pub fn mask_bits(&self, reg: u32, vl: u32) -> &[bool] {
        &self.masks[reg as usize][..vl as usize]
    }

    /// Raw bytes of a vreg (for reinterpret-style moves).
    pub fn reg_bytes(&self, reg: u32) -> &[u8] {
        &self.vregs[reg as usize]
    }

    pub fn reg_bytes_mut(&mut self, reg: u32) -> &mut Vec<u8> {
        &mut self.vregs[reg as usize]
    }

    /// Mutable access to two distinct registers (a < b) for bulk copies.
    pub fn regs_pair_mut(&mut self, a: usize, b: usize) -> (&mut [u8], &mut [u8]) {
        debug_assert!(a < b);
        let (lo, hi) = self.vregs.split_at_mut(b);
        (&mut lo[a], &mut hi[0])
    }

    // -- mask access -----------------------------------------------------------

    pub fn read_mask(&self, reg: u32, vl: u32) -> Vec<bool> {
        self.masks[reg as usize][..vl as usize].to_vec()
    }

    pub fn mask_bit(&self, reg: u32, lane: u32) -> bool {
        self.masks[reg as usize][lane as usize]
    }

    pub fn write_mask_bit(&mut self, reg: u32, lane: u32, v: bool) {
        self.masks[reg as usize][lane as usize] = v;
    }

    // -- memory -----------------------------------------------------------------

    /// Load `sew.bytes()` at a *byte* offset — RVV memory is untyped; the
    /// simulator converts the IR's element indices to byte addresses.
    /// Negative and past-the-end offsets trap as [`SimTrap`] out-of-bounds.
    pub fn load_at(&self, buf: u32, byte_off: i64, sew: Sew) -> Result<u64, SimTrap> {
        let w = sew.bytes() as usize;
        let b = self
            .bufs
            .get(buf as usize)
            .ok_or_else(|| SimTrap::oob(buf, byte_off, w, 0, false))?;
        if byte_off < 0 {
            return Err(SimTrap::oob(buf, byte_off, w, b.data.len(), false));
        }
        let off = byte_off as usize;
        if off + w > b.data.len() {
            return Err(SimTrap::oob(buf, byte_off, w, b.data.len(), false));
        }
        let mut raw = [0u8; 8];
        raw[..w].copy_from_slice(&b.data[off..off + w]);
        Ok(u64::from_le_bytes(raw))
    }

    /// Bytes of register-group payload one member register holds for bulk
    /// transfers: the full (2x) storage at `m1`, exactly `VLEN/8` when
    /// grouped.
    fn bulk_stride(&self, reg: u32, group: u32) -> usize {
        if group == 1 {
            self.vregs[reg as usize].len()
        } else {
            self.cfg.vlen_bytes()
        }
    }

    /// Bulk load: copy `n` bytes from buffer memory into the low bytes of
    /// a register group (unit-stride unmasked vle fast path — P2). Grouped
    /// operands fill `VLEN/8` bytes per member register in order.
    pub fn load_bulk(
        &mut self,
        buf: u32,
        byte_off: i64,
        n: usize,
        reg: u32,
        lmul: Lmul,
    ) -> Result<(), SimTrap> {
        let group = self.check_group(reg, lmul)?;
        let stride = self.bulk_stride(reg, group);
        if n > stride * group as usize {
            return Err(SimTrap::bad_operand(format!(
                "bulk load of {n} bytes exceeds {} group at v{reg}",
                lmul.asm()
            )));
        }
        let b = self
            .bufs
            .get(buf as usize)
            .ok_or_else(|| SimTrap::oob(buf, byte_off, n, 0, false))?;
        if byte_off < 0 {
            return Err(SimTrap::oob(buf, byte_off, n, b.data.len(), false));
        }
        let off = byte_off as usize;
        if off + n > b.data.len() {
            return Err(SimTrap::oob(buf, byte_off, n, b.data.len(), false));
        }
        // split borrows: registers and buffers are separate fields
        let src = &b.data[off..off + n] as *const [u8];
        // SAFETY: vregs and bufs are disjoint fields; no aliasing
        let src = unsafe { &*src };
        for (member, chunk) in src.chunks(stride).enumerate() {
            self.vregs[reg as usize + member][..chunk.len()].copy_from_slice(chunk);
        }
        Ok(())
    }

    /// Bulk store: copy the low `n` bytes of a register group into buffer
    /// memory (unit-stride unmasked vse fast path — P2).
    pub fn store_bulk(
        &mut self,
        buf: u32,
        byte_off: i64,
        n: usize,
        reg: u32,
        lmul: Lmul,
    ) -> Result<(), SimTrap> {
        let group = self.check_group(reg, lmul)?;
        let stride = self.bulk_stride(reg, group);
        if n > stride * group as usize {
            return Err(SimTrap::bad_operand(format!(
                "bulk store of {n} bytes exceeds {} group at v{reg}",
                lmul.asm()
            )));
        }
        let vregs = &self.vregs as *const Vec<Vec<u8>>;
        let b = self
            .bufs
            .get_mut(buf as usize)
            .ok_or_else(|| SimTrap::oob(buf, byte_off, n, 0, true))?;
        if byte_off < 0 {
            return Err(SimTrap::oob(buf, byte_off, n, b.data.len(), true));
        }
        let off = byte_off as usize;
        if off + n > b.data.len() {
            return Err(SimTrap::oob(buf, byte_off, n, b.data.len(), true));
        }
        // SAFETY: vregs and bufs are disjoint fields; no aliasing
        let vregs = unsafe { &*vregs };
        for (member, chunk) in b.data[off..off + n].chunks_mut(stride).enumerate() {
            let len = chunk.len();
            chunk.copy_from_slice(&vregs[reg as usize + member][..len]);
        }
        Ok(())
    }

    /// Store `sew.bytes()` at a *byte* offset.
    pub fn store_at(&mut self, buf: u32, byte_off: i64, sew: Sew, val: u64) -> Result<(), SimTrap> {
        let w = sew.bytes() as usize;
        let b = self
            .bufs
            .get_mut(buf as usize)
            .ok_or_else(|| SimTrap::oob(buf, byte_off, w, 0, true))?;
        if byte_off < 0 {
            return Err(SimTrap::oob(buf, byte_off, w, b.data.len(), true));
        }
        let off = byte_off as usize;
        if off + w > b.data.len() {
            return Err(SimTrap::oob(buf, byte_off, w, b.data.len(), true));
        }
        b.data[off..off + w].copy_from_slice(&val.to_le_bytes()[..w]);
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;
    use crate::neon::elem::Elem;

    use crate::rvv::trap::TrapKind;

    #[test]
    fn lane_rw_by_sew() {
        let cfg = RvvConfig::new(128);
        let mut m = RvvMachine::new(cfg, 2, 1, 0, vec![]);
        m.write_lane(0, Sew::E32, Lmul::M1, 0, 0xdead_beef).unwrap();
        m.write_lane(0, Sew::E32, Lmul::M1, 3, 7).unwrap();
        assert_eq!(m.read_lane(0, Sew::E32, Lmul::M1, 0).unwrap(), 0xdead_beef);
        assert_eq!(m.read_lane(0, Sew::E32, Lmul::M1, 3).unwrap(), 7);
        // byte view overlaps
        assert_eq!(m.read_lane(0, Sew::E8, Lmul::M1, 0).unwrap(), 0xef);
        assert_eq!(m.read_lane(0, Sew::E8, Lmul::M1, 3).unwrap(), 0xde);
    }

    #[test]
    fn bad_lane_indices_trap_instead_of_panicking() {
        let cfg = RvvConfig::new(128);
        let mut m = RvvMachine::new(cfg, 2, 0, 0, vec![]);
        // past the 2x widening storage of a single register
        let t = m.read_lane(0, Sew::E64, Lmul::M1, 4).unwrap_err();
        assert!(matches!(t.kind, TrapKind::BadOperand(_)), "{t}");
        let t = m.write_lane(1, Sew::E32, Lmul::M1, 8, 0).unwrap_err();
        assert!(matches!(t.kind, TrapKind::BadOperand(_)), "{t}");
    }

    #[test]
    fn grouped_lanes_span_consecutive_registers() {
        // VLEN=128, e32, m2: 4 lanes per member register, 8 total
        let cfg = RvvConfig::new(128);
        let mut m = RvvMachine::new(cfg, 8, 0, 0, vec![]);
        for lane in 0..8 {
            m.write_lane(2, Sew::E32, Lmul::M2, lane, 100 + lane as u64).unwrap();
        }
        // lanes 4..8 landed in the second member register, readable at m1
        for lane in 0..4 {
            assert_eq!(m.read_lane(2, Sew::E32, Lmul::M1, lane).unwrap(), 100 + lane as u64);
            assert_eq!(m.read_lane(3, Sew::E32, Lmul::M1, lane).unwrap(), 104 + lane as u64);
        }
        // batched read sees the same 8 lanes
        let got = m.read_lanes(2, Sew::E32, Lmul::M2, 8).unwrap();
        assert_eq!(got, (100..108).collect::<Vec<u64>>());
        // batched write round-trips across the group at m4
        let vals: Vec<u64> = (0..16).map(|i| 0x5000 + i).collect();
        m.write_lanes_from(4, Sew::E32, Lmul::M4, &vals).unwrap();
        let mut got = Vec::new();
        m.read_lanes_into(4, Sew::E32, Lmul::M4, 16, &mut got).unwrap();
        assert_eq!(got, vals);
        for (i, r) in (4..8).enumerate() {
            assert_eq!(
                m.read_lanes(r, Sew::E32, Lmul::M1, 4).unwrap(),
                (0..4).map(|l| 0x5000 + (i * 4 + l) as u64).collect::<Vec<u64>>()
            );
        }
    }

    #[test]
    fn misaligned_or_oversized_groups_trap() {
        let cfg = RvvConfig::new(128);
        let mut m = RvvMachine::new(cfg, 4, 0, 0, vec![]);
        // v1 is not 2-aligned
        let t = m.read_lane(1, Sew::E32, Lmul::M2, 0).unwrap_err();
        assert!(matches!(t.kind, TrapKind::BadOperand(_)), "{t}");
        assert!(t.to_string().contains("misaligned"), "{t}");
        // v3 is not 4-aligned either
        assert!(m.write_lane(3, Sew::E32, Lmul::M4, 0, 1).is_err());
        // lane beyond the group capacity
        let t = m.write_lane(0, Sew::E32, Lmul::M2, 8, 1).unwrap_err();
        assert!(matches!(t.kind, TrapKind::BadOperand(_)), "{t}");
        // group running off the end of the register file
        let t = m.read_lanes(0, Sew::E32, Lmul::M8, 1).unwrap_err();
        assert!(matches!(t.kind, TrapKind::BadOperand(_)), "{t}");
    }

    #[test]
    fn byte_addressed_memory() {
        // an i32 buffer accessed at e32 and e8
        let cfg = RvvConfig::new(128);
        let buf = Buffer::from_i32s(&[1, -1, 3, 4]);
        let mut m = RvvMachine::new(cfg, 1, 0, 0, vec![buf]);
        assert_eq!(m.load_at(0, 4, Sew::E32).unwrap(), 0xffff_ffff);
        assert_eq!(m.load_at(0, 4, Sew::E8).unwrap(), 0xff);
        m.store_at(0, 8, Sew::E32, 42).unwrap();
        assert_eq!(m.bufs[0].as_i32s(), vec![1, -1, 42, 4]);
        assert!(m.load_at(0, 16, Sew::E32).is_err());
        assert!(m.load_at(0, -1, Sew::E8).is_err());
    }

    #[test]
    fn batched_lane_access_matches_scalar() {
        let cfg = RvvConfig::new(128);
        let mut m = RvvMachine::new(cfg, 2, 0, 0, vec![]);
        for sew in [Sew::E8, Sew::E16, Sew::E32, Sew::E64] {
            let vl = 128 / sew.bits();
            let vals: Vec<u64> =
                (0..vl as u64).map(|i| (i.wrapping_mul(0x9e37_79b9_7f4a_7c15)) & sew_mask(sew)).collect();
            m.write_lanes_from(0, sew, Lmul::M1, &vals).unwrap();
            for (i, &v) in vals.iter().enumerate() {
                assert_eq!(m.read_lane(0, sew, Lmul::M1, i as u32).unwrap(), v, "{sew:?} lane {i}");
            }
            let mut got = Vec::new();
            m.read_lanes_into(0, sew, Lmul::M1, vl, &mut got).unwrap();
            assert_eq!(got, vals, "{sew:?} batched read");
        }
    }

    fn sew_mask(sew: Sew) -> u64 {
        match sew.bits() {
            64 => u64::MAX,
            b => (1u64 << b) - 1,
        }
    }

    #[test]
    fn vlen_scales_register_file() {
        // 2x VLEN storage for LMUL=2 widening results
        let m = RvvMachine::new(RvvConfig::new(512), 1, 0, 0, vec![]);
        assert_eq!(m.reg_bytes(0).len(), 128);
        let _ = Elem::F32; // silence unused import in some cfgs
    }
}
