//! Structured simulation traps.
//!
//! A [`SimTrap`] is the machine-readable failure record of the execution
//! layer: instead of `panic!`/`unreachable!` aborting the worker thread,
//! every fault the simulator can detect — illegal instructions,
//! out-of-bounds or negative memory accesses, operand-kind mismatches,
//! unsupported opcodes, vector-configuration violations — propagates as a
//! `Result<_, SimTrap>` up through [`crate::rvv::exec`] and the two
//! `sim` engines, which enrich it with kernel name, engine kind, PC/op
//! index and the offending instruction's debug render before handing it
//! to the coordinator.
//!
//! `SimTrap` implements [`std::error::Error`], so it threads through
//! `anyhow` with `?` and can be recovered at the job boundary with
//! `err.downcast_ref::<SimTrap>()` — this is how the coordinator turns a
//! trapped job into a structured `FaultRecord` instead of a dead worker.

use std::fmt;

/// What went wrong, with the fault-specific payload.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum TrapKind {
    /// Instruction not executable as encoded (e.g. a float op at e8, a
    /// widening op with no wider SEW).
    IllegalInstruction(String),
    /// Memory access outside a buffer: negative or past-the-end.
    OutOfBounds {
        buf: u32,
        byte_off: i64,
        /// Access width in bytes.
        width: usize,
        /// Buffer length in bytes.
        len: usize,
        store: bool,
    },
    /// Operand list or operand kind does not match what the opcode
    /// requires (e.g. a store without a vreg source).
    BadOperand(String),
    /// Opcode with no execution semantics on the taken path.
    UnsupportedOp(String),
    /// Invalid vector configuration (bad VLEN, vsetvli contract breach).
    VsetvliViolation(String),
    /// A panic caught at the job boundary — the `catch_unwind` backstop
    /// in the coordinator, not a trap the simulator raised itself.
    Panic(String),
    /// Deterministic test-only fault injected by the coordinator's
    /// `FaultPlan`.
    Injected(String),
    /// The dynamic-instruction budget of `sim::ExecLimits` ran out — a
    /// runaway (or grossly mis-estimated) program was stopped instead of
    /// hanging its worker thread.
    FuelExhausted(String),
    /// The wall-clock deadline of `sim::ExecLimits` passed.
    DeadlineExceeded(String),
}

impl TrapKind {
    /// Short stable label for logs and reports.
    pub fn label(&self) -> &'static str {
        match self {
            TrapKind::IllegalInstruction(_) => "illegal-instruction",
            TrapKind::OutOfBounds { store: true, .. } => "out-of-bounds-store",
            TrapKind::OutOfBounds { store: false, .. } => "out-of-bounds-load",
            TrapKind::BadOperand(_) => "bad-operand",
            TrapKind::UnsupportedOp(_) => "unsupported-op",
            TrapKind::VsetvliViolation(_) => "vsetvli-violation",
            TrapKind::Panic(_) => "panic",
            TrapKind::Injected(_) => "injected",
            TrapKind::FuelExhausted(_) => "fuel-exhausted",
            TrapKind::DeadlineExceeded(_) => "deadline-exceeded",
        }
    }

    /// Whether re-running the identical deterministic simulation is
    /// guaranteed to hit this fault again. The retry ladder skips repeat
    /// attempts on the same engine for deterministic kinds and goes
    /// straight to the cross-engine fallback; transient kinds (injected
    /// test faults, panics that may stem from shared state, wall-clock
    /// deadlines that depend on machine load) keep full retry semantics.
    pub fn is_deterministic(&self) -> bool {
        matches!(
            self,
            TrapKind::IllegalInstruction(_)
                | TrapKind::OutOfBounds { .. }
                | TrapKind::BadOperand(_)
                | TrapKind::UnsupportedOp(_)
                | TrapKind::VsetvliViolation(_)
                | TrapKind::FuelExhausted(_)
        )
    }
}

impl fmt::Display for TrapKind {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            TrapKind::IllegalInstruction(d)
            | TrapKind::BadOperand(d)
            | TrapKind::UnsupportedOp(d)
            | TrapKind::VsetvliViolation(d)
            | TrapKind::Panic(d)
            | TrapKind::Injected(d)
            | TrapKind::FuelExhausted(d)
            | TrapKind::DeadlineExceeded(d) => write!(f, "[{}] {d}", self.label()),
            TrapKind::OutOfBounds { buf, byte_off, width, len, store: _ } => write!(
                f,
                "[{}] {width} bytes at byte {byte_off} of buf{buf} ({len} bytes)",
                self.label(),
            ),
        }
    }
}

/// A structured simulation trap: the fault kind plus the execution context
/// the engines attach on the way out (innermost context wins — once a
/// field is set, outer layers leave it alone).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct SimTrap {
    pub kind: TrapKind,
    /// Kernel (program) name, attached by the engines.
    pub kernel: Option<String>,
    /// `"interp"` or `"decoded"`, attached by the engines.
    pub engine: Option<&'static str>,
    /// For the decoded engine: the static index into the decoded op
    /// stream. For the interpreter: the dynamic index of the executed
    /// statement (vector ops and scalar blocks).
    pub pc: Option<usize>,
    /// Debug render (`RvvInst::asm`) of the offending instruction.
    pub inst: Option<String>,
}

impl SimTrap {
    pub fn new(kind: TrapKind) -> SimTrap {
        SimTrap { kind, kernel: None, engine: None, pc: None, inst: None }
    }

    pub fn illegal(detail: impl Into<String>) -> SimTrap {
        SimTrap::new(TrapKind::IllegalInstruction(detail.into()))
    }

    pub fn bad_operand(detail: impl Into<String>) -> SimTrap {
        SimTrap::new(TrapKind::BadOperand(detail.into()))
    }

    pub fn unsupported(detail: impl Into<String>) -> SimTrap {
        SimTrap::new(TrapKind::UnsupportedOp(detail.into()))
    }

    pub fn vsetvli(detail: impl Into<String>) -> SimTrap {
        SimTrap::new(TrapKind::VsetvliViolation(detail.into()))
    }

    pub fn oob(buf: u32, byte_off: i64, width: usize, len: usize, store: bool) -> SimTrap {
        SimTrap::new(TrapKind::OutOfBounds { buf, byte_off, width, len, store })
    }

    pub fn panicked(message: impl Into<String>) -> SimTrap {
        SimTrap::new(TrapKind::Panic(message.into()))
    }

    pub fn injected(detail: impl Into<String>) -> SimTrap {
        SimTrap::new(TrapKind::Injected(detail.into()))
    }

    pub fn fuel_exhausted(detail: impl Into<String>) -> SimTrap {
        SimTrap::new(TrapKind::FuelExhausted(detail.into()))
    }

    pub fn deadline_exceeded(detail: impl Into<String>) -> SimTrap {
        SimTrap::new(TrapKind::DeadlineExceeded(detail.into()))
    }

    /// Attach the kernel name if not already set.
    pub fn in_kernel(mut self, kernel: &str) -> SimTrap {
        if self.kernel.is_none() {
            self.kernel = Some(kernel.to_string());
        }
        self
    }

    /// Attach the engine kind if not already set.
    pub fn on_engine(mut self, engine: &'static str) -> SimTrap {
        if self.engine.is_none() {
            self.engine = Some(engine);
        }
        self
    }

    /// Attach the PC / op index if not already set.
    pub fn at_pc(mut self, pc: usize) -> SimTrap {
        if self.pc.is_none() {
            self.pc = Some(pc);
        }
        self
    }

    /// Attach the offending instruction's debug render if not already set.
    pub fn with_inst(mut self, inst: impl Into<String>) -> SimTrap {
        if self.inst.is_none() {
            self.inst = Some(inst.into());
        }
        self
    }
}

impl fmt::Display for SimTrap {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "sim trap {}", self.kind)?;
        if let Some(k) = &self.kernel {
            write!(f, " kernel={k}")?;
        }
        if let Some(e) = self.engine {
            write!(f, " engine={e}")?;
        }
        if let Some(pc) = self.pc {
            write!(f, " pc={pc}")?;
        }
        if let Some(i) = &self.inst {
            write!(f, " inst=`{i}`")?;
        }
        Ok(())
    }
}

impl std::error::Error for SimTrap {}

#[cfg(test)]
mod tests {
    #![allow(clippy::unwrap_used, clippy::expect_used)]

    use super::*;

    #[test]
    fn context_is_innermost_wins() {
        let t = SimTrap::oob(1, -4, 8, 16, true)
            .at_pc(3)
            .with_inst("vse32.v v0, (buf1+0)")
            .in_kernel("end_store")
            .on_engine("interp")
            // outer enrichment must not overwrite
            .at_pc(99)
            .in_kernel("other");
        assert_eq!(t.pc, Some(3));
        assert_eq!(t.kernel.as_deref(), Some("end_store"));
        assert_eq!(t.kind.label(), "out-of-bounds-store");
        let s = t.to_string();
        assert!(s.contains("buf1"), "{s}");
        assert!(s.contains("pc=3"), "{s}");
        assert!(s.contains("vse32"), "{s}");
    }

    #[test]
    fn threads_through_anyhow_and_downcasts_back() {
        fn fails() -> anyhow::Result<()> {
            Err(SimTrap::illegal("no e8 float").in_kernel("k"))?;
            Ok(())
        }
        let err = fails().unwrap_err();
        let t = err.downcast_ref::<SimTrap>().expect("downcast");
        assert_eq!(t.kind, TrapKind::IllegalInstruction("no e8 float".into()));
    }
}
