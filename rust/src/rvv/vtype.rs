//! `vtype` state: selected element width (SEW), register grouping (LMUL),
//! and the `vsetvli` configuration model.
//!
//! The paper's type-conversion strategy (§3.2) targets LMUL=1 fixed-size
//! types (LLVM D145088), so the *translator* always emits `m1`. Since PR 9
//! LMUL is a live dimension everywhere above the translator: every
//! `RvvInst` carries an [`Lmul`], `vlmax = VLEN/SEW · LMUL` legality is
//! enforced at execution time (`SimTrap::VsetvliViolation`), `RvvMachine`
//! maps `m2`/`m4` operands onto aligned groups of 2/4 consecutive
//! architectural registers (`SimTrap::BadOperand` on misalignment), and
//! the autotuner's `lmul:F` candidate family re-emits legal loops at
//! grouped vtypes with the trip count divided accordingly.

use crate::neon::elem::Elem;

/// Selected element width.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub enum Sew {
    E8,
    E16,
    E32,
    E64,
}

impl Sew {
    pub fn bits(self) -> u32 {
        match self {
            Sew::E8 => 8,
            Sew::E16 => 16,
            Sew::E32 => 32,
            Sew::E64 => 64,
        }
    }

    pub fn bytes(self) -> u32 {
        self.bits() / 8
    }

    /// Fallible lookup; `None` when no SEW has that width.
    pub fn try_of_bits(bits: u32) -> Option<Sew> {
        match bits {
            8 => Some(Sew::E8),
            16 => Some(Sew::E16),
            32 => Some(Sew::E32),
            64 => Some(Sew::E64),
            _ => None,
        }
    }

    pub fn of_bits(bits: u32) -> Sew {
        match Sew::try_of_bits(bits) {
            Some(s) => s,
            None => panic!("no SEW of {bits} bits"),
        }
    }

    pub fn of_elem(e: Elem) -> Sew {
        Sew::of_bits(e.bits())
    }

    /// Assembly rendering, e.g. `e32`.
    pub fn asm(self) -> &'static str {
        match self {
            Sew::E8 => "e8",
            Sew::E16 => "e16",
            Sew::E32 => "e32",
            Sew::E64 => "e64",
        }
    }
}

/// Register grouping multiplier.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Lmul {
    MF2,
    M1,
    M2,
    M4,
    M8,
}

impl Lmul {
    /// Numerator/denominator representation.
    pub fn ratio(self) -> (u32, u32) {
        match self {
            Lmul::MF2 => (1, 2),
            Lmul::M1 => (1, 1),
            Lmul::M2 => (2, 1),
            Lmul::M4 => (4, 1),
            Lmul::M8 => (8, 1),
        }
    }

    pub fn asm(self) -> &'static str {
        match self {
            Lmul::MF2 => "mf2",
            Lmul::M1 => "m1",
            Lmul::M2 => "m2",
            Lmul::M4 => "m4",
            Lmul::M8 => "m8",
        }
    }

    /// Number of consecutive architectural registers one operand occupies.
    /// Fractional LMUL still occupies (part of) a single register.
    pub fn group(self) -> u32 {
        match self {
            Lmul::MF2 | Lmul::M1 => 1,
            Lmul::M2 => 2,
            Lmul::M4 => 4,
            Lmul::M8 => 8,
        }
    }

    /// Dense index for per-LMUL statistics tables (see `sim::stats`).
    pub fn index(self) -> usize {
        match self {
            Lmul::MF2 => 0,
            Lmul::M1 => 1,
            Lmul::M2 => 2,
            Lmul::M4 => 3,
            Lmul::M8 => 4,
        }
    }

    /// Number of distinct LMUL settings ([`Lmul::index`] range).
    pub const COUNT: usize = 5;

    /// Grouped LMUL for an integer factor (the tuner's `lmul:F` family).
    pub fn try_of_factor(f: u32) -> Option<Lmul> {
        match f {
            1 => Some(Lmul::M1),
            2 => Some(Lmul::M2),
            4 => Some(Lmul::M4),
            8 => Some(Lmul::M8),
            _ => None,
        }
    }
}

/// A `vtype` configuration (tail/mask agnosticism fixed at ta,ma like
/// compiler-generated code; the machine executes tail-undisturbed which is
/// a legal ta implementation).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct VType {
    pub sew: Sew,
    pub lmul: Lmul,
}

impl VType {
    pub fn m1(sew: Sew) -> VType {
        VType { sew, lmul: Lmul::M1 }
    }

    /// VLMAX for this vtype at a given VLEN (bits).
    pub fn vlmax(self, vlen: u32) -> u32 {
        let (n, d) = self.lmul.ratio();
        vlen / self.sew.bits() * n / d
    }

    /// `vsetvli` asm rendering: `vsetvli zero, a0, e32, m1, ta, ma`.
    pub fn asm(self) -> String {
        format!("{}, {}, ta, ma", self.sew.asm(), self.lmul.asm())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn vlmax_by_vlen() {
        assert_eq!(VType::m1(Sew::E32).vlmax(128), 4);
        assert_eq!(VType::m1(Sew::E32).vlmax(256), 8);
        assert_eq!(VType::m1(Sew::E8).vlmax(128), 16);
        assert_eq!(VType::m1(Sew::E64).vlmax(64), 1);
        assert_eq!(VType { sew: Sew::E32, lmul: Lmul::M2 }.vlmax(128), 8);
        assert_eq!(VType { sew: Sew::E16, lmul: Lmul::MF2 }.vlmax(128), 4);
    }

    #[test]
    fn sew_of_elem() {
        assert_eq!(Sew::of_elem(Elem::F32), Sew::E32);
        assert_eq!(Sew::of_elem(Elem::U8), Sew::E8);
        assert_eq!(Sew::of_elem(Elem::P64), Sew::E64);
    }

    #[test]
    fn asm_rendering() {
        assert_eq!(VType::m1(Sew::E32).asm(), "e32, m1, ta, ma");
    }

    #[test]
    fn group_sizes_and_factors() {
        assert_eq!(Lmul::MF2.group(), 1);
        assert_eq!(Lmul::M1.group(), 1);
        assert_eq!(Lmul::M2.group(), 2);
        assert_eq!(Lmul::M4.group(), 4);
        assert_eq!(Lmul::M8.group(), 8);
        assert_eq!(Lmul::try_of_factor(2), Some(Lmul::M2));
        assert_eq!(Lmul::try_of_factor(4), Some(Lmul::M4));
        assert_eq!(Lmul::try_of_factor(3), None);
        for (i, l) in [Lmul::MF2, Lmul::M1, Lmul::M2, Lmul::M4, Lmul::M8]
            .into_iter()
            .enumerate()
        {
            assert_eq!(l.index(), i);
        }
    }
}
