//! Minimal CLI argument parser (no clap offline): subcommand + `--key
//! value` flags + `--flag` booleans.

use std::collections::BTreeMap;

use anyhow::{bail, Result};

#[derive(Debug, Default)]
pub struct Args {
    pub subcommand: Option<String>,
    pub positional: Vec<String>,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    pub fn parse(argv: impl IntoIterator<Item = String>) -> Result<Args> {
        let mut args = Args::default();
        let mut it = argv.into_iter().peekable();
        while let Some(a) = it.next() {
            if let Some(key) = a.strip_prefix("--") {
                match it.peek() {
                    Some(v) if !v.starts_with("--") => {
                        let v = it.next().unwrap();
                        args.flags.insert(key.to_string(), v);
                    }
                    _ => args.bools.push(key.to_string()),
                }
            } else if args.subcommand.is_none() {
                args.subcommand = Some(a);
            } else {
                args.positional.push(a);
            }
        }
        Ok(args)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_u32(&self, key: &str, default: u32) -> Result<u32> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => match v.parse() {
                Ok(n) => Ok(n),
                Err(_) => bail!("--{key} must be an integer, got '{v}'"),
            },
        }
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        Ok(self.get_u32(key, default as u32)? as usize)
    }

    pub fn has(&self, key: &str) -> bool {
        self.bools.iter().any(|b| b == key)
    }

    /// Comma-separated string list flag; `None` when the flag is absent.
    pub fn get_str_list(&self, key: &str) -> Option<Vec<&str>> {
        self.get(key)
            .map(|v| v.split(',').map(str::trim).filter(|s| !s.is_empty()).collect())
    }

    /// Comma-separated u32 list flag.
    pub fn get_u32_list(&self, key: &str, default: &[u32]) -> Result<Vec<u32>> {
        match self.get(key) {
            None => Ok(default.to_vec()),
            Some(v) => v
                .split(',')
                .map(|x| x.trim().parse::<u32>().map_err(|_| anyhow::anyhow!("bad --{key} entry '{x}'")))
                .collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from)).unwrap()
    }

    #[test]
    fn subcommand_flags_and_bools() {
        // boolean flags bind greedily: put positionals before them
        let a = parse("bench fig2 --vlen 256 --verbose");
        assert_eq!(a.subcommand.as_deref(), Some("bench"));
        assert_eq!(a.get("vlen"), Some("256"));
        assert!(a.has("verbose"));
        assert_eq!(a.positional, vec!["fig2"]);
    }

    #[test]
    fn str_list() {
        let a = parse("tune --kernel vrelu,gemm, vsqrt");
        assert_eq!(a.get_str_list("kernel"), Some(vec!["vrelu", "gemm"]));
        assert_eq!(parse("tune").get_str_list("kernel"), None);
    }

    #[test]
    fn u32_list() {
        let a = parse("sweep --vlens 128,256,512");
        assert_eq!(a.get_u32_list("vlens", &[128]).unwrap(), vec![128, 256, 512]);
        let a = parse("sweep");
        assert_eq!(a.get_u32_list("vlens", &[128]).unwrap(), vec![128]);
    }

    #[test]
    fn default_values() {
        let a = parse("bench");
        assert_eq!(a.get_u32("vlen", 128).unwrap(), 128);
        assert!(parse("bench --vlen abc").get_u32("vlen", 128).is_err());
    }
}
