//! `simde-rvv` — leader binary for the NEON->RVV migration pipeline.
//!
//! Subcommands:
//!   report table1|table2|methods      regenerate the paper's tables
//!   bench [--vlen N] [--threads N] [--tuned] [--db TUNED.json]
//!                                     Figure 2 speedup table (optionally
//!                                     replaying tuned lowerings)
//!   verify [--kernel K] [--artifacts DIR] [--no-golden]
//!                                     validate both modes vs NEON + XLA
//!   verify --static [--vlens 128,256,512]
//!                                     run the admission verifier over
//!                                     every lowering (static rules plus
//!                                     all tuner candidate families) for
//!                                     suite x mode x vlen, no execution
//!   translate --kernel K [--mode baseline|custom]
//!                                     dump the translated RVV stream
//!   tune [--vlens 128,...] [--kernel K] [--mode M] [--budget N]
//!        [--out TUNED.json] [--smoke] search candidate lowerings, persist
//!                                     winners to the tuning database
//!   sweep [--vlens 128,256,512]       VLA scaling ablation (A1)
//!   catalog [--grep PAT]              dump the NEON intrinsic catalog

use std::path::Path;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use simde_rvv::cli::Args;
use simde_rvv::config::{Config, Settings};
use simde_rvv::coordinator::{self, verify_kernel};
use simde_rvv::kernels;
use simde_rvv::neon::catalog;
use simde_rvv::report;
use simde_rvv::runtime::GoldenOracle;
use simde_rvv::rvv::machine::RvvConfig;
use simde_rvv::simde::{Mode, Translator};
use simde_rvv::tuner::{self, db::TuningDb, TunerOptions};

fn main() {
    if let Err(e) = run() {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn settings(args: &Args) -> Result<Settings> {
    let mut s = match args.get("config") {
        Some(path) => Settings::from_config(&Config::load(Path::new(path))?)?,
        None => Settings::default(),
    };
    s.vlen = args.get_u32("vlen", s.vlen)?;
    s.threads = args.get_usize("threads", s.threads)?;
    if let Some(dir) = args.get("artifacts") {
        s.artifacts = dir.to_string();
    }
    Ok(s)
}

fn run() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1))?;
    match args.subcommand.as_deref() {
        Some("report") => report_cmd(&args),
        Some("bench") => bench_cmd(&args),
        Some("verify") => verify_cmd(&args),
        Some("translate") => translate_cmd(&args),
        Some("tune") => tune_cmd(&args),
        Some("sweep") => sweep_cmd(&args),
        Some("catalog") => catalog_cmd(&args),
        Some(other) => bail!("unknown subcommand '{other}' (try: report/bench/verify/translate/tune/sweep/catalog)"),
        None => {
            println!("simde-rvv {} — SIMD Everywhere NEON->RVV migration pipeline", simde_rvv::version());
            println!("subcommands: report bench verify translate tune sweep catalog");
            Ok(())
        }
    }
}

fn report_cmd(args: &Args) -> Result<()> {
    let s = settings(args)?;
    match args.positional.first().map(|s| s.as_str()) {
        Some("table1") => print!("{}", report::table1_markdown()),
        Some("table2") => {
            print!("{}", report::table2_markdown(true));
            println!();
            print!("{}", report::table2_markdown(false));
        }
        Some("methods") => print!("{}", report::methods_markdown(s.rvv())),
        _ => bail!("usage: report table1|table2|methods"),
    }
    Ok(())
}

fn bench_cmd(args: &Args) -> Result<()> {
    let s = settings(args)?;
    // fault-tolerant path: one bad kernel degrades to an annotated row
    // gap instead of losing the whole table
    let mut opts = coordinator::MatrixOptions::new(s.threads);
    if args.has("tuned") {
        let path = args.get("db").unwrap_or("TUNED.json");
        let db = TuningDb::load(Path::new(path))?;
        opts = opts.tuning(Arc::new(db));
    }
    let fig = coordinator::figure2_report_opts(s.vlen, opts);
    if args.has("csv") {
        print!("{}", report::fig2_csv(&fig.rows));
    } else {
        print!("{}", report::fig2_markdown_report(&fig));
    }
    for f in &fig.faults {
        eprintln!("warning: {f}");
    }
    if !fig.failed.is_empty() {
        bail!("{} kernel(s) produced no row: {}", fig.failed.len(), fig.failed.join(", "));
    }
    Ok(())
}

fn verify_cmd(args: &Args) -> Result<()> {
    if args.has("static") {
        return verify_static_cmd(args);
    }
    let s = settings(args)?;
    let oracle = if args.has("no-golden") {
        None
    } else {
        Some(GoldenOracle::load(Path::new(&s.artifacts)).context(
            "loading golden artifacts (use --no-golden to skip, or run `make artifacts`)",
        )?)
    };
    if let Some(o) = &oracle {
        println!("golden oracle: {} ops on {}", o.ops().len(), o.platform());
    }
    let cases: Vec<_> = match args.get("kernel") {
        Some(k) => vec![kernels::by_name(k).with_context(|| format!("unknown kernel '{k}'"))?],
        None => kernels::suite(),
    };
    let mut all_ok = true;
    for case in &cases {
        let out = verify_kernel(case, s.vlen, oracle.as_ref())?;
        let status = if out.passed { "OK " } else { "FAIL" };
        all_ok &= out.passed;
        println!("[{status}] {}", case.name);
        for (mode, name, d) in &out.vs_neon {
            println!("       {:<11} {:<4} vs NEON  max|d|={d:.2e}", format!("{mode:?}"), name);
        }
        for (name, d) in &out.vs_golden {
            println!("       NEON        {:<4} vs XLA   max|d|={d:.2e}", name);
        }
    }
    if !all_ok {
        bail!("verification failed");
    }
    println!("all {} kernels verified", cases.len());
    Ok(())
}

/// `verify --static`: admission-verify every program the pipeline can
/// produce — the static rule and every tuner candidate family
/// (`widen:*`, `lmul:*`, `force-baseline:*`) — for the full kernel suite
/// × both modes × the requested vlens, without executing anything. A
/// lowering that refuses to apply (unmappable types at this vlen, no
/// coalescible loop) is counted as not-applicable, not as a rejection.
fn verify_static_cmd(args: &Args) -> Result<()> {
    let vlens = args.get_u32_list("vlens", &[128, 256, 512])?;
    let mut admitted = 0usize;
    let mut not_applicable = 0usize;
    let mut rejected: Vec<String> = Vec::new();
    let mut check = |name: &str, mode: Mode, vlen: u32, id: &str,
                     lowered: Result<simde_rvv::rvv::program::RvvProgram>| {
        match lowered {
            Ok(rvv) => match simde_rvv::rvv::verify::verify(&rvv, vlen) {
                Ok(()) => admitted += 1,
                Err(e) => {
                    rejected.push(format!("{name} mode={} vlen={vlen} {id}: {e}", mode.name()));
                }
            },
            Err(_) => not_applicable += 1,
        }
    };
    for case in kernels::suite() {
        for mode in [Mode::Baseline, Mode::RvvCustom] {
            for &vlen in &vlens {
                let cfg = RvvConfig::new(vlen);
                check(
                    case.name,
                    mode,
                    vlen,
                    "static",
                    Translator::new(mode, cfg).translate(&case.prog).map(|(rvv, _)| rvv),
                );
                for cand in tuner::candidate::enumerate(&case.prog, mode, usize::MAX) {
                    if cand.is_static() {
                        continue;
                    }
                    check(
                        case.name,
                        mode,
                        vlen,
                        &cand.id(),
                        tuner::candidate::lower_with(&case.prog, mode, cfg, &cand)
                            .map(|(rvv, _)| rvv),
                    );
                }
            }
        }
    }
    for r in &rejected {
        eprintln!("REJECTED {r}");
    }
    println!(
        "verify --static: {admitted} program(s) admitted, {not_applicable} lowering(s) \
         not applicable, {} rejected",
        rejected.len()
    );
    if !rejected.is_empty() {
        bail!("{} program(s) rejected by the admission verifier", rejected.len());
    }
    Ok(())
}

fn translate_cmd(args: &Args) -> Result<()> {
    let s = settings(args)?;
    let k = args.get("kernel").context("--kernel required")?;
    let case = kernels::by_name(k).with_context(|| format!("unknown kernel '{k}'"))?;
    let mode_name = args.get("mode").unwrap_or("custom");
    let mode = Mode::parse(mode_name)
        .with_context(|| format!("bad --mode '{mode_name}' (baseline|custom)"))?;
    let tr = Translator::new(mode, RvvConfig::new(s.vlen));
    let (rp, rep) = tr.translate(&case.prog)?;
    println!("; {} translated with mode={} vlen={}", case.name, mode.name(), s.vlen);
    println!("; {} static RVV ops, methods: {:?}", rp.static_ops(), rep.count_by_method());
    print!("{}", rp.disasm());
    Ok(())
}

fn tune_cmd(args: &Args) -> Result<()> {
    let s = settings(args)?;
    let mut opts = if args.has("smoke") {
        // CI-sized search: one kernel, minimal candidate budget
        TunerOptions::smoke(s.vlen)
    } else {
        TunerOptions { vlens: args.get_u32_list("vlens", &[s.vlen])?, ..TunerOptions::default() }
    };
    if !args.has("smoke") {
        if let Some(ks) = args.get_str_list("kernel") {
            // kernels are keyed by 'static names; map through the suite list
            let mut names = Vec::new();
            for k in ks {
                let name = kernels::NAMES
                    .iter()
                    .copied()
                    .find(|n| *n == k)
                    .with_context(|| format!("unknown kernel '{k}'"))?;
                names.push(name);
            }
            opts.kernels = names;
        }
        if let Some(m) = args.get("mode") {
            let mode =
                Mode::parse(m).with_context(|| format!("bad --mode '{m}' (baseline|custom)"))?;
            opts.modes = vec![mode];
        }
        opts.max_candidates = args.get_usize("budget", opts.max_candidates)?;
    }
    let out = tuner::tune(&opts)?;
    print!("{}", report::tune_markdown(&out));
    for f in &out.faults {
        eprintln!("warning: candidate scored out by fault: {f}");
    }
    let path = Path::new(args.get("out").unwrap_or("TUNED.json"));
    out.db.save(path)?;
    println!("\ntuning database written to {}", path.display());
    Ok(())
}

fn sweep_cmd(args: &Args) -> Result<()> {
    let s = settings(args)?;
    let vlens = args.get_u32_list("vlens", &[128, 256, 512])?;
    println!("## A1 — vlen sweep (speedup = baseline/custom dynamic icount)\n");
    print!("| kernel |");
    for v in &vlens {
        print!(" vlen={v} |");
    }
    println!();
    print!("|---|");
    for _ in &vlens {
        print!("---:|");
    }
    println!();
    let per_vlen: Vec<_> = vlens
        .iter()
        .map(|&v| coordinator::figure2(v, s.threads))
        .collect::<Result<Vec<_>>>()?;
    for (i, name) in kernels::NAMES.iter().enumerate() {
        print!("| {name} |");
        for rows in &per_vlen {
            print!(" {:.2}x |", rows[i].speedup);
        }
        println!();
    }
    Ok(())
}

fn catalog_cmd(args: &Args) -> Result<()> {
    let pat = args.get("grep");
    let mut n = 0;
    for e in catalog::generate() {
        let keep = match pat {
            Some(p) => e.name.contains(p),
            None => true,
        };
        if keep {
            println!("{:<40} {}", e.name, e.ret.name());
            n += 1;
        }
    }
    eprintln!("{n} intrinsics");
    Ok(())
}
