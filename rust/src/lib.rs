//! # simde-rvv
//!
//! A reproduction of *"SIMD Everywhere Optimization from ARM NEON to RISC-V
//! Vector Extensions"* (CS.DC 2023) as a three-layer Rust + JAX/Pallas
//! system.
//!
//! The library contains:
//!
//! - [`neon`] — an executable ARM NEON semantic model (the migration
//!   *source* ISA) plus the full-surface intrinsic catalog (paper Table 1);
//! - [`rvv`] — a vector-length-agnostic RISC-V Vector semantic model (the
//!   migration *target* ISA);
//! - [`ir`] — the intrinsic-program IR kernels are written in;
//! - [`simde`] — the paper's contribution: the SIMDe-style translation
//!   engine with Table 2 type mapping and per-intrinsic conversion rules;
//! - [`sim`] — a Spike-like functional simulator producing the paper's
//!   dynamic-instruction-count metric;
//! - [`kernels`] — the 10 XNNPACK benchmark kernels in NEON IR (Figure 2);
//! - [`runtime`] — the JAX/XLA golden oracle loaded via PJRT;
//! - [`coordinator`] — the migration/benchmark pipeline;
//! - [`tuner`] — the lowering autotuner: candidate enumeration, search,
//!   and the persistent tuning database;
//! - [`report`] — Table 1 / Table 2 / Figure 2 emitters.

pub mod benchlib;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod ir;
pub mod kernels;
pub mod neon;
pub mod rvv;
pub mod sim;
pub mod report;
pub mod runtime;
pub mod simde;
pub mod testutil;
pub mod tuner;

/// Crate version string.
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
