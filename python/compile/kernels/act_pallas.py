"""Layer-1 Pallas kernel: fused elementwise activation over a flat array.

Covers the paper's elementwise benchmark ops (vrelu/vsqrt/vtanh/vsigmoid)
as one blocked Pallas kernel parameterised by the activation — the same
role XNNPACK's vunary microkernels play. interpret=True for CPU-PJRT
executability (see gemm_pallas.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_ACTS = {
    "relu": lambda x: jnp.maximum(x, 0.0),
    "sqrt": jnp.sqrt,
    "tanh": jnp.tanh,
    "sigmoid": lambda x: 1.0 / (1.0 + jnp.exp(-x)),
}


def _act_kernel(x_ref, o_ref, *, act):
    o_ref[...] = _ACTS[act](x_ref[...])


@functools.partial(jax.jit, static_argnames=("act", "block"))
def activation(x, *, act: str, block: int = 1024):
    """Apply `act` elementwise with a blocked Pallas kernel."""
    (n,) = x.shape
    block = min(block, n)
    assert n % block == 0, f"n={n} not divisible by block={block}"
    return pl.pallas_call(
        functools.partial(_act_kernel, act=act),
        grid=(n // block,),
        in_specs=[pl.BlockSpec((block,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), jnp.float32),
        interpret=True,
    )(x)
