"""Layer-1 Pallas kernel: tiled f32 GEMM microkernel.

The compute hot-spot of the XNNPACK workloads (gemm itself, and convhwc via
im2col) runs through this kernel in the L2 golden model. Tiling is
BlockSpec-driven: (BM, BK) x (BK, BN) tiles, accumulating into the output
tile across the K grid dimension (the innermost, sequential grid axis) —
MXU-shaped `jnp.dot` per tile step.

`interpret=True` always: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO so the Rust runtime can
run the artifact (see /opt/xla-example/README.md). Real-TPU VMEM/MXU
estimates are recorded in DESIGN.md §Perf.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref, *, k_steps):
    """One (BM, BN) output tile; grid axis 2 walks the K tiles."""
    k_idx = pl.program_id(2)

    @pl.when(k_idx == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        a_ref[...], b_ref[...], preferred_element_type=jnp.float32
    )
    del k_steps


@functools.partial(jax.jit, static_argnames=("bm", "bn", "bk"))
def gemm(a, b, *, bm: int = 32, bn: int = 32, bk: int = 32):
    """C = A @ B with a Pallas tiled microkernel (f32)."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims mismatch {k} vs {k2}"
    bm = min(bm, m)
    bn = min(bn, n)
    bk = min(bk, k)
    assert m % bm == 0 and n % bn == 0 and k % bk == 0, (
        f"shapes ({m},{k},{n}) not divisible by tiles ({bm},{bk},{bn})"
    )
    k_steps = k // bk
    return pl.pallas_call(
        functools.partial(_gemm_kernel, k_steps=k_steps),
        grid=(m // bm, n // bn, k_steps),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk, bn), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
