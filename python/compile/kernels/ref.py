"""Pure-jnp reference oracle for the 10 XNNPACK benchmark ops.

These references define the *mathematical* semantics the Rust kernels are
validated against. Shapes and layouts match rust/src/kernels/ exactly
(HWC layout, valid padding, the same bilinear corner formula, argmax tie =
first occurrence).
"""

import jax.numpy as jnp

IBILINEAR_WEIGHTS = (0.25, 0.75)


def gemm(a, b):
    """C[M,N] = A[M,K] @ B[K,N] (f32)."""
    return a @ b


def convhwc(i, w, bias):
    """3x3 valid conv, HWC in, HWC out; w layout (KH, KW, Cin, Cout)."""
    h = i.shape[0]
    oh = h - 2
    # im2col patches: (OH*OW, KH*KW*Cin)
    rows = []
    for ky in range(3):
        for kx in range(3):
            rows.append(i[ky : ky + oh, kx : kx + oh, :])
    patches = jnp.concatenate(rows, axis=-1).reshape(oh * oh, -1)
    wmat = w.reshape(-1, w.shape[-1])
    out = patches @ wmat + bias
    return out.reshape(oh, oh, w.shape[-1])


def dwconv(i, w, bias):
    """3x3 valid depthwise conv; w layout (KH*KW, C) flattened row-major."""
    h, _, c = i.shape
    oh = h - 2
    acc = jnp.broadcast_to(bias, (oh, oh, c))
    for ky in range(3):
        for kx in range(3):
            acc = acc + i[ky : ky + oh, kx : kx + oh, :] * w[ky * 3 + kx]
    return acc


def maxpool(i):
    """2x2 stride-2 max pooling, HWC."""
    h, _, c = i.shape
    oh = h // 2
    x = i.reshape(oh, 2, oh, 2, c)
    return x.max(axis=(1, 3))


def argmaxpool(i):
    """2x2 argmax pooling: (values, indices) with window order
    (0,0),(0,1),(1,0),(1,1) and first-max tie breaking."""
    h, _, c = i.shape
    oh = h // 2
    x = i.reshape(oh, 2, oh, 2, c)
    stacked = jnp.stack(
        [x[:, 0, :, 0], x[:, 0, :, 1], x[:, 1, :, 0], x[:, 1, :, 1]], axis=0
    )
    vals = stacked.max(axis=0)
    idxs = stacked.argmax(axis=0).astype(jnp.uint32)
    return vals, idxs


def vrelu(x):
    return jnp.maximum(x, 0.0)


def vsqrt(x):
    return jnp.sqrt(x)


def vtanh(x):
    return jnp.tanh(x)


def vsigmoid(x):
    return 1.0 / (1.0 + jnp.exp(-x))


def ibilinear(i):
    """2x bilinear upsampling with interior sample offsets {0.25, 0.75} --
    the exact corner formula of rust/src/kernels/ibilinear.rs."""
    h, _, c = i.shape
    oh = 2 * (h - 1)
    tl = i[:-1, :-1, :]
    tr = i[:-1, 1:, :]
    bl = i[1:, :-1, :]
    br = i[1:, 1:, :]
    out = jnp.zeros((oh, oh, c), dtype=i.dtype)
    for dy, wb in enumerate(IBILINEAR_WEIGHTS):
        for dx, wa in enumerate(IBILINEAR_WEIGHTS):
            top = tl + wa * (tr - tl)
            bot = bl + wa * (br - bl)
            px = top + wb * (bot - top)
            out = out.at[dy::2, dx::2, :].set(px)
    return out
