"""Layer-2 JAX model: the golden computations for the 10 XNNPACK benchmark
ops at the Figure-2 shapes, composed from the L1 Pallas kernels where the
compute is matmul/elementwise-shaped (gemm, convhwc-via-im2col, the four
v-ops) and plain jnp elsewhere.

Each entry in `GOLDEN` is (function, list of input ShapeDtypeStructs) whose
input order matches the Rust kernel's buffer declaration order — the Rust
runtime feeds its own input buffers positionally.
"""

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.act_pallas import activation
from .kernels.gemm_pallas import gemm as pallas_gemm

F32 = jnp.float32


def _spec(*shape):
    return jax.ShapeDtypeStruct(shape, F32)


# -- the ops, L1-composed ----------------------------------------------------


def gemm(a, b):
    return (pallas_gemm(a, b),)


def convhwc(i, w, bias):
    """im2col + the Pallas GEMM microkernel + bias."""
    h, _, cin = i.shape
    oh = h - 2
    cout = w.shape[-1]
    rows = []
    for ky in range(3):
        for kx in range(3):
            rows.append(i[ky : ky + oh, kx : kx + oh, :])
    patches = jnp.concatenate(rows, axis=-1).reshape(oh * oh, 9 * cin)
    wmat = w.reshape(9 * cin, cout)
    # tile sizes dividing (100, 72, 16)
    out = pallas_gemm(patches, wmat, bm=25, bn=cout, bk=9 * cin // 2) + bias
    return (out.reshape(oh, oh, cout),)


def dwconv(i, w, bias):
    return (ref.dwconv(i, w, bias),)


def maxpool(i):
    return (ref.maxpool(i),)


def argmaxpool(i):
    vals, idxs = ref.argmaxpool(i)
    return (vals, idxs)


def vrelu(x):
    return (activation(x, act="relu"),)


def vsqrt(x):
    return (activation(x, act="sqrt"),)


def vtanh(x):
    return (activation(x, act="tanh"),)


def vsigmoid(x):
    return (activation(x, act="sigmoid"),)


def ibilinear(i):
    return (ref.ibilinear(i),)


# -- the Figure-2 golden suite -------------------------------------------------

GOLDEN = {
    "gemm": (gemm, [_spec(64, 64), _spec(64, 64)]),
    "convhwc": (convhwc, [_spec(12, 12, 8), _spec(3, 3, 8, 16), _spec(16)]),
    "dwconv": (dwconv, [_spec(16, 16, 16), _spec(9, 16), _spec(16)]),
    "maxpool": (maxpool, [_spec(32, 32, 16)]),
    "argmaxpool": (argmaxpool, [_spec(32, 32, 16)]),
    "vrelu": (vrelu, [_spec(16384)]),
    "vsqrt": (vsqrt, [_spec(16384)]),
    "vtanh": (vtanh, [_spec(8192)]),
    "vsigmoid": (vsigmoid, [_spec(8192)]),
    "ibilinear": (ibilinear, [_spec(17, 17, 4)]),
}
