"""AOT lowering: jax -> HLO text artifacts for the Rust runtime.

HLO *text* (not serialized HloModuleProto) is the interchange format:
jax >= 0.5 emits protos with 64-bit instruction ids which the `xla` crate's
xla_extension 0.5.1 rejects (`proto.id() <= INT_MAX`); the text parser
reassigns ids, so text round-trips cleanly (see
/opt/xla-example/gen_hlo.py and README there).

Usage: cd python && python -m compile.aot --out ../artifacts
Writes artifacts/<op>.hlo.txt and artifacts/manifest.txt with lines
`<op>;<n_inputs>;<n_outputs>;<in shapes>;<out shapes>` for the runtime's
sanity checks. Build-time only; never on the Rust request path.
"""

import argparse
import os

import jax
from jax._src.lib import xla_client as xc

from .model import GOLDEN


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def shape_str(s) -> str:
    return "f32[" + ",".join(str(d) for d in s.shape) + "]"


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="lower a single op")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    manifest = []
    for name, (fn, specs) in GOLDEN.items():
        if args.only and name != args.only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        path = os.path.join(args.out, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        # output arity from an abstract eval
        outs = jax.eval_shape(fn, *specs)
        n_out = len(outs)
        in_shapes = "+".join(shape_str(s) for s in specs)
        out_shapes = "+".join(
            "{}[{}]".format(str(o.dtype), ",".join(str(d) for d in o.shape))
            for o in outs
        )
        manifest.append(f"{name};{len(specs)};{n_out};{in_shapes};{out_shapes}")
        print(f"wrote {path} ({len(text)} chars)")

    if not args.only:
        with open(os.path.join(args.out, "manifest.txt"), "w") as f:
            f.write("\n".join(manifest) + "\n")
        print(f"wrote manifest with {len(manifest)} ops")


if __name__ == "__main__":
    main()
