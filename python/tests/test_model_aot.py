"""L2 model + AOT path tests: golden functions match the oracle, every op
lowers to parseable HLO text, and the manifest matches the Rust kernels'
buffer layouts."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot
from compile.kernels import ref
from compile.model import GOLDEN


def rand_for(specs, seed=0):
    key = jax.random.PRNGKey(seed)
    out = []
    for s in specs:
        key, sub = jax.random.split(key)
        out.append(jax.random.uniform(sub, s.shape, s.dtype, -1, 1) + 1.5)
    return out


class TestGoldenSuite:
    def test_covers_the_ten_fig2_ops(self):
        assert sorted(GOLDEN) == sorted([
            "gemm", "convhwc", "dwconv", "maxpool", "argmaxpool",
            "vrelu", "vsqrt", "vtanh", "vsigmoid", "ibilinear",
        ])

    @pytest.mark.parametrize("name", sorted(GOLDEN))
    def test_golden_matches_oracle(self, name):
        fn, specs = GOLDEN[name]
        args = rand_for(specs, seed=hash(name) % 1000)
        got = fn(*args)
        want = {
            "gemm": lambda: (ref.gemm(*args),),
            "convhwc": lambda: (ref.convhwc(*args),),
            "dwconv": lambda: (ref.dwconv(*args),),
            "maxpool": lambda: (ref.maxpool(*args),),
            "argmaxpool": lambda: ref.argmaxpool(*args),
            "vrelu": lambda: (ref.vrelu(*args),),
            "vsqrt": lambda: (ref.vsqrt(*args),),
            "vtanh": lambda: (ref.vtanh(*args),),
            "vsigmoid": lambda: (ref.vsigmoid(*args),),
            "ibilinear": lambda: (ref.ibilinear(*args),),
        }[name]()
        assert len(got) == len(want)
        for g, w in zip(got, want):
            np.testing.assert_allclose(
                np.asarray(g, dtype=np.float64),
                np.asarray(w, dtype=np.float64),
                rtol=1e-4,
                atol=1e-5,
            )

    @pytest.mark.parametrize("name", sorted(GOLDEN))
    def test_lowering_produces_hlo_text(self, name):
        fn, specs = GOLDEN[name]
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert text.startswith("HloModule"), text[:60]
        assert "ENTRY" in text

    def test_vsqrt_positive_inputs_assumed(self):
        # the rust kernel takes positive inputs; golden uses +1.5 shift too
        fn, specs = GOLDEN["vsqrt"]
        (x,) = rand_for(specs)
        assert float(jnp.min(x)) > 0
