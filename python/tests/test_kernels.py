"""L1 Pallas kernels vs the pure-jnp oracle — the core correctness signal
of the compile path, including hypothesis shape/seed sweeps."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.act_pallas import activation
from compile.kernels.gemm_pallas import gemm as pallas_gemm


def rand(key, *shape):
    return jax.random.uniform(jax.random.PRNGKey(key), shape, jnp.float32, -1, 1)


class TestPallasGemm:
    def test_matches_ref_default(self):
        a = rand(0, 64, 64)
        b = rand(1, 64, 64)
        np.testing.assert_allclose(pallas_gemm(a, b), ref.gemm(a, b), rtol=1e-5, atol=1e-5)

    @pytest.mark.parametrize("m,k,n", [(32, 32, 32), (64, 32, 96), (96, 64, 32), (128, 128, 128)])
    def test_shapes(self, m, k, n):
        a = rand(m, m, k)
        b = rand(n, k, n)
        np.testing.assert_allclose(pallas_gemm(a, b), a @ b, rtol=1e-4, atol=1e-4)

    @pytest.mark.parametrize("bm,bn,bk", [(16, 16, 16), (32, 32, 32), (64, 64, 64), (32, 16, 64)])
    def test_tilings_agree(self, bm, bn, bk):
        a = rand(7, 64, 64)
        b = rand(8, 64, 64)
        got = pallas_gemm(a, b, bm=bm, bn=bn, bk=bk)
        np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)

    @settings(max_examples=20, deadline=None)
    @given(
        mt=st.integers(1, 4),
        kt=st.integers(1, 4),
        nt=st.integers(1, 4),
        seed=st.integers(0, 2**31 - 1),
    )
    def test_hypothesis_tile_multiples(self, mt, kt, nt, seed):
        m, k, n = 16 * mt, 16 * kt, 16 * nt
        a = rand(seed, m, k)
        b = rand(seed + 1, k, n)
        got = pallas_gemm(a, b, bm=16, bn=16, bk=16)
        np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)

    def test_rejects_mismatched_inner_dims(self):
        with pytest.raises(AssertionError):
            pallas_gemm(rand(0, 32, 16), rand(1, 32, 32))


class TestPallasActivation:
    @pytest.mark.parametrize("act,fn", [
        ("relu", ref.vrelu),
        ("tanh", ref.vtanh),
        ("sigmoid", ref.vsigmoid),
    ])
    def test_matches_ref(self, act, fn):
        x = rand(3, 4096) * 5
        np.testing.assert_allclose(activation(x, act=act), fn(x), rtol=1e-5, atol=1e-6)

    def test_sqrt_positive_domain(self):
        x = jnp.abs(rand(4, 4096)) * 100 + 0.01
        np.testing.assert_allclose(activation(x, act="sqrt"), jnp.sqrt(x), rtol=1e-6)

    @settings(max_examples=15, deadline=None)
    @given(blocks=st.integers(1, 8), block=st.sampled_from([64, 256, 1024]), seed=st.integers(0, 1 << 30))
    def test_hypothesis_blockings(self, blocks, block, seed):
        n = blocks * block
        x = rand(seed, n) * 3
        np.testing.assert_allclose(
            activation(x, act="tanh", block=block), jnp.tanh(x), rtol=1e-5, atol=1e-6
        )


class TestRefOracle:
    """Internal consistency of the oracle itself."""

    def test_maxpool_matches_loop(self):
        x = rand(0, 8, 8, 4)
        got = np.asarray(ref.maxpool(x))
        xn = np.asarray(x)
        for oy in range(4):
            for ox in range(4):
                for c in range(4):
                    want = xn[2 * oy : 2 * oy + 2, 2 * ox : 2 * ox + 2, c].max()
                    assert got[oy, ox, c] == want

    def test_argmaxpool_first_max_tiebreak(self):
        x = jnp.zeros((2, 2, 1), jnp.float32)  # all equal -> index 0
        vals, idxs = ref.argmaxpool(x)
        assert idxs.dtype == jnp.uint32
        assert int(idxs[0, 0, 0]) == 0

    def test_convhwc_matches_direct_loop(self):
        i = rand(1, 6, 6, 4)
        w = rand(2, 3, 3, 4, 8) * 0.5
        bias = rand(3, 8) * 0.1
        got = np.asarray(ref.convhwc(i, w, bias))
        (inp, wn, bn) = (np.asarray(i), np.asarray(w), np.asarray(bias))
        for oy in range(4):
            for ox in range(4):
                for co in range(8):
                    acc = bn[co]
                    for ky in range(3):
                        for kx in range(3):
                            for ci in range(4):
                                acc += inp[oy + ky, ox + kx, ci] * wn[ky, kx, ci, co]
                    assert abs(got[oy, ox, co] - acc) < 1e-4

    def test_ibilinear_corner_exactness(self):
        i = rand(5, 5, 5, 4)
        out = np.asarray(ref.ibilinear(i))
        assert out.shape == (8, 8, 4)
        inp = np.asarray(i)
        # spot-check pixel (0,0): weights (0.25, 0.25)
        tl, tr, bl = inp[0, 0, 0], inp[0, 1, 0], inp[1, 0, 0]
        br = inp[1, 1, 0]
        top = tl + 0.25 * (tr - tl)
        bot = bl + 0.25 * (br - bl)
        want = top + 0.25 * (bot - top)
        assert abs(out[0, 0, 0] - want) < 1e-6
